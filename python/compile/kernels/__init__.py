"""L1 Pallas kernels (build-time only; lowered into the L2 model's HLO)."""

from .attention import attention
from .fused_ffn import fused_ffn
from .layernorm import layernorm

__all__ = ["attention", "fused_ffn", "layernorm"]
