"""Pure-jnp reference oracles for the Pallas kernels.

Every kernel in this package has an entry here with identical semantics.
pytest asserts allclose(kernel, ref) across shapes/dtypes (hypothesis
sweeps); the backward-pass artifacts are derived from these references via
``jax.vjp``, so ref.py is the single source of mathematical truth for the
whole stack.
"""

import jax
import jax.numpy as jnp


def gelu(x):
    """tanh-approximation GELU (matches the kernel implementation)."""
    return 0.5 * x * (1.0 + jnp.tanh(0.7978845608028654 * (x + 0.044715 * x**3)))


def layernorm(x, gamma, beta, eps=1e-5):
    """LayerNorm over the last axis."""
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * gamma + beta


def ffn(x, w1, b1, w2, b2):
    """Two-layer feed-forward network with GELU: gelu(x@w1+b1)@w2+b2."""
    return gelu(x @ w1 + b1) @ w2 + b2


def attention(q, k, v, causal=True):
    """Scaled-dot-product attention.

    q, k, v: [heads, seq, head_dim] (batch folded into heads by callers).
    """
    d = q.shape[-1]
    scores = jnp.einsum("hqd,hkd->hqk", q, k) / jnp.sqrt(d).astype(q.dtype)
    if causal:
        s = q.shape[-2]
        mask = jnp.tril(jnp.ones((s, s), dtype=bool))
        scores = jnp.where(mask, scores, jnp.finfo(scores.dtype).min)
    probs = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("hqk,hkd->hqd", probs, v)
