"""L1 Pallas kernel: fused transformer feed-forward network.

Computes ``gelu(x @ w1 + b1) @ w2 + b2`` in one kernel so the [N, d_I]
intermediate never round-trips through HBM — the paper's hot spot is the
dense-layer matmul pair (Appendix C.1: the FFN holds 2/3 of the flops for
n_I = 4).

TPU mapping (DESIGN.md §Hardware-Adaptation): the grid tiles the token
axis; each program holds an (BN, D) input block, both weight matrices and
the (BN, d_I) intermediate in VMEM, feeding the MXU with [BN, D] x [D, d_I]
tiles. For the e2e shapes (D=1024, d_I=4096, BN=128, f32) the VMEM
footprint is 128*1024*4 + 1024*4096*4 + 128*4096*4 + 4096*1024*4 + 128*1024*4
≈ 36 MB in f32 — on a real TPU this would be bf16 weights (18 MB) double
buffered across two cores' 2x16 MB VMEM, or D-axis-split; under
interpret=True the BlockSpec still expresses that schedule.

interpret=True is mandatory here: real TPU lowering emits a Mosaic
custom-call the CPU PJRT plugin cannot execute.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ffn_kernel(x_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    x = x_ref[...]
    h = jnp.dot(x, w1_ref[...]) + b1_ref[...]
    # tanh-GELU, same constants as ref.gelu.
    g = 0.5 * h * (1.0 + jnp.tanh(0.7978845608028654 * (h + 0.044715 * h**3)))
    o_ref[...] = jnp.dot(g, w2_ref[...]) + b2_ref[...]


@functools.partial(jax.jit, static_argnames=("block_n",))
def fused_ffn(x, w1, b1, w2, b2, block_n=128):
    """Fused FFN over tokens.

    Args:
      x: [n, d] activations (token-major; callers flatten batch x seq).
      w1: [d, d_i]; b1: [d_i]; w2: [d_i, d]; b2: [d].
      block_n: token-block size (grid tile along n).
    Returns:
      [n, d] output.
    """
    n, d = x.shape
    d_i = w1.shape[1]
    bn = min(block_n, n)
    if n % bn != 0:
        # Fall back to one block for ragged sizes (shapes are static at
        # AOT time, so this is a compile-time choice, not a runtime one).
        bn = n
    grid = (n // bn,)
    return pl.pallas_call(
        _ffn_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((d, d_i), lambda i: (0, 0)),
            pl.BlockSpec((d_i,), lambda i: (0,)),
            pl.BlockSpec((d_i, d), lambda i: (0, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bn, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=True,
    )(x, w1, b1, w2, b2)


def vmem_bytes(n_block, d, d_i, dtype_bytes=4):
    """Static VMEM footprint estimate for one program (used by the
    DESIGN.md / EXPERIMENTS.md §Perf analysis)."""
    x = n_block * d
    w = 2 * d * d_i
    b = d_i + d
    inter = n_block * d_i
    out = n_block * d
    return (x + w + b + inter + out) * dtype_bytes


def mxu_utilisation_estimate(n_block, d, d_i):
    """Fraction of MXU-issue slots doing useful work for one program,
    assuming a 128x128 systolic array: full when all three matmul dims
    are multiples of 128."""
    def eff(dim):
        return dim / (((dim + 127) // 128) * 128)

    return eff(n_block) * eff(d) * eff(d_i)
