"""L1 Pallas kernel: causal scaled-dot-product attention with an online
softmax over key blocks (flash-attention-style streaming).

TPU mapping: grid = (heads, q-blocks). Each program streams the K/V
sequence in blocks through VMEM, maintaining the running max/denominator
pair, so the [S, S] score matrix never materialises in HBM — the same
memory-motion insight flash-attention expresses with CUDA threadblocks,
restated as a BlockSpec + fori_loop schedule for the MXU.

interpret=True for CPU-PJRT execution (see fused_ffn.py).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, o_ref, *, block_k, seq_len, causal):
    q = q_ref[...]  # [bq, d]
    bq, d = q.shape
    scale = 1.0 / jnp.sqrt(d).astype(q.dtype)
    q_idx = pl.program_id(1)

    neg = jnp.finfo(q.dtype).min

    def body(start, carry):
        acc, m, l = carry
        k = pl.load(k_ref, (pl.dslice(start * block_k, block_k), slice(None)))
        v = pl.load(v_ref, (pl.dslice(start * block_k, block_k), slice(None)))
        s = (q @ k.T) * scale  # [bq, bk]
        if causal:
            q_pos = q_idx * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 0)
            k_pos = start * block_k + jax.lax.broadcasted_iota(jnp.int32, (bq, block_k), 1)
            s = jnp.where(q_pos >= k_pos, s, neg)
        m_new = jnp.maximum(m, jnp.max(s, axis=-1))
        p = jnp.exp(s - m_new[:, None])
        alpha = jnp.exp(m - m_new)
        l_new = l * alpha + jnp.sum(p, axis=-1)
        acc_new = acc * alpha[:, None] + p @ v
        return acc_new, m_new, l_new

    n_blocks = seq_len // block_k
    acc = jnp.zeros((bq, d), dtype=q.dtype)
    m0 = jnp.full((bq,), neg, dtype=q.dtype)
    l0 = jnp.zeros((bq,), dtype=q.dtype)
    acc, m, l = jax.lax.fori_loop(0, n_blocks, body, (acc, m0, l0))
    o_ref[...] = acc / l[:, None]


@functools.partial(jax.jit, static_argnames=("block_q", "block_k", "causal"))
def attention(q, k, v, block_q=64, block_k=64, causal=True):
    """Streaming attention.

    Args:
      q, k, v: [h, s, d] (batch folded into the head axis by callers).
    Returns:
      [h, s, d] attention output.
    """
    h, s, d = q.shape
    bq = min(block_q, s)
    bk = min(block_k, s)
    if s % bq != 0:
        bq = s
    if s % bk != 0:
        bk = s
    grid = (h, s // bq)
    kernel = functools.partial(_attn_kernel, block_k=bk, seq_len=s, causal=causal)
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((None, bq, d), lambda hi, qi: (hi, qi, 0)),
            pl.BlockSpec((None, s, d), lambda hi, qi: (hi, 0, 0)),
            pl.BlockSpec((None, s, d), lambda hi, qi: (hi, 0, 0)),
        ],
        out_specs=pl.BlockSpec((None, bq, d), lambda hi, qi: (hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((h, s, d), q.dtype),
        interpret=True,
    )(q, k, v)
