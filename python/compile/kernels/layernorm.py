"""L1 Pallas kernel: fused LayerNorm over the feature axis.

One pass per token block: mean/variance reduction and the normalise +
affine transform fused, so x is read once from HBM instead of three times
(the memory-bound op the paper's §2.3 arithmetic-intensity discussion
flags — LN is ~1/6 flops/B and lives deep in the bandwidth-bound regime).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ln_kernel(x_ref, g_ref, b_ref, o_ref, *, eps):
    x = x_ref[...]
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    o_ref[...] = (x - mu) / jnp.sqrt(var + eps) * g_ref[...] + b_ref[...]


@functools.partial(jax.jit, static_argnames=("block_n", "eps"))
def layernorm(x, gamma, beta, block_n=256, eps=1e-5):
    """LayerNorm over the last axis of [n, d] activations."""
    n, d = x.shape
    bn = min(block_n, n)
    if n % bn != 0:
        bn = n
    return pl.pallas_call(
        functools.partial(_ln_kernel, eps=eps),
        grid=(n // bn,),
        in_specs=[
            pl.BlockSpec((bn, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bn, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), x.dtype),
        interpret=True,
    )(x, gamma, beta)
