"""AOT compiler: lower the L2 model's per-layer functions to HLO text
artifacts the Rust runtime loads via PJRT.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which the published xla
crate's xla_extension 0.5.1 rejects; the text parser reassigns ids, so
text round-trips cleanly (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --preset tiny --out ../artifacts
    python -m compile.aot --preset e2e --out ../artifacts

Writes  <out>/<preset>/<name>.hlo.txt  plus  <out>/<preset>/manifest.json
describing every artifact's argument shapes/dtypes and the model config
(the Rust side trusts the manifest, never re-deriving shapes).
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import (
    LAYER_PARAM_NAMES,
    PRESETS,
    ModelConfig,
    embed_bwd,
    embed_fwd,
    head_loss_grad,
    layer_bwd,
    layer_fwd,
)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    Rust side can uniformly unwrap tuples)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_artifacts(cfg: ModelConfig, batch: int):
    """Return {name: (callable, example_args)} for every artifact."""
    b, s, d, v = batch, cfg.d_seq, cfg.d_model, cfg.vocab
    layer_shapes = [cfg.layer_param_shapes()[n] for n in LAYER_PARAM_NAMES]
    layer_specs = [_spec(sh) for sh in layer_shapes]

    arts = {}
    arts["embed_fwd"] = (
        embed_fwd,
        (_spec((v, d)), _spec((s, d)), _spec((b, s), jnp.int32)),
    )
    arts["embed_bwd"] = (
        functools.partial(embed_bwd, vocab=v),
        (_spec((b, s, d)), _spec((b, s), jnp.int32)),
    )
    arts["layer_fwd"] = (
        lambda *a: layer_fwd(a[:12], a[12], cfg),
        (*layer_specs, _spec((b, s, d))),
    )
    arts["layer_bwd"] = (
        lambda *a: layer_bwd(a[:12], a[12], a[13], cfg),
        (*layer_specs, _spec((b, s, d)), _spec((b, s, d))),
    )
    arts["head_loss_grad"] = (
        head_loss_grad,
        (_spec((d, v)), _spec((b, s, d)), _spec((b, s), jnp.int32)),
    )
    return arts


def _manifest_io(args, fn):
    """Describe an artifact's inputs and outputs for the manifest."""
    out = jax.eval_shape(fn, *args)
    leaves = jax.tree_util.tree_leaves(out)
    return (
        [{"shape": list(a.shape), "dtype": str(a.dtype)} for a in args],
        [{"shape": list(o.shape), "dtype": str(o.dtype)} for o in leaves],
    )


def compile_preset(preset: str, out_dir: str, batch: int) -> dict:
    cfg = PRESETS[preset]
    os.makedirs(os.path.join(out_dir, preset), exist_ok=True)
    arts = build_artifacts(cfg, batch)
    manifest = {
        "preset": preset,
        "batch": batch,
        "model": {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_heads": cfg.n_heads,
            "d_seq": cfg.d_seq,
            "n_layers": cfg.n_layers,
            "d_ffn": cfg.d_ffn,
            "total_params": int(cfg.total_params()),
        },
        "layer_param_names": list(LAYER_PARAM_NAMES),
        "layer_param_shapes": {
            n: list(cfg.layer_param_shapes()[n]) for n in LAYER_PARAM_NAMES
        },
        "artifacts": {},
    }
    for name, (fn, args) in arts.items():
        # keep_unused: a VJP may not read some parameter *values* (e.g.
        # biases), but the Rust runtime passes every argument — the
        # artifact signature must stay stable.
        lowered = jax.jit(fn, keep_unused=True).lower(*args)
        text = to_hlo_text(lowered)
        rel = f"{preset}/{name}.hlo.txt"
        with open(os.path.join(out_dir, rel), "w") as f:
            f.write(text)
        inputs, outputs = _manifest_io(args, fn)
        manifest["artifacts"][name] = {
            "file": rel,
            "inputs": inputs,
            "outputs": outputs,
        }
        print(f"  {preset}/{name}: {len(text)} chars, "
              f"{len(inputs)} inputs -> {len(outputs)} outputs")
    with open(os.path.join(out_dir, preset, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--preset", default="all", choices=["all", *PRESETS])
    ap.add_argument("--batch", type=int, default=0,
                    help="micro-batch size baked into the artifacts "
                         "(default: 2 for tiny, 1 for e2e)")
    args = ap.parse_args()
    presets = list(PRESETS) if args.preset == "all" else [args.preset]
    for p in presets:
        batch = args.batch or (2 if p == "tiny" else 1)
        print(f"compiling preset {p} (micro-batch {batch})")
        m = compile_preset(p, args.out, batch)
        print(f"  model: {m['model']['total_params']:,} params")


if __name__ == "__main__":
    main()
