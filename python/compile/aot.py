"""AOT compiler: lower the L2 model's per-layer functions to HLO text
artifacts the Rust runtime loads via PJRT.

HLO *text* (not serialized HloModuleProto) is the interchange format: jax
>= 0.5 emits protos with 64-bit instruction ids which the published xla
crate's xla_extension 0.5.1 rejects; the text parser reassigns ids, so
text round-trips cleanly (see /opt/xla-example/README.md).

Usage:
    python -m compile.aot --preset tiny --out ../artifacts
    python -m compile.aot --preset e2e --out ../artifacts

Writes  <out>/<preset>/<name>.hlo.txt  plus  <out>/<preset>/manifest.json
describing every artifact's argument shapes/dtypes and the model config
(the Rust side trusts the manifest, never re-deriving shapes).
"""

import argparse
import functools
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from .model import (
    LAYER_PARAM_NAMES,
    PRESETS,
    ModelConfig,
    attn_bwd_part,
    attn_fwd_part,
    embed_bwd,
    embed_fwd,
    ffn_bwd_part,
    ffn_fwd_part,
    head_loss_grad,
    layer_bwd,
    layer_fwd,
    sharded_param_shapes,
    valid_tp_degrees,
)

# The four artifacts of one tensor-parallel shard degree (suffixed
# `_tp<d>`): the attention/FFN halves of the layer, forward and backward,
# with partial-sum outputs (see model.py's sharded-layer commentary).
TP_ARTIFACT_STEMS = ("attn_fwd", "ffn_fwd", "attn_bwd", "ffn_bwd")


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True so the
    Rust side can uniformly unwrap tuples)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def build_artifacts(cfg: ModelConfig, batch: int):
    """Return {name: (callable, example_args)} for every artifact."""
    b, s, d, v = batch, cfg.d_seq, cfg.d_model, cfg.vocab
    layer_shapes = [cfg.layer_param_shapes()[n] for n in LAYER_PARAM_NAMES]
    layer_specs = [_spec(sh) for sh in layer_shapes]

    arts = {}
    arts["embed_fwd"] = (
        embed_fwd,
        (_spec((v, d)), _spec((s, d)), _spec((b, s), jnp.int32)),
    )
    arts["embed_bwd"] = (
        functools.partial(embed_bwd, vocab=v),
        (_spec((b, s, d)), _spec((b, s), jnp.int32)),
    )
    arts["layer_fwd"] = (
        lambda *a: layer_fwd(a[:12], a[12], cfg),
        (*layer_specs, _spec((b, s, d))),
    )
    arts["layer_bwd"] = (
        lambda *a: layer_bwd(a[:12], a[12], a[13], cfg),
        (*layer_specs, _spec((b, s, d)), _spec((b, s, d))),
    )
    arts["head_loss_grad"] = (
        head_loss_grad,
        (_spec((d, v)), _spec((b, s, d)), _spec((b, s), jnp.int32)),
    )
    return arts


def build_tp_artifacts(cfg: ModelConfig, batch: int, tp: int):
    """Return {name: (callable, example_args)} for one shard degree."""
    shapes = sharded_param_shapes(cfg, tp)
    attn = [_spec(shapes[n]) for n in LAYER_PARAM_NAMES[:6]]
    ffn = [_spec(shapes[n]) for n in LAYER_PARAM_NAMES[6:]]
    act = _spec((batch, cfg.d_seq, cfg.d_model))
    stems = {
        "attn_fwd": (lambda *a, cfg, tp: attn_fwd_part(a[:6], a[6], cfg, tp),
                     (*attn, act)),
        "ffn_fwd": (lambda *a, cfg, tp: ffn_fwd_part(a[:6], a[6], cfg, tp),
                    (*ffn, act)),
        "attn_bwd": (lambda *a, cfg, tp: attn_bwd_part(a[:6], a[6], a[7], cfg, tp),
                     (*attn, act, act)),
        "ffn_bwd": (lambda *a, cfg, tp: ffn_bwd_part(a[:6], a[6], a[7], cfg, tp),
                    (*ffn, act, act)),
    }
    assert set(stems) == set(TP_ARTIFACT_STEMS)
    return {
        f"{stem}_tp{tp}": (functools.partial(stems[stem][0], cfg=cfg, tp=tp),
                           stems[stem][1])
        for stem in TP_ARTIFACT_STEMS
    }


def _manifest_io(args, fn):
    """Describe an artifact's inputs and outputs for the manifest."""
    out = jax.eval_shape(fn, *args)
    leaves = jax.tree_util.tree_leaves(out)
    return (
        [{"shape": list(a.shape), "dtype": str(a.dtype)} for a in args],
        [{"shape": list(o.shape), "dtype": str(o.dtype)} for o in leaves],
    )


def compile_preset(preset: str, out_dir: str, batch: int, tp_degrees=None) -> dict:
    """Compile one preset's artifacts. `tp_degrees` lists the tensor-
    parallel shard variants to emit alongside the unsharded set (default:
    [2] when the shape supports it); each degree adds the four `_tp<d>`
    half-layer artifacts and a `tp_shards` manifest entry carrying the
    per-rank parameter shapes (the Rust side never re-derives shapes)."""
    cfg = PRESETS[preset]
    if tp_degrees is None:
        tp_degrees = [t for t in valid_tp_degrees(cfg) if t == 2]
    for t in tp_degrees:
        assert t in valid_tp_degrees(cfg), f"{preset} does not support tp={t}"
    os.makedirs(os.path.join(out_dir, preset), exist_ok=True)
    arts = build_artifacts(cfg, batch)
    tp_of = {}
    for t in tp_degrees:
        for name, art in build_tp_artifacts(cfg, batch, t).items():
            arts[name] = art
            tp_of[name] = t
    manifest = {
        "preset": preset,
        "batch": batch,
        "model": {
            "vocab": cfg.vocab,
            "d_model": cfg.d_model,
            "n_heads": cfg.n_heads,
            "d_seq": cfg.d_seq,
            "n_layers": cfg.n_layers,
            "d_ffn": cfg.d_ffn,
            "total_params": int(cfg.total_params()),
        },
        "layer_param_names": list(LAYER_PARAM_NAMES),
        "layer_param_shapes": {
            n: list(cfg.layer_param_shapes()[n]) for n in LAYER_PARAM_NAMES
        },
        "tp_shards": {
            str(t): {
                "layer_param_shapes": {
                    n: list(sharded_param_shapes(cfg, t)[n])
                    for n in LAYER_PARAM_NAMES
                }
            }
            for t in tp_degrees
        },
        "artifacts": {},
    }
    for name, (fn, args) in arts.items():
        # keep_unused: a VJP may not read some parameter *values* (e.g.
        # biases), but the Rust runtime passes every argument — the
        # artifact signature must stay stable.
        lowered = jax.jit(fn, keep_unused=True).lower(*args)
        text = to_hlo_text(lowered)
        rel = f"{preset}/{name}.hlo.txt"
        with open(os.path.join(out_dir, rel), "w") as f:
            f.write(text)
        inputs, outputs = _manifest_io(args, fn)
        manifest["artifacts"][name] = {
            "file": rel,
            "tp": tp_of.get(name, 1),
            "inputs": inputs,
            "outputs": outputs,
        }
        print(f"  {preset}/{name}: {len(text)} chars, "
              f"{len(inputs)} inputs -> {len(outputs)} outputs")
    with open(os.path.join(out_dir, preset, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--preset", default="all", choices=["all", *PRESETS])
    ap.add_argument("--batch", type=int, default=0,
                    help="micro-batch size baked into the artifacts "
                         "(default: 2 for tiny, 1 for e2e)")
    ap.add_argument("--tp", default="2",
                    help="comma-separated tensor-parallel shard degrees to "
                         "emit (e.g. '2,4'); '0' emits none")
    args = ap.parse_args()
    presets = list(PRESETS) if args.preset == "all" else [args.preset]
    degrees = [int(t) for t in args.tp.split(",") if int(t) > 1]
    for p in presets:
        batch = args.batch or (2 if p == "tiny" else 1)
        tp = [t for t in degrees if t in valid_tp_degrees(PRESETS[p])]
        print(f"compiling preset {p} (micro-batch {batch}, tp variants {tp})")
        m = compile_preset(p, args.out, batch, tp_degrees=tp)
        print(f"  model: {m['model']['total_params']:,} params")


if __name__ == "__main__":
    main()
