"""L2: the transformer language model, built on the L1 Pallas kernels.

The model is decomposed into the per-layer / per-boundary functions the
Rust coordinator schedules independently (layered gradient accumulation
and modular pipeline parallelism need layer-granular artifacts, not one
monolithic train step):

  * ``embed_fwd``   — token + positional embedding lookup;
  * ``layer_fwd``   — one pre-LN transformer layer (Pallas kernels);
  * ``layer_bwd``   — VJP of the layer w.r.t. params and input, with the
                      activation recomputed from the checkpoint (the
                      paper's activation-checkpointing cost model: the
                      backward costs 3x the forward, Appendix C.1);
  * ``head_loss_grad`` — LM head + softmax cross-entropy, returning the
                      loss, input gradient and head-weight gradient;
  * ``embed_bwd``   — scatter-add gradient for the embedding tables.

Forward functions use the Pallas kernels; backward functions are the
``jax.vjp`` of the mathematically-identical jnp reference (kernels are
asserted equal to the reference in python/tests), so gradients are exact
for the function the forward computes.
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels import attention, fused_ffn, layernorm
from .kernels import ref

# Per-layer parameter layout, shared with the Rust runtime via the AOT
# manifest. Order matters.
LAYER_PARAM_NAMES = (
    "ln1_g", "ln1_b", "w_qkv", "b_qkv", "w_o", "b_o",
    "ln2_g", "ln2_b", "w1", "b1", "w2", "b2",
)


@dataclass(frozen=True)
class ModelConfig:
    """Static model shape (baked into the AOT artifacts)."""

    vocab: int
    d_model: int
    n_heads: int
    d_seq: int
    n_layers: int
    n_i: int = 4  # FFN expansion factor (paper Appendix B uses 4)

    @property
    def d_head(self):
        return self.d_model // self.n_heads

    @property
    def d_ffn(self):
        return self.n_i * self.d_model

    def layer_param_shapes(self):
        d, di = self.d_model, self.d_ffn
        return {
            "ln1_g": (d,), "ln1_b": (d,),
            "w_qkv": (d, 3 * d), "b_qkv": (3 * d,),
            "w_o": (d, d), "b_o": (d,),
            "ln2_g": (d,), "ln2_b": (d,),
            "w1": (d, di), "b1": (di,),
            "w2": (di, d), "b2": (d,),
        }

    def params_per_layer(self):
        return sum(
            int(jnp.prod(jnp.array(s))) for s in self.layer_param_shapes().values()
        )

    def total_params(self):
        embed = self.vocab * self.d_model + self.d_seq * self.d_model
        head = self.d_model * self.vocab
        return self.n_layers * self.params_per_layer() + embed + head


# Presets: "tiny" for tests, "mid" for loss-curve runs on the 1-core CI
# substrate, "e2e" is the ~100M-parameter end-to-end model.
PRESETS = {
    "tiny": ModelConfig(vocab=256, d_model=64, n_heads=4, d_seq=32, n_layers=2),
    "mid": ModelConfig(vocab=4096, d_model=512, n_heads=8, d_seq=64, n_layers=8),
    "e2e": ModelConfig(vocab=4096, d_model=1024, n_heads=16, d_seq=64, n_layers=8),
}


def _split_heads(x, n_heads):
    """[b, s, d] -> [b*h, s, d_head]."""
    b, s, d = x.shape
    x = x.reshape(b, s, n_heads, d // n_heads)
    return x.transpose(0, 2, 1, 3).reshape(b * n_heads, s, d // n_heads)


def _merge_heads(x, b):
    """[b*h, s, d_head] -> [b, s, d]."""
    bh, s, dh = x.shape
    h = bh // b
    return x.reshape(b, h, s, dh).transpose(0, 2, 1, 3).reshape(b, s, h * dh)


def _layer(x, p, cfg: ModelConfig, *, use_pallas: bool):
    """One pre-LN transformer layer. `p` is a dict of the 12 params."""
    ln = layernorm if use_pallas else ref.layernorm
    ffn_fn = fused_ffn if use_pallas else ref.ffn
    attn_fn = attention if use_pallas else ref.attention

    b, s, d = x.shape
    flat = lambda t: t.reshape(b * s, d)
    unflat = lambda t: t.reshape(b, s, d)

    h = unflat(ln(flat(x), p["ln1_g"], p["ln1_b"]))
    qkv = h @ p["w_qkv"] + p["b_qkv"]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q, k, v = (_split_heads(t, cfg.n_heads) for t in (q, k, v))
    ctx = _merge_heads(attn_fn(q, k, v), b)
    x = x + ctx @ p["w_o"] + p["b_o"]

    h2 = ln(flat(x), p["ln2_g"], p["ln2_b"])
    x = x + unflat(ffn_fn(h2, p["w1"], p["b1"], p["w2"], p["b2"]))
    return x


def layer_fwd(params, x, cfg: ModelConfig):
    """Forward through one layer (Pallas kernels). `params`: tuple in
    LAYER_PARAM_NAMES order; x: [b, s, d]."""
    p = dict(zip(LAYER_PARAM_NAMES, params))
    return _layer(x, p, cfg, use_pallas=True)


def layer_fwd_ref(params, x, cfg: ModelConfig):
    """Reference forward (pure jnp) — the function layer_bwd differentiates."""
    p = dict(zip(LAYER_PARAM_NAMES, params))
    return _layer(x, p, cfg, use_pallas=False)


def layer_bwd(params, x, dy, cfg: ModelConfig):
    """VJP of the layer w.r.t. (params, x). Recomputes the forward from
    the checkpoint `x` — activation checkpointing semantics."""
    _, vjp = jax.vjp(lambda ps, xx: layer_fwd_ref(ps, xx, cfg), params, x)
    dparams, dx = vjp(dy)
    return (*dparams, dx)


def embed_fwd(table, pos, tokens):
    """Token + positional embedding: [v,d],[s,d],[b,s]i32 -> [b,s,d]."""
    return table[tokens] + pos[None, :, :]


def embed_bwd(dx, tokens, vocab):
    """Gradients of embed_fwd: scatter-add into the token table, sum over
    batch for the positional table."""
    d_table = jnp.zeros((vocab, dx.shape[-1]), dx.dtype).at[tokens].add(dx)
    d_pos = dx.sum(axis=0)
    return d_table, d_pos


def head_loss(w_out, x, targets):
    """Mean softmax cross-entropy of the LM head logits x @ w_out."""
    logits = x @ w_out
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return jnp.mean(nll)


def head_loss_grad(w_out, x, targets):
    """(loss, dx, dw_out) for the LM head + loss."""
    loss, vjp = jax.vjp(lambda w, xx: head_loss(w, xx, targets), w_out, x)
    dw, dx = vjp(jnp.ones((), x.dtype))
    return loss, dx, dw


# ---------------------------------------------------------------------------
# Whole-model reference (used by tests and by aot.py's self-check, never
# exported to Rust — the Rust coordinator composes the per-layer pieces).
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key):
    """Initialise the full parameter set as (embed, pos, layers, head)."""
    keys = jax.random.split(key, 3 + cfg.n_layers)
    scale = 0.02
    table = scale * jax.random.normal(keys[0], (cfg.vocab, cfg.d_model), jnp.float32)
    pos = scale * jax.random.normal(keys[1], (cfg.d_seq, cfg.d_model), jnp.float32)
    head = scale * jax.random.normal(keys[2], (cfg.d_model, cfg.vocab), jnp.float32)
    layers = []
    for i in range(cfg.n_layers):
        lk = jax.random.split(keys[3 + i], 12)
        shapes = cfg.layer_param_shapes()
        layer = []
        for j, name in enumerate(LAYER_PARAM_NAMES):
            shape = shapes[name]
            if name.endswith("_g"):
                layer.append(jnp.ones(shape, jnp.float32))
            elif len(shape) == 1:
                layer.append(jnp.zeros(shape, jnp.float32))
            else:
                layer.append(scale * jax.random.normal(lk[j], shape, jnp.float32))
        layers.append(tuple(layer))
    return table, pos, tuple(layers), head


def model_loss(params, tokens, targets, cfg: ModelConfig, use_pallas=False):
    """Full-model loss (reference composition of the per-layer pieces)."""
    table, pos, layers, head = params
    x = embed_fwd(table, pos, tokens)
    fwd = layer_fwd if use_pallas else layer_fwd_ref
    for lp in layers:
        x = fwd(lp, x, cfg)
    return head_loss(head, x, targets)
