"""L2: the transformer language model, built on the L1 Pallas kernels.

The model is decomposed into the per-layer / per-boundary functions the
Rust coordinator schedules independently (layered gradient accumulation
and modular pipeline parallelism need layer-granular artifacts, not one
monolithic train step):

  * ``embed_fwd``   — token + positional embedding lookup;
  * ``layer_fwd``   — one pre-LN transformer layer (Pallas kernels);
  * ``layer_bwd``   — VJP of the layer w.r.t. params and input, with the
                      activation recomputed from the checkpoint (the
                      paper's activation-checkpointing cost model: the
                      backward costs 3x the forward, Appendix C.1);
  * ``head_loss_grad`` — LM head + softmax cross-entropy, returning the
                      loss, input gradient and head-weight gradient;
  * ``embed_bwd``   — scatter-add gradient for the embedding tables.

Forward functions use the Pallas kernels; backward functions are the
``jax.vjp`` of the mathematically-identical jnp reference (kernels are
asserted equal to the reference in python/tests), so gradients are exact
for the function the forward computes.
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from .kernels import attention, fused_ffn, layernorm
from .kernels import ref

# Per-layer parameter layout, shared with the Rust runtime via the AOT
# manifest. Order matters.
LAYER_PARAM_NAMES = (
    "ln1_g", "ln1_b", "w_qkv", "b_qkv", "w_o", "b_o",
    "ln2_g", "ln2_b", "w1", "b1", "w2", "b2",
)


@dataclass(frozen=True)
class ModelConfig:
    """Static model shape (baked into the AOT artifacts)."""

    vocab: int
    d_model: int
    n_heads: int
    d_seq: int
    n_layers: int
    n_i: int = 4  # FFN expansion factor (paper Appendix B uses 4)

    @property
    def d_head(self):
        return self.d_model // self.n_heads

    @property
    def d_ffn(self):
        return self.n_i * self.d_model

    def layer_param_shapes(self):
        d, di = self.d_model, self.d_ffn
        return {
            "ln1_g": (d,), "ln1_b": (d,),
            "w_qkv": (d, 3 * d), "b_qkv": (3 * d,),
            "w_o": (d, d), "b_o": (d,),
            "ln2_g": (d,), "ln2_b": (d,),
            "w1": (d, di), "b1": (di,),
            "w2": (di, d), "b2": (d,),
        }

    def params_per_layer(self):
        return sum(
            int(jnp.prod(jnp.array(s))) for s in self.layer_param_shapes().values()
        )

    def total_params(self):
        embed = self.vocab * self.d_model + self.d_seq * self.d_model
        head = self.d_model * self.vocab
        return self.n_layers * self.params_per_layer() + embed + head


# Presets: "tiny" for tests, "mid" for loss-curve runs on the 1-core CI
# substrate, "e2e" is the ~100M-parameter end-to-end model.
PRESETS = {
    "tiny": ModelConfig(vocab=256, d_model=64, n_heads=4, d_seq=32, n_layers=2),
    "mid": ModelConfig(vocab=4096, d_model=512, n_heads=8, d_seq=64, n_layers=8),
    "e2e": ModelConfig(vocab=4096, d_model=1024, n_heads=16, d_seq=64, n_layers=8),
}


def _split_heads(x, n_heads):
    """[b, s, d] -> [b*h, s, d_head]."""
    b, s, d = x.shape
    x = x.reshape(b, s, n_heads, d // n_heads)
    return x.transpose(0, 2, 1, 3).reshape(b * n_heads, s, d // n_heads)


def _merge_heads(x, b):
    """[b*h, s, d_head] -> [b, s, d]."""
    bh, s, dh = x.shape
    h = bh // b
    return x.reshape(b, h, s, dh).transpose(0, 2, 1, 3).reshape(b, s, h * dh)


def _layer(x, p, cfg: ModelConfig, *, use_pallas: bool):
    """One pre-LN transformer layer. `p` is a dict of the 12 params."""
    ln = layernorm if use_pallas else ref.layernorm
    ffn_fn = fused_ffn if use_pallas else ref.ffn
    attn_fn = attention if use_pallas else ref.attention

    b, s, d = x.shape
    flat = lambda t: t.reshape(b * s, d)
    unflat = lambda t: t.reshape(b, s, d)

    h = unflat(ln(flat(x), p["ln1_g"], p["ln1_b"]))
    qkv = h @ p["w_qkv"] + p["b_qkv"]
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q, k, v = (_split_heads(t, cfg.n_heads) for t in (q, k, v))
    ctx = _merge_heads(attn_fn(q, k, v), b)
    x = x + ctx @ p["w_o"] + p["b_o"]

    h2 = ln(flat(x), p["ln2_g"], p["ln2_b"])
    x = x + unflat(ffn_fn(h2, p["w1"], p["b1"], p["w2"], p["b2"]))
    return x


def layer_fwd(params, x, cfg: ModelConfig):
    """Forward through one layer (Pallas kernels). `params`: tuple in
    LAYER_PARAM_NAMES order; x: [b, s, d]."""
    p = dict(zip(LAYER_PARAM_NAMES, params))
    return _layer(x, p, cfg, use_pallas=True)


def layer_fwd_ref(params, x, cfg: ModelConfig):
    """Reference forward (pure jnp) — the function layer_bwd differentiates."""
    p = dict(zip(LAYER_PARAM_NAMES, params))
    return _layer(x, p, cfg, use_pallas=False)


def layer_bwd(params, x, dy, cfg: ModelConfig):
    """VJP of the layer w.r.t. (params, x). Recomputes the forward from
    the checkpoint `x` — activation checkpointing semantics."""
    _, vjp = jax.vjp(lambda ps, xx: layer_fwd_ref(ps, xx, cfg), params, x)
    dparams, dx = vjp(dy)
    return (*dparams, dx)


def embed_fwd(table, pos, tokens):
    """Token + positional embedding: [v,d],[s,d],[b,s]i32 -> [b,s,d]."""
    return table[tokens] + pos[None, :, :]


def embed_bwd(dx, tokens, vocab):
    """Gradients of embed_fwd: scatter-add into the token table, sum over
    batch for the positional table."""
    d_table = jnp.zeros((vocab, dx.shape[-1]), dx.dtype).at[tokens].add(dx)
    d_pos = dx.sum(axis=0)
    return d_table, d_pos


def head_loss(w_out, x, targets):
    """Mean softmax cross-entropy of the LM head logits x @ w_out."""
    logits = x @ w_out
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return jnp.mean(nll)


def head_loss_grad(w_out, x, targets):
    """(loss, dx, dw_out) for the LM head + loss."""
    loss, vjp = jax.vjp(lambda w, xx: head_loss(w, xx, targets), w_out, x)
    dw, dx = vjp(jnp.ones((), x.dtype))
    return loss, dx, dw


# ---------------------------------------------------------------------------
# Tensor-parallel sharded layer (Megatron-style column/row-parallel cuts).
#
# The layer splits into two halves at the residual boundaries:
#
#   x2 = x  + sum_r attn_part_r(x)      (heads sharded d_a/tp; w_o row-
#                                        parallel => partial-sum output)
#   y  = x2 + sum_r ffn_part_r(x2)      (w1 column-parallel, w2 row-
#                                        parallel => partial-sum output)
#
# Each rank computes a *partial* half-layer; the cross-rank sums are ring
# all-reduces in the Rust runtime (the mid-layer one inside the Fwd/Bwd
# op, the layer-boundary one being the scheduled ``TensorAllReduce``).
# Head sharding and the column-parallel first GEMMs are bitwise-exact
# under sharding (each output column sees the identical contraction);
# only the row-parallel partial sums reassociate one reduction axis.
#
# Biases that are added *after* a partial sum (b_o, b2) must enter the
# function exactly once: [`shard_layer_params`] zeroes them on every rank
# but rank 0 (the stored parameter stays replicated — only the artifact
# input is zeroed). Their gradients are nevertheless full and identical
# on every rank (the bias is an additive constant of each rank's
# partial), while the layernorm parameter gradients flow through the
# sharded GEMMs and are *partial* per rank — the runtime tp-all-reduces
# them at gradient-reduction time.
# ---------------------------------------------------------------------------

# The attention half owns the first six parameters, the FFN half the rest.
ATTN_PARAM_NAMES = LAYER_PARAM_NAMES[:6]
FFN_PARAM_NAMES = LAYER_PARAM_NAMES[6:]


def valid_tp_degrees(cfg: ModelConfig):
    """Shard counts the model shape supports: tp must divide the head
    count (head sharding) and the FFN intermediate (column sharding)."""
    return [
        t
        for t in (2, 4, 8, 16, 32)
        if t <= cfg.n_heads and cfg.n_heads % t == 0 and cfg.d_ffn % t == 0
    ]


def sharded_param_shapes(cfg: ModelConfig, tp: int):
    """Per-rank parameter shapes at shard degree `tp` (rank-independent).

    Layernorm parameters and the post-reduce biases stay replicated;
    w_qkv/b_qkv shard by heads (the same fraction of each of the fused
    q|k|v column groups), w1/b1 column-parallel, w_o/w2 row-parallel.
    """
    d, di = cfg.d_model, cfg.d_ffn
    assert cfg.n_heads % tp == 0 and di % tp == 0, (cfg, tp)
    return {
        "ln1_g": (d,), "ln1_b": (d,),
        "w_qkv": (d, 3 * d // tp), "b_qkv": (3 * d // tp,),
        "w_o": (d // tp, d), "b_o": (d,),
        "ln2_g": (d,), "ln2_b": (d,),
        "w1": (d, di // tp), "b1": (di // tp,),
        "w2": (di // tp, d), "b2": (d,),
    }


def shard_layer_params(cfg: ModelConfig, params, tp: int, rank: int):
    """Slice one rank's parameter shard out of the full 12-tuple.

    Returns a tuple in LAYER_PARAM_NAMES order with the shapes of
    [`sharded_param_shapes`]. b_o/b2 are zeroed for rank > 0 so the
    summed partials apply each post-reduce bias exactly once.
    """
    p = dict(zip(LAYER_PARAM_NAMES, params))
    d, di = cfg.d_model, cfg.d_ffn
    lo, hi = rank * d // tp, (rank + 1) * d // tp
    flo, fhi = rank * di // tp, (rank + 1) * di // tp
    once = lambda t: t if rank == 0 else jnp.zeros_like(t)
    out = {
        "ln1_g": p["ln1_g"], "ln1_b": p["ln1_b"],
        "w_qkv": jnp.concatenate(
            [p["w_qkv"][:, g * d + lo : g * d + hi] for g in range(3)], axis=1
        ),
        "b_qkv": jnp.concatenate(
            [p["b_qkv"][g * d + lo : g * d + hi] for g in range(3)]
        ),
        "w_o": p["w_o"][lo:hi, :],
        "b_o": once(p["b_o"]),
        "ln2_g": p["ln2_g"], "ln2_b": p["ln2_b"],
        "w1": p["w1"][:, flo:fhi], "b1": p["b1"][flo:fhi],
        "w2": p["w2"][flo:fhi, :],
        "b2": once(p["b2"]),
    }
    return tuple(out[n] for n in LAYER_PARAM_NAMES)


def attn_fwd_part(params6, x, cfg: ModelConfig, tp: int):
    """One rank's partial attention-block contribution.

    `params6`: (ln1_g, ln1_b, w_qkv, b_qkv, w_o, b_o) sharded per
    [`sharded_param_shapes`]; x: the full [b, s, d] layer input. Returns
    the [b, s, d] partial; x2 = x + sum over ranks of the partials.
    """
    ln1_g, ln1_b, w_qkv, b_qkv, w_o, b_o = params6
    b, s, d = x.shape
    h = ref.layernorm(x.reshape(b * s, d), ln1_g, ln1_b).reshape(b, s, d)
    qkv = h @ w_qkv + b_qkv
    q, k, v = jnp.split(qkv, 3, axis=-1)
    h_loc = cfg.n_heads // tp
    q, k, v = (_split_heads(t, h_loc) for t in (q, k, v))
    ctx = _merge_heads(ref.attention(q, k, v), b)
    return ctx @ w_o + b_o


def ffn_fwd_part(params6, x2, cfg: ModelConfig, tp: int):
    """One rank's partial FFN-block contribution (column-parallel w1,
    row-parallel w2). y = x2 + sum over ranks of the partials."""
    ln2_g, ln2_b, w1, b1, w2, b2 = params6
    b, s, d = x2.shape
    h2 = ref.layernorm(x2.reshape(b * s, d), ln2_g, ln2_b)
    return ref.ffn(h2, w1, b1, w2, b2).reshape(b, s, d)


def attn_bwd_part(params6, x, dy2, cfg: ModelConfig, tp: int):
    """VJP of [`attn_fwd_part`] w.r.t. (shard params, x) for the full
    upstream gradient dy2 = dL/dx2. Returns (*shard param grads,
    dx_partial); the true dx = dy2 + sum over ranks of dx_partial."""
    _, vjp = jax.vjp(lambda ps, xx: attn_fwd_part(ps, xx, cfg, tp), tuple(params6), x)
    dps, dx = vjp(dy2)
    return (*dps, dx)


def ffn_bwd_part(params6, x2, dy, cfg: ModelConfig, tp: int):
    """VJP of [`ffn_fwd_part`] w.r.t. (shard params, x2) for the full
    upstream gradient dy. Returns (*shard param grads, dx2_partial); the
    true dx2 = dy + sum over ranks of dx2_partial."""
    _, vjp = jax.vjp(lambda ps, xx: ffn_fwd_part(ps, xx, cfg, tp), tuple(params6), x2)
    dps, dx2 = vjp(dy)
    return (*dps, dx2)


def sharded_layer_fwd(params, x, cfg: ModelConfig, tp: int):
    """Reference composition of the sharded pieces (host-side sums in
    place of the runtime's ring all-reduces) — the oracle the property
    tests compare against [`layer_fwd_ref`]."""
    shards = [shard_layer_params(cfg, params, tp, r) for r in range(tp)]
    x2 = x + sum(attn_fwd_part(s[:6], x, cfg, tp) for s in shards)
    return x2 + sum(ffn_fwd_part(s[6:], x2, cfg, tp) for s in shards)


# ---------------------------------------------------------------------------
# Whole-model reference (used by tests and by aot.py's self-check, never
# exported to Rust — the Rust coordinator composes the per-layer pieces).
# ---------------------------------------------------------------------------

def init_params(cfg: ModelConfig, key):
    """Initialise the full parameter set as (embed, pos, layers, head)."""
    keys = jax.random.split(key, 3 + cfg.n_layers)
    scale = 0.02
    table = scale * jax.random.normal(keys[0], (cfg.vocab, cfg.d_model), jnp.float32)
    pos = scale * jax.random.normal(keys[1], (cfg.d_seq, cfg.d_model), jnp.float32)
    head = scale * jax.random.normal(keys[2], (cfg.d_model, cfg.vocab), jnp.float32)
    layers = []
    for i in range(cfg.n_layers):
        lk = jax.random.split(keys[3 + i], 12)
        shapes = cfg.layer_param_shapes()
        layer = []
        for j, name in enumerate(LAYER_PARAM_NAMES):
            shape = shapes[name]
            if name.endswith("_g"):
                layer.append(jnp.ones(shape, jnp.float32))
            elif len(shape) == 1:
                layer.append(jnp.zeros(shape, jnp.float32))
            else:
                layer.append(scale * jax.random.normal(lk[j], shape, jnp.float32))
        layers.append(tuple(layer))
    return table, pos, tuple(layers), head


def model_loss(params, tokens, targets, cfg: ModelConfig, use_pallas=False):
    """Full-model loss (reference composition of the per-layer pieces)."""
    table, pos, layers, head = params
    x = embed_fwd(table, pos, tokens)
    fwd = layer_fwd if use_pallas else layer_fwd_ref
    for lp in layers:
        x = fwd(lp, x, cfg)
    return head_loss(head, x, targets)
