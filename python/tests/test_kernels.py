"""L1 kernel correctness: Pallas kernels vs the pure-jnp oracles in
ref.py, swept over shapes and dtypes with hypothesis."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import attention, fused_ffn, layernorm
from compile.kernels import ref
from compile.kernels.fused_ffn import mxu_utilisation_estimate, vmem_bytes

jax.config.update("jax_enable_x64", False)


def rand(rng, *shape, scale=1.0, dtype=jnp.float32):
    return jnp.asarray(rng.normal(size=shape) * scale, dtype=dtype)


TOL = {jnp.float32: 2e-5}


# ---------------------------------------------------------------------------
# fused FFN
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([1, 3, 16, 64, 130]),
    d=st.sampled_from([8, 16, 64]),
    n_i=st.sampled_from([2, 4]),
    block=st.sampled_from([16, 64, 128]),
    seed=st.integers(0, 2**16),
)
def test_ffn_matches_ref(n, d, n_i, block, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, n, d)
    w1 = rand(rng, d, n_i * d, scale=d**-0.5)
    b1 = rand(rng, n_i * d, scale=0.1)
    w2 = rand(rng, n_i * d, d, scale=(n_i * d) ** -0.5)
    b2 = rand(rng, d, scale=0.1)
    got = fused_ffn(x, w1, b1, w2, b2, block_n=block)
    want = ref.ffn(x, w1, b1, w2, b2)
    np.testing.assert_allclose(got, want, atol=5e-5, rtol=5e-5)


def test_ffn_block_size_does_not_change_result():
    rng = np.random.default_rng(7)
    x = rand(rng, 256, 32)
    w1, b1 = rand(rng, 32, 128, scale=0.2), rand(rng, 128, scale=0.1)
    w2, b2 = rand(rng, 128, 32, scale=0.1), rand(rng, 32, scale=0.1)
    outs = [fused_ffn(x, w1, b1, w2, b2, block_n=bn) for bn in (16, 32, 256)]
    for o in outs[1:]:
        np.testing.assert_allclose(o, outs[0], atol=1e-5, rtol=1e-5)


def test_ffn_vmem_estimate_monotone_in_block():
    assert vmem_bytes(128, 1024, 4096) < vmem_bytes(256, 1024, 4096)


def test_ffn_mxu_estimate_full_for_aligned_shapes():
    assert mxu_utilisation_estimate(128, 1024, 4096) == 1.0
    assert mxu_utilisation_estimate(100, 1024, 4096) < 1.0


# ---------------------------------------------------------------------------
# layernorm
# ---------------------------------------------------------------------------

@settings(max_examples=20, deadline=None)
@given(
    n=st.sampled_from([1, 5, 32, 257]),
    d=st.sampled_from([4, 32, 128]),
    seed=st.integers(0, 2**16),
)
def test_layernorm_matches_ref(n, d, seed):
    rng = np.random.default_rng(seed)
    x = rand(rng, n, d, scale=3.0)
    g = rand(rng, d, scale=1.0)
    b = rand(rng, d, scale=0.5)
    np.testing.assert_allclose(
        layernorm(x, g, b), ref.layernorm(x, g, b), atol=2e-5, rtol=2e-5
    )


def test_layernorm_output_is_normalised():
    rng = np.random.default_rng(3)
    x = rand(rng, 64, 128, scale=10.0)
    y = layernorm(x, jnp.ones(128), jnp.zeros(128))
    np.testing.assert_allclose(np.mean(y, axis=-1), 0.0, atol=1e-4)
    np.testing.assert_allclose(np.std(y, axis=-1), 1.0, atol=1e-2)


# ---------------------------------------------------------------------------
# attention
# ---------------------------------------------------------------------------

@settings(max_examples=16, deadline=None)
@given(
    h=st.sampled_from([1, 2, 8]),
    s=st.sampled_from([16, 64, 96]),
    d=st.sampled_from([4, 16, 32]),
    block=st.sampled_from([16, 32]),
    causal=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_attention_matches_ref(h, s, d, block, causal, seed):
    rng = np.random.default_rng(seed)
    q, k, v = (rand(rng, h, s, d) for _ in range(3))
    got = attention(q, k, v, block_q=block, block_k=block, causal=causal)
    want = ref.attention(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, atol=2e-5, rtol=2e-5)


def test_attention_causality():
    """Changing a future key/value must not change earlier outputs."""
    rng = np.random.default_rng(11)
    q, k, v = (rand(rng, 2, 32, 8) for _ in range(3))
    base = attention(q, k, v, block_q=16, block_k=16, causal=True)
    k2 = k.at[:, -1, :].set(99.0)
    v2 = v.at[:, -1, :].set(-99.0)
    pert = attention(q, k2, v2, block_q=16, block_k=16, causal=True)
    np.testing.assert_allclose(base[:, :-1], pert[:, :-1], atol=1e-6)
    assert not np.allclose(base[:, -1], pert[:, -1])


def test_attention_softmax_rows_bounded():
    """Output rows are convex combinations of V rows (within fp error)."""
    rng = np.random.default_rng(5)
    q, k = rand(rng, 1, 32, 8), rand(rng, 1, 32, 8)
    v = jnp.ones((1, 32, 8), jnp.float32)
    out = attention(q, k, v, causal=False)
    np.testing.assert_allclose(out, 1.0, atol=1e-5)


def test_attention_block_invariance():
    rng = np.random.default_rng(13)
    q, k, v = (rand(rng, 4, 64, 16) for _ in range(3))
    a = attention(q, k, v, block_q=64, block_k=64)
    b = attention(q, k, v, block_q=16, block_k=32)
    np.testing.assert_allclose(a, b, atol=2e-5, rtol=2e-5)
