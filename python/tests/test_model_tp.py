"""Property tests for the tensor-parallel sharded layer math.

The claims the Rust runtime relies on, proved at the jnp level:

* head-sharded attention and the column-parallel first GEMMs are
  **bitwise** equal to the unsharded reference (each output column sees
  the identical contraction — sharding removes columns, it does not
  reassociate them);
* the row-parallel second GEMMs produce partial sums whose cross-rank
  total matches the unsharded layer within a scaled-ulp tolerance (one
  reduction axis is reassociated);
* the sharded backward halves compose to the exact VJP of the layer
  (gradients match jax.vjp of the unsharded reference within tolerance),
  with the post-reduce bias gradients (b_o, b2) replicated full on every
  rank and the layernorm gradients partial (summing to the truth).
"""

import jax
import jax.numpy as jnp
import pytest

from compile.model import (
    ATTN_PARAM_NAMES,
    FFN_PARAM_NAMES,
    LAYER_PARAM_NAMES,
    PRESETS,
    attn_bwd_part,
    attn_fwd_part,
    ffn_bwd_part,
    ffn_fwd_part,
    init_params,
    layer_fwd_ref,
    shard_layer_params,
    sharded_layer_fwd,
    sharded_param_shapes,
    valid_tp_degrees,
)
from compile.kernels import ref

CFG = PRESETS["tiny"]
BATCH = 2


@pytest.fixture(scope="module")
def layer_and_input():
    key = jax.random.PRNGKey(7)
    _, _, layers, _ = init_params(CFG, key)
    x = 0.5 * jax.random.normal(
        jax.random.PRNGKey(8), (BATCH, CFG.d_seq, CFG.d_model), jnp.float32
    )
    return layers[0], x


def test_valid_tp_degrees_divide_heads_and_ffn():
    assert valid_tp_degrees(CFG) == [2, 4]
    for t in valid_tp_degrees(CFG):
        assert CFG.n_heads % t == 0 and CFG.d_ffn % t == 0


@pytest.mark.parametrize("tp", [2, 4])
def test_shard_shapes_match_sliced_params(layer_and_input, tp):
    params, _ = layer_and_input
    shapes = sharded_param_shapes(CFG, tp)
    for r in range(tp):
        shard = shard_layer_params(CFG, params, tp, r)
        for name, t in zip(LAYER_PARAM_NAMES, shard):
            assert t.shape == shapes[name], (name, r)


@pytest.mark.parametrize("tp", [2, 4])
def test_shards_partition_the_sharded_tensors(layer_and_input, tp):
    """Concatenating every rank's shard reconstructs the full tensor
    bitwise (cols for w_qkv/b_qkv/w1/b1, rows for w_o/w2)."""
    params, _ = layer_and_input
    p = dict(zip(LAYER_PARAM_NAMES, params))
    shards = [
        dict(zip(LAYER_PARAM_NAMES, shard_layer_params(CFG, params, tp, r)))
        for r in range(tp)
    ]
    d = CFG.d_model
    # w1/b1: plain column concat. w_o/w2: row concat.
    for name, axis in [("w1", 1), ("b1", 0), ("w_o", 0), ("w2", 0)]:
        full = jnp.concatenate([s[name] for s in shards], axis=axis)
        assert (full == p[name]).all(), name
    # w_qkv/b_qkv: concat within each of the q|k|v groups.
    for g in range(3):
        got = jnp.concatenate(
            [s["w_qkv"][:, g * d // tp : (g + 1) * d // tp] for s in shards], axis=1
        )
        assert (got == p["w_qkv"][:, g * d : (g + 1) * d]).all()


@pytest.mark.parametrize("tp", [2, 4])
def test_head_sharded_context_is_bitwise_exact(layer_and_input, tp):
    """Up to the row-parallel projection, the sharded attention is a
    column selection of the unsharded one: qkv GEMM columns and per-head
    context outputs match bitwise."""
    params, x = layer_and_input
    p = dict(zip(LAYER_PARAM_NAMES, params))
    b, s, d = x.shape
    h = ref.layernorm(x.reshape(b * s, d), p["ln1_g"], p["ln1_b"]).reshape(b, s, d)

    def context(w_qkv, b_qkv, n_heads):
        qkv = h @ w_qkv + b_qkv
        q, k, v = jnp.split(qkv, 3, axis=-1)
        from compile.model import _merge_heads, _split_heads

        q, k, v = (_split_heads(t, n_heads) for t in (q, k, v))
        return _merge_heads(ref.attention(q, k, v), b)

    full_ctx = context(p["w_qkv"], p["b_qkv"], CFG.n_heads)
    h_loc = CFG.n_heads // tp
    for r in range(tp):
        sp = dict(zip(LAYER_PARAM_NAMES, shard_layer_params(CFG, params, tp, r)))
        ctx_r = context(sp["w_qkv"], sp["b_qkv"], h_loc)
        lo = r * d // tp
        assert (ctx_r == full_ctx[:, :, lo : lo + d // tp]).all(), f"rank {r}"


@pytest.mark.parametrize("tp", [2, 4])
def test_column_parallel_first_gemm_is_bitwise_exact(layer_and_input, tp):
    """The FFN's column-parallel GEMM + GELU shard-concats bitwise."""
    params, x = layer_and_input
    p = dict(zip(LAYER_PARAM_NAMES, params))
    b, s, d = x.shape
    h2 = ref.layernorm(x.reshape(b * s, d), p["ln2_g"], p["ln2_b"])
    full = ref.gelu(h2 @ p["w1"] + p["b1"])
    di = CFG.d_ffn
    for r in range(tp):
        sp = dict(zip(LAYER_PARAM_NAMES, shard_layer_params(CFG, params, tp, r)))
        got = ref.gelu(h2 @ sp["w1"] + sp["b1"])
        assert (got == full[:, r * di // tp : (r + 1) * di // tp]).all(), f"rank {r}"


@pytest.mark.parametrize("tp", [2, 4])
def test_sharded_layer_matches_reference_within_tolerance(layer_and_input, tp):
    """Row-parallel partial sums reassociate one reduction axis: the full
    sharded layer matches the unsharded reference within a scaled-ulp
    tolerance (not bitwise)."""
    params, x = layer_and_input
    want = layer_fwd_ref(params, x, CFG)
    got = sharded_layer_fwd(params, x, CFG, tp)
    scale = jnp.abs(want).max()
    assert jnp.abs(got - want).max() <= 1e-5 * scale, (
        jnp.abs(got - want).max(),
        scale,
    )


@pytest.mark.parametrize("tp", [2])
def test_sharded_backward_composes_to_the_reference_vjp(layer_and_input, tp):
    """Run the runtime's backward orchestration at the jnp level and
    compare every gradient to jax.vjp of the unsharded reference."""
    params, x = layer_and_input
    dy = 0.1 * jax.random.normal(jax.random.PRNGKey(9), x.shape, jnp.float32)

    # Reference gradients.
    _, vjp = jax.vjp(lambda ps, xx: layer_fwd_ref(ps, xx, CFG), params, x)
    want_dparams, want_dx = vjp(dy)
    want = dict(zip(LAYER_PARAM_NAMES, want_dparams))

    shards = [shard_layer_params(CFG, params, tp, r) for r in range(tp)]
    # Recompute x2 (one mid-layer all-reduce in the runtime).
    x2 = x + sum(attn_fwd_part(s[:6], x, CFG, tp) for s in shards)
    # FFN backward: dh partials all-reduce, dx2 = dy + sum.
    ffn_grads = [ffn_bwd_part(s[6:], x2, dy, CFG, tp) for s in shards]
    dx2 = dy + sum(g[6] for g in ffn_grads)
    # Attention backward: dx partials all-reduce, dx = dx2 + sum.
    attn_grads = [attn_bwd_part(s[:6], x, dx2, CFG, tp) for s in shards]
    dx = dx2 + sum(g[6] for g in attn_grads)

    tol = lambda w: 1e-5 * (jnp.abs(w).max() + 1e-3)
    assert jnp.abs(dx - want_dx).max() <= tol(want_dx)

    d, di = CFG.d_model, CFG.d_ffn
    for r in range(tp):
        ga = dict(zip(ATTN_PARAM_NAMES, attn_grads[r][:6]))
        gf = dict(zip(FFN_PARAM_NAMES, ffn_grads[r][:6]))
        lo, hi = r * d // tp, (r + 1) * d // tp
        flo, fhi = r * di // tp, (r + 1) * di // tp
        # Sharded weight gradients match the corresponding slice.
        qkv_want = jnp.concatenate(
            [want["w_qkv"][:, g * d + lo : g * d + hi] for g in range(3)], axis=1
        )
        assert jnp.abs(ga["w_qkv"] - qkv_want).max() <= tol(qkv_want), f"rank {r}"
        assert jnp.abs(ga["w_o"] - want["w_o"][lo:hi, :]).max() <= tol(want["w_o"])
        assert jnp.abs(gf["w1"] - want["w1"][:, flo:fhi]).max() <= tol(want["w1"])
        assert jnp.abs(gf["w2"] - want["w2"][flo:fhi, :]).max() <= tol(want["w2"])
        # Post-reduce biases: full, identical gradient on every rank.
        assert jnp.abs(ga["b_o"] - want["b_o"]).max() <= tol(want["b_o"]), f"rank {r}"
        assert jnp.abs(gf["b2"] - want["b2"]).max() <= tol(want["b2"]), f"rank {r}"
    # Layernorm gradients are partial: they sum to the truth across ranks.
    for i, name in [(0, "ln1_g"), (1, "ln1_b")]:
        total = sum(g[i] for g in attn_grads)
        assert jnp.abs(total - want[name]).max() <= tol(want[name]), name
    for i, name in [(0, "ln2_g"), (1, "ln2_b")]:
        total = sum(g[i] for g in ffn_grads)
        assert jnp.abs(total - want[name]).max() <= tol(want[name]), name
