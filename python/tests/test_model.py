"""L2 model correctness: Pallas layer vs reference layer, backward-pass
exactness, loss behaviour, and the per-layer decomposition against the
monolithic model."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as M


CFG = M.PRESETS["tiny"]


@pytest.fixture(scope="module")
def params():
    return M.init_params(CFG, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def batch():
    rng = np.random.default_rng(0)
    tokens = jnp.asarray(rng.integers(0, CFG.vocab, (2, CFG.d_seq)), jnp.int32)
    targets = jnp.asarray(rng.integers(0, CFG.vocab, (2, CFG.d_seq)), jnp.int32)
    return tokens, targets


def test_pallas_layer_matches_reference(params, batch):
    table, pos, layers, _ = params
    tokens, _ = batch
    x = M.embed_fwd(table, pos, tokens)
    got = M.layer_fwd(layers[0], x, CFG)
    want = M.layer_fwd_ref(layers[0], x, CFG)
    np.testing.assert_allclose(got, want, atol=5e-5, rtol=5e-5)


def test_layer_bwd_matches_autodiff(params, batch):
    """layer_bwd == gradients of the reference layer (exact VJP)."""
    table, pos, layers, _ = params
    tokens, _ = batch
    x = M.embed_fwd(table, pos, tokens)
    dy = jnp.ones_like(x) * 0.01

    outs = M.layer_bwd(layers[0], x, dy, CFG)
    dparams, dx = outs[:12], outs[12]

    def scalar(ps, xx):
        return jnp.sum(M.layer_fwd_ref(ps, xx, CFG) * dy)

    want_dp, want_dx = jax.grad(scalar, argnums=(0, 1))(layers[0], x)
    np.testing.assert_allclose(dx, want_dx, atol=1e-5, rtol=1e-5)
    for got, want, name in zip(dparams, want_dp, M.LAYER_PARAM_NAMES):
        np.testing.assert_allclose(got, want, atol=1e-5, rtol=1e-5, err_msg=name)


def test_head_loss_grad_consistent(params, batch):
    table, pos, layers, head = params
    tokens, targets = batch
    x = M.embed_fwd(table, pos, tokens)
    loss, dx, dw = M.head_loss_grad(head, x, targets)
    want_loss = M.head_loss(head, x, targets)
    assert np.allclose(loss, want_loss)
    want_dw, want_dx = jax.grad(M.head_loss, argnums=(0, 1))(head, x, targets)
    np.testing.assert_allclose(dx, want_dx, atol=1e-6)
    np.testing.assert_allclose(dw, want_dw, atol=1e-6)


def test_embed_bwd_scatter_add(params, batch):
    table, pos, _, _ = params
    tokens, _ = batch
    dx = jnp.ones((2, CFG.d_seq, CFG.d_model), jnp.float32)
    d_table, d_pos = M.embed_bwd(dx, tokens, CFG.vocab)

    def scalar(t, p):
        return jnp.sum(M.embed_fwd(t, p, tokens) * dx)

    want_dt, want_dp = jax.grad(scalar, argnums=(0, 1))(table, pos)
    np.testing.assert_allclose(d_table, want_dt, atol=1e-6)
    np.testing.assert_allclose(d_pos, want_dp, atol=1e-6)


def test_initial_loss_near_log_vocab(params, batch):
    """At init the model should be near-uniform: loss ~= ln(vocab)."""
    tokens, targets = batch
    loss = M.model_loss(params, tokens, targets, CFG)
    assert abs(float(loss) - np.log(CFG.vocab)) < 0.5, float(loss)


def test_composed_per_layer_training_step_decreases_loss(params, batch):
    """One SGD step assembled purely from the per-layer artifacts'
    functions (the exact composition the Rust trainer performs) reduces
    the loss — end-to-end gradient-flow check."""
    tokens, targets = batch
    table, pos, layers, head = params
    lr = 0.5

    # Forward, keeping checkpoints (the layer inputs).
    x = M.embed_fwd(table, pos, tokens)
    ckpts = [x]
    for lp in layers:
        x = M.layer_fwd_ref(lp, x, CFG)
        ckpts.append(x)
    loss0, dx, dhead = M.head_loss_grad(head, ckpts[-1], targets)

    # Backward per layer, accumulating parameter grads.
    new_layers = []
    grads = [None] * len(layers)
    for i in reversed(range(len(layers))):
        outs = M.layer_bwd(layers[i], ckpts[i], dx, CFG)
        grads[i], dx = outs[:12], outs[12]
    d_table, d_pos = M.embed_bwd(dx, tokens, CFG.vocab)

    # SGD update.
    table2 = table - lr * d_table
    pos2 = pos - lr * d_pos
    head2 = head - lr * dhead
    for lp, g in zip(layers, grads):
        new_layers.append(tuple(p - lr * gp for p, gp in zip(lp, g)))

    loss1 = M.model_loss((table2, pos2, tuple(new_layers), head2), tokens, targets, CFG)
    assert float(loss1) < float(loss0), (float(loss0), float(loss1))


def test_param_count_formula():
    # tiny: 12 tensors/layer; d=64, d_i=256.
    per_layer = CFG.params_per_layer()
    d, di = 64, 256
    want = 2 * d + d * 3 * d + 3 * d + d * d + d + 2 * d + d * di + di + di * d + d
    assert per_layer == want
    assert M.PRESETS["e2e"].total_params() > 95e6


def test_causal_masking_in_model(params):
    """Future tokens must not affect earlier positions' hidden states."""
    table, pos, layers, _ = params
    rng = np.random.default_rng(2)
    tokens = jnp.asarray(rng.integers(0, CFG.vocab, (1, CFG.d_seq)), jnp.int32)
    tokens2 = tokens.at[0, -1].set((int(tokens[0, -1]) + 1) % CFG.vocab)
    x1 = M.embed_fwd(table, pos, tokens)
    x2 = M.embed_fwd(table, pos, tokens2)
    for lp in layers:
        x1 = M.layer_fwd_ref(lp, x1, CFG)
        x2 = M.layer_fwd_ref(lp, x2, CFG)
    np.testing.assert_allclose(x1[0, :-1], x2[0, :-1], atol=1e-5)
