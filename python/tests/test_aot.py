"""AOT pipeline tests: manifests are self-consistent and the HLO text is
structurally sane (parameter/result counts match the manifest)."""

import json
import os
import re

import pytest

from compile.aot import build_artifacts, compile_preset, to_hlo_text
from compile.model import PRESETS

import jax


@pytest.fixture(scope="module")
def tiny_manifest(tmp_path_factory):
    out = str(tmp_path_factory.mktemp("artifacts"))
    m = compile_preset("tiny", out, batch=2, tp_degrees=[2])
    return m, out


def test_manifest_lists_all_artifacts(tiny_manifest):
    m, _ = tiny_manifest
    assert set(m["artifacts"]) == {
        "embed_fwd", "embed_bwd", "layer_fwd", "layer_bwd", "head_loss_grad",
        "attn_fwd_tp2", "ffn_fwd_tp2", "attn_bwd_tp2", "ffn_bwd_tp2",
    }


def test_manifest_matches_disk(tiny_manifest):
    m, out = tiny_manifest
    disk = json.load(open(os.path.join(out, "tiny", "manifest.json")))
    assert disk == m
    for art in m["artifacts"].values():
        path = os.path.join(out, art["file"])
        assert os.path.exists(path)
        assert os.path.getsize(path) > 100


def test_hlo_text_parameter_count_matches_manifest(tiny_manifest):
    m, out = tiny_manifest
    for name, art in m["artifacts"].items():
        text = open(os.path.join(out, art["file"])).read()
        entry = re.search(r"ENTRY .*?\{(.*?)\n\}", text, re.S)
        assert entry, f"{name}: no ENTRY block"
        params = re.findall(r"parameter\(\d+\)", entry.group(1))
        assert len(params) == len(art["inputs"]), name


def test_layer_bwd_shapes_mirror_layer_fwd(tiny_manifest):
    m, _ = tiny_manifest
    fwd = m["artifacts"]["layer_fwd"]
    bwd = m["artifacts"]["layer_bwd"]
    # bwd inputs = fwd inputs + dy (same shape as fwd output).
    assert bwd["inputs"][:13] == fwd["inputs"]
    assert bwd["inputs"][13] == fwd["outputs"][0]
    # bwd outputs = dparams (same shapes as the 12 params) + dx.
    assert [o["shape"] for o in bwd["outputs"][:12]] == [
        i["shape"] for i in fwd["inputs"][:12]
    ]
    assert bwd["outputs"][12]["shape"] == fwd["inputs"][12]["shape"]


def test_artifacts_lower_without_pallas_custom_calls(tiny_manifest):
    """interpret=True must lower Pallas to plain HLO — a Mosaic
    custom-call would be unloadable by the CPU PJRT client."""
    m, out = tiny_manifest
    for name, art in m["artifacts"].items():
        text = open(os.path.join(out, art["file"])).read()
        assert "mosaic" not in text.lower(), name
        assert "tpu_custom_call" not in text.lower(), name


def test_build_artifacts_shapes_scale_with_batch():
    arts1 = build_artifacts(PRESETS["tiny"], batch=1)
    arts4 = build_artifacts(PRESETS["tiny"], batch=4)
    a1 = arts1["layer_fwd"][1][12].shape
    a4 = arts4["layer_fwd"][1][12].shape
    assert a1[0] == 1 and a4[0] == 4


def test_sharded_manifest_schema_roundtrips(tiny_manifest):
    """The tp-shard schema the Rust manifest parser relies on survives a
    JSON round-trip: shard factors on every artifact, per-degree sharded
    parameter shapes, and shapes that are consistent with the artifact
    argument specs."""
    from compile.model import LAYER_PARAM_NAMES, PRESETS, sharded_param_shapes

    m, _ = tiny_manifest
    rt = json.loads(json.dumps(m))
    assert rt == m

    # Every artifact carries its shard factor; the base set is tp = 1.
    for name, art in rt["artifacts"].items():
        assert art["tp"] == (2 if name.endswith("_tp2") else 1), name

    # tp_shards carries the per-rank shapes, matching the model formula.
    shards = rt["tp_shards"]["2"]["layer_param_shapes"]
    want = sharded_param_shapes(PRESETS["tiny"], 2)
    assert shards == {n: list(want[n]) for n in LAYER_PARAM_NAMES}

    # The half-layer artifacts consume exactly those shapes: attention the
    # first six parameters, FFN the last six, then full activations.
    attn_in = rt["artifacts"]["attn_fwd_tp2"]["inputs"]
    ffn_in = rt["artifacts"]["ffn_fwd_tp2"]["inputs"]
    assert [i["shape"] for i in attn_in[:6]] == [
        shards[n] for n in LAYER_PARAM_NAMES[:6]
    ]
    assert [i["shape"] for i in ffn_in[:6]] == [
        shards[n] for n in LAYER_PARAM_NAMES[6:]
    ]
    act = rt["artifacts"]["layer_fwd"]["inputs"][12]["shape"]
    assert attn_in[6]["shape"] == act and ffn_in[6]["shape"] == act
    # Backward halves: same params + two activations in, six shard
    # gradients + one activation-shaped partial out.
    for stem in ("attn", "ffn"):
        bwd = rt["artifacts"][f"{stem}_bwd_tp2"]
        fwd = rt["artifacts"][f"{stem}_fwd_tp2"]
        assert bwd["inputs"][:7] == fwd["inputs"]
        assert bwd["inputs"][7]["shape"] == act
        assert [o["shape"] for o in bwd["outputs"][:6]] == [
            i["shape"] for i in fwd["inputs"][:6]
        ]
        assert bwd["outputs"][6]["shape"] == act


def test_to_hlo_text_roundtrip_smoke():
    import jax.numpy as jnp

    lowered = jax.jit(lambda x: (x * 2 + 1,)).lower(
        jax.ShapeDtypeStruct((4,), jnp.float32)
    )
    text = to_hlo_text(lowered)
    assert "ENTRY" in text and "parameter(0)" in text
