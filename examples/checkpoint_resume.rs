//! §8.2 end-to-end: real-time checkpoint streaming, a simulated crash,
//! and elastic resume on a different cluster size.
//!
//! The run trains the `tiny` preset with `offload` on, so every
//! optimizer step streams each layer's owned parameter shard + Adam
//! moments to a durable `FileStore` — every batch is a restore point.
//! After a "crash" (the process simply stops), training resumes from the
//! streamed state on a *different* data-parallel degree: the stored
//! shards are re-sliced through `ShardMap` on load, which is what makes
//! cluster resizing a zero-downtime event (§8.1).
//!
//! Run with: `cargo run --release --example checkpoint_resume`
//! Flags: --steps N (8)  --kill-at N (4)  --store DIR (temp dir)
//!
//! Needs the PJRT artifacts (`make artifacts`); prints a note and exits
//! cleanly without them.

use lga_mpp::offload::{FileStore, StateStore};
use lga_mpp::optim::LrSchedule;
use lga_mpp::report;
use lga_mpp::trainer::{train, Policy, TrainerConfig};

fn flag(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

fn config(n_b: usize, n_mu: usize, steps: usize, store: std::path::PathBuf) -> TrainerConfig {
    let mut c = TrainerConfig::quick("tiny");
    c.steps = steps;
    c.n_b = n_b;
    c.n_mu = n_mu;
    c.policy = Policy::Improved;
    c.partition = n_b > 1;
    c.offload = true;
    c.store_dir = Some(store);
    c.lr = LrSchedule::constant(3e-3);
    c
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let steps: usize = flag(&args, "--steps").map(|v| v.parse().unwrap()).unwrap_or(8);
    let kill_at: usize = flag(&args, "--kill-at").map(|v| v.parse().unwrap()).unwrap_or(4);
    anyhow::ensure!(kill_at < steps, "--kill-at must be below --steps");
    let dir = flag(&args, "--store").map(std::path::PathBuf::from).unwrap_or_else(|| {
        std::env::temp_dir().join(format!("lga_ckpt_example_{}", std::process::id()))
    });

    let probe = TrainerConfig::quick("tiny");
    if !probe.artifacts_root.join("tiny/manifest.json").exists() {
        println!("(skipping: run `make artifacts` first to build the tiny preset)");
        return Ok(());
    }
    let _ = std::fs::remove_dir_all(&dir);

    // --- phase 1: train with real-time checkpoints, then "crash" --------
    println!("== phase 1: dp=2, partitioned, streaming to {dir:?} ==");
    let r1 = train(&config(2, 2, kill_at, dir.clone()))?;
    for (i, l) in r1.losses.iter().enumerate() {
        println!("  step {i}  loss {l:.4}");
    }
    println!(
        "crash after step {} — {} records / {:.2} MiB already durable",
        kill_at - 1,
        r1.checkpoint_records,
        r1.checkpoint_bytes_written as f64 / (1u64 << 20) as f64
    );
    let store = FileStore::new(&dir)?;
    println!("store holds steps {:?}", store.steps()?);

    // --- phase 2: elastic resume on a smaller cluster -------------------
    println!("\n== phase 2: resume at dp=1 (shards re-sliced on load) ==");
    let mut cfg = config(1, 4, steps, dir.clone());
    cfg.resume = true;
    let r2 = train(&cfg)?;
    println!("resumed at step {}", r2.start_step);
    for (i, l) in r2.losses.iter().enumerate() {
        println!("  step {}  loss {l:.4}", r2.start_step + i);
    }

    println!(
        "\n{}",
        report::checkpoint_summary(
            r1.losses.len() + r2.losses.len(),
            r1.checkpoint_records + r2.checkpoint_records,
            r1.checkpoint_bytes_written + r2.checkpoint_bytes_written,
            1000.0,
        )
    );
    let _ = std::fs::remove_dir_all(&dir);
    Ok(())
}
