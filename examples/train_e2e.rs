//! End-to-end validation run: train the ~100M-parameter `e2e` preset on
//! the synthetic corpus with layered gradient accumulation + modular
//! pipeline parallelism + state partition, logging the loss curve — the
//! full three-layer stack (Pallas kernels -> JAX layer HLO -> rust
//! coordinator over PJRT) composing on a real workload.
//!
//! Results are recorded in EXPERIMENTS.md. Flags:
//!   --steps N (default 300)   --dp N (2)   --pp N (2)   --mb N (2)
//!   --preset tiny|e2e (e2e)   --policy baseline|improved (improved)
//!   --no-partition            --csv FILE (loss curve dump)
//!
//! Run with: `cargo run --release --example train_e2e -- --steps 300`

use lga_mpp::optim::LrSchedule;
use lga_mpp::trainer::{train, Policy, TrainerConfig};

fn flag(args: &[String], name: &str) -> Option<String> {
    args.iter().position(|a| a == name).and_then(|i| args.get(i + 1).cloned())
}

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let preset = flag(&args, "--preset").unwrap_or_else(|| "e2e".into());
    let steps: usize = flag(&args, "--steps").map(|v| v.parse().unwrap()).unwrap_or(300);

    let mut cfg = TrainerConfig::quick(&preset);
    cfg.steps = steps;
    cfg.n_b = flag(&args, "--dp").map(|v| v.parse().unwrap()).unwrap_or(2);
    cfg.n_l = flag(&args, "--pp").map(|v| v.parse().unwrap()).unwrap_or(2);
    cfg.tp = flag(&args, "--tp").map(|v| v.parse().unwrap()).unwrap_or(1);
    cfg.n_mu = flag(&args, "--mb").map(|v| v.parse().unwrap()).unwrap_or(2);
    cfg.partition = !args.iter().any(|a| a == "--no-partition");
    cfg.policy = match flag(&args, "--policy").as_deref() {
        Some("baseline") => Policy::Baseline,
        _ => Policy::Improved,
    };
    cfg.lr = LrSchedule {
        base_lr: flag(&args, "--lr").map(|v| v.parse().unwrap()).unwrap_or(6e-4),
        warmup_steps: (steps / 20).max(5) as u64,
        total_steps: steps as u64,
        min_ratio: 0.1,
    };

    anyhow::ensure!(
        cfg.artifacts_root.join(&preset).join("manifest.json").exists(),
        "artifacts for preset '{preset}' missing — run `make artifacts`"
    );

    let manifest =
        lga_mpp::runtime::Manifest::load(&cfg.artifacts_root, &preset)?;
    let global_batch = cfg.n_b * cfg.n_mu * manifest.batch;
    println!(
        "e2e run: {} params | {} layers | dp={} pp={} mb={} (global batch {} seqs x {} tokens)",
        manifest.model.total_params,
        manifest.model.n_layers,
        cfg.n_b,
        cfg.n_l,
        cfg.n_mu,
        global_batch,
        manifest.model.d_seq
    );
    println!(
        "policy={} partition={} steps={} — schedule `{}`",
        cfg.policy.name(),
        cfg.partition,
        cfg.steps,
        cfg.build_schedule(manifest.model.n_layers).name
    );

    let t0 = std::time::Instant::now();
    let report = train(&cfg)?;
    let tokens_per_step = (global_batch * manifest.model.d_seq) as f64;

    println!("\nstep    loss");
    for (i, l) in report.losses.iter().enumerate() {
        if i < 5 || i % 25 == 0 || i + 1 == report.losses.len() {
            println!("{i:>5}  {l:.4}");
        }
    }
    let uniform = (manifest.model.vocab as f64).ln();
    println!("\nuniform-baseline loss ln(V) = {uniform:.3}");
    println!(
        "final loss {:.4} (drop {:.2} nats from init {:.4})",
        report.losses.last().unwrap(),
        report.losses[0] - report.losses.last().unwrap(),
        report.losses[0]
    );
    println!(
        "throughput: {:.0} tokens/s | wall {:.1}s | PJRT {:.1}s ({:.0}% of wall) over {} calls",
        tokens_per_step * report.losses.len() as f64 / report.wall_secs,
        t0.elapsed().as_secs_f64(),
        report.execute_secs,
        100.0 * report.execute_secs / (report.wall_secs * (cfg.n_b * cfg.n_l) as f64),
        report.execute_calls,
    );

    if let Some(path) = flag(&args, "--csv") {
        let mut csv = String::from("step,loss\n");
        for (i, l) in report.losses.iter().enumerate() {
            csv.push_str(&format!("{i},{l}\n"));
        }
        std::fs::write(&path, csv)?;
        println!("loss curve written to {path}");
    }
    Ok(())
}
