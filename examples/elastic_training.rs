//! §8 walkthrough: elastic training with a dynamic critical batch size
//! ("don't decay the learning rate, increase the cluster size") and
//! real-time checkpoints.
//!
//! Run with: `cargo run --release --example elastic_training`

use lga_mpp::costmodel::{ParallelismMenu, Strategy};
use lga_mpp::elastic::{
    cluster_schedule, default_phases, resize_downtime_secs, run_elastic, run_fixed,
};
use lga_mpp::hardware::{ClusterSpec, LinkKind, GIB};
use lga_mpp::model::XModel;
use lga_mpp::offload::{state_offload_feasibility, TIERS};
use lga_mpp::planner::fastest_plan;

fn main() {
    let model = XModel::x160();
    let cluster = ClusterSpec::reference();
    let plan = fastest_plan(&model, &cluster, Strategy::Improved, ParallelismMenu::THREE_D)
        .expect("plan");
    let n_max = plan.cfg.n_gpu();

    // --- §8.1: cluster-size schedule ------------------------------------
    println!("== §8.1: dynamic critical batch -> dynamic cluster size ==");
    println!("late-training plan: {} GPUs (b_c = {:.0})", n_max, model.critical_batch_size());
    for (f, n) in cluster_schedule(&model, n_max, 8, 0.05) {
        let bar = "#".repeat((n * 40 / n_max).max(1));
        println!("  progress {f:.2}  {n:>6} GPUs {bar}");
    }
    let phases = default_phases(200);
    let fixed = run_fixed(&phases, 0.05);
    let elastic = run_elastic(&phases, 0.05);
    println!(
        "cost (GPU-time units): fixed {:.2} vs elastic {:.2} ({:.0}% saved); \
         wall: {:.2} vs {:.2}",
        fixed.samples,
        elastic.samples,
        100.0 * (1.0 - elastic.samples / fixed.samples),
        fixed.wall,
        elastic.wall
    );

    // --- §8.2: real-time checkpoints -------------------------------------
    println!("\n== §8.2: offload / real-time checkpoint feasibility (X_160) ==");
    let feas = state_offload_feasibility(&model.shape(), &plan.cfg, &cluster.gpu);
    for f in &feas {
        println!(
            "  state -> {:<22} nu_op {:.3e} vs threshold {:.3e} : {}",
            f.tier.name(),
            f.nu_op,
            f.nu_net,
            if f.is_free() { "FREE (fully hidden)" } else { "exposed" }
        );
    }
    let state_bytes = 12.0 * model.params();
    println!(
        "  full training state: {:.0} GiB; classic checkpoint stall to NVMe: {:.0} s;\n  \
         with streamed (real-time) checkpoints: {:.0} s and the loss window is one batch",
        state_bytes / GIB,
        resize_downtime_secs(state_bytes / plan.cfg.n_b as f64, LinkKind::DiskNvme.bandwidth(), false),
        resize_downtime_secs(state_bytes, LinkKind::DiskNvme.bandwidth(), true),
    );
    let _ = TIERS;

    // --- §8.3: Ethernet ---------------------------------------------------
    println!("\n== §8.3: Ethernet is enough (fastest plans per fabric) ==");
    for (c, name) in [(ClusterSpec::reference(), "InfiniBand"), (ClusterSpec::ethernet(), "Ethernet 25 Gb/s")] {
        for s in [Strategy::Baseline, Strategy::Improved] {
            if let Some(p) =
                lga_mpp::planner::search_fastest(&model, &c, s, ParallelismMenu::THREE_D)
            {
                println!(
                    "  {name:<18} {:<9} {:>6} GPUs  eff {:.2}  {:>7.1} days",
                    s.name(),
                    p.cfg.n_gpu(),
                    p.speed.efficiency,
                    p.speed.training_days()
                );
            }
        }
    }
}
