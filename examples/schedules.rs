//! Reproduce Figures 1, 2 and 3: render the four scheduling policies as
//! ASCII Gantt charts from the discrete-event simulator and print the
//! measured overlap/bubble numbers next to the paper's closed forms.
//!
//! Run with: `cargo run --release --example schedules`

use lga_mpp::costmodel::{Strategy, TrainConfig};
use lga_mpp::hardware::ClusterSpec;
use lga_mpp::model::XModel;
use lga_mpp::schedule::{
    interleaved_1f1b, layered_ga, modular_pipeline, one_f_one_b, standard_ga, Schedule,
    ScheduleSpec,
};
use lga_mpp::sim::{render, simulate, CostTable, SimResult};

fn costs(n_b: usize, n_l: usize, n_mu: usize, partition: bool) -> CostTable {
    let cfg = TrainConfig {
        strategy: if partition { Strategy::Improved } else { Strategy::Baseline },
        n_b,
        n_l,
        n_a: 1,
        n_mu,
        b_mu: 1.0,
        offload: false,
        partition,
        zero: 0,
    };
    CostTable::new(&XModel::new(32).shape(), &cfg, &ClusterSpec::reference())
}

fn show(title: &str, s: &Schedule, r: &SimResult) {
    println!("--- {title} [{}] ---", s.name);
    println!(
        "makespan {:.2} ms | compute eff {:.3} | exposed net tail {:.2} ms",
        r.makespan * 1e3,
        r.compute_efficiency(),
        r.exposed_network_tail() * 1e3,
    );
    println!("{}", render(r, 104));
}

fn main() {
    // Figure 1: standard vs layered gradient accumulation under data
    // parallelism (single stage, 4 micro-batches, 8-way DP reduction).
    println!("== Figure 1: gradient accumulation scheduling (data parallel) ==\n");
    let spec = ScheduleSpec {
        d_l: 8,
        n_l: 1,
        n_mu: 4,
        tp: 1,
        partition: false,
        offload: false,
        data_parallel: true,
        zero: 0,
    };
    let c = costs(8, 1, 4, false);
    let std_s = standard_ga(&spec);
    let r = simulate(&std_s, &c);
    show("standard gradient accumulation", &std_s, &r);
    let lga_s = layered_ga(&spec);
    let r2 = simulate(&lga_s, &c);
    show("layered gradient accumulation (§3)", &lga_s, &r2);
    println!(
        "reduction exposed after compute: standard {:.2} ms vs layered {:.2} ms\n",
        r.exposed_network_tail() * 1e3,
        r2.exposed_network_tail() * 1e3
    );

    // Figure 2: the same with a partitioned training state — standard GA
    // restores parameters per micro-batch, LGA once per layer per pass.
    println!("== Figure 2: with training-state partition (ZeRO-3) ==\n");
    let spec = ScheduleSpec {
        d_l: 8,
        n_l: 1,
        n_mu: 4,
        tp: 1,
        partition: true,
        offload: false,
        data_parallel: true,
        zero: 0,
    };
    let c = costs(8, 1, 4, true);
    let std_s = standard_ga(&spec);
    let lga_s = layered_ga(&spec);
    let restores = |s: &Schedule| {
        s.count(|o| matches!(o, lga_mpp::schedule::Op::RestoreParams { .. }))
    };
    println!(
        "parameter restorations per batch: standard {} vs layered {} (the\n\
         factor-n_mu traffic redundancy of Figure 2)\n",
        restores(&std_s),
        restores(&lga_s)
    );
    show("standard + partition", &std_s, &simulate(&std_s, &c));
    show("layered + partition", &lga_s, &simulate(&lga_s, &c));

    // Figure 3: contiguous vs modular pipeline.
    println!("== Figure 3: standard vs modular pipeline (16 layers / 4 stages) ==\n");
    let spec = ScheduleSpec {
        d_l: 16,
        n_l: 4,
        n_mu: 6,
        tp: 1,
        partition: false,
        offload: false,
        data_parallel: false,
        zero: 0,
    };
    let c = costs(1, 4, 6, false);
    let naive = standard_ga(&spec);
    let rn = simulate(&naive, &c);
    show("contiguous pipeline (GPipe-style)", &naive, &rn);
    let modular = modular_pipeline(&spec);
    let rm = simulate(&modular, &c);
    show("modular pipeline (§4)", &modular, &rm);
    println!(
        "bubble: contiguous {:.3} vs modular {:.3} — paper predicts a d_l/n_l = {}x reduction",
        rn.bubble_fraction(),
        rm.bubble_fraction(),
        16 / 4
    );

    // §4 baseline: Megatron-LM's interleaved 1F1B shrinks the 1F1B bubble
    // by the chunk count v; the modular pipeline is the v = d_l/n_l limit
    // of the same idea, combined with layered accumulation.
    println!("\n== §4 baseline: interleaved 1F1B (Megatron-LM) ==\n");
    let spec = ScheduleSpec {
        d_l: 16,
        n_l: 4,
        n_mu: 8,
        tp: 1,
        partition: false,
        offload: false,
        data_parallel: false,
        zero: 0,
    };
    let c = costs(1, 4, 8, false);
    let fb = one_f_one_b(&spec);
    let rf = simulate(&fb, &c);
    show("1F1B (PipeDream-flush)", &fb, &rf);
    let il = interleaved_1f1b(&spec, 2);
    let ri = simulate(&il, &c);
    show("interleaved 1F1B (v = 2)", &il, &ri);
    let md = modular_pipeline(&spec);
    let rmod = simulate(&md, &c);
    println!(
        "bubble: 1f1b {:.3} -> interleaved {:.3} (÷v) -> modular {:.3}",
        rf.bubble_fraction(),
        ri.bubble_fraction(),
        rmod.bubble_fraction()
    );
}
