//! Cluster-planning walkthrough: §6's "smaller clusters" analysis as a
//! runnable tool. Sweeps time budgets for a model and prints, for each
//! strategy, the smallest cluster that meets the deadline — plus the §8.3
//! Ethernet variant and the §7 node-size ablation.
//!
//! Run with: `cargo run --release --example plan_cluster -- [x]`

use lga_mpp::costmodel::{ParallelismMenu, Strategy};
use lga_mpp::hardware::{ClusterSpec, SECS_PER_DAY};
use lga_mpp::model::XModel;
use lga_mpp::planner::{min_gpu_plan, search_fastest};

fn main() {
    let x: usize = std::env::args().nth(1).and_then(|v| v.parse().ok()).unwrap_or(160);
    let model = XModel::new(x);
    println!(
        "model X_{x}: {:.3e} params, critical batch {:.0}, {} layers\n",
        model.params(),
        model.critical_batch_size(),
        model.shape().d_l
    );

    let clusters = [
        (ClusterSpec::reference(), "InfiniBand, node<=16"),
        (ClusterSpec::ethernet(), "25 Gb/s Ethernet"),
        (ClusterSpec::unlimited_node(), "unlimited NVLink node"),
    ];
    println!("== fastest possible (3d parallelism) ==");
    for (cluster, name) in &clusters {
        for strategy in [Strategy::Baseline, Strategy::Improved] {
            if let Some(p) =
                search_fastest(&model, cluster, strategy, ParallelismMenu::THREE_D)
            {
                println!(
                    "  {name:<24} {:<9} {:>7} GPUs  eff {:.2}  {:>8.1} days",
                    strategy.name(),
                    p.cfg.n_gpu(),
                    p.speed.efficiency,
                    p.speed.training_days()
                );
            }
        }
    }

    println!("\n== smallest cluster per time budget (Table 6.3 generalised) ==");
    let cluster = ClusterSpec::reference();
    for days in [33.0, 62.0, 181.0, 365.0] {
        println!("  budget {days:.0} days:");
        for (strategy, menu) in [
            (Strategy::Partitioned, ParallelismMenu::DATA_TENSOR),
            (Strategy::Baseline, ParallelismMenu::THREE_D),
            (Strategy::Improved, ParallelismMenu::THREE_D),
            (Strategy::Improved, ParallelismMenu::DATA_PIPE),
        ] {
            match min_gpu_plan(&model, &cluster, strategy, menu, days * SECS_PER_DAY) {
                Some(cp) => println!(
                    "    {:<12} {:<13} {:>7} GPUs  b={:<6} eff {:.2}  {:>6.1} d",
                    strategy.name(),
                    menu.name(),
                    cp.plan.cfg.n_gpu(),
                    cp.plan.cfg.batch_size() as u64,
                    cp.plan.speed.efficiency,
                    cp.plan.speed.training_days()
                ),
                None => println!(
                    "    {:<12} {:<13} infeasible",
                    strategy.name(),
                    menu.name()
                ),
            }
        }
    }
}
