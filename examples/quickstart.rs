//! Quickstart: the library's three faces in one file.
//!
//! 1. Plan: find the fastest training configuration for a model.
//! 2. Simulate: run the paper's schedules on the simulated cluster.
//! 3. Train: real distributed training via PJRT (needs `make artifacts`).
//!
//! Run with: `cargo run --release --example quickstart`

use lga_mpp::costmodel::{ParallelismMenu, Strategy};
use lga_mpp::hardware::ClusterSpec;
use lga_mpp::model::XModel;
use lga_mpp::planner::fastest_plan;
use lga_mpp::schedule::{modular_pipeline, standard_ga, ScheduleSpec};
use lga_mpp::sim::{simulate, CostTable};
use lga_mpp::trainer::{train, TrainerConfig};

fn main() -> anyhow::Result<()> {
    // --- 1. Plan the trillion-parameter run (Table 6.1's headline) ------
    let model = XModel::x160();
    let cluster = ClusterSpec::reference();
    for strategy in [Strategy::Baseline, Strategy::Improved] {
        let plan = fastest_plan(&model, &cluster, strategy, ParallelismMenu::THREE_D)
            .expect("plan");
        println!(
            "{:<9} 3d: {} GPUs, efficiency {:.2}, trains X_160 in {:.1} days",
            strategy.name(),
            plan.cfg.n_gpu(),
            plan.speed.efficiency,
            plan.speed.training_days()
        );
    }

    // --- 2. Simulate the schedules (Figure 3 in numbers) ----------------
    let spec = ScheduleSpec {
        d_l: 16,
        n_l: 4,
        n_mu: 8,
        tp: 1,
        partition: false,
        offload: false,
        data_parallel: false,
        zero: 0,
    };
    let cfg = lga_mpp::costmodel::TrainConfig {
        strategy: Strategy::Baseline,
        n_b: 1,
        n_l: 4,
        n_a: 1,
        n_mu: 8,
        b_mu: 1.0,
        offload: false,
        partition: false,
        zero: 0,
    };
    let costs = CostTable::new(&XModel::new(32).shape(), &cfg, &cluster);
    let naive = simulate(&standard_ga(&spec), &costs);
    let modular = simulate(&modular_pipeline(&spec), &costs);
    println!(
        "\npipeline bubble, 16 layers over 4 stages, 8 micro-batches:\n  \
         contiguous {:.3}  |  modular {:.3}  ({:.1}x smaller)",
        naive.bubble_fraction(),
        modular.bubble_fraction(),
        naive.bubble_fraction() / modular.bubble_fraction()
    );

    // --- 3. Real training (tiny preset; skipped if artifacts missing) ---
    let mut tcfg = TrainerConfig::quick("tiny");
    tcfg.steps = 10;
    tcfg.n_b = 2;
    tcfg.n_l = 2;
    tcfg.n_mu = 2;
    tcfg.partition = true;
    if tcfg.artifacts_root.join("tiny/manifest.json").exists() {
        let report = train(&tcfg)?;
        println!(
            "\nreal LGA+modular-pipeline training (2 dp x 2 stages, ZeRO partition):\n  \
             loss {:.3} -> {:.3} over {} steps ({:.1}s)",
            report.losses[0],
            report.losses.last().unwrap(),
            report.losses.len(),
            report.wall_secs
        );
    } else {
        println!("\n(skipping real training: run `make artifacts` first)");
    }
    Ok(())
}
