//! Optimizer: Adam with fp32 master state (the paper's assumed optimizer,
//! §2.5 — 12 bytes/parameter of training state) plus a warmup+cosine
//! learning-rate schedule and gradient clipping.

pub mod adam;
pub mod lr;

pub use adam::{Adam, AdamConfig};
pub use lr::LrSchedule;

/// Global-norm gradient clipping. Returns the pre-clip norm.
pub fn clip_grad_norm(grads: &mut [&mut [f32]], max_norm: f32) -> f32 {
    let sq: f32 = grads.iter().flat_map(|g| g.iter()).map(|v| v * v).sum();
    let norm = sq.sqrt();
    if norm > max_norm && norm > 0.0 {
        let scale = max_norm / norm;
        for g in grads.iter_mut() {
            for v in g.iter_mut() {
                *v *= scale;
            }
        }
    }
    norm
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn clip_scales_to_max_norm() {
        let mut a = vec![3.0f32, 0.0];
        let mut b = vec![0.0f32, 4.0];
        let norm = {
            let mut views: Vec<&mut [f32]> = vec![&mut a, &mut b];
            clip_grad_norm(&mut views, 1.0)
        };
        assert!((norm - 5.0).abs() < 1e-6);
        let new_sq: f32 = a.iter().chain(b.iter()).map(|v| v * v).sum();
        assert!((new_sq.sqrt() - 1.0).abs() < 1e-6);
    }

    #[test]
    fn clip_leaves_small_gradients_alone() {
        let mut a = vec![0.1f32, 0.1];
        let orig = a.clone();
        let mut views: Vec<&mut [f32]> = vec![&mut a];
        clip_grad_norm(&mut views, 1.0);
        assert_eq!(a, orig);
    }
}
