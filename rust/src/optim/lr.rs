//! Learning-rate schedule: linear warmup then cosine decay — the standard
//! large-LM schedule the paper's training setups assume.

/// Warmup + cosine decay schedule.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LrSchedule {
    pub base_lr: f32,
    pub warmup_steps: u64,
    pub total_steps: u64,
    /// Floor as a fraction of base_lr.
    pub min_ratio: f32,
}

impl LrSchedule {
    pub fn constant(lr: f32) -> Self {
        LrSchedule { base_lr: lr, warmup_steps: 0, total_steps: u64::MAX, min_ratio: 1.0 }
    }

    pub fn lr(&self, step: u64) -> f32 {
        if self.warmup_steps > 0 && step < self.warmup_steps {
            return self.base_lr * (step + 1) as f32 / self.warmup_steps as f32;
        }
        if self.total_steps == u64::MAX {
            return self.base_lr;
        }
        let progress = ((step - self.warmup_steps) as f32
            / (self.total_steps.saturating_sub(self.warmup_steps)).max(1) as f32)
            .clamp(0.0, 1.0);
        let cos = 0.5 * (1.0 + (std::f32::consts::PI * progress).cos());
        self.base_lr * (self.min_ratio + (1.0 - self.min_ratio) * cos)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn warmup_is_linear() {
        let s = LrSchedule { base_lr: 1.0, warmup_steps: 10, total_steps: 100, min_ratio: 0.1 };
        assert!((s.lr(0) - 0.1).abs() < 1e-6);
        assert!((s.lr(4) - 0.5).abs() < 1e-6);
        assert!((s.lr(9) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn cosine_decays_to_floor() {
        let s = LrSchedule { base_lr: 1.0, warmup_steps: 10, total_steps: 100, min_ratio: 0.1 };
        assert!((s.lr(100) - 0.1).abs() < 1e-5);
        assert!(s.lr(50) < s.lr(20));
    }

    #[test]
    fn constant_is_constant() {
        let s = LrSchedule::constant(0.3);
        assert_eq!(s.lr(0), 0.3);
        assert_eq!(s.lr(1_000_000), 0.3);
    }
}
