//! Adam optimizer over flat f32 buffers, with support for updating only a
//! shard of the parameter vector (the ZeRO-3-style partition updates each
//! rank's owned range only).

/// Adam hyper-parameters.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct AdamConfig {
    pub beta1: f32,
    pub beta2: f32,
    pub eps: f32,
    pub weight_decay: f32,
}

impl Default for AdamConfig {
    fn default() -> Self {
        AdamConfig { beta1: 0.9, beta2: 0.999, eps: 1e-8, weight_decay: 0.0 }
    }
}

/// Adam state for one flat parameter buffer (or one shard of it).
#[derive(Debug, Clone)]
pub struct Adam {
    pub cfg: AdamConfig,
    m: Vec<f32>,
    v: Vec<f32>,
    t: u64,
}

impl Adam {
    pub fn new(len: usize, cfg: AdamConfig) -> Self {
        Adam { cfg, m: vec![0.0; len], v: vec![0.0; len], t: 0 }
    }

    /// Number of state elements (2 moments per parameter).
    pub fn state_elements(&self) -> usize {
        self.m.len() + self.v.len()
    }

    /// The serialisable state: first/second moments and the step counter
    /// (what an `OffloadStore` streams to the checkpoint store).
    pub fn state(&self) -> (&[f32], &[f32], u64) {
        (&self.m, &self.v, self.t)
    }

    /// Rebuild Adam from checkpointed moments (the resume path). The
    /// moments may be any shard of the original buffer — Adam is
    /// elementwise, so a re-sliced shard resumes exactly.
    pub fn from_state(cfg: AdamConfig, m: Vec<f32>, v: Vec<f32>, t: u64) -> Self {
        assert_eq!(m.len(), v.len(), "moment buffers must match");
        Adam { cfg, m, v, t }
    }

    /// One Adam step over the whole buffer.
    pub fn step(&mut self, params: &mut [f32], grads: &[f32], lr: f32) {
        assert_eq!(params.len(), self.m.len());
        assert_eq!(grads.len(), self.m.len());
        self.t += 1;
        let b1 = self.cfg.beta1;
        let b2 = self.cfg.beta2;
        let bc1 = 1.0 - b1.powi(self.t as i32);
        let bc2 = 1.0 - b2.powi(self.t as i32);
        for i in 0..params.len() {
            let g = grads[i] + self.cfg.weight_decay * params[i];
            self.m[i] = b1 * self.m[i] + (1.0 - b1) * g;
            self.v[i] = b2 * self.v[i] + (1.0 - b2) * g * g;
            let mh = self.m[i] / bc1;
            let vh = self.v[i] / bc2;
            params[i] -= lr * mh / (vh.sqrt() + self.cfg.eps);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn converges_on_quadratic() {
        // minimise f(x) = sum((x - 3)^2) from x = 0.
        let mut adam = Adam::new(4, AdamConfig::default());
        let mut x = vec![0.0f32; 4];
        for _ in 0..2000 {
            let g: Vec<f32> = x.iter().map(|v| 2.0 * (v - 3.0)).collect();
            adam.step(&mut x, &g, 0.05);
        }
        for v in &x {
            assert!((v - 3.0).abs() < 1e-2, "{v}");
        }
    }

    #[test]
    fn first_step_moves_by_lr() {
        // With bias correction, the first Adam step is ~lr·sign(g).
        let mut adam = Adam::new(2, AdamConfig::default());
        let mut x = vec![0.0f32, 0.0];
        adam.step(&mut x, &[0.5, -2.0], 0.01);
        assert!((x[0] + 0.01).abs() < 1e-4);
        assert!((x[1] - 0.01).abs() < 1e-4);
    }

    #[test]
    fn weight_decay_pulls_towards_zero() {
        let cfg = AdamConfig { weight_decay: 0.1, ..Default::default() };
        let mut adam = Adam::new(1, cfg);
        let mut x = vec![5.0f32];
        for _ in 0..100 {
            adam.step(&mut x, &[0.0], 0.05);
        }
        assert!(x[0] < 5.0);
    }

    #[test]
    fn state_roundtrip_resumes_exactly() {
        // Two steps, checkpoint, resume, third step: bitwise identical to
        // an uninterrupted three-step run (including a re-sliced shard).
        let g: Vec<f32> = (0..8).map(|i| (i as f32 - 4.0) * 0.1).collect();
        let mut x_ref = vec![1.0f32; 8];
        let mut a_ref = Adam::new(8, AdamConfig::default());
        for _ in 0..3 {
            a_ref.step(&mut x_ref, &g, 0.01);
        }

        let mut x = vec![1.0f32; 8];
        let mut a = Adam::new(8, AdamConfig::default());
        a.step(&mut x, &g, 0.01);
        a.step(&mut x, &g, 0.01);
        let (m, v, t) = a.state();
        assert_eq!(t, 2);
        // Resume the two halves as independent shards.
        let mut lo = Adam::from_state(a.cfg, m[..4].to_vec(), v[..4].to_vec(), t);
        let mut hi = Adam::from_state(a.cfg, m[4..].to_vec(), v[4..].to_vec(), t);
        lo.step(&mut x[0..4], &g[0..4], 0.01);
        hi.step(&mut x[4..8], &g[4..8], 0.01);
        assert_eq!(x, x_ref);
    }

    #[test]
    fn sharded_updates_match_full_update() {
        // Updating two half-shards with independent Adam states equals
        // one full update (Adam is elementwise).
        let g: Vec<f32> = (0..10).map(|i| (i as f32 - 5.0) * 0.1).collect();
        let mut full = vec![1.0f32; 10];
        let mut adam_full = Adam::new(10, AdamConfig::default());
        adam_full.step(&mut full, &g, 0.01);

        let mut sharded = vec![1.0f32; 10];
        let mut a0 = Adam::new(5, AdamConfig::default());
        let mut a1 = Adam::new(5, AdamConfig::default());
        a0.step(&mut sharded[0..5], &g[0..5], 0.01);
        a1.step(&mut sharded[5..10], &g[5..10], 0.01);
        assert_eq!(full, sharded);
    }
}
