//! AOT manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime. The manifest carries every artifact's argument
//! shapes/dtypes and the model configuration; the Rust side never
//! re-derives shapes.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::json::Json;

/// Tensor dtype (the subset the model uses).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            other => bail!("unsupported dtype {other}"),
        }
    }

    /// Bytes per element, per variant. Every byte-accounting path
    /// (artifact sizes, checkpoint records, wire payloads) routes
    /// through this, so adding a half-precision variant forces the
    /// accounting to follow instead of silently mis-sizing buffers.
    pub fn bytes(self) -> usize {
        match self {
            DType::F32 => 4,
            DType::I32 => 4,
        }
    }
}

/// Shape + dtype of one artifact argument or result.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    /// Buffer size in bytes (elements × per-variant dtype width).
    pub fn byte_len(&self) -> usize {
        self.elements() * self.dtype.bytes()
    }

    fn from_json(j: &Json) -> Result<Self> {
        let shape = j
            .req("shape")?
            .as_arr()
            .context("shape not an array")?
            .iter()
            .map(|v| v.as_usize().context("shape element"))
            .collect::<Result<Vec<_>>>()?;
        let dtype = DType::parse(j.req("dtype")?.as_str().context("dtype")?)?;
        Ok(TensorSpec { shape, dtype })
    }
}

/// One compiled artifact's description.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub file: PathBuf,
    /// Tensor-parallel shard degree the artifact was compiled for (1 =
    /// the unsharded base set; t > 1 = one rank's half-layer variant
    /// with per-shard parameter `TensorSpec`s).
    pub tp: usize,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Model configuration (mirrors python `ModelConfig`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelInfo {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_seq: usize,
    pub n_layers: usize,
    pub d_ffn: usize,
    pub total_params: usize,
}

/// The four half-layer artifact stems a shard degree needs.
pub const TP_ARTIFACT_STEMS: [&str; 4] = ["attn_fwd", "ffn_fwd", "attn_bwd", "ffn_bwd"];

/// The half-layer artifact name for a stem + shard degree (e.g.
/// `attn_fwd_tp2`).
pub fn tp_artifact_name(stem: &str, tp: usize) -> String {
    format!("{stem}_tp{tp}")
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub preset: String,
    pub batch: usize,
    pub model: ModelInfo,
    pub layer_param_names: Vec<String>,
    pub layer_param_shapes: Vec<Vec<usize>>,
    /// Per-rank parameter shapes for each emitted tensor-parallel shard
    /// degree (`tp_shards` in the JSON; ordered by `layer_param_names`).
    /// The python side is the single source of shape truth — the Rust
    /// runtime validates against these, never re-deriving them.
    pub tp_shards: BTreeMap<usize, Vec<Vec<usize>>>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    /// Directory the artifact files are relative to.
    pub root: PathBuf,
}

impl Manifest {
    /// Load `<root>/<preset>/manifest.json`.
    pub fn load(root: impl AsRef<Path>, preset: &str) -> Result<Self> {
        let root = root.as_ref().to_path_buf();
        let path = root.join(preset).join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        Self::parse(&text, root).map_err(|e| anyhow::anyhow!("{path:?}: {e:#}"))
    }

    /// Parse manifest JSON with artifact paths rooted at `root`.
    pub fn parse(text: &str, root: PathBuf) -> Result<Self> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("{e}"))?;

        let m = j.req("model")?;
        let geti = |k: &str| -> Result<usize> {
            m.req(k)?.as_usize().with_context(|| format!("model.{k}"))
        };
        let model = ModelInfo {
            vocab: geti("vocab")?,
            d_model: geti("d_model")?,
            n_heads: geti("n_heads")?,
            d_seq: geti("d_seq")?,
            n_layers: geti("n_layers")?,
            d_ffn: geti("d_ffn")?,
            total_params: geti("total_params")?,
        };

        let layer_param_names: Vec<String> = j
            .req("layer_param_names")?
            .as_arr()
            .context("layer_param_names")?
            .iter()
            .map(|v| v.as_str().unwrap_or_default().to_string())
            .collect();
        let shapes_obj = j.req("layer_param_shapes")?;
        let shape_list = |obj: &Json, names: &[String]| -> Result<Vec<Vec<usize>>> {
            names
                .iter()
                .map(|n| -> Result<Vec<usize>> {
                    Ok(obj
                        .req(n)?
                        .as_arr()
                        .context("shape")?
                        .iter()
                        .map(|v| v.as_usize().unwrap_or(0))
                        .collect())
                })
                .collect()
        };
        let layer_param_shapes = shape_list(shapes_obj, &layer_param_names)?;

        // tp_shards is optional: manifests compiled before the sharded
        // variants existed simply support tp via replicated emulation.
        let mut tp_shards = BTreeMap::new();
        if let Some(shards) = j.get("tp_shards") {
            for (key, entry) in shards.as_obj().context("tp_shards")? {
                let tp: usize = key.parse().with_context(|| format!("tp_shards key {key}"))?;
                if tp < 2 {
                    bail!("tp_shards degree {tp} must be at least 2");
                }
                let shapes =
                    shape_list(entry.req("layer_param_shapes")?, &layer_param_names)?;
                tp_shards.insert(tp, shapes);
            }
        }

        let mut artifacts = BTreeMap::new();
        for (name, art) in j.req("artifacts")?.as_obj().context("artifacts")? {
            let file = root.join(art.req("file")?.as_str().context("file")?);
            // Optional for manifests predating the sharded variants.
            let tp = match art.get("tp") {
                Some(v) => v.as_usize().context("artifact tp")?,
                None => 1,
            };
            let parse_list = |key: &str| -> Result<Vec<TensorSpec>> {
                art.req(key)?
                    .as_arr()
                    .context("tensor list")?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect()
            };
            artifacts.insert(
                name.clone(),
                ArtifactSpec {
                    file,
                    tp,
                    inputs: parse_list("inputs")?,
                    outputs: parse_list("outputs")?,
                },
            );
        }

        Ok(Manifest {
            preset: j.req("preset")?.as_str().context("preset")?.to_string(),
            batch: j.req("batch")?.as_usize().context("batch")?,
            model,
            layer_param_names,
            layer_param_shapes,
            tp_shards,
            artifacts,
            root,
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts.get(name).with_context(|| format!("artifact '{name}' not in manifest"))
    }

    /// Parameter element-count of one transformer layer.
    pub fn layer_param_elements(&self) -> usize {
        self.layer_param_shapes.iter().map(|s| s.iter().product::<usize>()).sum()
    }

    /// Per-rank parameter shapes at shard degree `tp` (from the
    /// manifest's `tp_shards`); `None` when the degree was not emitted.
    pub fn shard_param_shapes(&self, tp: usize) -> Option<&Vec<Vec<usize>>> {
        self.tp_shards.get(&tp)
    }

    /// Per-rank parameter element-count of one layer at shard degree
    /// `tp` (tp = 1 is the full layer).
    pub fn layer_param_elements_tp(&self, tp: usize) -> Result<usize> {
        if tp == 1 {
            return Ok(self.layer_param_elements());
        }
        let shapes = self
            .shard_param_shapes(tp)
            .with_context(|| format!("manifest has no tp = {tp} shard shapes"))?;
        Ok(shapes.iter().map(|s| s.iter().product::<usize>()).sum())
    }

    /// Whether the manifest carries everything truly-sharded execution
    /// at degree `tp` needs: the per-rank shapes and all four half-layer
    /// artifacts. Degree 1 is always supported (the unsharded base set).
    pub fn supports_tp(&self, tp: usize) -> bool {
        if tp == 1 {
            return true;
        }
        self.tp_shards.contains_key(&tp)
            && TP_ARTIFACT_STEMS
                .iter()
                .all(|stem| self.artifacts.contains_key(&tp_artifact_name(stem, tp)))
            && self.model.n_heads % tp == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_root() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_tiny_manifest() {
        let root = artifacts_root();
        if !root.join("tiny/manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&root, "tiny").unwrap();
        assert_eq!(m.preset, "tiny");
        assert_eq!(m.model.d_model, 64);
        assert_eq!(m.layer_param_names.len(), 12);
        // 5 base artifacts, plus 4 half-layer variants per tp degree.
        assert_eq!(m.artifacts.len(), 5 + 4 * m.tp_shards.len());
        let lf = m.artifact("layer_fwd").unwrap();
        assert_eq!(lf.tp, 1);
        assert_eq!(lf.inputs.len(), 13);
        assert_eq!(lf.outputs.len(), 1);
        assert_eq!(lf.outputs[0].shape, vec![m.batch, m.model.d_seq, m.model.d_model]);
        assert!(lf.file.exists());
    }

    #[test]
    fn sharded_variants_validate_per_shard_specs() {
        let root = artifacts_root();
        if !root.join("tiny/manifest.json").exists() {
            return;
        }
        let m = Manifest::load(&root, "tiny").unwrap();
        if !m.supports_tp(2) {
            eprintln!("skipping: artifacts built without tp variants");
            return;
        }
        let shapes = m.shard_param_shapes(2).unwrap();
        // Sharded matrices carry 1/tp of the full elements; replicated
        // vectors are unchanged — per-rank total strictly between.
        let full = m.layer_param_elements();
        let shard = m.layer_param_elements_tp(2).unwrap();
        assert!(shard > full / 2 && shard < full, "{shard} vs {full}");
        // The half-layer artifacts consume exactly the sharded specs,
        // then full activations.
        let attn = m.artifact(&tp_artifact_name("attn_fwd", 2)).unwrap();
        assert_eq!(attn.tp, 2);
        for (spec, shape) in attn.inputs.iter().zip(&shapes[..6]) {
            assert_eq!(&spec.shape, shape);
        }
        let act = vec![m.batch, m.model.d_seq, m.model.d_model];
        assert_eq!(attn.inputs[6].shape, act);
        assert_eq!(attn.outputs[0].shape, act);
        let ffn_bwd = m.artifact(&tp_artifact_name("ffn_bwd", 2)).unwrap();
        assert_eq!(ffn_bwd.inputs.len(), 8);
        assert_eq!(ffn_bwd.outputs.len(), 7);
        for (spec, shape) in ffn_bwd.outputs.iter().zip(&shapes[6..12]) {
            assert_eq!(&spec.shape, shape);
        }
        assert!(attn.file.exists() && ffn_bwd.file.exists());
    }

    /// Synthetic-JSON parsing tests (no artifacts needed): the tp-shard
    /// schema round-trips and gates `supports_tp`.
    fn synthetic(tp_shards: &str, extra_artifacts: &str) -> String {
        format!(
            r#"{{
  "preset": "syn", "batch": 1,
  "model": {{"vocab": 8, "d_model": 4, "n_heads": 2, "d_seq": 2,
             "n_layers": 1, "d_ffn": 16, "total_params": 100}},
  "layer_param_names": ["w_qkv", "w_o"],
  "layer_param_shapes": {{"w_qkv": [4, 12], "w_o": [4, 4]}},
  {tp_shards}
  "artifacts": {{
    "layer_fwd": {{"file": "syn/layer_fwd.hlo.txt",
                   "inputs": [{{"shape": [4, 12], "dtype": "float32"}}],
                   "outputs": [{{"shape": [1, 2, 4], "dtype": "float32"}}]}}
    {extra_artifacts}
  }}
}}"#
        )
    }

    #[test]
    fn parses_tp_shard_schema() {
        let tp = r#""tp_shards": {"2": {"layer_param_shapes":
                      {"w_qkv": [4, 6], "w_o": [2, 4]}}},"#;
        let mut arts = String::new();
        for stem in TP_ARTIFACT_STEMS {
            arts.push_str(&format!(
                r#", "{}": {{"file": "syn/x.hlo.txt", "tp": 2,
                     "inputs": [{{"shape": [4, 6], "dtype": "float32"}}],
                     "outputs": [{{"shape": [1, 2, 4], "dtype": "float32"}}]}}"#,
                tp_artifact_name(stem, 2)
            ));
        }
        let m = Manifest::parse(&synthetic(tp, &arts), PathBuf::from("/tmp")).unwrap();
        assert_eq!(m.tp_shards.len(), 1);
        assert_eq!(m.shard_param_shapes(2).unwrap()[0], vec![4, 6]);
        assert_eq!(m.layer_param_elements_tp(2).unwrap(), 24 + 8);
        assert_eq!(m.layer_param_elements_tp(1).unwrap(), 48 + 16);
        assert!(m.supports_tp(1) && m.supports_tp(2));
        assert!(!m.supports_tp(4), "no tp=4 shapes/artifacts");
        assert_eq!(m.artifact("attn_fwd_tp2").unwrap().tp, 2);
    }

    #[test]
    fn manifests_without_tp_shards_fall_back_to_emulation() {
        let m = Manifest::parse(&synthetic("", ""), PathBuf::from("/tmp")).unwrap();
        assert!(m.tp_shards.is_empty());
        assert!(m.supports_tp(1));
        assert!(!m.supports_tp(2));
        assert!(m.layer_param_elements_tp(2).is_err());
        // Artifacts without a tp field default to the base set.
        assert_eq!(m.artifact("layer_fwd").unwrap().tp, 1);
    }

    #[test]
    fn dtype_bytes_are_per_variant_and_size_specs() {
        assert_eq!(DType::F32.bytes(), 4);
        assert_eq!(DType::I32.bytes(), 4);
        let spec = TensorSpec { shape: vec![3, 5], dtype: DType::F32 };
        assert_eq!(spec.byte_len(), 15 * DType::F32.bytes());
    }

    #[test]
    fn layer_param_elements_matches_python_count() {
        let root = artifacts_root();
        if !root.join("tiny/manifest.json").exists() {
            return;
        }
        let m = Manifest::load(&root, "tiny").unwrap();
        // tiny: d=64, di=256 — same formula as python ModelConfig.
        let (d, di) = (64usize, 256usize);
        let want =
            2 * d + d * 3 * d + 3 * d + d * d + d + 2 * d + d * di + di + di * d + d;
        assert_eq!(m.layer_param_elements(), want);
    }

    #[test]
    fn missing_preset_gives_helpful_error() {
        let err = Manifest::load(artifacts_root(), "nonexistent").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
