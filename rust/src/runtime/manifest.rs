//! AOT manifest: the contract between `python/compile/aot.py` and the
//! Rust runtime. The manifest carries every artifact's argument
//! shapes/dtypes and the model configuration; the Rust side never
//! re-derives shapes.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, Context, Result};

use super::json::Json;

/// Tensor dtype (the subset the model uses).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<Self> {
        match s {
            "float32" => Ok(DType::F32),
            "int32" => Ok(DType::I32),
            other => bail!("unsupported dtype {other}"),
        }
    }

    pub fn bytes(self) -> usize {
        4
    }
}

/// Shape + dtype of one artifact argument or result.
#[derive(Debug, Clone, PartialEq)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: DType,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    fn from_json(j: &Json) -> Result<Self> {
        let shape = j
            .req("shape")?
            .as_arr()
            .context("shape not an array")?
            .iter()
            .map(|v| v.as_usize().context("shape element"))
            .collect::<Result<Vec<_>>>()?;
        let dtype = DType::parse(j.req("dtype")?.as_str().context("dtype")?)?;
        Ok(TensorSpec { shape, dtype })
    }
}

/// One compiled artifact's description.
#[derive(Debug, Clone)]
pub struct ArtifactSpec {
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
}

/// Model configuration (mirrors python `ModelConfig`).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ModelInfo {
    pub vocab: usize,
    pub d_model: usize,
    pub n_heads: usize,
    pub d_seq: usize,
    pub n_layers: usize,
    pub d_ffn: usize,
    pub total_params: usize,
}

/// The parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub preset: String,
    pub batch: usize,
    pub model: ModelInfo,
    pub layer_param_names: Vec<String>,
    pub layer_param_shapes: Vec<Vec<usize>>,
    pub artifacts: BTreeMap<String, ArtifactSpec>,
    /// Directory the artifact files are relative to.
    pub root: PathBuf,
}

impl Manifest {
    /// Load `<root>/<preset>/manifest.json`.
    pub fn load(root: impl AsRef<Path>, preset: &str) -> Result<Self> {
        let root = root.as_ref().to_path_buf();
        let path = root.join(preset).join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {path:?} (run `make artifacts`)"))?;
        let j = Json::parse(&text).map_err(|e| anyhow::anyhow!("{path:?}: {e}"))?;

        let m = j.req("model")?;
        let geti = |k: &str| -> Result<usize> {
            m.req(k)?.as_usize().with_context(|| format!("model.{k}"))
        };
        let model = ModelInfo {
            vocab: geti("vocab")?,
            d_model: geti("d_model")?,
            n_heads: geti("n_heads")?,
            d_seq: geti("d_seq")?,
            n_layers: geti("n_layers")?,
            d_ffn: geti("d_ffn")?,
            total_params: geti("total_params")?,
        };

        let layer_param_names: Vec<String> = j
            .req("layer_param_names")?
            .as_arr()
            .context("layer_param_names")?
            .iter()
            .map(|v| v.as_str().unwrap_or_default().to_string())
            .collect();
        let shapes_obj = j.req("layer_param_shapes")?;
        let layer_param_shapes = layer_param_names
            .iter()
            .map(|n| -> Result<Vec<usize>> {
                Ok(shapes_obj
                    .req(n)?
                    .as_arr()
                    .context("shape")?
                    .iter()
                    .map(|v| v.as_usize().unwrap_or(0))
                    .collect())
            })
            .collect::<Result<Vec<_>>>()?;

        let mut artifacts = BTreeMap::new();
        for (name, art) in j.req("artifacts")?.as_obj().context("artifacts")? {
            let file = root.join(art.req("file")?.as_str().context("file")?);
            let parse_list = |key: &str| -> Result<Vec<TensorSpec>> {
                art.req(key)?
                    .as_arr()
                    .context("tensor list")?
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect()
            };
            artifacts.insert(
                name.clone(),
                ArtifactSpec { file, inputs: parse_list("inputs")?, outputs: parse_list("outputs")? },
            );
        }

        Ok(Manifest {
            preset: j.req("preset")?.as_str().context("preset")?.to_string(),
            batch: j.req("batch")?.as_usize().context("batch")?,
            model,
            layer_param_names,
            layer_param_shapes,
            artifacts,
            root,
        })
    }

    pub fn artifact(&self, name: &str) -> Result<&ArtifactSpec> {
        self.artifacts.get(name).with_context(|| format!("artifact '{name}' not in manifest"))
    }

    /// Parameter element-count of one transformer layer.
    pub fn layer_param_elements(&self) -> usize {
        self.layer_param_shapes.iter().map(|s| s.iter().product::<usize>()).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn artifacts_root() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    #[test]
    fn loads_tiny_manifest() {
        let root = artifacts_root();
        if !root.join("tiny/manifest.json").exists() {
            eprintln!("skipping: artifacts not built");
            return;
        }
        let m = Manifest::load(&root, "tiny").unwrap();
        assert_eq!(m.preset, "tiny");
        assert_eq!(m.model.d_model, 64);
        assert_eq!(m.layer_param_names.len(), 12);
        assert_eq!(m.artifacts.len(), 5);
        let lf = m.artifact("layer_fwd").unwrap();
        assert_eq!(lf.inputs.len(), 13);
        assert_eq!(lf.outputs.len(), 1);
        assert_eq!(lf.outputs[0].shape, vec![m.batch, m.model.d_seq, m.model.d_model]);
        assert!(lf.file.exists());
    }

    #[test]
    fn layer_param_elements_matches_python_count() {
        let root = artifacts_root();
        if !root.join("tiny/manifest.json").exists() {
            return;
        }
        let m = Manifest::load(&root, "tiny").unwrap();
        // tiny: d=64, di=256 — same formula as python ModelConfig.
        let (d, di) = (64usize, 256usize);
        let want =
            2 * d + d * 3 * d + 3 * d + d * d + d + 2 * d + d * di + di + di * d + d;
        assert_eq!(m.layer_param_elements(), want);
    }

    #[test]
    fn missing_preset_gives_helpful_error() {
        let err = Manifest::load(artifacts_root(), "nonexistent").unwrap_err();
        assert!(format!("{err:#}").contains("make artifacts"));
    }
}
