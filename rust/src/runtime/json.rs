//! Minimal JSON parser (recursive descent) for reading the AOT manifest.
//!
//! The offline build has no serde; the manifest grammar is small and
//! fully under our control (emitted by `python/compile/aot.py`), so a
//! ~150-line parser is the honest dependency-free substrate.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(src: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: src.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `get` that errors with the key name — manifest fields are required.
    pub fn req(&self, key: &str) -> Result<&Json, JsonError> {
        self.get(key).ok_or_else(|| JsonError(format!("missing key '{key}'")))
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    /// Integer accessor: `None` for non-numbers, negative or fractional
    /// values, and magnitudes beyond f64's exact-integer range (2^53).
    /// The old `as usize` cast silently truncated `1.5` and saturated
    /// `-1` — a corrupted manifest must be rejected, not reinterpreted.
    pub fn as_usize(&self) -> Option<usize> {
        const MAX_EXACT: f64 = 9_007_199_254_740_992.0; // 2^53
        match self.as_f64() {
            Some(n) if (0.0..=MAX_EXACT).contains(&n) && n.fract() == 0.0 => Some(n as usize),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }
}

/// Parse error with a short description.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct JsonError(pub String);

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error: {}", self.0)
    }
}
impl std::error::Error for JsonError {}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError(format!("{msg} at byte {}", self.i))
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{s}'")))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek().ok_or_else(|| self.err("eof"))? {
            b'n' => self.lit("null", Json::Null),
            b't' => self.lit("true", Json::Bool(true)),
            b'f' => self.lit("false", Json::Bool(false)),
            b'"' => self.string().map(Json::Str),
            b'[' => self.array(),
            b'{' => self.object(),
            _ => self.number(),
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut out = String::new();
        loop {
            let c = self.peek().ok_or_else(|| self.err("eof in string"))?;
            self.i += 1;
            match c {
                b'"' => return Ok(out),
                b'\\' => {
                    let e = self.peek().ok_or_else(|| self.err("eof in escape"))?;
                    self.i += 1;
                    match e {
                        b'"' => out.push('"'),
                        b'\\' => out.push('\\'),
                        b'/' => out.push('/'),
                        b'n' => out.push('\n'),
                        b't' => out.push('\t'),
                        b'r' => out.push('\r'),
                        b'b' => out.push('\u{8}'),
                        b'f' => out.push('\u{c}'),
                        b'u' => {
                            if self.i + 4 > self.b.len() {
                                return Err(self.err("short \\u escape"));
                            }
                            let hex = std::str::from_utf8(&self.b[self.i..self.i + 4])
                                .map_err(|_| self.err("bad \\u escape"))?;
                            let code = u32::from_str_radix(hex, 16)
                                .map_err(|_| self.err("bad \\u escape"))?;
                            self.i += 4;
                            out.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                }
                _ => {
                    // Collect the full UTF-8 sequence.
                    let start = self.i - 1;
                    let len = utf8_len(c);
                    self.i = start + len;
                    if self.i > self.b.len() {
                        return Err(self.err("truncated utf-8"));
                    }
                    out.push_str(
                        std::str::from_utf8(&self.b[start..self.i])
                            .map_err(|_| self.err("bad utf-8"))?,
                    );
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        while let Some(c) = self.peek() {
            if matches!(c, b'-' | b'+' | b'.' | b'e' | b'E' | b'0'..=b'9') {
                self.i += 1;
            } else {
                break;
            }
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            self.ws();
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }
}

fn utf8_len(first: u8) -> usize {
    match first {
        0x00..=0x7f => 1,
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -12.5e2 ").unwrap(), Json::Num(-1250.0));
        assert_eq!(Json::parse("\"a\\nb\"").unwrap(), Json::Str("a\nb".into()));
    }

    #[test]
    fn parses_nested_structures() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": {}}"#).unwrap();
        let a = v.get("a").unwrap().as_arr().unwrap();
        assert_eq!(a[0].as_f64(), Some(1.0));
        assert_eq!(a[2].get("b").unwrap().as_str(), Some("c"));
        assert!(v.get("d").unwrap().as_obj().unwrap().is_empty());
    }

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
  "preset": "tiny",
  "model": {"vocab": 256, "d_model": 64},
  "artifacts": {
    "layer_fwd": {"file": "tiny/layer_fwd.hlo.txt",
                  "inputs": [{"shape": [64], "dtype": "float32"}]}
  }
}"#;
        let v = Json::parse(doc).unwrap();
        assert_eq!(v.get("preset").unwrap().as_str(), Some("tiny"));
        let model = v.get("model").unwrap();
        assert_eq!(model.get("vocab").unwrap().as_usize(), Some(256));
        let art = v.get("artifacts").unwrap().get("layer_fwd").unwrap();
        let inp = &art.get("inputs").unwrap().as_arr().unwrap()[0];
        assert_eq!(inp.get("shape").unwrap().as_arr().unwrap()[0].as_usize(), Some(64));
    }

    #[test]
    fn rejects_malformed_documents() {
        for bad in ["{", "[1,", "\"unterminated", "{\"a\" 1}", "12x", "[1 2]"] {
            assert!(Json::parse(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn as_usize_rejects_non_integral_and_negative_numbers() {
        assert_eq!(Json::parse("3").unwrap().as_usize(), Some(3));
        assert_eq!(Json::parse("3.0").unwrap().as_usize(), Some(3));
        assert_eq!(Json::parse("0").unwrap().as_usize(), Some(0));
        // Fractional values must not truncate.
        assert_eq!(Json::parse("1.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("0.25").unwrap().as_usize(), None);
        // Negative values must not wrap/saturate.
        assert_eq!(Json::parse("-1").unwrap().as_usize(), None);
        assert_eq!(Json::parse("-3.5").unwrap().as_usize(), None);
        // Beyond f64's exact-integer range the value is untrustworthy.
        assert_eq!(Json::parse("1e300").unwrap().as_usize(), None);
        // Non-numbers stay None.
        assert_eq!(Json::parse("\"7\"").unwrap().as_usize(), None);
        assert_eq!(Json::parse("true").unwrap().as_usize(), None);
    }

    #[test]
    fn unicode_escapes() {
        assert_eq!(Json::parse(r#""Ab""#).unwrap(), Json::Str("Ab".into()));
        assert_eq!(Json::parse("\"héllo\"").unwrap(), Json::Str("héllo".into()));
    }
}
