//! PJRT execution engine: loads the HLO-text artifacts, compiles them once
//! on the CPU PJRT client, and executes them from the Rust hot path.
//!
//! One `Engine` per worker thread (the PJRT client wrapper is not Sync);
//! compilation results are cached per engine. Host tensors are plain
//! `Vec<f32>` / `Vec<i32>`; conversion to/from `xla::Literal` happens at
//! the call boundary.

use std::collections::HashMap;
use std::path::Path;

use anyhow::{bail, Context, Result};

use super::manifest::{ArtifactSpec, DType, Manifest, TensorSpec};

/// A host-side tensor (f32 or i32), shape-carrying.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32 { shape: Vec<usize>, data: Vec<f32> },
    I32 { shape: Vec<usize>, data: Vec<i32> },
}

impl HostTensor {
    pub fn f32(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor::F32 { shape, data }
    }

    pub fn i32(shape: Vec<usize>, data: Vec<i32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        HostTensor::I32 { shape, data }
    }

    pub fn zeros(spec: &TensorSpec) -> Self {
        match spec.dtype {
            DType::F32 => HostTensor::f32(spec.shape.clone(), vec![0.0; spec.elements()]),
            DType::I32 => HostTensor::i32(spec.shape.clone(), vec![0; spec.elements()]),
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32 { shape, .. } | HostTensor::I32 { shape, .. } => shape,
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut Vec<f32>> {
        match self {
            HostTensor::F32 { data, .. } => Ok(data),
            _ => bail!("tensor is not f32"),
        }
    }

    pub fn scalar_f32(&self) -> Result<f32> {
        let d = self.as_f32()?;
        if d.len() != 1 {
            bail!("not a scalar: {} elements", d.len());
        }
        Ok(d[0])
    }

    fn to_literal(&self) -> Result<xla::Literal> {
        let dims: Vec<i64> = self.shape().iter().map(|&d| d as i64).collect();
        let lit = match self {
            HostTensor::F32 { data, .. } => xla::Literal::vec1(data.as_slice()),
            HostTensor::I32 { data, .. } => xla::Literal::vec1(data.as_slice()),
        };
        Ok(lit.reshape(&dims)?)
    }

    /// Upload to a device buffer. Buffers are Rust-owned (freed on Drop);
    /// the literal-based `execute` path in the C wrapper leaks its
    /// transient per-call device buffers (§Perf L3 / EXPERIMENTS.md), so
    /// the hot path always goes through buffers + `execute_b`.
    fn to_buffer(&self, client: &xla::PjRtClient) -> Result<xla::PjRtBuffer> {
        let b = match self {
            HostTensor::F32 { shape, data } => {
                client.buffer_from_host_buffer::<f32>(data, shape, None)?
            }
            HostTensor::I32 { shape, data } => {
                client.buffer_from_host_buffer::<i32>(data, shape, None)?
            }
        };
        Ok(b)
    }

    fn from_literal(lit: &xla::Literal) -> Result<Self> {
        let shape = lit
            .array_shape()?
            .dims()
            .iter()
            .map(|&d| d as usize)
            .collect::<Vec<_>>();
        match lit.ty()? {
            xla::ElementType::F32 => Ok(HostTensor::F32 { shape, data: lit.to_vec::<f32>()? }),
            xla::ElementType::S32 => Ok(HostTensor::I32 { shape, data: lit.to_vec::<i32>()? }),
            other => bail!("unsupported output element type {other:?}"),
        }
    }

    fn matches(&self, spec: &TensorSpec) -> bool {
        let dt_ok = matches!(
            (self, spec.dtype),
            (HostTensor::F32 { .. }, DType::F32) | (HostTensor::I32 { .. }, DType::I32)
        );
        dt_ok && self.shape() == spec.shape.as_slice()
    }
}

/// A compiled artifact plus its manifest spec.
struct Compiled {
    exe: xla::PjRtLoadedExecutable,
    spec: ArtifactSpec,
}

/// The PJRT execution engine for one worker.
pub struct Engine {
    client: xla::PjRtClient,
    manifest: Manifest,
    compiled: HashMap<String, Compiled>,
    /// Cumulative PJRT execute time (profiling; §Perf).
    pub execute_secs: f64,
    pub execute_calls: u64,
}

impl Engine {
    /// Create an engine and eagerly compile the named artifacts
    /// (compile-once semantics: the hot path never compiles).
    pub fn new(root: impl AsRef<Path>, preset: &str, artifact_names: &[&str]) -> Result<Self> {
        let manifest = Manifest::load(root, preset)?;
        let client = xla::PjRtClient::cpu()?;
        let mut engine = Engine {
            client,
            manifest,
            compiled: HashMap::new(),
            execute_secs: 0.0,
            execute_calls: 0,
        };
        for name in artifact_names {
            engine.compile(name)?;
        }
        Ok(engine)
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Compile (and cache) one artifact.
    pub fn compile(&mut self, name: &str) -> Result<()> {
        if self.compiled.contains_key(name) {
            return Ok(());
        }
        let spec = self.manifest.artifact(name)?.clone();
        let path = spec
            .file
            .to_str()
            .context("artifact path not utf-8")?
            .to_string();
        let proto = xla::HloModuleProto::from_text_file(&path)
            .with_context(|| format!("parsing HLO text {path}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {name}"))?;
        self.compiled.insert(name.to_string(), Compiled { exe, spec });
        Ok(())
    }

    /// Execute an artifact with shape-checked inputs; returns its outputs
    /// as host tensors (the artifact's HLO returns a tuple).
    pub fn execute(&mut self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let c = self
            .compiled
            .get(name)
            .with_context(|| format!("artifact {name} not compiled"))?;
        if inputs.len() != c.spec.inputs.len() {
            bail!(
                "{name}: {} inputs given, manifest says {}",
                inputs.len(),
                c.spec.inputs.len()
            );
        }
        for (i, (t, spec)) in inputs.iter().zip(&c.spec.inputs).enumerate() {
            if !t.matches(spec) {
                bail!(
                    "{name}: input {i} shape {:?} does not match manifest {:?} ({:?})",
                    t.shape(),
                    spec.shape,
                    spec.dtype
                );
            }
        }
        let t0 = std::time::Instant::now();
        let buffers: Vec<xla::PjRtBuffer> =
            inputs.iter().map(|t| t.to_buffer(&self.client)).collect::<Result<_>>()?;
        let result = c.exe.execute_b::<xla::PjRtBuffer>(&buffers)?[0][0].to_literal_sync()?;
        self.execute_secs += t0.elapsed().as_secs_f64();
        self.execute_calls += 1;
        let parts = result.to_tuple()?;
        let outs: Vec<HostTensor> =
            parts.iter().map(HostTensor::from_literal).collect::<Result<_>>()?;
        if outs.len() != c.spec.outputs.len() {
            bail!("{name}: {} outputs, manifest says {}", outs.len(), c.spec.outputs.len());
        }
        Ok(outs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn artifacts_root() -> PathBuf {
        PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts")
    }

    fn have_artifacts() -> bool {
        artifacts_root().join("tiny/manifest.json").exists()
    }

    #[test]
    fn embed_fwd_executes_and_checks_shapes() {
        if !have_artifacts() {
            return;
        }
        let mut e = Engine::new(artifacts_root(), "tiny", &["embed_fwd"]).unwrap();
        let m = e.manifest().model;
        let (v, d, s, b) = (m.vocab, m.d_model, m.d_seq, e.manifest().batch);
        let table = HostTensor::f32(vec![v, d], (0..v * d).map(|i| i as f32 * 1e-4).collect());
        let pos = HostTensor::f32(vec![s, d], vec![0.5; s * d]);
        let tokens = HostTensor::i32(vec![b, s], vec![3; b * s]);
        let out = e.execute("embed_fwd", &[table.clone(), pos, tokens]).unwrap();
        assert_eq!(out.len(), 1);
        assert_eq!(out[0].shape(), &[b, s, d]);
        // x[b,s,:] = table[3,:] + 0.5
        let x = out[0].as_f32().unwrap();
        let want0 = (3 * d) as f32 * 1e-4 + 0.5;
        assert!((x[0] - want0).abs() < 1e-6, "{} vs {}", x[0], want0);

        // Wrong shape must be rejected before reaching PJRT.
        let bad = HostTensor::i32(vec![b, s + 1], vec![0; b * (s + 1)]);
        let table2 = HostTensor::f32(vec![v, d], vec![0.0; v * d]);
        let pos2 = HostTensor::f32(vec![s, d], vec![0.0; s * d]);
        assert!(e.execute("embed_fwd", &[table2, pos2, bad]).is_err());
    }

    #[test]
    fn layer_roundtrip_fwd_bwd_shapes() {
        if !have_artifacts() {
            return;
        }
        let mut e = Engine::new(artifacts_root(), "tiny", &["layer_fwd", "layer_bwd"]).unwrap();
        let specs = e.manifest().artifact("layer_fwd").unwrap().inputs.clone();
        let params: Vec<HostTensor> = specs[..12]
            .iter()
            .map(|s| {
                let n = s.elements();
                HostTensor::f32(s.shape.clone(), (0..n).map(|i| (i % 7) as f32 * 0.01).collect())
            })
            .collect();
        let x = HostTensor::zeros(&specs[12]);
        let mut inputs = params.clone();
        inputs.push(x.clone());
        let y = e.execute("layer_fwd", &inputs).unwrap();
        assert_eq!(y[0].shape(), x.shape());

        let mut bwd_in = params;
        bwd_in.push(x.clone());
        bwd_in.push(y[0].clone());
        let grads = e.execute("layer_bwd", &bwd_in).unwrap();
        assert_eq!(grads.len(), 13);
        assert_eq!(grads[12].shape(), x.shape());
        // Gradients must be finite.
        for g in &grads {
            assert!(g.as_f32().unwrap().iter().all(|v| v.is_finite()));
        }
    }
}
