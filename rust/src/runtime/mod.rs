//! PJRT runtime: artifact manifest, HLO loading/compilation, and the
//! execution engine the trainer's hot path calls. Python never runs here
//! — artifacts are produced once by `make artifacts`.

pub mod executor;
pub mod json;
pub mod manifest;

pub use executor::{Engine, HostTensor};
pub use json::{Json, JsonError};
pub use manifest::{
    tp_artifact_name, ArtifactSpec, DType, Manifest, ModelInfo, TensorSpec, TP_ARTIFACT_STEMS,
};
