//! State store: the executable half of §8.2's real-time checkpoints.
//!
//! The analysis in [`super`] shows that with layered gradient
//! accumulation the state-offload stream is intense enough (ν = b·d_s)
//! to hide behind compute even on slow tiers. This module is where those
//! streams *land*: every `OffloadStore` op the trainer executes writes
//! one [`StateRecord`] — a layer's owned parameter shard plus its Adam
//! moments — so that after any step the store holds a durable, complete
//! snapshot of the training state, one batch behind at worst.
//!
//! Records are sharded exactly like the ZeRO-style partition
//! ([`crate::partition::ShardMap`]): with `n_b` ranks each layer is
//! covered by `n_b` disjoint `[lo, hi)` records. Resume does not need
//! the writer's `n_b` — [`assemble`] stitches any complete cover back
//! into the full buffers, and the reader re-slices its own shard, which
//! is what makes *elastic* resume (different cluster size) work.
//!
//! Two tiers are provided: [`MemoryStore`] (the CPU-memory tier — byte
//! accounting and in-process resume, no durability) and [`FileStore`]
//! (the durable tier: one file per record, written atomically via
//! tmp-file + rename, so a crash mid-write never corrupts an earlier
//! checkpoint). Crash consistency is read-side: a step only counts as a
//! checkpoint once [`latest_complete_step`] can fully cover every slot.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

use anyhow::{bail, Context, Result};

use crate::runtime::DType;

/// Header magic ("LGASTORE") of a serialised [`StateRecord`].
pub const STORE_MAGIC: u64 = 0x4c47_4153_544f_5245;
/// Serialisation format version. v2 added the tensor-parallel shard
/// provenance (`tp`, `tp_rank`): with truly sharded layer compute every
/// tp rank owns a *different* slice of the state, so records carry which
/// shard layout they were written under and resume can re-shard across a
/// tp change. v3 added the data-parallel sharding provenance (`zero`,
/// `dp_rank`): which ZeRO stage the writer ran (0 also covers the
/// modular partition and full slots) and which dp rank owned the shard —
/// resume re-slices by `[lo, hi)` regardless, the provenance makes a
/// store auditable across a dp/zero change.
pub const STORE_VERSION: u64 = 3;
/// Header length in bytes: 13 u64 fields.
const HEADER_U64S: usize = 13;

/// Slot id of one layer's state written by one tensor-parallel rank:
/// each tp rank owns a disjoint block of `d_l + 3` slot ids, so shard
/// records of different ranks never collide. tp rank 0's block starts at
/// 0 — identical to the pre-sharding slot space, so tp = 1 stores are
/// unchanged on disk.
pub fn slot_layer(d_l: usize, tp_rank: usize, layer: usize) -> usize {
    tp_rank * (d_l + 3) + layer
}

/// Slot id of the embedding table (the slots after the `d_l` layers hold
/// the non-layer state: embedding, positional table, output head — all
/// replicated across tp, written by tp rank 0 into its block).
pub fn slot_embed(d_l: usize) -> usize {
    d_l
}

/// Slot id of the positional-embedding table.
pub fn slot_pos(d_l: usize) -> usize {
    d_l + 1
}

/// Slot id of the output head.
pub fn slot_head(d_l: usize) -> usize {
    d_l + 2
}

/// One streamed checkpoint record: a `[lo, hi)` shard of one slot's
/// parameters and Adam moments, as written by one rank after one
/// optimizer step.
#[derive(Debug, Clone, PartialEq)]
pub struct StateRecord {
    /// Training step the record belongs to (state *after* this step).
    pub step: u64,
    /// Slot: layer index, or one of the [`slot_embed`]-style specials.
    pub slot: u64,
    /// Shard start (elements into the slot's flat buffer).
    pub lo: u64,
    /// Shard end (exclusive).
    pub hi: u64,
    /// Full length of the slot's flat buffer (for cover checking).
    pub total: u64,
    /// Adam step counter at write time.
    pub adam_t: u64,
    /// The writer's global micro-batch count (n_b · n_μ). A resumed run
    /// may re-shard (different n_b) but must keep this product — it is
    /// what the split-invariant data keying and gradient scale hinge on
    /// — so resume verifies it instead of silently diverging.
    pub global_mbs: u64,
    /// Tensor-parallel shard layout the slot's state was written under
    /// (1 = unsharded, including replicated-compute emulation).
    pub tp: u64,
    /// Which tp rank's shard this slot holds (0 when `tp` is 1).
    pub tp_rank: u64,
    /// ZeRO stage (0–3) the writer ran under. 0 for full slots and for
    /// the modular partition (whose shards are `[lo, hi)`-described the
    /// same way).
    pub zero: u64,
    /// Data-parallel rank that owned this `[lo, hi)` shard (0 for full
    /// slots).
    pub dp_rank: u64,
    /// Parameter values over `[lo, hi)`.
    pub params: Vec<f32>,
    /// Adam first moment over `[lo, hi)`.
    pub m: Vec<f32>,
    /// Adam second moment over `[lo, hi)`.
    pub v: Vec<f32>,
}

impl StateRecord {
    /// Elements in the shard.
    pub fn shard_len(&self) -> usize {
        (self.hi - self.lo) as usize
    }

    /// Serialised size in bytes: the u64 header plus three fp32 arrays
    /// (params, m, v) sized by the dtype's per-variant width.
    pub fn byte_len(&self) -> usize {
        8 * HEADER_U64S + 3 * DType::F32.bytes() * self.shard_len()
    }

    fn check(&self) -> Result<()> {
        if self.lo > self.hi || self.hi > self.total {
            bail!(
                "record range [{}, {}) outside slot of {} elements",
                self.lo,
                self.hi,
                self.total
            );
        }
        let n = self.shard_len();
        if self.params.len() != n || self.m.len() != n || self.v.len() != n {
            bail!(
                "record buffers ({}, {}, {}) do not match range [{}, {})",
                self.params.len(),
                self.m.len(),
                self.v.len(),
                self.lo,
                self.hi
            );
        }
        if self.tp == 0 || self.tp_rank >= self.tp {
            bail!("bad shard provenance: tp rank {} of {}", self.tp_rank, self.tp);
        }
        if self.zero > 3 {
            bail!("bad shard provenance: ZeRO stage {} (stages are 0-3)", self.zero);
        }
        Ok(())
    }

    /// Serialise: little-endian u64 header, then params/m/v as f32 LE.
    pub fn to_bytes(&self) -> Result<Vec<u8>> {
        self.check()?;
        let mut out = Vec::with_capacity(self.byte_len());
        for x in [
            STORE_MAGIC,
            STORE_VERSION,
            self.step,
            self.slot,
            self.lo,
            self.hi,
            self.total,
            self.adam_t,
            self.global_mbs,
            self.tp,
            self.tp_rank,
            self.zero,
            self.dp_rank,
        ] {
            out.extend_from_slice(&x.to_le_bytes());
        }
        for arr in [&self.params, &self.m, &self.v] {
            for f in arr.iter() {
                out.extend_from_slice(&f.to_le_bytes());
            }
        }
        Ok(out)
    }

    /// Deserialise and validate a record.
    pub fn from_bytes(b: &[u8]) -> Result<StateRecord> {
        if b.len() < 8 * HEADER_U64S {
            bail!("record truncated: {} bytes", b.len());
        }
        let u = |i: usize| u64::from_le_bytes(b[8 * i..8 * i + 8].try_into().unwrap());
        if u(0) != STORE_MAGIC {
            bail!("bad record magic {:#x}", u(0));
        }
        if u(1) != STORE_VERSION {
            bail!("unsupported record version {}", u(1));
        }
        let (step, slot, lo, hi, total, adam_t) = (u(2), u(3), u(4), u(5), u(6), u(7));
        let (global_mbs, tp, tp_rank) = (u(8), u(9), u(10));
        let (zero, dp_rank) = (u(11), u(12));
        if lo > hi || hi > total {
            bail!("bad record range [{lo}, {hi}) of {total}");
        }
        if tp == 0 || tp_rank >= tp {
            bail!("bad shard provenance: tp rank {tp_rank} of {tp}");
        }
        if zero > 3 {
            bail!("bad shard provenance: ZeRO stage {zero} (stages are 0-3)");
        }
        let n = (hi - lo) as usize;
        let w = DType::F32.bytes();
        let body = &b[8 * HEADER_U64S..];
        if body.len() != 3 * w * n {
            bail!("record body {} bytes, want {}", body.len(), 3 * w * n);
        }
        let floats = |k: usize| -> Vec<f32> {
            body[w * k * n..w * (k + 1) * n]
                .chunks_exact(w)
                .map(|c| f32::from_le_bytes(c.try_into().unwrap()))
                .collect()
        };
        Ok(StateRecord {
            step,
            slot,
            lo,
            hi,
            total,
            adam_t,
            global_mbs,
            tp,
            tp_rank,
            zero,
            dp_rank,
            params: floats(0),
            m: floats(1),
            v: floats(2),
        })
    }
}

/// Where `OffloadStore` ops land: a byte-accounted checkpoint store.
/// Implementations are shared across worker threads (one `put` per
/// executed `OffloadStore` op, concurrent across stages and ranks).
pub trait StateStore: Send + Sync {
    /// Persist one record, replacing any prior record with the same
    /// (step, slot, lo, hi) key. Puts are O(record): a step is only ever
    /// written under one sharding — re-executing a step after a crash
    /// (possibly re-sharded) requires pruning it first, which the
    /// trainer does at resume via [`StateStore::prune_steps_after`].
    fn put(&self, rec: &StateRecord) -> Result<()>;

    /// Every record of one (step, slot), in unspecified order.
    fn read(&self, step: u64, slot: u64) -> Result<Vec<StateRecord>>;

    /// Steps with at least one record, ascending.
    fn steps(&self) -> Result<Vec<u64>>;

    /// Drop every step strictly below `step` — the retention knob that
    /// keeps a long real-time-checkpoint run from accumulating one full
    /// state copy per batch. The trainer keeps the in-flight step and
    /// the last complete one; everything older is dead weight.
    fn prune_steps_before(&self, step: u64) -> Result<()>;

    /// Drop every step strictly *above* `step` — how resume reclaims a
    /// torn in-flight step before re-executing it: the re-write (possibly
    /// under a different sharding) must start from an empty step, so
    /// stale shards can never poison the new cover.
    fn prune_steps_after(&self, step: u64) -> Result<()>;

    /// Total payload bytes written (the ν-stream accounting of §8.2).
    fn bytes_written(&self) -> u64;

    /// Total payload bytes read back (resume traffic).
    fn bytes_read(&self) -> u64;

    /// Records written so far.
    fn records_written(&self) -> u64;
}

/// A slot reassembled from a complete record cover.
#[derive(Debug, Clone, PartialEq)]
pub struct AssembledSlot {
    pub params: Vec<f32>,
    pub m: Vec<f32>,
    pub v: Vec<f32>,
    pub adam_t: u64,
}

/// Whether `records` form a gapless, non-overlapping cover of
/// `[0, total)` with consistent metadata.
pub fn covers(records: &[StateRecord], total: usize) -> bool {
    let mut spans: Vec<(u64, u64)> = records
        .iter()
        .filter(|r| r.total as usize == total)
        .map(|r| (r.lo, r.hi))
        .collect();
    spans.sort_unstable();
    let mut at = 0u64;
    for (lo, hi) in spans {
        if lo != at {
            return false;
        }
        at = hi;
    }
    at as usize == total
}

/// Stitch a complete record cover back into full parameter/moment
/// buffers. Errors on gaps, overlaps, length mismatches or inconsistent
/// Adam step counters — a torn checkpoint must fail loudly, not resume
/// silently wrong.
pub fn assemble(records: &[StateRecord], total: usize) -> Result<AssembledSlot> {
    if records.is_empty() {
        bail!("no records to assemble");
    }
    let mut recs: Vec<&StateRecord> = records.iter().collect();
    recs.sort_unstable_by_key(|r| (r.lo, r.hi));
    let adam_t = recs[0].adam_t;
    let mut params = vec![0.0f32; total];
    let mut m = vec![0.0f32; total];
    let mut v = vec![0.0f32; total];
    let mut at = 0usize;
    for r in recs {
        r.check()?;
        if r.total as usize != total {
            bail!("record covers a slot of {} elements, want {}", r.total, total);
        }
        if r.adam_t != adam_t {
            bail!("inconsistent Adam step counters ({} vs {})", r.adam_t, adam_t);
        }
        let (lo, hi) = (r.lo as usize, r.hi as usize);
        if lo != at {
            bail!("cover gap/overlap at element {at} (next record starts at {lo})");
        }
        params[lo..hi].copy_from_slice(&r.params);
        m[lo..hi].copy_from_slice(&r.m);
        v[lo..hi].copy_from_slice(&r.v);
        at = hi;
    }
    if at != total {
        bail!("cover stops at element {at} of {total}");
    }
    Ok(AssembledSlot { params, m, v, adam_t })
}

/// The newest step whose records fully cover every `(slot, total)` pair —
/// the crash-consistency rule: a step counts as checkpointed only once
/// every slot can be reassembled. A step torn by a mid-batch crash is
/// skipped and the previous complete one wins.
///
/// This reads full record bodies to check coverage; with retention
/// pruning the scan is bounded to the last two steps (≤ two state
/// copies), and it runs once per resume, so the simplicity is worth the
/// extra cold-path I/O over a names-only scan.
pub fn latest_complete_step(
    store: &dyn StateStore,
    slots: &[(usize, usize)],
) -> Result<Option<u64>> {
    for &step in store.steps()?.iter().rev() {
        let mut complete = true;
        for &(slot, total) in slots {
            if !covers(&store.read(step, slot as u64)?, total) {
                complete = false;
                break;
            }
        }
        if complete {
            return Ok(Some(step));
        }
    }
    Ok(None)
}

// ---------------------------------------------------------------------------
// CPU-memory tier
// ---------------------------------------------------------------------------

/// In-memory store: the CPU-RAM tier of Figure 7. Survives nothing, but
/// carries the same interface and byte accounting, so the trainer can
/// exercise (and measure) the streaming path without touching disk.
#[derive(Debug, Default)]
pub struct MemoryStore {
    map: Mutex<HashMap<(u64, u64), HashMap<(u64, u64), StateRecord>>>,
    written: AtomicU64,
    read_bytes: AtomicU64,
    records: AtomicU64,
}

impl MemoryStore {
    pub fn new() -> Self {
        Self::default()
    }
}

impl StateStore for MemoryStore {
    fn put(&self, rec: &StateRecord) -> Result<()> {
        rec.check()?;
        self.written.fetch_add(rec.byte_len() as u64, Ordering::Relaxed);
        self.records.fetch_add(1, Ordering::Relaxed);
        let mut map = self.map.lock().expect("memory store poisoned");
        map.entry((rec.step, rec.slot)).or_default().insert((rec.lo, rec.hi), rec.clone());
        Ok(())
    }

    fn read(&self, step: u64, slot: u64) -> Result<Vec<StateRecord>> {
        let map = self.map.lock().expect("memory store poisoned");
        let recs: Vec<StateRecord> =
            map.get(&(step, slot)).map(|m| m.values().cloned().collect()).unwrap_or_default();
        let bytes: usize = recs.iter().map(StateRecord::byte_len).sum();
        self.read_bytes.fetch_add(bytes as u64, Ordering::Relaxed);
        Ok(recs)
    }

    fn steps(&self) -> Result<Vec<u64>> {
        let map = self.map.lock().expect("memory store poisoned");
        let mut steps: Vec<u64> = map.keys().map(|&(s, _)| s).collect();
        steps.sort_unstable();
        steps.dedup();
        Ok(steps)
    }

    fn prune_steps_before(&self, step: u64) -> Result<()> {
        let mut map = self.map.lock().expect("memory store poisoned");
        map.retain(|&(s, _), _| s >= step);
        Ok(())
    }

    fn prune_steps_after(&self, step: u64) -> Result<()> {
        let mut map = self.map.lock().expect("memory store poisoned");
        map.retain(|&(s, _), _| s <= step);
        Ok(())
    }

    fn bytes_written(&self) -> u64 {
        self.written.load(Ordering::Relaxed)
    }

    fn bytes_read(&self) -> u64 {
        self.read_bytes.load(Ordering::Relaxed)
    }

    fn records_written(&self) -> u64 {
        self.records.load(Ordering::Relaxed)
    }
}

// ---------------------------------------------------------------------------
// Durable file tier
// ---------------------------------------------------------------------------

/// File-backed durable store: one file per record under
/// `<root>/step_XXXXXXXX/`, written to a temp name and atomically
/// renamed, so readers never observe a half-written record and a crash
/// mid-step leaves every earlier checkpoint intact.
#[derive(Debug)]
pub struct FileStore {
    root: PathBuf,
    written: AtomicU64,
    read_bytes: AtomicU64,
    records: AtomicU64,
}

impl FileStore {
    pub fn new(root: impl AsRef<Path>) -> Result<Self> {
        let root = root.as_ref().to_path_buf();
        std::fs::create_dir_all(&root)
            .with_context(|| format!("creating checkpoint store at {root:?}"))?;
        Ok(FileStore {
            root,
            written: AtomicU64::new(0),
            read_bytes: AtomicU64::new(0),
            records: AtomicU64::new(0),
        })
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    fn step_dir(&self, step: u64) -> PathBuf {
        self.root.join(format!("step_{step:08}"))
    }

    fn rec_name(slot: u64, lo: u64, hi: u64) -> String {
        format!("slot_{slot:05}_{lo}_{hi}.ckpt")
    }
}

impl StateStore for FileStore {
    fn put(&self, rec: &StateRecord) -> Result<()> {
        let bytes = rec.to_bytes()?;
        let dir = self.step_dir(rec.step);
        std::fs::create_dir_all(&dir).with_context(|| format!("creating {dir:?}"))?;
        let final_path = dir.join(Self::rec_name(rec.slot, rec.lo, rec.hi));
        // Atomic publish: write the whole record to a temp name in the
        // same directory, fsync it, rename over the final name, then
        // fsync the directory. Without the syncs a crash shortly after
        // the rename can surface the *name* without the *data* (rename
        // is durable only once the directory entry is flushed) — a
        // renamed-but-empty record would read as "complete".
        let tmp = dir.join(format!(".tmp_{}_{}_{}", rec.slot, rec.lo, rec.hi));
        {
            use std::io::Write;
            let mut f =
                std::fs::File::create(&tmp).with_context(|| format!("creating {tmp:?}"))?;
            f.write_all(&bytes).with_context(|| format!("writing {tmp:?}"))?;
            f.sync_all().with_context(|| format!("syncing {tmp:?}"))?;
        }
        std::fs::rename(&tmp, &final_path).with_context(|| format!("publishing {final_path:?}"))?;
        std::fs::File::open(&dir)
            .and_then(|d| d.sync_all())
            .with_context(|| format!("syncing directory {dir:?}"))?;
        self.written.fetch_add(bytes.len() as u64, Ordering::Relaxed);
        self.records.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    fn read(&self, step: u64, slot: u64) -> Result<Vec<StateRecord>> {
        let dir = self.step_dir(step);
        let mut out = Vec::new();
        let entries = match std::fs::read_dir(&dir) {
            Ok(e) => e,
            Err(_) => return Ok(out), // no such step: empty, not an error
        };
        let prefix = format!("slot_{slot:05}_");
        for entry in entries {
            let entry = entry?;
            let name = entry.file_name();
            let name = name.to_string_lossy();
            if !name.starts_with(&prefix) || !name.ends_with(".ckpt") {
                continue;
            }
            let bytes = std::fs::read(entry.path())
                .with_context(|| format!("reading {:?}", entry.path()))?;
            self.read_bytes.fetch_add(bytes.len() as u64, Ordering::Relaxed);
            let rec = StateRecord::from_bytes(&bytes)
                .with_context(|| format!("parsing {:?}", entry.path()))?;
            if rec.step != step || rec.slot != slot {
                bail!("record at {:?} claims (step {}, slot {})", entry.path(), rec.step, rec.slot);
            }
            out.push(rec);
        }
        Ok(out)
    }

    fn steps(&self) -> Result<Vec<u64>> {
        let mut steps = Vec::new();
        let entries = match std::fs::read_dir(&self.root) {
            Ok(e) => e,
            Err(_) => return Ok(steps),
        };
        for entry in entries {
            let entry = entry?;
            let name = entry.file_name();
            let name = name.to_string_lossy();
            if let Some(num) = name.strip_prefix("step_") {
                if let Ok(s) = num.parse::<u64>() {
                    steps.push(s);
                }
            }
        }
        steps.sort_unstable();
        Ok(steps)
    }

    fn prune_steps_before(&self, step: u64) -> Result<()> {
        for s in self.steps()? {
            if s < step {
                let dir = self.step_dir(s);
                std::fs::remove_dir_all(&dir)
                    .with_context(|| format!("pruning checkpoint {dir:?}"))?;
            }
        }
        Ok(())
    }

    fn prune_steps_after(&self, step: u64) -> Result<()> {
        for s in self.steps()? {
            if s > step {
                let dir = self.step_dir(s);
                std::fs::remove_dir_all(&dir)
                    .with_context(|| format!("pruning torn checkpoint {dir:?}"))?;
            }
        }
        Ok(())
    }

    fn bytes_written(&self) -> u64 {
        self.written.load(Ordering::Relaxed)
    }

    fn bytes_read(&self) -> u64 {
        self.read_bytes.load(Ordering::Relaxed)
    }

    fn records_written(&self) -> u64 {
        self.records.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicUsize;

    fn rec(step: u64, slot: u64, lo: u64, hi: u64, total: u64, fill: f32) -> StateRecord {
        let n = (hi - lo) as usize;
        StateRecord {
            step,
            slot,
            lo,
            hi,
            total,
            adam_t: step + 1,
            global_mbs: 4,
            tp: 1,
            tp_rank: 0,
            zero: 0,
            dp_rank: 0,
            params: vec![fill; n],
            m: vec![fill * 0.5; n],
            v: vec![fill * 0.25; n],
        }
    }

    fn tmp_dir(tag: &str) -> PathBuf {
        static NEXT: AtomicUsize = AtomicUsize::new(0);
        let id = NEXT.fetch_add(1, Ordering::Relaxed);
        let d = std::env::temp_dir()
            .join(format!("lga_store_test_{}_{}_{}", std::process::id(), tag, id));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    #[test]
    fn record_roundtrips_through_bytes() {
        let r = rec(3, 1, 10, 25, 40, 1.5);
        let b = r.to_bytes().unwrap();
        assert_eq!(b.len(), r.byte_len());
        assert_eq!(StateRecord::from_bytes(&b).unwrap(), r);
        // Truncation and corruption are rejected.
        assert!(StateRecord::from_bytes(&b[..b.len() - 1]).is_err());
        let mut bad = b.clone();
        bad[0] ^= 0xff;
        assert!(StateRecord::from_bytes(&bad).is_err());
    }

    #[test]
    fn assemble_stitches_shards_and_rejects_gaps() {
        let total = 10usize;
        let a = rec(0, 0, 0, 4, 10, 1.0);
        let b = rec(0, 0, 4, 10, 10, 2.0);
        let s = assemble(&[b.clone(), a.clone()], total).unwrap();
        assert_eq!(&s.params[..4], &[1.0; 4]);
        assert_eq!(&s.params[4..], &[2.0; 6]);
        assert_eq!(s.adam_t, 1);
        // A gap (missing middle shard) must fail.
        let c = rec(0, 0, 6, 10, 10, 2.0);
        assert!(assemble(&[a.clone(), c], total).is_err());
        assert!(assemble(&[], total).is_err());
        // Inconsistent Adam counters must fail.
        let mut b2 = b;
        b2.adam_t = 99;
        assert!(assemble(&[a, b2], total).is_err());
    }

    fn exercise_store(store: &dyn StateStore) {
        // Step 0: slot 0 in two shards + slot 1 whole.
        store.put(&rec(0, 0, 0, 3, 6, 1.0)).unwrap();
        store.put(&rec(0, 0, 3, 6, 6, 2.0)).unwrap();
        store.put(&rec(0, 1, 0, 4, 4, 3.0)).unwrap();
        // Step 1: torn — slot 0 only half covered.
        store.put(&rec(1, 0, 0, 3, 6, 9.0)).unwrap();
        store.put(&rec(1, 1, 0, 4, 4, 9.0)).unwrap();

        assert_eq!(store.steps().unwrap(), vec![0, 1]);
        let slots = [(0usize, 6usize), (1, 4)];
        // The torn step 1 is skipped; step 0 is the newest complete one.
        assert_eq!(latest_complete_step(store, &slots).unwrap(), Some(0));
        let s0 = assemble(&store.read(0, 0).unwrap(), 6).unwrap();
        assert_eq!(s0.params, vec![1.0, 1.0, 1.0, 2.0, 2.0, 2.0]);
        assert!(store.bytes_written() > 0);
        assert!(store.bytes_read() > 0);
        assert_eq!(store.records_written(), 5);

        // The resume flow for the torn step: reclaim everything past the
        // last complete step, then re-execute it — possibly under a
        // different sharding — into a now-empty step.
        store.prune_steps_after(0).unwrap();
        assert_eq!(store.steps().unwrap(), vec![0]);
        store.put(&rec(1, 0, 0, 6, 6, 5.0)).unwrap();
        store.put(&rec(1, 1, 0, 4, 4, 5.0)).unwrap();
        let recs = store.read(1, 0).unwrap();
        assert_eq!(recs.len(), 1, "the torn shards were reclaimed");
        assert_eq!(latest_complete_step(store, &slots).unwrap(), Some(1));
        assert_eq!(assemble(&recs, 6).unwrap().params, vec![5.0; 6]);

        // Re-writing a shard replaces, not duplicates.
        store.put(&rec(1, 0, 0, 6, 6, 7.0)).unwrap();
        assert_eq!(store.read(1, 0).unwrap().len(), 1);

        // Retention: pruning drops old steps wholesale.
        store.prune_steps_before(1).unwrap();
        assert_eq!(store.steps().unwrap(), vec![1]);
        assert!(store.read(0, 0).unwrap().is_empty());
        assert_eq!(latest_complete_step(store, &slots).unwrap(), Some(1));
    }

    #[test]
    fn memory_store_covers_the_contract() {
        exercise_store(&MemoryStore::new());
    }

    #[test]
    fn file_store_covers_the_contract_and_persists() {
        let dir = tmp_dir("contract");
        {
            let store = FileStore::new(&dir).unwrap();
            exercise_store(&store);
        }
        // A fresh handle (the "resumed process") sees the same state:
        // step 0 pruned, step 1 re-written as one full record per slot.
        let store = FileStore::new(&dir).unwrap();
        assert_eq!(store.steps().unwrap(), vec![1]);
        let s = assemble(&store.read(1, 0).unwrap(), 6).unwrap();
        assert_eq!(s.params, vec![7.0; 6]);
        // Leftover tmp files (a crash mid-write) are ignored by readers.
        std::fs::write(dir.join("step_00000001/.tmp_0_0_3"), b"garbage").unwrap();
        assert_eq!(store.read(1, 0).unwrap().len(), 1);
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn slot_ids_are_disjoint_from_layers() {
        assert_eq!(slot_embed(8), 8);
        assert_eq!(slot_pos(8), 9);
        assert_eq!(slot_head(8), 10);
        // tp rank blocks: rank 0's block is the legacy slot space; every
        // (tp_rank, layer) pair maps to a unique id past it.
        assert_eq!(slot_layer(8, 0, 3), 3);
        assert_eq!(slot_layer(8, 1, 0), 11);
        assert_eq!(slot_layer(8, 1, 7), 18);
        let mut seen = std::collections::HashSet::new();
        for tr in 0..4 {
            for l in 0..8 {
                assert!(seen.insert(slot_layer(8, tr, l)));
            }
        }
    }

    #[test]
    fn shard_provenance_roundtrips_and_is_validated() {
        let mut r = rec(2, 0, 0, 4, 8, 1.0);
        r.tp = 2;
        r.tp_rank = 1;
        let b = r.to_bytes().unwrap();
        let got = StateRecord::from_bytes(&b).unwrap();
        assert_eq!(got, r);
        assert_eq!((got.tp, got.tp_rank), (2, 1));
        // A rank outside its degree is rejected on both paths.
        r.tp_rank = 2;
        assert!(r.to_bytes().is_err());
        let mut bad = b.clone();
        bad[8 * 10..8 * 11].copy_from_slice(&5u64.to_le_bytes());
        assert!(StateRecord::from_bytes(&bad).is_err());
    }

    #[test]
    fn zero_provenance_roundtrips_and_is_validated() {
        let mut r = rec(1, 0, 4, 8, 16, 1.0);
        r.zero = 2;
        r.dp_rank = 3;
        let b = r.to_bytes().unwrap();
        let got = StateRecord::from_bytes(&b).unwrap();
        assert_eq!(got, r);
        assert_eq!((got.zero, got.dp_rank), (2, 3));
        // An out-of-range ZeRO stage is rejected on both paths.
        r.zero = 4;
        assert!(r.to_bytes().is_err());
        let mut bad = b;
        bad[8 * 11..8 * 12].copy_from_slice(&7u64.to_le_bytes());
        assert!(StateRecord::from_bytes(&bad).is_err());
    }
}
