//! Offloading and real-time checkpoints (paper §8.2, Figure 7).
//!
//! With layered gradient accumulation and a partitioned state, the state
//! offload intensity is ν = b·d_s (eq. 13) — high enough that the state
//! can stream not just to CPU memory but to SSDs, remote storage, or even
//! hard drives, turning every batch into a durable checkpoint at
//! negligible cost.
//!
//! This module holds both halves: the feasibility *analysis* (below) and
//! the executable [`store`] the trainer streams to when a schedule is
//! generated with `offload` — the real-time checkpoints that make crash
//! recovery and elastic resume (§8.1/§8.2) one-batch events.

pub mod store;

pub use store::{
    assemble, covers, latest_complete_step, slot_embed, slot_head, slot_layer, slot_pos,
    AssembledSlot, FileStore, MemoryStore, StateRecord, StateStore,
};

use crate::costmodel::{state_offload_intensity, TrainConfig};
use crate::hardware::{GpuSpec, LinkKind};
use crate::model::{TransformerShape, XModel};

/// Feasibility of offloading to one storage tier.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OffloadFeasibility {
    pub tier: LinkKind,
    /// Operation intensity of the offload stream, flops/B.
    pub nu_op: f64,
    /// The tier's intensity threshold.
    pub nu_net: f64,
    /// Relative overhead if attempted (0 = fully hidden).
    pub overhead: f64,
}

impl OffloadFeasibility {
    pub fn is_free(&self) -> bool {
        self.overhead < 1e-9
    }
}

/// Storage tiers considered by Figure 7.
pub const TIERS: [LinkKind; 4] =
    [LinkKind::CpuGpu, LinkKind::DiskNvme, LinkKind::Ethernet, LinkKind::DiskHdd];

/// Evaluate state-offload feasibility for every storage tier.
pub fn state_offload_feasibility(
    shape: &TransformerShape,
    cfg: &TrainConfig,
    gpu: &GpuSpec,
) -> Vec<OffloadFeasibility> {
    let mut c = *cfg;
    c.offload = true;
    let s = state_offload_intensity(shape, &c);
    TIERS
        .iter()
        .map(|&tier| {
            let nu_net = tier.intensity_threshold(gpu);
            OffloadFeasibility {
                tier,
                nu_op: s.nu,
                nu_net,
                overhead: (nu_net / s.nu - 1.0).max(0.0),
            }
        })
        .collect()
}

/// Activation-checkpoint offload intensity vs tiers (Figure 7's second
/// series): ν_c = (4 + 2 n_I)·d_m (eq. 14).
pub fn checkpoint_offload_feasibility(
    shape: &TransformerShape,
    gpu: &GpuSpec,
) -> Vec<OffloadFeasibility> {
    let nu = crate::costmodel::checkpoint_offload_intensity(shape);
    TIERS
        .iter()
        .map(|&tier| {
            let nu_net = tier.intensity_threshold(gpu);
            OffloadFeasibility { tier, nu_op: nu, nu_net, overhead: (nu_net / nu - 1.0).max(0.0) }
        })
        .collect()
}

/// §8.2 headline: the potential loss from a crash, in batches, when the
/// state streams to an external tier every batch (1 batch) vs classic
/// checkpointing every `interval` batches (interval/2 expected).
pub fn expected_loss_batches(realtime: bool, classic_interval: f64) -> f64 {
    if realtime {
        1.0
    } else {
        classic_interval / 2.0
    }
}

/// Figure 7 data point for one model scale: (params, state ν, ckpt ν).
pub fn figure7_point(x: usize, cfg: &TrainConfig) -> (f64, f64, f64) {
    let m = XModel::new(x);
    let shape = m.shape();
    let mut c = *cfg;
    c.offload = true;
    let s = state_offload_intensity(&shape, &c);
    let ck = crate::costmodel::checkpoint_offload_intensity(&shape);
    (m.params(), s.nu, ck)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::Strategy;
    use crate::hardware::ClusterSpec;

    fn improved_cfg(n_b: usize, n_mu: usize) -> TrainConfig {
        TrainConfig {
            strategy: Strategy::Improved,
            n_b,
            n_l: 5,
            n_a: 16,
            n_mu,
            b_mu: 1.0,
            offload: true,
            partition: true,
            zero: 0,
        }
    }

    #[test]
    fn partitioned_lga_state_can_stream_to_hdd_at_scale() {
        // §8.2: "for larger models even hard drives are fast enough".
        let m = XModel::x160();
        let cfg = improved_cfg(483, 5);
        let gpu = ClusterSpec::reference().gpu;
        let feas = state_offload_feasibility(&m.shape(), &cfg, &gpu);
        let hdd = feas.iter().find(|f| f.tier == LinkKind::DiskHdd).unwrap();
        // ν = b·d_s = 2415·2560 = 6.2M >= 2.91M (HDD threshold).
        assert!(hdd.is_free(), "overhead {}", hdd.overhead);
    }

    #[test]
    fn baseline_state_offload_cannot_even_use_ethernet() {
        // Without LGA+partition the per-micro-batch transfers push the
        // intensity down by n_b·n_μ — Figure 2's pathology.
        let m = XModel::x160();
        let mut cfg = improved_cfg(483, 5);
        cfg.strategy = Strategy::Baseline;
        cfg.partition = false;
        cfg.n_mu = 100;
        let gpu = ClusterSpec::reference().gpu;
        let feas = state_offload_feasibility(&m.shape(), &cfg, &gpu);
        let eth = feas.iter().find(|f| f.tier == LinkKind::Ethernet).unwrap();
        assert!(!eth.is_free());
    }

    #[test]
    fn checkpoint_offload_needs_more_bandwidth_than_state() {
        // Figure 7: the checkpoint series sits below the state series
        // (lower intensity = needs more bandwidth).
        let m = XModel::x160();
        let cfg = improved_cfg(483, 5);
        let gpu = ClusterSpec::reference().gpu;
        let s = state_offload_feasibility(&m.shape(), &cfg, &gpu)[0].nu_op;
        let c = checkpoint_offload_feasibility(&m.shape(), &gpu)[0].nu_op;
        assert!(c < s);
        // But still streams to NVMe at the trillion scale (§8.2).
        let nvme = checkpoint_offload_feasibility(&m.shape(), &gpu)
            .into_iter()
            .find(|f| f.tier == LinkKind::DiskNvme)
            .unwrap();
        assert!(nvme.is_free());
    }

    #[test]
    fn realtime_checkpoints_bound_the_loss_to_one_batch() {
        assert_eq!(expected_loss_batches(true, 1000.0), 1.0);
        assert_eq!(expected_loss_batches(false, 1000.0), 500.0);
    }

    #[test]
    fn figure7_intensity_grows_with_scale() {
        let cfg = improved_cfg(100, 5);
        let (_, s32, c32) = figure7_point(32, &cfg);
        let (_, s160, c160) = figure7_point(160, &cfg);
        assert!(s160 > s32);
        assert!(c160 > c32);
    }
}
