//! `repro` — the leader CLI.
//!
//! Subcommands:
//!   table 6.1|6.2|6.3|a.1|b.1        regenerate a paper table
//!   figure 4|5|6|7|8                 regenerate a paper figure (ASCII)
//!   schedule [--policy P] [...]      simulate + render a schedule Gantt
//!   train [--preset tiny|e2e] [...]  run real distributed training (in-process)
//!   launch --ranks N [...]           fork worker *processes* over TCP sockets
//!   worker --rank I --coord A [...]  one launched rank (spawned by `launch`)
//!   netbench [...]                   measure the socket wire, write calibration
//!   chaos [--probe] [...]            fault-injected elastic training
//!   plan [--x N] [--ethernet] [...]  plan the fastest configuration
//!   verify [--policy P] [--grid]     whole-world static schedule verification

use std::collections::HashMap;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use lga_mpp::analysis::{verify_program, MemoryModel};
use lga_mpp::collective::Topology;
use lga_mpp::costmodel::{KvCacheModel, MemoryBreakdown, ParallelismMenu, Strategy, TrainConfig};
use lga_mpp::hardware::{ClusterSpec, NetCalibration, SECS_PER_DAY, GIB};
use lga_mpp::model::{TransformerShape, XModel};
use lga_mpp::optim::LrSchedule;
use lga_mpp::planner::{plan_slo, verify_serving, SloSpec};
use lga_mpp::report;
use lga_mpp::runtime::DType;
use lga_mpp::schedule::{
    decode_waves, interleaved_1f1b, interleaved_applicable, layered_ga, lower, modular_pipeline,
    one_f_one_b, prefill_pipeline, standard_ga, Schedule, ScheduleSpec,
};
use lga_mpp::serve::{run_trace, ServeCosts, Trace};
use lga_mpp::sim::{render, render_requests, simulate_program, CostTable};
use lga_mpp::trainer::{launch, train, Policy, TrainerConfig};

/// Tiny flag parser: positionals + `--key value` / `--flag`.
struct Args {
    positional: Vec<String>,
    flags: HashMap<String, String>,
}

impl Args {
    fn parse(argv: &[String]) -> Self {
        let mut positional = Vec::new();
        let mut flags = HashMap::new();
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(key) = a.strip_prefix("--") {
                if i + 1 < argv.len() && !argv[i + 1].starts_with("--") {
                    flags.insert(key.to_string(), argv[i + 1].clone());
                    i += 2;
                } else {
                    flags.insert(key.to_string(), "true".to_string());
                    i += 1;
                }
            } else {
                positional.push(a.clone());
                i += 1;
            }
        }
        Args { positional, flags }
    }

    fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(String::as_str)
    }

    fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v}")),
        }
    }

    fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().with_context(|| format!("--{key} {v}")),
        }
    }

    fn has(&self, key: &str) -> bool {
        self.flags.contains_key(key)
    }
}

/// Bytes per MiB, for report formatting.
const MIB: f64 = (1u64 << 20) as f64;

fn cluster_from(args: &Args) -> Result<ClusterSpec> {
    let base = if args.has("ethernet") {
        ClusterSpec::ethernet()
    } else if args.has("unlimited-node") {
        ClusterSpec::unlimited_node()
    } else {
        ClusterSpec::reference()
    };
    // `--calibration BENCH_net_calibration.json` (written by `repro
    // netbench`) substitutes measured wire figures for the spec sheet.
    match args.get("calibration") {
        Some(path) => Ok(base.with_calibration(NetCalibration::load(path)?)),
        None => Ok(base),
    }
}

fn main() -> Result<()> {
    let argv: Vec<String> = std::env::args().skip(1).collect();
    if argv.is_empty() || argv[0] == "--help" || argv[0] == "help" {
        print!("{}", HELP);
        return Ok(());
    }
    let cmd = argv[0].clone();
    let args = Args::parse(&argv[1..]);
    match cmd.as_str() {
        "table" => cmd_table(&args),
        "figure" => cmd_figure(&args),
        "schedule" => cmd_schedule(&args),
        "train" => cmd_train(&args),
        "launch" => cmd_launch(&args),
        "worker" => cmd_worker(&args),
        "netbench" => cmd_netbench(&args),
        "chaos" => cmd_chaos(&args),
        "plan" => cmd_plan(&args),
        "serve" => cmd_serve(&args),
        "verify" => cmd_verify(&args),
        other => bail!("unknown subcommand '{other}' (see `repro help`)"),
    }
}

const HELP: &str = "\
repro — 'Layered gradient accumulation and modular pipeline parallelism'
usage:
  repro table <6.1|6.2|6.3|a.1|b.1>   [--x N] [--ethernet|--unlimited-node]
  repro table sched                   [--x N] [--layers N] [--stages N] [--mb N] [--tp N]
  repro figure <4|5|6|7|8>            [--max-x N]
  repro schedule [--policy baseline|improved|1f1b|interleaved] [--layers N]
                 [--stages N] [--mb N] [--tp N] [--chunks V] [--partition]
                 [--zero 0-3] [--offload] [--x N] [--width N]
  repro train [--preset tiny|e2e] [--dp N] [--pp N] [--tp N] [--mb N] [--steps N]
              [--policy baseline|improved|1f1b] [--partition] [--zero 0-3] [--lr F]
              [--tp-emulate] [--offload] [--store DIR] [--resume] [--artifacts DIR]
              (--zero shards optimizer state 1/dp ZeRO-style: stage 1 shards
              Adam moments, 2 adds reduce-scattered gradients, 3 gathers
              params before use; losses stay bit-identical to --zero 0)
  repro launch --ranks N [--tp T] [--dp D] [train flags...] [--probe] [--verify]
               [--coord-bind HOST:PORT] [--timeout-secs S] [--auth-token TOK]
               (pp = ranks / (tp*dp); forks one `repro worker` process per rank
               over loopback TCP; --probe runs the artifact-free connectivity
               exercise; --verify re-runs the same spec in-process and asserts
               bit-identical losses; --coord-bind runs only the coordinator,
               for multi-host jobs whose workers are started by hand with
               REPRO_HOSTMAP set; a rank that stalls past --timeout-secs
               (env REPRO_LAUNCH_TIMEOUT) is named with its last completed
               step; with --store, dead workers restart from the latest
               complete checkpoint)
  repro worker --rank I --coord HOST:PORT [--generation G] [train flags...] [--probe]
  repro netbench [--payload-mib N] [--iters N] [--frames N] [--ethernet]
               (measures socket rtt + bandwidth, writes BENCH_net_calibration.json;
               feed it back anywhere with --calibration FILE)
  repro chaos --store DIR [--seed N] [--kills N] [train flags...] | --probe [--steps N]
               (fault-injected elastic training: a seeded schedule of rank
               kills, torn checkpoint stores and dp/tp topology changes on
               revival, checked against an uninterrupted reference run;
               --probe instead SIGKILLs a real worker process over loopback
               and asserts the supervisor restarts it — artifact-free)
  repro plan [--x N] [--strategy S] [--menu M] [--ethernet|--unlimited-node]
             [--budget-days D] [--no-sim] [--tp N] [--zero 0-3] [--calibration FILE]
             [--mtbf HOURS] [--max-lost-work PCT]   (reliability-constrained:
             the fastest plan whose expected failure-rollback lost work
             stays under PCT% of wall clock at the given per-device MTBF)
  repro serve [--rate R] [--requests N] [--prompt P] [--decode D] [--seed S]
              [--stages N] [--tp N] [--max-batch B] [--x N] [--trace FILE]
              [--timeline] [--width N] [--probe] [--ethernet|--unlimited-node]
               (continuous-batching inference over the compiled forward-only
               schedules: replays a seeded Poisson stream — or --trace FILE
               with `arrival prompt decode` lines — through the KV-gated
               batcher and reports p50/p99 TTFT, per-token latency and
               tokens/sec; every deployment's prefill/decode programs pass
               whole-world verification first; --timeline renders
               request-labelled prefill and decode Gantt charts; --probe is
               the artifact-free CI smoke)
  repro serve plan --slo-p99-ms MS [--rate R] [--requests N] [--prompt P]
              [--decode D] [--seed S] [--x N] [--ethernet|--unlimited-node]
               (SLO planner: searches stages x tp x max-batch for the
               highest-throughput deployment whose p99 time-to-first-token
               meets the SLO, or reports the binding constraint)
  repro verify [--policy baseline|improved|1f1b|interleaved|serve|all]
               [--spec LAYERS:STAGES:MB | --layers N --stages N --mb N]
               [--dp N] [--tp N] [--partition] [--zero 0-3] [--offload]
               [--chunks V] [--prompt P] [--decode D]
               [--x N] [--grid] [--ethernet|--unlimited-node]
               (whole-world static verification: composes the lowered
               program over every rank of the {stages, dp, tp} grid and
               checks p2p send/recv matching, collective congruence on
               every dp/tp ring, cross-rank deadlock freedom and the
               static peak-memory bound; --grid sweeps all policies
               across stages x dp x tp x {plain, partition, offload,
               zero 1-3}, plus the forward-only serving worlds —
               prefill + decode at dp = 1 under the KV-aware memory
               bound)
";

fn cmd_table(args: &Args) -> Result<()> {
    let which = args.positional.first().map(String::as_str).unwrap_or("6.1");
    let x = args.get_usize("x", 160)?;
    let model = XModel::new(x);
    let cluster = cluster_from(args)?;
    let out = match which {
        "6.1" => report::table61(&model, &cluster),
        "6.2" => report::table62(&model, &cluster),
        "6.3" => report::table63(&model, &cluster),
        "a.1" | "A.1" => report::table_a1(&cluster.gpu),
        "b.1" | "B.1" => report::table_b1(),
        // Measured (simulated) schedule-policy comparison, incl. the
        // Megatron-LM interleaved baseline. Uses --x for the layer
        // costs like the other tables (default X_32: the comparison
        // shapes are pipeline-sized, not the full X_160).
        "sched" => report::schedule_comparison(
            args.get_usize("x", 32)?,
            args.get_usize("layers", 16)?,
            args.get_usize("stages", 4)?,
            args.get_usize("mb", 8)?,
            args.get_usize("tp", 1)?,
            &cluster,
        ),
        other => bail!("unknown table {other}"),
    };
    println!("{out}");
    Ok(())
}

fn cmd_figure(args: &Args) -> Result<()> {
    let which = args.positional.first().map(String::as_str).unwrap_or("4");
    let max_x = args.get_usize("max-x", 320)?;
    match which {
        "4" | "5" | "8" => {
            let (cluster, name) = match which {
                "4" => (ClusterSpec::reference(), "Figure 4 (node <= 16, InfiniBand)"),
                "5" => (ClusterSpec::unlimited_node(), "Figure 5 (no node limit)"),
                _ => (ClusterSpec::ethernet(), "Figure 8 (25 Gb/s Ethernet)"),
            };
            let fig = report::scaling_figure(&cluster, name, max_x);
            println!("{name}");
            let series: Vec<(&str, &report::Series)> =
                fig.time_days.iter().map(|(s, v)| (s.name(), v)).collect();
            println!("{}", report::ascii_plot(&series, 72, 20, "training time, days"));
            let series: Vec<(&str, &report::Series)> =
                fig.memory_gib.iter().map(|(s, v)| (s.name(), v)).collect();
            println!("{}", report::ascii_plot(&series, 72, 20, "GPU-resident memory, GiB"));
            for (s, v) in &fig.time_days {
                if let Some((x, t)) = v.last() {
                    println!("  {} @ X_{x}: {:.1} days", s.name(), t);
                }
            }
        }
        "6" => {
            let s = report::figure6(&ClusterSpec::reference(), max_x);
            println!("Figure 6: memory/compute ratio for one-month training");
            println!("{}", report::ascii_plot(&[("ratio", &s)], 72, 18, "bytes per flop/s"));
        }
        "7" => {
            let pts = report::figure7(&ClusterSpec::reference(), max_x);
            println!("Figure 7: offload arithmetic intensity (flops/B) vs scale");
            let state: report::Series = pts.iter().map(|&(x, s, _)| (x, s)).collect();
            let ckpt: report::Series = pts.iter().map(|&(x, _, c)| (x, c)).collect();
            println!(
                "{}",
                report::ascii_plot(&[("state", &state), ("checkpoints", &ckpt)], 72, 18, "flops/B")
            );
        }
        other => bail!("unknown figure {other}"),
    }
    Ok(())
}

fn cmd_schedule(args: &Args) -> Result<()> {
    let policy = args.get("policy").unwrap_or("improved");
    let d_l = args.get_usize("layers", 16)?;
    let n_l = args.get_usize("stages", 4)?;
    let n_mu = args.get_usize("mb", 8)?;
    let x = args.get_usize("x", 32)?;
    let width = args.get_usize("width", 110)?;
    let tp = args.get_usize("tp", 1)?;
    let zero = args.get_usize("zero", 0)? as u8;
    let spec = ScheduleSpec {
        d_l,
        n_l,
        n_mu,
        tp,
        partition: args.has("partition"),
        offload: args.has("offload"),
        data_parallel: true,
        zero,
    };
    spec.validate().map_err(|e| anyhow::anyhow!(e))?;
    let s = match policy {
        "baseline" => standard_ga(&spec),
        "improved" => {
            if n_l == 1 {
                lga_mpp::schedule::layered_ga(&spec)
            } else {
                modular_pipeline(&spec)
            }
        }
        "1f1b" => one_f_one_b(&spec),
        "interleaved" => {
            let chunks = args.get_usize("chunks", 2)?;
            if !lga_mpp::schedule::interleaved_applicable(&spec, chunks) {
                bail!(
                    "interleaved needs --layers divisible by --stages * --chunks \
                     ({d_l} vs {}) and --mb divisible by --stages ({n_mu} vs {n_l})",
                    n_l * chunks
                );
            }
            interleaved_1f1b(&spec, chunks)
        }
        other => bail!("unknown policy {other}"),
    };
    let cfg = TrainConfig {
        strategy: if policy == "improved" { Strategy::Improved } else { Strategy::Baseline },
        n_b: 8,
        n_l,
        n_a: tp,
        n_mu,
        b_mu: 1.0,
        offload: args.has("offload"),
        partition: args.has("partition"),
        zero,
    };
    let costs = CostTable::new(&XModel::new(x).shape(), &cfg, &ClusterSpec::reference());
    let program = lower(&s).map_err(|e| anyhow::anyhow!("invalid schedule: {e:?}"))?;
    let r = simulate_program(&program, &costs);
    println!(
        "schedule: {} (d_l={d_l}, n_l={n_l}, n_mu={n_mu}) — program: {} ops, {} edges",
        program.name,
        program.len(),
        program.n_edges()
    );
    println!(
        "makespan {:.3} ms | compute efficiency {:.3} | measured bubble {:.3}",
        r.makespan * 1e3,
        r.compute_efficiency(),
        r.bubble_fraction()
    );
    println!("{}", render(&r, width));
    Ok(())
}

/// Build a [`TrainerConfig`] from the flag set shared by `train`,
/// `launch` and `worker` — one parser so a forwarded flag list means
/// the same run in every process.
fn trainer_config_from(args: &Args) -> Result<TrainerConfig> {
    let preset = args.get("preset").unwrap_or("tiny").to_string();
    let mut cfg = TrainerConfig::quick(&preset);
    if let Some(dir) = args.get("artifacts") {
        cfg.artifacts_root = dir.into();
    }
    cfg.n_b = args.get_usize("dp", 1)?;
    cfg.n_l = args.get_usize("pp", 1)?;
    cfg.tp = args.get_usize("tp", 1)?;
    cfg.force_tp_emulation = args.has("tp-emulate");
    cfg.n_mu = args.get_usize("mb", 2)?;
    cfg.steps = args.get_usize("steps", 20)?;
    cfg.partition = args.has("partition");
    cfg.zero = args.get_usize("zero", 0)? as u8;
    cfg.offload = args.has("offload");
    cfg.resume = args.has("resume");
    if let Some(dir) = args.get("store") {
        cfg.store_dir = Some(dir.into());
    }
    if cfg.resume && cfg.store_dir.is_none() {
        bail!("--resume needs --store DIR (a durable checkpoint store to resume from)");
    }
    cfg.policy = match args.get("policy").unwrap_or("improved") {
        "baseline" => Policy::Baseline,
        "improved" => Policy::Improved,
        "1f1b" => Policy::OneFOneB,
        other => bail!("unknown policy {other}"),
    };
    let lr: f32 = args.get("lr").unwrap_or("3e-3").parse()?;
    cfg.lr = LrSchedule {
        base_lr: lr,
        warmup_steps: 10,
        total_steps: cfg.steps as u64,
        min_ratio: 0.1,
    };
    Ok(cfg)
}

fn cmd_train(args: &Args) -> Result<()> {
    let cfg = trainer_config_from(args)?;
    let preset = &cfg.preset;
    println!(
        "training preset={preset} dp={} pp={} tp={} mb={} policy={} partition={} zero={} \
         offload={} steps={}",
        cfg.n_b,
        cfg.n_l,
        cfg.tp,
        cfg.n_mu,
        cfg.policy.name(),
        cfg.partition,
        cfg.zero,
        cfg.offload,
        cfg.steps
    );
    let r = train(&cfg)?;
    if cfg.tp > 1 {
        println!(
            "tensor parallelism: {} over {} ranks/stage",
            if r.tp_sharded {
                "sharded column/row-parallel compute"
            } else {
                "replicated-compute emulation"
            },
            cfg.tp
        );
    }
    if r.start_step > 0 {
        println!("resumed from real-time checkpoint: continuing at step {}", r.start_step);
    }
    for (i, l) in r.losses.iter().enumerate() {
        let step = r.start_step + i;
        if i % 10 == 0 || i + 1 == r.losses.len() {
            println!("step {step:>5}  loss {l:.4}");
        }
    }
    println!(
        "done: {:.1}s wall | {} PJRT calls ({:.1}s, {:.0}% of wall) | wire elems: \
         {:.1} M dp / {:.1} M pipe / {:.1} M tp",
        r.wall_secs,
        r.execute_calls,
        r.execute_secs,
        100.0 * r.execute_secs / r.wall_secs.max(1e-9),
        r.collective_elems_sent as f64 / 1e6,
        r.pipeline_elems_sent as f64 / 1e6,
        r.tp_elems_sent as f64 / 1e6
    );
    println!(
        "bytes on wire: {:.2} MiB dp / {:.2} MiB pipe / {:.2} MiB tp \
         (elems x f32 width; compare `repro table sched` wire@f32)",
        r.collective_bytes_sent as f64 / MIB,
        r.pipeline_bytes_sent as f64 / MIB,
        r.tp_bytes_sent as f64 / MIB,
    );
    println!(
        "resident state per rank (measured): {:.2} MiB layer params+optimizer, \
         {:.2} MiB total",
        r.max_layer_state_bytes as f64 / (1u64 << 20) as f64,
        r.max_state_bytes as f64 / (1u64 << 20) as f64,
    );
    if cfg.offload {
        println!(
            "{}",
            report::checkpoint_summary(
                r.losses.len(),
                r.checkpoint_records,
                r.checkpoint_bytes_written,
                1000.0,
            )
        );
    }
    Ok(())
}

/// `repro launch`: fork one worker process per rank, rendezvous them
/// over TCP, and merge the per-rank reports.
fn cmd_launch(args: &Args) -> Result<()> {
    let ranks: usize = args
        .get("ranks")
        .context("launch needs --ranks N (total worker processes)")?
        .parse()
        .context("--ranks")?;
    let mut cfg = trainer_config_from(args)?;
    let tp = args.get_usize("tp", 1)?;
    let dp = args.get_usize("dp", 1)?;
    anyhow::ensure!(
        ranks > 0 && tp > 0 && dp > 0 && ranks % (tp * dp) == 0,
        "--ranks {ranks} must be a positive multiple of tp*dp = {}",
        tp * dp
    );
    // The pipeline depth is whatever is left once tp and dp are assigned.
    cfg.n_b = dp;
    cfg.tp = tp;
    cfg.n_l = ranks / (tp * dp);
    let probe = args.has("probe");

    // Every worker re-parses this exact flag list through
    // `trainer_config_from`, so the job config cannot skew per process.
    let mut flags: Vec<String> = [
        ("--preset", cfg.preset.clone()),
        ("--dp", cfg.n_b.to_string()),
        ("--pp", cfg.n_l.to_string()),
        ("--tp", cfg.tp.to_string()),
        ("--mb", cfg.n_mu.to_string()),
        ("--steps", cfg.steps.to_string()),
        ("--policy", cfg.policy.name().to_string()),
        ("--zero", cfg.zero.to_string()),
        ("--lr", args.get("lr").unwrap_or("3e-3").to_string()),
        ("--artifacts", cfg.artifacts_root.display().to_string()),
    ]
    .into_iter()
    .flat_map(|(k, v)| [k.to_string(), v])
    .collect();
    if let Some(dir) = &cfg.store_dir {
        flags.push("--store".to_string());
        flags.push(dir.display().to_string());
    }
    for (flag, on) in [
        ("--partition", cfg.partition),
        ("--tp-emulate", cfg.force_tp_emulation),
        ("--offload", cfg.offload),
        ("--resume", cfg.resume),
        ("--probe", probe),
    ] {
        if on {
            flags.push(flag.to_string());
        }
    }

    // Supervision knobs: stall timeout (also settable via the
    // REPRO_LAUNCH_TIMEOUT env default) and the rendezvous auth token.
    let mut opts = launch::LaunchOptions::default();
    if let Some(secs) = args.get("timeout-secs") {
        opts.timeout = Duration::from_secs(secs.parse().context("--timeout-secs")?);
    }
    if let Some(tok) = args.get("auth-token") {
        opts.auth_token = Some(tok.to_string());
    }

    println!(
        "launching {ranks} ranks: pp={} dp={} tp={} steps={} {}",
        cfg.n_l,
        dp,
        tp,
        cfg.steps,
        if probe { "(connectivity probe)" } else { "(training)" }
    );
    let lr = if let Some(bind) = args.get("coord-bind") {
        launch::coordinate_external(&cfg, bind, opts.timeout)?
    } else {
        launch::launch_local_opts(&cfg, &flags, &opts)?
    };
    if lr.restarts > 0 {
        println!(
            "supervisor: {} worker restart(s) recovered from the checkpoint store",
            lr.restarts
        );
    }
    let r = &lr.report;
    for (i, l) in r.losses.iter().enumerate() {
        if i % 10 == 0 || i + 1 == r.losses.len() {
            println!("step {i:>5}  loss {l:.4}");
        }
    }
    println!(
        "wire totals (all ranks): {:.1} M dp / {:.1} M pipe / {:.1} M tp elems \
         = {:.2} / {:.2} / {:.2} MiB on the wire",
        r.collective_elems_sent as f64 / 1e6,
        r.pipeline_elems_sent as f64 / 1e6,
        r.tp_elems_sent as f64 / 1e6,
        r.collective_bytes_sent as f64 / MIB,
        r.pipeline_bytes_sent as f64 / MIB,
        r.tp_bytes_sent as f64 / MIB,
    );
    println!(
        "done: {:.1}s wall | schedule {} | {} PJRT calls ({:.1}s summed) | \
         max resident state {:.2} MiB",
        r.wall_secs,
        r.schedule_name,
        r.execute_calls,
        r.execute_secs,
        r.max_state_bytes as f64 / MIB,
    );
    for (rank, s) in lr.per_rank.iter().enumerate() {
        println!(
            "  rank {rank}: {:.1}s wall, {} calls, {:.1} M elems sent",
            s.wall_secs,
            s.execute_calls,
            (s.collective_elems_sent + s.pipeline_elems_sent + s.tp_elems_sent) as f64 / 1e6,
        );
    }

    if args.has("verify") {
        anyhow::ensure!(!probe, "--verify needs a real training run, not --probe");
        println!("verify: re-running the same spec in-process over mpsc...");
        let solo = train(&cfg)?;
        anyhow::ensure!(
            solo.losses.len() == r.losses.len(),
            "verify: step count mismatch (mpsc {} vs sockets {})",
            solo.losses.len(),
            r.losses.len()
        );
        for (i, (a, b)) in solo.losses.iter().zip(&r.losses).enumerate() {
            anyhow::ensure!(
                a.to_bits() == b.to_bits(),
                "verify: loss diverged at step {i}: mpsc {a:?} vs sockets {b:?}"
            );
        }
        println!(
            "verify: socket losses bit-identical to the in-process mpsc run ({} steps)",
            r.losses.len()
        );
    }
    Ok(())
}

/// `repro worker`: one launched rank. Spawned by `launch`; can also be
/// started by hand on another host with `REPRO_HOSTMAP` set.
fn cmd_worker(args: &Args) -> Result<()> {
    let cfg = trainer_config_from(args)?;
    let rank: usize = args
        .get("rank")
        .context("worker needs --rank I")?
        .parse()
        .context("--rank")?;
    let coord = args.get("coord").context("worker needs --coord HOST:PORT")?;
    // Bumped by the supervisor on every restart round so stale peers
    // from the previous incarnation are rejected at the handshake.
    let generation = args.get_usize("generation", 0)? as u64;
    let probe = args.has("probe").then_some(cfg.steps);
    launch::worker_main(&cfg, rank, coord, generation, probe)
}

/// `repro chaos`: fault-injected elastic training. A seeded schedule of
/// rank kills (with dp/tp topology changes on revival) and torn
/// checkpoint stores runs against an uninterrupted reference, and the
/// final loss trajectories must agree. `--probe` instead SIGKILLs a
/// real worker process over loopback sockets and asserts the
/// supervisor restarts it — no artifacts needed.
fn cmd_chaos(args: &Args) -> Result<()> {
    if args.has("probe") {
        let steps = args.get_usize("steps", 6)?;
        println!("chaos probe: {steps} paced steps, SIGKILL rank 1 mid-run, expect a restart");
        let lr = lga_mpp::trainer::chaos_probe(steps)?;
        println!(
            "chaos probe survived: {} restart(s), {} steps merged, {:.1}s wall",
            lr.restarts,
            lr.report.losses.len(),
            lr.report.wall_secs
        );
        return Ok(());
    }
    let mut cfg = trainer_config_from(args)?;
    if cfg.store_dir.is_none() {
        bail!("chaos needs --store DIR (the durable checkpoints are the recovery mechanism)");
    }
    // Recovery replays from the streamed checkpoint tier, so the run
    // must produce one.
    cfg.offload = true;
    let seed: u64 = args.get("seed").unwrap_or("42").parse().context("--seed")?;
    let kills = args.get_usize("kills", 2)?;
    let plan = lga_mpp::trainer::seeded_plan(seed, cfg.steps, cfg.n_b, cfg.n_mu, kills);
    println!("chaos: seed {seed} -> {} fault events over {} steps", plan.events.len(), cfg.steps);
    let r = lga_mpp::trainer::run_chaos(&cfg, &plan)?;
    println!(
        "chaos: {} kill(s) ({} with a topology change, tp re-shard: {}), {} torn store(s)",
        r.kills, r.topology_changes, r.tp_resharded, r.torn_stores
    );
    println!(
        "loss trajectory: max |chaos - reference| = {:.3e} over {} steps (tolerance {:.1e})",
        r.max_abs_diff,
        r.reference.len(),
        r.tolerance()
    );
    anyhow::ensure!(
        r.max_abs_diff < r.tolerance(),
        "chaos run diverged from the uninterrupted reference: {} >= {}",
        r.max_abs_diff,
        r.tolerance()
    );
    println!("chaos run matches the uninterrupted reference");
    Ok(())
}

/// `repro netbench`: measure the socket transport's round-trip latency
/// and sustained framed bandwidth over loopback, compare against the
/// quoted link figures, and write `BENCH_net_calibration.json` for
/// `--calibration` consumption by the simulator and planner.
fn cmd_netbench(args: &Args) -> Result<()> {
    let payload_mib = args.get_usize("payload-mib", 4)?;
    let iters = args.get_usize("iters", 512)?;
    let frames = args.get_usize("frames", 64)?;
    let payload_elems = (payload_mib << 20) / 4;
    let mut bench = report::BenchJson::new("net_calibration");
    println!(
        "netbench: loopback socket transport — {iters} ping-pongs, \
         {frames} x {payload_mib} MiB streamed frames"
    );
    let probe = lga_mpp::collective::netbench(payload_elems.max(1), iters, frames)
        .context("netbench probe")?;
    println!("  rtt (median):      {:.1} us", probe.rtt_secs * 1e6);
    println!("  stream bandwidth:  {:.2} GiB/s", probe.bandwidth_bytes_per_s / GIB);
    println!(
        "  ring all-reduce:   {:.2} GiB/s per rank",
        probe.ring_allreduce_bytes_per_s / GIB
    );
    let quoted = cluster_from(args)?;
    let link = quoted.inter_node_link();
    println!(
        "  quoted {}: {:.2} GiB/s — measured/quoted = {:.2}x",
        link.name(),
        link.bandwidth() / GIB,
        probe.bandwidth_bytes_per_s / link.bandwidth()
    );
    let calibrated = quoted.with_calibration(NetCalibration {
        bandwidth_bytes_per_s: probe.bandwidth_bytes_per_s,
        rtt_secs: probe.rtt_secs,
    });
    println!(
        "  intensity threshold: {:.3e} flops/B quoted -> {:.3e} flops/B calibrated",
        quoted.inter_node_threshold(),
        calibrated.inter_node_threshold()
    );
    bench.push("rtt_secs", probe.rtt_secs);
    bench.push("bandwidth_bytes_per_s", probe.bandwidth_bytes_per_s);
    bench.push("ring_allreduce_bytes_per_s", probe.ring_allreduce_bytes_per_s);
    bench.push("payload_bytes", probe.payload_bytes as f64);
    bench.finish();
    println!(
        "feed the measured wire back with `repro plan --calibration \
         BENCH_net_calibration.json` (also accepted by `table`/`netbench`)"
    );
    Ok(())
}

fn cmd_plan(args: &Args) -> Result<()> {
    let x = args.get_usize("x", 160)?;
    let model = XModel::new(x);
    let cluster = cluster_from(args)?;
    let strategy = match args.get("strategy").unwrap_or("improved") {
        "baseline" => Strategy::Baseline,
        "partitioned" => Strategy::Partitioned,
        _ => Strategy::Improved,
    };
    let menu = match args.get("menu").unwrap_or("3d") {
        "data" => ParallelismMenu::DATA,
        "data+pipe" => ParallelismMenu::DATA_PIPE,
        "data+tensor" => ParallelismMenu::DATA_TENSOR,
        _ => ParallelismMenu::THREE_D,
    };
    if let Some(days) = args.get("budget-days") {
        let days: f64 = days.parse()?;
        match lga_mpp::planner::min_gpu_plan(&model, &cluster, strategy, menu, days * SECS_PER_DAY)
        {
            Some(cp) => println!("{}", report::explain(&model, &cluster, &cp.plan.cfg)),
            None => println!("no feasible plan within {days} days"),
        }
        return Ok(());
    }
    // --mtbf HOURS [--max-lost-work PCT]: reliability-constrained
    // planning — the fastest plan whose expected failure-rollback lost
    // work stays within the budget (Figure 2's restore-ratio argument
    // as a planner constraint).
    if let Some(mtbf) = args.get("mtbf") {
        let mtbf_hours: f64 = mtbf.parse().context("--mtbf")?;
        anyhow::ensure!(mtbf_hours > 0.0, "--mtbf must be positive (hours per device)");
        let pct: f64 =
            args.get("max-lost-work").unwrap_or("1").parse().context("--max-lost-work")?;
        anyhow::ensure!(pct > 0.0, "--max-lost-work must be positive (percent)");
        let rel = lga_mpp::planner::ReliabilityParams { mtbf_hours, max_lost_work: pct / 100.0 };
        match lga_mpp::planner::plan_with_reliability(&model, &cluster, strategy, menu, &rel) {
            Some(rp) => {
                println!("{}", report::explain(&model, &cluster, &rp.sim.plan.cfg));
                println!(
                    "reliability: n_gpu={} @ mtbf {mtbf_hours}h/device -> expected lost work \
                     <= {:.3}% of wall clock (budget {pct}%)",
                    rp.sim.plan.cfg.n_gpu(),
                    100.0 * rp.bound.fraction,
                );
                println!(
                    "  step {:.3}s | restore per failure {:.3}s | checkpoint interval {} \
                     step(s){}",
                    rp.bound.step_secs,
                    rp.bound.restore_secs,
                    rp.bound.ckpt_interval,
                    if rp.sim.plan.cfg.offload { " (streamed via offload)" } else { "" },
                );
            }
            None => println!("no feasible plan within a {pct}% expected-lost-work budget"),
        }
        return Ok(());
    }
    // --tp N pins the tensor-parallel degree (the new planner axis);
    // without it the search ranks the whole n_a grid.
    let tp = match args.get("tp") {
        Some(v) => Some(v.parse::<usize>().with_context(|| format!("--tp {v}"))?),
        None => None,
    };
    // --zero Z re-prices the whole candidate grid at one ZeRO stage
    // (dropping the partitioned candidates — the shardings are mutually
    // exclusive), so memory-bound configs a full-state plan cannot fit
    // become feasible.
    let zero = match args.get("zero") {
        Some(v) => {
            let z: u8 = v.parse().with_context(|| format!("--zero {v}"))?;
            anyhow::ensure!(z <= 3, "--zero {z} out of range (ZeRO stages are 0-3)");
            anyhow::ensure!(tp.is_none(), "--zero and --tp pin different sweeps; pick one");
            Some(z)
        }
        None => None,
    };
    let searched = match zero {
        Some(_) => lga_mpp::planner::search_fastest_zero(&model, &cluster, strategy, menu, zero),
        None => lga_mpp::planner::search_fastest_tp(&model, &cluster, strategy, menu, tp),
    };
    match searched {
        Some(p) => {
            println!("{}", report::explain(&model, &cluster, &p.cfg));
            if !args.has("no-sim") {
                // Simulate-in-the-loop, on by default now that the planner
                // is fast: re-rank the searched plan against the §5
                // closed-form plan by actually executing their schedules
                // on the discrete-event engine (lowering served from the
                // global cache).
                let mut cands = vec![p];
                cands.extend(lga_mpp::planner::fastest_plan(&model, &cluster, strategy, menu));
                if let Some(best) = lga_mpp::planner::rank_by_simulation(&model, &cluster, &cands)
                {
                    println!(
                        "simulated winner: {:?}\n  makespan {:.3} ms per batch-instance | \
                         sim efficiency {:.3} | {:.3e} s/sequence",
                        best.plan.cfg,
                        best.makespan * 1e3,
                        best.sim_efficiency,
                        best.secs_per_sequence,
                    );
                }
            }
        }
        None => println!("no feasible plan"),
    }
    Ok(())
}

/// `repro serve` — continuous-batching inference over the compiled
/// forward-only schedules: replay a request trace (seeded Poisson or
/// `--trace FILE`) through the KV-gated batcher and report latency and
/// throughput percentiles. `repro serve plan` instead searches
/// {stages, tp, max batch} for the highest throughput meeting a p99
/// TTFT SLO; `--probe` is the artifact-free CI smoke.
fn cmd_serve(args: &Args) -> Result<()> {
    if args.positional.first().map(String::as_str) == Some("plan") {
        return cmd_serve_plan(args);
    }
    if args.has("probe") {
        return cmd_serve_probe();
    }
    let cluster = cluster_from(args)?;
    let shape = XModel::new(args.get_usize("x", 16)?).shape();
    let stages = args.get_usize("stages", 2)?;
    let tp = args.get_usize("tp", 1)?;
    let max_batch = args.get_usize("max-batch", 8)?;
    let prompt = args.get_usize("prompt", 128)?;
    let decode = args.get_usize("decode", 32)?;
    let rate = args.get_f64("rate", 10.0)?;
    let requests = args.get_usize("requests", 64)?;
    let seed = args.get_usize("seed", 0)? as u64;
    anyhow::ensure!(
        shape.d_l % stages == 0,
        "model depth {} not divisible by --stages {stages}",
        shape.d_l
    );
    let trace = match args.get("trace") {
        Some(path) => {
            let text =
                std::fs::read_to_string(path).with_context(|| format!("--trace {path}"))?;
            Trace::parse(&text).map_err(|e| anyhow::anyhow!("--trace {path}: {e}"))?
        }
        None => Trace::poisson(seed, rate, requests, prompt, decode),
    };

    // Acceptance gate: before replaying anything, the deployment's
    // prefill and decode programs must pass whole-world verification
    // at the cap the batcher will actually run (KV-aware memory bound
    // at the trace's worst-case context).
    let kv = KvCacheModel::new(&shape, stages, tp, DType::F32, cluster.gpu.memory_bytes);
    let cap = max_batch.min(kv.admission_limit(trace.max_context()));
    if cap > 0 {
        let max_prompt = trace.requests.iter().map(|r| r.prompt).max().unwrap_or(1);
        let max_decode = trace.requests.iter().map(|r| r.decode).max().unwrap_or(1);
        verify_serving(&shape, &cluster, stages, tp, cap, max_prompt, max_decode)
            .map_err(|e| anyhow::anyhow!(e))?;
    }

    let report = run_trace(&shape, &cluster, stages, tp, max_batch, &trace)
        .map_err(|e| anyhow::anyhow!(e))?;
    println!(
        "serve: {} requests in {:.2}s simulated wall clock (verified prefill + decode worlds)",
        report.completed, report.makespan
    );
    println!(
        "  deployment   stages {} x tp {}, batch cap {} ({})",
        report.stages, report.tp, report.cap, report.cap_bound
    );
    println!(
        "  ttft         p50 {:8.1} ms   p99 {:8.1} ms",
        report.ttft_p50 * 1e3,
        report.ttft_p99 * 1e3
    );
    println!(
        "  per-token    p50 {:8.1} ms   p99 {:8.1} ms",
        report.token_p50 * 1e3,
        report.token_p99 * 1e3
    );
    println!(
        "  throughput   {:.1} tokens/sec over {} decode waves",
        report.tokens_per_sec, report.waves
    );
    println!(
        "  kv cache     peak {:.3} GiB at {} in-flight (admission limit {})",
        report.kv_peak_bytes / GIB,
        report.peak_in_flight,
        kv.admission_limit(trace.max_context()),
    );

    if args.has("timeline") {
        let width = args.get_usize("width", 100)?;
        let n_req = report.cap.max(1);
        let spec = ScheduleSpec {
            d_l: shape.d_l,
            n_l: stages,
            n_mu: n_req,
            tp,
            partition: false,
            offload: false,
            data_parallel: false,
            zero: 0,
        };
        let costs = ServeCosts::new(&shape, &cluster, stages, tp);
        let pre = lower(&prefill_pipeline(&spec))
            .map_err(|e| anyhow::anyhow!("prefill lowering: {e:?}"))?;
        let dec = lower(&decode_waves(&spec, 3))
            .map_err(|e| anyhow::anyhow!("decode lowering: {e:?}"))?;
        println!("\nprefill ({n_req} prompts pipelined, one digit per request):");
        print!("{}", render_requests(&simulate_program(&pre, &costs.table(prompt)), width, n_req));
        println!("decode (3 token waves x {n_req} requests):");
        print!("{}", render_requests(&simulate_program(&dec, &costs.table(1)), width, n_req));
    }
    Ok(())
}

/// `repro serve plan` — the SLO-driven deployment search.
fn cmd_serve_plan(args: &Args) -> Result<()> {
    let cluster = cluster_from(args)?;
    let shape = XModel::new(args.get_usize("x", 16)?).shape();
    let spec = SloSpec {
        rate: args.get_f64("rate", 10.0)?,
        slo_p99_ttft: args.get_f64("slo-p99-ms", 500.0)? / 1e3,
        n_requests: args.get_usize("requests", 64)?,
        prompt: args.get_usize("prompt", 128)?,
        decode: args.get_usize("decode", 32)?,
        seed: args.get_usize("seed", 0)? as u64,
    };
    let plan = plan_slo(&shape, &cluster, &spec).map_err(|e| anyhow::anyhow!(e))?;
    println!(
        "slo plan: p99 TTFT <= {:.0} ms at {} req/s ({} requests, prompt {}, decode {}, seed {})",
        spec.slo_p99_ttft * 1e3,
        spec.rate,
        spec.n_requests,
        spec.prompt,
        spec.decode,
        spec.seed
    );
    println!(
        "  {:>6} {:>4} {:>6} {:>12} {:>12} {:>12}",
        "stages", "tp", "batch", "p50 ttft", "p99 ttft", "tokens/sec"
    );
    for c in plan.evaluated.iter().take(10) {
        println!(
            "  {:>6} {:>4} {:>6} {:>10.1}ms {:>10.1}ms {:>12.1}  {}",
            c.stages,
            c.tp,
            c.max_batch,
            c.report.ttft_p50 * 1e3,
            c.report.ttft_p99 * 1e3,
            c.report.tokens_per_sec,
            if c.meets(spec.slo_p99_ttft) { "meets slo" } else { "misses slo" },
        );
    }
    if plan.evaluated.len() > 10 {
        println!("  ... {} more evaluated", plan.evaluated.len() - 10);
    }
    if !plan.rejected.is_empty() {
        println!("  ({} deployments rejected before replay)", plan.rejected.len());
    }
    match &plan.infeasible {
        None => println!(
            "winner: stages={} tp={} max-batch={} — {:.1} tokens/sec at p99 TTFT {:.1} ms",
            plan.best.stages,
            plan.best.tp,
            plan.best.max_batch,
            plan.best.report.tokens_per_sec,
            plan.best.report.ttft_p99 * 1e3,
        ),
        Some(diag) => println!("infeasible: {diag}"),
    }
    Ok(())
}

/// `repro serve --probe` — artifact-free smoke for CI: tiny model,
/// short seeded stream, determinism + token-conservation assertions
/// and one relaxed-SLO plan. Writes no files.
fn cmd_serve_probe() -> Result<()> {
    let cluster = ClusterSpec::reference();
    let shape = XModel::new(8).shape();
    let trace = Trace::poisson(7, 20.0, 16, 16, 4);
    verify_serving(&shape, &cluster, 2, 1, 4, 16, 4).map_err(|e| anyhow::anyhow!(e))?;
    let a = run_trace(&shape, &cluster, 2, 1, 4, &trace).map_err(|e| anyhow::anyhow!(e))?;
    let b = run_trace(&shape, &cluster, 2, 1, 4, &trace).map_err(|e| anyhow::anyhow!(e))?;
    anyhow::ensure!(a.completed == trace.requests.len(), "probe lost requests");
    anyhow::ensure!(
        (a.makespan - b.makespan).abs() < 1e-12 && a.tokens_per_sec == b.tokens_per_sec,
        "probe replay diverged between identical runs"
    );
    anyhow::ensure!(
        (a.tokens_per_sec * a.makespan - trace.total_decode_tokens() as f64).abs() < 1e-6,
        "probe did not conserve decode tokens"
    );
    let plan = plan_slo(
        &shape,
        &cluster,
        &SloSpec {
            rate: 20.0,
            slo_p99_ttft: f64::INFINITY,
            n_requests: 8,
            prompt: 16,
            decode: 4,
            seed: 7,
        },
    )
    .map_err(|e| anyhow::anyhow!(e))?;
    anyhow::ensure!(plan.infeasible.is_none(), "probe slo plan infeasible under an infinite SLO");
    println!(
        "serve probe ok: {} requests, {:.1} tokens/sec, p99 ttft {:.1} ms; slo winner \
         stages={} tp={} batch={}",
        a.completed,
        a.tokens_per_sec,
        a.ttft_p99 * 1e3,
        plan.best.stages,
        plan.best.tp,
        plan.best.max_batch,
    );
    Ok(())
}

/// Generate the schedule a `repro verify` policy name means for a spec,
/// or `None` when the policy cannot inhabit the shape (interleaved
/// divisibility). "improved" is the paper's pair: layered GA at one
/// stage, the modular pipeline otherwise — together with baseline,
/// 1f1b and interleaved that covers all five generators.
fn verify_schedule(policy: &str, spec: &ScheduleSpec, chunks: usize) -> Result<Option<Schedule>> {
    Ok(match policy {
        "baseline" => Some(standard_ga(spec)),
        "improved" => {
            Some(if spec.n_l == 1 { layered_ga(spec) } else { modular_pipeline(spec) })
        }
        "1f1b" => Some(one_f_one_b(spec)),
        "interleaved" => interleaved_applicable(spec, chunks)
            .then(|| interleaved_1f1b(spec, chunks)),
        other => bail!("unknown policy {other} (baseline|improved|1f1b|interleaved|all)"),
    })
}

/// Lower one (policy, spec) pair, compose it over the `{stages, dp, tp}`
/// grid and run the whole-world verifier with the cluster's real wire
/// table and memory budget. `Ok(false)` = policy inapplicable to the
/// shape; any verification failure is an error naming rank and op.
fn verify_world(
    cluster: &ClusterSpec,
    shape: &TransformerShape,
    policy: &str,
    spec: &ScheduleSpec,
    dp: usize,
    chunks: usize,
    verbose: bool,
) -> Result<bool> {
    let Some(schedule) = verify_schedule(policy, spec, chunks)? else {
        return Ok(false);
    };
    let program = lower(&schedule).map_err(|e| anyhow::anyhow!("invalid schedule: {e:?}"))?;
    let cfg = TrainConfig {
        strategy: if policy == "baseline" { Strategy::Baseline } else { Strategy::Improved },
        n_b: dp,
        n_l: spec.n_l,
        n_a: spec.tp,
        n_mu: spec.n_mu,
        b_mu: 1.0,
        offload: spec.offload,
        partition: spec.partition,
        zero: spec.zero,
    };
    let costs = CostTable::new(shape, &cfg, cluster);
    let memory = MemoryBreakdown::evaluate(shape, &cfg);
    let budget = MemoryModel::new(&costs, &memory, cluster.gpu.memory_bytes, spec.offload);
    let topo = Topology::new(spec.n_l, dp, spec.tp);
    match verify_program(&program, topo, costs.wire, Some(&budget)) {
        Ok(()) => {
            if verbose {
                println!(
                    "ok: {} over {} ranks (stages {} x dp {} x tp {}) — {} ops/stage-rank, \
                     p2p + congruence + deadlock + memory all pass",
                    program.name,
                    topo.n_ranks(),
                    topo.stages,
                    topo.dp,
                    topo.tp,
                    program.len() / topo.stages.max(1),
                );
            }
            Ok(true)
        }
        Err(errors) => {
            for e in &errors {
                eprintln!("  {e}");
            }
            bail!(
                "static verification FAILED for {policy} (layers {}, stages {}, mb {}, dp {dp}, \
                 tp {}, partition {}, offload {}, zero {}): {} error(s) above",
                spec.d_l,
                spec.n_l,
                spec.n_mu,
                spec.tp,
                spec.partition,
                spec.offload,
                spec.zero,
                errors.len(),
            )
        }
    }
}

fn cmd_verify(args: &Args) -> Result<()> {
    let cluster = cluster_from(args)?;
    let shape = XModel::new(args.get_usize("x", 32)?).shape();
    // Shape: --spec LAYERS:STAGES:MB shorthand, individual flags win.
    let (mut d_l, mut n_l, mut n_mu) = (16usize, 4usize, 8usize);
    if let Some(spec) = args.get("spec") {
        let parts: Vec<&str> = spec.split(':').collect();
        anyhow::ensure!(parts.len() == 3, "--spec wants LAYERS:STAGES:MB, got {spec}");
        d_l = parts[0].parse().with_context(|| format!("--spec layers '{}'", parts[0]))?;
        n_l = parts[1].parse().with_context(|| format!("--spec stages '{}'", parts[1]))?;
        n_mu = parts[2].parse().with_context(|| format!("--spec mb '{}'", parts[2]))?;
    }
    let d_l = args.get_usize("layers", d_l)?;
    let n_l = args.get_usize("stages", n_l)?;
    let n_mu = args.get_usize("mb", n_mu)?;
    let chunks = args.get_usize("chunks", 2)?;
    let prompt = args.get_usize("prompt", 64)?;
    let decode = args.get_usize("decode", 16)?;
    let policy = args.get("policy").unwrap_or("all");
    let policies: Vec<&str> = if policy == "all" {
        vec!["baseline", "improved", "1f1b", "interleaved"]
    } else if policy == "serve" {
        vec![]
    } else {
        vec![policy]
    };
    // "serve" covers both forward-only programs (prefill + decode).
    let want_serving = policy == "all" || policy == "serve";

    if args.has("grid") {
        // The acceptance sweep: every policy x stages x dp x tp x
        // {plain, partition, offload} world that is applicable must
        // verify clean.
        let (mut verified, mut skipped) = (0usize, 0usize);
        for policy in &policies {
            for stages in [1usize, 2, 3, 4] {
                if d_l % stages != 0 || n_mu < stages {
                    skipped += 1;
                    continue;
                }
                for dp in [1usize, 2] {
                    for tp in [1usize, 2] {
                        // The ZeRO worlds ride the same sweep: every
                        // stage must compose clean over the dp ring the
                        // reduce-scatter and all-gather rendezvous on.
                        for (partition, offload, zero) in [
                            (false, false, 0u8),
                            (true, false, 0),
                            (false, true, 0),
                            (false, false, 1),
                            (false, false, 2),
                            (false, false, 3),
                        ] {
                            let spec = ScheduleSpec {
                                d_l,
                                n_l: stages,
                                n_mu,
                                tp,
                                partition,
                                offload,
                                data_parallel: dp > 1,
                                zero,
                            };
                            if verify_world(
                                &cluster, &shape, policy, &spec, dp, chunks, false,
                            )? {
                                verified += 1;
                            } else {
                                skipped += 1;
                            }
                        }
                    }
                }
            }
        }
        if !policies.is_empty() {
            println!(
                "verified {verified} whole worlds clean ({skipped} inapplicable combinations \
                 skipped) across {} policies x stages {{1,2,3,4}} x dp {{1,2}} x tp {{1,2}} x \
                 {{plain, partition, offload, zero 1-3}}",
                policies.len(),
            );
        }
        if want_serving {
            // Serving worlds: forward-only prefill + decode programs at
            // dp = 1 with the KV-aware memory bound, across stages x tp
            // x in-flight batch.
            // Serving prices the model's real depth, so stage counts
            // must divide shape.d_l (= x), not the --layers flag.
            let mut serve_verified = 0usize;
            for stages in [1usize, 2, 3, 4] {
                if shape.d_l % stages != 0 {
                    continue;
                }
                for tp in [1usize, 2] {
                    for cap in [1usize, 2, 4, 8] {
                        verify_serving(&shape, &cluster, stages, tp, cap, prompt, decode)
                            .map_err(|e| anyhow::anyhow!(e))?;
                        serve_verified += 1;
                    }
                }
            }
            println!(
                "verified {serve_verified} serving worlds clean (prefill + decode at dp 1, \
                 stages {{1,2,3,4}} x tp {{1,2}} x in-flight {{1,2,4,8}}, prompt {prompt}, \
                 decode {decode}, KV-aware memory bound)"
            );
        }
        return Ok(());
    }

    let dp = args.get_usize("dp", 1)?;
    let tp = args.get_usize("tp", 1)?;
    anyhow::ensure!(d_l % n_l == 0, "--layers {d_l} not divisible by --stages {n_l}");
    if policy == "serve" {
        // Serving verifies at the model's own depth (the KV model and
        // ServeCosts price real layers), composes at dp = 1, and —
        // unlike training — legally runs fewer in-flight requests than
        // stages (a starved decode wave).
        anyhow::ensure!(
            shape.d_l % n_l == 0,
            "model depth {} (--x) not divisible by --stages {n_l}",
            shape.d_l
        );
        verify_serving(&shape, &cluster, n_l, tp, n_mu, prompt, decode)
            .map_err(|e| anyhow::anyhow!(e))?;
        println!(
            "ok: serving world (stages {n_l} x tp {tp}, {n_mu} in-flight, prompt {prompt}, \
             decode {decode}) — prefill + decode programs pass p2p + congruence + deadlock + \
             KV-aware memory"
        );
        return Ok(());
    }
    anyhow::ensure!(n_mu >= n_l, "--mb {n_mu} must be at least --stages {n_l}");
    let spec = ScheduleSpec {
        d_l,
        n_l,
        n_mu,
        tp,
        partition: args.has("partition"),
        offload: args.has("offload"),
        data_parallel: dp > 1,
        zero: args.get_usize("zero", 0)? as u8,
    };
    spec.validate().map_err(|e| anyhow::anyhow!(e))?;
    for policy in &policies {
        if !verify_world(&cluster, &shape, policy, &spec, dp, chunks, true)? {
            println!(
                "skip: {policy} is not applicable to layers {d_l} / stages {n_l} / \
                 chunks {chunks}"
            );
        }
    }
    Ok(())
}
