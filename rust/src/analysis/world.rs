//! World composition and the structural checks (p2p matching,
//! collective congruence, cross-rank deadlock detection).

use std::collections::VecDeque;

use crate::collective::{Rank, Topology};
use crate::schedule::{Op, ScheduleProgram};
use crate::sim::cost::WIRE_BYTES_PER_ELEM;
use crate::sim::WireBytes;

use super::memory::rank_peak;
use super::{fmt_rank, MemoryModel, WorldError};

/// One rank's view of the world: the op sequence it dispatches in
/// order, the local dependency edges between those ops (positions, not
/// arena ids), and the wire table it prices payloads with. Generated
/// worlds replicate their stage's slice of the program; tests mutate
/// individual ranks to build the adversarial worlds the checks exist
/// to reject.
#[derive(Debug, Clone)]
pub struct RankProgram {
    pub rank: Rank,
    pub ops: Vec<Op>,
    /// This rank's payload pricing. All ranks share one table in a
    /// generated world; a divergent entry models a rank that would
    /// put the wrong number of elements on the wire.
    pub wire: WireBytes,
    /// Local dependency edges `(producer, consumer)` as positions into
    /// `ops`. In-order dispatch edges are implied and not stored.
    pub(crate) edges: Vec<(u32, u32)>,
}

/// The composed whole world: every rank of a topology with its op
/// sequence. See the module docs for the four checks [`verify`] runs.
///
/// [`verify`]: WorldModel::verify
#[derive(Debug, Clone)]
pub struct WorldModel {
    pub topo: Topology,
    /// Indexed by [`Topology::index`].
    pub ranks: Vec<RankProgram>,
    /// Whether `RestoreParams` is a dp-ring all-gather (partitioned
    /// state) rather than a local CPU fetch (offload-only) — decides
    /// its membership in the dp collective sequence.
    partitioned: bool,
}

impl WorldModel {
    /// Replicate a lowered program over a rank grid: each rank runs its
    /// stage's op slice, dp/tp replicas run identical copies (exactly
    /// how the trainer dispatches the program). Fails when the program
    /// cannot inhabit the topology at all.
    pub fn compose(
        program: &ScheduleProgram,
        topo: Topology,
        wire: WireBytes,
    ) -> Result<WorldModel, WorldError> {
        if topo.stages != program.n_stages {
            return Err(WorldError::Topology {
                detail: format!(
                    "program has {} stages, topology has {}",
                    program.n_stages, topo.stages
                ),
            });
        }
        if topo.tp > 1 && program.tp <= 1 {
            return Err(WorldError::Topology {
                detail: format!(
                    "tensor-parallel grid (tp = {}) over a program with no \
                     TensorAllReduce ops — tp ranks would never reduce",
                    topo.tp
                ),
            });
        }
        if topo.dp > 1 {
            let mut reduced = vec![false; program.d_l];
            for node in &program.ops {
                if let Op::ReduceGrad { layer } | Op::ReduceScatterGrad { layer } = node.op {
                    reduced[layer] = true;
                }
            }
            if let Some(layer) = reduced.iter().position(|r| !r) {
                return Err(WorldError::Topology {
                    detail: format!(
                        "data-parallel grid (dp = {}) but layer {layer} has no \
                         ReduceGrad — its gradients would diverge across replicas",
                        topo.dp
                    ),
                });
            }
        }

        // Per-stage op slices and local edges, shared by every replica.
        let mut stage_ops: Vec<Vec<Op>> = Vec::with_capacity(topo.stages);
        let mut stage_edges: Vec<Vec<(u32, u32)>> = Vec::with_capacity(topo.stages);
        for s in 0..topo.stages {
            let slice = program.stage_ops(s);
            let base = slice.first().map(|n| n.id).unwrap_or(0);
            let mut edges = Vec::new();
            for (pos, node) in slice.iter().enumerate() {
                debug_assert_eq!(node.id - base, pos as u32, "stage arena must be contiguous");
                for &pred in program.preds_of(node.id) {
                    if program.ops[pred as usize].stage == s as u32 {
                        edges.push((pred - base, pos as u32));
                    }
                }
            }
            stage_ops.push(slice.iter().map(|n| n.op).collect());
            stage_edges.push(edges);
        }

        let ranks = (0..topo.n_ranks())
            .map(|i| {
                let rank = topo.rank_at(i);
                RankProgram {
                    rank,
                    ops: stage_ops[rank.stage].clone(),
                    wire,
                    edges: stage_edges[rank.stage].clone(),
                }
            })
            .collect();
        Ok(WorldModel { topo, ranks, partitioned: program.partitioned })
    }

    fn idx(&self, stage: usize, dp: usize, tp: usize) -> usize {
        self.topo.index(Rank { stage, dp, tp })
    }

    /// Position of the first op matching `pred` on rank `rank` — test
    /// and tooling convenience for targeting mutations.
    pub fn find_op(&self, rank: usize, pred: impl Fn(&Op) -> bool) -> Option<usize> {
        self.ranks[rank].ops.iter().position(pred)
    }

    /// Delete one op from one rank (a dropped receive, a skipped
    /// collective), keeping the local edges consistent: edges incident
    /// to the removed position disappear, later positions shift down.
    pub fn remove_op(&mut self, rank: usize, pos: usize) -> Op {
        let rp = &mut self.ranks[rank];
        let op = rp.ops.remove(pos);
        let p = pos as u32;
        rp.edges.retain(|&(a, b)| a != p && b != p);
        for e in rp.edges.iter_mut() {
            if e.0 > p {
                e.0 -= 1;
            }
            if e.1 > p {
                e.1 -= 1;
            }
        }
        op
    }

    /// Swap two ops on one rank (a reordered collective). Local edges
    /// follow their ops, so the *data* dependencies stay attached to
    /// the right computation — what changes is the dispatch order.
    pub fn swap_ops(&mut self, rank: usize, i: usize, j: usize) {
        let rp = &mut self.ranks[rank];
        rp.ops.swap(i, j);
        let (pi, pj) = (i as u32, j as u32);
        for e in rp.edges.iter_mut() {
            for end in [&mut e.0, &mut e.1] {
                *end = if *end == pi {
                    pj
                } else if *end == pj {
                    pi
                } else {
                    *end
                };
            }
        }
    }

    /// Run every check; returns all failures (empty = the world is
    /// statically sound). `mem = None` skips the memory bound.
    pub fn verify(&self, mem: Option<&MemoryModel>) -> Vec<WorldError> {
        let mut errors = Vec::new();
        self.check_p2p(&mut errors);
        self.check_congruence(&mut errors);
        self.check_deadlock(&mut errors);
        if let Some(model) = mem {
            self.check_memory(model, &mut errors);
        }
        errors
    }

    // ---- check 1: p2p matching ----------------------------------------

    /// The pipeline transports are FIFO per directed channel, so the
    /// k-th send *is* the k-th receive: pair them by index and demand
    /// identity agreement (`SendAct{l}` feeds `RecvAct{l+1}`,
    /// `SendGrad{l}` feeds `RecvGrad{l−1}`, same micro-batch), equal
    /// message counts, and an element count both wire tables agree on.
    fn check_p2p(&self, errors: &mut Vec<WorldError>) {
        if self.topo.stages <= 1 {
            return;
        }
        for dp in 0..self.topo.dp {
            for tp in 0..self.topo.tp {
                for s in 0..self.topo.stages {
                    let next = (s + 1) % self.topo.stages;
                    let prev = (s + self.topo.stages - 1) % self.topo.stages;
                    self.check_channel(self.idx(s, dp, tp), self.idx(next, dp, tp), false, errors);
                    self.check_channel(self.idx(s, dp, tp), self.idx(prev, dp, tp), true, errors);
                }
            }
        }
    }

    fn check_channel(&self, from: usize, to: usize, grads: bool, errors: &mut Vec<WorldError>) {
        let (tx, rx) = (&self.ranks[from], &self.ranks[to]);
        let sends: Vec<(usize, usize)> = tx
            .ops
            .iter()
            .filter_map(|op| match op {
                Op::SendAct { layer, mb } if !grads => Some((*layer, *mb)),
                Op::SendGrad { layer, mb } if grads => Some((*layer, *mb)),
                _ => None,
            })
            .collect();
        let recvs: Vec<(usize, usize)> = rx
            .ops
            .iter()
            .filter_map(|op| match op {
                Op::RecvAct { layer, mb } if !grads => Some((*layer, *mb)),
                Op::RecvGrad { layer, mb } if grads => Some((*layer, *mb)),
                _ => None,
            })
            .collect();
        let (skind, rkind) = if grads { ("sg", "rg") } else { ("sa", "ra") };
        for (k, (&(sl, smb), &(rl, rmb))) in sends.iter().zip(&recvs).enumerate() {
            // The receive names the consuming layer: the act of layer l
            // feeds layer l+1, the grad of layer l's output comes from
            // layer l+1 and is received as the grad *for* layer l.
            let want = if grads { sl.wrapping_sub(1) } else { sl + 1 };
            if rl != want || smb != rmb {
                errors.push(WorldError::P2p {
                    from: tx.rank,
                    to: rx.rank,
                    index: k,
                    detail: format!(
                        "{skind}{sl}.{smb} is consumed by {rkind}{rl}.{rmb}, \
                         want {rkind}{want}.{smb}"
                    ),
                });
                return; // FIFO: everything after a shift is noise
            }
        }
        if sends.len() != recvs.len() {
            let k = sends.len().min(recvs.len());
            let detail = if sends.len() > recvs.len() {
                let (l, mb) = sends[k];
                format!(
                    "{} sends but only {} receives: {skind}{l}.{mb} is never consumed \
                     (dropped receive?)",
                    sends.len(),
                    recvs.len()
                )
            } else {
                let (l, mb) = recvs[k];
                format!(
                    "{} receives but only {} sends: {rkind}{l}.{mb} waits forever",
                    recvs.len(),
                    sends.len()
                )
            };
            errors.push(WorldError::P2p { from: tx.rank, to: rx.rank, index: k, detail });
        }
        // Payload sizing: one verdict per channel — the wire table is
        // per-rank, so every message on the channel mis-sizes together.
        if let Some(&(l, mb)) = sends.first() {
            let pick = |w: &WireBytes| if grads { w.send_grad } else { w.send_act };
            let sent = pick(&tx.wire) / WIRE_BYTES_PER_ELEM;
            let expected = pick(&rx.wire) / WIRE_BYTES_PER_ELEM;
            if sent != expected {
                errors.push(WorldError::Payload {
                    from: tx.rank,
                    to: rx.rank,
                    op: format!("{skind}{l}.{mb}"),
                    sent_elems: sent,
                    expected_elems: expected,
                });
            }
        }
    }

    // ---- check 2: collective congruence -------------------------------

    /// Whether `op` runs on the given collective axis. `RestoreParams`
    /// is a dp all-gather only under a partition; offload-only restores
    /// are local CPU fetches. The ZeRO collectives (reduce-scatter,
    /// parameter all-gather) always rendezvous on the dp ring.
    fn on_axis(&self, op: &Op, dp_axis: bool) -> bool {
        match op {
            Op::ReduceGrad { .. } => dp_axis,
            Op::ReduceScatterGrad { .. } | Op::AllGatherParams { .. } => dp_axis,
            Op::RestoreParams { .. } => dp_axis && self.partitioned,
            Op::TensorAllReduce { .. } => !dp_axis,
            _ => false,
        }
    }

    /// The (identity, element-count) sequence rank `r` issues on one
    /// axis — what every other member of its ring must match exactly.
    fn collective_seq(&self, r: usize, dp_axis: bool) -> Vec<(String, f64)> {
        let rp = &self.ranks[r];
        rp.ops
            .iter()
            .filter(|op| self.on_axis(op, dp_axis))
            .map(|op| (op.to_string(), rp.wire.of(op) / WIRE_BYTES_PER_ELEM))
            .collect()
    }

    fn check_congruence(&self, errors: &mut Vec<WorldError>) {
        let mut rings: Vec<(Vec<usize>, bool)> = Vec::new();
        if self.topo.dp > 1 {
            for s in 0..self.topo.stages {
                for tp in 0..self.topo.tp {
                    rings.push(((0..self.topo.dp).map(|d| self.idx(s, d, tp)).collect(), true));
                }
            }
        }
        if self.topo.tp > 1 {
            for s in 0..self.topo.stages {
                for dp in 0..self.topo.dp {
                    rings.push(((0..self.topo.tp).map(|t| self.idx(s, dp, t)).collect(), false));
                }
            }
        }
        for (members, dp_axis) in rings {
            let axis = if dp_axis { "dp" } else { "tp" };
            let want = self.collective_seq(members[0], dp_axis);
            for &m in &members[1..] {
                let got = self.collective_seq(m, dp_axis);
                let diverge = want
                    .iter()
                    .zip(&got)
                    .position(|(a, b)| a != b)
                    .or_else(|| (want.len() != got.len()).then(|| want.len().min(got.len())));
                if let Some(i) = diverge {
                    let show = |seq: &[(String, f64)]| {
                        seq.get(i)
                            .map(|(op, n)| format!("{op} ({n} elems)"))
                            .unwrap_or_else(|| "(end of sequence)".into())
                    };
                    errors.push(WorldError::Collective {
                        axis,
                        a: self.ranks[members[0]].rank,
                        b: self.ranks[m].rank,
                        index: i,
                        got: show(&got),
                        want: show(&want),
                    });
                    break; // one divergence per ring member pair is enough
                }
            }
        }
    }

    // ---- check 3: global deadlock freedom ------------------------------

    /// Build the cross-rank wait-for graph and Kahn it. Edges:
    /// * in-order dispatch (op i → op i+1 on each rank) — the workers
    ///   are synchronous in-order executors;
    /// * local data edges (the program's CSR, per replica);
    /// * channel edges: k-th send → k-th receive per directed FIFO
    ///   channel (buffering is unbounded — mpsc / buffered TCP — so
    ///   sends never block and need no back-edges);
    /// * rendezvous edges: a ring collective completes only once every
    ///   member has *reached* its k-th instance, i.e. finished the op
    ///   before it.
    fn check_deadlock(&self, errors: &mut Vec<WorldError>) {
        let mut base = Vec::with_capacity(self.ranks.len() + 1);
        let mut n = 0u32;
        for rp in &self.ranks {
            base.push(n);
            n += rp.ops.len() as u32;
        }
        base.push(n);
        let node = |r: usize, pos: u32| base[r] + pos;

        let mut edges: Vec<(u32, u32)> = Vec::new();
        for (r, rp) in self.ranks.iter().enumerate() {
            for i in 1..rp.ops.len() as u32 {
                edges.push((node(r, i - 1), node(r, i)));
            }
            for &(a, b) in &rp.edges {
                edges.push((node(r, a), node(r, b)));
            }
        }
        // Channel edges, by FIFO index (up to the shorter side; count
        // mismatches are already p2p errors).
        if self.topo.stages > 1 {
            for dp in 0..self.topo.dp {
                for tp in 0..self.topo.tp {
                    for s in 0..self.topo.stages {
                        let next = (s + 1) % self.topo.stages;
                        let prev = (s + self.topo.stages - 1) % self.topo.stages;
                        for (grads, to) in [(false, next), (true, prev)] {
                            let (fi, ti) = (self.idx(s, dp, tp), self.idx(to, dp, tp));
                            let sends = positions(&self.ranks[fi].ops, grads, true);
                            let recvs = positions(&self.ranks[ti].ops, grads, false);
                            for (sp, rp) in sends.iter().zip(&recvs) {
                                edges.push((node(fi, *sp), node(ti, *rp)));
                            }
                        }
                    }
                }
            }
        }
        // Rendezvous edges for every ring collective instance.
        let mut rendezvous = |members: &[usize], dp_axis: bool| {
            let pos: Vec<Vec<u32>> = members
                .iter()
                .map(|&m| {
                    self.ranks[m]
                        .ops
                        .iter()
                        .enumerate()
                        .filter(|(_, op)| self.on_axis(op, dp_axis))
                        .map(|(i, _)| i as u32)
                        .collect()
                })
                .collect();
            let depth = pos.iter().map(|p| p.len()).min().unwrap_or(0);
            for k in 0..depth {
                for (ai, &a) in members.iter().enumerate() {
                    if pos[ai][k] == 0 {
                        continue; // reached at dispatch start
                    }
                    for (bi, &b) in members.iter().enumerate() {
                        if ai != bi {
                            edges.push((node(a, pos[ai][k] - 1), node(b, pos[bi][k])));
                        }
                    }
                }
            }
        };
        if self.topo.dp > 1 {
            for s in 0..self.topo.stages {
                for tp in 0..self.topo.tp {
                    let members: Vec<usize> =
                        (0..self.topo.dp).map(|d| self.idx(s, d, tp)).collect();
                    rendezvous(&members, true);
                }
            }
        }
        if self.topo.tp > 1 {
            for s in 0..self.topo.stages {
                for dp in 0..self.topo.dp {
                    let members: Vec<usize> =
                        (0..self.topo.tp).map(|t| self.idx(s, dp, t)).collect();
                    rendezvous(&members, false);
                }
            }
        }

        // CSR + Kahn.
        let n = n as usize;
        let mut succ_off = vec![0u32; n + 1];
        for &(a, _) in &edges {
            succ_off[a as usize + 1] += 1;
        }
        for i in 0..n {
            succ_off[i + 1] += succ_off[i];
        }
        let mut succs = vec![0u32; edges.len()];
        let mut cursor = succ_off.clone();
        let mut indeg = vec![0u32; n];
        for &(a, b) in &edges {
            succs[cursor[a as usize] as usize] = b;
            cursor[a as usize] += 1;
            indeg[b as usize] += 1;
        }
        let mut queue: VecDeque<u32> = (0..n as u32).filter(|&i| indeg[i as usize] == 0).collect();
        let mut done = 0usize;
        let mut alive = vec![true; n];
        while let Some(u) = queue.pop_front() {
            done += 1;
            alive[u as usize] = false;
            let (lo, hi) = (succ_off[u as usize] as usize, succ_off[u as usize + 1] as usize);
            for &v in &succs[lo..hi] {
                indeg[v as usize] -= 1;
                if indeg[v as usize] == 0 {
                    queue.push_back(v);
                }
            }
        }
        if done == n {
            return;
        }
        let cycle = minimal_cycle(n, &succ_off, &succs, &alive);
        let label = |id: u32| {
            let r = base.partition_point(|&b| b <= id) - 1;
            let pos = (id - base[r]) as usize;
            format!("{}: {}@{}", fmt_rank(&self.ranks[r].rank), self.ranks[r].ops[pos], pos)
        };
        errors.push(WorldError::Deadlock { cycle: cycle.into_iter().map(label).collect() });
    }

    // ---- check 4: static peak memory -----------------------------------

    fn check_memory(&self, model: &MemoryModel, errors: &mut Vec<WorldError>) {
        for rp in &self.ranks {
            let (peak, at) = rank_peak(&rp.ops, model);
            if peak > model.budget {
                errors.push(WorldError::Memory {
                    rank: rp.rank,
                    op: rp.ops.get(at).map(|o| o.to_string()).unwrap_or_default(),
                    at,
                    peak_bytes: peak,
                    budget_bytes: model.budget,
                });
            }
        }
    }
}

/// Positions of the sends (or receives) of one channel kind in an op
/// sequence, in dispatch order.
fn positions(ops: &[Op], grads: bool, sends: bool) -> Vec<u32> {
    ops.iter()
        .enumerate()
        .filter(|(_, op)| match op {
            Op::SendAct { .. } => sends && !grads,
            Op::SendGrad { .. } => sends && grads,
            Op::RecvAct { .. } => !sends && !grads,
            Op::RecvGrad { .. } => !sends && grads,
            _ => false,
        })
        .map(|(i, _)| i as u32)
        .collect()
}

/// A short cycle through the residual (non-executable) subgraph: find
/// any cycle by DFS, then BFS from each of its nodes (bounded) to
/// shrink it — the minimal diagnostic beats a thousand-op residue dump.
fn minimal_cycle(n: usize, succ_off: &[u32], succs: &[u32], alive: &[bool]) -> Vec<u32> {
    let succs_of = |u: u32| {
        let (lo, hi) = (succ_off[u as usize] as usize, succ_off[u as usize + 1] as usize);
        succs[lo..hi].iter().copied().filter(|&v| alive[v as usize])
    };
    // DFS for any cycle. Colors: 0 unvisited, 1 on stack, 2 finished.
    let mut color = vec![0u8; n];
    let mut found: Vec<u32> = Vec::new();
    'roots: for root in 0..n as u32 {
        if !alive[root as usize] || color[root as usize] != 0 {
            continue;
        }
        let mut stack: Vec<(u32, Vec<u32>)> = vec![(root, succs_of(root).collect())];
        color[root as usize] = 1;
        while let Some((u, rest)) = stack.last_mut() {
            let u = *u;
            match rest.pop() {
                Some(v) if color[v as usize] == 1 => {
                    // Back edge: the stack from v to u is a cycle.
                    let start = stack.iter().position(|(w, _)| *w == v).expect("on stack");
                    found = stack[start..].iter().map(|(w, _)| *w).collect();
                    break 'roots;
                }
                Some(v) if color[v as usize] == 0 => {
                    color[v as usize] = 1;
                    let kids = succs_of(v).collect();
                    stack.push((v, kids));
                }
                Some(_) => {}
                None => {
                    color[u as usize] = 2;
                    stack.pop();
                }
            }
        }
    }
    if found.is_empty() {
        return found; // unreachable for a stuck Kahn, but stay total
    }
    // Shrink: shortest cycle through any of (a bounded number of) the
    // found cycle's nodes.
    let mut best = found.clone();
    let mut parent = vec![u32::MAX; n];
    let mut stamp = vec![0u32; n];
    for (pass, &seed) in found.iter().take(64).enumerate() {
        let gen = pass as u32 + 1;
        let mut q = VecDeque::new();
        stamp[seed as usize] = gen;
        q.push_back(seed);
        'bfs: while let Some(u) = q.pop_front() {
            for v in succs_of(u) {
                if v == seed {
                    // Reconstruct seed -> ... -> u, a cycle via the edge
                    // u -> seed.
                    let mut path = vec![u];
                    let mut w = u;
                    while w != seed {
                        w = parent[w as usize];
                        path.push(w);
                    }
                    path.reverse();
                    if path.len() < best.len() {
                        best = path;
                    }
                    break 'bfs;
                }
                if stamp[v as usize] != gen {
                    stamp[v as usize] = gen;
                    parent[v as usize] = u;
                    q.push_back(v);
                }
            }
        }
    }
    best
}
