//! Whole-world static schedule verification — the **verify** stage of
//! the pipeline: generate → lower → **verify** → simulate | execute.
//!
//! [`crate::schedule::lower`] proves a *single rank's* program
//! self-consistent: ownership, compute counts, transfer pairing, and
//! the in-order deadlock condition — all within one
//! [`ScheduleProgram`]. But a training job is a grid of ranks,
//! [`Topology`] `{stages, dp, tp}`, and the failures that hang real
//! clusters are *cross-rank*: a send with no ordered receive on the
//! neighbor stage, two members of a ring issuing their collectives in
//! different orders, a wait-for cycle threading through pipeline
//! channels and a collective rendezvous, a rank whose stashed
//! activations overflow the device mid-batch. None of those are
//! visible to a per-rank check — they pass `validate` today and hang
//! (or silently skew gradients) at run time.
//!
//! [`WorldModel::compose`] replicates a lowered program across every
//! rank of a topology — each rank executes its stage's op slice, dp
//! and tp replicas run identical copies, exactly how
//! [`crate::trainer`] dispatches the program over a
//! [`crate::collective::CommWorld`] — and [`WorldModel::verify`] runs
//! four checks over the composed world:
//!
//! 1. **p2p matching.** The pipeline rings are FIFO per directed
//!    channel, so the k-th `SendAct` issued by stage *s* is consumed by
//!    the k-th `RecvAct` on stage *s+1* (and symmetrically for
//!    gradients toward *s−1*). Every pair must agree on `(layer, mb)`
//!    identity, every channel on message count, and sender/receiver on
//!    the payload element count their [`WireBytes`] tables imply — the
//!    static form of the worker's `check_payload`.
//! 2. **collective congruence.** All members of each `dp_group()` /
//!    `tp_group()` ring must issue an *identical ordered sequence* of
//!    collectives (kind, layer/micro-batch identity, element count):
//!    `ReduceGrad` and partitioned `RestoreParams` on the dp axis,
//!    `TensorAllReduce` on the tp axis. A reordered or missing
//!    collective on one rank becomes a compile-time diagnostic instead
//!    of a whole-ring hang.
//! 3. **global deadlock freedom.** A cross-rank wait-for graph: each
//!    rank's in-order dispatch and local CSR edges, channel edges
//!    pairing the k-th send with the k-th receive (the transports'
//!    FIFO semantics; buffering is unbounded, so sends never block),
//!    and rendezvous edges for every ring collective (a member's k-th
//!    collective completes only after *every* member has reached its
//!    own k-th). A Kahn pass proves the whole world executable; on
//!    failure the *minimal cycle* is reported as a rank/op chain. This
//!    subsumes the per-rank
//!    [`ScheduleProgram::check_inorder_executable`].
//! 4. **static peak memory.** A live-range walk of each rank's ops
//!    (checkpoints stashed between fwd/bwd, in-flight channel payload
//!    buffers, the working set while compute runs) on top of the
//!    resident state/buffer terms of
//!    [`crate::costmodel::MemoryBreakdown`], checked against the
//!    device budget — see [`MemoryModel`].
//!
//! The verifier is wired in three places: the `repro verify` CLI, the
//! planner's candidate filter (statically-invalid plans are rejected
//! before simulation; structural verdicts are memoised in
//! [`crate::planner::LoweringCache`]), and a debug assertion in
//! `trainer::prepare` before any worker launches.
//!
//! dp/tp replicas are byte-identical by construction, so for a
//! *generated* world every degree beyond 2 adds only symmetric copies
//! of existing constraints; [`verify_structural`] exploits that by
//! clamping both axes to ≤ 2, keeping planner-scale verification
//! O(stages · ops) regardless of the data-parallel degree. Mutation
//! tooling ([`WorldModel::remove_op`], [`WorldModel::swap_ops`], a
//! per-rank wire table) exists precisely so tests can build the
//! *asymmetric* worlds the reduction assumes away.

mod memory;
mod world;

use std::fmt;

use crate::collective::{Rank, Topology};
use crate::schedule::ScheduleProgram;
use crate::sim::WireBytes;

pub use memory::MemoryModel;
pub use world::{RankProgram, WorldModel};

/// Render a rank's grid coordinates for diagnostics.
fn fmt_rank(r: &Rank) -> String {
    format!("rank(stage {}, dp {}, tp {})", r.stage, r.dp, r.tp)
}

/// One whole-world verification failure. Every variant names the
/// offending rank(s) and op(s) — the diagnostics are the point: a
/// mismatched collective at compile time beats a thousand-GPU hang at
/// step 40k.
#[derive(Debug, Clone)]
pub enum WorldError {
    /// The program cannot be composed over the requested topology at
    /// all (stage-count mismatch, tp grid without `TensorAllReduce`
    /// ops, dp grid without `ReduceGrad` coverage).
    Topology { detail: String },
    /// A FIFO-paired send/receive disagrees on identity, or one side of
    /// a channel has more messages than the other.
    P2p { from: Rank, to: Rank, index: usize, detail: String },
    /// Sender and receiver price the same message differently — the
    /// static form of the worker's payload length check.
    Payload { from: Rank, to: Rank, op: String, sent_elems: f64, expected_elems: f64 },
    /// Two members of a dp/tp ring diverge in their collective
    /// sequences at `index`.
    Collective { axis: &'static str, a: Rank, b: Rank, index: usize, got: String, want: String },
    /// The cross-rank wait-for graph has a cycle; `cycle` is the
    /// minimal one found, as `rank: op@position` entries in order.
    Deadlock { cycle: Vec<String> },
    /// A rank's statically-bounded peak memory exceeds the device
    /// budget, first reached at op `at`.
    Memory { rank: Rank, op: String, at: usize, peak_bytes: f64, budget_bytes: f64 },
}

impl fmt::Display for WorldError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            WorldError::Topology { detail } => write!(f, "topology mismatch: {detail}"),
            WorldError::P2p { from, to, index, detail } => write!(
                f,
                "p2p mismatch on channel {} -> {} at message {index}: {detail}",
                fmt_rank(from),
                fmt_rank(to)
            ),
            WorldError::Payload { from, to, op, sent_elems, expected_elems } => write!(
                f,
                "payload mismatch for {op} from {} to {}: sender puts {sent_elems} \
                 elements on the wire, receiver expects {expected_elems}",
                fmt_rank(from),
                fmt_rank(to)
            ),
            WorldError::Collective { axis, a, b, index, got, want } => write!(
                f,
                "{axis} collective sequences diverge at index {index}: {} issues {got}, \
                 {} issues {want}",
                fmt_rank(b),
                fmt_rank(a)
            ),
            WorldError::Deadlock { cycle } => {
                write!(f, "cross-rank deadlock, minimal wait-for cycle: ")?;
                for (i, n) in cycle.iter().enumerate() {
                    write!(f, "{}{n}", if i == 0 { "" } else { " -> " })?;
                }
                Ok(())
            }
            WorldError::Memory { rank, op, at, peak_bytes, budget_bytes } => write!(
                f,
                "{} exceeds the device budget: static peak {:.3e} B > {:.3e} B, first \
                 reached at op {op} (position {at})",
                fmt_rank(rank),
                peak_bytes,
                budget_bytes
            ),
        }
    }
}

/// Compose `program` over `topo` and run every check. `mem = None`
/// skips the memory bound (structural checks only — e.g. when no
/// device budget is in scope). Returns all failures, not just the
/// first.
pub fn verify_program(
    program: &ScheduleProgram,
    topo: Topology,
    wire: WireBytes,
    mem: Option<&MemoryModel>,
) -> Result<(), Vec<WorldError>> {
    let world = WorldModel::compose(program, topo, wire).map_err(|e| vec![e])?;
    let errors = world.verify(mem);
    if errors.is_empty() {
        Ok(())
    } else {
        Err(errors)
    }
}

/// Structural verification only (p2p, congruence, deadlock) with the
/// replicated axes clamped to degree ≤ 2: dp/tp replicas of a lowered
/// program are identical, so higher degrees only add symmetric copies
/// of constraints already checked — this is what makes the planner
/// filter and the trainer's pre-launch assertion O(stages · ops).
/// Returns the first failure.
pub fn verify_structural(program: &ScheduleProgram, topo: Topology) -> Result<(), WorldError> {
    let reduced = Topology::new(topo.stages, topo.dp.min(2), topo.tp.min(2));
    verify_program(program, reduced, WireBytes::default(), None).map_err(|mut v| v.remove(0))
}

/// The memory bound alone, straight off a lowered program (dp/tp
/// replicas share their stage's live ranges, so one pass per stage
/// covers the world). Used by the planner's candidate filter, where
/// the structural verdict is memoised but the budget depends on the
/// per-candidate cost table.
pub fn check_program_memory(
    program: &ScheduleProgram,
    model: &MemoryModel,
) -> Result<(), WorldError> {
    for stage in 0..program.n_stages {
        let ops: Vec<crate::schedule::Op> =
            program.stage_ops(stage).iter().map(|n| n.op).collect();
        let (peak, at) = memory::rank_peak(&ops, model);
        if peak > model.budget {
            return Err(WorldError::Memory {
                rank: Rank { stage, dp: 0, tp: 0 },
                op: ops.get(at).map(|o| o.to_string()).unwrap_or_default(),
                at,
                peak_bytes: peak,
                budget_bytes: model.budget,
            });
        }
    }
    Ok(())
}
