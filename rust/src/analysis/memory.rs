//! Static per-rank peak-memory bound (check 4).
//!
//! A live-range walk over one rank's op sequence: checkpoints are
//! acquired by `Fwd` and released by the matching `Bwd`, channel
//! payloads are live while a transfer op runs, and the compute working
//! set is live while a compute op runs. The walk is deliberately a
//! *bound*, not a simulation: at every op the footprint is
//!
//! ```text
//! state + stashed·checkpoint + max(payload, live)
//! ```
//!
//! taking the *max* (not the sum) of the transfer and compute terms.
//! That makes the bound provably no larger than the analytic
//! [`MemoryBreakdown`] total for any generated schedule — the stash
//! high-water mark is exactly the analytic checkpoints term, and both
//! `payload` and `live` are individually covered by the activations
//! term — so the planner's static filter can never reject a candidate
//! the analytic memory filter admitted (planner parity by
//! construction), while still catching hand-mutated or pathological
//! worlds that stash more than the generators ever would.
//!
//! [`MemoryBreakdown`]: crate::costmodel::MemoryBreakdown

use crate::costmodel::{KvCacheModel, MemoryBreakdown};
use crate::schedule::Op;
use crate::sim::CostTable;

/// Byte coefficients for the live-range walk, plus the device budget.
/// Built from the same [`CostTable`] / [`MemoryBreakdown`] pair the
/// planner already evaluates, so the static bound and the analytic
/// model price one world identically.
#[derive(Debug, Clone, Copy)]
pub struct MemoryModel {
    /// Device budget the peak is checked against
    /// (`cluster.gpu.memory_bytes`).
    pub budget: f64,
    /// Always-resident bytes: fp16 params + Adam state (zero when
    /// offloaded to CPU, mirroring
    /// [`MemoryBreakdown::gpu_resident`]) plus gradient/transfer
    /// buffers.
    pub state_bytes: f64,
    /// Bytes stashed per outstanding activation checkpoint (zero when
    /// offloaded — the stash lives in CPU memory).
    pub checkpoint_bytes: f64,
    /// In-flight channel payload bytes while a send/recv runs.
    pub payload_bytes: f64,
    /// Working-set bytes while a compute op runs.
    pub live_bytes: f64,
}

impl MemoryModel {
    pub fn new(costs: &CostTable, mem: &MemoryBreakdown, budget: f64, offload: bool) -> Self {
        MemoryModel {
            budget,
            state_bytes: (if offload { 0.0 } else { mem.state }) + mem.buffers,
            checkpoint_bytes: if offload { 0.0 } else { costs.checkpoint_bytes },
            payload_bytes: costs.wire.send_act,
            live_bytes: costs.live_activation_bytes,
        }
    }

    /// KV-aware model for a forward-only serving program. The walk's
    /// stash term *is* the KV cache: every `Fwd` appends
    /// `tokens_per_fwd` tokens' K/V for one layer (the whole prompt in
    /// a prefill program, one token in a decode wave) and — with no
    /// `Bwd` to release it — the stash grows monotonically, exactly
    /// like the cache of an in-flight request. Cache already resident
    /// when the program starts (`in_flight` requests at `context`
    /// tokens, zero for a cold prefill) rides in the state term beside
    /// the weights, so the verified peak is the residency at the *end*
    /// of the program plus the transient compute/transfer terms.
    pub fn serving(
        kv: &KvCacheModel,
        costs: &CostTable,
        in_flight: usize,
        context: usize,
        tokens_per_fwd: usize,
    ) -> Self {
        MemoryModel {
            budget: kv.budget,
            state_bytes: kv.residency(in_flight, context),
            checkpoint_bytes: tokens_per_fwd as f64 * kv.bytes_per_token_layer,
            payload_bytes: costs.wire.send_act,
            live_bytes: costs.live_activation_bytes,
        }
    }
}

/// Walk one rank's ops and return `(peak bytes, position of the op
/// where the peak is first reached)`.
pub(crate) fn rank_peak(ops: &[Op], model: &MemoryModel) -> (f64, usize) {
    let mut stashed: f64 = 0.0;
    let mut peak = model.state_bytes;
    let mut at = 0usize;
    for (pos, op) in ops.iter().enumerate() {
        // Acquire before measuring: a Fwd's checkpoint is written while
        // the op runs.
        if matches!(op, Op::Fwd { .. }) {
            stashed += 1.0;
        }
        let extra = match op {
            Op::Fwd { .. } | Op::Bwd { .. } | Op::TensorAllReduce { .. } => model.live_bytes,
            Op::SendAct { .. } | Op::RecvAct { .. } | Op::SendGrad { .. } | Op::RecvGrad { .. } => {
                model.payload_bytes
            }
            _ => 0.0,
        };
        let cur = model.state_bytes + stashed * model.checkpoint_bytes + extra;
        if cur > peak {
            peak = cur;
            at = pos;
        }
        // Release after measuring: the Bwd consumes (and frees) its
        // layer's checkpoint, but needs it resident to run.
        if matches!(op, Op::Bwd { .. }) {
            stashed = (stashed - 1.0).max(0.0);
        }
    }
    (peak, at)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model(state: f64, ckpt: f64, payload: f64, live: f64) -> MemoryModel {
        MemoryModel {
            budget: f64::INFINITY,
            state_bytes: state,
            checkpoint_bytes: ckpt,
            payload_bytes: payload,
            live_bytes: live,
        }
    }

    #[test]
    fn peak_counts_outstanding_checkpoints() {
        // Two fwd stashes outstanding when the second Fwd runs.
        let ops = [
            Op::Fwd { layer: 0, mb: 0 },
            Op::Fwd { layer: 0, mb: 1 },
            Op::Bwd { layer: 0, mb: 1 },
            Op::Bwd { layer: 0, mb: 0 },
        ];
        let (peak, at) = rank_peak(&ops, &model(10.0, 4.0, 0.0, 1.0));
        assert_eq!(peak, 10.0 + 2.0 * 4.0 + 1.0);
        assert_eq!(at, 1);
    }

    #[test]
    fn transfer_and_compute_terms_take_the_max_not_the_sum() {
        let ops = [Op::Fwd { layer: 0, mb: 0 }, Op::SendAct { layer: 0, mb: 0 }];
        // payload > live: the send sets the peak even with one stash out.
        let (peak, at) = rank_peak(&ops, &model(0.0, 1.0, 7.0, 2.0));
        assert_eq!(peak, 1.0 + 7.0);
        assert_eq!(at, 1);
    }

    #[test]
    fn serving_walk_peak_is_the_final_kv_residency() {
        use crate::costmodel::KvCacheModel;
        use crate::model::XModel;
        use crate::runtime::DType;
        use crate::schedule::{decode_waves, lower, prefill_pipeline, ScheduleSpec};

        let shape = XModel::new(8).shape();
        let kv = KvCacheModel::new(&shape, 2, 1, DType::F32, f64::INFINITY);
        let spec = ScheduleSpec {
            d_l: shape.d_l,
            n_l: 2,
            n_mu: 3, // in-flight requests
            tp: 1,
            partition: false,
            offload: false,
            data_parallel: false,
            zero: 0,
        };

        // Prefill: cold cache, each Fwd appends a whole 16-token prompt.
        let mut m = MemoryModel::serving(&kv, &costs_inf(), 3, 0, 16);
        m.payload_bytes = 0.0;
        m.live_bytes = 0.0;
        let p = lower(&prefill_pipeline(&spec)).unwrap();
        let ops: Vec<Op> = p.stage_ops(0).iter().map(|n| n.op).collect();
        let (peak, _) = rank_peak(&ops, &m);
        assert!((peak - kv.residency(3, 16)).abs() < 1e-6, "prefill peak {peak}");

        // Decode: 3 requests already at 16 tokens, 2 more waves.
        let mut m = MemoryModel::serving(&kv, &costs_inf(), 3, 16, 1);
        m.payload_bytes = 0.0;
        m.live_bytes = 0.0;
        let d = lower(&decode_waves(&spec, 2)).unwrap();
        let ops: Vec<Op> = d.stage_ops(0).iter().map(|n| n.op).collect();
        let (peak, _) = rank_peak(&ops, &m);
        assert!((peak - kv.residency(3, 18)).abs() < 1e-6, "decode peak {peak}");
    }

    /// A cost table only used for its payload/live fields, which the
    /// serving walk tests zero out anyway.
    fn costs_inf() -> CostTable {
        use crate::costmodel::{Strategy, TrainConfig};
        use crate::hardware::ClusterSpec;
        use crate::model::XModel;
        let cfg = TrainConfig {
            strategy: Strategy::Improved,
            n_b: 1,
            n_l: 2,
            n_a: 1,
            n_mu: 1,
            b_mu: 1.0,
            offload: false,
            partition: false,
            zero: 0,
        };
        CostTable::new(&XModel::new(8).shape(), &cfg, &ClusterSpec::reference())
    }

    #[test]
    fn bwd_frees_its_checkpoint_after_running() {
        let ops = [
            Op::Fwd { layer: 0, mb: 0 },
            Op::Bwd { layer: 0, mb: 0 },
            Op::Fwd { layer: 0, mb: 1 },
        ];
        let (peak, _) = rank_peak(&ops, &model(0.0, 4.0, 0.0, 1.0));
        // Never two checkpoints at once.
        assert_eq!(peak, 4.0 + 1.0);
    }
}
