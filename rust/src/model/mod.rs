//! Model shape arithmetic: the transformer cost primitives (Appendix C.1)
//! and the X_[x] scaling family (Appendix B).

pub mod family;
pub mod transformer;

pub use family::{sweep_xs, XModel, TRAINING_STEPS};
pub use transformer::TransformerShape;
