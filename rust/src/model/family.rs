//! The X_[x] model family (paper Appendix B, eq. 1) and the empirical
//! critical-batch-size law (eq. 2).
//!
//! The family is parametrised by a single integer x:
//!   d_a = x/2, d_h = 2x, d_l = x, d_s = 16x, d_m = x², d_I = 4x².
//! Closed forms: p = 12x⁵ + 13x³ and b_c = 82.0 x^(2/3).

use super::transformer::TransformerShape;

/// A member of the X_[x] family.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct XModel {
    /// The family parameter x (must be even so that d_a = x/2 is integral).
    pub x: usize,
}

impl XModel {
    /// Construct X_[x]. Panics if `x` is odd or zero.
    pub fn new(x: usize) -> Self {
        assert!(x >= 2 && x % 2 == 0, "X_[x] requires even x >= 2, got {x}");
        XModel { x }
    }

    /// The trillion-parameter example model of §6 (1.26 T parameters).
    pub fn x160() -> Self {
        Self::new(160)
    }

    /// Transformer shape per eq. 1.
    pub fn shape(&self) -> TransformerShape {
        TransformerShape {
            d_l: self.x,
            d_a: self.x / 2,
            d_h: 2 * self.x,
            d_s: 16 * self.x,
            n_i: 4,
        }
    }

    /// Parameter count (exact; equals 12x⁵ + 13x³).
    pub fn params(&self) -> f64 {
        self.shape().params()
    }

    /// Critical batch size in sequences, b_c ≈ 82.0 x^(2/3) (eq. 2).
    pub fn critical_batch_size(&self) -> f64 {
        82.0 * (self.x as f64).powf(2.0 / 3.0)
    }

    /// Critical batch size in tokens: 573 p^(1/3) (eq. 2, first form).
    pub fn critical_batch_tokens(&self) -> f64 {
        self.critical_batch_size() * (16 * self.x) as f64
    }

    /// Total training flops for the paper's standard 100k-step run at
    /// batch size `b` (§6: 6.24e24 flops for X_160 at b = b_c).
    pub fn training_flops(&self, b: f64, steps: f64) -> f64 {
        self.shape().batch_flops(b) * steps
    }
}

/// Standard number of training steps assumed throughout the paper (§6).
pub const TRAINING_STEPS: f64 = 100_000.0;

/// Sweep helper: the even x values used in the scaling figures,
/// log-spaced from X_2 (488 params) past the quadrillion scale.
pub fn sweep_xs(max_x: usize) -> Vec<usize> {
    let mut xs = Vec::new();
    let mut x = 2usize;
    while x <= max_x {
        xs.push(x);
        // ~1.25x log spacing, snapped to even.
        let next = ((x as f64 * 1.26).ceil() as usize + 1) & !1usize;
        x = next.max(x + 2);
    }
    xs
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table_b1_parameter_counts() {
        // (x, p) rows from Table B.1.
        let rows = [
            (2, 488.0),
            (32, 403e6),
            (64, 12.9e9),
            (108, 176e9),
            (160, 1.26e12),
        ];
        for (x, p) in rows {
            let got = XModel::new(x).params();
            assert!((got / p - 1.0).abs() < 0.005, "X_{x}: got {got:.4e}, want {p:.4e}");
        }
    }

    #[test]
    fn table_b1_critical_batch_sizes() {
        let rows = [(2, 130.0), (32, 826.0), (64, 1310.0), (108, 1860.0), (160, 2420.0)];
        for (x, bc) in rows {
            let got = XModel::new(x).critical_batch_size();
            assert!((got / bc - 1.0).abs() < 0.005, "X_{x}: got {got:.1}, want {bc}");
        }
    }

    #[test]
    fn x160_shape_matches_section_6() {
        let s = XModel::x160().shape();
        assert_eq!(s.d_l, 160);
        assert_eq!(s.d_a, 80);
        assert_eq!(s.d_h, 320);
        assert_eq!(s.d_m(), 25_600);
        assert_eq!(s.d_s, 2560);
    }

    #[test]
    fn x160_training_flops() {
        // §6: training X_160 for 100k steps at b_c ≈ 2420 requires
        // 6.24e24 flops.
        let m = XModel::x160();
        let flops = m.training_flops(m.critical_batch_size(), TRAINING_STEPS);
        assert!((flops / 6.24e24 - 1.0).abs() < 0.01, "{flops:.4e}");
    }

    #[test]
    fn sweep_is_monotone_even() {
        let xs = sweep_xs(2000);
        assert!(xs.windows(2).all(|w| w[0] < w[1]));
        assert!(xs.iter().all(|x| x % 2 == 0));
        assert!(xs.len() > 20);
    }

    #[test]
    #[should_panic]
    fn odd_x_panics() {
        XModel::new(3);
    }
}
