//! Transformer shape arithmetic (paper §5 "Model" and Appendix C.1).
//!
//! The paper analyses a transformer encoder of `d_l` identical layers,
//! each a multi-head attention module (d_a heads of size d_h, width
//! d_m = d_a * d_h) followed by a two-layer feed-forward network with
//! intermediate size d_I = n_I * d_m. The embedding layer and LM head are
//! excluded from the parameter counts, as in the paper.

use crate::hardware::Bytes;

/// Shape of a transformer encoder/decoder stack.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct TransformerShape {
    /// Number of layers, d_l.
    pub d_l: usize,
    /// Attention heads per layer, d_a.
    pub d_a: usize,
    /// Head size, d_h.
    pub d_h: usize,
    /// Sequence length, d_s.
    pub d_s: usize,
    /// FFN intermediate expansion factor, n_I (d_I = n_I * d_m).
    pub n_i: usize,
}

impl TransformerShape {
    /// Layer width d_m = d_a * d_h.
    pub fn d_m(&self) -> usize {
        self.d_a * self.d_h
    }

    /// FFN intermediate size d_I = n_I * d_m.
    pub fn d_i(&self) -> usize {
        self.n_i * self.d_m()
    }

    /// Parameters in one layer: the paper's leading term
    /// p_l ≈ (4 + 2 n_I) d_m², plus the attention score path correction
    /// that makes the X_[x] closed form 12x⁵ + 13x³ (Table B.1): the
    /// sub-leading 13x³ term corresponds to per-layer biases and
    /// layer-norm parameters, ≈ 13 d_m x / x² per layer. We count the
    /// exact dense weights + biases + layernorms:
    ///   QKV: 3 d_m² + 3 d_m ; proj: d_m² + d_m ;
    ///   FFN: 2 n_I d_m² + (n_I + 1) d_m ; 2 layernorms: 4 d_m.
    pub fn params_per_layer(&self) -> f64 {
        let d_m = self.d_m() as f64;
        let n_i = self.n_i as f64;
        (4.0 + 2.0 * n_i) * d_m * d_m + (n_i + 9.0) * d_m
    }

    /// Total parameters p = d_l * p_l (embedding/LM head excluded).
    pub fn params(&self) -> f64 {
        self.d_l as f64 * self.params_per_layer()
    }

    /// Forward-pass flops for `tokens` input tokens: 2 flops per token
    /// per parameter (Appendix C.1; attention-score matmuls neglected).
    pub fn fwd_flops(&self, tokens: f64) -> f64 {
        2.0 * tokens * self.params()
    }

    /// Flops for one full batch of size `b` with activation
    /// recomputation: 8 b d_s p (Appendix C.1 — 2 forward, 2+2 backward,
    /// 2 recompute).
    pub fn batch_flops(&self, b: f64) -> f64 {
        8.0 * b * self.d_s as f64 * self.params()
    }

    /// Per-token activation footprint of a single layer (activations plus
    /// their gradients, half precision), bytes — the paper's `m₀`
    /// (Appendix C.3, symbol defined but value elided in the text).
    ///
    /// Counting fp16 values alive between two activation checkpoints:
    /// attention input (1 d_m), QKV (3), scores + softmax
    /// (2 · d_a d_s / d_m), context (1), proj out (1), residual+LN (2),
    /// FFN in (1), intermediate + GELU (2 n_I), FFN out (1) ≈
    /// (10 + 2 n_I + 2 d_a d_s / d_m) values at 2 bytes each, times a
    /// 1.5 peak factor for the concurrently-live gradients during the
    /// backward pass (gradients of consumed activations are freed as the
    /// backward proceeds, so the peak is ~half the activation set, not
    /// all of it). For the X_[x] family (d_a d_s / d_m = 8, n_I = 4) this
    /// gives m₀ = 102 d_m bytes/token — the value that reproduces
    /// Table 6.2's activation column exactly (e.g. 24.9 GiB for the
    /// X_160 single-GPU baseline with b_μ = 4).
    pub fn m0_bytes_per_token(&self) -> Bytes {
        let d_m = self.d_m() as f64;
        let score = 2.0 * (self.d_a * self.d_s) as f64 / d_m;
        let values = 10.0 + 2.0 * self.n_i as f64 + score;
        1.5 * 2.0 * values * d_m
    }

    /// Bytes of one activation checkpoint for `b` sequences: the layer
    /// output, 2 b d_s d_m (fp16). Per rank this is *independent of the
    /// tensor-parallel degree* — every tp rank holds the checkpoint in
    /// full (the boundary all-reduce completes it before it is stored);
    /// tp shards the live intermediates instead
    /// ([`Self::m0_bytes_per_token_shard`]).
    pub fn checkpoint_bytes(&self, b: f64) -> Bytes {
        2.0 * b * (self.d_s * self.d_m()) as f64
    }

    // --- tensor-parallel shard arithmetic -------------------------------
    //
    // With Megatron-style column/row-parallel execution a tp rank owns
    // 1/tp of every weight matrix (heads for attention, the d_I axis for
    // the FFN) while the layernorm parameters and post-reduce biases
    // stay replicated. These closed forms are the planner/bench-side
    // mirror of the runtime's `ShardedLayout` — exact for the 12-tensor
    // layer layout, not leading-order approximations.

    /// Per-rank parameters of one layer at shard degree `tp`: the
    /// (4 + 2 n_I) d_m² matrix block and the sharded biases
    /// ((n_I + 3) d_m: b_qkv + b1) divide by tp; the replicated
    /// layernorms and post-reduce biases (6 d_m) do not.
    pub fn params_per_layer_shard(&self, tp: usize) -> f64 {
        let d_m = self.d_m() as f64;
        let n_i = self.n_i as f64;
        ((4.0 + 2.0 * n_i) * d_m * d_m + (n_i + 3.0) * d_m) / tp as f64 + 6.0 * d_m
    }

    /// Per-rank, per-token live activation bytes at shard degree `tp`
    /// (the sharded m₀). The layer-boundary tensors a rank materialises
    /// in full — the attention input, the two residual sums and the
    /// reduced block outputs, ≈ 6 values — stay whole; the head-sharded
    /// and column-parallel intermediates (QKV, scores/softmax, context,
    /// the FFN intermediate pair) divide by tp. tp = 1 reduces to
    /// [`Self::m0_bytes_per_token`] exactly.
    pub fn m0_bytes_per_token_shard(&self, tp: usize) -> Bytes {
        let d_m = self.d_m() as f64;
        let score = 2.0 * (self.d_a * self.d_s) as f64 / d_m;
        let full = 6.0;
        let sharded = 4.0 + 2.0 * self.n_i as f64 + score;
        1.5 * 2.0 * (full + sharded / tp as f64) * d_m
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn bertish() -> TransformerShape {
        TransformerShape { d_l: 24, d_a: 16, d_h: 64, d_s: 512, n_i: 4 }
    }

    #[test]
    fn bert_large_param_count() {
        // BERT-large encoder stack ≈ 302M parameters (Table B.1: 301 M).
        let p = bertish().params();
        assert!((p / 301e6 - 1.0).abs() < 0.01, "p = {p:.3e}");
    }

    #[test]
    fn batch_flops_is_four_times_forward() {
        let s = bertish();
        let b = 32.0;
        let fwd = s.fwd_flops(b * s.d_s as f64);
        assert!((s.batch_flops(b) / fwd - 4.0).abs() < 1e-12);
    }

    #[test]
    fn shard_arithmetic_recovers_the_full_layer_at_tp1() {
        let s = bertish();
        assert!((s.params_per_layer_shard(1) - s.params_per_layer()).abs() < 1e-6);
        assert!((s.m0_bytes_per_token_shard(1) - s.m0_bytes_per_token()).abs() < 1e-6);
    }

    #[test]
    fn shard_params_scale_inversely_with_tp_up_to_replication() {
        let s = bertish();
        let d_m = s.d_m() as f64;
        for tp in [2usize, 4, 8] {
            let shard = s.params_per_layer_shard(tp);
            // Matrix-dominated: per-rank ≈ full/tp, exactly full/tp plus
            // the replicated 6·d_m·(1 − 1/tp).
            let want = s.params_per_layer() / tp as f64 + 6.0 * d_m * (1.0 - 1.0 / tp as f64);
            assert!((shard - want).abs() < 1e-6, "tp={tp}: {shard} vs {want}");
            // All ranks together hold slightly more than one copy.
            let total = shard * tp as f64;
            assert!(total > s.params_per_layer() && total < s.params_per_layer() * 1.001);
        }
    }

    #[test]
    fn shard_m0_keeps_boundary_tensors_full() {
        let s = bertish();
        let m2 = s.m0_bytes_per_token_shard(2);
        let m1 = s.m0_bytes_per_token();
        // Strictly less than full, strictly more than half (the layer
        // boundaries stay whole).
        assert!(m2 < m1 && m2 > m1 / 2.0, "{m2} vs {m1}");
    }

    #[test]
    fn m0_closed_form_for_family_ratios() {
        // For shapes with d_a d_s = 8 d_m and n_I = 4, m₀ = 102 d_m.
        let s = TransformerShape { d_l: 160, d_a: 80, d_h: 320, d_s: 2560, n_i: 4 };
        assert_eq!(s.d_m(), 25_600);
        assert!((s.m0_bytes_per_token() - 102.0 * 25_600.0).abs() < 1e-6);
    }
}
