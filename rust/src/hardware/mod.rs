//! Hardware model: devices, links and cluster topology (paper Appendix A).

pub mod gpu;
pub mod network;
pub mod topology;

pub use gpu::{Bytes, Flops, GpuSpec, GB, GIB, SECS_PER_DAY};
pub use network::{InterNode, LinkKind, NetCalibration};
pub use topology::ClusterSpec;
