//! GPU device specification (paper Appendix A).
//!
//! The paper's reference device is the NVIDIA A100 80 GB: 312 Tflop/s peak
//! fp16 compute, 80 GB HBM at 2039 GB/s. All cost-model results are
//! expressed relative to this device; other devices can be described with
//! the same struct (used by the ablation benches).

/// Floating-point operations per second (flop/s).
pub type Flops = f64;
/// Bytes (we keep everything in f64 — the cost model works with continuous
/// quantities, and the largest values exceed u64-safe integer arithmetic
/// conveniences anyway).
pub type Bytes = f64;

/// One gibibyte. The paper quotes device memory in "GB" but all of its
/// derived numbers are binary: the Table 6.2 memory rows are GiB (12p/483
/// bytes for X_160 = 29.1 GiB exactly), and the Table A.1 arithmetic
/// intensity thresholds divide 312 Tflop/s by the quoted "GB/s" scaled by
/// 2^30 (312e12 / (50 * 2^30) = 5.81k flops/B for InfiniBand, as printed).
/// We follow the same convention so tables match digit-for-digit.
pub const GIB: f64 = 1024.0 * 1024.0 * 1024.0;
/// One decimal gigabyte.
pub const GB: f64 = 1e9;
/// Seconds per day, for training-time reporting.
pub const SECS_PER_DAY: f64 = 86_400.0;

/// A single accelerator device.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GpuSpec {
    /// Peak half-precision compute, flop/s.
    pub peak_flops: Flops,
    /// Device memory capacity, bytes.
    pub memory_bytes: Bytes,
    /// Device memory bandwidth, bytes/s (input + output).
    pub memory_bandwidth: f64,
}

impl GpuSpec {
    /// The paper's reference device: NVIDIA A100 80 GB (Appendix A).
    /// Bandwidths are stored in the paper's GiB-scaled convention (see
    /// [`GIB`]) so that intensity thresholds reproduce Table A.1.
    pub const fn a100_80gb() -> Self {
        GpuSpec {
            peak_flops: 312e12,
            memory_bytes: 80.0 * GIB,
            memory_bandwidth: 2039.0 * GIB,
        }
    }

    /// A100 40 GB variant (ablations).
    pub const fn a100_40gb() -> Self {
        GpuSpec { memory_bytes: 40.0 * GIB, ..Self::a100_80gb() }
    }

    /// V100 16 GB (ablations; 125 Tflop/s tensor-core fp16, 900 GB/s HBM2).
    pub const fn v100_16gb() -> Self {
        GpuSpec { peak_flops: 125e12, memory_bytes: 16.0 * GIB, memory_bandwidth: 900.0 * GIB }
    }

    /// Arithmetic-intensity threshold (flops/byte) of the device memory
    /// itself — Table A.1 first row: 143 flops/B for the A100.
    pub fn hbm_intensity_threshold(&self) -> f64 {
        self.peak_flops / self.memory_bandwidth
    }

    /// Arithmetic-intensity threshold implied by an external link of the
    /// given bandwidth (bytes/s): compute/transfer ratio above which a
    /// perfectly-overlapped transfer is hidden by compute (§2.3).
    pub fn intensity_threshold(&self, link_bandwidth: f64) -> f64 {
        self.peak_flops / link_bandwidth
    }
}

impl Default for GpuSpec {
    fn default() -> Self {
        Self::a100_80gb()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn a100_hbm_threshold_matches_table_a1() {
        // Table A.1: GPU memory row — 143 flops/B.
        let g = GpuSpec::a100_80gb();
        assert!((g.hbm_intensity_threshold() - 142.5).abs() < 1.0);
    }

    #[test]
    fn intensity_threshold_scales_inversely_with_bandwidth() {
        let g = GpuSpec::a100_80gb();
        let t1 = g.intensity_threshold(50e9);
        let t2 = g.intensity_threshold(25e9);
        assert!((t2 / t1 - 2.0).abs() < 1e-12);
    }
}
