//! Interconnect and storage link specifications (paper Table A.1),
//! plus measured-wire calibration.
//!
//! Each link is described by its input+output bandwidth. Bandwidths are
//! stored in the paper's GiB-scaled convention (see [`super::gpu::GIB`])
//! so that the derived arithmetic-intensity thresholds reproduce the
//! printed table exactly.
//!
//! Quoted numbers are spec sheets; [`NetCalibration`] carries what
//! `repro netbench` actually measured (`BENCH_net_calibration.json`:
//! sustained framed bandwidth and round-trip latency of the socket
//! transport). Attached to a `ClusterSpec` it overrides the quoted
//! inter-node figures, so the simulator and planner price wire ops
//! from reality instead of the table — the [`LinkKind`] table itself
//! stays untouched (it *is* the paper's Table A.1).

use super::gpu::{GpuSpec, GIB};
use crate::runtime::Json;

/// The kinds of link that appear in the paper's analysis.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LinkKind {
    /// On-device HBM (2039 GB/s on the A100).
    GpuMemory,
    /// NVLink / NVSwitch intra-node fabric (600 GB/s per A100).
    NvLink,
    /// PCI-express 4.0 x16 (63 GB/s), shared between CPU and NIC traffic.
    PciExpress,
    /// 200 Gb/s InfiniBand NIC (50 GB/s in+out per GPU).
    InfiniBand,
    /// Effective CPU<->GPU path on an HGX node (31.5 GB/s — half of PCIe
    /// because one x16 link serves two GPUs plus two NICs, Appendix A).
    CpuGpu,
    /// 25 Gb/s-per-GPU Ethernet (§8.3; 400 Gb/s per 16-GPU node).
    Ethernet,
    /// NVMe SSD (3.2 GB/s).
    DiskNvme,
    /// Spinning hard drive (0.1 GB/s).
    DiskHdd,
}

impl LinkKind {
    /// All kinds, in Table A.1 order.
    pub const ALL: [LinkKind; 8] = [
        LinkKind::GpuMemory,
        LinkKind::NvLink,
        LinkKind::PciExpress,
        LinkKind::InfiniBand,
        LinkKind::CpuGpu,
        LinkKind::Ethernet,
        LinkKind::DiskNvme,
        LinkKind::DiskHdd,
    ];

    /// Bandwidth quoted in the paper, "GB/s" (input + output).
    pub fn quoted_gb_per_s(self) -> f64 {
        match self {
            LinkKind::GpuMemory => 2039.0,
            LinkKind::NvLink => 600.0,
            LinkKind::PciExpress => 63.0,
            LinkKind::InfiniBand => 50.0,
            LinkKind::CpuGpu => 31.5,
            LinkKind::Ethernet => 6.25,
            LinkKind::DiskNvme => 3.2,
            LinkKind::DiskHdd => 0.1,
        }
    }

    /// Bandwidth in bytes/s under the paper's GiB-scaled convention.
    pub fn bandwidth(self) -> f64 {
        self.quoted_gb_per_s() * GIB
    }

    /// Human-readable name, as printed in Table A.1.
    pub fn name(self) -> &'static str {
        match self {
            LinkKind::GpuMemory => "GPU memory",
            LinkKind::NvLink => "NVLINK",
            LinkKind::PciExpress => "PCI-express",
            LinkKind::InfiniBand => "InfiniBand (200 Gb/s)",
            LinkKind::CpuGpu => "CPU-GPU",
            LinkKind::Ethernet => "Ethernet (25 Gb/s)",
            LinkKind::DiskNvme => "Disk (NVMe)",
            LinkKind::DiskHdd => "Disk (Hard drive)",
        }
    }

    /// Arithmetic-intensity threshold of this link w.r.t. a device
    /// (Table A.1 right column): compute/byte ratio above which a
    /// perfectly-overlapped transfer over this link is hidden.
    pub fn intensity_threshold(self, gpu: &GpuSpec) -> f64 {
        gpu.peak_flops / self.bandwidth()
    }
}

/// The inter-node link used for data-parallel / pipeline-parallel traffic.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum InterNode {
    InfiniBand,
    Ethernet,
}

impl InterNode {
    pub fn link(self) -> LinkKind {
        match self {
            InterNode::InfiniBand => LinkKind::InfiniBand,
            InterNode::Ethernet => LinkKind::Ethernet,
        }
    }
}

/// Measured inter-node link parameters, as written by `repro netbench`
/// into `BENCH_net_calibration.json`. Attach to a cluster with
/// `ClusterSpec::with_calibration` to price wire ops from measurement.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct NetCalibration {
    /// Sustained framed socket bandwidth, bytes/s.
    pub bandwidth_bytes_per_s: f64,
    /// Small-frame round-trip time, seconds (one-way latency = half).
    pub rtt_secs: f64,
}

impl NetCalibration {
    /// Parse a `BENCH_net_calibration.json` document (the `BenchJson`
    /// shape: `{"bench": ..., "metrics": {...}}`).
    pub fn from_json(text: &str) -> anyhow::Result<Self> {
        let doc = Json::parse(text)?;
        let metrics = doc.req("metrics")?;
        let num = |key: &str| -> anyhow::Result<f64> {
            metrics
                .req(key)?
                .as_f64()
                .ok_or_else(|| anyhow::anyhow!("calibration key '{key}' is not a number"))
        };
        let cal = NetCalibration {
            bandwidth_bytes_per_s: num("bandwidth_bytes_per_s")?,
            rtt_secs: num("rtt_secs")?,
        };
        anyhow::ensure!(
            cal.bandwidth_bytes_per_s > 0.0 && cal.rtt_secs >= 0.0,
            "calibration out of range: bandwidth {} B/s, rtt {} s",
            cal.bandwidth_bytes_per_s,
            cal.rtt_secs
        );
        Ok(cal)
    }

    /// Load from a calibration file on disk.
    pub fn load(path: impl AsRef<std::path::Path>) -> anyhow::Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading calibration {}: {e}", path.display()))?;
        Self::from_json(&text)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn calibration_parses_the_bench_json_shape() {
        let text = r#"{
  "bench": "net_calibration",
  "metrics": {
    "rtt_secs": 0.000125,
    "bandwidth_bytes_per_s": 2500000000,
    "ring_allreduce_bytes_per_s": 1200000000,
    "payload_bytes": 4194304,
    "wall_secs": 1.5
  }
}"#;
        let c = NetCalibration::from_json(text).unwrap();
        assert_eq!(c.rtt_secs, 0.000125);
        assert_eq!(c.bandwidth_bytes_per_s, 2.5e9);
    }

    #[test]
    fn calibration_rejects_missing_or_non_positive_values() {
        assert!(NetCalibration::from_json("{}").is_err());
        assert!(NetCalibration::from_json(r#"{"metrics": {"rtt_secs": 1e-4}}"#).is_err());
        let zero = r#"{"metrics": {"rtt_secs": 1e-4, "bandwidth_bytes_per_s": 0}}"#;
        assert!(NetCalibration::from_json(zero).is_err());
    }

    #[test]
    fn table_a1_intensity_thresholds() {
        // Paper Table A.1, right column (flops/B @ 312 Tflop/s).
        let gpu = GpuSpec::a100_80gb();
        let expect = [
            (LinkKind::GpuMemory, 143.0, 0.01),
            (LinkKind::NvLink, 484.0, 0.01),
            (LinkKind::PciExpress, 4.61e3, 0.01),
            (LinkKind::InfiniBand, 5.81e3, 0.01),
            (LinkKind::CpuGpu, 9.22e3, 0.01),
            (LinkKind::Ethernet, 46.5e3, 0.01),
            (LinkKind::DiskNvme, 90.8e3, 0.01),
            (LinkKind::DiskHdd, 2.91e6, 0.01),
        ];
        for (kind, want, tol) in expect {
            let got = kind.intensity_threshold(&gpu);
            assert!(
                (got / want - 1.0).abs() < tol,
                "{}: got {got:.4e}, want {want:.4e}",
                kind.name()
            );
        }
    }

    #[test]
    fn all_kinds_have_distinct_bandwidths() {
        for (i, a) in LinkKind::ALL.iter().enumerate() {
            for b in &LinkKind::ALL[i + 1..] {
                assert_ne!(a.bandwidth(), b.bandwidth());
            }
        }
    }
}
