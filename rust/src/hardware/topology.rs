//! Cluster topology description (paper Appendix A).
//!
//! The reference cluster is built from 16-GPU DGX/HGX-style A100 nodes:
//! GPUs inside a node are fully connected through NVSwitch; nodes connect
//! through InfiniBand (one 200 Gb/s NIC effectively usable per GPU) or
//! 25 Gb/s-per-GPU Ethernet (§8.3). The CPU<->GPU path shares the PCIe
//! link with the NIC, which creates the offload bottleneck analysed in
//! Appendix C.5.

use super::gpu::GpuSpec;
use super::network::{InterNode, LinkKind};

/// Static description of the cluster a training job runs on.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClusterSpec {
    /// Per-device specification.
    pub gpu: GpuSpec,
    /// Maximum GPUs per NVLink island (16 for DGX/HGX; `usize::MAX` for the
    /// Figure 5 "no node-size limit" scenario).
    pub max_node_size: usize,
    /// Inter-node fabric used for data/pipeline-parallel traffic.
    pub inter_node: InterNode,
    /// CPU memory available per GPU for offloading, bytes. The paper
    /// assumes "a large amount"; 2 TB/node / 16 GPUs by default.
    pub cpu_memory_per_gpu: f64,
    /// Whether CPU-GPU offload traffic shares PCIe with the NIC
    /// (true for the HGX reference design, Appendix A).
    pub pcie_shared_with_nic: bool,
}

impl ClusterSpec {
    /// The paper's reference cluster: 16-GPU A100 nodes over InfiniBand.
    pub const fn reference() -> Self {
        ClusterSpec {
            gpu: GpuSpec::a100_80gb(),
            max_node_size: 16,
            inter_node: InterNode::InfiniBand,
            cpu_memory_per_gpu: 128.0e9,
            pcie_shared_with_nic: true,
        }
    }

    /// Figure 5 scenario: node-size limit removed (ring NVLink topology).
    pub const fn unlimited_node() -> Self {
        ClusterSpec { max_node_size: usize::MAX, ..Self::reference() }
    }

    /// §8.3 scenario: 25 Gb/s-per-GPU Ethernet instead of InfiniBand.
    pub const fn ethernet() -> Self {
        ClusterSpec { inter_node: InterNode::Ethernet, ..Self::reference() }
    }

    /// The link carrying data-parallel gradient traffic. Tensor parallelism
    /// always stays on NVLink (when it fits in a node); data and pipeline
    /// parallel cross nodes.
    pub fn inter_node_link(&self) -> LinkKind {
        self.inter_node.link()
    }

    /// The intensity threshold for the inter-node link.
    pub fn inter_node_threshold(&self) -> f64 {
        self.inter_node_link().intensity_threshold(&self.gpu)
    }

    /// Tensor-parallel link for a given tensor-parallel degree: NVLink
    /// while the group fits in a node, the inter-node fabric otherwise
    /// (the §7 "extreme scale" scenario).
    pub fn tensor_parallel_link(&self, n_a: usize) -> LinkKind {
        if n_a <= self.max_node_size {
            LinkKind::NvLink
        } else {
            self.inter_node_link()
        }
    }
}

impl Default for ClusterSpec {
    fn default() -> Self {
        Self::reference()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_uses_nvlink_for_small_tp_groups() {
        let c = ClusterSpec::reference();
        assert_eq!(c.tensor_parallel_link(16), LinkKind::NvLink);
        assert_eq!(c.tensor_parallel_link(32), LinkKind::InfiniBand);
    }

    #[test]
    fn unlimited_node_keeps_nvlink() {
        let c = ClusterSpec::unlimited_node();
        assert_eq!(c.tensor_parallel_link(1024), LinkKind::NvLink);
    }

    #[test]
    fn ethernet_threshold_is_higher_than_ib() {
        let eth = ClusterSpec::ethernet();
        let ib = ClusterSpec::reference();
        assert!(eth.inter_node_threshold() > ib.inter_node_threshold());
    }
}
