//! Cluster topology description (paper Appendix A).
//!
//! The reference cluster is built from 16-GPU DGX/HGX-style A100 nodes:
//! GPUs inside a node are fully connected through NVSwitch; nodes connect
//! through InfiniBand (one 200 Gb/s NIC effectively usable per GPU) or
//! 25 Gb/s-per-GPU Ethernet (§8.3). The CPU<->GPU path shares the PCIe
//! link with the NIC, which creates the offload bottleneck analysed in
//! Appendix C.5.

use super::gpu::GpuSpec;
use super::network::{InterNode, LinkKind, NetCalibration};

/// Static description of the cluster a training job runs on.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ClusterSpec {
    /// Per-device specification.
    pub gpu: GpuSpec,
    /// Maximum GPUs per NVLink island (16 for DGX/HGX; `usize::MAX` for the
    /// Figure 5 "no node-size limit" scenario).
    pub max_node_size: usize,
    /// Inter-node fabric used for data/pipeline-parallel traffic.
    pub inter_node: InterNode,
    /// CPU memory available per GPU for offloading, bytes. The paper
    /// assumes "a large amount"; 2 TB/node / 16 GPUs by default.
    pub cpu_memory_per_gpu: f64,
    /// Whether CPU-GPU offload traffic shares PCIe with the NIC
    /// (true for the HGX reference design, Appendix A).
    pub pcie_shared_with_nic: bool,
    /// Measured inter-node link override (`repro netbench`). `None`
    /// prices wire ops from the quoted Table A.1 figures with zero
    /// latency — the paper's idealised model; `Some` substitutes the
    /// measured bandwidth and half-RTT latency everywhere the
    /// inter-node fabric is consulted.
    pub calibration: Option<NetCalibration>,
}

impl ClusterSpec {
    /// The paper's reference cluster: 16-GPU A100 nodes over InfiniBand.
    pub const fn reference() -> Self {
        ClusterSpec {
            gpu: GpuSpec::a100_80gb(),
            max_node_size: 16,
            inter_node: InterNode::InfiniBand,
            cpu_memory_per_gpu: 128.0e9,
            pcie_shared_with_nic: true,
            calibration: None,
        }
    }

    /// This cluster with measured link parameters attached.
    pub fn with_calibration(mut self, cal: NetCalibration) -> Self {
        self.calibration = Some(cal);
        self
    }

    /// Figure 5 scenario: node-size limit removed (ring NVLink topology).
    pub const fn unlimited_node() -> Self {
        ClusterSpec { max_node_size: usize::MAX, ..Self::reference() }
    }

    /// §8.3 scenario: 25 Gb/s-per-GPU Ethernet instead of InfiniBand.
    pub const fn ethernet() -> Self {
        ClusterSpec { inter_node: InterNode::Ethernet, ..Self::reference() }
    }

    /// The link carrying data-parallel gradient traffic. Tensor parallelism
    /// always stays on NVLink (when it fits in a node); data and pipeline
    /// parallel cross nodes.
    pub fn inter_node_link(&self) -> LinkKind {
        self.inter_node.link()
    }

    /// Effective inter-node bandwidth, bytes/s: the measured figure when
    /// calibrated, the quoted Table A.1 figure otherwise.
    pub fn inter_node_bandwidth(&self) -> f64 {
        match self.calibration {
            Some(c) => c.bandwidth_bytes_per_s,
            None => self.inter_node_link().bandwidth(),
        }
    }

    /// One-way inter-node message latency, seconds: half the measured
    /// RTT when calibrated, zero otherwise (the paper's idealised
    /// bandwidth-only wire model).
    pub fn inter_node_latency(&self) -> f64 {
        self.calibration.map_or(0.0, |c| 0.5 * c.rtt_secs)
    }

    /// The intensity threshold for the inter-node link (calibration-
    /// aware: a slower measured wire raises the threshold).
    pub fn inter_node_threshold(&self) -> f64 {
        self.gpu.peak_flops / self.inter_node_bandwidth()
    }

    /// Tensor-parallel link for a given tensor-parallel degree: NVLink
    /// while the group fits in a node, the inter-node fabric otherwise
    /// (the §7 "extreme scale" scenario).
    pub fn tensor_parallel_link(&self, n_a: usize) -> LinkKind {
        if n_a <= self.max_node_size {
            LinkKind::NvLink
        } else {
            self.inter_node_link()
        }
    }

    /// Effective tensor-parallel bandwidth: quoted NVLink inside a
    /// node, the (possibly calibrated) inter-node figure beyond it.
    pub fn tensor_parallel_bandwidth(&self, n_a: usize) -> f64 {
        if n_a <= self.max_node_size {
            LinkKind::NvLink.bandwidth()
        } else {
            self.inter_node_bandwidth()
        }
    }

    /// Calibration-aware intensity threshold of the tensor-parallel
    /// fabric at degree `n_a`.
    pub fn tensor_parallel_threshold(&self, n_a: usize) -> f64 {
        self.gpu.peak_flops / self.tensor_parallel_bandwidth(n_a)
    }
}

impl Default for ClusterSpec {
    fn default() -> Self {
        Self::reference()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn reference_uses_nvlink_for_small_tp_groups() {
        let c = ClusterSpec::reference();
        assert_eq!(c.tensor_parallel_link(16), LinkKind::NvLink);
        assert_eq!(c.tensor_parallel_link(32), LinkKind::InfiniBand);
    }

    #[test]
    fn unlimited_node_keeps_nvlink() {
        let c = ClusterSpec::unlimited_node();
        assert_eq!(c.tensor_parallel_link(1024), LinkKind::NvLink);
    }

    #[test]
    fn ethernet_threshold_is_higher_than_ib() {
        let eth = ClusterSpec::ethernet();
        let ib = ClusterSpec::reference();
        assert!(eth.inter_node_threshold() > ib.inter_node_threshold());
    }

    #[test]
    fn calibration_overrides_the_quoted_inter_node_figures() {
        let quoted = ClusterSpec::reference();
        let cal = NetCalibration {
            bandwidth_bytes_per_s: quoted.inter_node_bandwidth() / 4.0,
            rtt_secs: 2.0e-4,
        };
        let measured = quoted.with_calibration(cal);
        // Uncalibrated: quoted bandwidth, zero latency.
        assert_eq!(quoted.inter_node_bandwidth(), LinkKind::InfiniBand.bandwidth());
        assert_eq!(quoted.inter_node_latency(), 0.0);
        // Calibrated: measured bandwidth, half-RTT latency, 4× threshold.
        assert_eq!(measured.inter_node_bandwidth(), cal.bandwidth_bytes_per_s);
        assert_eq!(measured.inter_node_latency(), 1.0e-4);
        let ratio = measured.inter_node_threshold() / quoted.inter_node_threshold();
        assert!((ratio - 4.0).abs() < 1e-9);
        // In-node tensor parallelism stays on quoted NVLink; beyond the
        // node it picks up the calibrated fabric.
        assert_eq!(measured.tensor_parallel_bandwidth(16), LinkKind::NvLink.bandwidth());
        assert_eq!(measured.tensor_parallel_bandwidth(32), cal.bandwidth_bytes_per_s);
    }
}
