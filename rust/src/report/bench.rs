//! Machine-readable bench artifacts.
//!
//! Every root bench (`benches/*.rs`) prints a human-readable report *and*
//! writes a `BENCH_<name>.json` next to it (working directory — the
//! workspace root under `cargo bench` — or `BENCH_JSON_DIR` when set),
//! so the perf trajectory can be tracked across PRs by diffing small
//! JSON files instead of scraping stdout.
//!
//! The format is deliberately tiny — a flat string→number metric map —
//! and the writer is dependency-free (no serde in this crate).
//!
//! The artifacts are *meant to be committed*: after a perf-relevant
//! change, re-run the benches and include the refreshed `BENCH_*.json`
//! files in the PR so the numbers diff alongside the code.

use std::io;
use std::path::PathBuf;
use std::time::Instant;

/// A flat metric report for one bench run. Construction starts a
/// wall-clock; [`BenchJson::finish`] records it as `wall_secs`, so no
/// bench can forget the one metric the cross-PR diffing relies on.
#[derive(Debug, Clone)]
pub struct BenchJson {
    name: String,
    started: Instant,
    metrics: Vec<(String, f64)>,
}

impl BenchJson {
    pub fn new(name: &str) -> Self {
        BenchJson { name: name.to_string(), started: Instant::now(), metrics: Vec::new() }
    }

    /// Record one metric. Keys are free-form (dots conventionally
    /// namespace repeated shapes, e.g. `"mops.modular_128L"`); insertion
    /// order is preserved in the output.
    pub fn push(&mut self, key: &str, value: f64) {
        self.metrics.push((key.to_string(), value));
    }

    /// Serialise to JSON. Non-finite values (a failed or skipped
    /// measurement) become `null`, keeping the document valid.
    pub fn to_json(&self) -> String {
        let mut out = String::from("{\n");
        out.push_str(&format!("  \"bench\": \"{}\",\n", escape(&self.name)));
        out.push_str("  \"metrics\": {");
        for (i, (key, value)) in self.metrics.iter().enumerate() {
            out.push_str(if i == 0 { "\n" } else { ",\n" });
            if value.is_finite() {
                out.push_str(&format!("    \"{}\": {}", escape(key), value));
            } else {
                out.push_str(&format!("    \"{}\": null", escape(key)));
            }
        }
        out.push_str("\n  }\n}\n");
        out
    }

    /// Write `BENCH_<name>.json` and return its path.
    pub fn write(&self) -> io::Result<PathBuf> {
        let dir = std::env::var("BENCH_JSON_DIR").unwrap_or_else(|_| ".".into());
        let path = PathBuf::from(dir).join(format!("BENCH_{}.json", self.name));
        std::fs::write(&path, self.to_json())?;
        Ok(path)
    }

    /// Append the run's wall-clock seconds, write, then report where (or
    /// why not) on stdout — the uniform trailer every bench ends with.
    pub fn finish(&mut self) {
        self.push("wall_secs", self.started.elapsed().as_secs_f64());
        match self.write() {
            Ok(path) => println!("\n[bench-json] wrote {}", path.display()),
            Err(e) => println!("\n[bench-json] could not write BENCH_{}.json: {e}", self.name),
        }
    }
}

fn escape(s: &str) -> String {
    s.replace('\\', "\\\\").replace('"', "\\\"")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn json_is_well_formed() {
        let mut j = BenchJson::new("demo");
        j.push("ops_per_sec", 1.5e6);
        j.push("makespan_secs", 0.25);
        j.push("skipped", f64::NAN);
        let s = j.to_json();
        assert!(s.contains("\"bench\": \"demo\""));
        assert!(s.contains("\"ops_per_sec\": 1500000"));
        assert!(s.contains("\"skipped\": null"));
        // Balanced braces, trailing newline, no trailing comma.
        assert_eq!(s.matches('{').count(), s.matches('}').count());
        assert!(s.ends_with("}\n"));
        assert!(!s.contains(",\n  }"));
    }

    #[test]
    fn empty_metrics_render() {
        let s = BenchJson::new("empty").to_json();
        assert!(s.contains("\"metrics\": {"));
        assert_eq!(s.matches('{').count(), 2);
    }

    #[test]
    fn keys_are_escaped() {
        let mut j = BenchJson::new("quo\"te");
        j.push("a\"b", 1.0);
        let s = j.to_json();
        assert!(s.contains("quo\\\"te"));
        assert!(s.contains("a\\\"b"));
    }
}
