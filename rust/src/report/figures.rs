//! Figure data series (4, 5, 6, 7, 8) and a small ASCII line plot.

use crate::costmodel::{ParallelismMenu, Strategy, TrainConfig};
use crate::hardware::{ClusterSpec, GIB, SECS_PER_DAY};
use crate::model::{sweep_xs, XModel, TRAINING_STEPS};
use crate::offload::figure7_point;
use crate::planner::{par_map, search_fastest};

/// One sweep series: (x, value) points.
pub type Series = Vec<(usize, f64)>;

/// Figures 4/5/8: training time (days) and memory (GiB, gpu-resident)
/// vs model scale for the three strategies on a cluster.
pub struct ScalingFigure {
    pub cluster_name: String,
    pub time_days: Vec<(Strategy, Series)>,
    pub memory_gib: Vec<(Strategy, Series)>,
}

/// Menu used in the scaling figures: the fastest available for each
/// strategy (3d for baseline/improved, data+tensor for partitioned).
/// Public so the planner parity tests and `benches/planner_search.rs`
/// sweep exactly the configurations the figures run.
pub fn menu_for(strategy: Strategy) -> ParallelismMenu {
    match strategy {
        Strategy::Partitioned => ParallelismMenu::DATA_TENSOR,
        _ => ParallelismMenu::THREE_D,
    }
}

/// Build a scaling figure (Figure 4 with the reference cluster, Figure 5
/// with `unlimited_node`, Figure 8 with `ethernet`). Every
/// (strategy, x) search is independent, so the whole sweep fans out over
/// the planner's worker threads; the output order is deterministic.
pub fn scaling_figure(cluster: &ClusterSpec, name: &str, max_x: usize) -> ScalingFigure {
    let xs = sweep_xs(max_x);
    let tasks: Vec<(Strategy, usize)> = Strategy::ALL
        .iter()
        .flat_map(|&s| xs.iter().map(move |&x| (s, x)))
        .collect();
    let plans = par_map(&tasks, |_, &(s, x)| {
        search_fastest(&XModel::new(x), cluster, s, menu_for(s))
    });
    let mut fig = ScalingFigure {
        cluster_name: name.to_string(),
        time_days: Vec::new(),
        memory_gib: Vec::new(),
    };
    for (si, &s) in Strategy::ALL.iter().enumerate() {
        let mut time = Vec::new();
        let mut mem = Vec::new();
        for (xi, &x) in xs.iter().enumerate() {
            if let Some(p) = &plans[si * xs.len() + xi] {
                time.push((x, p.speed.training_secs / SECS_PER_DAY));
                mem.push((x, p.memory.gpu_resident(p.cfg.offload) / GIB));
            }
        }
        fig.time_days.push((s, time));
        fig.memory_gib.push((s, mem));
    }
    fig
}

/// Figure 6: memory-to-compute ratio (bytes per flop/s) needed to train
/// in a fixed month, as a function of model size. The paper's point: the
/// ratio *decreases* with scale — there is no memory wall.
pub fn figure6(cluster: &ClusterSpec, max_x: usize) -> Series {
    let month = 30.0 * SECS_PER_DAY;
    let xs = sweep_xs(max_x);
    par_map(&xs, |_, &x| {
        let m = XModel::new(x);
        let p = search_fastest(&m, cluster, Strategy::Improved, ParallelismMenu::THREE_D)?;
        // Compute power needed to hit one month at this efficiency.
        let flops = m.training_flops(m.critical_batch_size(), TRAINING_STEPS);
        let needed_rate = flops / (month * p.speed.efficiency);
        let n_gpu_needed = needed_rate / cluster.gpu.peak_flops;
        // Memory per unit compute: per-GPU resident bytes over
        // per-GPU flops (scaled to the hypothetical cluster).
        let resident = p.memory.gpu_resident(p.cfg.offload) * p.cfg.n_gpu() as f64;
        Some((x, resident / (n_gpu_needed * cluster.gpu.peak_flops)))
    })
    .into_iter()
    .flatten()
    .collect()
}

/// Figure 7: offload arithmetic intensity vs scale for the improved
/// partitioned configuration; returns (x, state ν, checkpoint ν).
pub fn figure7(cluster: &ClusterSpec, max_x: usize) -> Vec<(usize, f64, f64)> {
    let xs = sweep_xs(max_x);
    par_map(&xs, |_, &x| {
        let m = XModel::new(x);
        let p = search_fastest(&m, cluster, Strategy::Improved, ParallelismMenu::THREE_D)?;
        let mut cfg: TrainConfig = p.cfg;
        cfg.offload = true;
        let (_, s, c) = figure7_point(x, &cfg);
        Some((x, s, c))
    })
    .into_iter()
    .flatten()
    .collect()
}

/// ASCII log-log plot of several series.
pub fn ascii_plot(series: &[(&str, &Series)], width: usize, height: usize, ylabel: &str) -> String {
    let mut pts: Vec<(f64, f64)> = Vec::new();
    for (_, s) in series {
        for &(x, y) in s.iter() {
            if y > 0.0 {
                pts.push(((x as f64).ln(), y.ln()));
            }
        }
    }
    if pts.is_empty() {
        return "(empty plot)".into();
    }
    let (x0, x1) = pts.iter().fold((f64::MAX, f64::MIN), |a, p| (a.0.min(p.0), a.1.max(p.0)));
    let (y0, y1) = pts.iter().fold((f64::MAX, f64::MIN), |a, p| (a.0.min(p.1), a.1.max(p.1)));
    let mut grid = vec![vec![' '; width]; height];
    let marks = ['B', 'P', 'I', '4', '5', '6'];
    for (si, (_, s)) in series.iter().enumerate() {
        for &(x, y) in s.iter() {
            if y <= 0.0 {
                continue;
            }
            let (lx, ly) = ((x as f64).ln(), y.ln());
            let cx = (((lx - x0) / (x1 - x0).max(1e-9)) * (width - 1) as f64) as usize;
            let cy = (((ly - y0) / (y1 - y0).max(1e-9)) * (height - 1) as f64) as usize;
            grid[height - 1 - cy][cx.min(width - 1)] = marks[si % marks.len()];
        }
    }
    let mut out = format!("{ylabel} (log-log; x = model scale parameter)\n");
    for row in grid {
        out.push('|');
        out.extend(row);
        out.push('\n');
    }
    for (si, (name, _)) in series.iter().enumerate() {
        out.push_str(&format!("  {} = {}\n", marks[si % marks.len()], name));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn figure4_improved_dominates_baseline() {
        // Figure 4's core shape: the improved method trains faster than
        // the baseline at every swept scale (node limit 16).
        let fig = scaling_figure(&ClusterSpec::reference(), "fig4", 160);
        let get = |s: Strategy| {
            fig.time_days.iter().find(|(st, _)| *st == s).map(|(_, v)| v.clone()).unwrap()
        };
        let base = get(Strategy::Baseline);
        let impr = get(Strategy::Improved);
        for ((x, tb), (x2, ti)) in base.iter().zip(&impr) {
            assert_eq!(x, x2);
            if *x < 32 {
                continue; // §9: sub-BERT scales are dominated by
                          // communication either way; the paper's claim
                          // targets BERT-scale (x = 32) and above.
            }
            assert!(
                ti <= &(tb * 1.02),
                "x={x}: improved {ti:.3} d vs baseline {tb:.3} d"
            );
        }
        // And at the trillion scale the gap is ~2x (Table 6.1).
        let tb = base.last().unwrap().1;
        let ti = impr.last().unwrap().1;
        assert!(tb / ti > 1.6, "ratio {:.2}", tb / ti);
    }

    #[test]
    fn figure5_unlimited_node_is_faster_at_scale() {
        let lim = scaling_figure(&ClusterSpec::reference(), "fig4", 160);
        let unl = scaling_figure(&ClusterSpec::unlimited_node(), "fig5", 160);
        let t = |f: &ScalingFigure| {
            f.time_days
                .iter()
                .find(|(s, _)| *s == Strategy::Improved)
                .unwrap()
                .1
                .last()
                .unwrap()
                .1
        };
        assert!(t(&unl) < t(&lim) * 0.8, "unl {} vs lim {}", t(&unl), t(&lim));
    }

    #[test]
    fn figure6_no_memory_wall() {
        // The memory/compute ratio decreases with scale (§7).
        let s = figure6(&ClusterSpec::reference(), 320);
        assert!(s.len() >= 6);
        let first = s[2].1; // skip tiny models where buffers dominate oddly
        let last = s.last().unwrap().1;
        assert!(
            last < first,
            "ratio should fall: {first:.3e} -> {last:.3e} ({s:?})"
        );
    }

    #[test]
    fn figure7_state_offloadable_to_slower_tiers_at_scale() {
        use crate::hardware::LinkKind;
        let pts = figure7(&ClusterSpec::reference(), 160);
        let gpu = ClusterSpec::reference().gpu;
        let hdd = LinkKind::DiskHdd.intensity_threshold(&gpu);
        let (_, s_last, _) = pts.last().unwrap();
        assert!(*s_last > hdd, "trillion-scale state streams to HDD");
    }

    #[test]
    fn ascii_plot_renders() {
        let s1: Series = vec![(2, 1.0), (16, 10.0), (160, 100.0)];
        let p = ascii_plot(&[("demo", &s1)], 40, 10, "time");
        assert!(p.contains('B'));
        assert!(p.lines().count() >= 11);
    }
}
