//! Render the paper's tables from the cost model / planner, row-for-row,
//! plus a measured schedule-policy comparison driven by the simulator.

use crate::costmodel::{estimate, MemoryBreakdown, ParallelismMenu, Strategy, TrainConfig};
use crate::hardware::{ClusterSpec, GpuSpec, LinkKind, GIB, SECS_PER_DAY};
use crate::model::XModel;
use crate::planner::{fastest_plan, min_gpu_plan, Plan};
use crate::schedule::{
    interleaved_1f1b, interleaved_applicable, lower, modular_pipeline, one_f_one_b, standard_ga,
    Schedule, ScheduleSpec,
};
use crate::sim::{simulate_program, CostTable};

/// The nine (strategy, menu) rows of Tables 6.1/6.2, in paper order.
pub fn table61_rows() -> Vec<(Strategy, ParallelismMenu)> {
    use ParallelismMenu as M;
    use Strategy as S;
    vec![
        (S::Baseline, M::NONE),
        (S::Baseline, M::DATA),
        (S::Partitioned, M::DATA),
        (S::Baseline, M::DATA_PIPE),
        (S::Improved, M::DATA_PIPE),
        (S::Baseline, M::DATA_TENSOR),
        (S::Partitioned, M::DATA_TENSOR),
        (S::Baseline, M::THREE_D),
        (S::Improved, M::THREE_D),
    ]
}

fn fmt_time(secs: f64) -> String {
    let days = secs / SECS_PER_DAY;
    if days > 365.25 {
        format!("{:.1} y", days / 365.25)
    } else {
        format!("{:.1} d", days)
    }
}

fn fmt_gib(bytes: f64) -> String {
    let g = bytes / GIB;
    if g >= 1000.0 {
        format!("{:.1} K", g / 1024.0)
    } else if g >= 10.0 {
        format!("{:.1}", g)
    } else {
        format!("{:.3}", g)
    }
}

/// Table 6.1: fastest training configuration per strategy for X_160.
pub fn table61(model: &XModel, cluster: &ClusterSpec) -> String {
    let mut out = String::from(
        "Table 6.1: fastest training configurations\n\
         Parallelism     Method       Off b      b_mu n_mu  n_gpu  n_b  n_l n_a  Eff   Time\n",
    );
    for (s, m) in table61_rows() {
        let Some(p) = fastest_plan(model, cluster, s, m) else { continue };
        let c = p.cfg;
        out.push_str(&format!(
            "{:<15} {:<12} {:<3} {:<6} {:<4} {:<5} {:<6} {:<4} {:<3} {:<4} {:.2}  {}\n",
            m.name(),
            s.name(),
            if c.offload { "Y" } else { "n" },
            c.batch_size() as u64,
            c.b_mu as u64,
            c.n_mu,
            c.n_gpu(),
            c.n_b,
            c.n_l,
            c.n_a,
            p.speed.efficiency,
            fmt_time(p.speed.training_secs),
        ));
    }
    out
}

/// Table 6.2: memory usage breakdown for the same configurations (GiB).
pub fn table62(model: &XModel, cluster: &ClusterSpec) -> String {
    let mut out = String::from(
        "Table 6.2: memory usage breakdown (GiB)\n\
         Parallelism     Method       State    Ckpt     Buffers  Acts     Offl     Non-offl\n",
    );
    for (s, m) in table61_rows() {
        let Some(p) = fastest_plan(model, cluster, s, m) else { continue };
        let mem = p.memory;
        out.push_str(&format!(
            "{:<15} {:<12} {:<8} {:<8} {:<8} {:<8} {:<8} {:<8}\n",
            m.name(),
            s.name(),
            fmt_gib(mem.state),
            fmt_gib(mem.checkpoints),
            fmt_gib(mem.buffers),
            fmt_gib(mem.activations),
            fmt_gib(mem.offloadable()),
            fmt_gib(mem.non_offloadable()),
        ));
    }
    out
}

/// Table 6.3: minimum-cluster configurations for time budgets.
pub fn table63(model: &XModel, cluster: &ClusterSpec) -> String {
    use ParallelismMenu as M;
    use Strategy as S;
    let mut out = String::from(
        "Table 6.3: time-budgeted configurations\n\
         Budget  Parallelism     Method       b      n_a  n_gpu  Offl     Non-offl Eff   Time\n",
    );
    for (days, rows) in [
        (33.0, vec![
            (S::Partitioned, M::DATA_TENSOR),
            (S::Baseline, M::THREE_D),
            (S::Improved, M::THREE_D),
        ]),
        (181.0, vec![
            (S::Partitioned, M::DATA_TENSOR),
            (S::Baseline, M::PIPE_TENSOR),
            (S::Improved, M::THREE_D),
            (S::Improved, M::DATA_PIPE),
        ]),
    ] {
        for (s, m) in rows {
            let Some(cp) = min_gpu_plan(model, cluster, s, m, days * SECS_PER_DAY) else {
                out.push_str(&format!(
                    "{:<7} {:<15} {:<12} infeasible\n",
                    days,
                    m.name(),
                    s.name()
                ));
                continue;
            };
            let p = &cp.plan;
            let c = p.cfg;
            out.push_str(&format!(
                "{:<7} {:<15} {:<12} {:<6} {:<4} {:<6} {:<8} {:<8} {:.2}  {}\n",
                days,
                m.name(),
                s.name(),
                c.batch_size() as u64,
                c.n_a,
                c.n_gpu(),
                fmt_gib(p.memory.offloadable()),
                fmt_gib(p.memory.non_offloadable()),
                p.speed.efficiency,
                fmt_time(p.speed.training_secs),
            ));
        }
    }
    out
}

/// Table A.1: link bandwidths and intensity thresholds.
pub fn table_a1(gpu: &GpuSpec) -> String {
    let mut out = String::from(
        "Table A.1: bandwidth and arithmetic intensity (A100, 312 Tflop/s)\n\
         Network                   GB/s     flops/B\n",
    );
    for kind in LinkKind::ALL {
        out.push_str(&format!(
            "{:<25} {:<8} {:.3e}\n",
            kind.name(),
            kind.quoted_gb_per_s(),
            kind.intensity_threshold(gpu),
        ));
    }
    out
}

/// Table B.1: X_[x] configuration examples.
pub fn table_b1() -> String {
    let mut out = String::from(
        "Table B.1: X_[x] model family\n\
         Model   p          b_c    d_s    d_a  d_h  d_m    d_l\n",
    );
    for x in [2usize, 32, 64, 108, 160, 250] {
        let m = XModel::new(x);
        let s = m.shape();
        out.push_str(&format!(
            "X_{:<5} {:<10.3e} {:<6.0} {:<6} {:<4} {:<4} {:<6} {}\n",
            x,
            m.params(),
            m.critical_batch_size(),
            s.d_s,
            s.d_a,
            s.d_h,
            s.d_m(),
            s.d_l,
        ));
    }
    out
}

/// Measured comparison of every pipeline scheduling policy at one shape:
/// each schedule is lowered to its dependency graph once and executed by
/// the discrete-event simulator. Covers the paper's modular pipeline,
/// the GPipe-style contiguous baseline, 1F1B and Megatron-LM's
/// interleaved 1F1B (the §4 comparison). With `tp > 1` every schedule
/// carries the per-layer `TensorAllReduce` ops, so the table shows the
/// tp trade-off the paper's C.4.3 amortisation argument is about.
///
/// The `comm` column is the per-stage-batch wire volume (all transfer
/// ops priced by the cost model's fp16 byte accounting), so tp vs
/// non-tp runs are comparable at a glance. The final `wire@f32` column
/// re-expresses the same op counts as runtime bytes-on-wire (payload
/// elements × 4-byte f32, the trainer's dtype) — the figure a real
/// `repro launch` run reports in its `TrainReport`, assertable against
/// measured socket traffic.
pub fn schedule_comparison(
    x: usize,
    d_l: usize,
    n_l: usize,
    n_mu: usize,
    tp: usize,
    cluster: &ClusterSpec,
) -> String {
    let spec = ScheduleSpec {
        d_l,
        n_l,
        n_mu,
        tp,
        partition: false,
        offload: false,
        data_parallel: true,
        zero: 0,
    };
    let cfg = TrainConfig {
        strategy: Strategy::Baseline,
        n_b: 8,
        n_l,
        n_a: tp,
        n_mu,
        b_mu: 1.0,
        offload: false,
        partition: false,
        zero: 0,
    };
    let costs = CostTable::new(&XModel::new(x).shape(), &cfg, cluster);
    let mut schedules: Vec<Schedule> =
        vec![standard_ga(&spec), one_f_one_b(&spec), modular_pipeline(&spec)];
    // Interleaved needs divisible shapes; include it whenever they fit.
    if interleaved_applicable(&spec, 2) {
        schedules.insert(2, interleaved_1f1b(&spec, 2));
    }
    let mut out = format!(
        "Schedule comparison (d_l={d_l}, n_l={n_l}, n_mu={n_mu}, tp={tp}, X_{x} layers)\n\
         {:<20} {:>3} {:>7} {:>8} {:>10} {:>8} {:>10} {:>10} {:>10}\n",
        "policy", "tp", "ops", "edges", "makespan", "bubble", "net tail", "comm", "wire@f32"
    );
    for s in &schedules {
        let p = lower(s).expect("generated schedules lower");
        let r = simulate_program(&p, &costs);
        // Total wire bytes the program moves (per data-parallel
        // instance per batch), from the op counts × the cost model's
        // per-op payloads — cheap, no simulation needed.
        let comm_bytes: f64 = p.ops.iter().map(|n| costs.wire_bytes(&n.op)).sum();
        // The same payloads in runtime elements × the trainer's f32
        // width: what the socket transport actually puts on the wire.
        let wire_f32_bytes: f64 = p
            .ops
            .iter()
            .map(|n| costs.wire_elements(&n.op) * crate::runtime::DType::F32.bytes() as f64)
            .sum();
        out.push_str(&format!(
            "{:<20} {:>3} {:>7} {:>8} {:>8.2}ms {:>8.3} {:>8.2}ms {:>7.2}MiB {:>7.2}MiB\n",
            p.name,
            p.tp,
            p.len(),
            p.n_edges(),
            r.makespan * 1e3,
            r.bubble_fraction(),
            r.exposed_network_tail() * 1e3,
            comm_bytes / (1u64 << 20) as f64,
            wire_f32_bytes / (1u64 << 20) as f64,
        ));
    }
    out
}

/// §8.2 real-time checkpoint report for a finished (or simulated)
/// training run: what streamed to the store and what a crash costs,
/// against classic interval checkpointing.
pub fn checkpoint_summary(
    steps: usize,
    records: u64,
    bytes: u64,
    classic_interval: f64,
) -> String {
    let per_step = if steps > 0 { bytes as f64 / steps as f64 } else { 0.0 };
    let realtime = crate::offload::expected_loss_batches(true, classic_interval);
    let classic = crate::offload::expected_loss_batches(false, classic_interval);
    format!(
        "real-time checkpoints (§8.2)\n  \
         {records} records / {:.2} MiB streamed over {steps} steps ({:.2} MiB per step)\n  \
         crash loss window: {realtime:.0} batch (vs {classic:.0} expected at a classic \
         every-{classic_interval:.0}-batch checkpoint)\n  \
         every batch is a durable restore point: resume (even at a different n_b) \
         re-slices the stored shards",
        bytes as f64 / (1u64 << 20) as f64,
        per_step / (1u64 << 20) as f64,
    )
}

/// One fully-described row (used by `repro explain` and the benches).
pub fn explain(model: &XModel, cluster: &ClusterSpec, cfg: &TrainConfig) -> String {
    let shape = model.shape();
    let sp = estimate(model, cfg, cluster);
    let mem = MemoryBreakdown::evaluate(&shape, cfg);
    format!(
        "config: {:?}\n  b = {}, n_gpu = {}\n  overheads: bubble {:.4}, dp {:.4}, pp {:.4}, tp {:.4}, offload {:.4}, pcie {:.4}\n  efficiency {:.3}, training {}\n  memory: state {} + ckpt {} + buffers {} + acts {} GiB (gpu-resident {})\n",
        cfg,
        cfg.batch_size(),
        cfg.n_gpu(),
        sp.overheads.bubble,
        sp.overheads.data_parallel,
        sp.overheads.pipeline_parallel,
        sp.overheads.tensor_parallel,
        sp.overheads.offload,
        sp.overheads.pcie_contention,
        sp.efficiency,
        fmt_time(sp.training_secs),
        fmt_gib(mem.state),
        fmt_gib(mem.checkpoints),
        fmt_gib(mem.buffers),
        fmt_gib(mem.activations),
        fmt_gib(mem.gpu_resident(cfg.offload)),
    )
}

/// All plans for the figure sweeps: (x, plan) per strategy. The
/// per-model searches are independent and fan out over the planner's
/// worker threads; output order follows `xs`.
pub fn sweep(
    cluster: &ClusterSpec,
    strategy: Strategy,
    menu: ParallelismMenu,
    xs: &[usize],
) -> Vec<(usize, Option<Plan>)> {
    let plans = crate::planner::par_map(xs, |_, &x| {
        crate::planner::search_fastest(&XModel::new(x), cluster, strategy, menu)
    });
    xs.iter().copied().zip(plans).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tables_render_without_panicking() {
        let m = XModel::x160();
        let c = ClusterSpec::reference();
        for t in [table61(&m, &c), table62(&m, &c), table_a1(&c.gpu), table_b1()] {
            assert!(t.lines().count() >= 5, "{t}");
        }
    }

    #[test]
    fn schedule_comparison_covers_all_policies() {
        let t = schedule_comparison(32, 16, 4, 8, 1, &ClusterSpec::reference());
        // Match row starts, not substrings — "1f1b" must be its own row,
        // not a hit inside "interleaved-1f1b".
        for name in ["standard-pipeline", "1f1b", "interleaved-1f1b", "modular-pipeline"] {
            assert!(
                t.lines().any(|l| l.starts_with(name)),
                "missing row {name} in:\n{t}"
            );
        }
        assert!(t.contains("comm"), "comm-volume column missing:\n{t}");
        assert!(t.contains("wire@f32"), "bytes-on-wire column missing:\n{t}");
        // The tensor-parallel axis is visible per row.
        assert!(t.lines().nth(1).unwrap().contains(" tp "), "tp column missing:\n{t}");
        for name in ["standard-pipeline", "modular-pipeline"] {
            let row = t.lines().find(|l| l.starts_with(name)).unwrap();
            assert_eq!(row.split_whitespace().nth(1), Some("1"), "{row}");
        }
    }

    #[test]
    fn schedule_comparison_tp_runs_move_more_wire_volume() {
        // The tp table is the C.4.3 trade-off made visible: same
        // policies, strictly more communication per batch.
        let c = ClusterSpec::reference();
        let grab = |t: &str, name: &str| -> f64 {
            let row = t.lines().find(|l| l.starts_with(name)).unwrap().to_string();
            row.split_whitespace().last().unwrap().trim_end_matches("MiB").parse().unwrap()
        };
        let t1 = schedule_comparison(32, 16, 4, 8, 1, &c);
        let t2 = schedule_comparison(32, 16, 4, 8, 2, &c);
        for name in ["standard-pipeline", "modular-pipeline"] {
            assert!(
                grab(&t2, name) > grab(&t1, name),
                "{name}: tp=2 volume not above tp=1\n{t1}\n{t2}"
            );
        }
    }

    #[test]
    fn wire_f32_column_is_the_fp16_volume_at_runtime_width() {
        // Same op counts, different unit: the runtime moves 4-byte f32
        // where the cost model prices 2-byte fp16, so bytes-on-wire is
        // exactly double the comm column.
        let t = schedule_comparison(32, 16, 4, 8, 2, &ClusterSpec::reference());
        for name in ["standard-pipeline", "modular-pipeline"] {
            let row = t.lines().find(|l| l.starts_with(name)).unwrap();
            let mib: Vec<f64> = row
                .split_whitespace()
                .filter(|w| w.ends_with("MiB"))
                .map(|w| w.trim_end_matches("MiB").parse().unwrap())
                .collect();
            assert_eq!(mib.len(), 2, "{row}");
            assert!((mib[1] / mib[0] - 2.0).abs() < 1e-6, "{row}");
        }
    }

    #[test]
    fn checkpoint_summary_reports_stream_and_loss_window() {
        let t = checkpoint_summary(10, 50, 50 << 20, 1000.0);
        assert!(t.contains("50 records"), "{t}");
        assert!(t.contains("1 batch"), "{t}");
        assert!(t.contains("500"), "{t}"); // classic interval/2 expectation
    }

    #[test]
    fn table61_contains_headline_rows() {
        let t = table61(&XModel::x160(), &ClusterSpec::reference());
        assert!(t.contains("3d"));
        assert!(t.contains("Improved"));
        // The improved 3d row trains in under 8 days.
        let line = t.lines().last().unwrap();
        assert!(line.contains("38640"), "{line}");
    }
}
