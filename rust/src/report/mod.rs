//! Report rendering: every table and figure of the paper, regenerated
//! from the cost model, planner and offload analysis.

pub mod bench;
pub mod figures;
pub mod tables;

pub use bench::BenchJson;
pub use figures::{ascii_plot, figure6, figure7, menu_for, scaling_figure, ScalingFigure, Series};
pub use tables::{
    checkpoint_summary, explain, schedule_comparison, sweep, table61, table61_rows, table62,
    table63, table_a1, table_b1,
};
