//! # lga-mpp — Layered Gradient Accumulation & Modular Pipeline Parallelism
//!
//! A full reproduction of *"Layered gradient accumulation and modular
//! pipeline parallelism: fast and efficient training of large language
//! models"* (Lamy-Poirier, 2021).
//!
//! The crate has two halves:
//!
//! * an **analytical half** ([`model`], [`costmodel`], [`planner`],
//!   [`offload`], [`elastic`], [`report`]) that reimplements the paper's
//!   cost model and regenerates every table and figure, plus a
//!   **discrete-event simulator** ([`schedule`], [`sim`]) that validates
//!   the closed forms by executing the actual schedules against the
//!   Appendix A hardware model;
//! * an **executable half** ([`runtime`], [`collective`], [`partition`],
//!   [`optim`], [`data`], [`trainer`]) — a real multi-worker training
//!   runtime where the schedules drive numeric training of a transformer
//!   whose per-layer compute is AOT-compiled from JAX (+ Pallas kernels)
//!   to HLO and executed via PJRT, with Python never on the hot path.

pub mod collective;
pub mod costmodel;
pub mod data;
pub mod elastic;
pub mod hardware;
pub mod model;
pub mod offload;
pub mod optim;
pub mod partition;
pub mod planner;
pub mod report;
pub mod runtime;
pub mod schedule;
pub mod sim;
pub mod trainer;
