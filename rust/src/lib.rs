//! # lga-mpp — Layered Gradient Accumulation & Modular Pipeline Parallelism
//!
//! A full reproduction of *"Layered gradient accumulation and modular
//! pipeline parallelism: fast and efficient training of large language
//! models"* (Lamy-Poirier, 2021).
//!
//! The crate has two halves joined by one scheduling compiler:
//!
//! * an **analytical half** ([`model`], [`costmodel`], [`planner`],
//!   [`offload`], [`elastic`], [`serve`], [`report`]) that reimplements
//!   the paper's cost model, regenerates every table and figure, and
//!   prices the forward-only serving workload (continuous batching +
//!   SLO planning over the same compiled schedules);
//! * an **executable half** ([`runtime`], [`collective`], [`partition`],
//!   [`optim`], [`data`], [`trainer`]) — a real multi-worker training
//!   runtime where the schedules drive numeric training of a transformer
//!   whose per-layer compute is AOT-compiled from JAX (+ Pallas kernels)
//!   to HLO and executed via PJRT, with Python never on the hot path.
//!
//! ## The scheduling pipeline: generate → lower → verify → (simulate | execute)
//!
//! Scheduling policy lives in [`schedule`]: generators emit each policy
//! (standard/layered gradient accumulation × contiguous/modular pipeline,
//! plus the 1F1B and Megatron-LM interleaved-1F1B baselines) as per-stage
//! ordered op lists — pure policy, no timing. The lowering pass
//! ([`schedule::lower`]) compiles a schedule once into a
//! [`schedule::ScheduleProgram`]: a flat op arena with every data
//! dependency (activation/gradient chains, send/recv pairing,
//! restore-before-use, reduce-after-last-bwd, optim-after-reduce) as an
//! explicit edge, per-stage/per-stream run queues, and a cycle check that
//! is exactly the deadlock condition of an in-order executor.
//!
//! Four consumers share that one graph, so they cannot disagree about
//! legality:
//!
//! * the **validator** ([`schedule::validate`]) reports lowering errors;
//! * the **whole-world verifier** ([`analysis`]) composes the program
//!   over every rank of a `{stages, dp, tp}` topology and statically
//!   proves cross-rank properties no per-rank check can see: p2p
//!   send/recv matching, collective congruence across dp/tp rings,
//!   global deadlock freedom (with minimal-cycle diagnostics) and a
//!   peak-memory bound — run by the `repro verify` CLI, the planner's
//!   candidate filter, and a pre-launch debug assertion in the trainer;
//! * the **discrete-event simulator** ([`sim`]) walks the edges in
//!   O(V+E), which is what lets the planner simulate candidate
//!   configurations in the loop ([`planner::simloop`]) at
//!   trillion-parameter layer counts;
//! * the **real trainer** ([`trainer`]) dispatches each stage's run
//!   queue over PJRT, checking the same edges before every op. Workers
//!   communicate exclusively through a [`collective::CommWorld`]
//!   process-group handle — pipeline p2p, data-parallel ring,
//!   tensor-parallel ring and control plane over a pluggable
//!   [`collective::Transport`] — so all three parallelism axes
//!   (including the per-layer `TensorAllReduce` of C.4.3) run over one
//!   uniform, traffic-accounted API.
//!
//! New policies (e.g. interleaved 1F1B) are generator-only changes — the
//! graph semantics downstream are untouched.

pub mod analysis;
pub mod collective;
pub mod costmodel;
pub mod data;
pub mod elastic;
pub mod hardware;
pub mod model;
pub mod offload;
pub mod optim;
pub mod partition;
pub mod planner;
pub mod report;
pub mod runtime;
pub mod schedule;
pub mod serve;
pub mod sim;
pub mod trainer;
