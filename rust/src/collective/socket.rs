//! TCP socket backend for the [`super::transport::Transport`] trait:
//! multi-process (and multi-host) training over real wires.
//!
//! Three layers live here:
//!
//! 1. **Frame codec** — length-prefixed binary frames (`[u32 LE length]
//!    [payload]`) with a [`Wire`] trait per message type. `Vec<f32>`
//!    payloads encode zero-copy on little-endian targets (the buffer is
//!    viewed as its wire bytes, no intermediate copy); decoding uses
//!    `from_le_bytes`, so NaN and subnormal bit patterns round-trip
//!    exactly — the bit-for-bit loss-parity gate depends on this.
//! 2. **[`SocketPort`]** — one directed duplex [`Transport`] port over a
//!    pair of TCP streams (one per direction): a buffered writer toward
//!    the send-peer and a dedicated reader thread draining the
//!    recv-peer into an unbounded channel, so `send` never blocks
//!    indefinitely on a live peer (the trait contract the ring
//!    collectives rely on).
//! 3. **Rendezvous + wiring** — a rank-0-side [`Coordinator`] listener
//!    collects every worker's `Hello{rank, addr}`, broadcasts the
//!    `Peers` address table, and each rank then dials exactly the
//!    pipeline/dp/tp ring edges [`CommWorld::build`] would wire over
//!    mpsc ([`connect_world`]). Data connections self-identify with a
//!    [`DataHello`] header frame; degenerate (size-1) axes stay on
//!    in-process self-loops so their no-op/zero-traffic semantics are
//!    identical to the mpsc backend. The same control connection then
//!    carries per-step losses and end-of-run [`RankStats`] back to the
//!    coordinator.
//!
//! Multi-host: set `REPRO_HOSTMAP=host0:port0,host1:port1,...` (one
//! bindable data-listener address per rank, in [`Topology::index`]
//! order) and start one `repro worker` per rank against a reachable
//! coordinator; without it, workers bind loopback ephemeral ports and
//! the address table is discovered through the rendezvous.

use std::collections::{HashMap, VecDeque};
use std::io::{self, BufReader, BufWriter, Read, Write};
use std::marker::PhantomData;
use std::net::{Shutdown, SocketAddr, TcpListener, TcpStream};
use std::sync::mpsc::{channel, Receiver};
use std::thread;
use std::time::{Duration, Instant};

use super::ring::RingGroup;
use super::transport::{mpsc_ring, mpsc_ring_rev, Disconnected, Transport};
use super::world::{CommWorld, ControlGroup, PipeMsg, PipelineGroup, Rank, Topology};

/// Hard cap on one frame's payload (guards against a corrupt or
/// malicious length prefix allocating unbounded memory).
pub const MAX_FRAME_BYTES: u32 = 1 << 30;

// ---------------------------------------------------------------------------
// Frame codec.

/// A malformed frame payload (the transport-level length prefix was
/// fine, but the bytes don't decode as the expected message type).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FrameError(pub &'static str);

impl std::fmt::Display for FrameError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "malformed frame: {}", self.0)
    }
}

impl std::error::Error for FrameError {}

/// A message type that can cross the wire inside one length-prefixed
/// frame. `encode` must write exactly `encoded_len()` bytes; `decode`
/// must consume the whole payload (trailing bytes are an error).
pub trait Wire: Send + Sized + 'static {
    fn encoded_len(&self) -> usize;
    fn encode(&self, w: &mut impl Write) -> io::Result<()>;
    fn decode(buf: &[u8]) -> Result<Self, FrameError>;
}

/// Write one framed message: `[u32 LE payload length][payload]`.
pub fn write_frame<M: Wire>(w: &mut impl Write, msg: &M) -> io::Result<()> {
    let len = u32::try_from(msg.encoded_len())
        .ok()
        .filter(|&l| l <= MAX_FRAME_BYTES)
        .ok_or_else(|| invalid_data("frame payload exceeds the 1 GiB cap"))?;
    w.write_all(&len.to_le_bytes())?;
    msg.encode(w)
}

/// Read one frame's payload. Errors with `UnexpectedEof` on a cleanly
/// closed stream and `InvalidData` on an oversized length prefix.
pub fn read_frame(r: &mut impl Read) -> io::Result<Vec<u8>> {
    let mut hdr = [0u8; 4];
    r.read_exact(&mut hdr)?;
    let len = u32::from_le_bytes(hdr);
    if len > MAX_FRAME_BYTES {
        return Err(invalid_data(format!("frame length {len} exceeds the 1 GiB cap")));
    }
    let mut buf = vec![0u8; len as usize];
    r.read_exact(&mut buf)?;
    Ok(buf)
}

fn invalid_data(msg: impl std::fmt::Display) -> io::Error {
    io::Error::new(io::ErrorKind::InvalidData, msg.to_string())
}

#[cfg(target_endian = "little")]
fn write_f32s(w: &mut impl Write, v: &[f32]) -> io::Result<()> {
    // Zero-copy fast path: an f32 buffer *is* its little-endian wire
    // bytes on this target.
    // SAFETY: every f32 bit pattern is a valid byte sequence, the view
    // covers exactly the buffer's 4·len bytes, and u8 has no alignment
    // requirement.
    let bytes = unsafe { std::slice::from_raw_parts(v.as_ptr().cast::<u8>(), size_of_val(v)) };
    w.write_all(bytes)
}

#[cfg(not(target_endian = "little"))]
fn write_f32s(w: &mut impl Write, v: &[f32]) -> io::Result<()> {
    for x in v {
        w.write_all(&x.to_le_bytes())?;
    }
    Ok(())
}

fn read_f32s(buf: &[u8]) -> Result<Vec<f32>, FrameError> {
    if buf.len() % 4 != 0 {
        return Err(FrameError("f32 payload length not a multiple of 4"));
    }
    // `from_le_bytes` is a bit-level reinterpretation: NaN payloads and
    // subnormals survive the round-trip exactly.
    Ok(buf.chunks_exact(4).map(|c| f32::from_le_bytes([c[0], c[1], c[2], c[3]])).collect())
}

/// Bounds-checked little-endian cursor over a frame payload.
struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(buf: &'a [u8]) -> Self {
        Cursor { buf, pos: 0 }
    }

    fn take(&mut self, n: usize) -> Result<&'a [u8], FrameError> {
        let end = self
            .pos
            .checked_add(n)
            .filter(|&e| e <= self.buf.len())
            .ok_or(FrameError("frame truncated"))?;
        let s = &self.buf[self.pos..end];
        self.pos = end;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8, FrameError> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32, FrameError> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().expect("4-byte slice")))
    }

    fn u64(&mut self) -> Result<u64, FrameError> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().expect("8-byte slice")))
    }

    fn f64(&mut self) -> Result<f64, FrameError> {
        Ok(f64::from_bits(self.u64()?))
    }

    fn string(&mut self) -> Result<String, FrameError> {
        let n = self.u32()? as usize;
        String::from_utf8(self.take(n)?.to_vec()).map_err(|_| FrameError("non-utf8 string"))
    }

    fn finish(&self) -> Result<(), FrameError> {
        if self.pos == self.buf.len() {
            Ok(())
        } else {
            Err(FrameError("trailing bytes"))
        }
    }
}

fn put_u32(w: &mut impl Write, v: u32) -> io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

fn put_u64(w: &mut impl Write, v: u64) -> io::Result<()> {
    w.write_all(&v.to_le_bytes())
}

fn put_f64(w: &mut impl Write, v: f64) -> io::Result<()> {
    // Bit-exact: losses must aggregate to the same f64 the worker saw.
    put_u64(w, v.to_bits())
}

fn put_str(w: &mut impl Write, s: &str) -> io::Result<()> {
    let len = u32::try_from(s.len()).map_err(|_| invalid_data("string exceeds u32 length"))?;
    put_u32(w, len)?;
    w.write_all(s.as_bytes())
}

fn small_u32(v: usize, what: &'static str) -> io::Result<u32> {
    u32::try_from(v).map_err(|_| invalid_data(format!("{what} exceeds u32")))
}

impl Wire for Vec<f32> {
    fn encoded_len(&self) -> usize {
        self.len() * 4
    }

    fn encode(&self, w: &mut impl Write) -> io::Result<()> {
        write_f32s(w, self)
    }

    fn decode(buf: &[u8]) -> Result<Self, FrameError> {
        read_f32s(buf)
    }
}

impl Wire for PipeMsg {
    fn encoded_len(&self) -> usize {
        8 + self.2.len() * 4
    }

    fn encode(&self, w: &mut impl Write) -> io::Result<()> {
        put_u32(w, small_u32(self.0, "pipe layer id")?)?;
        put_u32(w, small_u32(self.1, "pipe micro-batch id")?)?;
        write_f32s(w, &self.2)
    }

    fn decode(buf: &[u8]) -> Result<Self, FrameError> {
        if buf.len() < 8 {
            return Err(FrameError("pipe frame shorter than its header"));
        }
        let mut c = Cursor::new(&buf[..8]);
        let layer = c.u32()? as usize;
        let mb = c.u32()? as usize;
        Ok((layer, mb, read_f32s(&buf[8..])?))
    }
}

/// Per-rank end-of-run summary shipped over the control plane — the
/// socket-transport analogue of the in-process `WorkerStats` join.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct RankStats {
    pub execute_secs: f64,
    pub execute_calls: u64,
    /// Payload elements sent on the data-parallel ring.
    pub collective_elems_sent: u64,
    /// Payload elements sent on the pipeline rings.
    pub pipeline_elems_sent: u64,
    /// Payload elements sent on the tensor-parallel ring.
    pub tp_elems_sent: u64,
    pub layer_state_bytes: u64,
    pub total_state_bytes: u64,
    pub wall_secs: f64,
    /// Whether this rank ran truly sharded tensor-parallel compute.
    pub tp_sharded: bool,
    /// The lowered schedule's name (coordinator-side config-skew check).
    pub schedule: String,
}

/// Control-plane messages between workers and the launch coordinator.
#[derive(Debug, Clone, PartialEq)]
pub enum CtrlMsg {
    /// Worker → coordinator, first frame on the control connection: my
    /// rank index, the incarnation I belong to, the shared auth token,
    /// and the address my data listener accepts on. A restarted worker
    /// re-registers with a bumped `generation`; a peer from an older
    /// incarnation (stale generation) or with the wrong token is
    /// rejected at the rendezvous.
    Hello { rank: u32, generation: u64, token: String, addr: String },
    /// Coordinator → worker: the rank → data-listener address table.
    Peers { addrs: Vec<String> },
    /// Worker → coordinator: one step's loss report.
    Loss { step: u64, dp: u32, loss: f64 },
    /// Worker → coordinator: end-of-run statistics.
    Stats(RankStats),
    /// Worker → coordinator: clean shutdown marker.
    Done,
    /// Worker → coordinator: per-step liveness heartbeat ("I completed
    /// this step"). The supervisor uses it to attribute a stall or a
    /// kill to a specific rank and to know the last completed step.
    Progress { step: u64 },
}

const TAG_HELLO: u8 = 0;
const TAG_PEERS: u8 = 1;
const TAG_LOSS: u8 = 2;
const TAG_STATS: u8 = 3;
const TAG_DONE: u8 = 4;
const TAG_PROGRESS: u8 = 5;

impl Wire for CtrlMsg {
    fn encoded_len(&self) -> usize {
        1 + match self {
            CtrlMsg::Hello { token, addr, .. } => 4 + 8 + 4 + token.len() + 4 + addr.len(),
            CtrlMsg::Peers { addrs } => 4 + addrs.iter().map(|a| 4 + a.len()).sum::<usize>(),
            CtrlMsg::Loss { .. } => 8 + 4 + 8,
            CtrlMsg::Stats(s) => 8 * 8 + 1 + 4 + s.schedule.len(),
            CtrlMsg::Done => 0,
            CtrlMsg::Progress { .. } => 8,
        }
    }

    fn encode(&self, w: &mut impl Write) -> io::Result<()> {
        match self {
            CtrlMsg::Hello { rank, generation, token, addr } => {
                w.write_all(&[TAG_HELLO])?;
                put_u32(w, *rank)?;
                put_u64(w, *generation)?;
                put_str(w, token)?;
                put_str(w, addr)
            }
            CtrlMsg::Peers { addrs } => {
                w.write_all(&[TAG_PEERS])?;
                put_u32(w, small_u32(addrs.len(), "peer count")?)?;
                for a in addrs {
                    put_str(w, a)?;
                }
                Ok(())
            }
            CtrlMsg::Loss { step, dp, loss } => {
                w.write_all(&[TAG_LOSS])?;
                put_u64(w, *step)?;
                put_u32(w, *dp)?;
                put_f64(w, *loss)
            }
            CtrlMsg::Stats(s) => {
                w.write_all(&[TAG_STATS])?;
                put_f64(w, s.execute_secs)?;
                put_u64(w, s.execute_calls)?;
                put_u64(w, s.collective_elems_sent)?;
                put_u64(w, s.pipeline_elems_sent)?;
                put_u64(w, s.tp_elems_sent)?;
                put_u64(w, s.layer_state_bytes)?;
                put_u64(w, s.total_state_bytes)?;
                put_f64(w, s.wall_secs)?;
                w.write_all(&[u8::from(s.tp_sharded)])?;
                put_str(w, &s.schedule)
            }
            CtrlMsg::Done => w.write_all(&[TAG_DONE]),
            CtrlMsg::Progress { step } => {
                w.write_all(&[TAG_PROGRESS])?;
                put_u64(w, *step)
            }
        }
    }

    fn decode(buf: &[u8]) -> Result<Self, FrameError> {
        let mut c = Cursor::new(buf);
        let msg = match c.u8()? {
            TAG_HELLO => CtrlMsg::Hello {
                rank: c.u32()?,
                generation: c.u64()?,
                token: c.string()?,
                addr: c.string()?,
            },
            TAG_PEERS => {
                let n = c.u32()? as usize;
                let mut addrs = Vec::with_capacity(n.min(4096));
                for _ in 0..n {
                    addrs.push(c.string()?);
                }
                CtrlMsg::Peers { addrs }
            }
            TAG_LOSS => CtrlMsg::Loss { step: c.u64()?, dp: c.u32()?, loss: c.f64()? },
            TAG_STATS => CtrlMsg::Stats(RankStats {
                execute_secs: c.f64()?,
                execute_calls: c.u64()?,
                collective_elems_sent: c.u64()?,
                pipeline_elems_sent: c.u64()?,
                tp_elems_sent: c.u64()?,
                layer_state_bytes: c.u64()?,
                total_state_bytes: c.u64()?,
                wall_secs: c.f64()?,
                tp_sharded: c.u8()? != 0,
                schedule: c.string()?,
            }),
            TAG_DONE => CtrlMsg::Done,
            TAG_PROGRESS => CtrlMsg::Progress { step: c.u64()? },
            _ => return Err(FrameError("unknown control tag")),
        };
        c.finish()?;
        Ok(msg)
    }
}

/// Which logical channel of the topology a data connection carries.
/// Together with the receiver's own grid coordinates this pins the
/// exact ring instance, so one kind byte per connection suffices.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ChanKind {
    PipeAct,
    PipeGrad,
    DpRing,
    TpRing,
}

impl ChanKind {
    fn tag(self) -> u8 {
        match self {
            ChanKind::PipeAct => 0,
            ChanKind::PipeGrad => 1,
            ChanKind::DpRing => 2,
            ChanKind::TpRing => 3,
        }
    }

    fn from_tag(t: u8) -> Result<Self, FrameError> {
        Ok(match t {
            0 => ChanKind::PipeAct,
            1 => ChanKind::PipeGrad,
            2 => ChanKind::DpRing,
            3 => ChanKind::TpRing,
            _ => return Err(FrameError("unknown channel kind")),
        })
    }
}

/// First frame on every data-plane connection: the dialing rank
/// self-identifies so the receiver can demux its accepted streams, and
/// carries its incarnation so a stale dialer (a worker from a previous
/// generation that survived a partial restart) is rejected instead of
/// silently joining the wrong world.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct DataHello {
    pub chan: ChanKind,
    pub from: u32,
    pub to: u32,
    pub generation: u64,
}

impl Wire for DataHello {
    fn encoded_len(&self) -> usize {
        17
    }

    fn encode(&self, w: &mut impl Write) -> io::Result<()> {
        w.write_all(&[self.chan.tag()])?;
        put_u32(w, self.from)?;
        put_u32(w, self.to)?;
        put_u64(w, self.generation)
    }

    fn decode(buf: &[u8]) -> Result<Self, FrameError> {
        let mut c = Cursor::new(buf);
        let h = DataHello {
            chan: ChanKind::from_tag(c.u8()?)?,
            from: c.u32()?,
            to: c.u32()?,
            generation: c.u64()?,
        };
        c.finish()?;
        Ok(h)
    }
}

// ---------------------------------------------------------------------------
// The socket transport port.

/// One directed duplex [`Transport`] port over TCP: a buffered writer
/// toward the send-peer and a dedicated reader thread draining the
/// recv-peer's stream into an unbounded channel. The reader always
/// draining keeps `send` from blocking indefinitely on a live peer;
/// either side dying surfaces as [`Disconnected`], never a hang.
pub struct SocketPort<M: Wire> {
    tx: BufWriter<TcpStream>,
    rx: Receiver<M>,
}

impl<M: Wire> SocketPort<M> {
    /// Wrap an outgoing stream (toward the send-peer) and an incoming
    /// stream (from the recv-peer) — two distinct connections, one per
    /// direction, matching the ring wiring's asymmetric neighbours.
    pub fn new(out: TcpStream, inc: TcpStream) -> Self {
        let (tx, rx) = channel::<M>();
        thread::Builder::new()
            .name("socket-reader".into())
            .spawn(move || {
                let mut r = BufReader::new(inc);
                loop {
                    let Ok(buf) = read_frame(&mut r) else { return };
                    let Ok(msg) = M::decode(&buf) else { return };
                    if tx.send(msg).is_err() {
                        return; // port dropped: stop draining
                    }
                }
            })
            .expect("spawn socket reader thread");
        SocketPort { tx: BufWriter::new(out), rx }
    }
}

impl<M: Wire> Transport<M> for SocketPort<M> {
    fn send(&mut self, msg: M) -> Result<(), Disconnected> {
        write_frame(&mut self.tx, &msg)
            .and_then(|()| self.tx.flush())
            .map_err(|_| Disconnected)
    }

    fn recv(&mut self) -> Result<M, Disconnected> {
        // The reader thread drops its sender on EOF/error, which
        // surfaces here as a clean disconnect.
        self.rx.recv().map_err(|_| Disconnected)
    }
}

// ---------------------------------------------------------------------------
// Reconnecting port: bounded retry with an epoch handshake.

/// Sent frames retained for retransmission after a reconnect. A torn
/// link older than this window cannot be resumed (the port errors out
/// instead of silently dropping data) — collectives exchange strictly
/// alternating small frames, so in practice one or two frames are ever
/// in flight.
pub const REPLAY_WINDOW: usize = 64;

/// `"RCN1"`: the reconnect-handshake magic, so a foreign stream (or a
/// mid-stream resync against a data frame) fails loudly.
const RC_MAGIC: u32 = 0x5243_4e31;

/// The resync handshake exchanged on every (re)connect: which
/// incarnation I belong to and the next sequence number I have not yet
/// delivered — the peer retransmits from there.
struct RcHello {
    generation: u64,
    next_expect: u64,
}

impl Wire for RcHello {
    fn encoded_len(&self) -> usize {
        20
    }

    fn encode(&self, w: &mut impl Write) -> io::Result<()> {
        put_u32(w, RC_MAGIC)?;
        put_u64(w, self.generation)?;
        put_u64(w, self.next_expect)
    }

    fn decode(buf: &[u8]) -> Result<Self, FrameError> {
        let mut c = Cursor::new(buf);
        if c.u32()? != RC_MAGIC {
            return Err(FrameError("bad reconnect handshake magic"));
        }
        let h = RcHello { generation: c.u64()?, next_expect: c.u64()? };
        c.finish()?;
        Ok(h)
    }
}

/// Bounded-reconnect policy: attempt `i` waits
/// `min(backoff · 2^i, max_backoff)` before re-dialing (the listening
/// side polls its accept queue for at least as long), and the port
/// gives up — surfacing [`Disconnected`] — after `max_attempts`.
#[derive(Debug, Clone, Copy)]
pub struct ReconnectConfig {
    pub max_attempts: usize,
    pub backoff: Duration,
    pub max_backoff: Duration,
}

impl Default for ReconnectConfig {
    fn default() -> Self {
        ReconnectConfig {
            max_attempts: 8,
            backoff: Duration::from_millis(10),
            max_backoff: Duration::from_secs(1),
        }
    }
}

fn backoff_delay(cfg: &ReconnectConfig, attempt: usize) -> Duration {
    cfg.backoff.saturating_mul(1u32 << attempt.min(16)).min(cfg.max_backoff)
}

fn total_budget(cfg: &ReconnectConfig) -> Duration {
    (0..cfg.max_attempts).map(|i| backoff_delay(cfg, i)).sum::<Duration>()
        + Duration::from_secs(1)
}

enum RcRole {
    /// Keeps its listener and re-accepts after a tear.
    Listen(TcpListener),
    /// Re-dials the same address after a tear.
    Dial(String),
}

/// A duplex [`Transport`] port over **one** TCP connection that
/// *survives* the connection tearing: both sides detect the broken
/// stream, re-establish it (bounded exponential backoff on the dialing
/// side, re-accept on the listening side), resync through an
/// [`RcHello`] epoch handshake — a peer from a different generation is
/// rejected, not resumed — and retransmit whatever the other side had
/// not yet delivered. Every data frame carries a `u64` sequence number;
/// the receiver drops retransmitted duplicates and errors on gaps, so a
/// mid-collective tear is invisible to the ring algorithms above:
/// results are bit-identical to an untorn run.
///
/// Unlike [`SocketPort`] there is no reader thread and no `BufReader` —
/// reads go straight to the socket, so no buffered bytes can be lost
/// when the stream is replaced mid-run.
pub struct ReconnectPort<M: Wire> {
    role: RcRole,
    cfg: ReconnectConfig,
    generation: u64,
    stream: TcpStream,
    next_seq: u64,
    next_expect: u64,
    replay: VecDeque<(u64, Vec<u8>)>,
    sends: u64,
    tear_at: Option<u64>,
    _msg: PhantomData<M>,
}

fn write_payload(stream: &TcpStream, seq: u64, bytes: &[u8]) -> io::Result<()> {
    let len = u32::try_from(8 + bytes.len())
        .ok()
        .filter(|&l| l <= MAX_FRAME_BYTES)
        .ok_or_else(|| invalid_data("frame payload exceeds the 1 GiB cap"))?;
    let mut w = stream;
    w.write_all(&len.to_le_bytes())?;
    w.write_all(&seq.to_le_bytes())?;
    w.write_all(bytes)
}

impl<M: Wire> ReconnectPort<M> {
    /// Accept the peer on `listener` and handshake. The listener is
    /// retained: after a tear this side recovers by re-accepting.
    pub fn listen(
        listener: TcpListener,
        generation: u64,
        cfg: ReconnectConfig,
    ) -> io::Result<Self> {
        let (stream, _) = listener.accept()?;
        configure(&stream)?;
        let mut port = ReconnectPort::assemble(RcRole::Listen(listener), cfg, generation, stream);
        port.handshake()?;
        Ok(port)
    }

    /// Dial `addr` and handshake. The address is retained: after a tear
    /// this side recovers by re-dialing it.
    pub fn dial(addr: &str, generation: u64, cfg: ReconnectConfig) -> io::Result<Self> {
        let stream = connect_retry(addr, total_budget(&cfg))?;
        let role = RcRole::Dial(addr.to_string());
        let mut port = ReconnectPort::assemble(role, cfg, generation, stream);
        port.handshake()?;
        Ok(port)
    }

    fn assemble(role: RcRole, cfg: ReconnectConfig, generation: u64, stream: TcpStream) -> Self {
        ReconnectPort {
            role,
            cfg,
            generation,
            stream,
            next_seq: 0,
            next_expect: 0,
            replay: VecDeque::new(),
            sends: 0,
            tear_at: None,
            _msg: PhantomData,
        }
    }

    /// Chaos hook: shut this port's own stream down right before its
    /// `sends`-th send, simulating a connection torn mid-collective.
    pub fn tear_after(&mut self, sends: u64) {
        self.tear_at = Some(sends);
    }

    /// Exchange [`RcHello`]s on the current stream and retransmit what
    /// the peer has not delivered. Errors on a generation mismatch (a
    /// stale peer must not resume) or when the peer needs a frame that
    /// fell out of the replay window.
    fn handshake(&mut self) -> io::Result<()> {
        let hello = RcHello { generation: self.generation, next_expect: self.next_expect };
        let mut w = &self.stream;
        write_frame(&mut w, &hello)?;
        let mut r = &self.stream;
        let peer = RcHello::decode(&read_frame(&mut r)?).map_err(invalid_data)?;
        if peer.generation != self.generation {
            return Err(invalid_data(format!(
                "reconnect handshake from stale generation {} (ours is {})",
                peer.generation, self.generation
            )));
        }
        if peer.next_expect < self.next_seq {
            match self.replay.front() {
                Some(&(oldest, _)) if oldest <= peer.next_expect => {}
                _ => {
                    return Err(invalid_data(format!(
                        "peer needs frame {} but it fell out of the replay window",
                        peer.next_expect
                    )));
                }
            }
        }
        for (seq, bytes) in &self.replay {
            if *seq >= peer.next_expect {
                write_payload(&self.stream, *seq, bytes)?;
            }
        }
        Ok(())
    }

    /// Re-establish the stream within the bounded backoff budget and
    /// resync. The last failure is surfaced when every attempt fails.
    fn reconnect(&mut self) -> io::Result<()> {
        let _ = self.stream.shutdown(Shutdown::Both);
        let mut last = invalid_data("reconnect exhausted its attempts");
        for attempt in 0..self.cfg.max_attempts {
            let delay = backoff_delay(&self.cfg, attempt);
            match self.reattach(delay) {
                Ok(s) => {
                    self.stream = s;
                    match self.handshake() {
                        Ok(()) => return Ok(()),
                        Err(e) => last = e,
                    }
                }
                Err(e) => last = e,
            }
        }
        Err(last)
    }

    fn reattach(&self, delay: Duration) -> io::Result<TcpStream> {
        match &self.role {
            RcRole::Dial(addr) => {
                thread::sleep(delay);
                let s = TcpStream::connect(addr.as_str())?;
                configure(&s)?;
                Ok(s)
            }
            RcRole::Listen(l) => {
                l.set_nonblocking(true)?;
                let t0 = Instant::now();
                let window = delay.max(Duration::from_millis(50));
                let r = loop {
                    match l.accept() {
                        Ok((s, _)) => break Ok(s),
                        Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                            if t0.elapsed() > window {
                                break Err(io::Error::new(
                                    io::ErrorKind::TimedOut,
                                    "no reconnect attempt within the backoff window",
                                ));
                            }
                            thread::sleep(Duration::from_millis(2));
                        }
                        Err(e) => break Err(e),
                    }
                };
                l.set_nonblocking(false)?;
                let s = r?;
                configure(&s)?;
                Ok(s)
            }
        }
    }

    /// Read one data frame: `Ok(Some)` delivers the next in-sequence
    /// message, `Ok(None)` dropped a retransmitted duplicate, `Err`
    /// means the stream broke (or the sequence gapped) — reconnect.
    fn read_one(&mut self) -> io::Result<Option<M>> {
        let mut r = &self.stream;
        let buf = read_frame(&mut r)?;
        if buf.len() < 8 {
            return Err(invalid_data("reconnect frame shorter than its sequence header"));
        }
        let seq = u64::from_le_bytes(buf[..8].try_into().expect("8-byte slice"));
        if seq < self.next_expect {
            return Ok(None);
        }
        if seq > self.next_expect {
            return Err(invalid_data(format!(
                "sequence gap: got frame {seq}, expected {}",
                self.next_expect
            )));
        }
        let msg = M::decode(&buf[8..]).map_err(invalid_data)?;
        self.next_expect += 1;
        Ok(Some(msg))
    }
}

impl<M: Wire> Transport<M> for ReconnectPort<M> {
    fn send(&mut self, msg: M) -> Result<(), Disconnected> {
        if self.tear_at == Some(self.sends) {
            self.tear_at = None;
            let _ = self.stream.shutdown(Shutdown::Both);
        }
        self.sends += 1;
        let mut bytes = Vec::with_capacity(msg.encoded_len());
        if msg.encode(&mut bytes).is_err() {
            return Err(Disconnected);
        }
        let seq = self.next_seq;
        self.next_seq += 1;
        self.replay.push_back((seq, bytes));
        while self.replay.len() > REPLAY_WINDOW {
            self.replay.pop_front();
        }
        let last = self.replay.back().expect("just pushed");
        if write_payload(&self.stream, last.0, &last.1).is_ok() {
            return Ok(());
        }
        // The handshake retransmits this frame along with anything else
        // the peer missed, so a successful reconnect IS the delivery.
        self.reconnect().map_err(|_| Disconnected)
    }

    fn recv(&mut self) -> Result<M, Disconnected> {
        loop {
            match self.read_one() {
                Ok(Some(m)) => return Ok(m),
                Ok(None) => continue,
                Err(_) => self.reconnect().map_err(|_| Disconnected)?,
            }
        }
    }
}

// ---------------------------------------------------------------------------
// Loopback wiring helpers (tests, benches, netbench).

fn configure(s: &TcpStream) -> io::Result<()> {
    // Latency matters more than throughput aggregation for ring rounds
    // and barrier tokens; frames are already batched application-side.
    s.set_nodelay(true)
}

/// A connected duplex pair over loopback: `a.send → b.recv` and vice
/// versa (the n = 2 ring, where next and previous neighbour coincide).
pub fn socket_pair<M: Wire>() -> io::Result<(SocketPort<M>, SocketPort<M>)> {
    let listener = TcpListener::bind(("127.0.0.1", 0))?;
    let addr = listener.local_addr()?;
    // `connect` returns only once the handshake completed, so accept
    // order deterministically matches dial order.
    let a_out = TcpStream::connect(addr)?;
    let (b_in, _) = listener.accept()?;
    let b_out = TcpStream::connect(addr)?;
    let (a_in, _) = listener.accept()?;
    for s in [&a_out, &b_in, &b_out, &a_in] {
        configure(s)?;
    }
    Ok((SocketPort::new(a_out, a_in), SocketPort::new(b_out, b_in)))
}

/// Wire an `n`-member socket ring over loopback (rank i sends to
/// i+1 mod n, hears from i−1 mod n) — the socket analogue of
/// [`mpsc_ring`], for tests and the netbench probe.
pub fn socket_ring(n: usize) -> io::Result<Vec<SocketPort<Vec<f32>>>> {
    assert!(n >= 1);
    let listeners: Vec<TcpListener> =
        (0..n).map(|_| TcpListener::bind(("127.0.0.1", 0))).collect::<io::Result<_>>()?;
    let mut outs = Vec::with_capacity(n);
    for i in 0..n {
        let s = TcpStream::connect(listeners[(i + 1) % n].local_addr()?)?;
        configure(&s)?;
        outs.push(s);
    }
    let mut ports = Vec::with_capacity(n);
    for (l, out) in listeners.iter().zip(outs) {
        // listener[j] hears exactly one dialer: rank j−1.
        let (inc, _) = l.accept()?;
        configure(&inc)?;
        ports.push(SocketPort::new(out, inc));
    }
    Ok(ports)
}

// ---------------------------------------------------------------------------
// Rendezvous and world wiring.

fn connect_retry(addr: &str, timeout: Duration) -> io::Result<TcpStream> {
    let t0 = Instant::now();
    loop {
        match TcpStream::connect(addr) {
            Ok(s) => {
                configure(&s)?;
                return Ok(s);
            }
            Err(e) => {
                if t0.elapsed() > timeout {
                    return Err(io::Error::new(e.kind(), format!("connecting to {addr}: {e}")));
                }
                thread::sleep(Duration::from_millis(20));
            }
        }
    }
}

/// The launch-side rendezvous listener: accepts one control connection
/// per rank, collects their `Hello`s, broadcasts the `Peers` table.
/// Hardened for elasticity: an optional shared auth token gates
/// registration, a restarted rank may *re*-register (the newer control
/// stream replaces the older one), and a `Hello` from a previous
/// generation — a zombie of an earlier incarnation — is dropped.
pub struct Coordinator {
    listener: TcpListener,
    n: usize,
    token: String,
}

impl Coordinator {
    /// Bind on `addr` (`"127.0.0.1:0"` for a loopback launch; a
    /// reachable interface + fixed port for multi-host) expecting `n`
    /// workers.
    pub fn bind(addr: &str, n: usize) -> io::Result<Self> {
        assert!(n >= 1, "a world needs at least one rank");
        Ok(Coordinator { listener: TcpListener::bind(addr)?, n, token: String::new() })
    }

    /// Require every `Hello` to carry this shared auth token
    /// (`REPRO_AUTH_TOKEN` / `--auth-token`). Empty = open listener.
    pub fn with_token(mut self, token: &str) -> Self {
        self.token = token.to_string();
        self
    }

    pub fn local_addr(&self) -> io::Result<SocketAddr> {
        self.listener.local_addr()
    }

    /// Run the generation-0 rendezvous (see [`Coordinator::rendezvous_gen`]).
    pub fn rendezvous(&self, deadline: Duration) -> io::Result<Vec<TcpStream>> {
        self.rendezvous_gen(deadline, 0)
    }

    /// Run the rendezvous for one incarnation: accept all `n` workers
    /// within `deadline` (erroring out — naming the missing ranks —
    /// instead of hanging if one never shows up), then broadcast the
    /// address table. Returns the per-rank control streams, index =
    /// rank, ready for loss/stats draining.
    ///
    /// A wrong-token `Hello` is dropped (logged, connection closed) and
    /// the listener keeps accepting; a stale-generation `Hello` is
    /// dropped silently (the dialer sees EOF); a duplicate `Hello` for
    /// an already-registered rank *replaces* it — the restarted process
    /// wins, its predecessor is dead or dying.
    pub fn rendezvous_gen(
        &self,
        deadline: Duration,
        generation: u64,
    ) -> io::Result<Vec<TcpStream>> {
        self.listener.set_nonblocking(true)?;
        let t0 = Instant::now();
        let mut streams: Vec<Option<TcpStream>> = (0..self.n).map(|_| None).collect();
        let mut addrs: Vec<Option<String>> = vec![None; self.n];
        let mut got = 0usize;
        while got < self.n {
            match self.listener.accept() {
                Ok((mut s, _)) => {
                    s.set_nonblocking(false)?;
                    configure(&s)?;
                    s.set_read_timeout(Some(deadline))?;
                    let hello = CtrlMsg::decode(&read_frame(&mut s)?).map_err(invalid_data)?;
                    let CtrlMsg::Hello { rank, generation: g, token, addr } = hello else {
                        return Err(invalid_data("expected Hello as the first control frame"));
                    };
                    if token != self.token {
                        eprintln!("[coordinator] rejecting rank {rank}: bad auth token");
                        continue; // drop the stream; keep accepting
                    }
                    if g != generation {
                        // A zombie from a previous incarnation: drop it
                        // (it sees EOF) and keep waiting for the real one.
                        continue;
                    }
                    let rank = rank as usize;
                    if rank >= self.n {
                        return Err(invalid_data(format!(
                            "rank {rank} out of range for a {}-rank world",
                            self.n
                        )));
                    }
                    if streams[rank].is_none() {
                        got += 1;
                    }
                    // Re-registration of a restarted rank: newest wins.
                    streams[rank] = Some(s);
                    addrs[rank] = Some(addr);
                }
                Err(e) if e.kind() == io::ErrorKind::WouldBlock => {
                    if t0.elapsed() > deadline {
                        let missing: Vec<String> = streams
                            .iter()
                            .enumerate()
                            .filter(|(_, s)| s.is_none())
                            .map(|(i, _)| i.to_string())
                            .collect();
                        return Err(io::Error::new(
                            io::ErrorKind::TimedOut,
                            format!(
                                "rendezvous timed out: {got}/{} workers connected (missing rank{} {})",
                                self.n,
                                if missing.len() == 1 { "" } else { "s" },
                                missing.join(", ")
                            ),
                        ));
                    }
                    thread::sleep(Duration::from_millis(5));
                }
                Err(e) => return Err(e),
            }
        }
        self.listener.set_nonblocking(false)?;
        let addrs: Vec<String> = addrs.into_iter().map(|a| a.expect("collected")).collect();
        let mut out = Vec::with_capacity(self.n);
        for s in streams {
            let mut s = s.expect("collected");
            write_frame(&mut s, &CtrlMsg::Peers { addrs: addrs.clone() })?;
            s.set_read_timeout(None)?;
            out.push(s);
        }
        Ok(out)
    }
}

/// The ring edges a rank owns, as (kind, peer index) pairs: which
/// channels it accepts (dialed by the previous neighbour on each axis)
/// and which it dials (toward the next neighbour). Mirrors exactly the
/// mpsc wiring of [`CommWorld::build`].
fn ring_edges(topo: Topology, rank: Rank) -> (Vec<(ChanKind, usize)>, Vec<(ChanKind, usize)>) {
    let (s, d, t) = (topo.stages, topo.dp, topo.tp);
    let at = |r: Rank| topo.index(r);
    let mut expect = Vec::new();
    let mut dial = Vec::new();
    if s > 1 {
        // Activations flow forward (hear from stage−1, dial stage+1);
        // gradients flow backward.
        expect.push((ChanKind::PipeAct, at(Rank { stage: (rank.stage + s - 1) % s, ..rank })));
        dial.push((ChanKind::PipeAct, at(Rank { stage: (rank.stage + 1) % s, ..rank })));
        expect.push((ChanKind::PipeGrad, at(Rank { stage: (rank.stage + 1) % s, ..rank })));
        dial.push((ChanKind::PipeGrad, at(Rank { stage: (rank.stage + s - 1) % s, ..rank })));
    }
    if d > 1 {
        expect.push((ChanKind::DpRing, at(Rank { dp: (rank.dp + d - 1) % d, ..rank })));
        dial.push((ChanKind::DpRing, at(Rank { dp: (rank.dp + 1) % d, ..rank })));
    }
    if t > 1 {
        expect.push((ChanKind::TpRing, at(Rank { tp: (rank.tp + t - 1) % t, ..rank })));
        dial.push((ChanKind::TpRing, at(Rank { tp: (rank.tp + 1) % t, ..rank })));
    }
    (expect, dial)
}

/// A size-1 in-process self-loop ring member (degenerate axis): same
/// no-op collectives and zero traffic as the mpsc backend.
fn self_ring() -> RingGroup {
    super::ring::ring_group(1).pop().expect("ring_group(1) yields one member")
}

/// Per-rank options for joining a socket world: how long to wait on
/// peers, which incarnation this process belongs to, and the shared
/// auth token presented at the rendezvous. `Default` reads the token
/// from `REPRO_AUTH_TOKEN` (empty when unset) — the path a forked
/// `repro worker` takes.
#[derive(Debug, Clone)]
pub struct WorldOptions {
    pub timeout: Duration,
    pub generation: u64,
    pub token: String,
}

impl Default for WorldOptions {
    fn default() -> Self {
        WorldOptions {
            timeout: Duration::from_secs(120),
            generation: 0,
            token: std::env::var("REPRO_AUTH_TOKEN").unwrap_or_default(),
        }
    }
}

/// Join a socket-wired world as rank `index` of `topo` at generation 0
/// (see [`connect_world_opts`]).
pub fn connect_world(
    topo: Topology,
    index: usize,
    coord_addr: &str,
    hostmap: Option<&[String]>,
    timeout: Duration,
) -> io::Result<CommWorld> {
    let opts = WorldOptions { timeout, ..WorldOptions::default() };
    connect_world_opts(topo, index, coord_addr, hostmap, &opts)
}

/// Join a socket-wired world as rank `index` of `topo`: bind this
/// rank's data listener, rendezvous through the coordinator at
/// `coord_addr`, dial/accept exactly the ring edges the mpsc builder
/// would wire, and assemble the rank's [`CommWorld`].
///
/// `hostmap` (from `REPRO_HOSTMAP`) gives one bindable data-listener
/// address per rank for multi-host runs; `None` binds loopback
/// ephemeral ports discovered through the rendezvous. Data connections
/// from a different generation than `opts.generation` (stale peers of
/// a previous incarnation) are dropped and the listener keeps
/// accepting until every expected edge arrives from the *current*
/// incarnation.
pub fn connect_world_opts(
    topo: Topology,
    index: usize,
    coord_addr: &str,
    hostmap: Option<&[String]>,
    opts: &WorldOptions,
) -> io::Result<CommWorld> {
    let timeout = opts.timeout;
    let generation = opts.generation;
    let token = opts.token.clone();
    let n = topo.n_ranks();
    assert!(index < n, "rank index {index} out of range for {n} ranks");
    if let Some(m) = hostmap {
        if m.len() != n {
            return Err(invalid_data(format!(
                "REPRO_HOSTMAP has {} entries for a {n}-rank world",
                m.len()
            )));
        }
    }
    let rank = topo.rank_at(index);
    let (expect, dial) = ring_edges(topo, rank);

    let bind_addr = hostmap.map_or_else(|| "127.0.0.1:0".to_string(), |m| m[index].clone());
    let listener = TcpListener::bind(&bind_addr)?;
    let advertised = match hostmap {
        Some(m) => m[index].clone(),
        None => listener.local_addr()?.to_string(),
    };

    // Accept in a thread so dialing out can't deadlock against peers
    // dialing in.
    let expect_n = expect.len();
    let my_index = small_u32(index, "rank index")?;
    let accept: thread::JoinHandle<io::Result<Vec<(DataHello, TcpStream)>>> =
        thread::Builder::new()
            .name(format!("accept-rank-{index}"))
            .spawn(move || {
                let mut got = Vec::with_capacity(expect_n);
                while got.len() < expect_n {
                    let (mut s, _) = listener.accept()?;
                    configure(&s)?;
                    s.set_read_timeout(Some(timeout))?;
                    let hello = DataHello::decode(&read_frame(&mut s)?).map_err(invalid_data)?;
                    if hello.to != my_index {
                        return Err(invalid_data(format!(
                            "data connection addressed to rank {} reached rank {my_index}",
                            hello.to
                        )));
                    }
                    if hello.generation != generation {
                        // Stale peer from a previous incarnation: drop
                        // the stream (the dialer sees EOF) and keep
                        // accepting until the real edge shows up.
                        continue;
                    }
                    s.set_read_timeout(None)?;
                    got.push((hello, s));
                }
                Ok(got)
            })
            .expect("spawn accept thread");

    // Control rendezvous: Hello out, Peers table back.
    let mut ctrl = connect_retry(coord_addr, timeout)?;
    let hello = CtrlMsg::Hello { rank: my_index, generation, token, addr: advertised };
    write_frame(&mut ctrl, &hello)?;
    ctrl.set_read_timeout(Some(timeout))?;
    let peers = match CtrlMsg::decode(&read_frame(&mut ctrl)?).map_err(invalid_data)? {
        CtrlMsg::Peers { addrs } => addrs,
        _ => return Err(invalid_data("expected Peers from the coordinator")),
    };
    ctrl.set_read_timeout(None)?;
    if peers.len() != n {
        return Err(invalid_data(format!("coordinator sent {} peers, expected {n}", peers.len())));
    }

    // Dial the outgoing edges, self-identifying per connection.
    let mut out_streams: HashMap<ChanKind, TcpStream> = HashMap::new();
    for (kind, to) in dial {
        let mut s = connect_retry(&peers[to], timeout)?;
        let h = DataHello { chan: kind, from: my_index, to: small_u32(to, "rank")?, generation };
        write_frame(&mut s, &h)?;
        out_streams.insert(kind, s);
    }

    // Collect the incoming edges and demux by channel kind.
    let mut inc_streams: HashMap<ChanKind, TcpStream> = HashMap::new();
    let accepted = accept.join().map_err(|_| invalid_data("accept thread panicked"))??;
    for (hello, s) in accepted {
        let want_from = expect.iter().find(|(k, _)| *k == hello.chan).map(|&(_, f)| f);
        match want_from {
            Some(f) if f == hello.from as usize => {
                if inc_streams.insert(hello.chan, s).is_some() {
                    return Err(invalid_data("duplicate data connection for a channel"));
                }
            }
            _ => {
                return Err(invalid_data(format!(
                    "unexpected data connection {:?} from rank {}",
                    hello.chan, hello.from
                )))
            }
        }
    }

    let mut take = |kind: ChanKind| -> io::Result<(TcpStream, TcpStream)> {
        let o = out_streams.remove(&kind).ok_or_else(|| invalid_data("missing outgoing edge"))?;
        let i = inc_streams.remove(&kind).ok_or_else(|| invalid_data("missing incoming edge"))?;
        Ok((o, i))
    };

    let pipeline = if topo.stages > 1 {
        let (ao, ai) = take(ChanKind::PipeAct)?;
        let (go, gi) = take(ChanKind::PipeGrad)?;
        PipelineGroup::new(
            Box::new(SocketPort::<PipeMsg>::new(ao, ai)),
            Box::new(SocketPort::<PipeMsg>::new(go, gi)),
        )
    } else {
        // Degenerate stage axis: the same in-process self-loops the
        // mpsc builder wires.
        let act = mpsc_ring::<PipeMsg>(1).pop().expect("one port");
        let grad = mpsc_ring_rev::<PipeMsg>(1).pop().expect("one port");
        PipelineGroup::new(Box::new(act), Box::new(grad))
    };
    let dp_group = if topo.dp > 1 {
        let (o, i) = take(ChanKind::DpRing)?;
        RingGroup::new_wire(rank.dp, topo.dp, Box::new(SocketPort::<Vec<f32>>::new(o, i)))
    } else {
        self_ring()
    };
    let tp_group = if topo.tp > 1 {
        let (o, i) = take(ChanKind::TpRing)?;
        RingGroup::new_wire(rank.tp, topo.tp, Box::new(SocketPort::<Vec<f32>>::new(o, i)))
    } else {
        self_ring()
    };

    Ok(CommWorld::from_parts(rank, topo, pipeline, dp_group, tp_group, ControlGroup::wire(ctrl)))
}

// ---------------------------------------------------------------------------
// Netbench: measure the wire the calibration feeds on.

/// Measured loopback (or hostmap'd) socket characteristics.
#[derive(Debug, Clone, Copy)]
pub struct NetProbe {
    /// Median small-frame round-trip time, seconds.
    pub rtt_secs: f64,
    /// Sustained one-way framed bandwidth, bytes/s.
    pub bandwidth_bytes_per_s: f64,
    /// Effective per-rank all-reduce bandwidth over a 2-member socket
    /// ring (payload bytes per rank per second, at the 2·(n−1)/n ring
    /// volume).
    pub ring_allreduce_bytes_per_s: f64,
    /// Streaming payload size used for the bandwidth probes, bytes.
    pub payload_bytes: usize,
}

fn disconnected(_: Disconnected) -> io::Error {
    io::Error::new(io::ErrorKind::ConnectionAborted, "netbench peer hung up")
}

/// Measure socket round-trip latency and sustained bandwidth over
/// loopback: the numbers `BENCH_net_calibration.json` records and
/// [`crate::hardware::NetCalibration`] feeds back into the cost model.
pub fn netbench(
    payload_elems: usize,
    pingpong_iters: usize,
    stream_frames: usize,
) -> io::Result<NetProbe> {
    assert!(payload_elems > 0 && pingpong_iters > 0 && stream_frames > 0);
    let (mut a, mut b) = socket_pair::<Vec<f32>>()?;

    // Round-trip latency: tiny-frame ping-pong, median over the runs.
    let echo = thread::spawn(move || -> Result<SocketPort<Vec<f32>>, Disconnected> {
        for _ in 0..pingpong_iters {
            let m = b.recv()?;
            b.send(m)?;
        }
        Ok(b)
    });
    let mut rtts = Vec::with_capacity(pingpong_iters);
    for _ in 0..pingpong_iters {
        let t = Instant::now();
        a.send(vec![1.0]).map_err(disconnected)?;
        a.recv().map_err(disconnected)?;
        rtts.push(t.elapsed().as_secs_f64());
    }
    let mut b = echo.join().expect("echo thread").map_err(disconnected)?;
    rtts.sort_by(f64::total_cmp);
    let rtt_secs = rtts[rtts.len() / 2];

    // Sustained one-way bandwidth: stream frames, one ack back.
    let payload_bytes = payload_elems * 4;
    let sink = thread::spawn(move || -> Result<(), Disconnected> {
        for _ in 0..stream_frames {
            b.recv()?;
        }
        b.send(vec![0.0])?;
        Ok(())
    });
    let payload = vec![0.5f32; payload_elems];
    let t0 = Instant::now();
    for _ in 0..stream_frames {
        a.send(payload.clone()).map_err(disconnected)?;
    }
    a.recv().map_err(disconnected)?;
    let secs = t0.elapsed().as_secs_f64().max(1e-9);
    sink.join().expect("sink thread").map_err(disconnected)?;
    let bandwidth_bytes_per_s = (stream_frames * payload_bytes) as f64 / secs;

    // Ring all-reduce over sockets: per-rank wire volume for n = 2 is
    // exactly the payload size per all-reduce.
    let iters = 16usize;
    let mut ports = socket_ring(2)?;
    let p1 = ports.pop().expect("two ports");
    let p0 = ports.pop().expect("two ports");
    let peer = thread::spawn(move || {
        let mut g = RingGroup::new_wire(1, 2, Box::new(p1));
        let mut buf = vec![1.0f32; payload_elems];
        for _ in 0..iters {
            g.all_reduce(&mut buf);
        }
    });
    let mut g = RingGroup::new_wire(0, 2, Box::new(p0));
    let mut buf = vec![1.0f32; payload_elems];
    let t0 = Instant::now();
    for _ in 0..iters {
        g.all_reduce(&mut buf);
    }
    let ring_secs = t0.elapsed().as_secs_f64().max(1e-9);
    peer.join().expect("ring peer thread");
    let ring_allreduce_bytes_per_s = (iters * payload_bytes) as f64 / ring_secs;

    Ok(NetProbe { rtt_secs, bandwidth_bytes_per_s, ring_allreduce_bytes_per_s, payload_bytes })
}

#[cfg(test)]
mod tests {
    use super::*;

    // -- Frame codec (socket-free: stays in the fast tier-1 path). ---------

    fn roundtrip<M: Wire + PartialEq + std::fmt::Debug + Clone>(msg: &M) {
        let mut buf = Vec::new();
        write_frame(&mut buf, msg).unwrap();
        assert_eq!(buf.len(), 4 + msg.encoded_len(), "length prefix mismatch");
        let payload = read_frame(&mut buf.as_slice()).unwrap();
        assert_eq!(&M::decode(&payload).unwrap(), msg);
    }

    #[test]
    fn f32_payloads_roundtrip_bit_exactly() {
        let cases: Vec<Vec<f32>> = vec![
            vec![],
            vec![0.0, -0.0, 1.5, -2.25e-3],
            vec![f32::NAN, f32::INFINITY, f32::NEG_INFINITY],
            vec![1e-45, -3.0e-39, f32::MIN_POSITIVE, f32::MAX, f32::MIN],
            (0..10_000).map(|i| (i as f32).sin() * 1e30).collect(),
        ];
        for v in cases {
            let mut buf = Vec::new();
            write_frame(&mut buf, &v).unwrap();
            let got = Vec::<f32>::decode(&read_frame(&mut buf.as_slice()).unwrap()).unwrap();
            assert_eq!(got.len(), v.len());
            for (a, b) in got.iter().zip(&v) {
                // Bit-level equality: NaN payloads and signed zeros too.
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn pipe_msgs_roundtrip() {
        roundtrip(&(0usize, 0usize, Vec::<f32>::new()));
        roundtrip(&(7usize, 31usize, vec![1.0f32, f32::NAN, 1e-45]));
        roundtrip(&(usize::from(u16::MAX), 2usize, vec![0.25f32; 1023]));
    }

    #[test]
    fn control_msgs_roundtrip() {
        roundtrip(&CtrlMsg::Hello {
            rank: 3,
            generation: 0,
            token: String::new(),
            addr: "127.0.0.1:45133".into(),
        });
        roundtrip(&CtrlMsg::Hello {
            rank: 0,
            generation: u64::MAX,
            token: "repro-чаос".into(),
            addr: String::new(),
        });
        roundtrip(&CtrlMsg::Peers { addrs: vec!["a:1".into(), "b:2".into(), String::new()] });
        roundtrip(&CtrlMsg::Loss { step: u64::MAX, dp: 0, loss: -f64::NAN });
        roundtrip(&CtrlMsg::Loss { step: 0, dp: 7, loss: 5.551e-308 });
        roundtrip(&CtrlMsg::Progress { step: 0 });
        roundtrip(&CtrlMsg::Progress { step: u64::MAX });
        roundtrip(&CtrlMsg::Stats(RankStats {
            execute_secs: 1.25,
            execute_calls: 42,
            collective_elems_sent: u64::MAX,
            pipeline_elems_sent: 0,
            tp_elems_sent: 9,
            layer_state_bytes: 1 << 40,
            total_state_bytes: 3,
            wall_secs: f64::INFINITY,
            tp_sharded: true,
            schedule: "modular-pipeline".into(),
        }));
        roundtrip(&CtrlMsg::Done);
    }

    #[test]
    fn data_hello_roundtrips() {
        for chan in [ChanKind::PipeAct, ChanKind::PipeGrad, ChanKind::DpRing, ChanKind::TpRing] {
            roundtrip(&DataHello { chan, from: 11, to: 4, generation: 3 });
        }
    }

    /// Fuzz-ish property sweep: a structured message survives the codec
    /// for many payload shapes, and *any* truncation of its payload is
    /// rejected rather than mis-decoded.
    #[test]
    fn codec_rejects_every_truncation() {
        let msg = CtrlMsg::Stats(RankStats {
            execute_secs: 0.5,
            execute_calls: 1,
            collective_elems_sent: 2,
            pipeline_elems_sent: 3,
            tp_elems_sent: 4,
            layer_state_bytes: 5,
            total_state_bytes: 6,
            wall_secs: 7.0,
            tp_sharded: false,
            schedule: "probe".into(),
        });
        let mut buf = Vec::new();
        msg.encode(&mut buf).unwrap();
        for cut in 0..buf.len() {
            assert!(CtrlMsg::decode(&buf[..cut]).is_err(), "truncation at {cut} accepted");
        }
        // Trailing garbage is rejected too.
        let mut extended = buf.clone();
        extended.push(0);
        assert!(CtrlMsg::decode(&extended).is_err());
    }

    /// Pseudo-random frame bytes never panic the decoder — they decode
    /// or error. (Deterministic LCG; no RNG dependency.)
    #[test]
    fn codec_survives_random_bytes() {
        let mut state = 0x2545_f491_4f6c_dd1du64;
        let mut next = move || {
            state ^= state << 13;
            state ^= state >> 7;
            state ^= state << 17;
            state
        };
        for _ in 0..2000 {
            let len = (next() % 64) as usize;
            let bytes: Vec<u8> = (0..len).map(|_| (next() & 0xff) as u8).collect();
            let _ = CtrlMsg::decode(&bytes);
            let _ = DataHello::decode(&bytes);
            let _ = <(usize, usize, Vec<f32>)>::decode(&bytes);
            let _ = Vec::<f32>::decode(&bytes);
        }
    }

    #[test]
    fn oversized_length_prefix_is_rejected_without_allocating() {
        let mut framed = Vec::new();
        framed.extend_from_slice(&(MAX_FRAME_BYTES + 1).to_le_bytes());
        framed.extend_from_slice(&[0u8; 16]);
        let err = read_frame(&mut framed.as_slice()).unwrap_err();
        assert_eq!(err.kind(), io::ErrorKind::InvalidData);
    }

    // -- Socket transport over loopback. ------------------------------------

    #[test]
    fn socket_pair_delivers_in_order_both_directions() {
        let (mut a, mut b) = socket_pair::<Vec<f32>>().unwrap();
        for i in 0..10 {
            a.send(vec![i as f32]).unwrap();
            b.send(vec![-(i as f32)]).unwrap();
        }
        for i in 0..10 {
            assert_eq!(b.recv().unwrap(), vec![i as f32]);
            assert_eq!(a.recv().unwrap(), vec![-(i as f32)]);
        }
    }

    #[test]
    fn torn_connection_is_a_clean_disconnect_not_a_hang() {
        let (mut a, b) = socket_pair::<Vec<f32>>().unwrap();
        drop(b);
        // recv surfaces the peer's death immediately.
        assert_eq!(a.recv(), Err(Disconnected));
        // send errors once the kernel learns of the reset — bounded
        // loop, never an indefinite block.
        let mut surfaced = false;
        for _ in 0..10_000 {
            if a.send(vec![0.0f32; 16 * 1024]).is_err() {
                surfaced = true;
                break;
            }
        }
        assert!(surfaced, "send never surfaced the torn connection");
    }

    #[test]
    fn socket_ring_matches_mpsc_ring_bitwise() {
        use super::super::ring::ring_group;
        for n in [2usize, 3] {
            let data = |rank: usize| -> Vec<f32> {
                (0..37).map(|i| ((rank * 100 + i) as f32).sin() * 1e3).collect()
            };
            // mpsc reference.
            let mut mpsc_results = Vec::new();
            let handles: Vec<_> = ring_group(n)
                .into_iter()
                .map(|mut g| {
                    let mut d = data(g.rank);
                    thread::spawn(move || {
                        g.all_reduce(&mut d);
                        (g.rank, d, g.sent_elems())
                    })
                })
                .collect();
            for h in handles {
                mpsc_results.push(h.join().unwrap());
            }
            mpsc_results.sort_by_key(|r| r.0);
            // socket run of the same SPMD program.
            let handles: Vec<_> = socket_ring(n)
                .unwrap()
                .into_iter()
                .enumerate()
                .map(|(rank, p)| {
                    let mut g = RingGroup::new_wire(rank, n, Box::new(p));
                    let mut d = data(rank);
                    thread::spawn(move || {
                        g.all_reduce(&mut d);
                        g.barrier();
                        (rank, d, g.sent_elems())
                    })
                })
                .collect();
            let mut sock_results: Vec<_> = handles.into_iter().map(|h| h.join().unwrap()).collect();
            sock_results.sort_by_key(|r| r.0);
            for ((_, md, me), (_, sd, se)) in mpsc_results.iter().zip(&sock_results) {
                assert_eq!(me, se, "n={n}: traffic accounting diverged (barrier counted?)");
                for (x, y) in md.iter().zip(sd) {
                    assert_eq!(x.to_bits(), y.to_bits(), "n={n}: reduction diverged");
                }
            }
        }
    }

    #[test]
    fn single_rank_socket_ring_is_a_self_loop() {
        let mut ports = socket_ring(1).unwrap();
        ports[0].send(vec![7.5]).unwrap();
        assert_eq!(ports[0].recv().unwrap(), vec![7.5]);
    }

    #[test]
    fn netbench_reports_sane_numbers() {
        let p = netbench(1 << 12, 16, 8).unwrap();
        assert!(p.rtt_secs > 0.0 && p.rtt_secs < 1.0, "rtt {:.6}s", p.rtt_secs);
        assert!(p.bandwidth_bytes_per_s > 0.0);
        assert!(p.ring_allreduce_bytes_per_s > 0.0);
        assert_eq!(p.payload_bytes, 4 << 12);
    }

    // -- Reconnecting port. --------------------------------------------------

    fn rc_data(rank: usize) -> Vec<f32> {
        // Awkward (non-divisible) length: uneven ring chunk boundaries.
        (0..33).map(|i| ((rank * 1000 + i) as f32).sin() * 1e2).collect()
    }

    fn rc_run(groups: Vec<RingGroup>) -> Vec<Vec<f32>> {
        let handles: Vec<_> = groups
            .into_iter()
            .enumerate()
            .map(|(r, mut g)| {
                thread::spawn(move || {
                    let mut d = rc_data(r);
                    g.all_reduce(&mut d);
                    d
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    /// The satellite acceptance test: a link torn in the middle of an
    /// all-reduce (between the two ring rounds) reconnects, resyncs and
    /// finishes with results bit-identical to an untorn run.
    #[test]
    fn reconnect_mid_all_reduce_is_bit_identical_to_a_clean_run() {
        let clean = rc_run(super::super::ring::ring_group(2));
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let cfg = ReconnectConfig::default();
        let server =
            thread::spawn(move || ReconnectPort::<Vec<f32>>::listen(listener, 7, cfg).unwrap());
        let mut dialer = ReconnectPort::<Vec<f32>>::dial(&addr, 7, cfg).unwrap();
        // A 2-rank all-reduce is two rounds of one send each: tear the
        // dialer's stream right before its second send.
        dialer.tear_after(1);
        let listener_port = server.join().unwrap();
        let groups = vec![
            RingGroup::new_wire(0, 2, Box::new(dialer)),
            RingGroup::new_wire(1, 2, Box::new(listener_port)),
        ];
        let torn = rc_run(groups);
        for (r, (a, b)) in clean.iter().zip(&torn).enumerate() {
            assert_eq!(a.len(), b.len(), "rank {r}");
            for (k, (x, y)) in a.iter().zip(b).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "rank {r} elem {k}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn stale_generation_peer_is_rejected_at_handshake() {
        let listener = TcpListener::bind(("127.0.0.1", 0)).unwrap();
        let addr = listener.local_addr().unwrap().to_string();
        let cfg = ReconnectConfig { max_attempts: 1, ..ReconnectConfig::default() };
        let server = thread::spawn(move || ReconnectPort::<Vec<f32>>::listen(listener, 2, cfg));
        let err = ReconnectPort::<Vec<f32>>::dial(&addr, 1, cfg).unwrap_err();
        assert!(err.to_string().contains("stale generation"), "{err}");
        assert!(server.join().unwrap().is_err(), "listener accepted a stale peer");
    }

    /// Rendezvous hardening: wrong-token Hellos are dropped, stale
    /// generations ignored, and a restarted rank's re-registration
    /// replaces its predecessor (the new address wins the Peers table).
    #[test]
    fn coordinator_accepts_re_registration_and_rejects_bad_token() {
        let coord = Coordinator::bind("127.0.0.1:0", 2).unwrap().with_token("secret");
        let addr = coord.local_addr().unwrap().to_string();
        let h = thread::spawn(move || coord.rendezvous_gen(Duration::from_secs(10), 3));
        let hello = |rank: u32, generation: u64, token: &str, a: &str| {
            let mut s = TcpStream::connect(&addr).unwrap();
            let msg = CtrlMsg::Hello { rank, generation, token: token.into(), addr: a.into() };
            write_frame(&mut s, &msg).unwrap();
            s
        };
        // Wrong token: dropped (the client sees EOF, not a Peers table).
        let mut bad = hello(0, 3, "wrong", "x:1");
        // Stale generation: dropped silently.
        let _stale = hello(0, 2, "secret", "x:2");
        // Rank 0 registers, then its restarted incarnation replaces it.
        let _first = hello(0, 3, "secret", "old:0");
        let mut r0 = hello(0, 3, "secret", "new:0");
        let mut r1 = hello(1, 3, "secret", "b:1");
        let streams = h.join().unwrap().unwrap();
        assert_eq!(streams.len(), 2);
        assert!(read_frame(&mut bad).is_err(), "bad-token stream saw data");
        let want = CtrlMsg::Peers { addrs: vec!["new:0".into(), "b:1".into()] };
        for s in [&mut r0, &mut r1] {
            let peers = CtrlMsg::decode(&read_frame(s).unwrap()).unwrap();
            assert_eq!(peers, want, "the restarted rank's address wins");
        }
    }
}
