//! Ring collectives over mpsc channels.
//!
//! `ring_group(n)` builds the communicators; each participating thread
//! then calls the same sequence of collective ops (SPMD style). Chunk
//! boundaries are deterministic, so results are bit-identical across
//! ranks and across runs.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::sync::{Arc, Barrier};

/// Per-rank communicator for a ring of `n` members.
pub struct Comm {
    pub rank: usize,
    pub n: usize,
    tx_next: Sender<Vec<f32>>,
    rx_prev: Receiver<Vec<f32>>,
    barrier: Arc<Barrier>,
    /// Total payload elements sent by this rank (traffic accounting).
    pub sent_elems: u64,
}

/// Build communicators for an `n`-rank ring. Index i talks to i+1 mod n.
pub fn ring_group(n: usize) -> Vec<Comm> {
    assert!(n >= 1);
    let barrier = Arc::new(Barrier::new(n));
    let mut txs: Vec<Option<Sender<Vec<f32>>>> = Vec::with_capacity(n);
    let mut rxs: Vec<Option<Receiver<Vec<f32>>>> = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = channel();
        txs.push(Some(tx));
        rxs.push(Some(rx));
    }
    // rank r sends on channel r (to r+1), receives on channel (r-1+n)%n.
    let mut comms = Vec::with_capacity(n);
    let mut rx_rot: Vec<Option<Receiver<Vec<f32>>>> = Vec::with_capacity(n);
    for r in 0..n {
        rx_rot.push(rxs[(r + n - 1) % n].take());
    }
    for (r, rx) in rx_rot.into_iter().enumerate() {
        comms.push(Comm {
            rank: r,
            n,
            tx_next: txs[r].take().unwrap(),
            rx_prev: rx.unwrap(),
            barrier: barrier.clone(),
            sent_elems: 0,
        });
    }
    comms
}

/// Chunk boundaries: `n` nearly-equal chunks of a `len`-element buffer.
fn chunk_bounds(len: usize, n: usize, i: usize) -> (usize, usize) {
    let base = len / n;
    let rem = len % n;
    let start = i * base + i.min(rem);
    let size = base + usize::from(i < rem);
    (start, start + size)
}

impl Comm {
    /// Synchronisation barrier across the group.
    pub fn barrier(&self) {
        self.barrier.wait();
    }

    fn send(&mut self, data: Vec<f32>) {
        self.sent_elems += data.len() as u64;
        // Receiver outliving sender is guaranteed by trainer shutdown
        // ordering; a send on a closed ring is a bug.
        self.tx_next.send(data).expect("ring peer hung up");
    }

    fn recv(&mut self) -> Vec<f32> {
        self.rx_prev.recv().expect("ring peer hung up")
    }

    /// Ring all-reduce (sum): reduce-scatter then all-gather.
    /// All ranks end with identical, fully-summed buffers.
    pub fn all_reduce(&mut self, data: &mut [f32]) {
        if self.n == 1 {
            return;
        }
        self.reduce_scatter(data);
        self.all_gather_owned(data);
    }

    /// Ring reduce-scatter: afterwards, rank r holds the fully-reduced
    /// chunk `owned_chunk()` (other chunks are partial — callers either
    /// continue with `all_gather_owned` or use only their own chunk, as
    /// the ZeRO-style partition does).
    pub fn reduce_scatter(&mut self, data: &mut [f32]) {
        if self.n == 1 {
            return;
        }
        let n = self.n;
        for step in 0..n - 1 {
            let send_idx = (self.rank + n - step) % n;
            let recv_idx = (self.rank + n - step - 1) % n;
            let (a, b) = chunk_bounds(data.len(), n, send_idx);
            self.send(data[a..b].to_vec());
            let incoming = self.recv();
            let (a, b) = chunk_bounds(data.len(), n, recv_idx);
            for (d, x) in data[a..b].iter_mut().zip(&incoming) {
                *d += x;
            }
        }
    }

    /// The chunk index rank `rank` owns after [`Self::reduce_scatter`].
    pub fn owned_chunk(&self) -> usize {
        (self.rank + 1) % self.n
    }

    /// Element range of this rank's owned chunk in a `len` buffer.
    pub fn owned_range(&self, len: usize) -> (usize, usize) {
        chunk_bounds(len, self.n, self.owned_chunk())
    }

    /// Ring all-gather assuming each rank's `owned_chunk()` is complete
    /// (the state `reduce_scatter` leaves). Afterwards all chunks are
    /// complete everywhere.
    pub fn all_gather_owned(&mut self, data: &mut [f32]) {
        if self.n == 1 {
            return;
        }
        let n = self.n;
        for step in 0..n - 1 {
            let send_idx = (self.rank + 1 + n - step) % n;
            let recv_idx = (self.rank + n - step) % n;
            let (a, b) = chunk_bounds(data.len(), n, send_idx);
            self.send(data[a..b].to_vec());
            let incoming = self.recv();
            let (a, b) = chunk_bounds(data.len(), n, recv_idx);
            data[a..b].copy_from_slice(&incoming);
        }
    }

    /// Broadcast from `root` around the ring.
    pub fn broadcast(&mut self, data: &mut [f32], root: usize) {
        if self.n == 1 {
            return;
        }
        // Pass the buffer around the ring n-1 hops starting at root.
        let hops_from_root = (self.rank + self.n - root) % self.n;
        if hops_from_root == 0 {
            self.send(data.to_vec());
            let _ = self.recv(); // swallow the returning copy
        } else {
            let incoming = self.recv();
            data.copy_from_slice(&incoming);
            self.send(incoming);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn run_group<F>(n: usize, f: F) -> Vec<Vec<f32>>
    where
        F: Fn(&mut Comm, &mut Vec<f32>) + Send + Sync + Copy + 'static,
    {
        let comms = ring_group(n);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|mut c| {
                thread::spawn(move || {
                    let mut data: Vec<f32> =
                        (0..10).map(|i| (c.rank * 100 + i) as f32).collect();
                    f(&mut c, &mut data);
                    data
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn all_reduce_sums_across_ranks() {
        for n in [1, 2, 3, 4, 7] {
            let results = run_group(n, |c, d| c.all_reduce(d));
            let want: Vec<f32> = (0..10)
                .map(|i| (0..n).map(|r| (r * 100 + i) as f32).sum())
                .collect();
            for (r, res) in results.iter().enumerate() {
                assert_eq!(res, &want, "n={n} rank={r}");
            }
        }
    }

    #[test]
    fn reduce_scatter_owned_chunk_is_complete() {
        let n = 4;
        let results = run_group(n, |c, d| {
            c.reduce_scatter(d);
            // Zero everything but the owned chunk, then all-gather to
            // verify the owned chunks alone reconstruct the full sum.
            let (a, b) = c.owned_range(d.len());
            for (i, v) in d.iter_mut().enumerate() {
                if i < a || i >= b {
                    *v = 0.0;
                }
            }
            c.all_gather_owned(d);
        });
        let want: Vec<f32> =
            (0..10).map(|i| (0..n).map(|r| (r * 100 + i) as f32).sum()).collect();
        for res in &results {
            assert_eq!(res, &want);
        }
    }

    #[test]
    fn broadcast_copies_root_buffer() {
        let results = run_group(3, |c, d| c.broadcast(d, 1));
        let want: Vec<f32> = (0..10).map(|i| (100 + i) as f32).collect();
        for res in &results {
            assert_eq!(res, &want);
        }
    }

    #[test]
    fn traffic_matches_ring_bound() {
        // All-reduce traffic per rank = 2·(n−1)/n·len elements.
        let comms = ring_group(4);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|mut c| {
                thread::spawn(move || {
                    let mut d = vec![1.0f32; 1000];
                    c.all_reduce(&mut d);
                    c.sent_elems
                })
            })
            .collect();
        for h in handles {
            let sent = h.join().unwrap();
            assert_eq!(sent, 2 * 3 * 250); // 2·(n−1)·chunk
        }
    }

    #[test]
    fn uneven_lengths_are_handled() {
        let results = run_group(3, |c, d| {
            d.truncate(7); // 7 elements over 3 ranks: chunks 3,2,2
            c.all_reduce(d);
        });
        let want: Vec<f32> = (0..7).map(|i| (0..3).map(|r| (r * 100 + i) as f32).sum()).collect();
        for res in &results {
            assert_eq!(res, &want);
        }
    }
}
