//! Ring collectives over an abstract [`Transport`].
//!
//! `ring_group(n)` builds `n` communicators over the in-process mpsc
//! backend; each participating thread then calls the same sequence of
//! collective ops (SPMD style). Chunk boundaries are deterministic, so
//! results are bit-identical across ranks and across runs. The ring
//! algorithm is the bandwidth-optimal one the paper's C.4.1 traffic
//! accounting assumes (each rank sends/receives 2·(n−1)/n of the buffer
//! for an all-reduce).
//!
//! A [`RingGroup`] no longer owns raw channels: it drives any
//! [`Transport<Vec<f32>>`] — the in-process mpsc backend or the TCP
//! socket backend (`super::socket`) — so the same reduce-scatter /
//! all-gather / broadcast code serves the data-parallel groups, the
//! tensor-parallel groups, and multi-process rings over real wires.

use std::sync::{Arc, Barrier};

use super::transport::{mpsc_ring, Transport};

/// How a ring synchronises: in-process groups share a [`Barrier`];
/// wire-backed groups (one rank per process) pass empty token frames
/// around the ring instead, since no shared memory exists.
enum RingBarrier {
    Local(Arc<Barrier>),
    Wire,
}

/// Per-rank communicator for a ring of `n` members, generic over the
/// transport that moves the chunks.
pub struct RingGroup {
    pub rank: usize,
    pub n: usize,
    port: Box<dyn Transport<Vec<f32>>>,
    barrier: RingBarrier,
    /// Total payload elements sent by this rank (traffic accounting).
    sent_elems: u64,
}

/// Build communicators for an `n`-rank ring over the in-process mpsc
/// transport. Index i talks to i+1 mod n.
pub fn ring_group(n: usize) -> Vec<RingGroup> {
    let barrier = Arc::new(Barrier::new(n.max(1)));
    mpsc_ring(n)
        .into_iter()
        .enumerate()
        .map(|(rank, port)| RingGroup::new(rank, n, Box::new(port), barrier.clone()))
        .collect()
}

/// Chunk boundaries: `n` nearly-equal chunks of a `len`-element buffer.
fn chunk_bounds(len: usize, n: usize, i: usize) -> (usize, usize) {
    let base = len / n;
    let rem = len % n;
    let start = i * base + i.min(rem);
    let size = base + usize::from(i < rem);
    (start, start + size)
}

impl RingGroup {
    /// Wrap a wired transport port as rank `rank` of an `n`-ring. The
    /// barrier must be shared by exactly the `n` members.
    pub fn new(
        rank: usize,
        n: usize,
        port: Box<dyn Transport<Vec<f32>>>,
        barrier: Arc<Barrier>,
    ) -> Self {
        RingGroup { rank, n, port, barrier: RingBarrier::Local(barrier), sent_elems: 0 }
    }

    /// Wrap a wire-backed (e.g. socket) transport port as rank `rank` of
    /// an `n`-ring whose members live in different processes: barriers
    /// run as token rounds over the port instead of a shared-memory
    /// [`Barrier`].
    pub fn new_wire(rank: usize, n: usize, port: Box<dyn Transport<Vec<f32>>>) -> Self {
        RingGroup { rank, n, port, barrier: RingBarrier::Wire, sent_elems: 0 }
    }

    /// Payload elements this rank has pushed onto the wire so far.
    pub fn sent_elems(&self) -> u64 {
        self.sent_elems
    }

    /// Synchronisation barrier across the group.
    ///
    /// Wire mode runs n−1 rounds of empty token frames around the ring:
    /// receiving round-k's token means the previous rank entered the
    /// barrier and had itself received k−1 tokens, so after n−1 rounds
    /// every member transitively has entered. Tokens carry no payload
    /// and bypass `sent_elems`, keeping traffic totals bit-identical to
    /// the shared-memory backend.
    pub fn barrier(&mut self) {
        match &self.barrier {
            RingBarrier::Local(b) => {
                b.wait();
            }
            RingBarrier::Wire => {
                for _ in 0..self.n.saturating_sub(1) {
                    self.port.send(Vec::new()).expect("ring peer hung up");
                    let token = self.port.recv().expect("ring peer hung up");
                    assert!(token.is_empty(), "data frame arrived during a barrier");
                }
            }
        }
    }

    fn send(&mut self, data: Vec<f32>) {
        self.sent_elems += data.len() as u64;
        // Receiver outliving sender is guaranteed by trainer shutdown
        // ordering; a send on a closed ring is a bug.
        self.port.send(data).expect("ring peer hung up");
    }

    fn recv(&mut self) -> Vec<f32> {
        self.port.recv().expect("ring peer hung up")
    }

    /// Ring all-reduce (sum): reduce-scatter then all-gather.
    /// All ranks end with identical, fully-summed buffers.
    pub fn all_reduce(&mut self, data: &mut [f32]) {
        if self.n == 1 {
            return;
        }
        self.reduce_scatter(data);
        self.all_gather_owned(data);
    }

    /// Ring reduce-scatter: afterwards, rank r holds the fully-reduced
    /// chunk `owned_chunk()` (other chunks are partial — callers either
    /// continue with `all_gather_owned` or use only their own chunk, as
    /// the ZeRO-style partition does).
    pub fn reduce_scatter(&mut self, data: &mut [f32]) {
        if self.n == 1 {
            return;
        }
        let n = self.n;
        for step in 0..n - 1 {
            let send_idx = (self.rank + n - step) % n;
            let recv_idx = (self.rank + n - step - 1) % n;
            let (a, b) = chunk_bounds(data.len(), n, send_idx);
            self.send(data[a..b].to_vec());
            let incoming = self.recv();
            let (a, b) = chunk_bounds(data.len(), n, recv_idx);
            for (d, x) in data[a..b].iter_mut().zip(&incoming) {
                *d += x;
            }
        }
    }

    /// The chunk index rank `rank` owns after [`Self::reduce_scatter`].
    pub fn owned_chunk(&self) -> usize {
        (self.rank + 1) % self.n
    }

    /// Element range of this rank's owned chunk in a `len` buffer.
    pub fn owned_range(&self, len: usize) -> (usize, usize) {
        chunk_bounds(len, self.n, self.owned_chunk())
    }

    /// Ring all-gather assuming each rank's `owned_chunk()` is complete
    /// (the state `reduce_scatter` leaves). Afterwards all chunks are
    /// complete everywhere.
    pub fn all_gather_owned(&mut self, data: &mut [f32]) {
        if self.n == 1 {
            return;
        }
        let n = self.n;
        for step in 0..n - 1 {
            let send_idx = (self.rank + 1 + n - step) % n;
            let recv_idx = (self.rank + n - step) % n;
            let (a, b) = chunk_bounds(data.len(), n, send_idx);
            self.send(data[a..b].to_vec());
            let incoming = self.recv();
            let (a, b) = chunk_bounds(data.len(), n, recv_idx);
            data[a..b].copy_from_slice(&incoming);
        }
    }

    /// Broadcast from `root` around the ring.
    pub fn broadcast(&mut self, data: &mut [f32], root: usize) {
        if self.n == 1 {
            return;
        }
        // Pass the buffer around the ring n-1 hops starting at root.
        let hops_from_root = (self.rank + self.n - root) % self.n;
        if hops_from_root == 0 {
            self.send(data.to_vec());
            let _ = self.recv(); // swallow the returning copy
        } else {
            let incoming = self.recv();
            data.copy_from_slice(&incoming);
            self.send(incoming);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::thread;

    fn run_group<F>(n: usize, f: F) -> Vec<Vec<f32>>
    where
        F: Fn(&mut RingGroup, &mut Vec<f32>) + Send + Sync + Copy + 'static,
    {
        let comms = ring_group(n);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|mut c| {
                thread::spawn(move || {
                    let mut data: Vec<f32> =
                        (0..10).map(|i| (c.rank * 100 + i) as f32).collect();
                    f(&mut c, &mut data);
                    data
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn all_reduce_sums_across_ranks() {
        for n in [1, 2, 3, 4, 7] {
            let results = run_group(n, |c, d| c.all_reduce(d));
            let want: Vec<f32> = (0..10)
                .map(|i| (0..n).map(|r| (r * 100 + i) as f32).sum())
                .collect();
            for (r, res) in results.iter().enumerate() {
                assert_eq!(res, &want, "n={n} rank={r}");
            }
        }
    }

    #[test]
    fn reduce_scatter_owned_chunk_is_complete() {
        let n = 4;
        let results = run_group(n, |c, d| {
            c.reduce_scatter(d);
            // Zero everything but the owned chunk, then all-gather to
            // verify the owned chunks alone reconstruct the full sum.
            let (a, b) = c.owned_range(d.len());
            for (i, v) in d.iter_mut().enumerate() {
                if i < a || i >= b {
                    *v = 0.0;
                }
            }
            c.all_gather_owned(d);
        });
        let want: Vec<f32> =
            (0..10).map(|i| (0..n).map(|r| (r * 100 + i) as f32).sum()).collect();
        for res in &results {
            assert_eq!(res, &want);
        }
    }

    #[test]
    fn broadcast_copies_root_buffer() {
        let results = run_group(3, |c, d| c.broadcast(d, 1));
        let want: Vec<f32> = (0..10).map(|i| (100 + i) as f32).collect();
        for res in &results {
            assert_eq!(res, &want);
        }
    }

    #[test]
    fn traffic_matches_ring_bound() {
        // All-reduce traffic per rank = 2·(n−1)/n·len elements.
        let comms = ring_group(4);
        let handles: Vec<_> = comms
            .into_iter()
            .map(|mut c| {
                thread::spawn(move || {
                    let mut d = vec![1.0f32; 1000];
                    c.all_reduce(&mut d);
                    c.sent_elems()
                })
            })
            .collect();
        for h in handles {
            let sent = h.join().unwrap();
            assert_eq!(sent, 2 * 3 * 250); // 2·(n−1)·chunk
        }
    }

    #[test]
    fn uneven_lengths_are_handled() {
        let results = run_group(3, |c, d| {
            d.truncate(7); // 7 elements over 3 ranks: chunks 3,2,2
            c.all_reduce(d);
        });
        let want: Vec<f32> = (0..7).map(|i| (0..3).map(|r| (r * 100 + i) as f32).sum()).collect();
        for res in &results {
            assert_eq!(res, &want);
        }
    }

    #[test]
    fn single_member_group_is_a_no_op_with_no_traffic() {
        let mut comms = ring_group(1);
        let c = &mut comms[0];
        let mut d = vec![3.5f32; 9];
        c.all_reduce(&mut d);
        c.reduce_scatter(&mut d);
        c.all_gather_owned(&mut d);
        c.broadcast(&mut d, 0);
        c.barrier();
        assert!(d.iter().all(|&v| v == 3.5));
        assert_eq!(c.sent_elems(), 0);
    }
}
