//! The byte-moving substrate of every [`crate::collective`] group.
//!
//! A [`Transport`] is one *directed duplex port*: `send` ships a message
//! toward this rank's designated peer(s), `recv` blocks for the next
//! inbound message. Which peer a port talks to is fixed at wiring time —
//! a ring port talks to the next/previous rank, a pipeline port to the
//! adjacent stage — so the collective algorithms above it stay
//! backend-agnostic: the in-process mpsc backend here is the first
//! implementation, and a socket/RDMA transport slots in per-port without
//! touching the ring or pipeline code.

use std::sync::mpsc::{channel, Receiver, Sender};
use std::time::Duration;

/// A peer endpoint disappeared mid-operation. During orderly trainer
/// shutdown receivers outlive senders, so seeing this means a peer
/// worker died (panicked or bailed) — callers surface it, they don't
/// retry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Disconnected;

impl std::fmt::Display for Disconnected {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.write_str("collective peer hung up")
    }
}

impl std::error::Error for Disconnected {}

/// One directed duplex port carrying `M`-typed messages between two
/// fixed peers. `send` must not block indefinitely on a live peer
/// (buffered delivery); `recv` blocks until a message or disconnect.
pub trait Transport<M: Send>: Send {
    fn send(&mut self, msg: M) -> Result<(), Disconnected>;
    fn recv(&mut self) -> Result<M, Disconnected>;
}

/// In-process mpsc implementation: an unbounded sender toward the peer
/// plus a receiver from (possibly a different) peer — exactly the shape
/// ring and pipeline wiring need, where "who I send to" and "who I hear
/// from" are distinct neighbours.
pub struct MpscPort<M> {
    tx: Sender<M>,
    rx: Receiver<M>,
}

impl<M> MpscPort<M> {
    pub fn new(tx: Sender<M>, rx: Receiver<M>) -> Self {
        MpscPort { tx, rx }
    }
}

impl<M: Send> Transport<M> for MpscPort<M> {
    fn send(&mut self, msg: M) -> Result<(), Disconnected> {
        self.tx.send(msg).map_err(|_| Disconnected)
    }

    fn recv(&mut self) -> Result<M, Disconnected> {
        self.rx.recv().map_err(|_| Disconnected)
    }
}

/// Shared ring-wiring core: rank `r` always sends on channel `r`; which
/// channel it *reads* fixes the ring's direction.
fn mpsc_ring_reading<M: Send>(n: usize, rx_of: impl Fn(usize) -> usize) -> Vec<MpscPort<M>> {
    assert!(n >= 1);
    let mut txs: Vec<Option<Sender<M>>> = Vec::with_capacity(n);
    let mut rxs: Vec<Option<Receiver<M>>> = Vec::with_capacity(n);
    for _ in 0..n {
        let (tx, rx) = channel();
        txs.push(Some(tx));
        rxs.push(Some(rx));
    }
    let mut ports = Vec::with_capacity(n);
    for r in 0..n {
        let tx = txs[r].take().unwrap();
        let rx = rxs[rx_of(r)].take().unwrap();
        ports.push(MpscPort::new(tx, rx));
    }
    ports
}

/// Wire `n` mpsc ports into a ring: port `r` sends to rank `r + 1 mod n`
/// and receives from rank `r − 1 mod n`. The wiring primitive behind
/// [`super::ring_group`] and the forward (activation) pipeline rings.
pub fn mpsc_ring<M: Send>(n: usize) -> Vec<MpscPort<M>> {
    mpsc_ring_reading(n, |r| (r + n - 1) % n)
}

/// The reversed ring: port `r` sends to rank `r − 1 mod n` (its channel
/// is read by `r − 1`) and receives from rank `r + 1 mod n` — the
/// gradient direction of the pipeline.
pub fn mpsc_ring_rev<M: Send>(n: usize) -> Vec<MpscPort<M>> {
    mpsc_ring_reading(n, |r| (r + 1) % n)
}

/// Scripted fault schedule for one [`FaultInjector`]-wrapped link,
/// keyed by the port's own operation index: sends and recvs share one
/// counter, bumped in call order. The collectives above are
/// deterministic, so a given schedule always hits the same op of the
/// same collective — which is what makes chaos runs replayable.
#[derive(Debug, Clone, Default)]
pub struct LinkFaults {
    /// `(op index, extra latency)`: sleep that long before the op runs.
    pub delays: Vec<(u64, Duration)>,
    /// Op indices that fail with [`Disconnected`] instead of running.
    pub tears: Vec<u64>,
}

/// Fault-injecting decorator over any [`Transport`]: replays a
/// [`LinkFaults`] schedule against the wrapped port. Delays model a
/// congested or flapping link (the op still completes, late); tears
/// model a dropped connection (the op fails with [`Disconnected`] and
/// the message never moves — exactly what a torn TCP stream surfaces).
pub struct FaultInjector<M: Send> {
    inner: Box<dyn Transport<M>>,
    faults: LinkFaults,
    ops: u64,
}

impl<M: Send> FaultInjector<M> {
    pub fn new(inner: Box<dyn Transport<M>>, faults: LinkFaults) -> Self {
        FaultInjector { inner, faults, ops: 0 }
    }

    /// Ops executed (or torn) so far on this link.
    pub fn ops(&self) -> u64 {
        self.ops
    }

    fn fault(&mut self) -> Result<(), Disconnected> {
        let op = self.ops;
        self.ops += 1;
        if let Some(&(_, d)) = self.faults.delays.iter().find(|(at, _)| *at == op) {
            std::thread::sleep(d);
        }
        if self.faults.tears.contains(&op) {
            return Err(Disconnected);
        }
        Ok(())
    }
}

impl<M: Send> Transport<M> for FaultInjector<M> {
    fn send(&mut self, msg: M) -> Result<(), Disconnected> {
        self.fault()?;
        self.inner.send(msg)
    }

    fn recv(&mut self) -> Result<M, Disconnected> {
        self.fault()?;
        self.inner.recv()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mpsc_ring_routes_to_the_next_rank() {
        let mut ports = mpsc_ring::<usize>(3);
        for (r, p) in ports.iter_mut().enumerate() {
            p.send(r).unwrap();
        }
        for (r, p) in ports.iter_mut().enumerate() {
            // Rank r hears from rank r−1.
            assert_eq!(p.recv().unwrap(), (r + 3 - 1) % 3);
        }
    }

    #[test]
    fn mpsc_ring_rev_routes_to_the_previous_rank() {
        let mut ports = mpsc_ring_rev::<usize>(3);
        for (r, p) in ports.iter_mut().enumerate() {
            p.send(r).unwrap();
        }
        for (r, p) in ports.iter_mut().enumerate() {
            // Rank r hears from rank r+1.
            assert_eq!(p.recv().unwrap(), (r + 1) % 3);
        }
    }

    #[test]
    fn disconnect_is_reported_not_panicked() {
        let mut ports = mpsc_ring::<u8>(2);
        ports.remove(1); // drop the peer
        let p = &mut ports[0];
        assert_eq!(p.send(1), Err(Disconnected));
        assert_eq!(p.recv(), Err(Disconnected));
    }

    #[test]
    fn single_rank_ring_talks_to_itself() {
        let mut ports = mpsc_ring::<u8>(1);
        ports[0].send(7).unwrap();
        assert_eq!(ports[0].recv().unwrap(), 7);
    }

    fn payload(r: usize) -> Vec<f32> {
        // Awkward length: chunk boundaries uneven across the ring.
        (0..33).map(|k| ((r * 100 + k) as f32).sin()).collect()
    }

    fn run_pair(groups: Vec<crate::collective::RingGroup>) -> Vec<Vec<f32>> {
        let handles: Vec<_> = groups
            .into_iter()
            .enumerate()
            .map(|(r, mut g)| {
                std::thread::spawn(move || {
                    let mut d = payload(r);
                    g.all_reduce(&mut d);
                    d
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().unwrap()).collect()
    }

    #[test]
    fn delayed_link_all_reduce_stays_bit_identical() {
        use crate::collective::{ring_group, RingGroup};
        let clean = run_pair(ring_group(2));
        let faults = LinkFaults {
            delays: vec![(0, Duration::from_millis(5)), (3, Duration::from_millis(5))],
            tears: vec![],
        };
        let mut ports = mpsc_ring::<Vec<f32>>(2).into_iter();
        let slow = FaultInjector::new(Box::new(ports.next().unwrap()), faults);
        let groups = vec![
            RingGroup::new_wire(0, 2, Box::new(slow)),
            RingGroup::new_wire(1, 2, Box::new(ports.next().unwrap())),
        ];
        let delayed = run_pair(groups);
        for (r, (a, b)) in clean.iter().zip(&delayed).enumerate() {
            assert_eq!(a.len(), b.len(), "rank {r}");
            for (k, (x, y)) in a.iter().zip(b).enumerate() {
                assert_eq!(x.to_bits(), y.to_bits(), "rank {r} elem {k}: {x} vs {y}");
            }
        }
    }

    #[test]
    fn torn_link_surfaces_disconnected_at_the_scripted_op() {
        let faults = LinkFaults { delays: vec![], tears: vec![2] };
        let mut ports = mpsc_ring::<u8>(1); // self-loop carries the data
        let mut p = FaultInjector::new(Box::new(ports.remove(0)), faults);
        p.send(1).unwrap(); // op 0
        assert_eq!(p.recv().unwrap(), 1); // op 1
        assert_eq!(p.send(2), Err(Disconnected), "op 2 is scripted to tear");
        // The tear models one dropped connection, not a dead link:
        // later ops run again (reconnect policy lives a layer above).
        p.send(3).unwrap();
        assert_eq!(p.recv().unwrap(), 3);
        assert_eq!(p.ops(), 5);
    }
}
