//! `CommWorld`: the per-rank process-group handle every trainer worker
//! communicates through.
//!
//! A training job is a 3-D grid of ranks, [`Topology`] `{stages, dp,
//! tp}` (pipeline × data-parallel × tensor-parallel — the same axes the
//! planner's `TrainConfig` prices as n_l, n_b, n_a). Each rank holds one
//! `CommWorld`, built once by [`CommWorld::build`], exposing typed
//! sub-groups instead of loose channels:
//!
//! * [`CommWorld::pipeline`] — p2p send/recv of [`PipeMsg`] along the
//!   stage axis (activations forward, gradients backward);
//! * [`CommWorld::dp_group`] — the ring spanning the data-parallel axis
//!   (gradient all-reduce / reduce-scatter, parameter all-gather);
//! * [`CommWorld::tp_group`] — the ring spanning the tensor-parallel
//!   axis: the cut-point all-reduces of sharded column/row-parallel
//!   execution (the scheduled per-layer `TensorAllReduce` plus the
//!   mid-layer and layernorm-gradient reduces the worker issues
//!   in-op), or the amortised C.4.3 reduce under replicated emulation;
//! * [`CommWorld::control`] — loss reporting back to the coordinator.
//!
//! Degenerate axes stay uniform: a size-1 ring is a no-op group (its
//! collectives return immediately and count zero traffic), so callers
//! never branch on "is there a group". Every group counts the payload
//! elements it puts on the wire; [`CommWorld::traffic`] reports them
//! per-group for `WorkerStats` and the traffic-accounting tests.
//!
//! All groups run over the [`super::transport::Transport`] trait.
//! [`CommWorld::build`] wires a whole topology over the in-process mpsc
//! backend (threads in one process); `super::socket::connect_world`
//! assembles the identical group structure per *process* over TCP via
//! [`CommWorld::from_parts`], with the control plane switching from an
//! mpsc sender to a framed socket back to the launch coordinator.

use std::io::{BufWriter, Write};
use std::net::TcpStream;
use std::sync::mpsc::{channel, Receiver, Sender};

use super::ring::{ring_group, RingGroup};
use super::socket::{write_frame, CtrlMsg, RankStats};
use super::transport::{mpsc_ring, mpsc_ring_rev, Disconnected, MpscPort, Transport};

/// A pipeline message: (consumer layer, micro-batch, payload).
pub type PipeMsg = (usize, usize, Vec<f32>);

/// A control-plane loss report: (step, dp rank, mean micro-batch loss).
pub type LossMsg = (usize, usize, f64);

/// Shape of the rank grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Topology {
    /// Pipeline stages (n_l).
    pub stages: usize,
    /// Data-parallel degree (n_b).
    pub dp: usize,
    /// Tensor-parallel degree (n_a).
    pub tp: usize,
}

impl Topology {
    pub fn new(stages: usize, dp: usize, tp: usize) -> Self {
        assert!(stages >= 1 && dp >= 1 && tp >= 1, "degenerate topology");
        Topology { stages, dp, tp }
    }

    /// Total ranks in the grid.
    pub fn n_ranks(&self) -> usize {
        self.stages * self.dp * self.tp
    }

    /// Flat index of a rank in [`CommWorld::build`]'s output order
    /// (dp-major, then stage, then tp).
    pub fn index(&self, rank: Rank) -> usize {
        (rank.dp * self.stages + rank.stage) * self.tp + rank.tp
    }

    /// Inverse of [`Topology::index`]: the grid coordinates of flat
    /// rank `index` (what a spawned worker process is handed).
    pub fn rank_at(&self, index: usize) -> Rank {
        assert!(index < self.n_ranks(), "rank index {index} out of range");
        Rank {
            tp: index % self.tp,
            stage: (index / self.tp) % self.stages,
            dp: index / (self.tp * self.stages),
        }
    }
}

/// One rank's coordinates in the grid.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Rank {
    pub stage: usize,
    pub dp: usize,
    pub tp: usize,
}

/// Point-to-point pipeline group: this rank's ports on the activation
/// ring (toward the next stage) and the gradient ring (toward the
/// previous stage), with payload-element accounting on the send side.
pub struct PipelineGroup {
    act: Box<dyn Transport<PipeMsg>>,
    grad: Box<dyn Transport<PipeMsg>>,
    sent_elems: u64,
}

impl PipelineGroup {
    /// Wrap wired activation/gradient ports (any transport backend) as
    /// this rank's pipeline group.
    pub fn new(act: Box<dyn Transport<PipeMsg>>, grad: Box<dyn Transport<PipeMsg>>) -> Self {
        PipelineGroup { act, grad, sent_elems: 0 }
    }

    /// Ship a micro-batch's activations to the next stage.
    pub fn send_act(
        &mut self,
        layer: usize,
        mb: usize,
        payload: Vec<f32>,
    ) -> Result<(), Disconnected> {
        self.sent_elems += payload.len() as u64;
        self.act.send((layer, mb, payload))
    }

    /// Block for the next inbound activation.
    pub fn recv_act(&mut self) -> Result<PipeMsg, Disconnected> {
        self.act.recv()
    }

    /// Ship an input-gradient back to the previous stage.
    pub fn send_grad(
        &mut self,
        layer: usize,
        mb: usize,
        payload: Vec<f32>,
    ) -> Result<(), Disconnected> {
        self.sent_elems += payload.len() as u64;
        self.grad.send((layer, mb, payload))
    }

    /// Block for the next inbound output-gradient.
    pub fn recv_grad(&mut self) -> Result<PipeMsg, Disconnected> {
        self.grad.recv()
    }

    /// Payload elements this rank has sent on both pipeline rings.
    pub fn sent_elems(&self) -> u64 {
        self.sent_elems
    }
}

/// Where a rank's control-plane reports go: an in-process mpsc sender
/// (thread-backed worlds) or a framed socket toward the launch
/// coordinator (process-backed worlds).
enum ControlSink {
    Mpsc(Sender<LossMsg>),
    Wire(BufWriter<TcpStream>),
}

/// Control plane: loss and end-of-run stats reporting toward the
/// coordinator. Send-only; the coordinator holds the receiving end
/// (the [`CommWorld::build`] receiver, or the rendezvous control
/// stream). Reports after the coordinator stopped listening are
/// dropped (normal during shutdown), not errors.
pub struct ControlGroup {
    sink: ControlSink,
}

impl ControlGroup {
    /// In-process control plane feeding the build-time loss receiver.
    pub(super) fn mpsc(tx: Sender<LossMsg>) -> Self {
        ControlGroup { sink: ControlSink::Mpsc(tx) }
    }

    /// Socket control plane: the rendezvous connection, reused for
    /// loss/stats streaming back to the launch coordinator.
    pub fn wire(stream: TcpStream) -> Self {
        ControlGroup { sink: ControlSink::Wire(BufWriter::new(stream)) }
    }

    pub fn report_loss(&mut self, step: usize, dp: usize, loss: f64) {
        match &mut self.sink {
            ControlSink::Mpsc(tx) => {
                let _ = tx.send((step, dp, loss));
            }
            ControlSink::Wire(w) => {
                let msg = CtrlMsg::Loss { step: step as u64, dp: dp as u32, loss };
                let _ = write_frame(w, &msg).and_then(|()| w.flush());
            }
        }
    }

    /// Per-step liveness heartbeat: "this rank completed `step`". A
    /// no-op on the mpsc backend (threads share a fate — per-rank
    /// liveness is meaningless); on the wire the launch supervisor uses
    /// it to attribute stalls and kills to a specific rank and to know
    /// each rank's last completed step.
    pub fn report_progress(&mut self, step: usize) {
        if let ControlSink::Wire(w) = &mut self.sink {
            let msg = CtrlMsg::Progress { step: step as u64 };
            let _ = write_frame(w, &msg).and_then(|()| w.flush());
        }
    }

    /// Ship this rank's end-of-run statistics. A no-op on the mpsc
    /// backend (stats return through the thread join); on the wire the
    /// coordinator needs them streamed, followed by a `Done` marker.
    pub fn report_stats(&mut self, stats: RankStats) {
        if let ControlSink::Wire(w) = &mut self.sink {
            let _ = write_frame(w, &CtrlMsg::Stats(stats))
                .and_then(|()| write_frame(w, &CtrlMsg::Done))
                .and_then(|()| w.flush());
        }
    }
}

/// Per-group wire-traffic totals (payload elements sent by this rank).
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct Traffic {
    pub pipeline: u64,
    pub dp: u64,
    pub tp: u64,
}

impl Traffic {
    /// The same totals as bytes on the wire, at `elem_bytes` per
    /// payload element (the runtime dtype's width — `DType::bytes()`).
    pub fn bytes(&self, elem_bytes: usize) -> Traffic {
        let b = elem_bytes as u64;
        Traffic { pipeline: self.pipeline * b, dp: self.dp * b, tp: self.tp * b }
    }

    /// Sum across groups.
    pub fn total(&self) -> u64 {
        self.pipeline + self.dp + self.tp
    }
}

/// One rank's handle on every communicator of the job.
pub struct CommWorld {
    rank: Rank,
    topo: Topology,
    pipeline: PipelineGroup,
    dp: RingGroup,
    tp: RingGroup,
    control: ControlGroup,
}

impl CommWorld {
    /// Wire a whole topology over the in-process mpsc transport: one
    /// `CommWorld` per rank (ordered by [`Topology::index`]) plus the
    /// coordinator's end of the control plane.
    ///
    /// Groups per rank `(stage, dp, tp)`:
    /// * pipeline rings span the stage axis, one pair per (dp, tp);
    /// * the dp ring spans the data-parallel axis, one per (stage, tp);
    /// * the tp ring spans the tensor-parallel axis, one per (dp, stage).
    pub fn build(topo: Topology) -> (Vec<CommWorld>, Receiver<LossMsg>) {
        assert!(topo.stages >= 1 && topo.dp >= 1 && topo.tp >= 1, "degenerate topology");
        let (loss_tx, loss_rx) = channel::<LossMsg>();

        // Pipeline ports per (dp, tp): a forward act ring and a reversed
        // grad ring over the stages. `take`-able option grids.
        let mut acts: Vec<Option<MpscPort<PipeMsg>>> = Vec::new();
        let mut grads: Vec<Option<MpscPort<PipeMsg>>> = Vec::new();
        for _ in 0..topo.dp * topo.tp {
            acts.extend(mpsc_ring::<PipeMsg>(topo.stages).into_iter().map(Some));
            grads.extend(mpsc_ring_rev::<PipeMsg>(topo.stages).into_iter().map(Some));
        }
        let pipe_at = |dp: usize, tp: usize, stage: usize| {
            (dp * topo.tp + tp) * topo.stages + stage
        };

        // DP rings per (stage, tp), spanning the dp axis.
        let mut dp_rings: Vec<Option<RingGroup>> = Vec::new();
        for _ in 0..topo.stages * topo.tp {
            dp_rings.extend(ring_group(topo.dp).into_iter().map(Some));
        }
        let dp_at = |stage: usize, tp: usize, dp: usize| {
            (stage * topo.tp + tp) * topo.dp + dp
        };

        // TP rings per (dp, stage), spanning the tp axis.
        let mut tp_rings: Vec<Option<RingGroup>> = Vec::new();
        for _ in 0..topo.dp * topo.stages {
            tp_rings.extend(ring_group(topo.tp).into_iter().map(Some));
        }
        let tp_at = |dp: usize, stage: usize, tp: usize| {
            (dp * topo.stages + stage) * topo.tp + tp
        };

        let mut worlds = Vec::with_capacity(topo.n_ranks());
        for dp in 0..topo.dp {
            for stage in 0..topo.stages {
                for tp in 0..topo.tp {
                    let rank = Rank { stage, dp, tp };
                    let pipeline = PipelineGroup {
                        act: Box::new(acts[pipe_at(dp, tp, stage)].take().unwrap()),
                        grad: Box::new(grads[pipe_at(dp, tp, stage)].take().unwrap()),
                        sent_elems: 0,
                    };
                    worlds.push(CommWorld {
                        rank,
                        topo,
                        pipeline,
                        dp: dp_rings[dp_at(stage, tp, dp)].take().unwrap(),
                        tp: tp_rings[tp_at(dp, stage, tp)].take().unwrap(),
                        control: ControlGroup::mpsc(loss_tx.clone()),
                    });
                }
            }
        }
        (worlds, loss_rx)
    }

    /// Assemble one rank's world from externally wired groups — the
    /// socket backend's entry point (`super::socket::connect_world`),
    /// and the seam any future transport plugs into.
    pub fn from_parts(
        rank: Rank,
        topo: Topology,
        pipeline: PipelineGroup,
        dp: RingGroup,
        tp: RingGroup,
        control: ControlGroup,
    ) -> Self {
        CommWorld { rank, topo, pipeline, dp, tp, control }
    }

    /// This rank's grid coordinates.
    pub fn rank(&self) -> Rank {
        self.rank
    }

    /// The job's rank-grid shape.
    pub fn topology(&self) -> Topology {
        self.topo
    }

    /// The p2p pipeline group (activations forward, gradients backward).
    pub fn pipeline(&mut self) -> &mut PipelineGroup {
        &mut self.pipeline
    }

    /// The data-parallel ring (size `topology().dp`; size-1 is a no-op
    /// group).
    pub fn dp_group(&mut self) -> &mut RingGroup {
        &mut self.dp
    }

    /// The tensor-parallel ring (size `topology().tp`; size-1 is a no-op
    /// group).
    pub fn tp_group(&mut self) -> &mut RingGroup {
        &mut self.tp
    }

    /// The control plane (loss reporting).
    pub fn control(&mut self) -> &mut ControlGroup {
        &mut self.control
    }

    /// End-of-step synchronisation: barrier on the dp and tp rings this
    /// rank belongs to (size-1 rings return immediately). Keeps the lag
    /// between any two ranks of a group bounded to the step in flight —
    /// the invariant the checkpoint-retention pruning relies on.
    pub fn step_barrier(&mut self) {
        self.dp.barrier();
        self.tp.barrier();
    }

    /// Per-group payload elements this rank has sent.
    pub fn traffic(&self) -> Traffic {
        Traffic {
            pipeline: self.pipeline.sent_elems(),
            dp: self.dp.sent_elems(),
            tp: self.tp.sent_elems(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::{Arc, Barrier};
    use std::thread;

    fn barrier_arc(n: usize) -> Arc<Barrier> {
        Arc::new(Barrier::new(n))
    }

    #[test]
    fn topology_index_is_a_bijection() {
        let t = Topology::new(3, 2, 2);
        let mut seen = vec![false; t.n_ranks()];
        for dp in 0..2 {
            for stage in 0..3 {
                for tp in 0..2 {
                    let i = t.index(Rank { stage, dp, tp });
                    assert!(!seen[i], "index collision at {i}");
                    seen[i] = true;
                }
            }
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn rank_at_inverts_index() {
        let t = Topology::new(3, 2, 2);
        for i in 0..t.n_ranks() {
            assert_eq!(t.index(t.rank_at(i)), i, "index {i}");
        }
    }

    #[test]
    fn build_order_matches_topology_index() {
        let t = Topology::new(2, 2, 2);
        let (worlds, _rx) = CommWorld::build(t);
        assert_eq!(worlds.len(), t.n_ranks());
        for (i, w) in worlds.iter().enumerate() {
            assert_eq!(t.index(w.rank()), i);
            assert_eq!(w.topology(), t);
        }
    }

    #[test]
    fn pipeline_routes_acts_forward_and_grads_backward() {
        let t = Topology::new(3, 1, 1);
        let (worlds, _rx) = CommWorld::build(t);
        let handles: Vec<_> = worlds
            .into_iter()
            .map(|mut w| {
                thread::spawn(move || {
                    let s = w.rank().stage;
                    if s + 1 < 3 {
                        w.pipeline().send_act(s + 1, 0, vec![s as f32]).unwrap();
                    }
                    if s > 0 {
                        w.pipeline().send_grad(s - 1, 0, vec![-(s as f32)]).unwrap();
                    }
                    let mut got = Vec::new();
                    if s > 0 {
                        let (l, mb, p) = w.pipeline().recv_act().unwrap();
                        got.push((l, mb, p[0]));
                    }
                    if s + 1 < 3 {
                        let (l, mb, p) = w.pipeline().recv_grad().unwrap();
                        got.push((l, mb, p[0]));
                    }
                    (s, got, w.traffic())
                })
            })
            .collect();
        for h in handles {
            let (s, got, traffic) = h.join().unwrap();
            // Acts come from stage s−1 addressed to layer s; grads from
            // stage s+1 addressed to layer s.
            if s > 0 {
                assert!(got.contains(&(s, 0, (s - 1) as f32)), "stage {s}: {got:?}");
            }
            if s + 1 < 3 {
                assert!(got.contains(&(s, 0, -((s + 1) as f32))), "stage {s}: {got:?}");
            }
            let sends = usize::from(s + 1 < 3) + usize::from(s > 0);
            assert_eq!(traffic.pipeline, sends as u64);
            assert_eq!(traffic.dp, 0);
            assert_eq!(traffic.tp, 0);
        }
    }

    #[test]
    fn tp_ring_spans_the_tensor_axis_only() {
        // 1 stage, 2 dp, 2 tp: each (dp, stage) pair owns a private tp
        // ring — summing rank-coloured data must mix tp ranks of the
        // same dp instance and nothing else.
        let t = Topology::new(1, 2, 2);
        let (worlds, _rx) = CommWorld::build(t);
        let handles: Vec<_> = worlds
            .into_iter()
            .map(|mut w| {
                thread::spawn(move || {
                    let r = w.rank();
                    // Value encodes (dp, tp) so cross-group mixing is
                    // detectable: dp contributes 100s, tp contributes 1s.
                    let mut d = vec![(100 * r.dp + r.tp) as f32, 1.0];
                    w.tp_group().all_reduce(&mut d);
                    (r, d[0], w.traffic())
                })
            })
            .collect();
        for h in handles {
            let (r, v, traffic) = h.join().unwrap();
            // Sum over tp ∈ {0, 1} of (100·dp + tp) = 200·dp + 1.
            assert_eq!(v, (200 * r.dp + 1) as f32, "rank {r:?}");
            // All-reduce of 2 elements over 2 ranks: each rank sends
            // 2·(n−1)/n·len = 2 elements.
            assert_eq!(traffic.tp, 2);
            assert_eq!(traffic.dp, 0);
        }
    }

    #[test]
    fn dp_ring_spans_the_data_axis_only() {
        let t = Topology::new(2, 2, 1);
        let (worlds, _rx) = CommWorld::build(t);
        let handles: Vec<_> = worlds
            .into_iter()
            .map(|mut w| {
                thread::spawn(move || {
                    let r = w.rank();
                    let mut d = vec![(100 * r.stage + r.dp) as f32, 1.0];
                    w.dp_group().all_reduce(&mut d);
                    (r, d[0], d[1])
                })
            })
            .collect();
        for h in handles {
            let (r, v, ones) = h.join().unwrap();
            // Sum over dp ∈ {0, 1} of (100·stage + dp) = 200·stage + 1.
            assert_eq!(v, (200 * r.stage + 1) as f32, "rank {r:?}");
            assert_eq!(ones, 2.0);
        }
    }

    #[test]
    fn degenerate_axes_are_no_op_groups() {
        let t = Topology::new(1, 1, 1);
        let (mut worlds, _rx) = CommWorld::build(t);
        let w = &mut worlds[0];
        let mut d = vec![2.0f32; 4];
        w.dp_group().all_reduce(&mut d);
        w.tp_group().all_reduce(&mut d);
        w.step_barrier();
        assert_eq!(d, vec![2.0; 4]);
        assert_eq!(w.traffic(), Traffic::default());
    }

    #[test]
    fn control_plane_reaches_the_coordinator() {
        let t = Topology::new(1, 2, 1);
        let (worlds, rx) = CommWorld::build(t);
        for mut w in worlds {
            let dp = w.rank().dp;
            w.control().report_loss(3, dp, dp as f64 + 0.5);
        }
        let mut got: Vec<LossMsg> = rx.try_iter().collect();
        got.sort_by_key(|&(_, dp, _)| dp);
        assert_eq!(got, vec![(3, 0, 0.5), (3, 1, 1.5)]);
    }

    #[test]
    fn ring_group_new_composes_with_custom_wiring() {
        // The RingGroup constructor is public so non-mpsc transports (or
        // custom wirings like this 2-ring) can form groups directly.
        let ports = mpsc_ring::<Vec<f32>>(2);
        let b = barrier_arc(2);
        let groups: Vec<RingGroup> = ports
            .into_iter()
            .enumerate()
            .map(|(r, p)| RingGroup::new(r, 2, Box::new(p), b.clone()))
            .collect();
        let handles: Vec<_> = groups
            .into_iter()
            .map(|mut g| {
                thread::spawn(move || {
                    let mut d = vec![g.rank as f32 + 1.0; 6];
                    g.all_reduce(&mut d);
                    d
                })
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), vec![3.0; 6]);
        }
    }
}
