//! In-memory collectives for the real trainer: ring all-reduce,
//! reduce-scatter and all-gather over std mpsc channels, one `Comm` per
//! rank. The ring algorithm is the bandwidth-optimal one the paper's
//! C.4.1 traffic accounting assumes (each rank sends/receives
//! 2·(n−1)/n of the buffer for an all-reduce).

pub mod ring;

pub use ring::{ring_group, Comm};
