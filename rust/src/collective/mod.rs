//! Worker communication: process groups over pluggable transports.
//!
//! The crate's communication layer is organised in three levels:
//!
//! 1. [`transport`] — the byte-moving substrate. A [`Transport`] is one
//!    directed duplex port between fixed peers; the in-process mpsc
//!    implementation ([`transport::MpscPort`]) is the first backend, and
//!    a socket/RDMA port can replace it without touching anything above.
//! 2. [`ring`] — SPMD ring collectives ([`RingGroup`]): all-reduce,
//!    reduce-scatter, all-gather, broadcast over any transport. Chunk
//!    boundaries are deterministic, so results are bit-identical across
//!    ranks and across runs; per-rank traffic matches the
//!    bandwidth-optimal 2·(n−1)/n bound the paper's C.4.1 accounting
//!    assumes.
//! 3. [`world`] — the process-group API the trainer programs against:
//!    one [`CommWorld`] per rank of a [`Topology`] `{stages, dp, tp}`,
//!    exposing the pipeline p2p group, the data-parallel ring, the
//!    tensor-parallel ring and the control plane, each with per-group
//!    traffic accounting ([`world::Traffic`]).
//!
//! Built once in `trainer::train` and handed to each worker as the
//! single communication handle in `WorkerCtx` — there are no raw
//! channels in the trainer any more.

pub mod ring;
pub mod transport;
pub mod world;

pub use ring::{ring_group, RingGroup};
pub use transport::{Disconnected, Transport};
pub use world::{CommWorld, LossMsg, PipeMsg, Rank, Topology, Traffic};
