//! Worker communication: process groups over pluggable transports.
//!
//! The crate's communication layer is organised in three levels:
//!
//! 1. [`transport`] — the byte-moving substrate. A [`Transport`] is one
//!    directed duplex port between fixed peers, with two backends: the
//!    in-process mpsc port ([`transport::MpscPort`]) for thread-backed
//!    worlds, and the TCP socket port ([`socket::SocketPort`]) —
//!    length-prefixed binary frames, buffered writer, dedicated reader
//!    thread — for process-backed (and multi-host) worlds. Everything
//!    above is backend-agnostic.
//! 2. [`ring`] — SPMD ring collectives ([`RingGroup`]): all-reduce,
//!    reduce-scatter, all-gather, broadcast over any transport. Chunk
//!    boundaries are deterministic, so results are bit-identical across
//!    ranks, across runs, *and across backends*; per-rank traffic
//!    matches the bandwidth-optimal 2·(n−1)/n bound the paper's C.4.1
//!    accounting assumes.
//! 3. [`world`] — the process-group API the trainer programs against:
//!    one [`CommWorld`] per rank of a [`Topology`] `{stages, dp, tp}`,
//!    exposing the pipeline p2p group, the data-parallel ring, the
//!    tensor-parallel ring and the control plane, each with per-group
//!    traffic accounting ([`world::Traffic`]). [`CommWorld::build`]
//!    wires all ranks over mpsc in one process;
//!    [`socket::connect_world`] wires one rank per process over TCP
//!    after a coordinator rendezvous ([`socket::Coordinator`]), with
//!    losses and [`socket::RankStats`] streaming back over the control
//!    connection.
//!
//! Built once per rank (by `trainer::train` for threads, `repro
//! worker` via `trainer::launch` for processes) and handed to each
//! worker as the single communication handle in `WorkerCtx` — there
//! are no raw channels in the trainer any more.

pub mod ring;
pub mod socket;
pub mod transport;
pub mod world;

pub use ring::{ring_group, RingGroup};
pub use socket::{
    netbench, socket_pair, socket_ring, connect_world, connect_world_opts, Coordinator, CtrlMsg,
    NetProbe, RankStats, ReconnectConfig, ReconnectPort, SocketPort, Wire, WorldOptions,
};
pub use transport::{Disconnected, FaultInjector, LinkFaults, Transport};
pub use world::{CommWorld, ControlGroup, LossMsg, PipeMsg, PipelineGroup, Rank, Topology, Traffic};
