//! `repro chaos` — fault-injected elastic training.
//!
//! The paper's availability argument (§8.2, Figure 2: real-time
//! checkpoints make the restore ratio `2·d_l` instead of `2·d_l·n_μ`)
//! is only worth anything if the system actually *survives* the faults
//! it prices. This module drives long trainings while injecting faults
//! from a seeded, scriptable schedule and asserts the final loss
//! trajectory still matches an uninterrupted reference run:
//!
//! * **Rank kills** — a worker crashes after completing `at_step`
//!   steps. In the in-process driver the whole incarnation ends there
//!   and the job resumes from the latest complete checkpoint —
//!   optionally under a *different* topology (`dp`/`n_μ`/`tp` picked
//!   via [`crate::elastic::cluster_schedule`]), exercising the elastic
//!   re-sharding resume path. Over real processes,
//!   [`super::launch::LaunchOptions::kill_plan`] delivers a true
//!   SIGKILL mid-step and the supervisor restarts the incarnation.
//! * **Torn stores** — a crash mid-checkpoint-write: a garbage
//!   in-flight tmp record plus a lost published record in the newest
//!   step directory. Readers must ignore the former and the
//!   completeness cover must reject the latter, falling back one step.
//! * **Torn / delayed links** — scripted at the transport layer by
//!   [`crate::collective::FaultInjector`] and absorbed by the
//!   reconnecting socket port ([`crate::collective::ReconnectPort`]);
//!   unit-tested there.
//!
//! Determinism is the point: the same seed yields the same fault
//! sequence, and the trainer's math is deterministic per topology, so
//! a chaos run is replayable end to end.

use std::path::{Path, PathBuf};

use anyhow::{Context, Result};

use crate::costmodel::{MemoryBreakdown, Strategy, TrainConfig};
use crate::elastic::cluster_schedule;
use crate::hardware::ClusterSpec;
use crate::model::XModel;
use crate::sim::Xorshift;

use super::launch::{launch_local_opts, LaunchOptions, LaunchReport};
use super::{train, TrainReport, TrainerConfig};

/// Topology a killed job revives under. The global batch must be
/// preserved (`n_b · n_mu` constant) — that is the resume contract —
/// so a revive only re-shards the same training trajectory.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Revive {
    pub n_b: usize,
    pub n_mu: usize,
    pub tp: usize,
}

/// One scripted fault.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ChaosEvent {
    /// `rank` dies having completed steps `0..at_step`; the job
    /// revives under `revive` from the latest complete checkpoint.
    Kill { at_step: usize, rank: usize, revive: Revive },
    /// The newest checkpoint step is torn mid-write at `at_step`: a
    /// garbage in-flight tmp record appears and one published record
    /// of that step is lost, so resume must fall back one step.
    TearStore { at_step: usize },
}

impl ChaosEvent {
    pub fn at_step(&self) -> usize {
        match self {
            ChaosEvent::Kill { at_step, .. } | ChaosEvent::TearStore { at_step } => *at_step,
        }
    }
}

/// A seeded, scriptable fault schedule.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ChaosPlan {
    pub seed: u64,
    pub events: Vec<ChaosEvent>,
}

/// Largest divisor of `g` that is ≤ `target` (≥ 1): clamps an elastic
/// cluster-size suggestion to a data-parallel degree that preserves
/// the global micro-batch count.
fn clamp_to_divisor(g: usize, target: usize) -> usize {
    (1..=g).filter(|d| g % d == 0 && *d <= target.max(1)).max().unwrap_or(1)
}

/// Grow a revived data-parallel degree to the smallest divisor of `g`
/// (at least `n_b`) whose re-sharded optimizer state fits `budget`
/// bytes per device. The elastic suggestion is throughput-driven and
/// knows nothing about state feasibility: shrinking dp concentrates
/// the 1/dp ZeRO (or partition) shards onto fewer devices, and a
/// revive that cannot hold its own optimizer state is dead on arrival.
fn clamp_to_state_budget(model: &XModel, g: usize, n_b: usize, budget: f64) -> usize {
    let shape = model.shape();
    (n_b..=g)
        .filter(|d| g % d == 0)
        .find(|&d| {
            let cfg = TrainConfig {
                strategy: Strategy::Improved,
                n_b: d,
                n_l: 1,
                n_a: 1,
                n_mu: g / d,
                b_mu: 1.0,
                offload: false,
                partition: false,
                // Stages 1–2 are the most state-hungry sharded shape
                // (params replicated, only the moments split 1/dp), so
                // a dp that holds them holds every stage.
                zero: 2,
            };
            MemoryBreakdown::evaluate(&shape, &cfg).state <= budget
        })
        .unwrap_or(g)
}

/// Generate a deterministic chaos schedule: `kills` rank kills at
/// seeded steps, each reviving under a topology suggested by the §8.1
/// elastic cluster schedule at that point of training (clamped to a
/// divisor of the global batch `n_b · n_mu`), plus one torn store.
/// Draws come from the shared [`Xorshift`] generator — the same
/// recurrence this module used to inline, so old seeds replay the same
/// schedules.
pub fn seeded_plan(seed: u64, steps: usize, n_b: usize, n_mu: usize, kills: usize) -> ChaosPlan {
    let g = (n_b * n_mu).max(1);
    let span = steps.saturating_sub(1).max(1);
    let mut rng = Xorshift::new(seed);
    // The elastic schedule says how many workers training *wants* at
    // each progress fraction; a kill at step s revives onto that size,
    // grown if needed until the re-sharded optimizer state fits the
    // reference device budget (the clamp draws nothing from the rng,
    // so old seeds replay the same fault sequence).
    let model = XModel::new(32);
    let budget = ClusterSpec::reference().gpu.memory_bytes;
    let sched = cluster_schedule(&model, g, steps.max(1), 0.05);
    let mut events = Vec::with_capacity(kills + 1);
    for _ in 0..kills {
        let at_step = 1 + (rng.next_u64() as usize) % span;
        let rank = (rng.next_u64() as usize) % g;
        let suggested = sched[at_step.min(sched.len() - 1)].1;
        let n_b2 = clamp_to_state_budget(&model, g, clamp_to_divisor(g, suggested), budget);
        let tp = 1 + (rng.next_u64() % 2) as usize;
        events.push(ChaosEvent::Kill {
            at_step,
            rank,
            revive: Revive { n_b: n_b2, n_mu: g / n_b2, tp },
        });
    }
    events.push(ChaosEvent::TearStore { at_step: 1 + (rng.next_u64() as usize) % span });
    events.sort_by_key(|e| e.at_step());
    ChaosPlan { seed, events }
}

/// Result of a chaos run: the uninterrupted reference trajectory, the
/// stitched fault-injected trajectory, and what was injected.
#[derive(Debug, Clone)]
pub struct ChaosReport {
    pub reference: Vec<f64>,
    pub chaos: Vec<f64>,
    pub kills: usize,
    pub torn_stores: usize,
    pub topology_changes: usize,
    /// Whether any revive changed the tensor-parallel degree — the
    /// re-sharded resume path is tolerance-exact, not bit-exact.
    pub tp_resharded: bool,
    /// Largest per-step |chaos − reference| (infinite if the chaos run
    /// left any reference step uncovered).
    pub max_abs_diff: f64,
}

impl ChaosReport {
    /// Acceptance tolerance: the PR 5 re-sharding bound when a revive
    /// changed tp, the dp-change resume bound otherwise.
    pub fn tolerance(&self) -> f64 {
        if self.tp_resharded {
            5e-3
        } else {
            3e-3
        }
    }
}

/// Overlay one (possibly resumed) segment's losses onto the stitched
/// trajectory: later segments overwrite re-executed steps.
fn record(into: &mut [f64], r: &TrainReport) {
    for (i, l) in r.losses.iter().enumerate() {
        let s = r.start_step + i;
        if s < into.len() {
            into[s] = *l;
        }
    }
}

/// Inject a torn checkpoint: a garbage in-flight `.tmp_` record (which
/// readers must skip) plus one lost published record in the newest
/// step directory (which breaks that step's completeness cover).
/// Returns whether a published record was actually torn.
fn tear_newest_record(root: &Path) -> Result<bool> {
    let mut steps: Vec<(u64, PathBuf)> = Vec::new();
    for e in std::fs::read_dir(root).with_context(|| format!("listing store {root:?}"))? {
        let e = e?;
        let name = e.file_name();
        let name = name.to_string_lossy();
        if let Some(n) = name.strip_prefix("step_").and_then(|s| s.parse::<u64>().ok()) {
            steps.push((n, e.path()));
        }
    }
    steps.sort();
    let Some((_, newest)) = steps.pop() else { return Ok(false) };
    std::fs::write(newest.join(".tmp_torn_0_0"), b"torn mid-write")
        .with_context(|| format!("planting torn tmp record in {newest:?}"))?;
    let mut recs: Vec<PathBuf> = Vec::new();
    for e in std::fs::read_dir(&newest)? {
        let e = e?;
        let name = e.file_name();
        let name = name.to_string_lossy();
        if name.starts_with("slot_") && name.ends_with(".ckpt") {
            recs.push(e.path());
        }
    }
    recs.sort();
    match recs.first() {
        Some(p) => {
            std::fs::remove_file(p).with_context(|| format!("tearing {p:?}"))?;
            Ok(true)
        }
        None => Ok(false),
    }
}

/// Run the fault-injected training and its uninterrupted reference
/// over the in-process mpsc world, and compare trajectories.
///
/// `cfg` must stream checkpoints to a durable store (`offload` +
/// `store_dir`); the store directory and a `_reference`-suffixed
/// sibling are wiped first. Each [`ChaosEvent::Kill`] ends the current
/// incarnation after `at_step` completed steps and resumes from the
/// latest complete checkpoint under the event's revive topology; each
/// [`ChaosEvent::TearStore`] corrupts the newest checkpoint step so
/// the resume falls back one step and re-executes it.
pub fn run_chaos(cfg: &TrainerConfig, plan: &ChaosPlan) -> Result<ChaosReport> {
    anyhow::ensure!(
        cfg.offload && cfg.store_dir.is_some(),
        "chaos needs --offload and --store DIR (faults are survived via the durable store)"
    );
    anyhow::ensure!(cfg.steps >= 2, "chaos needs at least 2 steps");
    let dir = cfg.store_dir.clone().expect("checked above");
    let mut ref_os = dir.clone().into_os_string();
    ref_os.push("_reference");
    let ref_dir = PathBuf::from(ref_os);
    let _ = std::fs::remove_dir_all(&dir);
    let _ = std::fs::remove_dir_all(&ref_dir);

    // Uninterrupted reference: the trajectory every fault-injected
    // incarnation must still reproduce.
    let mut ref_cfg = cfg.clone();
    ref_cfg.resume = false;
    ref_cfg.store_dir = Some(ref_dir);
    let reference = train(&ref_cfg).context("uninterrupted reference run")?.losses;

    let mut events = plan.events.clone();
    events.sort_by_key(|e| e.at_step());

    let mut chaos = vec![f64::NAN; cfg.steps];
    let mut cur = cfg.clone();
    cur.resume = false;
    let (mut kills, mut torn, mut topo_changes) = (0usize, 0usize, 0usize);
    let mut tp_resharded = false;
    for ev in &events {
        let mut seg = cur.clone();
        seg.steps = ev.at_step().min(cfg.steps);
        let r = train(&seg)
            .with_context(|| format!("chaos segment ending at step {}", seg.steps))?;
        record(&mut chaos, &r);
        match ev {
            ChaosEvent::Kill { rank: _, revive, .. } => {
                kills += 1;
                anyhow::ensure!(
                    revive.n_b * revive.n_mu == cfg.n_b * cfg.n_mu,
                    "revive {revive:?} changes the global batch — the resume contract \
                     requires n_b * n_mu to stay {}",
                    cfg.n_b * cfg.n_mu
                );
                if (revive.n_b, revive.n_mu, revive.tp) != (cur.n_b, cur.n_mu, cur.tp) {
                    topo_changes += 1;
                }
                if revive.tp != cur.tp {
                    tp_resharded = true;
                }
                cur.n_b = revive.n_b;
                cur.n_mu = revive.n_mu;
                cur.tp = revive.tp;
            }
            ChaosEvent::TearStore { .. } => {
                if tear_newest_record(&dir)? {
                    torn += 1;
                }
            }
        }
        cur.resume = true;
    }
    // Final incarnation: run to the end.
    let mut seg = cur.clone();
    seg.steps = cfg.steps;
    let r = train(&seg).context("final chaos segment")?;
    record(&mut chaos, &r);

    let mut max_abs_diff = 0.0f64;
    for (a, b) in reference.iter().zip(&chaos) {
        let d = if b.is_finite() { (a - b).abs() } else { f64::INFINITY };
        max_abs_diff = max_abs_diff.max(d);
    }
    Ok(ChaosReport {
        reference,
        chaos,
        kills,
        torn_stores: torn,
        topology_changes: topo_changes,
        tp_resharded,
        max_abs_diff,
    })
}

/// Artifact-free chaos smoke over real processes: run the socket
/// connectivity probe with a kill plan that SIGKILLs one rank mid-run,
/// and assert the supervisor restarted the job and the merged loss
/// trajectory is exactly what an uninterrupted probe reports.
pub fn chaos_probe(steps: usize) -> Result<LaunchReport> {
    let mut cfg = TrainerConfig::quick("tiny");
    cfg.n_b = 2;
    cfg.n_l = 1;
    cfg.tp = 1;
    cfg.n_mu = 1;
    cfg.steps = steps;
    let mut flags: Vec<String> = ["--preset", "tiny", "--dp", "2", "--pp", "1", "--tp", "1"]
        .iter()
        .map(|s| s.to_string())
        .collect();
    flags.push("--steps".to_string());
    flags.push(steps.to_string());
    flags.push("--probe".to_string());
    // Pace the probe so the scripted kill lands mid-run, not after the
    // victim already finished.
    std::env::set_var("REPRO_PROBE_STEP_MS", "20");
    let opts = LaunchOptions { kill_plan: vec![(2, 1)], ..LaunchOptions::default() };
    let out = launch_local_opts(&cfg, &flags, &opts);
    std::env::remove_var("REPRO_PROBE_STEP_MS");
    let r = out?;
    anyhow::ensure!(r.restarts >= 1, "the kill plan fired but no restart was recorded");
    let got = r.report.losses.len();
    anyhow::ensure!(got == steps, "probe reported {got} steps, want {steps}");
    for (i, l) in r.report.losses.iter().enumerate() {
        anyhow::ensure!(
            *l == (i + 1) as f64,
            "merged probe loss at step {i} is {l}, want {} — restart rounds must merge \
             into the uninterrupted trajectory",
            i + 1
        );
    }
    Ok(r)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_replays_the_same_fault_sequence() {
        let a = seeded_plan(42, 100, 2, 4, 3);
        let b = seeded_plan(42, 100, 2, 4, 3);
        assert_eq!(a, b);
        // And the seed actually matters: not every seed collapses to
        // one schedule.
        let plans: Vec<ChaosPlan> = (0..8).map(|s| seeded_plan(s, 100, 2, 4, 3)).collect();
        assert!(plans.iter().any(|p| *p != plans[0]), "all 8 seeds produced the same plan");
    }

    #[test]
    fn seeded_events_respect_the_resume_contract() {
        for seed in 0..16 {
            let plan = seeded_plan(seed, 50, 2, 4, 4);
            assert_eq!(plan.events.len(), 5); // 4 kills + 1 torn store
            assert!(plan.events.windows(2).all(|w| w[0].at_step() <= w[1].at_step()));
            for e in &plan.events {
                assert!(e.at_step() >= 1 && e.at_step() < 50, "{e:?}");
                if let ChaosEvent::Kill { revive, .. } = e {
                    assert_eq!(revive.n_b * revive.n_mu, 8, "{e:?}");
                    assert!(revive.tp == 1 || revive.tp == 2, "{e:?}");
                }
            }
        }
    }

    #[test]
    fn divisor_clamp_preserves_the_global_batch() {
        assert_eq!(clamp_to_divisor(8, 5), 4);
        assert_eq!(clamp_to_divisor(8, 8), 8);
        assert_eq!(clamp_to_divisor(8, 1), 1);
        assert_eq!(clamp_to_divisor(8, 0), 1);
        assert_eq!(clamp_to_divisor(6, 4), 3);
    }

    #[test]
    fn state_budget_clamp_grows_dp_until_the_shards_fit() {
        let model = XModel::new(32);
        // A generous budget leaves the suggestion alone.
        assert_eq!(clamp_to_state_budget(&model, 8, 2, f64::INFINITY), 2);
        // A budget that only fits the fully-spread shards forces dp up
        // to the full group.
        assert_eq!(clamp_to_state_budget(&model, 8, 1, 0.0), 8);
        // In between, the clamp lands on the smallest divisor whose
        // zero-2 state term fits: (4 + 8/dp)·p per device.
        let p = model.params();
        let mid = (4.0 + 8.0 / 4.0) * p; // fits at dp = 4, not below
        assert_eq!(clamp_to_state_budget(&model, 8, 1, mid), 4);
        // The result always divides the global batch.
        for b in [0.0, mid, f64::INFINITY] {
            assert_eq!(8 % clamp_to_state_budget(&model, 8, 1, b), 0);
        }
    }

    #[test]
    fn torn_store_injection_needs_a_store() {
        let dir = std::env::temp_dir().join(format!("lga_tear_{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&dir);
        std::fs::create_dir_all(&dir).unwrap();
        // Empty store: nothing to tear.
        assert!(!tear_newest_record(&dir).unwrap());
        // A populated step loses exactly one published record and
        // gains a garbage tmp file.
        let step = dir.join("step_00000003");
        std::fs::create_dir_all(&step).unwrap();
        std::fs::write(step.join("slot_00000_0_10.ckpt"), b"x").unwrap();
        std::fs::write(step.join("slot_00001_0_10.ckpt"), b"y").unwrap();
        assert!(tear_newest_record(&dir).unwrap());
        assert!(!step.join("slot_00000_0_10.ckpt").exists());
        assert!(step.join("slot_00001_0_10.ckpt").exists());
        assert!(step.join(".tmp_torn_0_0").exists());
        let _ = std::fs::remove_dir_all(&dir);
    }
}
