//! Trainer configuration and the schedule-policy switch.

use std::path::PathBuf;

use crate::optim::LrSchedule;
use crate::schedule::{
    layered_ga, modular_pipeline, one_f_one_b, standard_ga, Schedule, ScheduleSpec,
};

/// Which scheduling policy drives the workers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Policy {
    /// Standard gradient accumulation / contiguous (GPipe-style) pipeline.
    Baseline,
    /// Layered gradient accumulation + modular pipeline (this paper).
    Improved,
    /// 1F1B (PipeDream-flush) ablation.
    OneFOneB,
}

impl Policy {
    pub fn name(self) -> &'static str {
        match self {
            Policy::Baseline => "baseline",
            Policy::Improved => "improved",
            Policy::OneFOneB => "1f1b",
        }
    }
}

/// Full configuration of a real training run.
#[derive(Debug, Clone)]
pub struct TrainerConfig {
    pub artifacts_root: PathBuf,
    pub preset: String,
    /// Data-parallel degree.
    pub n_b: usize,
    /// Pipeline stages.
    pub n_l: usize,
    /// Micro-batches per step per data-parallel instance.
    pub n_mu: usize,
    /// Tensor-parallel degree (n_a). Each pipeline stage spans `tp`
    /// ranks executing the per-layer `TensorAllReduce` collectives of
    /// C.4.3 over the [`crate::collective::CommWorld`] tp group — truly
    /// sharded column/row-parallel compute when the manifest carries the
    /// `_tp<d>` half-layer artifacts, replicated-compute emulation
    /// otherwise; 1 disables tensor parallelism.
    pub tp: usize,
    /// Force replicated-compute emulation even when sharded artifacts
    /// are available — the mode whose tp = 2 loss trajectory bit-matches
    /// tp = 1 (sharded execution matches within tolerance instead).
    pub force_tp_emulation: bool,
    pub policy: Policy,
    /// ZeRO-3-style state partition over the data-parallel group.
    pub partition: bool,
    /// ZeRO stage (0–3, Rajbhandari et al.) over the data-parallel
    /// group: stage ≥1 shards the Adam moments 1/dp and rebuilds full
    /// params with a post-step all-gather, stage ≥2 reduce-scatters the
    /// gradients instead of all-reducing, stage 3 gathers params before
    /// each use (FSDP-style). Mutually exclusive with `partition`.
    pub zero: u8,
    /// Stream the training state to a checkpoint store after every
    /// optimizer step (§8.2 real-time checkpoints): the schedule gains
    /// RestoreParams/OffloadStore ops and the workers execute them.
    pub offload: bool,
    /// Directory of the durable [`crate::offload::FileStore`]; `None`
    /// keeps the stream in a process-local
    /// [`crate::offload::MemoryStore`] (byte-accounted, not durable).
    pub store_dir: Option<PathBuf>,
    /// Resume from the latest *complete* checkpoint in the store instead
    /// of initialising from the seed. The data-parallel degree may
    /// differ from the writer's — shards are re-sliced on load (§8.1
    /// elastic resume).
    pub resume: bool,
    pub steps: usize,
    pub lr: LrSchedule,
    pub seed: u64,
}

impl TrainerConfig {
    pub fn quick(preset: &str) -> Self {
        TrainerConfig {
            artifacts_root: PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts"),
            preset: preset.to_string(),
            n_b: 1,
            n_l: 1,
            n_mu: 1,
            tp: 1,
            force_tp_emulation: false,
            policy: Policy::Improved,
            partition: false,
            zero: 0,
            offload: false,
            store_dir: None,
            resume: false,
            steps: 10,
            lr: LrSchedule::constant(1e-3),
            seed: 0,
        }
    }

    /// Build the schedule for `d_l` model layers under this config.
    pub fn build_schedule(&self, d_l: usize) -> Schedule {
        let spec = ScheduleSpec {
            d_l,
            n_l: self.n_l,
            n_mu: self.n_mu,
            tp: self.tp,
            partition: self.partition,
            offload: self.offload,
            data_parallel: self.n_b > 1,
            zero: self.zero,
        };
        match (self.policy, self.n_l) {
            (Policy::Improved, 1) => layered_ga(&spec),
            (Policy::Improved, _) => modular_pipeline(&spec),
            (Policy::Baseline, _) => standard_ga(&spec),
            (Policy::OneFOneB, _) => one_f_one_b(&spec),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn offload_flag_reaches_the_schedule() {
        let mut c = TrainerConfig::quick("tiny");
        c.n_mu = 2;
        assert!(!c.build_schedule(2).offloaded);
        c.offload = true;
        let s = c.build_schedule(2);
        assert!(s.offloaded);
        assert_eq!(
            s.count(|o| matches!(o, crate::schedule::Op::OffloadStore { .. })),
            2,
            "one store per layer"
        );
    }

    #[test]
    fn tp_flag_reaches_the_schedule() {
        let mut c = TrainerConfig::quick("tiny");
        c.n_mu = 2;
        assert_eq!(c.build_schedule(2).tp, 1);
        assert_eq!(
            c.build_schedule(2)
                .count(|o| matches!(o, crate::schedule::Op::TensorAllReduce { .. })),
            0
        );
        c.tp = 2;
        let s = c.build_schedule(2);
        assert_eq!(s.tp, 2);
        // One amortised all-reduce per (layer, micro-batch) phase.
        assert_eq!(
            s.count(|o| matches!(o, crate::schedule::Op::TensorAllReduce { .. })),
            2 * 2 * 2,
        );
    }

    #[test]
    fn zero_flag_reaches_the_schedule() {
        let mut c = TrainerConfig::quick("tiny");
        c.n_mu = 2;
        c.n_b = 2;
        c.zero = 2;
        let s = c.build_schedule(2);
        assert_eq!(s.zero, 2);
        assert_eq!(
            s.count(|o| matches!(o, crate::schedule::Op::ReduceScatterGrad { .. })),
            2,
            "one reduce-scatter per layer"
        );
        assert_eq!(
            s.count(|o| matches!(o, crate::schedule::Op::AllGatherParams { .. })),
            2,
            "one post-step gather per layer"
        );
    }

    #[test]
    fn policy_schedule_mapping() {
        let mut c = TrainerConfig::quick("tiny");
        c.n_mu = 2;
        assert_eq!(c.build_schedule(2).name, "layered-ga");
        c.policy = Policy::Baseline;
        assert_eq!(c.build_schedule(2).name, "standard-ga");
        c.n_l = 2;
        assert_eq!(c.build_schedule(2).name, "standard-pipeline");
        c.policy = Policy::Improved;
        assert_eq!(c.build_schedule(2).name, "modular-pipeline");
        c.policy = Policy::OneFOneB;
        assert_eq!(c.build_schedule(2).name, "1f1b");
    }
}
