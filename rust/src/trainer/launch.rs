//! Multi-process launch: fork real worker processes, rendezvous them
//! into a socket-wired [`CommWorld`], and merge their control-plane
//! reports into one [`TrainReport`].
//!
//! Three entry points, one protocol:
//!
//! * [`launch_local`] — `repro launch`: spawn `n_ranks` copies of the
//!   current executable as `repro worker --rank i --coord <addr>` over
//!   loopback, coordinate, and merge. [`launch_local_opts`] adds the
//!   supervision knobs: a configurable inactivity timeout, a shared
//!   auth token, and an elastic restart budget — when a worker dies
//!   mid-run the whole incarnation is torn down and every rank is
//!   relaunched under a bumped generation, resuming from the latest
//!   complete checkpoint when the job has a durable store.
//! * [`coordinate_external`] — `repro launch --coord-bind`: run only
//!   the coordinator on a fixed address; workers are started by hand
//!   (or a cluster scheduler) on other hosts with `REPRO_HOSTMAP` set.
//! * [`launch_threads`] — the in-process test harness: every rank is a
//!   thread but the full socket stack (rendezvous, TCP rings, framed
//!   control plane) is exercised; the socket-vs-mpsc parity suite runs
//!   through this.
//!
//! The coordinator drains each rank's control stream: per-step
//! [`CtrlMsg::Loss`] reports (dp-averaged exactly like the thread
//! backend), a [`CtrlMsg::Progress`] heartbeat after every step, and
//! exactly one [`CtrlMsg::Stats`] per rank. A worker that dies early
//! shows up as a stream without stats — under [`launch_local_opts`]
//! that triggers a restart round instead of failing the job, and a
//! stalled job is killed with an error naming the laggard rank and its
//! last completed step, never a hang.

use std::net::TcpStream;
use std::path::Path;
use std::process::{Child, Command, Stdio};
use std::sync::mpsc::{channel, RecvTimeoutError, Sender};
use std::thread;
use std::time::{Duration, Instant};

use anyhow::{bail, Context, Result};

use crate::collective::socket::read_frame;
use crate::collective::{
    connect_world, connect_world_opts, CommWorld, Coordinator, CtrlMsg, RankStats, Topology, Wire,
    WorldOptions,
};
use crate::runtime::DType;

use super::{train_rank, TrainReport, TrainerConfig};

/// Default deadline for rendezvous, connection handshakes and
/// steady-state inactivity (no control frame from any rank). Override
/// with `repro launch --timeout-secs` or `REPRO_LAUNCH_TIMEOUT`.
pub const LAUNCH_TIMEOUT: Duration = Duration::from_secs(120);

/// Supervision knobs for [`launch_local_opts`].
#[derive(Debug, Clone)]
pub struct LaunchOptions {
    /// Rendezvous deadline *and* steady-state inactivity bound: if no
    /// rank produces a control frame for this long the job is killed
    /// with an error naming the stalled rank.
    pub timeout: Duration,
    /// Shared rendezvous secret (`REPRO_AUTH_TOKEN` in the workers).
    /// `None` generates a per-launch token so stray processes can
    /// never join a loopback job.
    pub auth_token: Option<String>,
    /// How many whole-job restart rounds a dying worker may trigger
    /// before the launch gives up.
    pub max_restarts: usize,
    /// Chaos hook: `(step, rank)` pairs — when `rank` reports progress
    /// at or past `step`, it is SIGKILLed. Each entry fires once.
    pub kill_plan: Vec<(u64, usize)>,
}

impl Default for LaunchOptions {
    fn default() -> Self {
        let timeout = std::env::var("REPRO_LAUNCH_TIMEOUT")
            .ok()
            .and_then(|v| v.parse::<u64>().ok())
            .map(Duration::from_secs)
            .unwrap_or(LAUNCH_TIMEOUT);
        LaunchOptions { timeout, auth_token: None, max_restarts: 2, kill_plan: Vec::new() }
    }
}

/// A merged multi-process run: the coordinator's view of the job plus
/// each rank's own statistics.
#[derive(Debug, Clone)]
pub struct LaunchReport {
    pub report: TrainReport,
    /// Per-rank stats, index = rank (the `WorkerStats` the thread
    /// backend would have joined on, shipped over the control plane).
    /// After an elastic restart these come from the final incarnation.
    pub per_rank: Vec<RankStats>,
    /// Whole-job restart rounds the supervisor performed (0 for a
    /// clean run).
    pub restarts: usize,
}

/// Read control frames until the worker closes its stream.
fn drain_ctrl(stream: TcpStream) -> Result<Vec<CtrlMsg>> {
    let mut r = std::io::BufReader::new(stream);
    let mut msgs = Vec::new();
    loop {
        match read_frame(&mut r) {
            Ok(buf) => msgs.push(CtrlMsg::decode(&buf).context("control frame")?),
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::UnexpectedEof
                        | std::io::ErrorKind::ConnectionReset
                        | std::io::ErrorKind::ConnectionAborted
                ) =>
            {
                return Ok(msgs)
            }
            Err(e) => return Err(e).context("control stream"),
        }
    }
}

/// Persistent per-step loss accumulator: survives restart rounds so a
/// resumed incarnation's reports merge with its predecessor's (a
/// re-executed step simply averages both incarnations' identical
/// values).
struct MergeAcc {
    sums: Vec<f64>,
    counts: Vec<usize>,
}

impl MergeAcc {
    fn new(steps: usize) -> Self {
        MergeAcc { sums: vec![0.0; steps], counts: vec![0; steps] }
    }

    fn add(&mut self, step: u64, loss: f64) {
        let step = step as usize;
        if step < self.sums.len() {
            self.sums[step] += loss;
            self.counts[step] += 1;
        }
    }
}

/// Fold per-rank stats and the accumulated losses into one report.
fn merge_report(
    acc: &MergeAcc,
    per_rank: Vec<RankStats>,
    wall_secs: f64,
    restarts: usize,
) -> Result<LaunchReport> {
    // Config skew across processes shows up as disagreeing schedules —
    // catch it here rather than as silent divergence.
    let schedule_name = per_rank[0].schedule.clone();
    for (rank, s) in per_rank.iter().enumerate() {
        anyhow::ensure!(
            s.schedule == schedule_name,
            "rank {rank} ran schedule {:?} while rank 0 ran {:?} — mismatched worker configs",
            s.schedule,
            schedule_name
        );
    }

    let losses: Vec<f64> = acc
        .sums
        .iter()
        .zip(&acc.counts)
        .map(|(s, &c)| if c > 0 { s / c as f64 } else { f64::NAN })
        .collect();
    let sum = |f: fn(&RankStats) -> u64| per_rank.iter().map(f).sum::<u64>();
    let elem_bytes = DType::F32.bytes() as u64;
    let (dp_e, pipe_e, tp_e) = (
        sum(|s| s.collective_elems_sent),
        sum(|s| s.pipeline_elems_sent),
        sum(|s| s.tp_elems_sent),
    );
    let report = TrainReport {
        losses,
        start_step: 0,
        wall_secs,
        collective_elems_sent: dp_e,
        pipeline_elems_sent: pipe_e,
        tp_elems_sent: tp_e,
        collective_bytes_sent: dp_e * elem_bytes,
        pipeline_bytes_sent: pipe_e * elem_bytes,
        tp_bytes_sent: tp_e * elem_bytes,
        tp_sharded: per_rank[0].tp_sharded,
        max_layer_state_bytes: per_rank.iter().map(|s| s.layer_state_bytes).max().unwrap_or(0),
        max_state_bytes: per_rank.iter().map(|s| s.total_state_bytes).max().unwrap_or(0),
        execute_secs: per_rank.iter().map(|s| s.execute_secs).sum(),
        execute_calls: sum(|s| s.execute_calls),
        checkpoint_bytes_written: 0,
        checkpoint_records: 0,
        schedule_name,
    };
    Ok(LaunchReport { report, per_rank, restarts })
}

/// Run the coordinator half of a launch: rendezvous `n` workers, drain
/// their control streams, and merge losses + stats into one report.
/// The drain-to-EOF protocol (no supervision, no restarts) — the
/// thread-harness and external-coordinator path.
fn coordinate(
    coord: &Coordinator,
    n: usize,
    steps: usize,
    timeout: Duration,
) -> Result<LaunchReport> {
    let t0 = Instant::now();
    let streams = coord.rendezvous(timeout).context("rendezvous")?;
    let drains: Vec<_> = streams
        .into_iter()
        .enumerate()
        .map(|(rank, s)| {
            thread::Builder::new()
                .name(format!("ctrl-drain-{rank}"))
                .spawn(move || drain_ctrl(s))
                .expect("spawn control drain thread")
        })
        .collect();

    let mut acc = MergeAcc::new(steps);
    let mut per_rank: Vec<RankStats> = Vec::with_capacity(n);
    for (rank, h) in drains.into_iter().enumerate() {
        let msgs = h.join().map_err(|_| anyhow::anyhow!("control drain panicked"))?;
        let msgs = msgs.with_context(|| format!("rank {rank} control stream"))?;
        let mut stats: Option<RankStats> = None;
        for m in msgs {
            match m {
                CtrlMsg::Loss { step, dp: _, loss } => acc.add(step, loss),
                CtrlMsg::Stats(s) => stats = Some(s),
                CtrlMsg::Progress { .. } | CtrlMsg::Done => {}
                CtrlMsg::Hello { .. } | CtrlMsg::Peers { .. } => {
                    bail!("rank {rank} sent a rendezvous message mid-run")
                }
            }
        }
        per_rank.push(stats.with_context(|| {
            format!("rank {rank} exited without reporting stats (worker crashed?)")
        })?);
    }
    merge_report(&acc, per_rank, t0.elapsed().as_secs_f64(), 0)
}

fn kill_all(children: &mut [Child]) {
    for c in children.iter_mut() {
        let _ = c.kill();
    }
}

/// Kill and reap every child (between restart rounds: exit statuses of
/// a torn-down incarnation are expected to be failures).
fn reap_all(children: &mut Vec<Child>) {
    kill_all(children);
    for mut c in children.drain(..) {
        let _ = c.wait();
    }
}

/// One event from a rank's control-stream drain thread.
enum DrainEvent {
    Msg(CtrlMsg),
    Eof,
    Err(String),
}

fn drain_to(rank: usize, stream: TcpStream, tx: Sender<(usize, DrainEvent)>) {
    let mut r = std::io::BufReader::new(stream);
    loop {
        match read_frame(&mut r) {
            Ok(buf) => match CtrlMsg::decode(&buf) {
                Ok(m) => {
                    if tx.send((rank, DrainEvent::Msg(m))).is_err() {
                        return;
                    }
                }
                Err(e) => {
                    let _ = tx.send((rank, DrainEvent::Err(format!("control frame: {e}"))));
                    return;
                }
            },
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::UnexpectedEof
                        | std::io::ErrorKind::ConnectionReset
                        | std::io::ErrorKind::ConnectionAborted
                ) =>
            {
                let _ = tx.send((rank, DrainEvent::Eof));
                return;
            }
            Err(e) => {
                let _ = tx.send((rank, DrainEvent::Err(e.to_string())));
                return;
            }
        }
    }
}

/// Outcome of one supervised incarnation of the job.
enum Round {
    /// Every rank reported stats and closed its stream cleanly.
    Done(Vec<RankStats>),
    /// A rank's stream ended before it reported stats — the process
    /// died (crash or chaos SIGKILL).
    WorkerDied { rank: usize, last_step: Option<u64> },
}

/// Supervised coordination of one process incarnation: rendezvous
/// under `generation`, then multiplex every rank's control stream
/// through one event channel so death, progress and inactivity are
/// observed *live* (the drain-to-EOF path would block on rank order
/// while a dead rank's peers hang in a collective).
#[allow(clippy::too_many_arguments)]
fn coordinate_processes(
    coord: &Coordinator,
    children: &mut Vec<Child>,
    n: usize,
    generation: u64,
    opts: &LaunchOptions,
    acc: &mut MergeAcc,
    kill_plan: &mut Vec<(u64, usize)>,
) -> Result<Round> {
    let streams = coord.rendezvous_gen(opts.timeout, generation).context("rendezvous")?;
    let (tx, rx) = channel::<(usize, DrainEvent)>();
    for (rank, s) in streams.into_iter().enumerate() {
        let tx = tx.clone();
        thread::Builder::new()
            .name(format!("ctrl-drain-{rank}"))
            .spawn(move || drain_to(rank, s, tx))
            .expect("spawn control drain thread");
    }
    drop(tx);

    let mut per_rank: Vec<Option<RankStats>> = vec![None; n];
    let mut last_step: Vec<Option<u64>> = vec![None; n];
    let mut eofs = 0usize;
    while eofs < n {
        match rx.recv_timeout(opts.timeout) {
            Ok((rank, DrainEvent::Msg(m))) => match m {
                CtrlMsg::Loss { step, dp: _, loss } => acc.add(step, loss),
                CtrlMsg::Progress { step } => {
                    last_step[rank] = Some(step);
                    if let Some(i) =
                        kill_plan.iter().position(|&(at, kr)| kr == rank && step >= at)
                    {
                        kill_plan.remove(i);
                        let _ = children[rank].kill();
                    }
                }
                CtrlMsg::Stats(s) => per_rank[rank] = Some(s),
                CtrlMsg::Done => {}
                CtrlMsg::Hello { .. } | CtrlMsg::Peers { .. } => {
                    bail!("rank {rank} sent a rendezvous message mid-run")
                }
            },
            Ok((rank, DrainEvent::Eof)) => {
                if per_rank[rank].is_none() {
                    return Ok(Round::WorkerDied { rank, last_step: last_step[rank] });
                }
                eofs += 1;
            }
            Ok((rank, DrainEvent::Err(e))) => {
                if per_rank[rank].is_none() {
                    eprintln!("[launch] rank {rank} control stream error: {e}");
                    return Ok(Round::WorkerDied { rank, last_step: last_step[rank] });
                }
                eofs += 1;
            }
            Err(RecvTimeoutError::Timeout) => {
                kill_all(children);
                let stalled = (0..n)
                    .filter(|&r| per_rank[r].is_none())
                    .min_by_key(|&r| last_step[r].map(|s| s + 1).unwrap_or(0))
                    .unwrap_or(0);
                let at = match last_step[stalled] {
                    Some(s) => format!("after completing step {s}"),
                    None => "before completing any step".to_string(),
                };
                bail!(
                    "no worker activity for {:.0?}: rank {stalled} stalled {at} \
                     (raise --timeout-secs / REPRO_LAUNCH_TIMEOUT if the steps are just slow)",
                    opts.timeout
                );
            }
            Err(RecvTimeoutError::Disconnected) => break,
        }
    }
    let mut stats = Vec::with_capacity(n);
    for (rank, s) in per_rank.into_iter().enumerate() {
        stats.push(s.with_context(|| {
            format!("rank {rank} exited without reporting stats (worker crashed?)")
        })?);
    }
    Ok(Round::Done(stats))
}

fn spawn_ranks(
    exe: &Path,
    n: usize,
    addr: &str,
    worker_flags: &[String],
    generation: u64,
    token: &str,
    timeout: Duration,
) -> Result<Vec<Child>> {
    let mut children: Vec<Child> = Vec::with_capacity(n);
    for rank in 0..n {
        let child = Command::new(exe)
            .arg("worker")
            .arg("--rank")
            .arg(rank.to_string())
            .arg("--coord")
            .arg(addr)
            .arg("--generation")
            .arg(generation.to_string())
            .args(worker_flags)
            .env("REPRO_AUTH_TOKEN", token)
            .env("REPRO_LAUNCH_TIMEOUT", timeout.as_secs().max(1).to_string())
            .stdin(Stdio::null())
            .spawn()
            .with_context(|| format!("spawn worker rank {rank}"));
        match child {
            Ok(c) => children.push(c),
            Err(e) => {
                reap_all(&mut children);
                return Err(e);
            }
        }
    }
    Ok(children)
}

/// Fork one `repro worker` process per rank over loopback, coordinate
/// the run, and merge the result. `worker_flags` is forwarded verbatim
/// to every child (preset, topology, steps, …).
pub fn launch_local(cfg: &TrainerConfig, worker_flags: &[String]) -> Result<LaunchReport> {
    launch_local_opts(cfg, worker_flags, &LaunchOptions::default())
}

/// [`launch_local`] with supervision: an elastic restart loop. When a
/// worker dies mid-run, the whole incarnation is killed (its peers are
/// wedged in collectives anyway), the generation is bumped so stale
/// sockets can never rejoin, and every rank is relaunched — with
/// `--resume` appended when the job has a durable store, so training
/// continues from the latest complete checkpoint instead of step 0.
pub fn launch_local_opts(
    cfg: &TrainerConfig,
    worker_flags: &[String],
    opts: &LaunchOptions,
) -> Result<LaunchReport> {
    let topo = Topology::new(cfg.n_l, cfg.n_b, cfg.tp);
    let n = topo.n_ranks();
    let coord = Coordinator::bind("127.0.0.1:0", n).context("bind coordinator")?;
    let addr = coord.local_addr()?.to_string();
    let token = opts.auth_token.clone().unwrap_or_else(|| {
        let port = addr.rsplit(':').next().unwrap_or("0");
        format!("repro-{}-{}", std::process::id(), port)
    });
    let coord = coord.with_token(&token);
    let exe = std::env::current_exe().context("locate current executable")?;

    let t0 = Instant::now();
    let mut acc = MergeAcc::new(cfg.steps);
    let mut kill_plan = opts.kill_plan.clone();
    let mut generation: u64 = 0;
    let mut restarts = 0usize;
    loop {
        let mut flags = worker_flags.to_vec();
        if generation > 0 && cfg.store_dir.is_some() && !flags.iter().any(|f| f == "--resume") {
            flags.push("--resume".to_string());
        }
        let mut children = spawn_ranks(&exe, n, &addr, &flags, generation, &token, opts.timeout)?;
        let round = coordinate_processes(
            &coord,
            &mut children,
            n,
            generation,
            opts,
            &mut acc,
            &mut kill_plan,
        );
        match round {
            Ok(Round::Done(per_rank)) => {
                let mut failures = Vec::new();
                for (rank, mut c) in children.into_iter().enumerate() {
                    match c.wait() {
                        Ok(status) if status.success() => {}
                        Ok(status) => failures.push(format!("rank {rank} exited with {status}")),
                        Err(e) => failures.push(format!("rank {rank} unwaitable: {e}")),
                    }
                }
                if !failures.is_empty() {
                    bail!("worker processes failed: {}", failures.join("; "));
                }
                return merge_report(&acc, per_rank, t0.elapsed().as_secs_f64(), restarts);
            }
            Ok(Round::WorkerDied { rank, last_step }) => {
                reap_all(&mut children);
                let at = match last_step {
                    Some(s) => format!("after completing step {s}"),
                    None => "before completing any step".to_string(),
                };
                if restarts >= opts.max_restarts {
                    bail!(
                        "rank {rank} died {at}; restart budget exhausted \
                         ({} rounds)",
                        opts.max_restarts
                    );
                }
                restarts += 1;
                generation += 1;
                eprintln!(
                    "[launch] rank {rank} died {at}; restarting all ranks \
                     (generation {generation}, round {restarts}/{})",
                    opts.max_restarts
                );
            }
            Err(e) => {
                reap_all(&mut children);
                return Err(e);
            }
        }
    }
}

/// Run only the coordinator, bound on `bind` (multi-host mode: workers
/// are started externally, typically with `REPRO_HOSTMAP` set).
pub fn coordinate_external(
    cfg: &TrainerConfig,
    bind: &str,
    timeout: Duration,
) -> Result<LaunchReport> {
    let topo = Topology::new(cfg.n_l, cfg.n_b, cfg.tp);
    let n = topo.n_ranks();
    let coord = Coordinator::bind(bind, n).context("bind coordinator")?;
    println!(
        "coordinator listening on {} for {n} workers (start them with `repro worker --rank I --coord <this address>`)",
        coord.local_addr()?
    );
    coordinate(&coord, n, cfg.steps, timeout)
}

/// In-process harness: every rank is a thread, but all communication
/// runs the real socket stack (rendezvous, TCP ring wiring, framed
/// control plane). This is what the socket-vs-mpsc parity tests drive.
pub fn launch_threads(cfg: &TrainerConfig) -> Result<LaunchReport> {
    let topo = Topology::new(cfg.n_l, cfg.n_b, cfg.tp);
    let n = topo.n_ranks();
    let coord = Coordinator::bind("127.0.0.1:0", n).context("bind coordinator")?;
    let addr = coord.local_addr()?.to_string();
    let workers: Vec<_> = (0..n)
        .map(|rank| {
            let cfg = cfg.clone();
            let addr = addr.clone();
            thread::Builder::new()
                .name(format!("launch-rank-{rank}"))
                .spawn(move || -> Result<()> {
                    let world = connect_world(topo, rank, &addr, None, LAUNCH_TIMEOUT)
                        .with_context(|| format!("rank {rank} connect"))?;
                    train_rank(&cfg, world)?;
                    Ok(())
                })
                .expect("spawn launch rank thread")
        })
        .collect();
    let merged = coordinate(&coord, n, cfg.steps, LAUNCH_TIMEOUT);
    for (rank, h) in workers.into_iter().enumerate() {
        h.join()
            .map_err(|_| anyhow::anyhow!("rank {rank} panicked"))?
            .with_context(|| format!("rank {rank}"))?;
    }
    merged
}

/// `repro worker` body: join the socket world as `rank` (under
/// `generation`, with the auth token from `REPRO_AUTH_TOKEN`) and run
/// either real training or the artifact-free connectivity probe.
pub fn worker_main(
    cfg: &TrainerConfig,
    rank: usize,
    coord_addr: &str,
    generation: u64,
    probe_steps: Option<usize>,
) -> Result<()> {
    let topo = Topology::new(cfg.n_l, cfg.n_b, cfg.tp);
    let hostmap: Option<Vec<String>> = std::env::var("REPRO_HOSTMAP")
        .ok()
        .map(|v| v.split(',').map(|s| s.trim().to_string()).collect());
    let opts = WorldOptions {
        timeout: LaunchOptions::default().timeout,
        generation,
        ..WorldOptions::default()
    };
    let world = connect_world_opts(topo, rank, coord_addr, hostmap.as_deref(), &opts)
        .with_context(|| format!("rank {rank} joining the world via {coord_addr}"))?;
    match probe_steps {
        Some(steps) => probe_rank(world, steps),
        None => {
            train_rank(cfg, world)?;
            Ok(())
        }
    }
}

/// Artifact-free full-stack exercise of a socket world: per step, a
/// verified all-reduce on the dp and tp rings, a verified ring-wrapped
/// activation/gradient hop on the pipeline, a loss report, a progress
/// heartbeat and the step barrier — the CI smoke path on runners
/// without PJRT artifacts. `REPRO_PROBE_STEP_MS` paces each step so a
/// chaos kill plan can target a live step deterministically.
pub fn probe_rank(mut world: CommWorld, steps: usize) -> Result<()> {
    let topo = world.topology();
    let r = world.rank();
    let (s_n, d_n, t_n) = (topo.stages, topo.dp, topo.tp);
    let pace = std::env::var("REPRO_PROBE_STEP_MS").ok().and_then(|v| v.parse::<u64>().ok());
    for i in 0..steps {
        if let Some(ms) = pace {
            thread::sleep(Duration::from_millis(ms));
        }
        let mut d: Vec<f32> = (0..8).map(|k| (r.dp * 31 + i + k) as f32).collect();
        world.dp_group().all_reduce(&mut d);
        for (k, &v) in d.iter().enumerate() {
            let want = (31 * d_n * (d_n - 1) / 2 + d_n * (i + k)) as f32;
            anyhow::ensure!(v == want, "dp all-reduce: got {v}, want {want}");
        }
        let mut t: Vec<f32> = (0..8).map(|k| (r.tp * 7 + i + k) as f32).collect();
        world.tp_group().all_reduce(&mut t);
        for (k, &v) in t.iter().enumerate() {
            let want = (7 * t_n * (t_n - 1) / 2 + t_n * (i + k)) as f32;
            anyhow::ensure!(v == want, "tp all-reduce: got {v}, want {want}");
        }
        // Ring-wrapped pipeline hop: acts flow forward, grads backward.
        // Buffered sends mean everyone can send before anyone receives.
        world
            .pipeline()
            .send_act(r.stage, i, vec![r.stage as f32; 16])
            .map_err(|e| anyhow::anyhow!("send_act: {e}"))?;
        let (_, mb, act) =
            world.pipeline().recv_act().map_err(|e| anyhow::anyhow!("recv_act: {e}"))?;
        let prev = (r.stage + s_n - 1) % s_n;
        anyhow::ensure!(
            mb == i && act == vec![prev as f32; 16],
            "activation hop: got mb {mb} payload {act:?} from stage {prev}"
        );
        world
            .pipeline()
            .send_grad(r.stage, i, vec![-(r.stage as f32); 16])
            .map_err(|e| anyhow::anyhow!("send_grad: {e}"))?;
        let (_, mb, grad) =
            world.pipeline().recv_grad().map_err(|e| anyhow::anyhow!("recv_grad: {e}"))?;
        let next = (r.stage + 1) % s_n;
        anyhow::ensure!(
            mb == i && grad == vec![-(next as f32); 16],
            "gradient hop: got mb {mb} payload {grad:?} from stage {next}"
        );
        if r.stage == s_n - 1 && r.tp == 0 {
            world.control().report_loss(i, r.dp, (i + 1) as f64);
        }
        world.control().report_progress(i);
        world.step_barrier();
    }
    let traffic = world.traffic();
    world.control().report_stats(RankStats {
        collective_elems_sent: traffic.dp,
        pipeline_elems_sent: traffic.pipeline,
        tp_elems_sent: traffic.tp,
        schedule: "probe".into(),
        ..RankStats::default()
    });
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Full socket stack, no artifacts: rendezvous, ring wiring over
    /// TCP, verified collectives and pipeline hops, merged report.
    #[test]
    fn probe_launch_over_threads_produces_a_merged_report() {
        let topo = Topology::new(2, 2, 1);
        let n = topo.n_ranks();
        let steps = 3usize;
        let coord = Coordinator::bind("127.0.0.1:0", n).unwrap();
        let addr = coord.local_addr().unwrap().to_string();
        let workers: Vec<_> = (0..n)
            .map(|rank| {
                let addr = addr.clone();
                thread::spawn(move || {
                    let world =
                        connect_world(topo, rank, &addr, None, Duration::from_secs(30)).unwrap();
                    probe_rank(world, steps).unwrap();
                })
            })
            .collect();
        let merged = coordinate(&coord, n, steps, Duration::from_secs(30)).unwrap();
        for h in workers {
            h.join().unwrap();
        }
        // Losses: each step's dp-average of (step + 1).
        assert_eq!(merged.report.losses, vec![1.0, 2.0, 3.0]);
        assert_eq!(merged.per_rank.len(), n);
        assert_eq!(merged.restarts, 0);
        assert_eq!(merged.report.schedule_name, "probe");
        // dp rings moved traffic; no tp axis, pipeline hops counted.
        assert!(merged.report.collective_elems_sent > 0);
        assert_eq!(merged.report.tp_elems_sent, 0);
        assert_eq!(merged.report.pipeline_elems_sent, (n * steps * 2 * 16) as u64);
        assert_eq!(
            merged.report.pipeline_bytes_sent,
            merged.report.pipeline_elems_sent * DType::F32.bytes() as u64
        );
    }

    #[test]
    fn missing_worker_times_out_instead_of_hanging() {
        let topo = Topology::new(1, 2, 1);
        let coord = Coordinator::bind("127.0.0.1:0", 2).unwrap();
        let addr = coord.local_addr().unwrap().to_string();
        // Only one of the two expected workers shows up...
        let w = thread::spawn(move || {
            // ...and its own connect fails once the coordinator gives up.
            let _ = connect_world(topo, 0, &addr, None, Duration::from_secs(10));
        });
        let err = coord.rendezvous(Duration::from_millis(300)).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::TimedOut, "{err}");
        w.join().unwrap();
    }

    #[test]
    fn launch_timeout_honors_the_environment_variable() {
        std::env::set_var("REPRO_LAUNCH_TIMEOUT", "7");
        let opts = LaunchOptions::default();
        std::env::remove_var("REPRO_LAUNCH_TIMEOUT");
        assert_eq!(opts.timeout, Duration::from_secs(7));
        assert_eq!(LaunchOptions::default().timeout, LAUNCH_TIMEOUT);
    }
}
