//! Multi-process launch: fork real worker processes, rendezvous them
//! into a socket-wired [`CommWorld`], and merge their control-plane
//! reports into one [`TrainReport`].
//!
//! Three entry points, one protocol:
//!
//! * [`launch_local`] — `repro launch`: spawn `n_ranks` copies of the
//!   current executable as `repro worker --rank i --coord <addr>` over
//!   loopback, coordinate, and merge.
//! * [`coordinate_external`] — `repro launch --coord-bind`: run only
//!   the coordinator on a fixed address; workers are started by hand
//!   (or a cluster scheduler) on other hosts with `REPRO_HOSTMAP` set.
//! * [`launch_threads`] — the in-process test harness: every rank is a
//!   thread but the full socket stack (rendezvous, TCP rings, framed
//!   control plane) is exercised; the socket-vs-mpsc parity suite runs
//!   through this.
//!
//! The coordinator drains each rank's control stream to EOF: per-step
//! [`CtrlMsg::Loss`] reports (dp-averaged exactly like the thread
//! backend) and exactly one [`CtrlMsg::Stats`] per rank. A worker that
//! dies early shows up as a stream without stats — an error naming the
//! rank, never a hang (rendezvous and handshakes carry deadlines; CI
//! adds a hard process timeout for the steady state).

use std::net::TcpStream;
use std::process::{Child, Command, Stdio};
use std::thread;
use std::time::Duration;

use anyhow::{bail, Context, Result};

use crate::collective::socket::read_frame;
use crate::collective::{connect_world, CommWorld, Coordinator, CtrlMsg, RankStats, Topology, Wire};
use crate::runtime::DType;

use super::{train_rank, TrainReport, TrainerConfig};

/// Deadline for rendezvous and connection handshakes. Steady-state
/// training reads carry no timeout (a slow step is not a failure) —
/// the CI smoke run bounds those with a process-level `timeout`.
pub const LAUNCH_TIMEOUT: Duration = Duration::from_secs(120);

/// A merged multi-process run: the coordinator's view of the job plus
/// each rank's own statistics.
#[derive(Debug, Clone)]
pub struct LaunchReport {
    pub report: TrainReport,
    /// Per-rank stats, index = rank (the `WorkerStats` the thread
    /// backend would have joined on, shipped over the control plane).
    pub per_rank: Vec<RankStats>,
}

/// Read control frames until the worker closes its stream.
fn drain_ctrl(stream: TcpStream) -> Result<Vec<CtrlMsg>> {
    let mut r = std::io::BufReader::new(stream);
    let mut msgs = Vec::new();
    loop {
        match read_frame(&mut r) {
            Ok(buf) => msgs.push(CtrlMsg::decode(&buf).context("control frame")?),
            Err(e)
                if matches!(
                    e.kind(),
                    std::io::ErrorKind::UnexpectedEof
                        | std::io::ErrorKind::ConnectionReset
                        | std::io::ErrorKind::ConnectionAborted
                ) =>
            {
                return Ok(msgs)
            }
            Err(e) => return Err(e).context("control stream"),
        }
    }
}

/// Run the coordinator half of a launch: rendezvous `n` workers, drain
/// their control streams, and merge losses + stats into one report.
fn coordinate(coord: &Coordinator, n: usize, steps: usize) -> Result<LaunchReport> {
    let t0 = std::time::Instant::now();
    let streams = coord.rendezvous(LAUNCH_TIMEOUT).context("rendezvous")?;
    let drains: Vec<_> = streams
        .into_iter()
        .enumerate()
        .map(|(rank, s)| {
            thread::Builder::new()
                .name(format!("ctrl-drain-{rank}"))
                .spawn(move || drain_ctrl(s))
                .expect("spawn control drain thread")
        })
        .collect();

    let mut sums = vec![0.0f64; steps];
    let mut counts = vec![0usize; steps];
    let mut per_rank: Vec<RankStats> = Vec::with_capacity(n);
    for (rank, h) in drains.into_iter().enumerate() {
        let msgs = h.join().map_err(|_| anyhow::anyhow!("control drain panicked"))?;
        let msgs = msgs.with_context(|| format!("rank {rank} control stream"))?;
        let mut stats: Option<RankStats> = None;
        for m in msgs {
            match m {
                CtrlMsg::Loss { step, dp: _, loss } => {
                    let step = step as usize;
                    if step < steps {
                        sums[step] += loss;
                        counts[step] += 1;
                    }
                }
                CtrlMsg::Stats(s) => stats = Some(s),
                CtrlMsg::Done => {}
                CtrlMsg::Hello { .. } | CtrlMsg::Peers { .. } => {
                    bail!("rank {rank} sent a rendezvous message mid-run")
                }
            }
        }
        per_rank.push(stats.with_context(|| {
            format!("rank {rank} exited without reporting stats (worker crashed?)")
        })?);
    }

    // Config skew across processes shows up as disagreeing schedules —
    // catch it here rather than as silent divergence.
    let schedule_name = per_rank[0].schedule.clone();
    for (rank, s) in per_rank.iter().enumerate() {
        anyhow::ensure!(
            s.schedule == schedule_name,
            "rank {rank} ran schedule {:?} while rank 0 ran {:?} — mismatched worker configs",
            s.schedule,
            schedule_name
        );
    }

    let losses: Vec<f64> = sums
        .iter()
        .zip(&counts)
        .map(|(s, &c)| if c > 0 { s / c as f64 } else { f64::NAN })
        .collect();
    let sum = |f: fn(&RankStats) -> u64| per_rank.iter().map(f).sum::<u64>();
    let elem_bytes = DType::F32.bytes() as u64;
    let (dp_e, pipe_e, tp_e) = (
        sum(|s| s.collective_elems_sent),
        sum(|s| s.pipeline_elems_sent),
        sum(|s| s.tp_elems_sent),
    );
    let report = TrainReport {
        losses,
        start_step: 0,
        wall_secs: t0.elapsed().as_secs_f64(),
        collective_elems_sent: dp_e,
        pipeline_elems_sent: pipe_e,
        tp_elems_sent: tp_e,
        collective_bytes_sent: dp_e * elem_bytes,
        pipeline_bytes_sent: pipe_e * elem_bytes,
        tp_bytes_sent: tp_e * elem_bytes,
        tp_sharded: per_rank[0].tp_sharded,
        max_layer_state_bytes: per_rank.iter().map(|s| s.layer_state_bytes).max().unwrap_or(0),
        max_state_bytes: per_rank.iter().map(|s| s.total_state_bytes).max().unwrap_or(0),
        execute_secs: per_rank.iter().map(|s| s.execute_secs).sum(),
        execute_calls: sum(|s| s.execute_calls),
        checkpoint_bytes_written: 0,
        checkpoint_records: 0,
        schedule_name,
    };
    Ok(LaunchReport { report, per_rank })
}

fn kill_all(children: &mut [Child]) {
    for c in children.iter_mut() {
        let _ = c.kill();
    }
}

/// Fork one `repro worker` process per rank over loopback, coordinate
/// the run, and merge the result. `worker_flags` is forwarded verbatim
/// to every child (preset, topology, steps, …).
pub fn launch_local(cfg: &TrainerConfig, worker_flags: &[String]) -> Result<LaunchReport> {
    let topo = Topology::new(cfg.n_l, cfg.n_b, cfg.tp);
    let n = topo.n_ranks();
    let coord = Coordinator::bind("127.0.0.1:0", n).context("bind coordinator")?;
    let addr = coord.local_addr()?.to_string();
    let exe = std::env::current_exe().context("locate current executable")?;

    let mut children: Vec<Child> = Vec::with_capacity(n);
    for rank in 0..n {
        let child = Command::new(&exe)
            .arg("worker")
            .arg("--rank")
            .arg(rank.to_string())
            .arg("--coord")
            .arg(&addr)
            .args(worker_flags)
            .stdin(Stdio::null())
            .spawn()
            .with_context(|| format!("spawn worker rank {rank}"));
        match child {
            Ok(c) => children.push(c),
            Err(e) => {
                kill_all(&mut children);
                return Err(e);
            }
        }
    }

    let merged = coordinate(&coord, n, cfg.steps);
    if merged.is_err() {
        kill_all(&mut children);
    }
    let mut failures = Vec::new();
    for (rank, mut c) in children.into_iter().enumerate() {
        match c.wait() {
            Ok(status) if status.success() => {}
            Ok(status) => failures.push(format!("rank {rank} exited with {status}")),
            Err(e) => failures.push(format!("rank {rank} unwaitable: {e}")),
        }
    }
    let merged = merged?;
    if !failures.is_empty() {
        bail!("worker processes failed: {}", failures.join("; "));
    }
    Ok(merged)
}

/// Run only the coordinator, bound on `bind` (multi-host mode: workers
/// are started externally, typically with `REPRO_HOSTMAP` set).
pub fn coordinate_external(cfg: &TrainerConfig, bind: &str) -> Result<LaunchReport> {
    let topo = Topology::new(cfg.n_l, cfg.n_b, cfg.tp);
    let n = topo.n_ranks();
    let coord = Coordinator::bind(bind, n).context("bind coordinator")?;
    println!(
        "coordinator listening on {} for {n} workers (start them with `repro worker --rank I --coord <this address>`)",
        coord.local_addr()?
    );
    coordinate(&coord, n, cfg.steps)
}

/// In-process harness: every rank is a thread, but all communication
/// runs the real socket stack (rendezvous, TCP ring wiring, framed
/// control plane). This is what the socket-vs-mpsc parity tests drive.
pub fn launch_threads(cfg: &TrainerConfig) -> Result<LaunchReport> {
    let topo = Topology::new(cfg.n_l, cfg.n_b, cfg.tp);
    let n = topo.n_ranks();
    let coord = Coordinator::bind("127.0.0.1:0", n).context("bind coordinator")?;
    let addr = coord.local_addr()?.to_string();
    let workers: Vec<_> = (0..n)
        .map(|rank| {
            let cfg = cfg.clone();
            let addr = addr.clone();
            thread::Builder::new()
                .name(format!("launch-rank-{rank}"))
                .spawn(move || -> Result<()> {
                    let world = connect_world(topo, rank, &addr, None, LAUNCH_TIMEOUT)
                        .with_context(|| format!("rank {rank} connect"))?;
                    train_rank(&cfg, world)?;
                    Ok(())
                })
                .expect("spawn launch rank thread")
        })
        .collect();
    let merged = coordinate(&coord, n, cfg.steps);
    for (rank, h) in workers.into_iter().enumerate() {
        h.join()
            .map_err(|_| anyhow::anyhow!("rank {rank} panicked"))?
            .with_context(|| format!("rank {rank}"))?;
    }
    merged
}

/// `repro worker` body: join the socket world as `rank` and run either
/// real training or the artifact-free connectivity probe.
pub fn worker_main(
    cfg: &TrainerConfig,
    rank: usize,
    coord_addr: &str,
    probe_steps: Option<usize>,
) -> Result<()> {
    let topo = Topology::new(cfg.n_l, cfg.n_b, cfg.tp);
    let hostmap: Option<Vec<String>> = std::env::var("REPRO_HOSTMAP")
        .ok()
        .map(|v| v.split(',').map(|s| s.trim().to_string()).collect());
    let world = connect_world(topo, rank, coord_addr, hostmap.as_deref(), LAUNCH_TIMEOUT)
        .with_context(|| format!("rank {rank} joining the world via {coord_addr}"))?;
    match probe_steps {
        Some(steps) => probe_rank(world, steps),
        None => {
            train_rank(cfg, world)?;
            Ok(())
        }
    }
}

/// Artifact-free full-stack exercise of a socket world: per step, a
/// verified all-reduce on the dp and tp rings, a verified ring-wrapped
/// activation/gradient hop on the pipeline, a loss report, and the
/// step barrier — the CI smoke path on runners without PJRT artifacts.
pub fn probe_rank(mut world: CommWorld, steps: usize) -> Result<()> {
    let topo = world.topology();
    let r = world.rank();
    let (s_n, d_n, t_n) = (topo.stages, topo.dp, topo.tp);
    for i in 0..steps {
        let mut d: Vec<f32> = (0..8).map(|k| (r.dp * 31 + i + k) as f32).collect();
        world.dp_group().all_reduce(&mut d);
        for (k, &v) in d.iter().enumerate() {
            let want = (31 * d_n * (d_n - 1) / 2 + d_n * (i + k)) as f32;
            anyhow::ensure!(v == want, "dp all-reduce: got {v}, want {want}");
        }
        let mut t: Vec<f32> = (0..8).map(|k| (r.tp * 7 + i + k) as f32).collect();
        world.tp_group().all_reduce(&mut t);
        for (k, &v) in t.iter().enumerate() {
            let want = (7 * t_n * (t_n - 1) / 2 + t_n * (i + k)) as f32;
            anyhow::ensure!(v == want, "tp all-reduce: got {v}, want {want}");
        }
        // Ring-wrapped pipeline hop: acts flow forward, grads backward.
        // Buffered sends mean everyone can send before anyone receives.
        world
            .pipeline()
            .send_act(r.stage, i, vec![r.stage as f32; 16])
            .map_err(|e| anyhow::anyhow!("send_act: {e}"))?;
        let (_, mb, act) =
            world.pipeline().recv_act().map_err(|e| anyhow::anyhow!("recv_act: {e}"))?;
        let prev = (r.stage + s_n - 1) % s_n;
        anyhow::ensure!(
            mb == i && act == vec![prev as f32; 16],
            "activation hop: got mb {mb} payload {act:?} from stage {prev}"
        );
        world
            .pipeline()
            .send_grad(r.stage, i, vec![-(r.stage as f32); 16])
            .map_err(|e| anyhow::anyhow!("send_grad: {e}"))?;
        let (_, mb, grad) =
            world.pipeline().recv_grad().map_err(|e| anyhow::anyhow!("recv_grad: {e}"))?;
        let next = (r.stage + 1) % s_n;
        anyhow::ensure!(
            mb == i && grad == vec![-(next as f32); 16],
            "gradient hop: got mb {mb} payload {grad:?} from stage {next}"
        );
        if r.stage == s_n - 1 && r.tp == 0 {
            world.control().report_loss(i, r.dp, (i + 1) as f64);
        }
        world.step_barrier();
    }
    let traffic = world.traffic();
    world.control().report_stats(RankStats {
        collective_elems_sent: traffic.dp,
        pipeline_elems_sent: traffic.pipeline,
        tp_elems_sent: traffic.tp,
        schedule: "probe".into(),
        ..RankStats::default()
    });
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Full socket stack, no artifacts: rendezvous, ring wiring over
    /// TCP, verified collectives and pipeline hops, merged report.
    #[test]
    fn probe_launch_over_threads_produces_a_merged_report() {
        let topo = Topology::new(2, 2, 1);
        let n = topo.n_ranks();
        let steps = 3usize;
        let coord = Coordinator::bind("127.0.0.1:0", n).unwrap();
        let addr = coord.local_addr().unwrap().to_string();
        let workers: Vec<_> = (0..n)
            .map(|rank| {
                let addr = addr.clone();
                thread::spawn(move || {
                    let world =
                        connect_world(topo, rank, &addr, None, Duration::from_secs(30)).unwrap();
                    probe_rank(world, steps).unwrap();
                })
            })
            .collect();
        let merged = coordinate(&coord, n, steps).unwrap();
        for h in workers {
            h.join().unwrap();
        }
        // Losses: each step's dp-average of (step + 1).
        assert_eq!(merged.report.losses, vec![1.0, 2.0, 3.0]);
        assert_eq!(merged.per_rank.len(), n);
        assert_eq!(merged.report.schedule_name, "probe");
        // dp rings moved traffic; no tp axis, pipeline hops counted.
        assert!(merged.report.collective_elems_sent > 0);
        assert_eq!(merged.report.tp_elems_sent, 0);
        assert_eq!(merged.report.pipeline_elems_sent, (n * steps * 2 * 16) as u64);
        assert_eq!(
            merged.report.pipeline_bytes_sent,
            merged.report.pipeline_elems_sent * DType::F32.bytes() as u64
        );
    }

    #[test]
    fn missing_worker_times_out_instead_of_hanging() {
        let topo = Topology::new(1, 2, 1);
        let coord = Coordinator::bind("127.0.0.1:0", 2).unwrap();
        let addr = coord.local_addr().unwrap().to_string();
        // Only one of the two expected workers shows up...
        let w = thread::spawn(move || {
            // ...and its own connect fails once the coordinator gives up.
            let _ = connect_world(topo, 0, &addr, None, Duration::from_secs(10));
        });
        let err = coord.rendezvous(Duration::from_millis(300)).unwrap_err();
        assert_eq!(err.kind(), std::io::ErrorKind::TimedOut, "{err}");
        w.join().unwrap();
    }
}
