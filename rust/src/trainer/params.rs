//! Host-side model parameters: flattened fp32 buffers per layer (fusing
//! the 12 tensors into one contiguous allocation — the paper's §2.5
//! pre-allocation/fusion recommendation, which also makes the ring
//! collectives and Adam run over single slices).

use crate::data::Rng;
use crate::runtime::{HostTensor, Manifest};

/// Byte/element layout of one layer's flattened parameter buffer.
#[derive(Debug, Clone)]
pub struct LayerLayout {
    pub names: Vec<String>,
    pub shapes: Vec<Vec<usize>>,
    pub offsets: Vec<usize>,
    pub total: usize,
}

impl LayerLayout {
    pub fn from_manifest(m: &Manifest) -> Self {
        let names = m.layer_param_names.clone();
        let shapes = m.layer_param_shapes.clone();
        let mut offsets = Vec::with_capacity(shapes.len());
        let mut total = 0usize;
        for s in &shapes {
            offsets.push(total);
            total += s.iter().product::<usize>();
        }
        LayerLayout { names, shapes, offsets, total }
    }

    /// Slice tensor `i` out of a flat buffer as a HostTensor (copy).
    pub fn tensor(&self, flat: &[f32], i: usize) -> HostTensor {
        let n: usize = self.shapes[i].iter().product();
        let a = self.offsets[i];
        HostTensor::f32(self.shapes[i].clone(), flat[a..a + n].to_vec())
    }

    /// All 12 tensors of a flat buffer, in artifact argument order.
    pub fn tensors(&self, flat: &[f32]) -> Vec<HostTensor> {
        (0..self.shapes.len()).map(|i| self.tensor(flat, i)).collect()
    }

    /// Scatter per-tensor gradients back into a flat accumulator.
    pub fn accumulate(&self, acc: &mut [f32], grads: &[HostTensor]) {
        assert_eq!(grads.len(), self.shapes.len());
        for (i, g) in grads.iter().enumerate() {
            let data = g.as_f32().expect("grad dtype");
            let a = self.offsets[i];
            for (dst, src) in acc[a..a + data.len()].iter_mut().zip(data) {
                *dst += src;
            }
        }
    }

    /// Deterministic initialisation of one layer's flat buffer:
    /// matrices ~ N(0, 0.02²), layernorm gains 1, biases 0 — matching
    /// python `init_params` semantics (not bitwise: each side owns its
    /// RNG; equivalence is established statistically and by loss curves).
    pub fn init(&self, rng: &mut Rng) -> Vec<f32> {
        let mut flat = vec![0.0f32; self.total];
        for (i, name) in self.names.iter().enumerate() {
            let a = self.offsets[i];
            let n: usize = self.shapes[i].iter().product();
            if name.ends_with("_g") {
                flat[a..a + n].fill(1.0);
            } else if self.shapes[i].len() >= 2 {
                for v in flat[a..a + n].iter_mut() {
                    *v = 0.02 * rng.normal() as f32;
                }
            } // 1-d biases stay 0
        }
        flat
    }
}

/// Initialise an embedding-like matrix.
pub fn init_matrix(rng: &mut Rng, rows: usize, cols: usize, scale: f32) -> Vec<f32> {
    (0..rows * cols).map(|_| scale * rng.normal() as f32).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn manifest() -> Option<Manifest> {
        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Manifest::load(root, "tiny").ok()
    }

    #[test]
    fn layout_offsets_are_contiguous() {
        let Some(m) = manifest() else { return };
        let l = LayerLayout::from_manifest(&m);
        assert_eq!(l.names.len(), 12);
        for i in 1..l.offsets.len() {
            let prev: usize = l.shapes[i - 1].iter().product();
            assert_eq!(l.offsets[i], l.offsets[i - 1] + prev);
        }
        assert_eq!(l.total, m.layer_param_elements());
    }

    #[test]
    fn roundtrip_tensor_accumulate() {
        let Some(m) = manifest() else { return };
        let l = LayerLayout::from_manifest(&m);
        let flat: Vec<f32> = (0..l.total).map(|i| i as f32).collect();
        let tensors = l.tensors(&flat);
        let mut acc = vec![0.0f32; l.total];
        l.accumulate(&mut acc, &tensors);
        assert_eq!(acc, flat);
    }

    #[test]
    fn init_respects_param_roles() {
        let Some(m) = manifest() else { return };
        let l = LayerLayout::from_manifest(&m);
        let mut rng = Rng::new(1);
        let flat = l.init(&mut rng);
        for (i, name) in l.names.iter().enumerate() {
            let a = l.offsets[i];
            let n: usize = l.shapes[i].iter().product();
            let slice = &flat[a..a + n];
            if name.ends_with("_g") {
                assert!(slice.iter().all(|&v| v == 1.0), "{name}");
            } else if l.shapes[i].len() == 1 {
                assert!(slice.iter().all(|&v| v == 0.0), "{name}");
            } else {
                let std = (slice.iter().map(|v| v * v).sum::<f32>() / n as f32).sqrt();
                assert!((std - 0.02).abs() < 0.01, "{name}: std {std}");
            }
        }
    }
}
