//! Host-side model parameters: flattened fp32 buffers per layer (fusing
//! the 12 tensors into one contiguous allocation — the paper's §2.5
//! pre-allocation/fusion recommendation, which also makes the ring
//! collectives and Adam run over single slices).

use crate::data::Rng;
use crate::runtime::{HostTensor, Manifest};

/// Byte/element layout of one layer's flattened parameter buffer.
#[derive(Debug, Clone)]
pub struct LayerLayout {
    pub names: Vec<String>,
    pub shapes: Vec<Vec<usize>>,
    pub offsets: Vec<usize>,
    pub total: usize,
}

impl LayerLayout {
    pub fn from_manifest(m: &Manifest) -> Self {
        let names = m.layer_param_names.clone();
        let shapes = m.layer_param_shapes.clone();
        let mut offsets = Vec::with_capacity(shapes.len());
        let mut total = 0usize;
        for s in &shapes {
            offsets.push(total);
            total += s.iter().product::<usize>();
        }
        LayerLayout { names, shapes, offsets, total }
    }

    /// Slice tensor `i` out of a flat buffer as a HostTensor (copy).
    pub fn tensor(&self, flat: &[f32], i: usize) -> HostTensor {
        let n: usize = self.shapes[i].iter().product();
        let a = self.offsets[i];
        HostTensor::f32(self.shapes[i].clone(), flat[a..a + n].to_vec())
    }

    /// All 12 tensors of a flat buffer, in artifact argument order.
    pub fn tensors(&self, flat: &[f32]) -> Vec<HostTensor> {
        (0..self.shapes.len()).map(|i| self.tensor(flat, i)).collect()
    }

    /// Scatter per-tensor gradients back into a flat accumulator.
    pub fn accumulate(&self, acc: &mut [f32], grads: &[HostTensor]) {
        assert_eq!(grads.len(), self.shapes.len());
        for (i, g) in grads.iter().enumerate() {
            let data = g.as_f32().expect("grad dtype");
            let a = self.offsets[i];
            for (dst, src) in acc[a..a + data.len()].iter_mut().zip(data) {
                *dst += src;
            }
        }
    }

    /// Deterministic initialisation of one layer's flat buffer:
    /// matrices ~ N(0, 0.02²), layernorm gains 1, biases 0 — matching
    /// python `init_params` semantics (not bitwise: each side owns its
    /// RNG; equivalence is established statistically and by loss curves).
    pub fn init(&self, rng: &mut Rng) -> Vec<f32> {
        let mut flat = vec![0.0f32; self.total];
        for (i, name) in self.names.iter().enumerate() {
            let a = self.offsets[i];
            let n: usize = self.shapes[i].iter().product();
            if name.ends_with("_g") {
                flat[a..a + n].fill(1.0);
            } else if self.shapes[i].len() >= 2 {
                for v in flat[a..a + n].iter_mut() {
                    *v = 0.02 * rng.normal() as f32;
                }
            } // 1-d biases stay 0
        }
        flat
    }
}

/// Initialise an embedding-like matrix.
pub fn init_matrix(rng: &mut Rng, rows: usize, cols: usize, scale: f32) -> Vec<f32> {
    (0..rows * cols).map(|_| scale * rng.normal() as f32).collect()
}

/// How one layer tensor shards across the tensor-parallel ring
/// (Megatron-style column/row-parallel cut points, by parameter name).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardRule {
    /// Replicated on every rank (layernorms and the post-reduce biases).
    Full,
    /// Row-sharded: rank r owns rows [r·R/tp, (r+1)·R/tp) — the
    /// row-parallel w_o / w2 whose partial outputs feed an all-reduce.
    Rows,
    /// Column-sharded in `g` equal groups: rank r owns the same fraction
    /// of every group (g = 3 for the fused q|k|v axis, 1 for w1/b1).
    ColGroups(usize),
}

/// The cut point for each parameter of the shared 12-name layout.
pub fn shard_rule(name: &str) -> ShardRule {
    match name {
        "w_qkv" | "b_qkv" => ShardRule::ColGroups(3),
        "w1" | "b1" => ShardRule::ColGroups(1),
        "w_o" | "w2" => ShardRule::Rows,
        _ => ShardRule::Full,
    }
}

/// Byte/element layout of one rank's *sharded* flat parameter buffer at
/// tensor-parallel degree `tp`, plus the full↔shard index maps that
/// power checkpoint re-sharding. Shapes are rank-independent (every
/// rank's shard has the same shape; the *content* differs by rank).
#[derive(Debug, Clone)]
pub struct ShardedLayout {
    pub tp: usize,
    /// The unsharded layout (shapes shared with tp = 1 state).
    pub full: LayerLayout,
    /// Sharded per-tensor shapes, in layout order.
    pub shapes: Vec<Vec<usize>>,
    pub offsets: Vec<usize>,
    pub total: usize,
    rules: Vec<ShardRule>,
    /// Indices of the post-reduce biases (b_o, b2): replicated
    /// parameters that must enter the artifact exactly once, so their
    /// input is zeroed on every rank but tp rank 0.
    bias_after_reduce: Vec<usize>,
    /// `(offset, len)` spans of the layernorm parameters within the
    /// sharded flat buffer: their gradients flow through the sharded
    /// GEMMs and are *partial* per rank — the worker tp-all-reduces
    /// exactly these spans at gradient-reduction time.
    grad_tp_spans: Vec<(usize, usize)>,
}

impl ShardedLayout {
    /// Build from the manifest's `tp_shards` shapes (python is the shape
    /// source of truth); cross-checks them against the rule arithmetic.
    pub fn from_manifest(m: &Manifest, tp: usize) -> anyhow::Result<Self> {
        use anyhow::{bail, Context};
        let full = LayerLayout::from_manifest(m);
        let shapes = m
            .shard_param_shapes(tp)
            .with_context(|| format!("manifest has no tp = {tp} shard shapes"))?
            .clone();
        if m.model.n_heads % tp != 0 {
            bail!("tp = {tp} does not divide n_heads = {}", m.model.n_heads);
        }
        let mut rules = Vec::with_capacity(full.names.len());
        let mut offsets = Vec::with_capacity(full.names.len());
        let mut bias_after_reduce = Vec::new();
        let mut grad_tp_spans = Vec::new();
        let mut total = 0usize;
        for (i, name) in full.names.iter().enumerate() {
            let rule = shard_rule(name);
            let fs = &full.shapes[i];
            let want: Vec<usize> = match rule {
                ShardRule::Full => fs.clone(),
                ShardRule::Rows => {
                    if fs[0] % tp != 0 {
                        bail!("{name}: {} rows not divisible by tp = {tp}", fs[0]);
                    }
                    let mut s = fs.clone();
                    s[0] /= tp;
                    s
                }
                ShardRule::ColGroups(g) => {
                    let cols = *fs.last().unwrap();
                    if cols % (g * tp) != 0 {
                        bail!("{name}: {cols} cols not divisible by {g}·tp");
                    }
                    let mut s = fs.clone();
                    *s.last_mut().unwrap() = cols / tp;
                    s
                }
            };
            if want != shapes[i] {
                bail!(
                    "{name}: manifest shard shape {:?} does not match the \
                     {rule:?} cut of {:?} at tp = {tp} (expected {want:?})",
                    shapes[i],
                    fs
                );
            }
            let n: usize = want.iter().product();
            if matches!(rule, ShardRule::Full) {
                if name == "b_o" || name == "b2" {
                    bias_after_reduce.push(i);
                } else {
                    grad_tp_spans.push((total, n));
                }
            }
            rules.push(rule);
            offsets.push(total);
            total += n;
        }
        Ok(ShardedLayout {
            tp,
            full,
            shapes,
            offsets,
            total,
            rules,
            bias_after_reduce,
            grad_tp_spans,
        })
    }

    /// Enumerate rank `rank`'s corresponding contiguous spans as
    /// `(full_start, shard_start, len)` pairs — the one index map behind
    /// gather, scatter and the re-shard path of an elastic resume.
    fn for_spans(&self, rank: usize, mut f: impl FnMut(usize, usize, usize)) {
        for i in 0..self.shapes.len() {
            let fo = self.full.offsets[i];
            let so = self.offsets[i];
            let n_shard: usize = self.shapes[i].iter().product();
            match self.rules[i] {
                ShardRule::Full => f(fo, so, n_shard),
                // Row blocks are contiguous in row-major flats.
                ShardRule::Rows => f(fo + rank * n_shard, so, n_shard),
                ShardRule::ColGroups(g) => {
                    let fs = &self.full.shapes[i];
                    let cols = *fs.last().unwrap();
                    let rows = fs.iter().product::<usize>() / cols;
                    let w = cols / g; // full group width
                    let ws = w / self.tp; // shard width per group
                    let cols_s = cols / self.tp;
                    for r in 0..rows {
                        for k in 0..g {
                            f(fo + r * cols + k * w + rank * ws, so + r * cols_s + k * ws, ws);
                        }
                    }
                }
            }
        }
    }

    /// Slice rank `rank`'s shard out of a full flat buffer.
    pub fn gather(&self, full: &[f32], rank: usize) -> Vec<f32> {
        assert_eq!(full.len(), self.full.total);
        let mut shard = vec![0.0f32; self.total];
        self.for_spans(rank, |fa, sa, n| shard[sa..sa + n].copy_from_slice(&full[fa..fa + n]));
        shard
    }

    /// Write rank `rank`'s shard back into a full flat buffer (the
    /// re-shard path of an elastic resume: every writer rank scatters,
    /// together reconstructing the full state).
    pub fn scatter(&self, shard: &[f32], rank: usize, full: &mut [f32]) {
        assert_eq!(shard.len(), self.total);
        assert_eq!(full.len(), self.full.total);
        self.for_spans(rank, |fa, sa, n| full[fa..fa + n].copy_from_slice(&shard[sa..sa + n]));
    }

    /// HostTensor views of one *half* of the sharded flat buffer in
    /// artifact argument order: indices `[start, start + 6)` (attention
    /// half starts at 0, FFN half at 6). Post-reduce biases are zeroed
    /// for tp rank > 0 so the summed partials apply them exactly once —
    /// the stored parameter stays replicated, only the artifact input is
    /// masked.
    pub fn half_tensors(&self, flat: &[f32], start: usize, tp_rank: usize) -> Vec<HostTensor> {
        (start..start + 6)
            .map(|i| {
                let n: usize = self.shapes[i].iter().product();
                let a = self.offsets[i];
                let data = if tp_rank > 0 && self.bias_after_reduce.contains(&i) {
                    vec![0.0; n]
                } else {
                    flat[a..a + n].to_vec()
                };
                HostTensor::f32(self.shapes[i].clone(), data)
            })
            .collect()
    }

    /// Scatter one half's per-tensor gradients (artifact outputs
    /// `[..6]`) into the sharded flat accumulator starting at layout
    /// index `start`.
    pub fn accumulate_half(&self, acc: &mut [f32], grads: &[HostTensor], start: usize) {
        assert!(grads.len() >= 6);
        for (k, g) in grads.iter().take(6).enumerate() {
            let i = start + k;
            let data = g.as_f32().expect("grad dtype");
            let a = self.offsets[i];
            for (dst, src) in acc[a..a + data.len()].iter_mut().zip(data) {
                *dst += src;
            }
        }
    }

    /// The flat spans whose gradients are partial per tp rank (the
    /// layernorm parameters) — the worker all-reduces exactly these
    /// over the tp ring before the optimizer consumes them.
    pub fn grad_tp_spans(&self) -> &[(usize, usize)] {
        &self.grad_tp_spans
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::PathBuf;

    fn manifest() -> Option<Manifest> {
        let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("artifacts");
        Manifest::load(root, "tiny").ok()
    }

    #[test]
    fn layout_offsets_are_contiguous() {
        let Some(m) = manifest() else { return };
        let l = LayerLayout::from_manifest(&m);
        assert_eq!(l.names.len(), 12);
        for i in 1..l.offsets.len() {
            let prev: usize = l.shapes[i - 1].iter().product();
            assert_eq!(l.offsets[i], l.offsets[i - 1] + prev);
        }
        assert_eq!(l.total, m.layer_param_elements());
    }

    #[test]
    fn roundtrip_tensor_accumulate() {
        let Some(m) = manifest() else { return };
        let l = LayerLayout::from_manifest(&m);
        let flat: Vec<f32> = (0..l.total).map(|i| i as f32).collect();
        let tensors = l.tensors(&flat);
        let mut acc = vec![0.0f32; l.total];
        l.accumulate(&mut acc, &tensors);
        assert_eq!(acc, flat);
    }

    /// A self-contained manifest (d_m = 4, 2 heads, d_I = 8) so the
    /// shard arithmetic is testable without built artifacts.
    fn synthetic_manifest(with_tp2: bool) -> Manifest {
        let shapes = r#"{
            "ln1_g": [4], "ln1_b": [4], "w_qkv": [4, 12], "b_qkv": [12],
            "w_o": [4, 4], "b_o": [4], "ln2_g": [4], "ln2_b": [4],
            "w1": [4, 8], "b1": [8], "w2": [8, 4], "b2": [4]}"#;
        let tp = if with_tp2 {
            r#""tp_shards": {"2": {"layer_param_shapes": {
                "ln1_g": [4], "ln1_b": [4], "w_qkv": [4, 6], "b_qkv": [6],
                "w_o": [2, 4], "b_o": [4], "ln2_g": [4], "ln2_b": [4],
                "w1": [4, 4], "b1": [4], "w2": [4, 4], "b2": [4]}}},"#
        } else {
            ""
        };
        let names = r#"["ln1_g", "ln1_b", "w_qkv", "b_qkv", "w_o", "b_o",
                        "ln2_g", "ln2_b", "w1", "b1", "w2", "b2"]"#;
        let text = format!(
            r#"{{"preset": "syn", "batch": 1,
                "model": {{"vocab": 8, "d_model": 4, "n_heads": 2, "d_seq": 2,
                           "n_layers": 1, "d_ffn": 8, "total_params": 100}},
                "layer_param_names": {names},
                "layer_param_shapes": {shapes},
                {tp}
                "artifacts": {{}}}}"#
        );
        Manifest::parse(&text, std::path::PathBuf::from("/tmp")).unwrap()
    }

    #[test]
    fn sharded_layout_shapes_and_offsets() {
        let m = synthetic_manifest(true);
        let s = ShardedLayout::from_manifest(&m, 2).unwrap();
        assert_eq!(s.total, m.layer_param_elements_tp(2).unwrap());
        for i in 1..s.offsets.len() {
            let prev: usize = s.shapes[i - 1].iter().product();
            assert_eq!(s.offsets[i], s.offsets[i - 1] + prev);
        }
        // Sharded matrices halve; replicated vectors do not: the
        // per-rank total sits strictly between half and full.
        assert!(s.total > s.full.total / 2 && s.total < s.full.total);
        // Missing shard shapes must fail loudly.
        assert!(ShardedLayout::from_manifest(&synthetic_manifest(false), 2).is_err());
    }

    #[test]
    fn gather_scatter_roundtrips_every_rank() {
        let m = synthetic_manifest(true);
        let s = ShardedLayout::from_manifest(&m, 2).unwrap();
        let full: Vec<f32> = (0..s.full.total).map(|i| i as f32).collect();
        let shards: Vec<Vec<f32>> = (0..2).map(|r| s.gather(&full, r)).collect();
        // Shards of sharded tensors are disjoint; scattering both back
        // reconstructs the full buffer exactly.
        let mut rebuilt = vec![-1.0f32; s.full.total];
        for (r, shard) in shards.iter().enumerate() {
            s.scatter(shard, r, &mut rebuilt);
        }
        assert_eq!(rebuilt, full);
        // Rank shards differ (different columns/rows) but replicated
        // tensors agree.
        assert_ne!(shards[0], shards[1]);
        let (a, n) = (s.offsets[0], 4usize); // ln1_g span
        assert_eq!(&shards[0][a..a + n], &shards[1][a..a + n]);
    }

    #[test]
    fn column_groups_map_matches_qkv_slicing() {
        // w_qkv (4 rows × 12 cols, groups q|k|v of width 4): rank 1's
        // shard must be columns {2,3, 6,7, 10,11} of every row.
        let m = synthetic_manifest(true);
        let s = ShardedLayout::from_manifest(&m, 2).unwrap();
        let full: Vec<f32> = (0..s.full.total).map(|i| i as f32).collect();
        let shard = s.gather(&full, 1);
        let fo = s.full.offsets[2]; // w_qkv
        let so = s.offsets[2];
        for row in 0..4 {
            for (j, col) in [2usize, 3, 6, 7, 10, 11].into_iter().enumerate() {
                assert_eq!(shard[so + row * 6 + j], full[fo + row * 12 + col]);
            }
        }
    }

    #[test]
    fn half_tensors_mask_post_reduce_biases_off_rank0() {
        let m = synthetic_manifest(true);
        let s = ShardedLayout::from_manifest(&m, 2).unwrap();
        let flat: Vec<f32> = (0..s.total).map(|i| 1.0 + i as f32).collect();
        let attn0 = s.half_tensors(&flat, 0, 0);
        let attn1 = s.half_tensors(&flat, 0, 1);
        assert_eq!(attn0.len(), 6);
        // b_o is index 5 of the attention half: real on rank 0, zeroed
        // on rank 1; everything else identical.
        assert!(attn0[5].as_f32().unwrap().iter().all(|&v| v > 0.0));
        assert!(attn1[5].as_f32().unwrap().iter().all(|&v| v == 0.0));
        for i in 0..5 {
            assert_eq!(attn0[i], attn1[i]);
        }
        let ffn1 = s.half_tensors(&flat, 6, 1);
        assert!(ffn1[5].as_f32().unwrap().iter().all(|&v| v == 0.0), "b2 masked");
    }

    #[test]
    fn grad_tp_spans_cover_exactly_the_layernorm_params() {
        let m = synthetic_manifest(true);
        let s = ShardedLayout::from_manifest(&m, 2).unwrap();
        let spans = s.grad_tp_spans();
        // ln1_g, ln1_b, ln2_g, ln2_b — 4 spans of d_m = 4 elements.
        assert_eq!(spans.len(), 4);
        assert!(spans.iter().all(|&(_, n)| n == 4));
        let expect: Vec<usize> = [0usize, 1, 6, 7].iter().map(|&i| s.offsets[i]).collect();
        assert_eq!(spans.iter().map(|&(o, _)| o).collect::<Vec<_>>(), expect);
    }

    #[test]
    fn accumulate_half_targets_the_right_tensors() {
        let m = synthetic_manifest(true);
        let s = ShardedLayout::from_manifest(&m, 2).unwrap();
        let mut acc = vec![0.0f32; s.total];
        let ones: Vec<HostTensor> = (6..12)
            .map(|i| {
                let n: usize = s.shapes[i].iter().product();
                HostTensor::f32(s.shapes[i].clone(), vec![1.0; n])
            })
            .collect();
        s.accumulate_half(&mut acc, &ones, 6);
        let ffn_start = s.offsets[6];
        assert!(acc[..ffn_start].iter().all(|&v| v == 0.0));
        assert!(acc[ffn_start..].iter().all(|&v| v == 1.0));
    }

    #[test]
    fn init_respects_param_roles() {
        let Some(m) = manifest() else { return };
        let l = LayerLayout::from_manifest(&m);
        let mut rng = Rng::new(1);
        let flat = l.init(&mut rng);
        for (i, name) in l.names.iter().enumerate() {
            let a = l.offsets[i];
            let n: usize = l.shapes[i].iter().product();
            let slice = &flat[a..a + n];
            if name.ends_with("_g") {
                assert!(slice.iter().all(|&v| v == 1.0), "{name}");
            } else if l.shapes[i].len() == 1 {
                assert!(slice.iter().all(|&v| v == 0.0), "{name}");
            } else {
                let std = (slice.iter().map(|v| v * v).sum::<f32>() / n as f32).sqrt();
                assert!((std - 0.02).abs() < 0.01, "{name}: std {std}");
            }
        }
    }
}
