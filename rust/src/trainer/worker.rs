//! Stage worker: executes its rank's slice of a compiled
//! [`ScheduleProgram`] against the PJRT engine and its
//! [`CommWorld`] process groups. One worker = one (dp, stage, tp) rank
//! = one OS thread.
//!
//! The worker runs the program's per-stage op order and checks every
//! local dependency edge before dispatching an op — the same edges the
//! validator verified and the simulator timed. Cross-stage edges are
//! enforced physically by the blocking pipeline group; that the
//! blocking order can complete at all is verified up front by
//! [`ScheduleProgram::check_inorder_executable`] in
//! [`super::train`].
//!
//! Tensor parallelism executes in one of two modes:
//!
//! * **Sharded execution** (the default when the manifest carries the
//!   tp shard variants): Megatron-style column/row-parallel compute.
//!   Each tp rank owns 1/tp of every layer matrix (attention sharded by
//!   heads, FFN column-parallel first GEMM / row-parallel second GEMM)
//!   and runs the layer as two half-layer artifacts with *partial-sum*
//!   outputs. Three ring all-reduces complete a backward pass (two
//!   forward): the mid-layer attention reduce inside the Fwd/Bwd op, the
//!   FFN input-gradient reduce inside Bwd, and the layer-boundary reduce
//!   that is the scheduled `TensorAllReduce` op. Per-rank parameters,
//!   gradients, Adam state and checkpoint records all shrink to the
//!   owned shard ([`super::params::ShardedLayout`]); layernorm gradients
//!   are partial per rank and are tp-all-reduced at gradient-reduction
//!   time. tp = 2 matches tp = 1 within a tight tolerance (the
//!   row-parallel partial sums reassociate one reduction axis); the
//!   head-sharded and column-parallel intermediates are bitwise-exact
//!   under sharding (proved in `python/tests/test_model_tp.py`).
//!
//! * **Replicated-compute emulation** (manifests without shard variants,
//!   or `force_tp_emulation`): every tp rank runs the full layer math
//!   from the same seed, and each `TensorAllReduce` ring-sums its tensor
//!   over the tp group and post-scales by 1/tp — an exact identity on
//!   the replicated values (bit-exact for tp = 2 on every finite value,
//!   subnormals included) that moves the real 2·(tp−1)/tp per-rank wire
//!   traffic the cost model prices, so a tp = 2 run's loss trajectory
//!   equals the tp = 1 run's bit for bit.

use std::collections::HashMap;
use std::sync::Arc;

use anyhow::{bail, Context, Result};

use crate::collective::{CommWorld, Rank, RingGroup};
use crate::data::Corpus;
use crate::offload::store::{
    assemble, slot_embed, slot_head, slot_layer, slot_pos, StateRecord, StateStore,
};
use crate::optim::{Adam, AdamConfig, LrSchedule};
use crate::partition::ShardMap;
use crate::runtime::{tp_artifact_name, Engine, HostTensor};
use crate::schedule::{Op, ScheduleProgram};

use super::params::{init_matrix, LayerLayout, ShardedLayout};

/// Everything a worker thread needs (all Send; the PJRT engine is
/// created inside the thread).
pub struct WorkerCtx {
    /// This rank's process groups — the only communication handle a
    /// worker holds: pipeline p2p, dp ring, tp ring and the control
    /// plane all hang off it.
    pub world: CommWorld,
    /// Micro-batches per step per data-parallel instance.
    pub n_mu: usize,
    pub seed: u64,
    pub steps: usize,
    /// First step this run executes; steps `0..start_step` were already
    /// trained by a previous (crashed or resized) run and are loaded from
    /// the checkpoint store.
    pub start_step: usize,
    pub lr: LrSchedule,
    pub partition: bool,
    /// ZeRO stage (0–3) over the dp group: stage ≥1 sizes the Adam
    /// moments to the owned 1/dp range (the schedule carries the
    /// matching `ReduceScatterGrad`/`AllGatherParams` ops). Mutually
    /// exclusive with `partition`.
    pub zero: u8,
    /// Whether the schedule streams real-time checkpoints
    /// (`OffloadStore` ops write to `store`).
    pub offload: bool,
    /// Whether tp > 1 runs truly sharded layer compute (decided once by
    /// the trainer from the manifest's shard support and the
    /// `force_tp_emulation` config; every worker must agree).
    pub tp_sharded: bool,
    /// Shard degree of the checkpoint being resumed (1 = unsharded;
    /// meaningful only when `start_step > 0`). May differ from the
    /// current topology's tp — resume re-shards.
    pub ckpt_tp: usize,
    /// Checkpoint store; present when offloading and/or resuming.
    pub store: Option<Arc<dyn StateStore>>,
    /// The compiled schedule shared by every worker (and by the validator
    /// and simulator that vetted it).
    pub program: Arc<ScheduleProgram>,
    pub artifacts_root: std::path::PathBuf,
    pub preset: String,
}

/// Post-run statistics from one worker.
#[derive(Debug, Clone, Default)]
pub struct WorkerStats {
    pub execute_secs: f64,
    pub execute_calls: u64,
    /// Payload elements sent on the data-parallel ring (gradient
    /// reductions, parameter all-gathers, epilogue reduces).
    pub collective_elems_sent: u64,
    /// Payload elements sent on the pipeline rings (activations +
    /// gradients).
    pub pipeline_elems_sent: u64,
    /// Payload elements sent on the tensor-parallel ring
    /// (`TensorAllReduce` ops and, under sharded execution, the
    /// mid-layer reduces and layernorm-gradient reduces).
    pub tp_elems_sent: u64,
    /// Measured resident bytes of this rank's layer parameters + Adam
    /// moments (the state tensor parallelism shards — ≈ 1/tp per rank
    /// under sharded execution).
    pub layer_state_bytes: u64,
    /// Measured resident parameter + optimizer bytes including the
    /// replicated embedding/positional/head state.
    pub total_state_bytes: u64,
    pub wall_secs: f64,
}

/// Validate a pipeline payload against what the schedule expects. The
/// rings deliver in program order, so an identity mismatch is a
/// schedule/engine bug; a wrong element count would otherwise surface
/// later as a shape error deep inside PJRT (or, for gradients, silently
/// skew an accumulation). `got`/`want` are (layer, micro-batch, len);
/// `peer` is the rank whose send this receive pairs with and `op_id`
/// the receiving op's arena id — a payload error on a thousand-rank
/// job must name where to look, not just what went wrong.
fn check_payload(
    kind: &str,
    peer: Rank,
    op_id: u32,
    got: (usize, usize, usize),
    want: (usize, usize, usize),
) -> Result<()> {
    let ((l, mb, len), (wl, wmb, wlen)) = (got, want);
    let from = format!(
        "from peer rank(stage {}, dp {}, tp {}) at op {op_id}",
        peer.stage, peer.dp, peer.tp
    );
    if l != wl || mb != wmb {
        bail!("{kind} ring out of order {from}: got ({l},{mb}), want ({wl},{wmb})");
    }
    if len != wlen {
        bail!(
            "bad {kind} payload for ({l},{mb}) {from}: got {len} elements, expected {wlen}"
        );
    }
    Ok(())
}

/// The executable `TensorAllReduce`: the deterministic ring sum, then a
/// 1/n post-scale. On replicated inputs the roundtrip is the identity —
/// for n = 2 exactly, on every finite value including subnormals
/// (x + x = 2x is exact, and halving 2x is an exact power-of-two
/// downscale back to x; the only exception is overflow at |x| >
/// f32::MAX/2, far beyond any activation) — while each rank moves the
/// real 2·(n−1)/n ring traffic. Prescaling instead would round
/// subnormal inputs and break the tp=2 bit-match. A size-1 group is a
/// no-op.
fn tp_all_reduce(group: &mut RingGroup, data: &mut [f32]) {
    let n = group.n;
    if n <= 1 {
        return;
    }
    group.all_reduce(data);
    let inv = 1.0 / n as f32;
    for v in data.iter_mut() {
        *v *= inv;
    }
}

/// `dst += src`, elementwise — the residual adds that complete a
/// reduced partial sum (x2 = x + Σ attn_part, dx2 = dy + Σ dh_part, …).
fn add_into(dst: &mut [f32], src: &[f32]) {
    debug_assert_eq!(dst.len(), src.len());
    for (d, s) in dst.iter_mut().zip(src) {
        *d += s;
    }
}

/// Tensor-parallel all-reduce of the layernorm-gradient spans of one
/// layer's sharded flat gradient buffer: those gradients flow through
/// the sharded GEMMs, so each rank holds a *partial* — the ring sum
/// completes them (one bunched collective per layer per step).
fn tp_reduce_spans(group: &mut RingGroup, g: &mut [f32], spans: &[(usize, usize)]) {
    if group.n <= 1 || spans.is_empty() {
        return;
    }
    let total: usize = spans.iter().map(|&(_, n)| n).sum();
    let mut buf = Vec::with_capacity(total);
    for &(o, n) in spans {
        buf.extend_from_slice(&g[o..o + n]);
    }
    group.all_reduce(&mut buf);
    let mut at = 0usize;
    for &(o, n) in spans {
        g[o..o + n].copy_from_slice(&buf[at..at + n]);
        at += n;
    }
}

/// Reassemble one layer's *full* (unsharded) state from a checkpoint
/// written at shard degree `wtp`: each writer rank's slot is stitched
/// from its dp cover, then scattered back through the writer's shard
/// layout — the tp half of elastic resume. The caller re-slices the
/// result to its own shard (or keeps it whole at tp = 1).
fn assemble_layer_full(
    store: &dyn StateStore,
    step: u64,
    d_l: usize,
    layer: usize,
    full_total: usize,
    wlayout: Option<&ShardedLayout>,
) -> Result<(Vec<f32>, Vec<f32>, Vec<f32>, u64)> {
    let Some(wl) = wlayout else {
        let s = assemble(&store.read(step, slot_layer(d_l, 0, layer) as u64)?, full_total)
            .with_context(|| format!("layer {layer} checkpoint at step {step}"))?;
        return Ok((s.params, s.m, s.v, s.adam_t));
    };
    let wtp = wl.tp;
    let mut params = vec![0.0f32; full_total];
    let mut m = vec![0.0f32; full_total];
    let mut v = vec![0.0f32; full_total];
    let mut adam_t = 0u64;
    for r in 0..wtp {
        let slot = assemble(&store.read(step, slot_layer(d_l, r, layer) as u64)?, wl.total)
            .with_context(|| {
                format!("layer {layer} tp-shard {r}/{wtp} checkpoint at step {step}")
            })?;
        if r > 0 && slot.adam_t != adam_t {
            bail!(
                "layer {layer}: tp shards disagree on the Adam step ({} vs {adam_t})",
                slot.adam_t
            );
        }
        adam_t = slot.adam_t;
        wl.scatter(&slot.params, r, &mut params);
        wl.scatter(&slot.m, r, &mut m);
        wl.scatter(&slot.v, r, &mut v);
    }
    Ok((params, m, v, adam_t))
}

/// Run the embedding backward for one micro-batch's (reduced) input
/// gradient, accumulating into the embedding-table and positional
/// gradients.
#[allow(clippy::too_many_arguments)]
fn embed_backward(
    engine: &mut Engine,
    act_shape: &[usize],
    batch: usize,
    d_seq: usize,
    tokens: Vec<i32>,
    dx: Vec<f32>,
    d_table: &mut [f32],
    d_pos: &mut [f32],
) -> Result<()> {
    let outs = engine.execute(
        "embed_bwd",
        &[
            HostTensor::f32(act_shape.to_vec(), dx),
            HostTensor::i32(vec![batch, d_seq], tokens),
        ],
    )?;
    for (d, s) in d_table.iter_mut().zip(outs[0].as_f32()?) {
        *d += s;
    }
    for (d, s) in d_pos.iter_mut().zip(outs[1].as_f32()?) {
        *d += s;
    }
    Ok(())
}

/// Stream one whole (dp-unsharded) slot — params + Adam state — to the
/// checkpoint store. `(tp, tp_rank)` records the slot's tensor-parallel
/// provenance: (1, 0) for replicated tensors (embedding / positional /
/// head, and full layers under emulation), the writer's shard
/// coordinates for sharded layer slots.
#[allow(clippy::too_many_arguments)]
fn store_full_slot(
    store: &dyn StateStore,
    step: usize,
    slot: usize,
    global_mbs: u64,
    params: &[f32],
    adam: &Adam,
    tp: usize,
    tp_rank: usize,
) -> Result<()> {
    let (m, v, t) = adam.state();
    store.put(&StateRecord {
        step: step as u64,
        slot: slot as u64,
        lo: 0,
        hi: params.len() as u64,
        total: params.len() as u64,
        adam_t: t,
        global_mbs,
        tp: tp as u64,
        tp_rank: tp_rank as u64,
        zero: 0,
        dp_rank: 0,
        params: params.to_vec(),
        m: m.to_vec(),
        v: v.to_vec(),
    })
}

/// Run the worker to completion (all steps). Returns its stats.
pub fn run_worker(mut ctx: WorkerCtx) -> Result<WorkerStats> {
    let t0 = std::time::Instant::now();
    let prog = ctx.program.clone();
    let rank = ctx.world.rank();
    let topo = ctx.world.topology();
    anyhow::ensure!(
        topo.tp == prog.tp,
        "topology tp = {} but the schedule was generated for tp = {}",
        topo.tp,
        prog.tp
    );
    let (dp_rank, stage) = (rank.dp, rank.stage);
    let n_b = topo.dp;
    let has_tp = topo.tp > 1;
    let tp_rank = rank.tp;
    // Replicated state (specials, loss) is written by tp rank 0 only.
    let tp_writer = rank.tp == 0;
    // Sharded layer compute (decided once by the trainer; see module
    // docs). Under emulation every rank holds full replicated state.
    let sharded = has_tp && ctx.tp_sharded;

    let owns_first = prog.stage_of(0) == stage;
    let d_l = prog.d_l;
    let owns_last = prog.stage_of(d_l - 1) == stage;

    let art_attn_fwd = tp_artifact_name("attn_fwd", topo.tp);
    let art_ffn_fwd = tp_artifact_name("ffn_fwd", topo.tp);
    let art_attn_bwd = tp_artifact_name("attn_bwd", topo.tp);
    let art_ffn_bwd = tp_artifact_name("ffn_bwd", topo.tp);
    let mut names: Vec<&str> = if sharded {
        vec![&art_attn_fwd, &art_ffn_fwd, &art_attn_bwd, &art_ffn_bwd]
    } else {
        vec!["layer_fwd", "layer_bwd"]
    };
    if owns_first {
        names.extend(["embed_fwd", "embed_bwd"]);
    }
    if owns_last {
        names.push("head_loss_grad");
    }
    let mut engine = Engine::new(&ctx.artifacts_root, &ctx.preset, &names)?;
    let m = engine.manifest().model;
    let batch = engine.manifest().batch;
    let layout = LayerLayout::from_manifest(engine.manifest());
    // The sharded flat layout (and the full↔shard index map behind
    // init/checkpoint re-sharding); validated against the manifest's
    // per-shard TensorSpecs.
    let slayout: Option<ShardedLayout> = if sharded {
        Some(ShardedLayout::from_manifest(engine.manifest(), topo.tp)?)
    } else {
        None
    };
    let slot_total = slayout.as_ref().map_or(layout.total, |s| s.total);
    let corpus = Corpus::new(m.vocab);

    // --- parameter state -------------------------------------------------
    let my_layers: Vec<usize> =
        (0..d_l).filter(|&l| prog.stage_of(l) == stage).collect();
    let mut params: HashMap<usize, Vec<f32>> = HashMap::new();
    let mut grads: HashMap<usize, Vec<f32>> = HashMap::new();
    let mut adam: HashMap<usize, Adam> = HashMap::new();
    let shard = ShardMap::new(slot_total, n_b);
    for &l in &my_layers {
        // Same seed across dp and tp ranks: the full initialisation is
        // replicated, and a sharded rank slices its shard out of it —
        // so a tp run starts from exactly the tp = 1 network.
        let mut rng = crate::data::Rng::new(ctx.seed ^ (0x517c_c1b7_2722_0a95 + l as u64));
        let full = layout.init(&mut rng);
        let mine = match &slayout {
            Some(sl) => sl.gather(&full, tp_rank),
            None => full,
        };
        params.insert(l, mine);
        grads.insert(l, vec![0.0; slot_total]);
        let n = if (ctx.partition || ctx.zero >= 1) && n_b > 1 {
            let (a, b) = shard.owned_range(dp_rank);
            b - a
        } else {
            slot_total
        };
        adam.insert(l, Adam::new(n, AdamConfig::default()));
    }

    // Embedding / head state (first / last stage only; never partitioned
    // — they are small and the paper's partition concerns the layers).
    let mut rng_e = crate::data::Rng::new(ctx.seed ^ 0xabcd_ef01);
    let (mut table, mut pos, mut d_table, mut d_pos, mut adam_table, mut adam_pos) =
        if owns_first {
            (
                init_matrix(&mut rng_e, m.vocab, m.d_model, 0.02),
                init_matrix(&mut rng_e, m.d_seq, m.d_model, 0.02),
                vec![0.0f32; m.vocab * m.d_model],
                vec![0.0f32; m.d_seq * m.d_model],
                Some(Adam::new(m.vocab * m.d_model, AdamConfig::default())),
                Some(Adam::new(m.d_seq * m.d_model, AdamConfig::default())),
            )
        } else {
            (vec![], vec![], vec![], vec![], None, None)
        };
    let mut rng_h = crate::data::Rng::new(ctx.seed ^ 0x1234_5678);
    let (mut head, mut d_head, mut adam_head) = if owns_last {
        (
            init_matrix(&mut rng_h, m.d_model, m.vocab, 0.02),
            vec![0.0f32; m.d_model * m.vocab],
            Some(Adam::new(m.d_model * m.vocab, AdamConfig::default())),
        )
    } else {
        (vec![], vec![], None)
    };

    // --- resume: overwrite the seed state from the checkpoint store ------
    if ctx.start_step > 0 {
        let store =
            ctx.store.as_deref().context("resume requires a checkpoint store")?;
        let ck = (ctx.start_step - 1) as u64;
        // This run's state sharding vs the writer's. Matching layouts
        // read the rank's own slot directly (no full-state buffers —
        // the common restart path must not cost tp× the redundant I/O
        // or a full-model memory spike on ranks sized for 1/tp state);
        // a tp *change* re-shards: scatter the writer's shards back to
        // the full state, then gather this rank's own shard.
        let state_tp = if sharded { topo.tp } else { 1 };
        let state_rank = if sharded { tp_rank } else { 0 };
        let wlayout: Option<ShardedLayout> = if ctx.ckpt_tp > 1 && ctx.ckpt_tp != state_tp {
            Some(ShardedLayout::from_manifest(engine.manifest(), ctx.ckpt_tp)?)
        } else {
            None
        };
        for &l in &my_layers {
            // Any complete cover reassembles, regardless of the writer's
            // n_b *or* tp; the Adam moments then re-slice to *this*
            // run's owned range — the §8.1 elastic-resume re-shard,
            // extended across the tensor-parallel axis.
            let (p, sm, sv, adam_t) = if ctx.ckpt_tp == state_tp {
                let slot = assemble(
                    &store.read(ck, slot_layer(d_l, state_rank, l) as u64)?,
                    slot_total,
                )
                .with_context(|| format!("layer {l} checkpoint at step {ck}"))?;
                (slot.params, slot.m, slot.v, slot.adam_t)
            } else {
                let (fp, fm, fv, adam_t) =
                    assemble_layer_full(store, ck, d_l, l, layout.total, wlayout.as_ref())?;
                match &slayout {
                    Some(sl) => (
                        sl.gather(&fp, tp_rank),
                        sl.gather(&fm, tp_rank),
                        sl.gather(&fv, tp_rank),
                        adam_t,
                    ),
                    None => (fp, fm, fv, adam_t),
                }
            };
            params.insert(l, p);
            let a = if (ctx.partition || ctx.zero >= 1) && n_b > 1 {
                let (lo, hi) = shard.owned_range(dp_rank);
                Adam::from_state(
                    AdamConfig::default(),
                    sm[lo..hi].to_vec(),
                    sv[lo..hi].to_vec(),
                    adam_t,
                )
            } else {
                Adam::from_state(AdamConfig::default(), sm, sv, adam_t)
            };
            adam.insert(l, a);
        }
        if owns_first {
            let e = assemble(&store.read(ck, slot_embed(d_l) as u64)?, m.vocab * m.d_model)
                .context("embedding checkpoint")?;
            table = e.params;
            adam_table = Some(Adam::from_state(AdamConfig::default(), e.m, e.v, e.adam_t));
            let p = assemble(&store.read(ck, slot_pos(d_l) as u64)?, m.d_seq * m.d_model)
                .context("positional checkpoint")?;
            pos = p.params;
            adam_pos = Some(Adam::from_state(AdamConfig::default(), p.m, p.v, p.adam_t));
        }
        if owns_last {
            let h = assemble(&store.read(ck, slot_head(d_l) as u64)?, m.d_model * m.vocab)
                .context("head checkpoint")?;
            head = h.params;
            adam_head = Some(Adam::from_state(AdamConfig::default(), h.m, h.v, h.adam_t));
        }
    }

    // Measured (not modeled) resident parameter + optimizer bytes — the
    // acceptance number tensor parallelism is supposed to divide.
    let f32b = crate::runtime::DType::F32.bytes() as u64;
    let mut layer_state_bytes = 0u64;
    for &l in &my_layers {
        let (am, av, _) = adam[&l].state();
        layer_state_bytes += (params[&l].len() + am.len() + av.len()) as u64 * f32b;
    }
    let mut total_state_bytes = layer_state_bytes
        + (table.len() + pos.len() + head.len()) as u64 * f32b;
    for a in [&adam_table, &adam_pos, &adam_head].into_iter().flatten() {
        let (am, av, _) = a.state();
        total_state_bytes += (am.len() + av.len()) as u64 * f32b;
    }

    let act_shape = vec![batch, m.d_seq, m.d_model];
    let act_elems: usize = act_shape.iter().product();

    // This stage's slice of the program arena, in dispatch order, plus a
    // per-step completion bitmap for checking local dependency edges.
    let stage_nodes: Vec<(u32, Op)> =
        prog.stage_ops(stage).iter().map(|n| (n.id, n.op)).collect();
    let mut op_done: Vec<bool> = vec![false; prog.len()];

    let (seed, n_mu) = (ctx.seed, ctx.n_mu);
    let tokens_of = move |step: usize, mb: usize| {
        // Micro-batches are keyed by their *global* index, so the
        // data a step consumes is invariant to how the batch splits
        // across data-parallel instances — exactly what lets an
        // elastic resume at a different n_b (same n_b·n_μ) continue
        // the same training trajectory. Tensor-parallel ranks replicate
        // their dp instance's data (tp shards compute, not the batch).
        let global_mb = (dp_rank * n_mu + mb) as u64;
        corpus.batch(seed, step as u64, 0, global_mb, batch, m.d_seq)
    };

    // --- step loop ---------------------------------------------------------
    for step in ctx.start_step..ctx.steps {
        op_done.fill(false);
        // Transient per-step state.
        let mut inbox: HashMap<(usize, usize), Vec<f32>> = HashMap::new(); // input of (layer, mb)
        let mut ckpt: HashMap<(usize, usize), Vec<f32>> = HashMap::new();
        let mut outbox: HashMap<(usize, usize), Vec<f32>> = HashMap::new(); // output of (layer, mb)
        let mut douts: HashMap<(usize, usize), Vec<f32>> = HashMap::new(); // dL/d out(layer, mb)
        let mut goutbox: HashMap<(usize, usize), Vec<f32>> = HashMap::new(); // dL/d in(layer, mb)
        let mut last_out: HashMap<usize, Vec<f32>> = HashMap::new();
        // Layer 0's input-gradients awaiting their backward
        // TensorAllReduce (emulation mode only): the embedding must
        // consume the *reduced* gradient, so the embed backward runs
        // inside the tb0 op instead of B0.
        let mut embed_dx: HashMap<usize, Vec<f32>> = HashMap::new();
        // Sharded execution: the residual input x2 of (layer, mb),
        // stashed by Fwd and added back once the scheduled forward
        // TensorAllReduce has summed the FFN partials.
        let mut residual: HashMap<(usize, usize), Vec<f32>> = HashMap::new();
        // Sharded execution: (dx_partial, dx2) of (layer, mb), stashed
        // by Bwd; the backward TensorAllReduce sums the partials and
        // completes dx = dx2 + Σ dx_partial.
        let mut pending_bwd: HashMap<(usize, usize), (Vec<f32>, Vec<f32>)> = HashMap::new();
        let mut loss_sum = 0.0f64;
        // Per-(layer, half) HostTensor views of the parameters, reused
        // across micro-batches (§Perf L3: converting 12 tensors per PJRT
        // call dominated tiny-model steps). Unsharded layers use half 0
        // for the whole 12-tensor set; sharded layers cache the
        // attention (0) and FFN (1) halves separately. Invalidated when
        // the parameters change (OptimStep) or are re-gathered
        // (RestoreParams).
        let mut param_cache: HashMap<(usize, u8), Vec<HostTensor>> = HashMap::new();

        for &(op_id, op) in &stage_nodes {
            // An in-order dispatcher satisfies a local edge iff the
            // producer already ran; a violation here means the program's
            // stage order contradicts its own dependency graph (lowering
            // rejects such schedules, so this guards engine bugs and
            // hand-built programs).
            for &pid in prog.preds_of(op_id) {
                let pn = &prog.ops[pid as usize];
                if pn.stage as usize == stage && !op_done[pid as usize] {
                    bail!(
                        "stage {} dispatched {} before its dependency {}",
                        stage,
                        op,
                        pn.op
                    );
                }
            }
            match op {
                Op::RestoreParams { layer } => {
                    if ctx.partition && n_b > 1 {
                        ctx.world.dp_group().all_gather_owned(params.get_mut(&layer).unwrap());
                        param_cache.remove(&(layer, 0));
                        param_cache.remove(&(layer, 1));
                    }
                }
                Op::AllGatherParams { layer } => {
                    // ZeRO 1–2 post-step gather (redistributes the owned
                    // 1/dp slices each rank just updated) and ZeRO 3
                    // gather-before-use share one op: both rebuild the
                    // full parameter buffer from the dp group's owned
                    // chunks, identical to the partition's RestoreParams.
                    if n_b > 1 {
                        ctx.world.dp_group().all_gather_owned(params.get_mut(&layer).unwrap());
                        param_cache.remove(&(layer, 0));
                        param_cache.remove(&(layer, 1));
                    }
                }
                Op::Fwd { layer, mb } => {
                    let x = if layer == 0 {
                        let b = tokens_of(step, mb);
                        let out = engine.execute(
                            "embed_fwd",
                            &[
                                HostTensor::f32(vec![m.vocab, m.d_model], table.clone()),
                                HostTensor::f32(vec![m.d_seq, m.d_model], pos.clone()),
                                HostTensor::i32(vec![batch, m.d_seq], b.tokens),
                            ],
                        )?;
                        out[0].as_f32()?.to_vec()
                    } else {
                        inbox
                            .remove(&(layer, mb))
                            .with_context(|| format!("missing input for F{layer}.{mb}"))?
                    };
                    let y = if let Some(sl) = &slayout {
                        // Sharded half-layer forward: partial attention
                        // → mid-layer all-reduce → residual → partial
                        // FFN. The scheduled TensorAllReduce later sums
                        // the FFN partials and adds the stashed x2.
                        let mut args = param_cache
                            .entry((layer, 0))
                            .or_insert_with(|| sl.half_tensors(&params[&layer], 0, tp_rank))
                            .clone();
                        args.push(HostTensor::f32(act_shape.clone(), x.clone()));
                        let a = engine.execute(&art_attn_fwd, &args)?;
                        let mut x2 = a[0].as_f32()?.to_vec();
                        ctx.world.tp_group().all_reduce(&mut x2);
                        add_into(&mut x2, &x);
                        let mut args = param_cache
                            .entry((layer, 1))
                            .or_insert_with(|| sl.half_tensors(&params[&layer], 6, tp_rank))
                            .clone();
                        args.push(HostTensor::f32(act_shape.clone(), x2.clone()));
                        let f = engine.execute(&art_ffn_fwd, &args)?;
                        residual.insert((layer, mb), x2);
                        f[0].as_f32()?.to_vec()
                    } else {
                        let mut args = param_cache
                            .entry((layer, 0))
                            .or_insert_with(|| layout.tensors(&params[&layer]))
                            .clone();
                        args.push(HostTensor::f32(act_shape.clone(), x.clone()));
                        let y = engine.execute("layer_fwd", &args)?;
                        y[0].as_f32()?.to_vec()
                    };
                    ckpt.insert((layer, mb), x);
                    if layer + 1 == d_l {
                        last_out.insert(mb, y);
                    } else if prog.stage_of(layer + 1) == stage {
                        inbox.insert((layer + 1, mb), y);
                    } else {
                        outbox.insert((layer, mb), y);
                    }
                }
                Op::SendAct { layer, mb } => {
                    let y = outbox
                        .remove(&(layer, mb))
                        .with_context(|| format!("missing payload for sa{layer}.{mb}"))?;
                    ctx.world.pipeline().send_act(layer + 1, mb, y).context("act ring closed")?;
                }
                Op::RecvAct { layer, mb } => {
                    let (l, m_, y) =
                        ctx.world.pipeline().recv_act().context("act ring closed")?;
                    let peer =
                        Rank { stage: prog.stage_of(layer - 1), dp: dp_rank, tp: tp_rank };
                    check_payload("act", peer, op_id, (l, m_, y.len()), (layer, mb, act_elems))?;
                    inbox.insert((layer, mb), y);
                }
                Op::Bwd { layer, mb } => {
                    let dy = if layer + 1 == d_l {
                        let b = tokens_of(step, mb);
                        let x_out = last_out
                            .remove(&mb)
                            .with_context(|| format!("missing head input for B{layer}.{mb}"))?;
                        let outs = engine.execute(
                            "head_loss_grad",
                            &[
                                HostTensor::f32(vec![m.d_model, m.vocab], head.clone()),
                                HostTensor::f32(act_shape.clone(), x_out),
                                HostTensor::i32(vec![batch, m.d_seq], b.targets),
                            ],
                        )?;
                        loss_sum += outs[0].scalar_f32()? as f64;
                        for (d, s) in d_head.iter_mut().zip(outs[2].as_f32()?) {
                            *d += s;
                        }
                        outs[1].as_f32()?.to_vec()
                    } else {
                        douts
                            .remove(&(layer, mb))
                            .with_context(|| format!("missing dy for B{layer}.{mb}"))?
                    };
                    let x = ckpt
                        .remove(&(layer, mb))
                        .with_context(|| format!("missing checkpoint for B{layer}.{mb}"))?;
                    if let Some(sl) = &slayout {
                        // Sharded backward, three phases from the
                        // checkpoint input x and the full dy:
                        //  1. recompute x2 = x + Σ attn_part(x) (one
                        //     mid-layer all-reduce, same values as Fwd);
                        //  2. FFN-half VJP → shard grads + dh partial;
                        //     dx2 = dy + Σ dh (second all-reduce);
                        //  3. attention-half VJP → shard grads + dx
                        //     partial, left for the scheduled backward
                        //     TensorAllReduce to complete.
                        let attn_args = param_cache
                            .entry((layer, 0))
                            .or_insert_with(|| sl.half_tensors(&params[&layer], 0, tp_rank))
                            .clone();
                        let mut args = attn_args.clone();
                        args.push(HostTensor::f32(act_shape.clone(), x.clone()));
                        let a = engine.execute(&art_attn_fwd, &args)?;
                        let mut x2 = a[0].as_f32()?.to_vec();
                        ctx.world.tp_group().all_reduce(&mut x2);
                        add_into(&mut x2, &x);

                        let mut args = param_cache
                            .entry((layer, 1))
                            .or_insert_with(|| sl.half_tensors(&params[&layer], 6, tp_rank))
                            .clone();
                        args.push(HostTensor::f32(act_shape.clone(), x2));
                        args.push(HostTensor::f32(act_shape.clone(), dy.clone()));
                        let outs = engine.execute(&art_ffn_bwd, &args)?;
                        sl.accumulate_half(grads.get_mut(&layer).unwrap(), &outs[..6], 6);
                        let mut dx2 = outs[6].as_f32()?.to_vec();
                        ctx.world.tp_group().all_reduce(&mut dx2);
                        add_into(&mut dx2, &dy);

                        let mut args = attn_args;
                        args.push(HostTensor::f32(act_shape.clone(), x));
                        args.push(HostTensor::f32(act_shape.clone(), dx2.clone()));
                        let outs = engine.execute(&art_attn_bwd, &args)?;
                        sl.accumulate_half(grads.get_mut(&layer).unwrap(), &outs[..6], 0);
                        let dx_part = outs[6].as_f32()?.to_vec();
                        pending_bwd.insert((layer, mb), (dx_part, dx2));
                    } else {
                        let mut args = param_cache
                            .entry((layer, 0))
                            .or_insert_with(|| layout.tensors(&params[&layer]))
                            .clone();
                        args.push(HostTensor::f32(act_shape.clone(), x));
                        args.push(HostTensor::f32(act_shape.clone(), dy));
                        let outs = engine.execute("layer_bwd", &args)?;
                        layout.accumulate(grads.get_mut(&layer).unwrap(), &outs[..12]);
                        let dx = outs[12].as_f32()?.to_vec();
                        if layer == 0 {
                            if has_tp {
                                // Defer: the embedding consumes the
                                // *reduced* gradient inside the tb0 op.
                                embed_dx.insert(mb, dx);
                            } else {
                                let b = tokens_of(step, mb);
                                embed_backward(
                                    &mut engine,
                                    &act_shape,
                                    batch,
                                    m.d_seq,
                                    b.tokens,
                                    dx,
                                    &mut d_table,
                                    &mut d_pos,
                                )?;
                            }
                        } else if prog.stage_of(layer - 1) == stage {
                            douts.insert((layer - 1, mb), dx);
                        } else {
                            goutbox.insert((layer, mb), dx);
                        }
                    }
                }
                Op::SendGrad { layer, mb } => {
                    let g = goutbox
                        .remove(&(layer, mb))
                        .with_context(|| format!("missing payload for sg{layer}.{mb}"))?;
                    ctx.world
                        .pipeline()
                        .send_grad(layer - 1, mb, g)
                        .context("grad ring closed")?;
                }
                Op::RecvGrad { layer, mb } => {
                    let (l, m_, g) =
                        ctx.world.pipeline().recv_grad().context("grad ring closed")?;
                    // The output-gradient has the activation's shape; an
                    // unchecked length here skewed nothing visibly until
                    // layer_bwd rejected the tensor much later.
                    let peer =
                        Rank { stage: prog.stage_of(layer + 1), dp: dp_rank, tp: tp_rank };
                    check_payload("grad", peer, op_id, (l, m_, g.len()), (layer, mb, act_elems))?;
                    douts.insert((layer, mb), g);
                }
                Op::TensorAllReduce { layer, mb, bwd } => {
                    // The layer-boundary reduce. Sharded execution: a
                    // plain ring *sum* of genuine partials, completed
                    // with the stashed residual (fwd: y = x2 + Σ ffn
                    // partials; bwd: dx = dx2 + Σ dx partials).
                    // Emulation: sum-then-1/tp-postscale, an exact
                    // identity on the replicated values (module docs).
                    if !bwd {
                        let buf = if layer + 1 == d_l {
                            last_out.get_mut(&mb)
                        } else if prog.stage_of(layer + 1) == stage {
                            inbox.get_mut(&(layer + 1, mb))
                        } else {
                            outbox.get_mut(&(layer, mb))
                        };
                        let buf = buf
                            .with_context(|| format!("missing activation for tf{layer}.{mb}"))?;
                        if slayout.is_some() {
                            let x2 = residual
                                .remove(&(layer, mb))
                                .with_context(|| format!("missing residual for tf{layer}.{mb}"))?;
                            ctx.world.tp_group().all_reduce(buf);
                            add_into(buf, &x2);
                        } else {
                            tp_all_reduce(ctx.world.tp_group(), buf);
                        }
                    } else if slayout.is_some() {
                        let (mut dx, dx2) = pending_bwd
                            .remove(&(layer, mb))
                            .with_context(|| format!("missing partials for tb{layer}.{mb}"))?;
                        ctx.world.tp_group().all_reduce(&mut dx);
                        add_into(&mut dx, &dx2);
                        if layer == 0 {
                            let b = tokens_of(step, mb);
                            embed_backward(
                                &mut engine,
                                &act_shape,
                                batch,
                                m.d_seq,
                                b.tokens,
                                dx,
                                &mut d_table,
                                &mut d_pos,
                            )?;
                        } else if prog.stage_of(layer - 1) == stage {
                            douts.insert((layer - 1, mb), dx);
                        } else {
                            goutbox.insert((layer, mb), dx);
                        }
                    } else if layer == 0 {
                        let mut dx = embed_dx
                            .remove(&mb)
                            .with_context(|| format!("missing gradient for tb0.{mb}"))?;
                        tp_all_reduce(ctx.world.tp_group(), &mut dx);
                        let b = tokens_of(step, mb);
                        embed_backward(
                            &mut engine,
                            &act_shape,
                            batch,
                            m.d_seq,
                            b.tokens,
                            dx,
                            &mut d_table,
                            &mut d_pos,
                        )?;
                    } else {
                        let buf = if prog.stage_of(layer - 1) == stage {
                            douts.get_mut(&(layer - 1, mb))
                        } else {
                            goutbox.get_mut(&(layer, mb))
                        };
                        let buf = buf
                            .with_context(|| format!("missing gradient for tb{layer}.{mb}"))?;
                        tp_all_reduce(ctx.world.tp_group(), buf);
                    }
                }
                Op::ReduceGrad { layer } => {
                    let g = grads.get_mut(&layer).unwrap();
                    let scale = 1.0 / (n_b as f32 * n_mu as f32);
                    for v in g.iter_mut() {
                        *v *= scale;
                    }
                    // Sharded execution: complete the layernorm
                    // gradients (partial per tp rank) before the dp
                    // reduction consumes them. Sums commute, so the
                    // order against the 1/batch scale is immaterial.
                    if let Some(sl) = &slayout {
                        tp_reduce_spans(ctx.world.tp_group(), g, sl.grad_tp_spans());
                    }
                    if n_b > 1 {
                        if ctx.partition {
                            ctx.world.dp_group().reduce_scatter(g);
                        } else {
                            ctx.world.dp_group().all_reduce(g);
                        }
                    }
                }
                Op::ReduceScatterGrad { layer } => {
                    // ZeRO ≥2: each rank keeps only the fully-reduced
                    // owned chunk — the same ring rounds as the
                    // all-reduce's first half, so the owned values are
                    // bitwise the all-reduce's (the zero ↔ zero=0 parity
                    // hinges on this; see collective::ring).
                    let g = grads.get_mut(&layer).unwrap();
                    let scale = 1.0 / (n_b as f32 * n_mu as f32);
                    for v in g.iter_mut() {
                        *v *= scale;
                    }
                    if let Some(sl) = &slayout {
                        tp_reduce_spans(ctx.world.tp_group(), g, sl.grad_tp_spans());
                    }
                    if n_b > 1 {
                        ctx.world.dp_group().reduce_scatter(g);
                    }
                }
                Op::OptimStep { layer } => {
                    let lr = ctx.lr.lr(step as u64);
                    let p = params.get_mut(&layer).unwrap();
                    let g = grads.get_mut(&layer).unwrap();
                    let a = adam.get_mut(&layer).unwrap();
                    // Schedules emit ReduceGrad only when n_b > 1 or the
                    // state is partitioned; without one, nothing has
                    // normalized the micro-batch sum yet. Scale here so
                    // Adam always consumes the batch *mean* — the same
                    // gradient for every (n_b, n_mu) split of the batch,
                    // which is what lets a checkpoint written at one
                    // cluster size resume at another.
                    if n_b == 1 && !ctx.partition {
                        // ... and, without a ReduceGrad, nothing has
                        // completed the partial layernorm gradients of a
                        // sharded layer either — do it here, once.
                        if let Some(sl) = &slayout {
                            tp_reduce_spans(ctx.world.tp_group(), g, sl.grad_tp_spans());
                        }
                        let scale = 1.0 / n_mu as f32;
                        for v in g.iter_mut() {
                            *v *= scale;
                        }
                    }
                    if (ctx.partition || ctx.zero >= 1) && n_b > 1 {
                        let (lo, hi) = shard.owned_range(dp_rank);
                        a.step(&mut p[lo..hi], &g[lo..hi], lr);
                    } else {
                        a.step(p, g, lr);
                    }
                    g.fill(0.0);
                    param_cache.remove(&(layer, 0));
                    param_cache.remove(&(layer, 1));
                }
                Op::OffloadStore { layer } => {
                    // Stream the post-step state (the store-after-optim
                    // edge guarantees the buffers hold updated values).
                    // With a partition every dp rank writes its owned
                    // shard — together a complete cover; replicated
                    // state is written once, by dp rank 0. Sharded
                    // execution: every tp rank owns a *different* slice,
                    // so each writes its own (layer, tp_rank) slot;
                    // under emulation the replicas are identical and tp
                    // rank 0 writes the one full copy.
                    let (state_tp, state_tp_rank) = match &slayout {
                        Some(_) => (topo.tp, tp_rank),
                        None => (1, 0),
                    };
                    if state_tp == 1 && !tp_writer {
                        op_done[op_id as usize] = true;
                        continue;
                    }
                    let store = ctx
                        .store
                        .as_deref()
                        .context("offload schedule without a checkpoint store")?;
                    let global_mbs = (n_b * n_mu) as u64;
                    let slot = slot_layer(d_l, state_tp_rank, layer);
                    if (ctx.partition || ctx.zero >= 1) && n_b > 1 {
                        let (lo, hi) = shard.owned_range(dp_rank);
                        let (am, av, at) = adam.get(&layer).unwrap().state();
                        store.put(&StateRecord {
                            step: step as u64,
                            slot: slot as u64,
                            lo: lo as u64,
                            hi: hi as u64,
                            total: slot_total as u64,
                            adam_t: at,
                            global_mbs,
                            tp: state_tp as u64,
                            tp_rank: state_tp_rank as u64,
                            zero: ctx.zero as u64,
                            dp_rank: dp_rank as u64,
                            params: params[&layer][lo..hi].to_vec(),
                            m: am.to_vec(),
                            v: av.to_vec(),
                        })?;
                    } else if dp_rank == 0 {
                        let a = &adam[&layer];
                        store_full_slot(
                            store,
                            step,
                            slot,
                            global_mbs,
                            &params[&layer],
                            a,
                            state_tp,
                            state_tp_rank,
                        )?;
                    }
                }
            }
            op_done[op_id as usize] = true;
        }

        // Step epilogue: embedding / head parameters (reduced over DP).
        let lr = ctx.lr.lr(step as u64);
        let scale = 1.0 / (n_b as f32 * n_mu as f32);
        if owns_first {
            for g in [&mut d_table, &mut d_pos] {
                for v in g.iter_mut() {
                    *v *= scale;
                }
            }
            ctx.world.dp_group().all_reduce(&mut d_table);
            ctx.world.dp_group().all_reduce(&mut d_pos);
            adam_table.as_mut().unwrap().step(&mut table, &d_table, lr);
            adam_pos.as_mut().unwrap().step(&mut pos, &d_pos, lr);
            d_table.fill(0.0);
            d_pos.fill(0.0);
        }
        if owns_last {
            for v in d_head.iter_mut() {
                *v *= scale;
            }
            ctx.world.dp_group().all_reduce(&mut d_head);
            adam_head.as_mut().unwrap().step(&mut head, &d_head, lr);
            d_head.fill(0.0);
            if tp_writer {
                ctx.world.control().report_loss(step, dp_rank, loss_sum / n_mu as f64);
            }
        }
        // Real-time checkpoint epilogue: the replicated non-layer state
        // (embedding / positional / head) streams out once per step from
        // (dp 0, tp 0) of its owning stage, completing the step's record
        // cover.
        if ctx.offload && dp_rank == 0 && tp_writer {
            if let Some(store) = ctx.store.as_deref() {
                let g = (n_b * n_mu) as u64;
                if owns_first {
                    let a = adam_table.as_ref().unwrap();
                    store_full_slot(store, step, slot_embed(d_l), g, &table, a, 1, 0)?;
                    let a = adam_pos.as_ref().unwrap();
                    store_full_slot(store, step, slot_pos(d_l), g, &pos, a, 1, 0)?;
                    // Retention: keep the in-flight step and the last
                    // complete one, drop everything older. Safe here:
                    // stage 0 reaching step `s` implies every stage of
                    // every rank has finished step `s-2` (the pipeline
                    // and step barriers bound the lag to one step), so no
                    // one is still writing the steps being pruned.
                    if step >= 2 {
                        store.prune_steps_before((step - 1) as u64)?;
                    }
                }
                if owns_last {
                    let a = adam_head.as_ref().unwrap();
                    store_full_slot(store, step, slot_head(d_l), g, &head, a, 1, 0)?;
                }
            }
        }
        // Heartbeat: tells a supervising coordinator this rank finished
        // the step (feeds the stall detector and chaos kill plans).
        ctx.world.control().report_progress(step);
        ctx.world.step_barrier();
    }

    let traffic = ctx.world.traffic();
    let wall_secs = t0.elapsed().as_secs_f64();
    // Ship the same numbers over the control plane (a no-op on the
    // thread backend, where stats return through the join; the socket
    // backend's coordinator needs them streamed).
    ctx.world.control().report_stats(crate::collective::RankStats {
        execute_secs: engine.execute_secs,
        execute_calls: engine.execute_calls,
        collective_elems_sent: traffic.dp,
        pipeline_elems_sent: traffic.pipeline,
        tp_elems_sent: traffic.tp,
        layer_state_bytes,
        total_state_bytes,
        wall_secs,
        tp_sharded: ctx.tp_sharded,
        schedule: prog.name.clone(),
    });
    Ok(WorkerStats {
        execute_secs: engine.execute_secs,
        execute_calls: engine.execute_calls,
        collective_elems_sent: traffic.dp,
        pipeline_elems_sent: traffic.pipeline,
        tp_elems_sent: traffic.tp,
        layer_state_bytes,
        total_state_bytes,
        wall_secs,
    })
}

#[cfg(test)]
mod tests {
    use super::{add_into, check_payload, tp_all_reduce, tp_reduce_spans};
    use crate::collective::{ring_group, Rank};

    fn peer() -> Rank {
        Rank { stage: 2, dp: 1, tp: 0 }
    }

    #[test]
    fn payload_check_accepts_exact_match_only() {
        assert!(check_payload("act", peer(), 7, (3, 2, 64), (3, 2, 64)).is_ok());
        // Identity mismatches.
        assert!(check_payload("act", peer(), 7, (4, 2, 64), (3, 2, 64)).is_err());
        assert!(check_payload("act", peer(), 7, (3, 1, 64), (3, 2, 64)).is_err());
        // Size mismatches — both directions (a short *gradient* payload
        // used to be accepted silently, unlike activations).
        assert!(check_payload("grad", peer(), 7, (3, 2, 63), (3, 2, 64)).is_err());
        assert!(check_payload("grad", peer(), 7, (3, 2, 65), (3, 2, 64)).is_err());
    }

    #[test]
    fn payload_check_reports_what_where_and_who() {
        let err = check_payload("grad", peer(), 41, (1, 0, 10), (1, 0, 20)).unwrap_err();
        let msg = format!("{err:#}");
        // What went wrong: kind + actual/expected element counts.
        assert!(msg.contains("grad") && msg.contains("10") && msg.contains("20"), "{msg}");
        // Where to look: the peer's full grid coordinates and the op id.
        assert!(msg.contains("stage 2") && msg.contains("dp 1") && msg.contains("tp 0"), "{msg}");
        assert!(msg.contains("op 41"), "{msg}");
    }

    #[test]
    fn tp_all_reduce_is_bitwise_identity_on_replicated_tp2_buffers() {
        // The loss-match guarantee in miniature: two ranks holding the
        // same buffer run the sum-and-postscale roundtrip and end
        // exactly where they started ((x + x) / 2 = x in IEEE 754 for
        // every finite x — including the subnormals a prescale would
        // round away).
        let mut data: Vec<f32> = (0..257).map(|i| (i as f32 - 77.5) * 1.618e-3).collect();
        data.extend([1e-45f32, -3.0e-39, f32::MIN_POSITIVE, 0.0, -0.0]);
        let handles: Vec<_> = ring_group(2)
            .into_iter()
            .map(|mut g| {
                let mut d = data.clone();
                std::thread::spawn(move || {
                    tp_all_reduce(&mut g, &mut d);
                    d
                })
            })
            .collect();
        for h in handles {
            let out = h.join().unwrap();
            for (a, b) in out.iter().zip(&data) {
                assert_eq!(a.to_bits(), b.to_bits());
            }
        }
    }

    #[test]
    fn tp_all_reduce_on_a_single_rank_is_untouched() {
        let mut g = ring_group(1).remove(0);
        let mut d = vec![1.25f32, -3.5];
        tp_all_reduce(&mut g, &mut d);
        assert_eq!(d, vec![1.25, -3.5]);
        assert_eq!(g.sent_elems(), 0);
    }

    #[test]
    fn add_into_is_elementwise() {
        let mut d = vec![1.0f32, 2.0, 3.0];
        add_into(&mut d, &[0.5, -2.0, 1.0]);
        assert_eq!(d, vec![1.5, 0.0, 4.0]);
    }

    #[test]
    fn tp_reduce_spans_sums_exactly_the_spans() {
        // Two ranks hold different layernorm partials inside a larger
        // gradient buffer; the span reduce must sum the spans across
        // ranks and leave everything else untouched.
        let spans = vec![(1usize, 2usize), (5, 1)];
        let handles: Vec<_> = ring_group(2)
            .into_iter()
            .map(|mut g| {
                let spans = spans.clone();
                let r = g.rank as f32;
                std::thread::spawn(move || {
                    let mut d = vec![r; 7]; // rank 0: all 0s, rank 1: all 1s
                    tp_reduce_spans(&mut g, &mut d, &spans);
                    d
                })
            })
            .collect();
        for h in handles {
            let out = h.join().unwrap();
            // Span positions hold 0 + 1 = 1 on both ranks; the rest keep
            // their per-rank value (0 or 1 — untouched either way).
            assert_eq!(out[1], 1.0);
            assert_eq!(out[2], 1.0);
            assert_eq!(out[5], 1.0);
            assert!(out[0] == 0.0 || out[0] == 1.0);
            assert_eq!(out[3], out[0]);
            assert_eq!(out[4], out[0]);
            assert_eq!(out[6], out[0]);
        }
    }

    #[test]
    fn tp_reduce_spans_is_a_no_op_for_single_rank_or_empty_spans() {
        let mut g = ring_group(1).remove(0);
        let mut d = vec![2.0f32; 4];
        tp_reduce_spans(&mut g, &mut d, &[(0, 2)]);
        assert_eq!(d, vec![2.0; 4]);
        assert_eq!(g.sent_elems(), 0);
    }
}
