//! The real distributed trainer: worker threads (one per (dp, stage,
//! tp) rank) execute the generated schedules against PJRT-compiled
//! layer artifacts, communicating through a [`CommWorld`] process-group
//! handle (pipeline p2p, data-parallel ring, tensor-parallel ring,
//! control plane). This is the executable half of the reproduction —
//! the same scheduling policies the simulator measures, running real
//! math.
//!
//! The schedule is lowered exactly once ([`crate::schedule::lower`]);
//! the resulting [`crate::schedule::ScheduleProgram`] is shared by every
//! worker, which dispatches its stage's run queue and checks the
//! program's local dependency edges before each op.

pub mod chaos;
pub mod config;
pub mod launch;
pub mod params;
pub mod worker;

use std::sync::Arc;
use std::thread;

use anyhow::{Context, Result};

pub use chaos::{chaos_probe, run_chaos, seeded_plan, ChaosEvent, ChaosPlan, ChaosReport, Revive};
pub use config::{Policy, TrainerConfig};
pub use launch::{launch_local, launch_local_opts, LaunchOptions, LaunchReport};
pub use params::LayerLayout;
pub use worker::{run_worker, WorkerCtx, WorkerStats};

use crate::collective::{CommWorld, Topology};
use crate::offload::store::{
    covers, slot_embed, slot_head, slot_layer, slot_pos, FileStore, MemoryStore, StateStore,
};
use crate::runtime::{DType, Manifest};
use crate::schedule::{lower, ScheduleProgram};

/// Result of one training run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    /// Mean loss per step (averaged over data-parallel instances).
    /// `losses[i]` is the loss of absolute step `start_step + i`.
    pub losses: Vec<f64>,
    /// First step this run executed (non-zero after a resume).
    pub start_step: usize,
    pub wall_secs: f64,
    /// Total elements moved through the DP collectives, all workers.
    pub collective_elems_sent: u64,
    /// Total elements moved through the pipeline rings, all workers.
    pub pipeline_elems_sent: u64,
    /// Total elements moved through the tensor-parallel rings, all
    /// workers.
    pub tp_elems_sent: u64,
    /// The same traffic as bytes on the wire at the runtime dtype's
    /// width (`elements × DType::bytes()`) — what a socket backend
    /// physically moves, assertable against the schedule-implied
    /// `WireBytes` accounting.
    pub collective_bytes_sent: u64,
    pub pipeline_bytes_sent: u64,
    pub tp_bytes_sent: u64,
    /// Whether tp > 1 ran truly sharded layer compute (Megatron-style
    /// column/row-parallel artifacts) rather than replicated emulation.
    pub tp_sharded: bool,
    /// Largest measured per-rank resident bytes of layer parameters +
    /// Adam moments — the state sharded execution divides by tp.
    pub max_layer_state_bytes: u64,
    /// Largest measured per-rank resident parameter + optimizer bytes
    /// including the replicated embedding/positional/head state.
    pub max_state_bytes: u64,
    /// Total PJRT execute time / calls, all workers.
    pub execute_secs: f64,
    pub execute_calls: u64,
    /// Real-time checkpoint stream accounting (0 without `offload`).
    pub checkpoint_bytes_written: u64,
    pub checkpoint_records: u64,
    pub schedule_name: String,
}

/// Newest checkpointed step whose records fully cover every slot of the
/// layout they were written under, plus the writer's tensor-parallel
/// shard degree (needed to enumerate its per-rank layer slots — the
/// degree is read from the records' provenance, so resume works across
/// a tp change).
fn latest_resumable_step(
    store: &dyn StateStore,
    manifest: &Manifest,
) -> Result<Option<(u64, usize)>> {
    let mi = manifest.model;
    let d_l = mi.n_layers;
    for &step in store.steps()?.iter().rev() {
        // Slot 0 (layer 0, tp rank 0) exists under every layout; its
        // provenance names the writer's shard degree.
        let Some(r0) = store.read(step, 0)?.into_iter().next() else { continue };
        let wtp = (r0.tp as usize).max(1);
        let layer_total = manifest.layer_param_elements_tp(wtp).with_context(|| {
            format!("checkpoint step {step} was written with tp = {wtp} shards")
        })?;
        let mut slots: Vec<(usize, usize)> = Vec::new();
        for tp_rank in 0..wtp {
            for l in 0..d_l {
                slots.push((slot_layer(d_l, tp_rank, l), layer_total));
            }
        }
        slots.push((slot_embed(d_l), mi.vocab * mi.d_model));
        slots.push((slot_pos(d_l), mi.d_seq * mi.d_model));
        slots.push((slot_head(d_l), mi.d_model * mi.vocab));
        let mut complete = true;
        for &(slot, total) in &slots {
            if !covers(&store.read(step, slot as u64)?, total) {
                complete = false;
                break;
            }
        }
        if complete {
            return Ok(Some((step, wtp)));
        }
    }
    Ok(None)
}

/// Bytes on the wire for a payload-element count at the runtime dtype
/// (all trainer payloads are f32 today).
fn wire_bytes(elems: u64) -> u64 {
    elems * DType::F32.bytes() as u64
}

/// Everything a rank needs before it can execute: the loaded manifest,
/// the lowered schedule, the checkpoint store and the resume point.
/// Identical per rank by construction — in the thread backend it is
/// computed once and shared; each `repro worker` process recomputes it
/// from the same config and artifacts.
struct Prepared {
    tp_sharded: bool,
    program: Arc<ScheduleProgram>,
    store: Option<Arc<dyn StateStore>>,
    start_step: usize,
    ckpt_tp: usize,
}

fn prepare(cfg: &TrainerConfig) -> Result<Prepared> {
    let manifest = Manifest::load(&cfg.artifacts_root, &cfg.preset)?;
    let d_l = manifest.model.n_layers;
    anyhow::ensure!(
        d_l % cfg.n_l == 0,
        "n_layers {d_l} not divisible by pipeline degree {}",
        cfg.n_l
    );
    anyhow::ensure!(cfg.tp >= 1, "tensor-parallel degree must be at least 1");
    anyhow::ensure!(cfg.zero <= 3, "ZeRO stages are 0-3, got {}", cfg.zero);
    anyhow::ensure!(
        cfg.zero == 0 || !cfg.partition,
        "--zero and --partition are mutually exclusive ways to shard the state"
    );
    // Sharded vs emulated tensor parallelism, decided once for every
    // worker: truly sharded compute needs the manifest's `_tp<d>`
    // half-layer artifacts and per-shard shapes.
    let tp_sharded =
        cfg.tp > 1 && !cfg.force_tp_emulation && manifest.supports_tp(cfg.tp);
    let schedule = cfg.build_schedule(d_l);
    // Lowering validates every structural invariant (ownership, compute
    // counts, send/recv pairing, cycle-freedom) and yields the dependency
    // graph all workers execute. Workers are synchronous in-order
    // executors with blocking receives — stricter than the per-stream
    // model lowering checks — so verify that stronger condition too.
    let program =
        Arc::new(lower(&schedule).map_err(|e| anyhow::anyhow!("invalid schedule: {e:?}"))?);
    program
        .check_inorder_executable()
        .map_err(|e| anyhow::anyhow!("schedule would deadlock in-order workers: {e:?}"))?;
    // Debug builds additionally verify the *whole world* before any
    // worker launches: the program composed over every rank of this
    // run's {stages, dp, tp} grid must have matched p2p channels,
    // congruent collective sequences on every ring, and a cycle-free
    // cross-rank wait-for graph. Release builds skip it — the planner
    // already filters statically-invalid plans, and the check is
    // O(world) on the launch path.
    #[cfg(debug_assertions)]
    {
        let topo = crate::collective::Topology::new(cfg.n_l, cfg.n_b, cfg.tp);
        if let Err(e) = crate::analysis::verify_structural(&program, topo) {
            panic!("whole-world static verification failed before launch: {e}");
        }
    }

    // Checkpoint store: the durable file tier when a directory is given,
    // else the in-process CPU-memory tier. Needed to execute OffloadStore
    // ops (offload) and/or to load the latest state (resume).
    anyhow::ensure!(
        !cfg.resume || cfg.store_dir.is_some(),
        "resume requires a durable store_dir — the in-memory tier dies with the process, \
         so a fresh one can never hold a checkpoint to resume from"
    );
    let store: Option<Arc<dyn StateStore>> = if cfg.offload || cfg.resume {
        Some(match &cfg.store_dir {
            Some(dir) => Arc::new(FileStore::new(dir)?),
            None => Arc::new(MemoryStore::new()),
        })
    } else {
        None
    };

    // Resume point: the newest step whose records fully cover every slot
    // of the layout they were written under (per-tp-rank layer shards +
    // embedding + positional + head) — a step torn by a crash is
    // skipped. Training continues at the step after it; `ckpt_tp` tells
    // the workers which shard layout to reassemble from (it may differ
    // from this run's tp — resume re-shards).
    let mut ckpt_tp = 1usize;
    let start_step = if cfg.resume {
        let store = store.as_deref().expect("store exists when resuming");
        match latest_resumable_step(store, &manifest)? {
            Some((s, wtp)) => {
                ckpt_tp = wtp;
                // The split-invariance contract covers re-*sharding*: a
                // resumed run may change n_b, but n_b·n_μ (the global
                // micro-batch count) must match the writer's — otherwise
                // each step consumes different data at a different
                // gradient scale and the trajectory silently diverges.
                let g = cfg.n_b * cfg.n_mu;
                if let Some(rec) = store.read(s, 0)?.first() {
                    anyhow::ensure!(
                        rec.global_mbs as usize == g,
                        "checkpoint was written with a global batch of {} micro-batches; \
                         resuming with n_b*n_mu = {g} would change the training trajectory \
                         — pick n_b, n_mu with the same product",
                        rec.global_mbs
                    );
                }
                // Reclaim whatever the crashed run left past the resume
                // point: the torn step will be re-executed (possibly
                // under a different sharding) into an empty directory,
                // so stale shards can never poison the new cover.
                store.prune_steps_after(s)?;
                s as usize + 1
            }
            None => {
                // No complete step: a cold start. Clear torn leftovers
                // (e.g. a crash inside step 0) for the same reason.
                store.prune_steps_before(u64::MAX)?;
                0
            }
        }
    } else {
        0
    };
    Ok(Prepared { tp_sharded, program, store, start_step, ckpt_tp })
}

/// Build one rank's `WorkerCtx` from the shared preparation.
fn worker_ctx(cfg: &TrainerConfig, p: &Prepared, world: CommWorld) -> WorkerCtx {
    WorkerCtx {
        world,
        n_mu: cfg.n_mu,
        seed: cfg.seed,
        steps: cfg.steps,
        start_step: p.start_step,
        lr: cfg.lr,
        partition: cfg.partition,
        zero: cfg.zero,
        offload: cfg.offload,
        tp_sharded: p.tp_sharded,
        ckpt_tp: p.ckpt_tp,
        store: p.store.clone(),
        program: p.program.clone(),
        artifacts_root: cfg.artifacts_root.clone(),
        preset: cfg.preset.clone(),
    }
}

/// Execute exactly one rank of a training job over an externally wired
/// world (the socket backend's per-process entry point: `repro worker`
/// connects its `CommWorld` through the rendezvous, then calls this).
/// Losses and end-of-run stats flow back over the world's control
/// plane.
pub fn train_rank(cfg: &TrainerConfig, world: CommWorld) -> Result<WorkerStats> {
    // The in-memory store tier is process-local, so offload/resume in a
    // multi-process world needs the durable file tier every rank can
    // see — with one, elastic restarts resume from it.
    anyhow::ensure!(
        (!cfg.offload && !cfg.resume) || cfg.store_dir.is_some(),
        "multi-process --offload/--resume needs --store DIR \
         (the in-memory checkpoint tier is process-local)"
    );
    let expected = Topology::new(cfg.n_l, cfg.n_b, cfg.tp);
    anyhow::ensure!(
        world.topology() == expected,
        "world topology {:?} does not match the config's {:?}",
        world.topology(),
        expected
    );
    let p = prepare(cfg)?;
    run_worker(worker_ctx(cfg, &p, world))
}

/// Run a training job to completion.
pub fn train(cfg: &TrainerConfig) -> Result<TrainReport> {
    let p = prepare(cfg)?;
    let (tp_sharded, start_step) = (p.tp_sharded, p.start_step);
    if start_step >= cfg.steps {
        // The checkpoint already covers everything requested (e.g. a
        // supervisor restarting a finished run): report cleanly instead
        // of erroring a completed job.
        return Ok(TrainReport {
            losses: Vec::new(),
            start_step,
            wall_secs: 0.0,
            collective_elems_sent: 0,
            pipeline_elems_sent: 0,
            tp_elems_sent: 0,
            collective_bytes_sent: 0,
            pipeline_bytes_sent: 0,
            tp_bytes_sent: 0,
            tp_sharded,
            max_layer_state_bytes: 0,
            max_state_bytes: 0,
            execute_secs: 0.0,
            execute_calls: 0,
            checkpoint_bytes_written: p.store.as_ref().map(|s| s.bytes_written()).unwrap_or(0),
            checkpoint_records: p.store.as_ref().map(|s| s.records_written()).unwrap_or(0),
            schedule_name: p.program.name.clone(),
        });
    }

    let t0 = std::time::Instant::now();

    // Every communicator of the job — pipeline p2p per (dp, tp)
    // instance, a dp ring per (stage, tp), a tp ring per (dp, stage) and
    // the control plane — is wired here, once, by the CommWorld builder.
    let topo = Topology::new(cfg.n_l, cfg.n_b, cfg.tp);
    let (worlds, loss_rx) = CommWorld::build(topo);

    let mut joins = Vec::new();
    for world in worlds {
        let rank = world.rank();
        let ctx = worker_ctx(cfg, &p, world);
        joins.push(
            thread::Builder::new()
                .name(format!("worker-d{}s{}t{}", rank.dp, rank.stage, rank.tp))
                .spawn(move || run_worker(ctx))
                .context("spawn")?,
        );
    }

    let mut stats = WorkerStats::default();
    for j in joins {
        let s = j.join().map_err(|_| anyhow::anyhow!("worker panicked"))??;
        stats.execute_secs += s.execute_secs;
        stats.execute_calls += s.execute_calls;
        stats.collective_elems_sent += s.collective_elems_sent;
        stats.pipeline_elems_sent += s.pipeline_elems_sent;
        stats.tp_elems_sent += s.tp_elems_sent;
        stats.layer_state_bytes = stats.layer_state_bytes.max(s.layer_state_bytes);
        stats.total_state_bytes = stats.total_state_bytes.max(s.total_state_bytes);
    }

    // Aggregate losses: average over dp ranks per step (executed steps
    // only — a resumed run reports from `start_step` on).
    let mut sums = vec![0.0f64; cfg.steps];
    let mut counts = vec![0usize; cfg.steps];
    while let Ok((step, _dp, loss)) = loss_rx.recv() {
        sums[step] += loss;
        counts[step] += 1;
    }
    let losses: Vec<f64> = sums[start_step..]
        .iter()
        .zip(&counts[start_step..])
        .map(|(s, &c)| if c > 0 { s / c as f64 } else { f64::NAN })
        .collect();

    Ok(TrainReport {
        losses,
        start_step,
        wall_secs: t0.elapsed().as_secs_f64(),
        collective_elems_sent: stats.collective_elems_sent,
        pipeline_elems_sent: stats.pipeline_elems_sent,
        tp_elems_sent: stats.tp_elems_sent,
        collective_bytes_sent: wire_bytes(stats.collective_elems_sent),
        pipeline_bytes_sent: wire_bytes(stats.pipeline_elems_sent),
        tp_bytes_sent: wire_bytes(stats.tp_elems_sent),
        tp_sharded,
        max_layer_state_bytes: stats.layer_state_bytes,
        max_state_bytes: stats.total_state_bytes,
        execute_secs: stats.execute_secs,
        execute_calls: stats.execute_calls,
        checkpoint_bytes_written: p.store.as_ref().map(|s| s.bytes_written()).unwrap_or(0),
        checkpoint_records: p.store.as_ref().map(|s| s.records_written()).unwrap_or(0),
        schedule_name: p.program.name.clone(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::optim::LrSchedule;

    fn have_artifacts() -> bool {
        std::path::PathBuf::from(env!("CARGO_MANIFEST_DIR"))
            .join("artifacts/tiny/manifest.json")
            .exists()
    }

    #[test]
    fn single_worker_loss_decreases() {
        if !have_artifacts() {
            return;
        }
        let mut cfg = TrainerConfig::quick("tiny");
        cfg.steps = 25;
        cfg.n_mu = 2;
        cfg.lr = LrSchedule::constant(3e-3);
        let r = train(&cfg).unwrap();
        assert_eq!(r.losses.len(), 25);
        let first = r.losses[0];
        let last = *r.losses.last().unwrap();
        // tiny vocab = 256: initial loss ~ ln(256) = 5.55.
        assert!((first - 5.55).abs() < 0.5, "first loss {first}");
        assert!(last < first - 0.3, "no learning: {first} -> {last}");
    }

    #[test]
    fn baseline_and_improved_schedules_compute_the_same_training() {
        if !have_artifacts() {
            return;
        }
        let mut a = TrainerConfig::quick("tiny");
        a.steps = 4;
        a.n_mu = 2;
        a.policy = Policy::Baseline;
        let mut b = a.clone();
        b.policy = Policy::Improved;
        let ra = train(&a).unwrap();
        let rb = train(&b).unwrap();
        // Same math, different op order: losses agree to fp tolerance.
        for (x, y) in ra.losses.iter().zip(&rb.losses) {
            assert!((x - y).abs() < 2e-3, "{x} vs {y}");
        }
    }

    #[test]
    fn pipeline_matches_single_stage() {
        if !have_artifacts() {
            return;
        }
        let mut a = TrainerConfig::quick("tiny");
        a.steps = 3;
        a.n_mu = 2;
        let mut b = a.clone();
        b.n_l = 2; // tiny model has 2 layers -> one per stage (modular)
        let ra = train(&a).unwrap();
        let rb = train(&b).unwrap();
        for (x, y) in ra.losses.iter().zip(&rb.losses) {
            assert!((x - y).abs() < 2e-3, "{x} vs {y}");
        }
    }

    #[test]
    fn data_parallel_replicas_agree_and_learn() {
        if !have_artifacts() {
            return;
        }
        let mut cfg = TrainerConfig::quick("tiny");
        cfg.steps = 6;
        cfg.n_b = 2;
        cfg.n_mu = 2;
        cfg.lr = LrSchedule::constant(3e-3);
        let r = train(&cfg).unwrap();
        assert!(r.losses.iter().all(|l| l.is_finite()));
        assert!(r.losses.last().unwrap() < &r.losses[0]);
        assert!(r.collective_elems_sent > 0);
    }

    #[test]
    fn partitioned_training_matches_replicated() {
        if !have_artifacts() {
            return;
        }
        let mut a = TrainerConfig::quick("tiny");
        a.steps = 4;
        a.n_b = 2;
        a.n_mu = 2;
        a.policy = Policy::Improved;
        let mut b = a.clone();
        b.partition = true;
        let ra = train(&a).unwrap();
        let rb = train(&b).unwrap();
        // ZeRO-3 partition is an exact re-arrangement of the same update.
        for (x, y) in ra.losses.iter().zip(&rb.losses) {
            assert!((x - y).abs() < 2e-3, "{x} vs {y}");
        }
    }

    #[test]
    fn lga_moves_less_partition_traffic_than_standard() {
        if !have_artifacts() {
            return;
        }
        // Figure 2's point, measured on the real runtime: with a
        // partitioned state, standard GA re-gathers parameters for every
        // micro-batch; LGA gathers once per layer per pass.
        let mut std_cfg = TrainerConfig::quick("tiny");
        std_cfg.steps = 2;
        std_cfg.n_b = 2;
        std_cfg.n_mu = 4;
        std_cfg.partition = true;
        std_cfg.policy = Policy::Baseline;
        let mut lga_cfg = std_cfg.clone();
        lga_cfg.policy = Policy::Improved;
        let rs = train(&std_cfg).unwrap();
        let rl = train(&lga_cfg).unwrap();
        assert!(
            rl.collective_elems_sent * 2 < rs.collective_elems_sent,
            "LGA {} vs standard {}",
            rl.collective_elems_sent,
            rs.collective_elems_sent
        );
        // And the losses still agree.
        for (x, y) in rs.losses.iter().zip(&rl.losses) {
            assert!((x - y).abs() < 2e-3, "{x} vs {y}");
        }
    }

    #[test]
    fn offloaded_training_streams_checkpoints_without_changing_the_math() {
        if !have_artifacts() {
            return;
        }
        let mut a = TrainerConfig::quick("tiny");
        a.steps = 4;
        a.n_mu = 2;
        a.lr = LrSchedule::constant(3e-3);
        let mut b = a.clone();
        b.offload = true; // in-process memory tier
        let ra = train(&a).unwrap();
        let rb = train(&b).unwrap();
        // The store ops only *read* state: the training math is identical.
        for (x, y) in ra.losses.iter().zip(&rb.losses) {
            assert!((x - y).abs() < 1e-12, "{x} vs {y}");
        }
        assert_eq!(ra.checkpoint_records, 0);
        // Every step streams each layer (tiny: 2) plus embedding,
        // positional table and head — a complete cover per step.
        assert_eq!(rb.checkpoint_records, 4 * (2 + 3));
        assert!(rb.checkpoint_bytes_written > 0);
    }

    #[test]
    fn tensor_parallel_emulation_matches_tp1_bit_for_bit() {
        if !have_artifacts() {
            return;
        }
        // The acceptance bar for the replicated-compute tp emulation:
        // the ring-sum-then-postscale roundtrip is exact for tp = 2, so
        // the loss trajectory must equal the tp = 1 run's bitwise.
        // (Sharded execution matches within tolerance instead — see
        // tests/tp_parity.rs — so emulation is pinned explicitly here.)
        let mut a = TrainerConfig::quick("tiny");
        a.steps = 4;
        a.n_mu = 2;
        let mut b = a.clone();
        b.tp = 2;
        b.force_tp_emulation = true;
        let ra = train(&a).unwrap();
        let rb = train(&b).unwrap();
        assert!(!rb.tp_sharded);
        assert_eq!(ra.losses.len(), rb.losses.len());
        for (x, y) in ra.losses.iter().zip(&rb.losses) {
            assert_eq!(x.to_bits(), y.to_bits(), "{x} vs {y}");
        }
        // And the tp run moved real collective traffic where the tp=1
        // run moved none.
        assert_eq!(ra.tp_elems_sent, 0);
        assert!(rb.tp_elems_sent > 0);
    }

    #[test]
    fn one_f_one_b_matches_gpipe_numerics() {
        if !have_artifacts() {
            return;
        }
        let mut a = TrainerConfig::quick("tiny");
        a.steps = 3;
        a.n_l = 2;
        a.n_mu = 4;
        a.policy = Policy::Baseline;
        let mut b = a.clone();
        b.policy = Policy::OneFOneB;
        let ra = train(&a).unwrap();
        let rb = train(&b).unwrap();
        for (x, y) in ra.losses.iter().zip(&rb.losses) {
            assert!((x - y).abs() < 2e-3, "{x} vs {y}");
        }
    }
}
