//! Synthetic token corpus: a deterministic Markov (bigram) source with a
//! learnable structure — ~80% of transitions follow a fixed permutation
//! chain, the rest are zipf-ish noise. A transformer LM can push the loss
//! well below the unigram entropy, which is what the e2e run's loss curve
//! demonstrates.

/// xorshift64* PRNG — deterministic, seedable, dependency-free.
#[derive(Debug, Clone)]
pub struct Rng(u64);

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng(seed.wrapping_mul(0x9e3779b97f4a7c15) | 1)
    }

    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.0;
        x ^= x >> 12;
        x ^= x << 25;
        x ^= x >> 27;
        self.0 = x;
        x.wrapping_mul(0x2545f4914f6cdd1d)
    }

    /// Uniform in [0, 1).
    pub fn uniform(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n as u64) as usize
    }

    /// Standard normal (Box-Muller).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.uniform().max(1e-12);
        let u2 = self.uniform();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }
}

/// The synthetic corpus generator.
#[derive(Debug, Clone)]
pub struct Corpus {
    vocab: usize,
    /// Deterministic successor for the structured transitions.
    succ: Vec<u32>,
    /// Probability of following the chain (the learnable signal).
    p_chain: f64,
}

impl Corpus {
    pub fn new(vocab: usize) -> Self {
        // Successor permutation: an affine map with a multiplier coprime
        // to the vocab size gives one long cycle through most tokens.
        let mult = (vocab / 2 + 1) | 1;
        let succ = (0..vocab).map(|t| ((t * mult + 7) % vocab) as u32).collect();
        Corpus { vocab, succ, p_chain: 0.8 }
    }

    pub fn vocab(&self) -> usize {
        self.vocab
    }

    /// The structure's conditional entropy in nats (lower bound on the
    /// achievable LM loss).
    pub fn entropy_bound(&self) -> f64 {
        // H = -p ln p - (1-p) ln((1-p)/V)  (noise is uniform over V).
        let p = self.p_chain;
        -p * p.ln() - (1.0 - p) * ((1.0 - p) / self.vocab as f64).ln()
    }

    /// Generate one sequence of `len + 1` tokens; the first `len` are the
    /// inputs and the shifted-by-one slice is the target.
    pub fn sequence(&self, rng: &mut Rng, len: usize) -> Vec<i32> {
        let mut out = Vec::with_capacity(len + 1);
        let mut cur = rng.below(self.vocab);
        out.push(cur as i32);
        for _ in 0..len {
            cur = if rng.uniform() < self.p_chain {
                self.succ[cur] as usize
            } else {
                rng.below(self.vocab)
            };
            out.push(cur as i32);
        }
        out
    }
}

/// A (tokens, targets) pair for one micro-batch, flattened [b, s].
#[derive(Debug, Clone, PartialEq)]
pub struct Batch {
    pub b: usize,
    pub s: usize,
    pub tokens: Vec<i32>,
    pub targets: Vec<i32>,
}

impl Corpus {
    /// Deterministic micro-batch keyed by (seed, step, dp_rank, mb):
    /// every worker of a data-parallel instance regenerates the same
    /// batch without communication.
    pub fn batch(&self, seed: u64, step: u64, dp_rank: u64, mb: u64, b: usize, s: usize) -> Batch {
        let mut tokens = Vec::with_capacity(b * s);
        let mut targets = Vec::with_capacity(b * s);
        for row in 0..b {
            let key = seed
                .wrapping_mul(0x100000001b3)
                .wrapping_add(step << 32)
                .wrapping_add(dp_rank << 16)
                .wrapping_add(mb << 8)
                .wrapping_add(row as u64);
            let mut rng = Rng::new(key);
            let seq = self.sequence(&mut rng, s);
            tokens.extend_from_slice(&seq[..s]);
            targets.extend_from_slice(&seq[1..]);
        }
        Batch { b, s, tokens, targets }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rng_is_deterministic_and_uniformish() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        let mean: f64 = (0..10_000).map(|_| a.uniform()).sum::<f64>() / 10_000.0;
        assert!((mean - 0.5).abs() < 0.02, "{mean}");
    }

    #[test]
    fn sequences_follow_the_chain_mostly() {
        let c = Corpus::new(64);
        let mut rng = Rng::new(1);
        let seq = c.sequence(&mut rng, 10_000);
        let follows = seq
            .windows(2)
            .filter(|w| c.succ[w[0] as usize] as i32 == w[1])
            .count();
        let frac = follows as f64 / 10_000.0;
        // p_chain plus accidental matches.
        assert!(frac > 0.75 && frac < 0.88, "{frac}");
    }

    #[test]
    fn batches_are_deterministic_and_distinct() {
        let c = Corpus::new(256);
        let b1 = c.batch(7, 0, 0, 0, 2, 32);
        let b2 = c.batch(7, 0, 0, 0, 2, 32);
        assert_eq!(b1, b2);
        let b3 = c.batch(7, 1, 0, 0, 2, 32);
        assert_ne!(b1.tokens, b3.tokens);
        let b4 = c.batch(7, 0, 1, 0, 2, 32);
        assert_ne!(b1.tokens, b4.tokens);
    }

    #[test]
    fn targets_are_shifted_tokens() {
        let c = Corpus::new(64);
        let b = c.batch(3, 0, 0, 0, 1, 16);
        // targets[i] is the successor of tokens[i] in the generated
        // sequence, i.e. targets[..-1] == tokens[1..].
        assert_eq!(&b.targets[..15], &b.tokens[1..16]);
    }

    #[test]
    fn entropy_bound_is_below_uniform() {
        let c = Corpus::new(256);
        assert!(c.entropy_bound() < (256f64).ln());
        assert!(c.entropy_bound() > 0.5);
    }
}
