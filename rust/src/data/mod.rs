//! Synthetic data pipeline: deterministic seedable corpus + batching.

pub mod corpus;

pub use corpus::{Batch, Corpus, Rng};
