//! Discrete-event simulator.
//!
//! Executes a compiled [`ScheduleProgram`] against a [`CostTable`]. Each
//! pipeline stage is a device with four streams (compute, net-out,
//! net-in, cpu-link); ops on a stream run FIFO in program order, but an
//! op only *starts* once every one of its precomputed dependency edges is
//! satisfied — the pipeline bubble, communication stalls and overlap (or
//! lack of it) all emerge from this rule rather than being assumed.
//!
//! The dependency rules themselves (activation chains, gradient chains,
//! send/recv pairing, restore-before-use, reduce-after-last-bwd,
//! optim-after-reduce) live in the lowering pass,
//! [`crate::schedule::program::lower`] — this module no longer derives
//! any of them. The event loop is a pure graph walk: every op keeps a
//! count of outstanding predecessor edges; a completing op decrements its
//! successors' counts and frees its stream, and whichever stream heads
//! reach zero start next. That makes one simulation O(V + E + V log V)
//! in the program size (the log factor from the event heap), which is
//! what lets the planner simulate candidate configurations in the loop —
//! see `benches/sim_engine.rs` for the measured throughput.
//!
//! Hot-path layout (the planner simulates thousands of programs per
//! sweep, so the per-call constant matters):
//!
//! * busy accounting is a flat `Vec<f64>` indexed by
//!   `stage * N_STREAMS + stream` — no hashing;
//! * [`SimOptions::record_timeline`] turns off the per-op [`TimedOp`]
//!   timeline; makespan, busy and peak memory are bit-identical either
//!   way (the parity tests in `tests/planner_parity.rs` prove it), so
//!   planner-loop callers skip the only O(V) allocation. Gantt/report
//!   callers keep the default (recording);
//! * [`SimScratch`] pools every working buffer (pending counters, stream
//!   state, the event heap, and the result vectors via
//!   [`SimScratch::recycle`]) so back-to-back [`simulate_program_into`]
//!   calls allocate nothing after warmup — `benches/planner_search.rs`
//!   asserts exactly zero bytes with a counting allocator.
//!
//! [`simulate`] is the convenience wrapper (lower + run); callers that
//! simulate the same schedule repeatedly — the planner, the benches —
//! should lower once and call [`simulate_program`] per cost table (or
//! [`simulate_program_into`] with a scratch to also skip the setup
//! allocations).

use std::collections::BinaryHeap;

use crate::schedule::program::{ScheduleProgram, Stream, N_STREAMS, STREAMS};
use crate::schedule::{lower, Op, Schedule};

use super::cost::CostTable;

/// A completed op with its simulated time window.
#[derive(Debug, Clone, PartialEq)]
pub struct TimedOp {
    pub stage: usize,
    pub op: Op,
    pub stream: Stream,
    pub start: f64,
    pub end: f64,
}

/// Knobs for one simulation run.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct SimOptions {
    /// Record the full per-op [`TimedOp`] timeline. Required by the Gantt
    /// renderer and the timeline-derived metrics
    /// ([`SimResult::reduce_spread`], [`SimResult::exposed_network_tail`]);
    /// planner loops turn it off — makespan, busy and peak memory are
    /// unaffected — to keep the hot path allocation-free.
    pub record_timeline: bool,
}

impl Default for SimOptions {
    fn default() -> Self {
        SimOptions { record_timeline: true }
    }
}

/// Reusable working memory for [`simulate_program_into`]: pending
/// counters, per-stream cursors, the event heap and (via
/// [`SimScratch::recycle`]) the result vectors of a previous run. After
/// the first call at a given program size, subsequent calls perform no
/// heap allocation at all when the timeline is off.
#[derive(Debug, Default)]
pub struct SimScratch {
    pending: Vec<u32>,
    head: Vec<u32>,
    running: Vec<bool>,
    stream_free: Vec<f64>,
    mem: Vec<f64>,
    retry: Vec<u32>,
    events: BinaryHeap<Event>,
    batch: Vec<Event>,
    busy_pool: Vec<f64>,
    peak_pool: Vec<f64>,
    timeline_pool: Vec<TimedOp>,
}

impl SimScratch {
    pub fn new() -> Self {
        Self::default()
    }

    /// Return a finished result's buffers to the pool so the next
    /// [`simulate_program_into`] call reuses them instead of allocating.
    /// Call this once the result's numbers have been read off.
    pub fn recycle(&mut self, result: SimResult) {
        self.busy_pool = result.busy;
        self.peak_pool = result.peak_memory;
        self.timeline_pool = result.timeline;
    }
}

/// Result of simulating one schedule.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Total makespan, seconds.
    pub makespan: f64,
    /// Busy time per (stage, stream), indexed `stage * N_STREAMS +
    /// stream.index()` (see [`SimResult::stream_busy`]).
    pub busy: Vec<f64>,
    /// Peak per-stage memory from checkpoints + live activations, bytes.
    pub peak_memory: Vec<f64>,
    /// Full timeline (for Gantt rendering and fine-grained metrics).
    /// Empty when the run used `record_timeline: false`.
    pub timeline: Vec<TimedOp>,
    pub n_stages: usize,
}

impl SimResult {
    /// Busy seconds of one (stage, stream) pair. Out-of-range lookups
    /// (degenerate results) report 0.
    pub fn stream_busy(&self, stage: usize, stream: Stream) -> f64 {
        self.busy.get(stage * N_STREAMS + stream.index()).copied().unwrap_or(0.0)
    }

    /// Fraction of the makespan each stage's compute stream is busy,
    /// averaged over stages: the simulator's measured efficiency.
    /// Degenerate inputs (zero makespan, no stages) report 0 rather than
    /// NaN so planner comparisons stay well-ordered.
    pub fn compute_efficiency(&self) -> f64 {
        if self.n_stages == 0 || self.makespan <= 0.0 {
            return 0.0;
        }
        let total: f64 = (0..self.n_stages).map(|s| self.stream_busy(s, Stream::Compute)).sum();
        total / (self.n_stages as f64 * self.makespan)
    }

    /// Measured bubble fraction: idle compute time relative to busy
    /// compute time (comparable to the paper's (n_l−1)/n_μ closed form).
    /// A schedule with zero compute efficiency has an unbounded bubble;
    /// reported as `f64::INFINITY` (never NaN) so comparisons against it
    /// behave.
    pub fn bubble_fraction(&self) -> f64 {
        let eff = self.compute_efficiency();
        if eff <= 0.0 {
            return f64::INFINITY;
        }
        (1.0 - eff) / eff
    }

    /// Network busy fraction (out-stream) of the busiest stage.
    pub fn max_netout_utilisation(&self) -> f64 {
        if self.makespan <= 0.0 {
            return 0.0;
        }
        (0..self.n_stages)
            .map(|s| self.stream_busy(s, Stream::NetOut) / self.makespan)
            .fold(0.0, f64::max)
    }

    /// Largest gap (seconds) between consecutive gradient-reduction
    /// completions (`ReduceGrad`, or `ReduceScatterGrad` under ZeRO ≥2)
    /// — small for LGA (spread over the backward pass), large for
    /// standard GA (bunched at the end). Needs a recorded timeline
    /// (`record_timeline: true`); reports 0 otherwise.
    pub fn reduce_spread(&self) -> f64 {
        let mut ends: Vec<f64> = self
            .timeline
            .iter()
            .filter(|t| matches!(t.op, Op::ReduceGrad { .. } | Op::ReduceScatterGrad { .. }))
            .map(|t| t.end)
            .collect();
        if ends.len() < 2 {
            return 0.0;
        }
        ends.sort_by(f64::total_cmp);
        ends.windows(2).map(|w| w[1] - w[0]).fold(0.0, f64::max)
    }

    /// Exposed network tail: time between the last Fwd/Bwd compute
    /// finishing and the last network op finishing. Standard gradient
    /// accumulation serialises the whole gradient reduction here
    /// (Figure 1 top); LGA hides it behind the backward pass. Needs a
    /// recorded timeline (`record_timeline: true`).
    pub fn exposed_network_tail(&self) -> f64 {
        let last_compute = self
            .timeline
            .iter()
            .filter(|t| matches!(t.op, Op::Fwd { .. } | Op::Bwd { .. }))
            .map(|t| t.end)
            .fold(0.0, f64::max);
        let last_net = self
            .timeline
            .iter()
            .filter(|t| t.op.is_transfer())
            .map(|t| t.end)
            .fold(0.0, f64::max);
        (last_net - last_compute).max(0.0)
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
struct Event {
    time: f64,
    id: u32,
}

impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Min-heap on time. `total_cmp` so a NaN duration (a broken cost
        // table) degrades to a deterministic order instead of a panic.
        other.time.total_cmp(&self.time).then_with(|| other.id.cmp(&self.id))
    }
}

/// Simulate a schedule with the given cost table: lower it and run the
/// program. Panics if the schedule fails to lower — validate first (or
/// call [`crate::schedule::lower`] yourself and keep the program).
pub fn simulate(s: &Schedule, costs: &CostTable) -> SimResult {
    let program = lower(s)
        .unwrap_or_else(|errs| panic!("schedule '{}' failed to lower: {errs:?}", s.name));
    simulate_program(&program, costs)
}

/// Run a compiled program against a cost table with default options
/// (timeline recorded) and fresh scratch. This is the convenience entry
/// point; the planner's simulate-in-the-loop search uses
/// [`simulate_program_into`] to skip the timeline and reuse buffers.
pub fn simulate_program(p: &ScheduleProgram, costs: &CostTable) -> SimResult {
    simulate_program_into(p, costs, SimOptions::default(), &mut SimScratch::new())
}

/// Run a compiled program with explicit options and fresh scratch.
pub fn simulate_program_opts(p: &ScheduleProgram, costs: &CostTable, opts: SimOptions) -> SimResult {
    simulate_program_into(p, costs, opts, &mut SimScratch::new())
}

/// Run a compiled program against a cost table, reusing `scratch` across
/// calls. This is the hot path of the planner's simulate-in-the-loop
/// search: no per-event dependency scanning (just counter decrements
/// along the precomputed edges) and, with `record_timeline: false` plus
/// [`SimScratch::recycle`], no heap allocation after warmup.
pub fn simulate_program_into(
    p: &ScheduleProgram,
    costs: &CostTable,
    opts: SimOptions,
    scratch: &mut SimScratch,
) -> SimResult {
    let n = p.len();
    let n_slots = p.n_stages * N_STREAMS;

    let SimScratch {
        pending,
        head,
        running,
        stream_free,
        mem,
        retry,
        events,
        batch,
        busy_pool,
        peak_pool,
        timeline_pool,
    } = scratch;

    // Outstanding predecessor-edge counts per op.
    pending.clear();
    p.fill_pending(pending);
    // Per-(stage, stream) cursor / occupancy / free-time, flat-indexed
    // `stage * N_STREAMS + stream`.
    head.clear();
    head.resize(n_slots, 0);
    running.clear();
    running.resize(n_slots, false);
    stream_free.clear();
    stream_free.resize(n_slots, 0.0);
    // Memory tracking: running checkpoint count per stage; peak.
    mem.clear();
    mem.resize(p.n_stages, 0.0);
    events.clear();
    batch.clear();
    // Streams whose head op may have become startable.
    retry.clear();
    retry.extend(0..n_slots as u32);

    let mut busy = std::mem::take(busy_pool);
    busy.clear();
    busy.resize(n_slots, 0.0);
    let mut peak = std::mem::take(peak_pool);
    peak.clear();
    peak.resize(p.n_stages, 0.0);
    let mut timeline = std::mem::take(timeline_pool);
    timeline.clear();
    if opts.record_timeline {
        timeline.reserve(n);
    }

    let mut now = 0.0f64;
    let mut completed = 0usize;

    macro_rules! try_start {
        ($slot:expr) => {{
            let slot = $slot as usize;
            if !running[slot] {
                let (stage, si) = (slot / N_STREAMS, slot % N_STREAMS);
                let q = &p.queues[stage][si];
                let h = head[slot] as usize;
                if h < q.len() {
                    let id = q[h] as usize;
                    if pending[id] == 0 {
                        head[slot] = h as u32 + 1;
                        let op = p.ops[id].op;
                        let start = now.max(stream_free[slot]);
                        let dur = costs.duration(&op);
                        let end = start + dur;
                        running[slot] = true;
                        events.push(Event { time: end, id: id as u32 });
                        busy[slot] += dur;
                        if opts.record_timeline {
                            timeline.push(TimedOp { stage, op, stream: STREAMS[si], start, end });
                        }
                        // Memory: checkpoints accumulate at Fwd, free at Bwd.
                        if let Op::Fwd { .. } = op {
                            mem[stage] += costs.checkpoint_bytes;
                            peak[stage] =
                                peak[stage].max(mem[stage] + costs.live_activation_bytes);
                        } else if let Op::Bwd { .. } = op {
                            peak[stage] =
                                peak[stage].max(mem[stage] + costs.live_activation_bytes);
                            mem[stage] -= costs.checkpoint_bytes;
                        }
                    }
                }
            }
        }};
    }

    loop {
        while let Some(slot) = retry.pop() {
            try_start!(slot);
        }
        if completed == n {
            break;
        }
        let Some(ev) = events.pop() else {
            let mut stuck: Vec<String> = Vec::new();
            for st in 0..p.n_stages {
                for si in 0..N_STREAMS {
                    if let Some(&id) = p.queues[st][si].get(head[st * N_STREAMS + si] as usize) {
                        stuck.push(format!(
                            "stage {st} {} waiting on {} edges",
                            p.ops[id as usize].op,
                            pending[id as usize]
                        ));
                    }
                }
            }
            panic!(
                "simulator deadlock at t={now}; completed {completed}/{n}; blocked heads: {stuck:?} \
                 (a lowered program is acyclic — this indicates an engine bug)"
            );
        };
        now = ev.time;
        // Complete every op finishing at this instant.
        batch.clear();
        batch.push(ev);
        while let Some(next) = events.peek() {
            if next.time <= now {
                batch.push(events.pop().unwrap());
            } else {
                break;
            }
        }
        for &e in batch.iter() {
            let node = &p.ops[e.id as usize];
            let slot = node.stage as usize * N_STREAMS + node.stream.index();
            running[slot] = false;
            stream_free[slot] = e.time;
            for &sc in p.succs_of(e.id) {
                pending[sc as usize] -= 1;
                if pending[sc as usize] == 0 {
                    let sn = &p.ops[sc as usize];
                    retry.push(sn.stage * N_STREAMS as u32 + sn.stream.index() as u32);
                }
            }
            retry.push(slot as u32);
            completed += 1;
        }
    }

    // Events complete in time order, so the clock's final value is the
    // last op's end — identical to the max over a recorded timeline.
    SimResult { makespan: now, busy, peak_memory: peak, timeline, n_stages: p.n_stages }
}

// ---------------------------------------------------------------------------
// Failure / restart accounting (§8.2, Figure 2's restore-ratio argument)
// ---------------------------------------------------------------------------

/// One injected failure: a rank of `stage` dies `at_secs` into the
/// job's simulated wall clock.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FailureEvent {
    pub at_secs: f64,
    pub stage: usize,
}

/// What one failure cost the job.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FailureRecord {
    /// When the failure actually hit (clamped into the job's lifetime).
    pub at_secs: f64,
    pub stage: usize,
    /// Completed-but-uncheckpointed steps the restart rolled back.
    pub rolled_back_steps: usize,
    /// Wall clock this failure cost: rolled-back work + in-flight
    /// partial step + the restore itself.
    pub lost_secs: f64,
}

/// Failure-aware accounting of a whole training job: `steps` steps of
/// `step_secs` each, interrupted by restart events, each charged a
/// roll-back to the last checkpoint plus `restore_secs` of restore.
#[derive(Debug, Clone, PartialEq)]
pub struct RecoveryAccounting {
    /// Simulated makespan of one step of the program.
    pub step_secs: f64,
    /// Restore cost per failure, from the schedule's real
    /// `RestoreParams` volume (see [`recovery_costs`]).
    pub restore_secs: f64,
    pub steps: usize,
    /// Steps between durable checkpoints (1 = the paper's real-time
    /// streamed checkpoints).
    pub ckpt_interval: usize,
    pub failures: Vec<FailureRecord>,
    /// Total wall clock including every roll-back and restore.
    pub wall_secs: f64,
    /// `wall_secs` minus the failure-free runtime.
    pub lost_secs: f64,
    /// `lost_secs / wall_secs` — the "expected lost work" the planner
    /// bounds with `--max-lost-work`.
    pub lost_fraction: f64,
}

/// Per-step makespan and per-failure restore cost of a program. The
/// restore cost is charged from the schedule's own `RestoreParams`
/// ops — the largest per-stage sum of their durations, since a
/// restarted rank must re-load its stage's parameters from the store
/// before compute resumes (Figure 2: `2·d_l` layer-sized transfers,
/// not `2·d_l·n_μ`). Programs without restore ops (non-offloaded
/// schedules) fall back to the cost table's per-layer restore figure
/// times the layers per stage.
pub fn recovery_costs(p: &ScheduleProgram, costs: &CostTable) -> (f64, f64) {
    let step_secs = simulate_program_opts(p, costs, SimOptions { record_timeline: false }).makespan;
    let mut per_stage = vec![0.0f64; p.n_stages.max(1)];
    for op in &p.ops {
        if let Op::RestoreParams { .. } = op.op {
            per_stage[op.stage as usize] += costs.duration(&op.op);
        }
    }
    let mut restore_secs = per_stage.iter().copied().fold(0.0f64, f64::max);
    if restore_secs == 0.0 && p.n_stages > 0 {
        restore_secs = costs.restore_params * (p.d_l / p.n_stages) as f64;
    }
    (step_secs, restore_secs)
}

/// Replay a `steps`-step job under injected per-rank failures: each
/// failure rolls the job back to its last durable checkpoint (every
/// `ckpt_interval` steps) and charges a restore before training
/// resumes. Purely arithmetic on top of one program simulation — the
/// recorded-timeline path is untouched — and deterministic in the
/// event list, so a seeded chaos schedule prices identically every
/// run. Failures landing after the job would have finished are
/// ignored.
pub fn simulate_with_failures(
    p: &ScheduleProgram,
    costs: &CostTable,
    steps: usize,
    ckpt_interval: usize,
    events: &[FailureEvent],
) -> RecoveryAccounting {
    let (step_secs, restore_secs) = recovery_costs(p, costs);
    let ckpt_interval = ckpt_interval.max(1);
    let mut events: Vec<FailureEvent> = events.to_vec();
    events.sort_by(|a, b| a.at_secs.total_cmp(&b.at_secs));

    let mut wall = 0.0f64; // clock at the last restart point
    let mut done = 0usize; // steps durably checkpointed at `wall`
    let mut failures = Vec::with_capacity(events.len());
    for ev in &events {
        let t = ev.at_secs.max(wall);
        let steps_run = if step_secs > 0.0 {
            ((t - wall) / step_secs).floor() as usize
        } else {
            steps - done
        };
        let done_t = (done + steps_run).min(steps);
        if done_t >= steps {
            break; // the job finished before this failure hit
        }
        let ckpt = (done_t / ckpt_interval) * ckpt_interval;
        let lost = t - (wall + (ckpt - done) as f64 * step_secs) + restore_secs;
        failures.push(FailureRecord {
            at_secs: t,
            stage: ev.stage,
            rolled_back_steps: done_t - ckpt,
            lost_secs: lost,
        });
        wall = t + restore_secs;
        done = ckpt;
    }
    wall += (steps - done) as f64 * step_secs;
    let lost_secs = wall - steps as f64 * step_secs;
    let lost_fraction = if wall > 0.0 { lost_secs / wall } else { 0.0 };
    RecoveryAccounting {
        step_secs,
        restore_secs,
        steps,
        ckpt_interval,
        failures,
        wall_secs: wall,
        lost_secs,
        lost_fraction,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::{Strategy, TrainConfig};
    use crate::hardware::ClusterSpec;
    use crate::model::XModel;
    use crate::schedule::{
        interleaved_1f1b, modular_pipeline, one_f_one_b, standard_ga, ScheduleSpec,
    };
    use crate::sim::cost::CostTable;

    fn costs(n_b: usize, n_l: usize, n_mu: usize, partition: bool) -> CostTable {
        let shape = XModel::new(32).shape();
        let cfg = TrainConfig {
            strategy: if partition { Strategy::Improved } else { Strategy::Baseline },
            n_b,
            n_l,
            n_a: 1,
            n_mu,
            b_mu: 1.0,
            offload: false,
            partition,
            zero: 0,
        };
        CostTable::new(&shape, &cfg, &ClusterSpec::reference())
    }

    #[test]
    fn single_stage_standard_ga_has_full_efficiency() {
        let sp = ScheduleSpec {
            d_l: 8,
            n_l: 1,
            n_mu: 4,
            tp: 1,
            partition: false,
            offload: false,
            data_parallel: false,
            zero: 0,
        };
        let s = standard_ga(&sp);
        let r = simulate(&s, &costs(1, 1, 4, false));
        // No pipeline, no DP: compute runs back-to-back.
        assert!(r.compute_efficiency() > 0.99, "eff = {}", r.compute_efficiency());
    }

    /// A cost table with only compute time — isolates the bubble from
    /// transfer/optimizer effects, like the paper's closed form does.
    fn compute_only(c: &CostTable) -> CostTable {
        CostTable { send_act: 0.0, send_grad: 0.0, reduce_grad: 0.0, restore_params: 0.0, offload_store: 0.0, optim_step: 0.0, ..c.clone() }
    }

    #[test]
    fn gpipe_bubble_matches_closed_form() {
        // Contiguous pipeline, 4 stages, 8 micro-batches: closed-form
        // bubble (n_l−1)/n_μ = 3/8 (§2.4). Transfers/optimizer zeroed —
        // the closed form ignores them.
        let sp = ScheduleSpec {
            d_l: 16,
            n_l: 4,
            n_mu: 8,
            tp: 1,
            partition: false,
            offload: false,
            data_parallel: false,
            zero: 0,
        };
        let s = standard_ga(&sp);
        let r = simulate(&s, &compute_only(&costs(1, 4, 8, false)));
        let measured = r.bubble_fraction();
        assert!(
            (measured - 3.0 / 8.0).abs() < 1e-6,
            "measured bubble {measured:.6} vs closed form 0.375"
        );
    }

    #[test]
    fn modular_bubble_matches_closed_form_exactly() {
        // §4: modular bubble = n_l(n_l−1)/(n_μ·d_l) = 4·3/(8·16) = 3/32.
        let sp = ScheduleSpec {
            d_l: 16,
            n_l: 4,
            n_mu: 8,
            tp: 1,
            partition: false,
            offload: false,
            data_parallel: false,
            zero: 0,
        };
        let s = modular_pipeline(&sp);
        let r = simulate(&s, &compute_only(&costs(1, 4, 8, false)));
        let measured = r.bubble_fraction();
        assert!(
            (measured - 3.0 / 32.0).abs() < 1e-6,
            "measured bubble {measured:.6} vs closed form {:.6}",
            3.0 / 32.0
        );
    }

    #[test]
    fn simulate_program_reuses_one_lowering() {
        // Lower once, simulate twice with different cost tables — the
        // planner's simulate-in-the-loop pattern.
        let sp = ScheduleSpec {
            d_l: 16,
            n_l: 4,
            n_mu: 8,
            tp: 1,
            partition: false,
            offload: false,
            data_parallel: false,
            zero: 0,
        };
        let s = modular_pipeline(&sp);
        let p = crate::schedule::lower(&s).unwrap();
        let full = simulate_program(&p, &costs(1, 4, 8, false));
        let compute = simulate_program(&p, &compute_only(&costs(1, 4, 8, false)));
        assert!(full.makespan >= compute.makespan);
        // And the wrapper agrees with the explicit two-step path.
        let wrapped = simulate(&s, &costs(1, 4, 8, false));
        assert!((wrapped.makespan - full.makespan).abs() < 1e-12);
    }

    #[test]
    fn timeline_off_matches_recording_path_bit_for_bit() {
        let sp = ScheduleSpec {
            d_l: 16,
            n_l: 4,
            n_mu: 8,
            tp: 1,
            partition: true,
            offload: false,
            data_parallel: true,
            zero: 0,
        };
        let s = modular_pipeline(&sp);
        let p = crate::schedule::lower(&s).unwrap();
        let c = costs(8, 4, 8, true);
        let on = simulate_program(&p, &c);
        let off = simulate_program_opts(&p, &c, SimOptions { record_timeline: false });
        assert_eq!(on.makespan.to_bits(), off.makespan.to_bits());
        assert_eq!(on.busy.len(), off.busy.len());
        for (a, b) in on.busy.iter().zip(&off.busy) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        for (a, b) in on.peak_memory.iter().zip(&off.peak_memory) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
        assert!(off.timeline.is_empty() && !on.timeline.is_empty());
    }

    #[test]
    fn scratch_reuse_changes_nothing() {
        let sp = ScheduleSpec {
            d_l: 16,
            n_l: 4,
            n_mu: 8,
            tp: 1,
            partition: false,
            offload: false,
            data_parallel: true,
            zero: 0,
        };
        let s = standard_ga(&sp);
        let p = crate::schedule::lower(&s).unwrap();
        let c = costs(8, 4, 8, false);
        let fresh = simulate_program(&p, &c);
        let mut scratch = SimScratch::new();
        for _ in 0..3 {
            let r = simulate_program_into(&p, &c, SimOptions { record_timeline: false }, &mut scratch);
            assert_eq!(r.makespan.to_bits(), fresh.makespan.to_bits());
            assert_eq!(r.busy, fresh.busy);
            scratch.recycle(r);
        }
    }

    #[test]
    fn modular_bubble_is_dl_over_nl_smaller_than_contiguous() {
        let d_l = 16;
        let n_l = 4;
        let n_mu = 8;
        let c = costs(1, n_l, n_mu, false);
        let sp = ScheduleSpec {
            d_l,
            n_l,
            n_mu,
            tp: 1,
            partition: false,
            offload: false,
            data_parallel: false,
            zero: 0,
        };
        let naive = simulate(&standard_ga(&sp), &c);
        let modular = simulate(&modular_pipeline(&sp), &c);
        let ratio = naive.bubble_fraction() / modular.bubble_fraction();
        // §4: the bubble shrinks by d_l/n_l = 4 (within simulation noise
        // from transfers).
        assert!(
            ratio > 2.5 && ratio < 6.0,
            "bubble ratio {ratio:.2} (naive {:.4}, modular {:.4})",
            naive.bubble_fraction(),
            modular.bubble_fraction()
        );
        // And the modular makespan is strictly better.
        assert!(modular.makespan < naive.makespan);
    }

    #[test]
    fn interleaved_bubble_sits_between_one_f_one_b_and_modular() {
        // §4 / Megatron-LM: v chunks shrink the 1F1B bubble by v; modular
        // (v = d_l/n_l with layered accumulation) shrinks it further.
        let sp = ScheduleSpec {
            d_l: 16,
            n_l: 4,
            n_mu: 8,
            tp: 1,
            partition: false,
            offload: false,
            data_parallel: false,
            zero: 0,
        };
        let c = compute_only(&costs(1, 4, 8, false));
        let fb = simulate(&one_f_one_b(&sp), &c).bubble_fraction();
        let il = simulate(&interleaved_1f1b(&sp, 2), &c).bubble_fraction();
        let md = simulate(&modular_pipeline(&sp), &c).bubble_fraction();
        assert!(il < fb * 0.8, "interleaved {il:.4} should clearly beat 1F1B {fb:.4}");
        assert!(md < il, "modular {md:.4} should beat interleaved {il:.4}");
        assert!(il > 0.0);
    }

    #[test]
    fn tp_programs_charge_the_amortised_all_reduce_time() {
        // Acceptance bar for the C.4.3 gap: a tp > 1 plan's
        // TensorAllReduce ops must cost real simulated time, and exactly
        // the cost model's amortised per-layer wire time — the compute
        // stream of each stage grows by (TAR ops per stage) × duration.
        let shape = XModel::new(32).shape();
        let cfg = TrainConfig {
            strategy: Strategy::Baseline,
            n_b: 1,
            n_l: 4,
            n_a: 2,
            n_mu: 8,
            b_mu: 1.0,
            offload: false,
            partition: false,
            zero: 0,
        };
        let c2 = CostTable::new(&shape, &cfg, &ClusterSpec::reference());
        assert!(c2.tp_all_reduce_fwd > 0.0 && c2.tp_all_reduce_bwd > 0.0);
        let mut sp = ScheduleSpec {
            d_l: 16,
            n_l: 4,
            n_mu: 8,
            tp: 2,
            partition: false,
            offload: false,
            data_parallel: false,
            zero: 0,
        };
        let tp_run = simulate(&modular_pipeline(&sp), &c2);
        sp.tp = 1;
        let base = simulate(&modular_pipeline(&sp), &c2);
        assert!(tp_run.makespan > base.makespan, "tp must not simulate for free");
        // Modular, 16 layers over 4 stages, 8 micro-batches: 4·8 TAR ops
        // per phase per stage, serialised on the compute stream.
        let per_stage = 4.0 * 8.0 * (c2.tp_all_reduce_fwd + c2.tp_all_reduce_bwd);
        for s in 0..4 {
            let grew = tp_run.stream_busy(s, Stream::Compute)
                - base.stream_busy(s, Stream::Compute);
            assert!(
                (grew - per_stage).abs() < 1e-9 * per_stage,
                "stage {s}: compute busy grew {grew:.3e}, want {per_stage:.3e}"
            );
        }
    }

    #[test]
    fn one_f_one_b_uses_less_memory_than_gpipe() {
        let sp = ScheduleSpec {
            d_l: 16,
            n_l: 4,
            n_mu: 16,
            tp: 1,
            partition: false,
            offload: false,
            data_parallel: false,
            zero: 0,
        };
        let c = costs(1, 4, 16, false);
        let gpipe = simulate(&standard_ga(&sp), &c);
        let fb = simulate(&one_f_one_b(&sp), &c);
        // Compare the checkpoint component (the live working set is a
        // constant floor shared by both schedules).
        let gp = gpipe.peak_memory.iter().cloned().fold(0.0, f64::max) - c.live_activation_bytes;
        let fp = fb.peak_memory.iter().cloned().fold(0.0, f64::max) - c.live_activation_bytes;
        assert!(
            fp < gp * 0.5,
            "1F1B checkpoint peak {fp:.3e} should be well under GPipe's {gp:.3e}"
        );
    }

    #[test]
    fn lga_spreads_reductions_standard_bunches_them() {
        use crate::schedule::layered_ga;
        let sp = ScheduleSpec {
            d_l: 16,
            n_l: 1,
            n_mu: 8,
            tp: 1,
            partition: false,
            offload: false,
            data_parallel: true,
            zero: 0,
        };
        let c = costs(8, 1, 8, false);
        let std_r = simulate(&standard_ga(&sp), &c);
        let lga_r = simulate(&layered_ga(&sp), &c);
        // Figure 1: the standard schedule can only overlap the reduction
        // with the last micro-batch, leaving most of it exposed after the
        // compute ends; LGA hides it behind the whole backward pass.
        let std_tail = std_r.exposed_network_tail();
        let lga_tail = lga_r.exposed_network_tail();
        assert!(
            lga_tail < std_tail * 0.3,
            "LGA tail {lga_tail:.3e} vs standard tail {std_tail:.3e}"
        );
        // And the LGA makespan is strictly better overall.
        assert!(lga_r.makespan < std_r.makespan);
    }

    #[test]
    fn makespan_at_least_critical_path() {
        let sp = ScheduleSpec {
            d_l: 8,
            n_l: 4,
            n_mu: 4,
            tp: 1,
            partition: false,
            offload: false,
            data_parallel: false,
            zero: 0,
        };
        let c = costs(1, 4, 4, false);
        let r = simulate(&modular_pipeline(&sp), &c);
        // Lower bound: per-stage compute (2 layers × 4 mb × (fwd+bwd)).
        let per_stage = 2.0 * 4.0 * (c.fwd + c.bwd);
        assert!(r.makespan >= per_stage - 1e-12);
        // Upper bound sanity: fully serial would be n_l times that.
        assert!(r.makespan < 4.0 * per_stage);
    }

    #[test]
    fn degenerate_results_never_yield_nan() {
        // An empty program produces a zero-makespan result; the derived
        // metrics must stay comparable (no NaN poisoning planner sorts).
        let empty = SimResult {
            makespan: 0.0,
            busy: Vec::new(),
            peak_memory: vec![],
            timeline: vec![],
            n_stages: 0,
        };
        assert_eq!(empty.compute_efficiency(), 0.0);
        assert!(empty.bubble_fraction().is_infinite() && !empty.bubble_fraction().is_nan());
        assert_eq!(empty.max_netout_utilisation(), 0.0);
        let idle = SimResult {
            makespan: 1.0,
            busy: Vec::new(),
            peak_memory: vec![0.0],
            timeline: vec![],
            n_stages: 1,
        };
        assert_eq!(idle.compute_efficiency(), 0.0);
        assert!(idle.bubble_fraction().is_infinite());
    }

    fn offloaded_program() -> (ScheduleProgram, CostTable) {
        let sp = ScheduleSpec {
            d_l: 8,
            n_l: 4,
            n_mu: 4,
            tp: 1,
            partition: true,
            offload: true,
            data_parallel: true,
            zero: 0,
        };
        let p = lower(&modular_pipeline(&sp)).unwrap();
        (p, costs(4, 4, 4, true))
    }

    #[test]
    fn failure_free_replay_is_exactly_the_serial_runtime() {
        let (p, c) = offloaded_program();
        let acc = simulate_with_failures(&p, &c, 100, 1, &[]);
        assert!(acc.step_secs > 0.0);
        // The offloaded schedule carries real RestoreParams ops, so the
        // restore cost comes from the schedule, not the fallback.
        assert!(acc.restore_secs > 0.0);
        assert!(acc.failures.is_empty());
        // Bit-exact identity: no failures means no lost work at all.
        assert_eq!(acc.wall_secs, 100.0 * acc.step_secs);
        assert_eq!(acc.lost_secs, 0.0);
        assert_eq!(acc.lost_fraction, 0.0);
    }

    #[test]
    fn a_failure_rolls_back_to_the_checkpoint_and_charges_the_restore() {
        let (p, c) = offloaded_program();
        let s = recovery_costs(&p, &c).0;
        let hit = [FailureEvent { at_secs: 3.5 * s, stage: 0 }];
        // Real-time checkpoints (interval 1): only the in-flight half
        // step plus the restore is lost.
        let rt = simulate_with_failures(&p, &c, 10, 1, &hit);
        assert_eq!(rt.failures.len(), 1);
        assert_eq!(rt.failures[0].rolled_back_steps, 0);
        let want = 10.0 * s + 0.5 * s + rt.restore_secs;
        assert!((rt.wall_secs - want).abs() < 1e-9 * want, "{} vs {want}", rt.wall_secs);
        // Classic interval-4 checkpoints: the same failure also rolls
        // back 3 completed steps — Figure 2's argument, quantified.
        let classic = simulate_with_failures(&p, &c, 10, 4, &hit);
        assert_eq!(classic.failures[0].rolled_back_steps, 3);
        assert!(classic.lost_secs > rt.lost_secs);
        assert!(classic.lost_fraction > rt.lost_fraction);
        // The per-failure records account for every lost second.
        let sum: f64 = classic.failures.iter().map(|f| f.lost_secs).sum();
        assert!((classic.lost_secs - sum).abs() < 1e-9 * sum.max(1.0));
    }

    #[test]
    fn failures_after_completion_cost_nothing() {
        let (p, c) = offloaded_program();
        let s = recovery_costs(&p, &c).0;
        let acc =
            simulate_with_failures(&p, &c, 5, 1, &[FailureEvent { at_secs: 100.0 * s, stage: 2 }]);
        assert!(acc.failures.is_empty());
        assert_eq!(acc.wall_secs, 5.0 * s);
        // And events arrive unsorted without changing the accounting.
        let ev = [
            FailureEvent { at_secs: 3.2 * s, stage: 1 },
            FailureEvent { at_secs: 1.4 * s, stage: 0 },
        ];
        let mut rev = ev;
        rev.reverse();
        let a = simulate_with_failures(&p, &c, 10, 1, &ev);
        let b = simulate_with_failures(&p, &c, 10, 1, &rev);
        assert_eq!(a, b);
        assert_eq!(a.failures.len(), 2);
    }
}
