//! Discrete-event simulator.
//!
//! Executes a [`Schedule`] against a [`CostTable`]. Each pipeline stage is
//! a device with four streams (compute, net-out, net-in, cpu-link); ops on
//! a stream run in schedule order, but an op only *starts* when its data
//! dependencies are satisfied — the pipeline bubble, communication stalls
//! and overlap (or lack of it) all emerge from this rule rather than being
//! assumed.
//!
//! Dependency rules (tokens):
//! * `Fwd(l, mb)` needs the activation of `l−1` for `mb` on this device
//!   (local `Fwd` or a completed `RecvAct`), and the latest preceding
//!   `RestoreParams(l)` on this stage if the schedule carries them;
//! * `Bwd(l, mb)` needs `Fwd(l, mb)` (the checkpoint) and the gradient of
//!   `l+1` (local `Bwd`, a completed `RecvGrad`, or nothing for the last
//!   layer), plus the latest preceding restore;
//! * `SendX` needs its payload; `RecvX` needs the matching `SendX` to have
//!   completed (wire time is charged on the sender);
//! * `ReduceGrad(l)` needs every local `Bwd(l, ·)`;
//! * `OptimStep(l)` needs `ReduceGrad(l)` when present, else the local
//!   backward ops.

use std::collections::{BinaryHeap, HashMap, HashSet};

use crate::schedule::{Op, Schedule};

use super::cost::{CostTable, Stream, STREAMS};

/// A completed op with its simulated time window.
#[derive(Debug, Clone, PartialEq)]
pub struct TimedOp {
    pub stage: usize,
    pub op: Op,
    pub stream: Stream,
    pub start: f64,
    pub end: f64,
}

/// Result of simulating one schedule.
#[derive(Debug, Clone)]
pub struct SimResult {
    /// Total makespan, seconds.
    pub makespan: f64,
    /// Busy time per (stage, stream).
    pub busy: HashMap<(usize, Stream), f64>,
    /// Peak per-stage memory from checkpoints + live activations, bytes.
    pub peak_memory: Vec<f64>,
    /// Full timeline (for Gantt rendering and fine-grained metrics).
    pub timeline: Vec<TimedOp>,
    pub n_stages: usize,
}

impl SimResult {
    /// Fraction of the makespan each stage's compute stream is busy,
    /// averaged over stages: the simulator's measured efficiency.
    pub fn compute_efficiency(&self) -> f64 {
        let total: f64 = (0..self.n_stages)
            .map(|s| self.busy.get(&(s, Stream::Compute)).copied().unwrap_or(0.0))
            .sum();
        total / (self.n_stages as f64 * self.makespan)
    }

    /// Measured bubble fraction: idle compute time relative to busy
    /// compute time (comparable to the paper's (n_l−1)/n_μ closed form).
    pub fn bubble_fraction(&self) -> f64 {
        let eff = self.compute_efficiency();
        (1.0 - eff) / eff
    }

    /// Network busy fraction (out-stream) of the busiest stage.
    pub fn max_netout_utilisation(&self) -> f64 {
        (0..self.n_stages)
            .map(|s| self.busy.get(&(s, Stream::NetOut)).copied().unwrap_or(0.0) / self.makespan)
            .fold(0.0, f64::max)
    }

    /// Largest gap (seconds) between consecutive `ReduceGrad` completions
    /// — small for LGA (spread over the backward pass), large for
    /// standard GA (bunched at the end).
    pub fn reduce_spread(&self) -> f64 {
        let mut ends: Vec<f64> = self
            .timeline
            .iter()
            .filter(|t| matches!(t.op, Op::ReduceGrad { .. }))
            .map(|t| t.end)
            .collect();
        if ends.len() < 2 {
            return 0.0;
        }
        ends.sort_by(|a, b| a.partial_cmp(b).unwrap());
        ends.windows(2).map(|w| w[1] - w[0]).fold(0.0, f64::max)
    }

    /// Exposed network tail: time between the last Fwd/Bwd compute
    /// finishing and the last network op finishing. Standard gradient
    /// accumulation serialises the whole gradient reduction here
    /// (Figure 1 top); LGA hides it behind the backward pass.
    pub fn exposed_network_tail(&self) -> f64 {
        let last_compute = self
            .timeline
            .iter()
            .filter(|t| matches!(t.op, Op::Fwd { .. } | Op::Bwd { .. }))
            .map(|t| t.end)
            .fold(0.0, f64::max);
        let last_net = self
            .timeline
            .iter()
            .filter(|t| t.op.is_transfer())
            .map(|t| t.end)
            .fold(0.0, f64::max);
        (last_net - last_compute).max(0.0)
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
struct Event {
    time: f64,
    stage: usize,
    stream_idx: usize,
}

impl Eq for Event {}
impl PartialOrd for Event {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}
impl Ord for Event {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        // Min-heap on time.
        other
            .time
            .partial_cmp(&self.time)
            .unwrap()
            .then_with(|| other.stage.cmp(&self.stage))
            .then_with(|| other.stream_idx.cmp(&self.stream_idx))
    }
}

/// Tokens produced by completed ops.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
enum Token {
    /// Activation of `layer` for `mb` available on `stage`.
    Act { stage: usize, layer: usize, mb: usize },
    /// Output-gradient w.r.t. `layer`'s output available on `stage`.
    Grad { stage: usize, layer: usize, mb: usize },
    /// Wire: SendAct(layer, mb) completed (globally visible).
    WireAct { layer: usize, mb: usize },
    /// Wire: SendGrad(layer, mb) completed.
    WireGrad { layer: usize, mb: usize },
    /// The `idx`-th RestoreParams op on `stage` completed.
    Restore { stage: usize, idx: usize },
    /// ReduceGrad(layer) completed on `stage`.
    Reduced { stage: usize, layer: usize },
    /// Bwd(layer, mb) completed on `stage` (for reduce deps).
    BwdDone { stage: usize, layer: usize, mb: usize },
}

/// Per-op dependency list, precomputed from the schedule.
fn dependencies(s: &Schedule) -> Vec<Vec<Vec<Token>>> {
    let mut deps: Vec<Vec<Vec<Token>>> = Vec::with_capacity(s.n_stages);
    for (stage, ops) in s.ops.iter().enumerate() {
        // Track the index of the most recent RestoreParams per layer, and
        // the running count of restore ops on this stage.
        let mut last_restore_for_layer: HashMap<usize, usize> = HashMap::new();
        let mut restore_count = 0usize;
        let mut op_deps: Vec<Vec<Token>> = Vec::with_capacity(ops.len());
        for op in ops {
            let mut d = Vec::new();
            match *op {
                Op::RestoreParams { layer } => {
                    last_restore_for_layer.insert(layer, restore_count);
                    restore_count += 1;
                }
                Op::Fwd { layer, mb } => {
                    if layer > 0 {
                        if s.stage_of(layer - 1) == stage {
                            d.push(Token::Act { stage, layer: layer - 1, mb });
                        } else {
                            d.push(Token::WireAct { layer: layer - 1, mb });
                        }
                    }
                    if let Some(&idx) = last_restore_for_layer.get(&layer) {
                        d.push(Token::Restore { stage, idx });
                    }
                }
                Op::Bwd { layer, mb } => {
                    d.push(Token::Act { stage, layer, mb }); // checkpoint
                    if layer + 1 < s.d_l {
                        if s.stage_of(layer + 1) == stage {
                            d.push(Token::Grad { stage, layer: layer + 1, mb });
                        } else {
                            d.push(Token::WireGrad { layer: layer + 1, mb });
                        }
                    }
                    if let Some(&idx) = last_restore_for_layer.get(&layer) {
                        d.push(Token::Restore { stage, idx });
                    }
                }
                Op::SendAct { layer, mb } => d.push(Token::Act { stage, layer, mb }),
                Op::SendGrad { layer, mb } => d.push(Token::Grad { stage, layer, mb }),
                Op::RecvAct { layer, mb } => d.push(Token::WireAct { layer: layer - 1, mb }),
                Op::RecvGrad { layer, mb } => d.push(Token::WireGrad { layer: layer + 1, mb }),
                Op::ReduceGrad { layer } => {
                    for mb in 0..s.n_mu {
                        d.push(Token::BwdDone { stage, layer, mb });
                    }
                }
                Op::OptimStep { layer } => {
                    // Depends on the reduction when the schedule has one.
                    let has_reduce =
                        s.ops[stage].iter().any(|o| matches!(o, Op::ReduceGrad { layer: l } if *l == layer));
                    if has_reduce {
                        d.push(Token::Reduced { stage, layer });
                    } else {
                        for mb in 0..s.n_mu {
                            d.push(Token::BwdDone { stage, layer, mb });
                        }
                    }
                }
                Op::OffloadStore { layer } => {
                    let has_reduce =
                        s.ops[stage].iter().any(|o| matches!(o, Op::ReduceGrad { layer: l } if *l == layer));
                    if has_reduce {
                        d.push(Token::Reduced { stage, layer });
                    }
                }
                Op::TensorAllReduce { .. } => {}
            }
            op_deps.push(d);
        }
        deps.push(op_deps);
    }
    deps
}

/// Tokens produced when an op completes.
fn productions(_s: &Schedule, stage: usize, op: &Op, restore_idx: usize) -> Vec<Token> {
    match *op {
        Op::Fwd { layer, mb } => vec![Token::Act { stage, layer, mb }],
        Op::Bwd { layer, mb } => vec![
            Token::Grad { stage, layer, mb },
            Token::BwdDone { stage, layer, mb },
        ],
        Op::SendAct { layer, mb } => vec![Token::WireAct { layer, mb }],
        Op::SendGrad { layer, mb } => vec![Token::WireGrad { layer, mb }],
        // A receive re-homes the wire data as a local token.
        Op::RecvAct { layer, mb } => vec![Token::Act { stage, layer: layer - 1, mb }],
        Op::RecvGrad { layer, mb } => vec![Token::Grad { stage, layer: layer + 1, mb }],
        Op::ReduceGrad { layer } => vec![Token::Reduced { stage, layer }],
        Op::RestoreParams { .. } => vec![Token::Restore { stage, idx: restore_idx }],
        _ => vec![],
    }
}

/// Simulate a schedule with the given cost table.
///
/// Panics on deadlock (a validated schedule never deadlocks — see
/// [`crate::schedule::validate`]).
pub fn simulate(s: &Schedule, costs: &CostTable) -> SimResult {
    let deps = dependencies(s);

    // Per-(stage, stream) FIFO of op indices into s.ops[stage].
    let mut queues: Vec<[Vec<usize>; 4]> = Vec::with_capacity(s.n_stages);
    for ops in &s.ops {
        let mut q: [Vec<usize>; 4] = Default::default();
        for (i, op) in ops.iter().enumerate() {
            let stream = CostTable::stream(op);
            let idx = STREAMS.iter().position(|&x| x == stream).unwrap();
            q[idx].push(i);
        }
        for v in q.iter_mut() {
            v.reverse(); // pop from the back
        }
        queues.push(q);
    }

    // Restore-op ordinal per stage (used for Restore tokens).
    let mut restore_ordinal: Vec<HashMap<usize, usize>> = Vec::with_capacity(s.n_stages);
    for ops in &s.ops {
        let mut m = HashMap::new();
        let mut count = 0usize;
        for (i, op) in ops.iter().enumerate() {
            if matches!(op, Op::RestoreParams { .. }) {
                m.insert(i, count);
                count += 1;
            }
        }
        restore_ordinal.push(m);
    }

    let mut tokens: HashSet<Token> = HashSet::new();
    let mut stream_free: Vec<[f64; 4]> = vec![[0.0; 4]; s.n_stages];
    let mut running: Vec<[Option<(usize, f64)>; 4]> = vec![[None; 4]; s.n_stages];
    let mut events: BinaryHeap<Event> = BinaryHeap::new();
    let mut timeline: Vec<TimedOp> = Vec::new();
    let mut busy: HashMap<(usize, Stream), f64> = HashMap::new();
    let mut now = 0.0f64;

    // Memory tracking: running checkpoint count per stage; peak.
    let mut mem: Vec<f64> = vec![0.0; s.n_stages];
    let mut peak: Vec<f64> = vec![0.0; s.n_stages];

    let total_ops = s.len();
    let mut completed = 0usize;

    // Wake-list scheduler (§Perf L3): instead of rescanning every stream
    // head after every event (O(events · stages)), each blocked stream
    // registers as a waiter on its first missing token; producing a token
    // wakes exactly the streams that were blocked on it, and a completing
    // op re-queues only its own stream. Amortised O(ops · deps).
    let mut waiters: HashMap<Token, Vec<(usize, usize)>> = HashMap::new();
    let mut worklist: Vec<(usize, usize)> =
        (0..s.n_stages).flat_map(|st| (0..4).map(move |si| (st, si))).collect();

    // Try to start the head op of one idle stream; on a missing dep,
    // register as a waiter on it.
    macro_rules! try_start_one {
        ($stage:expr, $si:expr) => {{
            let (stage, si) = ($stage, $si);
            'attempt: loop {
                if running[stage][si].is_some() {
                    break 'attempt;
                }
                let Some(&op_idx) = queues[stage][si].last() else { break 'attempt };
                if let Some(missing) =
                    deps[stage][op_idx].iter().find(|t| !tokens.contains(*t))
                {
                    waiters.entry(*missing).or_default().push((stage, si));
                    break 'attempt;
                }
                queues[stage][si].pop();
                let op = s.ops[stage][op_idx];
                let start = now.max(stream_free[stage][si]);
                let dur = costs.duration(&op);
                let end = start + dur;
                running[stage][si] = Some((op_idx, end));
                events.push(Event { time: end, stage, stream_idx: si });
                timeline.push(TimedOp { stage, op, stream: STREAMS[si], start, end });
                *busy.entry((stage, STREAMS[si])).or_insert(0.0) += dur;
                // Memory: checkpoints accumulate at Fwd, free at Bwd.
                if let Op::Fwd { .. } = op {
                    mem[stage] += costs.checkpoint_bytes;
                    peak[stage] = peak[stage].max(mem[stage] + costs.live_activation_bytes);
                } else if let Op::Bwd { .. } = op {
                    peak[stage] = peak[stage].max(mem[stage] + costs.live_activation_bytes);
                    mem[stage] -= costs.checkpoint_bytes;
                }
                break 'attempt;
            }
        }};
    }

    loop {
        // Drain the worklist: start everything startable right now.
        while let Some((stage, si)) = worklist.pop() {
            try_start_one!(stage, si);
        }
        if completed == total_ops {
            break;
        }
        let Some(ev) = events.pop() else {
            let stuck: Vec<String> = (0..s.n_stages)
                .flat_map(|st| {
                    queues[st]
                        .iter()
                        .filter_map(move |q| q.last().map(move |&i| (st, i)))
                        .map(|(st, i)| format!("stage {} op {}", st, s.ops[st][i]))
                        .collect::<Vec<_>>()
                })
                .collect();
            let waiting: Vec<String> = waiters
                .iter()
                .map(|(t, w)| format!("{t:?} <- {w:?}"))
                .collect();
            panic!(
                "simulator deadlock at t={now}; completed {completed}/{total_ops}; blocked heads: {stuck:?}; waiters: {waiting:?}"
            );
        };
        now = ev.time;
        // Complete every op finishing at this instant.
        let mut to_complete = vec![ev];
        while let Some(next) = events.peek() {
            if next.time <= now {
                to_complete.push(events.pop().unwrap());
            } else {
                break;
            }
        }
        for e in to_complete {
            let (op_idx, end) = running[e.stage][e.stream_idx].take().expect("event without op");
            debug_assert!(end <= now + 1e-12);
            stream_free[e.stage][e.stream_idx] = end;
            let op = s.ops[e.stage][op_idx];
            let ridx = restore_ordinal[e.stage].get(&op_idx).copied().unwrap_or(0);
            for t in productions(s, e.stage, &op, ridx) {
                tokens.insert(t);
                if let Some(w) = waiters.remove(&t) {
                    worklist.extend(w);
                }
            }
            // The freed stream can take its next op.
            worklist.push((e.stage, e.stream_idx));
            completed += 1;
        }
    }

    let makespan = timeline.iter().map(|t| t.end).fold(0.0, f64::max);
    SimResult { makespan, busy, peak_memory: peak, timeline, n_stages: s.n_stages }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::{Strategy, TrainConfig};
    use crate::hardware::ClusterSpec;
    use crate::model::XModel;
    use crate::schedule::{modular_pipeline, one_f_one_b, standard_ga, ScheduleSpec};
    use crate::sim::cost::CostTable;

    fn costs(n_b: usize, n_l: usize, n_mu: usize, partition: bool) -> CostTable {
        let shape = XModel::new(32).shape();
        let cfg = TrainConfig {
            strategy: if partition { Strategy::Improved } else { Strategy::Baseline },
            n_b,
            n_l,
            n_a: 1,
            n_mu,
            b_mu: 1.0,
            offload: false,
            partition,
        };
        CostTable::new(&shape, &cfg, &ClusterSpec::reference())
    }

    #[test]
    fn single_stage_standard_ga_has_full_efficiency() {
        let sp = ScheduleSpec { d_l: 8, n_l: 1, n_mu: 4, partition: false, data_parallel: false };
        let s = standard_ga(&sp);
        let r = simulate(&s, &costs(1, 1, 4, false));
        // No pipeline, no DP: compute runs back-to-back.
        assert!(r.compute_efficiency() > 0.99, "eff = {}", r.compute_efficiency());
    }

    /// A cost table with only compute time — isolates the bubble from
    /// transfer/optimizer effects, like the paper's closed form does.
    fn compute_only(c: &CostTable) -> CostTable {
        CostTable { send_act: 0.0, send_grad: 0.0, reduce_grad: 0.0, restore_params: 0.0, offload_store: 0.0, optim_step: 0.0, ..c.clone() }
    }

    #[test]
    fn gpipe_bubble_matches_closed_form() {
        // Contiguous pipeline, 4 stages, 8 micro-batches: closed-form
        // bubble (n_l−1)/n_μ = 3/8 (§2.4). Transfers/optimizer zeroed —
        // the closed form ignores them.
        let sp = ScheduleSpec { d_l: 16, n_l: 4, n_mu: 8, partition: false, data_parallel: false };
        let s = standard_ga(&sp);
        let r = simulate(&s, &compute_only(&costs(1, 4, 8, false)));
        let measured = r.bubble_fraction();
        assert!(
            (measured - 3.0 / 8.0).abs() < 1e-6,
            "measured bubble {measured:.6} vs closed form 0.375"
        );
    }

    #[test]
    fn modular_bubble_matches_closed_form_exactly() {
        // §4: modular bubble = n_l(n_l−1)/(n_μ·d_l) = 4·3/(8·16) = 3/32.
        let sp = ScheduleSpec { d_l: 16, n_l: 4, n_mu: 8, partition: false, data_parallel: false };
        let s = modular_pipeline(&sp);
        let r = simulate(&s, &compute_only(&costs(1, 4, 8, false)));
        let measured = r.bubble_fraction();
        assert!(
            (measured - 3.0 / 32.0).abs() < 1e-6,
            "measured bubble {measured:.6} vs closed form {:.6}",
            3.0 / 32.0
        );
    }

    #[test]
    fn modular_bubble_is_dl_over_nl_smaller_than_contiguous() {
        let d_l = 16;
        let n_l = 4;
        let n_mu = 8;
        let c = costs(1, n_l, n_mu, false);
        let sp = ScheduleSpec { d_l, n_l, n_mu, partition: false, data_parallel: false };
        let naive = simulate(&standard_ga(&sp), &c);
        let modular = simulate(&modular_pipeline(&sp), &c);
        let ratio = naive.bubble_fraction() / modular.bubble_fraction();
        // §4: the bubble shrinks by d_l/n_l = 4 (within simulation noise
        // from transfers).
        assert!(
            ratio > 2.5 && ratio < 6.0,
            "bubble ratio {ratio:.2} (naive {:.4}, modular {:.4})",
            naive.bubble_fraction(),
            modular.bubble_fraction()
        );
        // And the modular makespan is strictly better.
        assert!(modular.makespan < naive.makespan);
    }

    #[test]
    fn one_f_one_b_uses_less_memory_than_gpipe() {
        let sp = ScheduleSpec { d_l: 16, n_l: 4, n_mu: 16, partition: false, data_parallel: false };
        let c = costs(1, 4, 16, false);
        let gpipe = simulate(&standard_ga(&sp), &c);
        let fb = simulate(&one_f_one_b(&sp), &c);
        // Compare the checkpoint component (the live working set is a
        // constant floor shared by both schedules).
        let gp = gpipe.peak_memory.iter().cloned().fold(0.0, f64::max) - c.live_activation_bytes;
        let fp = fb.peak_memory.iter().cloned().fold(0.0, f64::max) - c.live_activation_bytes;
        assert!(
            fp < gp * 0.5,
            "1F1B checkpoint peak {fp:.3e} should be well under GPipe's {gp:.3e}"
        );
    }

    #[test]
    fn lga_spreads_reductions_standard_bunches_them() {
        use crate::schedule::layered_ga;
        let sp = ScheduleSpec { d_l: 16, n_l: 1, n_mu: 8, partition: false, data_parallel: true };
        let c = costs(8, 1, 8, false);
        let std_r = simulate(&standard_ga(&sp), &c);
        let lga_r = simulate(&layered_ga(&sp), &c);
        // Figure 1: the standard schedule can only overlap the reduction
        // with the last micro-batch, leaving most of it exposed after the
        // compute ends; LGA hides it behind the whole backward pass.
        let std_tail = std_r.exposed_network_tail();
        let lga_tail = lga_r.exposed_network_tail();
        assert!(
            lga_tail < std_tail * 0.3,
            "LGA tail {lga_tail:.3e} vs standard tail {std_tail:.3e}"
        );
        // And the LGA makespan is strictly better overall.
        assert!(lga_r.makespan < std_r.makespan);
    }

    #[test]
    fn makespan_at_least_critical_path() {
        let sp = ScheduleSpec { d_l: 8, n_l: 4, n_mu: 4, partition: false, data_parallel: false };
        let c = costs(1, 4, 4, false);
        let r = simulate(&modular_pipeline(&sp), &c);
        // Lower bound: per-stage compute (2 layers × 4 mb × (fwd+bwd)).
        let per_stage = 2.0 * 4.0 * (c.fwd + c.bwd);
        assert!(r.makespan >= per_stage - 1e-12);
        // Upper bound sanity: fully serial would be n_l times that.
        assert!(r.makespan < 4.0 * per_stage);
    }
}
