//! ASCII Gantt rendering of simulated timelines — the reproduction of the
//! paper's Figures 1–3 scheduling diagrams.
//!
//! Each stage gets two rows: a compute row and a network row. Time is
//! quantised into character cells; each cell shows the micro-batch digit
//! for forward ops, the digit in brackets style for backward (lowercase
//! letters f/b prefix dropped for width), `R` for gradient reduction,
//! `G` for parameter restoration, `·` for idle.

use crate::schedule::Op;

use super::cost::Stream;
use super::engine::{SimResult, TimedOp};

/// Character used for an op's cells.
fn glyph(op: &Op) -> char {
    match op {
        Op::Fwd { mb, .. } => char::from_digit((*mb % 10) as u32, 10).unwrap(),
        Op::Bwd { mb, .. } => {
            // Backward shown as letters a..j to distinguish from forward.
            (b'a' + (*mb % 10) as u8) as char
        }
        Op::SendAct { .. } => '>',
        Op::RecvAct { .. } => '<',
        Op::SendGrad { .. } => '}',
        Op::RecvGrad { .. } => '{',
        Op::ReduceGrad { .. } => 'R',
        Op::RestoreParams { .. } => 'G',
        Op::OffloadStore { .. } => 'O',
        Op::OptimStep { .. } => 'U',
        Op::TensorAllReduce { .. } => 't',
    }
}

/// Serving glyphs: label forward-only ops with *request* identity
/// instead of the raw micro-batch index. Decode programs encode
/// `mb = token · n_req + request` (see
/// [`crate::schedule::decode_identity`]); a prefill program is the
/// `n_req = n_mu` special case, where the two labellings coincide.
/// Compute cells show the request digit; transfer cells keep the
/// direction glyphs (the request is readable from the adjacent
/// compute cell).
fn serve_glyph(op: &Op, n_req: usize) -> char {
    match op {
        Op::Fwd { mb, .. } | Op::TensorAllReduce { mb, .. } => {
            let (_token, req) = crate::schedule::decode_identity(*mb, n_req);
            char::from_digit((req % 10) as u32, 10).unwrap()
        }
        other => glyph(other),
    }
}

/// Render a simulated timeline as ASCII, `width` characters across.
/// Needs a result produced with `record_timeline: true` (the default);
/// a timeline-free planner-loop result renders as all-idle rows.
pub fn render(result: &SimResult, width: usize) -> String {
    render_with(result, width, glyph)
}

/// Render a *serving* timeline: forward-only ops are labelled with the
/// request slot they advance (`n_req` in-flight requests), so a decode
/// Gantt reads as waves of request digits instead of ever-growing
/// micro-batch indices.
pub fn render_requests(result: &SimResult, width: usize, n_req: usize) -> String {
    render_with(result, width, |op| serve_glyph(op, n_req))
}

fn render_with(result: &SimResult, width: usize, glyph_of: impl Fn(&Op) -> char) -> String {
    let span = result.makespan.max(1e-30);
    let scale = width as f64 / span;
    let mut out = String::new();
    for stage in 0..result.n_stages {
        for (stream, label) in [(Stream::Compute, "comp"), (Stream::NetOut, "nout"), (Stream::NetIn, "nin ")] {
            let mut row = vec!['·'; width];
            for t in result.timeline.iter().filter(|t| t.stage == stage && t.stream == stream) {
                paint(&mut row, t, scale, &glyph_of);
            }
            // Skip all-idle network rows to keep small figures compact.
            if stream != Stream::Compute && row.iter().all(|&c| c == '·') {
                continue;
            }
            out.push_str(&format!("s{stage} {label} |{}|\n", row.iter().collect::<String>()));
        }
    }
    out
}

fn paint(row: &mut [char], t: &TimedOp, scale: f64, glyph_of: &impl Fn(&Op) -> char) {
    let width = row.len();
    let a = ((t.start * scale).floor() as usize).min(width.saturating_sub(1));
    let b = ((t.end * scale).ceil() as usize).clamp(a + 1, width);
    let g = glyph_of(&t.op);
    for cell in row.iter_mut().take(b).skip(a) {
        *cell = g;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::{Strategy, TrainConfig};
    use crate::hardware::ClusterSpec;
    use crate::model::XModel;
    use crate::schedule::{modular_pipeline, standard_ga, ScheduleSpec};
    use crate::sim::cost::CostTable;
    use crate::sim::engine::simulate;

    fn render_policy(modular: bool) -> String {
        let sp = ScheduleSpec {
            d_l: 8,
            n_l: 4,
            n_mu: 6,
            tp: 1,
            partition: false,
            offload: false,
            data_parallel: false,
            zero: 0,
        };
        let s = if modular { modular_pipeline(&sp) } else { standard_ga(&sp) };
        let cfg = TrainConfig {
            strategy: if modular { Strategy::Improved } else { Strategy::Baseline },
            n_b: 1,
            n_l: 4,
            n_a: 1,
            n_mu: 6,
            b_mu: 1.0,
            offload: false,
            partition: false,
            zero: 0,
        };
        let costs = CostTable::new(&XModel::new(16).shape(), &cfg, &ClusterSpec::reference());
        render(&simulate(&s, &costs), 100)
    }

    #[test]
    fn renders_all_stages() {
        let g = render_policy(false);
        for stage in 0..4 {
            assert!(g.contains(&format!("s{stage} comp")), "{g}");
        }
    }

    #[test]
    fn serving_timeline_labels_requests_not_micro_batches() {
        use crate::schedule::{decode_waves, lower, ScheduleSpec};
        use crate::sim::engine::simulate_program;

        let sp = ScheduleSpec {
            d_l: 4,
            n_l: 2,
            n_mu: 2, // two in-flight requests
            tp: 1,
            partition: false,
            offload: false,
            data_parallel: false,
            zero: 0,
        };
        let program = lower(&decode_waves(&sp, 3)).unwrap();
        let cfg = TrainConfig {
            strategy: Strategy::Improved,
            n_b: 1,
            n_l: 2,
            n_a: 1,
            n_mu: 1,
            b_mu: 1.0 / 256.0,
            offload: false,
            partition: false,
            zero: 0,
        };
        let costs = CostTable::new(&XModel::new(16).shape(), &cfg, &ClusterSpec::reference());
        let result = simulate_program(&program, &costs);
        let plain = render(&result, 80);
        let served = render_requests(&result, 80, 2);
        // Six micro-batch slots (2 requests × 3 waves): the raw render
        // leaks wave-encoded indices 2..5, the serving render shows
        // only request digits 0 and 1.
        for bad in ['2', '3', '4', '5'] {
            assert!(plain.contains(bad), "raw render should show slot {bad}:\n{plain}");
            assert!(!served.contains(bad), "serving render leaks slot {bad}:\n{served}");
        }
        assert!(served.contains('0') && served.contains('1'), "{served}");
    }

    #[test]
    fn modular_figure_has_less_idle_than_naive() {
        let naive = render_policy(false);
        let modular = render_policy(true);
        let idle = |s: &str| s.lines().filter(|l| l.contains("comp")).map(|l| l.matches('·').count()).sum::<usize>();
        assert!(idle(&modular) < idle(&naive), "modular:\n{modular}\nnaive:\n{naive}");
    }
}
