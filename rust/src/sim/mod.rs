//! Discrete-event simulator: executes compiled schedule programs against
//! the Appendix A hardware model, measuring the bubble, communication
//! overlap and peak memory that the closed-form cost model predicts.
//!
//! The simulator consumes [`crate::schedule::ScheduleProgram`] — the
//! same dependency graph the validator checks and the trainer executes —
//! so the two halves cannot disagree about a schedule's dependency
//! semantics (the trainer's synchronous workers additionally verify the
//! stricter in-order condition at launch).

pub mod cost;
pub mod engine;
pub mod gantt;
pub mod rng;

pub use cost::{CostTable, Stream, WireBytes};
pub use engine::{
    recovery_costs, simulate, simulate_program, simulate_program_into, simulate_program_opts,
    simulate_with_failures, FailureEvent, FailureRecord, RecoveryAccounting, SimOptions, SimResult,
    SimScratch, TimedOp,
};
pub use gantt::{render, render_requests};
pub use rng::Xorshift;
