//! Discrete-event simulator: executes schedules against the Appendix A
//! hardware model, measuring the bubble, communication overlap and peak
//! memory that the closed-form cost model predicts.

pub mod cost;
pub mod engine;
pub mod gantt;

pub use cost::{CostTable, Stream};
pub use engine::{simulate, SimResult, TimedOp};
pub use gantt::render;
