//! Op cost table: how long each schedule op takes on a device, derived
//! from the Appendix A hardware model and the Appendix C traffic
//! formulas. The simulator multiplies these against the schedule; no
//! timing lives in the schedule itself.

use crate::costmodel::TrainConfig;
use crate::hardware::{ClusterSpec, LinkKind};
use crate::model::TransformerShape;
use crate::schedule::Op;

// Stream classification lives with the schedule program (the lowering
// pass needs it to build run queues); re-exported here for callers that
// reach it through the simulator.
pub use crate::schedule::program::{Stream, STREAMS};

/// Precomputed durations (seconds) for every op kind.
#[derive(Debug, Clone)]
pub struct CostTable {
    pub fwd: f64,
    pub bwd: f64,
    pub send_act: f64,
    pub send_grad: f64,
    pub reduce_grad: f64,
    /// ZeRO ≥2 gradient reduce-scatter: the first half of the ring
    /// all-reduce, so exactly half its bytes and rounds.
    pub reduce_scatter_grad: f64,
    /// ZeRO parameter all-gather (post-step for stages 1–2, before use
    /// for stage 3): the second half of the ring all-reduce.
    pub all_gather_params: f64,
    pub restore_params: f64,
    pub offload_store: f64,
    pub optim_step: f64,
    /// One forward-phase `TensorAllReduce`: the 2 amortised C.4.3
    /// all-reduces of a layer's forward pass for one micro-batch —
    /// exactly what the sharded runtime moves (mid-layer + boundary).
    pub tp_all_reduce_fwd: f64,
    /// One backward-phase `TensorAllReduce`: the 4 amortised all-reduces
    /// (backward + recompute) of a layer for one micro-batch. The
    /// paper's model recomputes the full forward (2 reduces); the
    /// sharded runtime needs only the x2 recompute reduce, so it moves
    /// 3 — the model is kept as the paper's conservative C.4.3 bound.
    pub tp_all_reduce_bwd: f64,
    /// Checkpoint bytes stored by one Fwd (freed by the matching Bwd).
    pub checkpoint_bytes: f64,
    /// Live working-set bytes while a compute op runs.
    pub live_activation_bytes: f64,
    /// Per-op wire payloads (bytes per rank) — the volume side of the
    /// durations above, for traffic accounting and the comparison
    /// tables.
    pub wire: WireBytes,
}

/// Wire bytes each transfer-like op puts on its link, per rank. Receives
/// are completion points (the sender is charged), so they report 0.
#[derive(Debug, Clone, Copy, Default)]
pub struct WireBytes {
    pub send_act: f64,
    pub send_grad: f64,
    pub reduce_grad: f64,
    pub reduce_scatter_grad: f64,
    pub all_gather_params: f64,
    pub restore_params: f64,
    pub offload_store: f64,
    pub tp_all_reduce_fwd: f64,
    pub tp_all_reduce_bwd: f64,
}

impl WireBytes {
    /// Wire bytes moved by one op.
    pub fn of(&self, op: &Op) -> f64 {
        match op {
            Op::SendAct { .. } => self.send_act,
            Op::SendGrad { .. } => self.send_grad,
            Op::ReduceGrad { .. } => self.reduce_grad,
            Op::ReduceScatterGrad { .. } => self.reduce_scatter_grad,
            Op::AllGatherParams { .. } => self.all_gather_params,
            Op::RestoreParams { .. } => self.restore_params,
            Op::OffloadStore { .. } => self.offload_store,
            Op::TensorAllReduce { bwd, .. } => {
                if *bwd {
                    self.tp_all_reduce_bwd
                } else {
                    self.tp_all_reduce_fwd
                }
            }
            Op::Fwd { .. }
            | Op::Bwd { .. }
            | Op::OptimStep { .. }
            | Op::RecvAct { .. }
            | Op::RecvGrad { .. } => 0.0,
        }
    }
}

/// Wire-byte convention of the cost tables: every payload is priced as
/// fp16 (Appendix C), 2 bytes per element. The runtime's measured
/// traffic counts *elements*, so `WireBytes / WIRE_BYTES_PER_ELEM`
/// bridges the two (see [`CostTable::wire_elements`]).
pub const WIRE_BYTES_PER_ELEM: f64 = 2.0;

impl CostTable {
    /// Build the table for a model shape + training config on a cluster.
    ///
    /// Bandwidths come from the cluster's calibration-aware accessors:
    /// uncalibrated clusters price the quoted Table A.1 figures with
    /// zero latency (the paper's idealised model — every latency term
    /// below is exactly 0 then); a `repro netbench` calibration
    /// substitutes the measured bandwidth and adds per-message
    /// half-RTT latency on every inter-node hop and ring round.
    pub fn new(shape: &TransformerShape, cfg: &TrainConfig, cluster: &ClusterSpec) -> Self {
        let peak = cluster.gpu.peak_flops;
        let inter_bw = cluster.inter_node_bandwidth();
        let inter_lat = cluster.inter_node_latency();
        let cpu_bw = LinkKind::CpuGpu.bandwidth();

        let b_mu = cfg.b_mu;
        let d_s = shape.d_s as f64;
        let d_m = shape.d_m() as f64;
        let n_a = cfg.n_a as f64;
        let n_b = cfg.n_b as f64;
        let p_l = shape.params_per_layer();

        // Compute: 2 flops/token/param forward; backward = 3x (includes
        // the activation recomputation), Appendix C.1.
        let fwd_flops = 2.0 * b_mu * d_s * p_l / n_a;
        let fwd = fwd_flops / peak;
        let bwd = 3.0 * fwd;

        // Pipeline boundary transfer: fp16 activations of one micro-batch
        // (one inter-node message — one latency charge when calibrated).
        let act_bytes = 2.0 * b_mu * d_s * d_m / n_a;
        let send_act = act_bytes / inter_bw + inter_lat;
        let send_grad = send_act; // gradient of the same tensor

        // Data-parallel gradient handling for one layer's parameters
        // (fp16, split over the tensor-parallel group):
        //  * plain all-reduce: ring scatter-reduce + all-gather,
        //    2 · 2 bytes · (n_b−1)/n_b per parameter;
        //  * partitioned: reduce-scatter only (the optimizer shard is
        //    local), half the traffic; the all-gather moved into
        //    RestoreParams.
        let ring = (n_b - 1.0).max(0.0) / n_b.max(1.0);
        let ring_rounds = (n_b - 1.0).max(0.0);
        let reduce_bytes =
            if cfg.partition { 2.0 * p_l / n_a * ring } else { 4.0 * p_l / n_a * ring };
        // Ring rounds: reduce-scatter is n_b−1 neighbour messages per
        // rank; a full all-reduce doubles that — each round pays one
        // latency when calibrated.
        let reduce_rounds = if cfg.partition { ring_rounds } else { 2.0 * ring_rounds };
        let reduce_grad = if n_b > 1.0 || cfg.partition {
            reduce_bytes / inter_bw + reduce_rounds * inter_lat
        } else {
            0.0
        };

        // Parameter restoration: fp16 all-gather over the data-parallel
        // group (partition), or a CPU->GPU fetch (offload), or both —
        // the slower path dominates when both apply.
        let restore_bytes = 2.0 * p_l / n_a;
        let restore_part_bytes = if cfg.partition { restore_bytes * ring } else { 0.0 };
        let restore_part_lat = if cfg.partition { ring_rounds * inter_lat } else { 0.0 };
        let restore_off_bytes = if cfg.offload { restore_bytes } else { 0.0 };
        let restore_params =
            (restore_part_bytes / inter_bw + restore_part_lat).max(restore_off_bytes / cpu_bw);

        let store_bytes = if cfg.offload { restore_bytes } else { 0.0 };
        let offload_store = store_bytes / cpu_bw;

        // ZeRO collectives: a reduce-scatter is the first half of the
        // ring all-reduce, the parameter all-gather the second — each
        // moves 2 bytes · (n_b−1)/n_b per parameter over n_b−1 rounds.
        // Their sum equals the plain all-reduce, which is the stage-2
        // invariant the traffic tables assert.
        let zero_half_bytes = if cfg.zero > 0 && n_b > 1.0 { 2.0 * p_l / n_a * ring } else { 0.0 };
        let zero_half = zero_half_bytes / inter_bw
            + if cfg.zero > 0 && n_b > 1.0 { ring_rounds * inter_lat } else { 0.0 };
        let reduce_scatter_grad = zero_half;
        let all_gather_params = zero_half;

        // Tensor-parallel all-reduces (C.4.3): six per layer per
        // micro-batch — 2 forward, 4 backward (recompute included) —
        // amortised into one op per phase. The reduced tensor is the
        // full fp16 activation (b_μ · d_s · d_m); each ring all-reduce
        // moves 2·(n_a−1)/n_a of it per rank, over the tensor-parallel
        // link (NVLink while the group fits in a node).
        let tp_ring = (n_a - 1.0).max(0.0) / n_a.max(1.0);
        let tp_bw = cluster.tensor_parallel_bandwidth(cfg.n_a);
        // Latency only applies once the group spills across nodes (the
        // §7 scenario) — in-node NVLink hops stay latency-free.
        let tp_lat =
            if cfg.n_a > cluster.max_node_size { cluster.inter_node_latency() } else { 0.0 };
        let tp_ar_bytes = 2.0 * b_mu * d_s * d_m * 2.0 * tp_ring;
        // One ring all-reduce is 2·(n_a−1) neighbour messages per rank.
        let tp_one = tp_ar_bytes / tp_bw + 2.0 * (n_a - 1.0).max(0.0) * tp_lat;
        let tp_all_reduce_fwd = 2.0 * tp_one;
        let tp_all_reduce_bwd = 4.0 * tp_one;

        // Optimizer step: fp32 state read-modify-write at HBM bandwidth,
        // negligible next to the layer compute but not zero.
        let optim_step = 12.0 * p_l / n_a / cluster.gpu.memory_bandwidth;

        let checkpoint_bytes = 2.0 * b_mu * d_s * d_m / n_a;
        let live_activation_bytes = b_mu * d_s * shape.m0_bytes_per_token() / n_a;

        let wire = WireBytes {
            send_act: act_bytes,
            send_grad: act_bytes,
            reduce_grad: if n_b > 1.0 || cfg.partition { reduce_bytes } else { 0.0 },
            reduce_scatter_grad: zero_half_bytes,
            all_gather_params: zero_half_bytes,
            // Both restore paths move bytes when both apply (the duration
            // takes the max because the links run in parallel; the volume
            // is the sum).
            restore_params: restore_part_bytes + restore_off_bytes,
            offload_store: store_bytes,
            tp_all_reduce_fwd: 2.0 * tp_ar_bytes,
            tp_all_reduce_bwd: 4.0 * tp_ar_bytes,
        };

        CostTable {
            fwd,
            bwd,
            send_act,
            send_grad,
            reduce_grad,
            reduce_scatter_grad,
            all_gather_params,
            restore_params,
            offload_store,
            optim_step,
            tp_all_reduce_fwd,
            tp_all_reduce_bwd,
            checkpoint_bytes,
            live_activation_bytes,
            wire,
        }
    }

    /// The stream an op occupies (delegates to [`Stream::of`]).
    pub fn stream(op: &Op) -> Stream {
        Stream::of(op)
    }

    /// Duration of an op, seconds.
    pub fn duration(&self, op: &Op) -> f64 {
        match op {
            Op::Fwd { .. } => self.fwd,
            Op::Bwd { .. } => self.bwd,
            Op::SendAct { .. } => self.send_act,
            Op::SendGrad { .. } => self.send_grad,
            // Receives are completion points of the matching send; the
            // wire time is charged on the sender side.
            Op::RecvAct { .. } | Op::RecvGrad { .. } => 0.0,
            Op::ReduceGrad { .. } => self.reduce_grad,
            Op::ReduceScatterGrad { .. } => self.reduce_scatter_grad,
            Op::AllGatherParams { .. } => self.all_gather_params,
            Op::RestoreParams { .. } => self.restore_params,
            Op::OffloadStore { .. } => self.offload_store,
            Op::OptimStep { .. } => self.optim_step,
            // The amortised per-layer tp wire time (C.4.3) — 0 only when
            // the config has no tensor parallelism (n_a = 1).
            Op::TensorAllReduce { bwd, .. } => {
                if *bwd {
                    self.tp_all_reduce_bwd
                } else {
                    self.tp_all_reduce_fwd
                }
            }
        }
    }

    /// Wire bytes an op moves (per rank) — see [`WireBytes`].
    pub fn wire_bytes(&self, op: &Op) -> f64 {
        self.wire.of(op)
    }

    /// Payload *elements* an op moves (per rank): the table's fp16 wire
    /// bytes divided by [`WIRE_BYTES_PER_ELEM`]. This is the quantity
    /// the runtime's `Traffic` counters measure, so schedule-implied
    /// volume and measured socket volume compare in the same unit
    /// (multiply by the runtime dtype's width for its bytes-on-wire).
    pub fn wire_elements(&self, op: &Op) -> f64 {
        self.wire.of(op) / WIRE_BYTES_PER_ELEM
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::Strategy;
    use crate::model::XModel;

    fn setup() -> (TransformerShape, TrainConfig, ClusterSpec) {
        let shape = XModel::new(32).shape();
        let cfg = TrainConfig {
            strategy: Strategy::Improved,
            n_b: 8,
            n_l: 4,
            n_a: 1,
            n_mu: 8,
            b_mu: 1.0,
            offload: false,
            partition: true,
            zero: 0,
        };
        (shape, cfg, ClusterSpec::reference())
    }

    #[test]
    fn backward_is_three_times_forward() {
        let (shape, cfg, cluster) = setup();
        let t = CostTable::new(&shape, &cfg, &cluster);
        assert!((t.bwd / t.fwd - 3.0).abs() < 1e-12);
    }

    #[test]
    fn partitioned_reduce_is_half_of_plain() {
        let (shape, mut cfg, cluster) = setup();
        let part = CostTable::new(&shape, &cfg, &cluster);
        cfg.partition = false;
        cfg.strategy = Strategy::Baseline;
        let plain = CostTable::new(&shape, &cfg, &cluster);
        assert!((plain.reduce_grad / part.reduce_grad - 2.0).abs() < 1e-9);
    }

    #[test]
    fn tensor_parallel_scales_compute_and_transfers() {
        let (shape, mut cfg, cluster) = setup();
        let t1 = CostTable::new(&shape, &cfg, &cluster);
        cfg.n_a = 4;
        let t4 = CostTable::new(&shape, &cfg, &cluster);
        assert!((t1.fwd / t4.fwd - 4.0).abs() < 1e-9);
        assert!((t1.send_act / t4.send_act - 4.0).abs() < 1e-9);
    }

    #[test]
    fn tensor_all_reduce_charges_the_amortised_c43_time() {
        let (shape, mut cfg, cluster) = setup();
        let t1 = CostTable::new(&shape, &cfg, &cluster);
        // No tensor parallelism: the op is free and moves no bytes.
        assert_eq!(t1.tp_all_reduce_fwd, 0.0);
        assert_eq!(t1.tp_all_reduce_bwd, 0.0);
        assert_eq!(t1.wire.tp_all_reduce_fwd, 0.0);

        cfg.n_a = 4;
        let t4 = CostTable::new(&shape, &cfg, &cluster);
        assert!(t4.tp_all_reduce_fwd > 0.0);
        // 4 backward all-reduces (bwd + recompute) vs 2 forward ones.
        assert!((t4.tp_all_reduce_bwd / t4.tp_all_reduce_fwd - 2.0).abs() < 1e-12);
        let fwd_op = Op::TensorAllReduce { layer: 0, mb: 0, bwd: false };
        let bwd_op = Op::TensorAllReduce { layer: 0, mb: 0, bwd: true };
        assert_eq!(t4.duration(&fwd_op), t4.tp_all_reduce_fwd);
        assert_eq!(t4.duration(&bwd_op), t4.tp_all_reduce_bwd);
        assert!(t4.wire_bytes(&bwd_op) > t4.wire_bytes(&fwd_op));

        // Consistency with the closed form (eq. 12): the six all-reduces
        // of one layer pass cost ν_net/ν_a of the layer's fwd+bwd
        // compute, up to the linear (bias/layernorm) parameter terms the
        // intensity formula drops.
        use crate::costmodel::tensor_parallel_intensity;
        let s = tensor_parallel_intensity(&shape, &cfg);
        let nu_net = cluster.tensor_parallel_link(cfg.n_a).intensity_threshold(&cluster.gpu);
        let measured = (t4.tp_all_reduce_fwd + t4.tp_all_reduce_bwd) / (t4.fwd + t4.bwd);
        let closed = s.overhead(nu_net);
        assert!(
            (measured / closed - 1.0).abs() < 0.01,
            "tp overhead {measured:.5} vs closed form {closed:.5}"
        );
    }

    #[test]
    fn zero_reduce_scatter_plus_gather_equals_all_reduce() {
        let (shape, mut cfg, cluster) = setup();
        cfg.partition = false;
        cfg.strategy = Strategy::Baseline;
        let plain = CostTable::new(&shape, &cfg, &cluster);
        cfg.zero = 2;
        let z = CostTable::new(&shape, &cfg, &cluster);
        // Stage-2 invariant: splitting the all-reduce into its two ring
        // halves moves exactly the same total volume and time.
        let rs = Op::ReduceScatterGrad { layer: 0 };
        let ag = Op::AllGatherParams { layer: 0 };
        let ar = Op::ReduceGrad { layer: 0 };
        assert!(z.wire_bytes(&rs) > 0.0);
        assert!(
            (z.wire_bytes(&rs) + z.wire_bytes(&ag) - plain.wire_bytes(&ar)).abs() < 1e-9,
            "reduce-scatter + all-gather must equal the all-reduce volume"
        );
        assert!((z.duration(&rs) + z.duration(&ag) - plain.duration(&ar)).abs() < 1e-12);
        // Element accounting follows the same convention.
        assert!(
            (z.wire_elements(&rs) + z.wire_elements(&ag) - plain.wire_elements(&ar)).abs() < 1e-9
        );
        // zero = 0 prices the ops at nothing (they are never emitted).
        assert_eq!(plain.wire_bytes(&rs), 0.0);
        assert_eq!(plain.duration(&ag), 0.0);
    }

    #[test]
    fn offload_uses_cpu_link_timing() {
        let (shape, mut cfg, cluster) = setup();
        cfg.offload = true;
        cfg.partition = false;
        cfg.strategy = Strategy::Baseline;
        let t = CostTable::new(&shape, &cfg, &cluster);
        let expect = 2.0 * shape.params_per_layer() / LinkKind::CpuGpu.bandwidth();
        assert!((t.restore_params / expect - 1.0).abs() < 1e-9);
        assert!(t.offload_store > 0.0);
    }
}
