//! Shared seeded PRNG: one audited xorshift64* generator for every
//! subsystem that needs reproducible randomness — chaos fault plans
//! ([`crate::trainer`]'s `repro chaos`) and serving request traces
//! ([`crate::serve`]'s Poisson arrival stream) draw from this exact
//! sequence, so a seed printed in a report replays the run bit-for-bit.
//!
//! The generator is deliberately tiny and fully specified here (no
//! external crates, no global state): an xorshift64* step with a
//! golden-ratio seed scramble, the same recurrence the chaos module
//! originally inlined — extracting it did not change a single drawn
//! value (the chaos determinism tests pin that).

/// Seedable xorshift64* generator.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Xorshift {
    state: u64,
}

impl Xorshift {
    /// Seed the generator. The golden-ratio XOR decorrelates small
    /// consecutive seeds; the all-zero state (the one fixed point of
    /// the recurrence) is remapped to 1.
    pub fn new(seed: u64) -> Self {
        let mut state = seed ^ 0x9e37_79b9_7f4a_7c15;
        if state == 0 {
            state = 1;
        }
        Xorshift { state }
    }

    /// Next raw 64-bit draw (xorshift64* step).
    pub fn next_u64(&mut self) -> u64 {
        let mut x = self.state;
        x ^= x << 13;
        x ^= x >> 7;
        x ^= x << 17;
        self.state = x;
        x.wrapping_mul(0x2545_f491_4f6c_dd1d)
    }

    /// Uniform draw in `[0, 1)` from the top 53 bits (the full f64
    /// mantissa — every representable value in the grid is reachable).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in `[0, n)`; `n = 0` is treated as 1.
    pub fn next_below(&mut self, n: usize) -> usize {
        (self.next_u64() as usize) % n.max(1)
    }

    /// Exponential draw with the given rate (mean `1/rate`) — the
    /// inter-arrival gap of a Poisson process by inverse transform.
    /// The uniform is reflected to `(0, 1]` so the log is finite.
    pub fn next_exp(&mut self, rate: f64) -> f64 {
        let u = 1.0 - self.next_f64();
        -u.ln() / rate
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_seed_same_sequence() {
        let mut a = Xorshift::new(42);
        let mut b = Xorshift::new(42);
        for _ in 0..1000 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_diverge() {
        let mut a = Xorshift::new(1);
        let mut b = Xorshift::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4, "seeds 1 and 2 collide on {same}/64 draws");
    }

    #[test]
    fn zero_seed_is_usable() {
        // seed ^ scramble could in principle hit the xorshift fixed
        // point; the constructor guards it, and seed 0 must still
        // produce a non-degenerate stream.
        let mut r = Xorshift::new(0x9e37_79b9_7f4a_7c15); // maps to state 0 -> 1
        let draws: Vec<u64> = (0..8).map(|_| r.next_u64()).collect();
        assert!(draws.windows(2).any(|w| w[0] != w[1]));
    }

    #[test]
    fn uniform_is_in_range_and_covers_both_halves() {
        let mut r = Xorshift::new(7);
        let draws: Vec<f64> = (0..1000).map(|_| r.next_f64()).collect();
        assert!(draws.iter().all(|u| (0.0..1.0).contains(u)));
        assert!(draws.iter().any(|u| *u < 0.5) && draws.iter().any(|u| *u >= 0.5));
    }

    #[test]
    fn exponential_matches_its_mean() {
        let mut r = Xorshift::new(3);
        let rate = 4.0;
        let n = 20_000;
        let mean: f64 = (0..n).map(|_| r.next_exp(rate)).sum::<f64>() / n as f64;
        assert!(
            (mean * rate - 1.0).abs() < 0.05,
            "exp(rate={rate}) sample mean {mean}, want ~{}",
            1.0 / rate
        );
        let mut r = Xorshift::new(3);
        assert!((0..1000).all(|_| r.next_exp(rate) > 0.0));
    }

    #[test]
    fn next_below_stays_in_range() {
        let mut r = Xorshift::new(9);
        assert!((0..1000).all(|_| r.next_below(7) < 7));
        assert_eq!(r.next_below(0), 0);
        assert_eq!(r.next_below(1), 0);
    }
}
