//! Shard ownership: which data-parallel rank stores (and updates) which
//! slice of each layer's flattened parameter vector.
//!
//! The split matches [`crate::collective::RingGroup`]'s ring chunking so
//! that a reduce-scatter leaves exactly the owned slice fully reduced on
//! its owner, and an all-gather restores the full vector — the
//! partitioned data flow of Figure 2 (bottom).

/// Shard map for one flattened buffer of `len` elements over `n` ranks.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ShardMap {
    pub len: usize,
    pub n: usize,
}

impl ShardMap {
    pub fn new(len: usize, n: usize) -> Self {
        assert!(n >= 1);
        ShardMap { len, n }
    }

    /// Chunk boundaries identical to the ring collective's chunking.
    pub fn range(&self, chunk: usize) -> (usize, usize) {
        let base = self.len / self.n;
        let rem = self.len % self.n;
        let start = chunk * base + chunk.min(rem);
        (start, start + base + usize::from(chunk < rem))
    }

    /// The chunk rank `r` owns after a ring reduce-scatter
    /// (= `RingGroup::owned_chunk`).
    pub fn owned_chunk_of_rank(&self, rank: usize) -> usize {
        (rank + 1) % self.n
    }

    /// The range rank `r` owns.
    pub fn owned_range(&self, rank: usize) -> (usize, usize) {
        self.range(self.owned_chunk_of_rank(rank))
    }

    /// Bytes of fp32 Adam state (12 B/param) rank `r` must hold — the
    /// partitioned "State" column of Table 6.2 at this micro-scale.
    pub fn state_bytes_of_rank(&self, rank: usize) -> usize {
        let (a, b) = self.owned_range(rank);
        12 * (b - a)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ranges_partition_the_buffer() {
        for (len, n) in [(10, 3), (100, 7), (5, 5), (3, 4), (1000, 1)] {
            let m = ShardMap::new(len, n);
            let mut covered = vec![false; len];
            for c in 0..n {
                let (a, b) = m.range(c);
                for item in covered.iter_mut().take(b).skip(a) {
                    assert!(!*item, "overlap at chunk {c}");
                    *item = true;
                }
            }
            assert!(covered.iter().all(|&x| x), "{len}/{n} gap");
        }
    }

    #[test]
    fn owner_map_is_a_bijection() {
        let m = ShardMap::new(100, 8);
        let mut seen = vec![false; 8];
        for r in 0..8 {
            let c = m.owned_chunk_of_rank(r);
            assert!(!seen[c]);
            seen[c] = true;
        }
    }

    #[test]
    fn matches_comm_chunking() {
        use crate::collective::ring_group;
        let comms = ring_group(4);
        let m = ShardMap::new(37, 4);
        for c in &comms {
            assert_eq!(m.owned_range(c.rank), c.owned_range(37));
        }
    }

    #[test]
    fn partitioned_state_is_one_nth() {
        let m = ShardMap::new(1000, 4);
        let total: usize = (0..4).map(|r| m.state_bytes_of_rank(r)).sum();
        assert_eq!(total, 12 * 1000);
    }
}
