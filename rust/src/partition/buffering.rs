//! Mixed parameter/gradient buffering (paper Appendix C.2, Table C.1).
//!
//! With a partitioned or offloaded state, fp16 working copies of a
//! layer's parameters live in transient buffers. The *mixed* method uses
//! two parameter buffers (so the next layer's restore overlaps the
//! current layer's compute — double buffering) and a single gradient
//! buffer (the reduce of layer i overlaps the gradient compute of layer
//! i−1). This module is the state machine enforcing those invariants;
//! the trainer drives it and the memory accounting reads its high-water
//! marks.

/// Buffer classes of Table C.1.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum BufferKind {
    Param,
    Grad,
}

/// The mixed-buffering state machine.
#[derive(Debug, Clone)]
pub struct MixedBuffering {
    /// Layers currently holding a parameter buffer.
    param_holders: Vec<usize>,
    /// Layer currently holding the gradient buffer, if any.
    grad_holder: Option<usize>,
    max_params: usize,
    peak_params: usize,
}

impl Default for MixedBuffering {
    fn default() -> Self {
        Self::new()
    }
}

impl MixedBuffering {
    pub fn new() -> Self {
        MixedBuffering { param_holders: Vec::new(), grad_holder: None, max_params: 2, peak_params: 0 }
    }

    /// Acquire a parameter buffer for `layer` (restore starting).
    /// Errors when both buffers are held — the schedule violated the
    /// double-buffering depth.
    pub fn acquire_param(&mut self, layer: usize) -> Result<(), String> {
        if self.param_holders.contains(&layer) {
            return Err(format!("layer {layer} already holds a param buffer"));
        }
        if self.param_holders.len() >= self.max_params {
            return Err(format!(
                "param buffers exhausted (held by {:?}, wanted {layer})",
                self.param_holders
            ));
        }
        self.param_holders.push(layer);
        self.peak_params = self.peak_params.max(self.param_holders.len());
        Ok(())
    }

    /// Release `layer`'s parameter buffer (compute finished with it).
    pub fn release_param(&mut self, layer: usize) -> Result<(), String> {
        match self.param_holders.iter().position(|&l| l == layer) {
            Some(i) => {
                self.param_holders.remove(i);
                Ok(())
            }
            None => Err(format!("layer {layer} holds no param buffer")),
        }
    }

    /// Acquire the single gradient buffer.
    pub fn acquire_grad(&mut self, layer: usize) -> Result<(), String> {
        if let Some(h) = self.grad_holder {
            return Err(format!("grad buffer busy (layer {h}, wanted {layer})"));
        }
        self.grad_holder = Some(layer);
        Ok(())
    }

    /// Release the gradient buffer (reduce finished).
    pub fn release_grad(&mut self, layer: usize) -> Result<(), String> {
        if self.grad_holder == Some(layer) {
            self.grad_holder = None;
            Ok(())
        } else {
            Err(format!("grad buffer not held by layer {layer}"))
        }
    }

    /// High-water mark of simultaneously-held parameter buffers.
    pub fn peak_param_buffers(&self) -> usize {
        self.peak_params
    }

    /// Total transient buffer bytes for a layer of `p_l` parameters,
    /// fp16: 2 param + 1 grad buffers = 6·p_l (C.3).
    pub fn buffer_bytes(p_l: f64) -> f64 {
        6.0 * p_l
    }
}

/// Drive the state machine through one backward pass in the Table C.1
/// order, verifying the schedule respects the buffer depths. Returns the
/// peak parameter-buffer count.
pub fn simulate_backward_pass(layers: usize) -> Result<usize, String> {
    let mut mb = MixedBuffering::new();
    // Prologue: restore the last layer.
    mb.acquire_param(layers - 1)?;
    for l in (0..layers).rev() {
        // Restore(l-1) overlaps Gradients(l): second param buffer.
        if l > 0 {
            mb.acquire_param(l - 1)?;
        }
        // Gradients(l) into the grad buffer.
        mb.acquire_grad(l)?;
        // Activations/recompute(l) overlaps Reduce(l): grad buffer
        // released once the reduce drains, param buffer after use.
        mb.release_param(l)?;
        mb.release_grad(l)?;
    }
    Ok(mb.peak_param_buffers())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn backward_pass_fits_in_two_param_buffers() {
        // Table C.1: the whole pass runs within 2 param + 1 grad buffers.
        let peak = simulate_backward_pass(8).unwrap();
        assert_eq!(peak, 2);
    }

    #[test]
    fn triple_buffering_is_rejected() {
        let mut mb = MixedBuffering::new();
        mb.acquire_param(0).unwrap();
        mb.acquire_param(1).unwrap();
        assert!(mb.acquire_param(2).is_err());
    }

    #[test]
    fn grad_buffer_is_exclusive() {
        let mut mb = MixedBuffering::new();
        mb.acquire_grad(3).unwrap();
        assert!(mb.acquire_grad(2).is_err());
        mb.release_grad(3).unwrap();
        mb.acquire_grad(2).unwrap();
    }

    #[test]
    fn double_release_is_an_error() {
        let mut mb = MixedBuffering::new();
        mb.acquire_param(0).unwrap();
        mb.release_param(0).unwrap();
        assert!(mb.release_param(0).is_err());
        assert!(mb.release_grad(0).is_err());
    }

    #[test]
    fn buffer_bytes_matches_c3() {
        assert_eq!(MixedBuffering::buffer_bytes(1000.0), 6000.0);
    }
}
