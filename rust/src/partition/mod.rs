//! ZeRO-3-style training-state partition bookkeeping (paper §2.4/§2.5 and
//! Appendix C.2): shard ownership across the data-parallel group and the
//! mixed parameter/gradient buffering state machine of Table C.1.

pub mod buffering;
pub mod owner;

pub use buffering::{BufferKind, MixedBuffering};
pub use owner::ShardMap;
