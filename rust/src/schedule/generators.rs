//! Schedule generators: the four policies compared in Figures 1–3, plus
//! 1F1B and interleaved 1F1B (the Megatron-LM baseline of §4) as
//! ablation comparators.
//!
//! All generators emit one batch worth of ops. Conventions:
//! * `RecvAct`/`SendAct` appear only at stage boundaries (the producing
//!   stage sends, the consuming stage receives);
//! * with `partition` or `offload`, `RestoreParams { layer }` precedes the
//!   first use of a layer in each pass, and is re-issued *per micro-batch*
//!   in the standard schedules (the redundancy Figure 2 shows LGA
//!   eliminating);
//! * with `offload`, `OffloadStore { layer }` follows the layer's
//!   `OptimStep`: the post-step state streams back out once per layer per
//!   batch (the §8.2 real-time checkpoint), in every policy — it is the
//!   *restores* where standard accumulation pays the per-micro-batch
//!   pathology;
//! * `ReduceGrad { layer }` is issued as soon as the layer's gradient is
//!   complete: after the last micro-batch of that layer's backward;
//! * with `tp > 1`, `TensorAllReduce { layer, mb, bwd }` follows every
//!   `Fwd`/`Bwd` (before the corresponding send): the six per-layer
//!   tensor-parallel all-reduces of C.4.3, amortised into one op per
//!   phase, in every policy — the modular pipeline's claim is that these
//!   amortise over the per-layer transfers it already makes.

use super::ir::{LayerAssignment, Op, Schedule};

/// Parameters shared by all generators.
#[derive(Debug, Clone, Copy)]
pub struct ScheduleSpec {
    /// Total layers d_l (must be divisible by n_l).
    pub d_l: usize,
    /// Pipeline stages n_l.
    pub n_l: usize,
    /// Micro-batches n_μ.
    pub n_mu: usize,
    /// Tensor-parallel degree n_a (1 = off). When `tp > 1` every layer
    /// pass carries an amortised `TensorAllReduce` op — the six
    /// Megatron-style all-reduces of C.4.3 bunched into one op per
    /// (layer, micro-batch) phase: 2 forward, 4 backward (recompute
    /// included).
    pub tp: usize,
    /// Whether the training state is partitioned (emit RestoreParams +
    /// per-layer reduce-scatter semantics).
    pub partition: bool,
    /// Whether the training state is offloaded to an external tier (emit
    /// RestoreParams before use and OffloadStore after each OptimStep —
    /// the §8.2 real-time checkpoint path).
    pub offload: bool,
    /// Whether to emit data-parallel ReduceGrad ops (n_b > 1).
    pub data_parallel: bool,
    /// ZeRO stage (0–3, Rajbhandari et al. 1910.02054) over the
    /// data-parallel group. Stage ≥1 shards Adam state 1/dp and emits a
    /// post-step `AllGatherParams` per layer; stage ≥2 additionally
    /// replaces `ReduceGrad` with `ReduceScatterGrad`; stage 3 moves the
    /// gather to before each use (FSDP-style) and drops the post-step
    /// one. Mutually exclusive with `partition` (the paper's modular
    /// state partition is the comparison baseline, not a composition).
    pub zero: u8,
}

impl ScheduleSpec {
    /// Whether `RestoreParams` ops are emitted: a partitioned state needs
    /// an all-gather before use, an offloaded one a CPU-link fetch —
    /// either way the parameters must be staged.
    pub fn restores(&self) -> bool {
        self.partition || self.offload
    }

    /// The dp gradient-reduction op for one layer: a plain ring
    /// all-reduce, or a reduce-scatter when ZeRO stage ≥2 leaves each dp
    /// rank owning only its 1/dp slice of the reduced gradient.
    pub fn reduce_op(&self, layer: usize) -> Op {
        if self.data_parallel && self.zero >= 2 {
            Op::ReduceScatterGrad { layer }
        } else {
            Op::ReduceGrad { layer }
        }
    }

    /// Whether generators emit one post-step `AllGatherParams` per layer
    /// (ZeRO stages 1–2 rebuild full params right after the sharded
    /// optimizer update).
    pub fn zero_gathers_post_step(&self) -> bool {
        self.data_parallel && (self.zero == 1 || self.zero == 2)
    }

    /// Whether generators emit `AllGatherParams` before each use of a
    /// layer (ZeRO stage 3 / FSDP gather-before-use) — the same emission
    /// points as `RestoreParams`, so standard accumulation pays the
    /// Figure 2 per-micro-batch gather pathology here too.
    pub fn zero_gathers_before_use(&self) -> bool {
        self.data_parallel && self.zero == 3
    }

    pub fn validate(&self) -> Result<(), String> {
        if self.n_l == 0 || self.d_l == 0 || self.n_mu == 0 || self.tp == 0 {
            return Err("zero dimension".into());
        }
        if self.d_l % self.n_l != 0 {
            return Err(format!("d_l = {} not divisible by n_l = {}", self.d_l, self.n_l));
        }
        if self.n_mu < self.n_l {
            return Err(format!("n_mu = {} < n_l = {} starves the pipeline", self.n_mu, self.n_l));
        }
        if self.zero > 3 {
            return Err(format!("zero = {} out of range (ZeRO stages are 0-3)", self.zero));
        }
        if self.zero > 0 && self.partition {
            return Err(
                "ZeRO sharding and the modular state partition are mutually exclusive".into()
            );
        }
        Ok(())
    }
}

/// Standard gradient accumulation (Figure 1 top, single stage; GPipe-style
/// when n_l > 1 — Figure 3 top with contiguous layer chunks).
///
/// Each micro-batch runs through all local layers before the next starts.
/// With a partition, every (layer, micro-batch) pair needs its own
/// parameter restoration — the bandwidth pathology of Figure 2 (top).
pub fn standard_ga(spec: &ScheduleSpec) -> Schedule {
    spec.validate().expect("invalid schedule spec");
    let assignment = LayerAssignment::Contiguous;
    let mut ops: Vec<Vec<Op>> = vec![Vec::new(); spec.n_l];
    for (stage, stage_ops) in ops.iter_mut().enumerate() {
        let layers = assignment.layers_of(stage, spec.d_l, spec.n_l);
        // Forward: every micro-batch through the whole local chunk.
        for mb in 0..spec.n_mu {
            for &l in &layers {
                if spec.restores() {
                    stage_ops.push(Op::RestoreParams { layer: l });
                }
                if spec.zero_gathers_before_use() {
                    stage_ops.push(Op::AllGatherParams { layer: l });
                }
                if l > 0 && assignment.stage_of(l - 1, spec.d_l, spec.n_l) != stage {
                    stage_ops.push(Op::RecvAct { layer: l, mb });
                }
                stage_ops.push(Op::Fwd { layer: l, mb });
                if spec.tp > 1 {
                    stage_ops.push(Op::TensorAllReduce { layer: l, mb, bwd: false });
                }
                if l + 1 < spec.d_l && assignment.stage_of(l + 1, spec.d_l, spec.n_l) != stage {
                    stage_ops.push(Op::SendAct { layer: l, mb });
                }
            }
        }
        // Backward: micro-batches in order, layers reversed.
        for mb in 0..spec.n_mu {
            for &l in layers.iter().rev() {
                if spec.restores() {
                    stage_ops.push(Op::RestoreParams { layer: l });
                }
                if spec.zero_gathers_before_use() {
                    stage_ops.push(Op::AllGatherParams { layer: l });
                }
                if l + 1 < spec.d_l && assignment.stage_of(l + 1, spec.d_l, spec.n_l) != stage {
                    stage_ops.push(Op::RecvGrad { layer: l, mb });
                }
                stage_ops.push(Op::Bwd { layer: l, mb });
                if spec.tp > 1 {
                    stage_ops.push(Op::TensorAllReduce { layer: l, mb, bwd: true });
                }
                if l > 0 && assignment.stage_of(l - 1, spec.d_l, spec.n_l) != stage {
                    stage_ops.push(Op::SendGrad { layer: l, mb });
                }
                // Gradient complete only after the last micro-batch:
                // the reduction bunches at the end (Figure 1 top).
                if mb + 1 == spec.n_mu && (spec.data_parallel || spec.partition) {
                    stage_ops.push(spec.reduce_op(l));
                }
            }
        }
        // Optimizer steps go last: they depend on the reductions but must
        // not block the remaining backward computes (an in-order executor
        // would otherwise serialise the reductions into the compute
        // stream). With offload, each layer's post-step state streams out
        // right after its update (once per layer — the store side has no
        // per-micro-batch redundancy even here).
        for &l in &layers {
            stage_ops.push(Op::OptimStep { layer: l });
            if spec.offload {
                stage_ops.push(Op::OffloadStore { layer: l });
            }
            if spec.zero_gathers_post_step() {
                stage_ops.push(Op::AllGatherParams { layer: l });
            }
        }
    }
    Schedule {
        name: if spec.n_l > 1 { "standard-pipeline".into() } else { "standard-ga".into() },
        n_stages: spec.n_l,
        d_l: spec.d_l,
        n_mu: spec.n_mu,
        assignment,
        ops,
        tp: spec.tp,
        partitioned: spec.partition,
        offloaded: spec.offload,
        zero: spec.zero,
    }
}

/// Layered gradient accumulation (Figure 1 bottom; §3): all micro-batches
/// of a layer before the next layer. Single-stage only — combining LGA
/// with a pipeline requires the modular split (§3 last paragraph), which
/// is [`modular_pipeline`].
pub fn layered_ga(spec: &ScheduleSpec) -> Schedule {
    assert_eq!(spec.n_l, 1, "layered GA without modular split is single-stage (§3)");
    spec.validate().expect("invalid schedule spec");
    let mut ops = vec![Vec::new()];
    let stage_ops = &mut ops[0];
    for l in 0..spec.d_l {
        if spec.restores() {
            stage_ops.push(Op::RestoreParams { layer: l }); // once per layer!
        }
        if spec.zero_gathers_before_use() {
            stage_ops.push(Op::AllGatherParams { layer: l }); // once per layer!
        }
        for mb in 0..spec.n_mu {
            stage_ops.push(Op::Fwd { layer: l, mb });
            if spec.tp > 1 {
                stage_ops.push(Op::TensorAllReduce { layer: l, mb, bwd: false });
            }
        }
    }
    for l in (0..spec.d_l).rev() {
        if spec.restores() {
            stage_ops.push(Op::RestoreParams { layer: l });
        }
        if spec.zero_gathers_before_use() {
            stage_ops.push(Op::AllGatherParams { layer: l });
        }
        for mb in 0..spec.n_mu {
            stage_ops.push(Op::Bwd { layer: l, mb });
            if spec.tp > 1 {
                stage_ops.push(Op::TensorAllReduce { layer: l, mb, bwd: true });
            }
        }
        // Gradient for layer l is complete here — the reduction spreads
        // over the whole backward pass (Figure 1 bottom).
        if spec.data_parallel || spec.partition {
            stage_ops.push(spec.reduce_op(l));
        }
    }
    for l in 0..spec.d_l {
        stage_ops.push(Op::OptimStep { layer: l });
        if spec.offload {
            stage_ops.push(Op::OffloadStore { layer: l });
        }
        if spec.zero_gathers_post_step() {
            stage_ops.push(Op::AllGatherParams { layer: l });
        }
    }
    Schedule {
        name: "layered-ga".into(),
        n_stages: 1,
        d_l: spec.d_l,
        n_mu: spec.n_mu,
        assignment: LayerAssignment::Contiguous,
        ops,
        tp: spec.tp,
        partitioned: spec.partition,
        offloaded: spec.offload,
        zero: spec.zero,
    }
}

/// Modular pipeline parallelism (Figure 3 bottom; §4): layers are assigned
/// round-robin and each stage processes all micro-batches of a layer
/// before moving to its next layer (layered scheduling). A micro-batch
/// reaches the last stage after n_l − 1 single layers instead of
/// d_l·(1 − 1/n_l), shrinking the bubble by d_l/n_l.
pub fn modular_pipeline(spec: &ScheduleSpec) -> Schedule {
    spec.validate().expect("invalid schedule spec");
    let assignment = LayerAssignment::Modular;
    let mut ops: Vec<Vec<Op>> = vec![Vec::new(); spec.n_l];
    for (stage, stage_ops) in ops.iter_mut().enumerate() {
        let layers = assignment.layers_of(stage, spec.d_l, spec.n_l);
        for &l in &layers {
            if spec.restores() {
                stage_ops.push(Op::RestoreParams { layer: l }); // once per layer
            }
            if spec.zero_gathers_before_use() {
                stage_ops.push(Op::AllGatherParams { layer: l }); // once per layer
            }
            for mb in 0..spec.n_mu {
                if l > 0 {
                    stage_ops.push(Op::RecvAct { layer: l, mb });
                }
                stage_ops.push(Op::Fwd { layer: l, mb });
                if spec.tp > 1 {
                    // The C.4.3 amortisation claim in op form: the tp
                    // all-reduce rides the same per-layer cadence as the
                    // modular pipeline's boundary transfer.
                    stage_ops.push(Op::TensorAllReduce { layer: l, mb, bwd: false });
                }
                if l + 1 < spec.d_l {
                    stage_ops.push(Op::SendAct { layer: l, mb });
                }
            }
        }
        for &l in layers.iter().rev() {
            if spec.restores() {
                stage_ops.push(Op::RestoreParams { layer: l });
            }
            if spec.zero_gathers_before_use() {
                stage_ops.push(Op::AllGatherParams { layer: l });
            }
            for mb in 0..spec.n_mu {
                if l + 1 < spec.d_l {
                    stage_ops.push(Op::RecvGrad { layer: l, mb });
                }
                stage_ops.push(Op::Bwd { layer: l, mb });
                if spec.tp > 1 {
                    stage_ops.push(Op::TensorAllReduce { layer: l, mb, bwd: true });
                }
                if l > 0 {
                    stage_ops.push(Op::SendGrad { layer: l, mb });
                }
            }
            if spec.data_parallel || spec.partition {
                stage_ops.push(spec.reduce_op(l));
            }
        }
        for &l in &layers {
            stage_ops.push(Op::OptimStep { layer: l });
            if spec.offload {
                stage_ops.push(Op::OffloadStore { layer: l });
            }
            if spec.zero_gathers_post_step() {
                stage_ops.push(Op::AllGatherParams { layer: l });
            }
        }
    }
    Schedule {
        name: "modular-pipeline".into(),
        n_stages: spec.n_l,
        d_l: spec.d_l,
        n_mu: spec.n_mu,
        assignment,
        ops,
        tp: spec.tp,
        partitioned: spec.partition,
        offloaded: spec.offload,
        zero: spec.zero,
    }
}

/// 1F1B (PipeDream-flush) over contiguous chunks — the scheduling used by
/// Megatron-LM, included as an ablation comparator. Same bubble as GPipe
/// but bounded activation memory (at most n_l in-flight micro-batches per
/// stage).
pub fn one_f_one_b(spec: &ScheduleSpec) -> Schedule {
    spec.validate().expect("invalid schedule spec");
    let assignment = LayerAssignment::Contiguous;
    let n_l = spec.n_l;
    let mut ops: Vec<Vec<Op>> = vec![Vec::new(); n_l];
    for (stage, stage_ops) in ops.iter_mut().enumerate() {
        let layers = assignment.layers_of(stage, spec.d_l, n_l);
        let warmup = (n_l - 1 - stage).min(spec.n_mu);
        let mut emitted_f = 0usize;
        let mut emitted_b = 0usize;
        let fwd_chunk = |stage_ops: &mut Vec<Op>, mb: usize| {
            for &l in &layers {
                if spec.restores() {
                    stage_ops.push(Op::RestoreParams { layer: l });
                }
                if spec.zero_gathers_before_use() {
                    stage_ops.push(Op::AllGatherParams { layer: l });
                }
                if l > 0 && assignment.stage_of(l - 1, spec.d_l, n_l) != stage {
                    stage_ops.push(Op::RecvAct { layer: l, mb });
                }
                stage_ops.push(Op::Fwd { layer: l, mb });
                if spec.tp > 1 {
                    stage_ops.push(Op::TensorAllReduce { layer: l, mb, bwd: false });
                }
                if l + 1 < spec.d_l && assignment.stage_of(l + 1, spec.d_l, n_l) != stage {
                    stage_ops.push(Op::SendAct { layer: l, mb });
                }
            }
        };
        let bwd_chunk = |stage_ops: &mut Vec<Op>, mb: usize, last: bool, restore: bool, dp: bool| {
            for &l in layers.iter().rev() {
                if restore {
                    stage_ops.push(Op::RestoreParams { layer: l });
                }
                if spec.zero_gathers_before_use() {
                    stage_ops.push(Op::AllGatherParams { layer: l });
                }
                if l + 1 < spec.d_l && assignment.stage_of(l + 1, spec.d_l, n_l) != stage {
                    stage_ops.push(Op::RecvGrad { layer: l, mb });
                }
                stage_ops.push(Op::Bwd { layer: l, mb });
                if spec.tp > 1 {
                    stage_ops.push(Op::TensorAllReduce { layer: l, mb, bwd: true });
                }
                if l > 0 && assignment.stage_of(l - 1, spec.d_l, n_l) != stage {
                    stage_ops.push(Op::SendGrad { layer: l, mb });
                }
                if last && (dp || spec.partition) {
                    stage_ops.push(spec.reduce_op(l));
                }
            }
        };
        // Warmup forwards.
        for _ in 0..warmup {
            fwd_chunk(stage_ops, emitted_f);
            emitted_f += 1;
        }
        // Steady 1F1B.
        while emitted_b < spec.n_mu {
            if emitted_f < spec.n_mu {
                fwd_chunk(stage_ops, emitted_f);
                emitted_f += 1;
            }
            let last = emitted_b + 1 == spec.n_mu;
            bwd_chunk(stage_ops, emitted_b, last, spec.restores(), spec.data_parallel);
            emitted_b += 1;
        }
        for &l in &layers {
            stage_ops.push(Op::OptimStep { layer: l });
            if spec.offload {
                stage_ops.push(Op::OffloadStore { layer: l });
            }
            if spec.zero_gathers_post_step() {
                stage_ops.push(Op::AllGatherParams { layer: l });
            }
        }
    }
    Schedule {
        name: "1f1b".into(),
        n_stages: n_l,
        d_l: spec.d_l,
        n_mu: spec.n_mu,
        assignment,
        ops,
        tp: spec.tp,
        partitioned: spec.partition,
        offloaded: spec.offload,
        zero: spec.zero,
    }
}

/// The interleaved-1F1B preconditions, with a message per failure: d_l
/// must divide into n_l·chunks blocks and n_mu must be divisible by n_l
/// (the Megatron-LM constraint on interleaved scheduling). The one
/// source of truth — [`interleaved_applicable`] and the generator's
/// panic path both delegate here.
fn interleaved_check(spec: &ScheduleSpec, chunks: usize) -> Result<(), String> {
    if chunks < 1 {
        return Err("chunks (v) must be at least 1".into());
    }
    spec.validate()?;
    if spec.d_l % (spec.n_l * chunks) != 0 {
        return Err(format!(
            "d_l = {} must divide into n_l * chunks = {} blocks",
            spec.d_l,
            spec.n_l * chunks
        ));
    }
    if spec.n_mu % spec.n_l != 0 {
        return Err(format!(
            "interleaved 1F1B needs n_mu = {} divisible by n_l = {}",
            spec.n_mu, spec.n_l
        ));
    }
    Ok(())
}

/// Whether [`interleaved_1f1b`] accepts a spec with this chunk count —
/// for call sites that conditionally include the interleaved policy.
pub fn interleaved_applicable(spec: &ScheduleSpec, chunks: usize) -> bool {
    interleaved_check(spec, chunks).is_ok()
}

/// Interleaved 1F1B (Megatron-LM's virtual-stage schedule, Narayanan et
/// al. 2021) — the strongest published baseline the paper compares
/// against in §4. Each stage owns `chunks` (v) non-contiguous blocks of
/// d_l/(n_l·v) layers; micro-batches advance through the blocks in
/// groups of n_l, shrinking the bubble by the factor v at the price of
/// v× more pipeline traffic. Modular pipelining is the v = d_l/n_l
/// limit of this family combined with layered accumulation.
///
/// Requires [`interleaved_applicable`] — panics otherwise.
pub fn interleaved_1f1b(spec: &ScheduleSpec, chunks: usize) -> Schedule {
    interleaved_check(spec, chunks).unwrap_or_else(|e| panic!("{e}"));
    let assignment = LayerAssignment::Interleaved { chunks };
    let n_l = spec.n_l;
    let v = chunks;
    let block = spec.d_l / (n_l * v);
    // Virtual iterations per stage: every micro-batch visits every chunk.
    let total = spec.n_mu * v;

    // Iteration -> (chunk, micro-batch): micro-batches advance in groups
    // of n_l; within a group the stage sweeps chunk 0..v forward (and
    // v-1..0 backward).
    let fwd_of = |k: usize| -> (usize, usize) {
        let group = k / (n_l * v);
        let within = k % (n_l * v);
        (within / n_l, group * n_l + within % n_l)
    };
    let bwd_of = |k: usize| -> (usize, usize) {
        let group = k / (n_l * v);
        let within = k % (n_l * v);
        (v - 1 - within / n_l, group * n_l + within % n_l)
    };

    let mut ops: Vec<Vec<Op>> = vec![Vec::new(); n_l];
    for (stage, stage_ops) in ops.iter_mut().enumerate() {
        let chunk_base = |c: usize| (c * n_l + stage) * block;
        let emit_fwd = |stage_ops: &mut Vec<Op>, c: usize, mb: usize| {
            for l in chunk_base(c)..chunk_base(c) + block {
                if spec.restores() {
                    stage_ops.push(Op::RestoreParams { layer: l });
                }
                if spec.zero_gathers_before_use() {
                    stage_ops.push(Op::AllGatherParams { layer: l });
                }
                if l > 0 && assignment.stage_of(l - 1, spec.d_l, n_l) != stage {
                    stage_ops.push(Op::RecvAct { layer: l, mb });
                }
                stage_ops.push(Op::Fwd { layer: l, mb });
                if spec.tp > 1 {
                    stage_ops.push(Op::TensorAllReduce { layer: l, mb, bwd: false });
                }
                if l + 1 < spec.d_l && assignment.stage_of(l + 1, spec.d_l, n_l) != stage {
                    stage_ops.push(Op::SendAct { layer: l, mb });
                }
            }
        };
        let mut bwd_done = vec![0usize; spec.d_l];
        let mut emit_bwd = |stage_ops: &mut Vec<Op>, c: usize, mb: usize| {
            for l in (chunk_base(c)..chunk_base(c) + block).rev() {
                if spec.restores() {
                    stage_ops.push(Op::RestoreParams { layer: l });
                }
                if spec.zero_gathers_before_use() {
                    stage_ops.push(Op::AllGatherParams { layer: l });
                }
                if l + 1 < spec.d_l && assignment.stage_of(l + 1, spec.d_l, n_l) != stage {
                    stage_ops.push(Op::RecvGrad { layer: l, mb });
                }
                stage_ops.push(Op::Bwd { layer: l, mb });
                if spec.tp > 1 {
                    stage_ops.push(Op::TensorAllReduce { layer: l, mb, bwd: true });
                }
                if l > 0 && assignment.stage_of(l - 1, spec.d_l, n_l) != stage {
                    stage_ops.push(Op::SendGrad { layer: l, mb });
                }
                bwd_done[l] += 1;
                // Gradient complete after the layer's last micro-batch.
                if bwd_done[l] == spec.n_mu && (spec.data_parallel || spec.partition) {
                    stage_ops.push(spec.reduce_op(l));
                }
            }
        };

        // Megatron-LM warmup depth: enough in-flight micro-batches to keep
        // every virtual stage fed.
        let warmup = ((n_l - 1 - stage) * 2 + (v - 1) * n_l).min(total);
        let mut ef = 0usize;
        let mut eb = 0usize;
        for _ in 0..warmup {
            let (c, mb) = fwd_of(ef);
            emit_fwd(stage_ops, c, mb);
            ef += 1;
        }
        // Steady 1F1B over virtual iterations, then cooldown backwards.
        while eb < total {
            if ef < total {
                let (c, mb) = fwd_of(ef);
                emit_fwd(stage_ops, c, mb);
                ef += 1;
            }
            let (c, mb) = bwd_of(eb);
            emit_bwd(stage_ops, c, mb);
            eb += 1;
        }
        for c in 0..v {
            for l in chunk_base(c)..chunk_base(c) + block {
                stage_ops.push(Op::OptimStep { layer: l });
                if spec.offload {
                    stage_ops.push(Op::OffloadStore { layer: l });
                }
                if spec.zero_gathers_post_step() {
                    stage_ops.push(Op::AllGatherParams { layer: l });
                }
            }
        }
    }
    Schedule {
        name: "interleaved-1f1b".into(),
        n_stages: n_l,
        d_l: spec.d_l,
        n_mu: spec.n_mu,
        assignment,
        ops,
        tp: spec.tp,
        partitioned: spec.partition,
        offloaded: spec.offload,
        zero: spec.zero,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn spec(d_l: usize, n_l: usize, n_mu: usize, partition: bool) -> ScheduleSpec {
        ScheduleSpec {
            d_l,
            n_l,
            n_mu,
            tp: 1,
            partition,
            offload: false,
            data_parallel: true,
            zero: 0,
        }
    }

    fn count_gather(s: &Schedule) -> usize {
        s.count(|o| matches!(o, Op::AllGatherParams { .. }))
    }

    fn count_fwd(s: &Schedule) -> usize {
        s.count(|o| matches!(o, Op::Fwd { .. }))
    }

    fn count_restore(s: &Schedule) -> usize {
        s.count(|o| matches!(o, Op::RestoreParams { .. }))
    }

    fn count_store(s: &Schedule) -> usize {
        s.count(|o| matches!(o, Op::OffloadStore { .. }))
    }

    #[test]
    fn all_generators_emit_every_fwd_bwd_pair() {
        let sp = spec(8, 4, 8, false);
        for s in [standard_ga(&sp), modular_pipeline(&sp), one_f_one_b(&sp)] {
            assert_eq!(count_fwd(&s), 8 * 8, "{}", s.name);
            assert_eq!(s.count(|o| matches!(o, Op::Bwd { .. })), 8 * 8, "{}", s.name);
            assert_eq!(s.count(|o| matches!(o, Op::ReduceGrad { .. })), 8, "{}", s.name);
            assert_eq!(s.count(|o| matches!(o, Op::OptimStep { .. })), 8, "{}", s.name);
        }
        let single = spec(8, 1, 8, false);
        for s in [standard_ga(&single), layered_ga(&single)] {
            assert_eq!(count_fwd(&s), 8 * 8, "{}", s.name);
        }
    }

    #[test]
    fn lga_restores_each_layer_twice_standard_restores_per_microbatch() {
        // Figure 2: with a partitioned state, standard GA restores a
        // layer's parameters for every micro-batch (2·d_l·n_μ restores),
        // LGA only once per pass (2·d_l).
        let sp = spec(6, 1, 10, true);
        let std_s = standard_ga(&sp);
        let lga_s = layered_ga(&sp);
        assert_eq!(count_restore(&std_s), 2 * 6 * 10);
        assert_eq!(count_restore(&lga_s), 2 * 6);
    }

    #[test]
    fn modular_pipeline_keeps_lga_restore_economy() {
        let sp = spec(8, 4, 8, true);
        let s = modular_pipeline(&sp);
        // Each of the 8 layers restored once per pass, twice total.
        assert_eq!(count_restore(&s), 2 * 8);
    }

    #[test]
    fn offload_only_specs_emit_restores_and_stores() {
        // §8.2: with `offload` (and no partition) the state still has to
        // be staged before use and streamed back after the update — an
        // offload-only spec must not degenerate to a no-op schedule.
        let mut sp = spec(8, 4, 8, false);
        sp.offload = true;
        for s in [standard_ga(&sp), modular_pipeline(&sp), one_f_one_b(&sp)] {
            assert!(count_restore(&s) > 0, "{}", s.name);
            // Exactly one post-step store per layer, every policy.
            assert_eq!(count_store(&s), 8, "{}", s.name);
            assert!(s.offloaded && !s.partitioned, "{}", s.name);
        }
        assert_eq!(count_store(&interleaved_1f1b(&sp, 2)), 8);
        let mut single = spec(8, 1, 8, false);
        single.offload = true;
        for s in [standard_ga(&single), layered_ga(&single)] {
            assert_eq!(count_store(&s), 8, "{}", s.name);
        }
    }

    #[test]
    fn offload_restores_keep_figure2_shape() {
        // The restore side keeps Figure 2's asymmetry on the offload
        // path: standard GA re-fetches per micro-batch (2·d_l·n_μ), LGA
        // and the modular pipeline once per layer per pass (2·d_l).
        let mut single = spec(6, 1, 10, false);
        single.offload = true;
        assert_eq!(count_restore(&standard_ga(&single)), 2 * 6 * 10);
        assert_eq!(count_restore(&layered_ga(&single)), 2 * 6);
        let mut piped = spec(8, 4, 8, false);
        piped.offload = true;
        assert_eq!(count_restore(&modular_pipeline(&piped)), 2 * 8);
    }

    #[test]
    fn offload_stores_follow_their_optim_step() {
        let mut sp = spec(8, 4, 8, true);
        sp.offload = true;
        let s = modular_pipeline(&sp);
        for (stage, ops) in s.ops.iter().enumerate() {
            for &l in &s.assignment.layers_of(stage, 8, 4) {
                let u = ops.iter().position(|o| *o == Op::OptimStep { layer: l }).unwrap();
                let o = ops.iter().position(|o| *o == Op::OffloadStore { layer: l }).unwrap();
                assert!(u < o, "stage {stage} layer {l}");
            }
        }
    }

    #[test]
    fn non_offload_specs_emit_no_offload_ops() {
        for sp in [spec(8, 4, 8, false), spec(8, 4, 8, true)] {
            for s in [standard_ga(&sp), modular_pipeline(&sp), one_f_one_b(&sp)] {
                assert_eq!(count_store(&s), 0, "{}", s.name);
            }
        }
    }

    #[test]
    fn standard_ga_reduces_only_after_last_microbatch() {
        // All ReduceGrad ops must sit after the final Bwd of their layer
        // AND after the final Bwd of the last micro-batch index.
        let sp = spec(4, 1, 6, false);
        let s = standard_ga(&sp);
        let ops = &s.ops[0];
        let first_reduce = ops.iter().position(|o| matches!(o, Op::ReduceGrad { .. })).unwrap();
        let bwds_before: usize = ops[..first_reduce]
            .iter()
            .filter(|o| matches!(o, Op::Bwd { mb, .. } if *mb + 1 < 6))
            .count();
        // Every non-final micro-batch backward happens before any
        // reduction: the reduction window is only the last micro-batch.
        assert_eq!(bwds_before, 4 * 5);
    }

    #[test]
    fn layered_ga_interleaves_reduction_with_backward() {
        // In LGA the first reduction (last layer) happens after only
        // n_μ backward ops — the reduction is spread across the pass.
        let sp = spec(4, 1, 6, false);
        let s = layered_ga(&sp);
        let ops = &s.ops[0];
        let first_reduce = ops.iter().position(|o| matches!(o, Op::ReduceGrad { .. })).unwrap();
        let bwds_before =
            ops[..first_reduce].iter().filter(|o| matches!(o, Op::Bwd { .. })).count();
        assert_eq!(bwds_before, 6, "reduction of the last layer right after its n_mu bwd ops");
    }

    #[test]
    fn modular_sends_after_every_layer_contiguous_after_chunks() {
        let sp = spec(16, 4, 8, false);
        let modular = modular_pipeline(&sp);
        let contiguous = standard_ga(&sp);
        let sends = |s: &Schedule| s.count(|o| matches!(o, Op::SendAct { .. }));
        // Modular: every layer except the last sends, for every mb.
        assert_eq!(sends(&modular), 15 * 8);
        // Contiguous: only 3 chunk boundaries send.
        assert_eq!(sends(&contiguous), 3 * 8);
    }

    #[test]
    fn one_f_one_b_matches_fwd_bwd_counts_and_orders() {
        let sp = spec(8, 4, 12, false);
        let s = one_f_one_b(&sp);
        for (stage, ops) in s.ops.iter().enumerate() {
            // Within a stage, Bwd k must come after Fwd k.
            let pos = |pred: &dyn Fn(&Op) -> bool| ops.iter().position(|o| pred(o)).unwrap();
            for mb in 0..12 {
                let f = pos(&|o: &Op| matches!(o, Op::Fwd { mb: m, .. } if *m == mb));
                let b = pos(&|o: &Op| matches!(o, Op::Bwd { mb: m, .. } if *m == mb));
                assert!(f < b, "stage {stage} mb {mb}");
            }
        }
    }

    #[test]
    #[should_panic]
    fn starved_pipeline_rejected() {
        let sp = spec(8, 4, 2, false);
        modular_pipeline(&sp);
    }

    fn count_tar(s: &Schedule, want_bwd: bool) -> usize {
        s.count(|o| matches!(o, Op::TensorAllReduce { bwd, .. } if *bwd == want_bwd))
    }

    #[test]
    fn tp_specs_emit_one_tensor_all_reduce_per_layer_pass() {
        // C.4.3: one amortised op per (layer, micro-batch) phase — in
        // every policy.
        let mut sp = spec(8, 4, 8, false);
        sp.tp = 2;
        for s in [standard_ga(&sp), modular_pipeline(&sp), one_f_one_b(&sp)] {
            assert_eq!(count_tar(&s, false), 8 * 8, "{} fwd", s.name);
            assert_eq!(count_tar(&s, true), 8 * 8, "{} bwd", s.name);
            assert_eq!(s.tp, 2, "{}", s.name);
        }
        assert_eq!(count_tar(&interleaved_1f1b(&sp, 2), false), 8 * 8);
        let mut single = spec(8, 1, 8, false);
        single.tp = 4;
        for s in [standard_ga(&single), layered_ga(&single)] {
            assert_eq!(count_tar(&s, false) + count_tar(&s, true), 2 * 8 * 8, "{}", s.name);
        }
    }

    #[test]
    fn non_tp_specs_emit_no_tensor_all_reduce() {
        let sp = spec(8, 4, 8, true);
        for s in [standard_ga(&sp), modular_pipeline(&sp), one_f_one_b(&sp)] {
            assert_eq!(count_tar(&s, false) + count_tar(&s, true), 0, "{}", s.name);
            assert_eq!(s.tp, 1, "{}", s.name);
        }
    }

    #[test]
    fn tensor_all_reduce_sits_between_compute_and_send() {
        // The reduced tensor is what goes on the wire: within a stage's
        // list, tf(l, mb) follows F(l, mb) and precedes sa(l, mb); the
        // backward op likewise precedes sg(l, mb).
        let mut sp = spec(8, 4, 8, false);
        sp.tp = 2;
        let s = modular_pipeline(&sp);
        for (stage, ops) in s.ops.iter().enumerate() {
            for &l in &s.assignment.layers_of(stage, 8, 4) {
                for mb in 0..8 {
                    let pos = |op: Op| ops.iter().position(|o| *o == op);
                    let f = pos(Op::Fwd { layer: l, mb }).unwrap();
                    let tf = pos(Op::TensorAllReduce { layer: l, mb, bwd: false }).unwrap();
                    assert!(f < tf, "stage {stage} F{l}.{mb}");
                    if l + 1 < 8 {
                        let sa = pos(Op::SendAct { layer: l, mb }).unwrap();
                        assert!(tf < sa, "stage {stage} sa{l}.{mb}");
                    }
                    let b = pos(Op::Bwd { layer: l, mb }).unwrap();
                    let tb = pos(Op::TensorAllReduce { layer: l, mb, bwd: true }).unwrap();
                    assert!(b < tb, "stage {stage} B{l}.{mb}");
                    if l > 0 {
                        let sg = pos(Op::SendGrad { layer: l, mb }).unwrap();
                        assert!(tb < sg, "stage {stage} sg{l}.{mb}");
                    }
                }
            }
        }
    }

    #[test]
    fn interleaved_emits_every_compute_op_exactly_once() {
        let sp = spec(16, 4, 8, false);
        let s = interleaved_1f1b(&sp, 2);
        assert_eq!(count_fwd(&s), 16 * 8);
        assert_eq!(s.count(|o| matches!(o, Op::Bwd { .. })), 16 * 8);
        assert_eq!(s.count(|o| matches!(o, Op::ReduceGrad { .. })), 16);
        assert_eq!(s.count(|o| matches!(o, Op::OptimStep { .. })), 16);
        // Every boundary crossing sends: with blocks of 2 layers, every
        // second layer boundary is a stage boundary... here ALL chunk
        // boundaries cross stages (round-robin blocks), so sends =
        // (d_l/block - 1) boundaries x block-edge = 7 x 8 micro-batches.
        assert_eq!(s.count(|o| matches!(o, Op::SendAct { .. })), 7 * 8);
    }

    #[test]
    fn interleaved_bwd_follows_fwd_within_each_stage() {
        let sp = spec(8, 4, 8, false);
        let s = interleaved_1f1b(&sp, 2);
        for (stage, ops) in s.ops.iter().enumerate() {
            for mb in 0..8 {
                for &l in &s.assignment.layers_of(stage, 8, 4) {
                    let f = ops.iter().position(|o| *o == Op::Fwd { layer: l, mb }).unwrap();
                    let b = ops.iter().position(|o| *o == Op::Bwd { layer: l, mb }).unwrap();
                    assert!(f < b, "stage {stage} layer {l} mb {mb}");
                }
            }
        }
    }

    #[test]
    #[should_panic]
    fn interleaved_rejects_indivisible_microbatches() {
        // n_mu = 6 not divisible by n_l = 4.
        let sp = spec(16, 4, 6, false);
        interleaved_1f1b(&sp, 2);
    }

    #[test]
    fn zero2_replaces_reduce_with_reduce_scatter_and_gathers_post_step() {
        let mut sp = spec(8, 4, 8, false);
        sp.zero = 2;
        for s in [standard_ga(&sp), modular_pipeline(&sp), one_f_one_b(&sp)] {
            assert_eq!(s.count(|o| matches!(o, Op::ReduceGrad { .. })), 0, "{}", s.name);
            assert_eq!(s.count(|o| matches!(o, Op::ReduceScatterGrad { .. })), 8, "{}", s.name);
            // One post-step gather per layer rebuilds full params.
            assert_eq!(count_gather(&s), 8, "{}", s.name);
            assert_eq!(s.zero, 2, "{}", s.name);
        }
        assert_eq!(count_gather(&interleaved_1f1b(&sp, 2)), 8);
    }

    #[test]
    fn zero1_keeps_all_reduce_but_gathers_post_step() {
        let mut sp = spec(8, 4, 8, false);
        sp.zero = 1;
        let s = modular_pipeline(&sp);
        assert_eq!(s.count(|o| matches!(o, Op::ReduceGrad { .. })), 8);
        assert_eq!(s.count(|o| matches!(o, Op::ReduceScatterGrad { .. })), 0);
        assert_eq!(count_gather(&s), 8);
    }

    #[test]
    fn zero3_gathers_keep_figure2_shape() {
        // Stage 3 gathers before use, mirroring RestoreParams: standard
        // GA pays per micro-batch (2·d_l·n_μ), LGA and the modular
        // pipeline once per layer per pass (2·d_l) — no post-step gather.
        let mut single = spec(6, 1, 10, false);
        single.zero = 3;
        assert_eq!(count_gather(&standard_ga(&single)), 2 * 6 * 10);
        assert_eq!(count_gather(&layered_ga(&single)), 2 * 6);
        let mut piped = spec(8, 4, 8, false);
        piped.zero = 3;
        let s = modular_pipeline(&piped);
        assert_eq!(count_gather(&s), 2 * 8);
        assert_eq!(s.count(|o| matches!(o, Op::ReduceScatterGrad { .. })), 8);
    }

    #[test]
    fn zero_without_data_parallel_is_inert() {
        let mut sp = spec(8, 4, 8, false);
        sp.data_parallel = false;
        sp.zero = 2;
        let s = modular_pipeline(&sp);
        assert_eq!(count_gather(&s), 0);
        assert_eq!(s.count(|o| matches!(o, Op::ReduceScatterGrad { .. })), 0);
        assert_eq!(s.count(|o| matches!(o, Op::ReduceGrad { .. })), 0);
    }

    #[test]
    fn zero_spec_validation() {
        let mut sp = spec(8, 4, 8, true);
        sp.zero = 1;
        assert!(sp.validate().is_err(), "zero + partition must be rejected");
        sp.partition = false;
        assert!(sp.validate().is_ok());
        sp.zero = 4;
        assert!(sp.validate().is_err(), "zero stages beyond 3 must be rejected");
    }
}
