//! Schedule intermediate representation.
//!
//! A [`Schedule`] is, for every pipeline stage, an *ordered* list of
//! operations. The order **is** the scheduling policy (standard vs layered
//! gradient accumulation, contiguous vs modular pipeline); timing is not
//! part of the IR — it emerges when the discrete-event simulator executes
//! the schedule against a hardware model (ops block until their data
//! dependencies are satisfied, which is what produces the pipeline
//! bubble), or when the real trainer executes it against PJRT.

use std::fmt;

/// One schedulable operation on a pipeline stage.
///
/// `layer` indices are global (0..d_l); `mb` is the micro-batch index
/// (0..n_μ). Compute ops run on the device's compute stream; transfer ops
/// run on the network streams and overlap with compute.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Op {
    /// Forward pass of one layer for one micro-batch (stores the
    /// activation checkpoint).
    Fwd { layer: usize, mb: usize },
    /// Backward pass of one layer for one micro-batch, including the
    /// activation recomputation (costed at 3x forward, Appendix C.1).
    Bwd { layer: usize, mb: usize },
    /// Send a micro-batch's activations to the stage owning `layer + 1`.
    SendAct { layer: usize, mb: usize },
    /// Receive the activations of `layer - 1` (i.e. this stage's input
    /// for `layer`).
    RecvAct { layer: usize, mb: usize },
    /// Send the input-gradient of `layer` back to the stage owning
    /// `layer - 1`.
    SendGrad { layer: usize, mb: usize },
    /// Receive the output-gradient for `layer` from the stage owning
    /// `layer + 1`.
    RecvGrad { layer: usize, mb: usize },
    /// Data-parallel gradient reduction for one layer's parameters
    /// (ring reduce-scatter + all-gather, or reduce-scatter only when the
    /// state is partitioned).
    ReduceGrad { layer: usize },
    /// Restore (all-gather) one layer's fp16 parameters from the
    /// partitioned training state (ZeRO-3) or from CPU memory (offload).
    RestoreParams { layer: usize },
    /// Six tensor-parallel all-reduces amortised into one op per layer
    /// per micro-batch phase (2 fwd / 4 bwd with recompute; C.4.3).
    TensorAllReduce { layer: usize, mb: usize, bwd: bool },
    /// Move one layer's state shard GPU -> CPU (offload write-back).
    OffloadStore { layer: usize },
    /// Optimizer update for one layer (runs once the layer's gradients
    /// are reduced; negligible compute in the paper's accounting).
    OptimStep { layer: usize },
    /// ZeRO stage ≥2 gradient reduction: ring reduce-scatter over the
    /// data-parallel group — afterwards each dp rank owns only its
    /// contiguous 1/dp slice of the layer's reduced gradient.
    ReduceScatterGrad { layer: usize },
    /// ZeRO all-gather of one layer's parameters over the data-parallel
    /// group: post-step (stages 1–2) to rebuild full params from the
    /// owned slices, or gather-before-use (stage 3, FSDP-style).
    AllGatherParams { layer: usize },
}

impl Op {
    /// True for ops that occupy the compute stream. `TensorAllReduce` is
    /// compute-side: the Megatron-style all-reduce serialises with the
    /// layer math (C.4.3, "never overlapped"), and it must run on the
    /// stage owning the layer.
    pub fn is_compute(&self) -> bool {
        matches!(
            self,
            Op::Fwd { .. } | Op::Bwd { .. } | Op::OptimStep { .. } | Op::TensorAllReduce { .. }
        )
    }

    /// True for ops that occupy a network/transfer stream.
    pub fn is_transfer(&self) -> bool {
        !self.is_compute()
    }

    /// The layer the op concerns.
    pub fn layer(&self) -> usize {
        match *self {
            Op::Fwd { layer, .. }
            | Op::Bwd { layer, .. }
            | Op::SendAct { layer, .. }
            | Op::RecvAct { layer, .. }
            | Op::SendGrad { layer, .. }
            | Op::RecvGrad { layer, .. }
            | Op::ReduceGrad { layer }
            | Op::RestoreParams { layer }
            | Op::TensorAllReduce { layer, .. }
            | Op::OffloadStore { layer }
            | Op::OptimStep { layer }
            | Op::ReduceScatterGrad { layer }
            | Op::AllGatherParams { layer } => layer,
        }
    }
}

impl fmt::Display for Op {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match *self {
            Op::Fwd { layer, mb } => write!(f, "F{layer}.{mb}"),
            Op::Bwd { layer, mb } => write!(f, "B{layer}.{mb}"),
            Op::SendAct { layer, mb } => write!(f, "sa{layer}.{mb}"),
            Op::RecvAct { layer, mb } => write!(f, "ra{layer}.{mb}"),
            Op::SendGrad { layer, mb } => write!(f, "sg{layer}.{mb}"),
            Op::RecvGrad { layer, mb } => write!(f, "rg{layer}.{mb}"),
            Op::ReduceGrad { layer } => write!(f, "R{layer}"),
            Op::RestoreParams { layer } => write!(f, "G{layer}"),
            Op::TensorAllReduce { layer, mb, bwd } => {
                write!(f, "t{}{layer}.{mb}", if bwd { "b" } else { "f" })
            }
            Op::OffloadStore { layer } => write!(f, "O{layer}"),
            Op::OptimStep { layer } => write!(f, "U{layer}"),
            Op::ReduceScatterGrad { layer } => write!(f, "S{layer}"),
            Op::AllGatherParams { layer } => write!(f, "A{layer}"),
        }
    }
}

/// How layers map onto pipeline stages.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum LayerAssignment {
    /// Contiguous chunks: stage s owns layers [s·d_l/n_l, (s+1)·d_l/n_l).
    Contiguous,
    /// Modular (round-robin): stage s owns layers {l : l ≡ s (mod n_l)}
    /// (§4).
    Modular,
    /// Interleaved (Megatron-LM virtual stages): the model splits into
    /// n_l·chunks contiguous blocks assigned round-robin, so stage s owns
    /// blocks {s, s + n_l, ...}. `chunks` is the number of blocks per
    /// stage (v); requires d_l divisible by n_l·chunks. Modular is the
    /// chunks = d_l/n_l extreme of this family.
    Interleaved { chunks: usize },
}

impl LayerAssignment {
    /// The stage owning a given layer.
    pub fn stage_of(&self, layer: usize, d_l: usize, n_l: usize) -> usize {
        match *self {
            LayerAssignment::Contiguous => layer * n_l / d_l,
            LayerAssignment::Modular => layer % n_l,
            LayerAssignment::Interleaved { chunks } => {
                // Generators assert n_l·chunks | d_l; clamp the block so a
                // hand-built schedule with a malformed assignment yields
                // validation errors (wrong-stage ops) instead of a
                // divide-by-zero panic inside the validator.
                let block = (d_l / (n_l * chunks)).max(1);
                (layer / block) % n_l
            }
        }
    }

    /// The layers owned by a stage, in forward order.
    pub fn layers_of(&self, stage: usize, d_l: usize, n_l: usize) -> Vec<usize> {
        (0..d_l).filter(|&l| self.stage_of(l, d_l, n_l) == stage).collect()
    }
}

/// A complete static schedule for one training batch.
#[derive(Debug, Clone)]
pub struct Schedule {
    /// Human-readable policy name (e.g. "layered-ga", "modular-pipeline").
    pub name: String,
    /// Pipeline stages (n_l).
    pub n_stages: usize,
    /// Total layers d_l.
    pub d_l: usize,
    /// Micro-batches per batch n_μ.
    pub n_mu: usize,
    /// Layer-to-stage assignment.
    pub assignment: LayerAssignment,
    /// Ordered op list per stage.
    pub ops: Vec<Vec<Op>>,
    /// Tensor-parallel degree the schedule was generated for: every
    /// compute stage is replicated over `tp` ranks, and `tp > 1`
    /// schedules carry the per-layer `TensorAllReduce` ops (C.4.3).
    pub tp: usize,
    /// Whether the training state is partitioned (RestoreParams ops are
    /// all-gathers over the data-parallel group).
    pub partitioned: bool,
    /// Whether the training state is offloaded (RestoreParams ops fetch
    /// over the CPU link and OffloadStore ops stream the post-step state
    /// back out — the §8.2 real-time checkpoint path).
    pub offloaded: bool,
    /// ZeRO stage (0–3) the schedule was generated for: stage ≥1 shards
    /// Adam state 1/dp, stage ≥2 replaces `ReduceGrad` with
    /// `ReduceScatterGrad`, stage 3 moves the post-step
    /// `AllGatherParams` to gather-before-use.
    pub zero: u8,
}

impl Schedule {
    /// Total number of ops across all stages.
    pub fn len(&self) -> usize {
        self.ops.iter().map(Vec::len).sum()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Count ops matching a predicate.
    pub fn count(&self, pred: impl Fn(&Op) -> bool) -> usize {
        self.ops.iter().flatten().filter(|o| pred(o)).count()
    }

    /// The stage that owns a layer under this schedule's assignment.
    pub fn stage_of(&self, layer: usize) -> usize {
        self.assignment.stage_of(layer, self.d_l, self.n_stages)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn contiguous_assignment_chunks() {
        let a = LayerAssignment::Contiguous;
        // 8 layers over 4 stages: [0,1],[2,3],[4,5],[6,7].
        assert_eq!(a.layers_of(0, 8, 4), vec![0, 1]);
        assert_eq!(a.layers_of(3, 8, 4), vec![6, 7]);
        assert_eq!(a.stage_of(5, 8, 4), 2);
    }

    #[test]
    fn modular_assignment_round_robin() {
        let a = LayerAssignment::Modular;
        // 8 layers over 4 stages: {0,4},{1,5},{2,6},{3,7}.
        assert_eq!(a.layers_of(0, 8, 4), vec![0, 4]);
        assert_eq!(a.layers_of(3, 8, 4), vec![3, 7]);
        assert_eq!(a.stage_of(6, 8, 4), 2);
    }

    #[test]
    fn interleaved_assignment_round_robins_blocks() {
        let a = LayerAssignment::Interleaved { chunks: 2 };
        // 16 layers, 4 stages, 2 chunks: blocks of 2 layers, stage 0 owns
        // blocks 0 and 4 = layers {0,1,8,9}.
        assert_eq!(a.layers_of(0, 16, 4), vec![0, 1, 8, 9]);
        assert_eq!(a.layers_of(3, 16, 4), vec![6, 7, 14, 15]);
        assert_eq!(a.stage_of(10, 16, 4), 1);
    }

    #[test]
    fn every_layer_owned_exactly_once() {
        for a in [
            LayerAssignment::Contiguous,
            LayerAssignment::Modular,
            LayerAssignment::Interleaved { chunks: 2 },
        ] {
            for (d_l, n_l) in [(8, 4), (16, 4), (160, 5), (12, 3)] {
                let mut owned = vec![0usize; d_l];
                for s in 0..n_l {
                    for l in a.layers_of(s, d_l, n_l) {
                        owned[l] += 1;
                    }
                }
                assert!(owned.iter().all(|&c| c == 1), "{a:?} {d_l}/{n_l}");
            }
        }
    }

    #[test]
    fn op_stream_classification() {
        assert!(Op::Fwd { layer: 0, mb: 0 }.is_compute());
        assert!(Op::Bwd { layer: 0, mb: 0 }.is_compute());
        assert!(Op::SendAct { layer: 0, mb: 0 }.is_transfer());
        assert!(Op::ReduceGrad { layer: 0 }.is_transfer());
        assert!(Op::RestoreParams { layer: 0 }.is_transfer());
        assert!(Op::ReduceScatterGrad { layer: 0 }.is_transfer());
        assert!(Op::AllGatherParams { layer: 0 }.is_transfer());
        // Serialised with the layer math (C.4.3) — compute-side.
        assert!(Op::TensorAllReduce { layer: 0, mb: 0, bwd: true }.is_compute());
    }

    #[test]
    fn display_is_compact() {
        assert_eq!(Op::Fwd { layer: 3, mb: 1 }.to_string(), "F3.1");
        assert_eq!(Op::ReduceGrad { layer: 7 }.to_string(), "R7");
        assert_eq!(Op::ReduceScatterGrad { layer: 2 }.to_string(), "S2");
        assert_eq!(Op::AllGatherParams { layer: 5 }.to_string(), "A5");
    }
}
