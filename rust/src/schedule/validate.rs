//! Static schedule validation: data-dependency closure and send/recv
//! matching. A schedule that passes these checks cannot deadlock in the
//! simulator or the real trainer.

use std::collections::HashSet;

use super::ir::{Op, Schedule};

/// Errors found by [`validate`].
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ScheduleError {
    /// A layer/micro-batch forward appears on a stage that does not own
    /// the layer.
    WrongStage { stage: usize, op: String },
    /// Fwd/Bwd for a (layer, mb) pair is missing or duplicated.
    BadComputeCount { layer: usize, mb: usize, fwd: usize, bwd: usize },
    /// A SendAct has no matching RecvAct on the consuming stage (or vice
    /// versa).
    UnmatchedTransfer { op: String },
    /// Within a stage, an op consumes data produced later on the same
    /// stage (guaranteed deadlock).
    LocalOrderViolation { stage: usize, consumer: String, producer: String },
}

/// Validate a schedule's structural invariants.
pub fn validate(s: &Schedule) -> Result<(), Vec<ScheduleError>> {
    let mut errors = Vec::new();

    // 1. Ownership: compute ops only on the owning stage.
    for (stage, ops) in s.ops.iter().enumerate() {
        for op in ops {
            if op.is_compute() && s.stage_of(op.layer()) != stage {
                errors.push(ScheduleError::WrongStage { stage, op: op.to_string() });
            }
        }
    }

    // 2. Exactly one Fwd and one Bwd per (layer, mb).
    let mut fwd = vec![vec![0usize; s.n_mu]; s.d_l];
    let mut bwd = vec![vec![0usize; s.n_mu]; s.d_l];
    for op in s.ops.iter().flatten() {
        match *op {
            Op::Fwd { layer, mb } => fwd[layer][mb] += 1,
            Op::Bwd { layer, mb } => bwd[layer][mb] += 1,
            _ => {}
        }
    }
    for l in 0..s.d_l {
        for mb in 0..s.n_mu {
            if fwd[l][mb] != 1 || bwd[l][mb] != 1 {
                errors.push(ScheduleError::BadComputeCount {
                    layer: l,
                    mb,
                    fwd: fwd[l][mb],
                    bwd: bwd[l][mb],
                });
            }
        }
    }

    // 3. Send/Recv matching across stage boundaries.
    let mut sends: HashSet<(usize, usize, bool)> = HashSet::new(); // (layer, mb, grad?)
    let mut recvs: HashSet<(usize, usize, bool)> = HashSet::new();
    for op in s.ops.iter().flatten() {
        match *op {
            Op::SendAct { layer, mb } => {
                sends.insert((layer, mb, false));
            }
            // RecvAct{layer} receives the *output of layer-1*.
            Op::RecvAct { layer, mb } => {
                recvs.insert((layer - 1, mb, false));
            }
            Op::SendGrad { layer, mb } => {
                sends.insert((layer, mb, true));
            }
            // RecvGrad{layer} receives the gradient of layer+1's input.
            Op::RecvGrad { layer, mb } => {
                recvs.insert((layer + 1, mb, true));
            }
            _ => {}
        }
    }
    for miss in sends.symmetric_difference(&recvs) {
        errors.push(ScheduleError::UnmatchedTransfer {
            op: format!(
                "{}{} layer {} mb {}",
                if miss.2 { "grad" } else { "act" },
                if sends.contains(miss) { " send" } else { " recv" },
                miss.0,
                miss.1
            ),
        });
    }

    // 4. Same-stage ordering: Fwd(l, mb) before Fwd(l', mb) for owned
    //    consecutive layers, Bwd(l, mb) after Fwd(l, mb), SendAct after
    //    its Fwd, RecvAct before its Fwd.
    for (stage, ops) in s.ops.iter().enumerate() {
        let index_of = |pred: &dyn Fn(&Op) -> bool| ops.iter().position(|o| pred(o));
        for (i, op) in ops.iter().enumerate() {
            match *op {
                Op::SendAct { layer, mb } => {
                    if let Some(j) = index_of(&|o: &Op| *o == Op::Fwd { layer, mb }) {
                        if j > i {
                            errors.push(ScheduleError::LocalOrderViolation {
                                stage,
                                consumer: op.to_string(),
                                producer: format!("F{layer}.{mb}"),
                            });
                        }
                    }
                }
                Op::Bwd { layer, mb } => {
                    if let Some(j) = index_of(&|o: &Op| *o == Op::Fwd { layer, mb }) {
                        if j > i {
                            errors.push(ScheduleError::LocalOrderViolation {
                                stage,
                                consumer: op.to_string(),
                                producer: format!("F{layer}.{mb}"),
                            });
                        }
                    }
                }
                _ => {}
            }
        }
    }

    if errors.is_empty() {
        Ok(())
    } else {
        Err(errors)
    }
}

#[cfg(test)]
mod tests {
    use super::super::generators::*;
    use super::*;

    #[test]
    fn all_generated_schedules_validate() {
        for (d_l, n_l, n_mu) in [(8, 4, 8), (16, 4, 6), (12, 3, 3), (8, 1, 4), (160, 5, 5)] {
            for partition in [false, true] {
                let sp = ScheduleSpec { d_l, n_l, n_mu, partition, data_parallel: true };
                if n_l == 1 {
                    validate(&layered_ga(&sp)).expect("layered");
                } else {
                    validate(&modular_pipeline(&sp)).expect("modular");
                    validate(&one_f_one_b(&sp)).expect("1f1b");
                }
                validate(&standard_ga(&sp)).expect("standard");
            }
        }
    }

    #[test]
    fn detects_missing_bwd() {
        let sp = ScheduleSpec { d_l: 4, n_l: 2, n_mu: 2, partition: false, data_parallel: false };
        let mut s = modular_pipeline(&sp);
        // Drop one backward op.
        let pos = s.ops[0].iter().position(|o| matches!(o, Op::Bwd { .. })).unwrap();
        s.ops[0].remove(pos);
        let errs = validate(&s).unwrap_err();
        assert!(errs.iter().any(|e| matches!(e, ScheduleError::BadComputeCount { .. })));
    }

    #[test]
    fn detects_unmatched_send() {
        let sp = ScheduleSpec { d_l: 4, n_l: 2, n_mu: 2, partition: false, data_parallel: false };
        let mut s = modular_pipeline(&sp);
        let pos = s.ops[0].iter().position(|o| matches!(o, Op::SendAct { .. })).unwrap();
        s.ops[0].remove(pos);
        let errs = validate(&s).unwrap_err();
        assert!(errs.iter().any(|e| matches!(e, ScheduleError::UnmatchedTransfer { .. })));
    }

    #[test]
    fn detects_wrong_stage() {
        let sp = ScheduleSpec { d_l: 4, n_l: 2, n_mu: 2, partition: false, data_parallel: false };
        let mut s = modular_pipeline(&sp);
        s.ops[0].push(Op::Fwd { layer: 1, mb: 0 }); // layer 1 belongs to stage 1
        let errs = validate(&s).unwrap_err();
        assert!(errs.iter().any(|e| matches!(e, ScheduleError::WrongStage { .. })));
    }
}
