//! Static schedule validation, as a thin wrapper over the lowering pass.
//!
//! Every structural invariant — compute-op ownership, exactly one
//! Fwd/Bwd per (layer, micro-batch), send/recv pairing, producer
//! availability and cycle-freedom over the dependency graph plus the
//! per-stream FIFO order — is checked once, inside
//! [`super::program::lower`]. A schedule that lowers cleanly cannot
//! deadlock the simulator, which executes the very graph the checks ran
//! on; the synchronous trainer is stricter (one total order per stage)
//! and additionally runs
//! [`super::program::ScheduleProgram::check_inorder_executable`] before
//! spawning workers.

use super::ir::Schedule;
use super::program::lower;

/// Errors found while lowering a schedule (see [`super::program`]).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ScheduleError {
    /// A compute op (Fwd/Bwd/OptimStep) appears on a stage that does not
    /// own the layer, or names an out-of-range layer/micro-batch.
    WrongStage { stage: usize, op: String },
    /// Fwd/Bwd for a (layer, mb) pair is missing or duplicated.
    BadComputeCount { layer: usize, mb: usize, fwd: usize, bwd: usize },
    /// A SendX has no matching RecvX on the consuming stage (or vice
    /// versa).
    UnmatchedTransfer { op: String },
    /// An op consumes data that no op on its stage produces (the schedule
    /// would stall forever waiting for it).
    MissingDependency { stage: usize, op: String, needs: String },
    /// The dependency edges plus the per-stream FIFO order contain a
    /// cycle — a guaranteed deadlock for any in-order executor. Lists up
    /// to eight of the ops involved.
    Cycle { ops: Vec<String> },
}

/// Validate a schedule's structural invariants by lowering it and
/// discarding the program. Callers that also want to *execute* the
/// schedule should call [`lower`] directly and keep the result.
pub fn validate(s: &Schedule) -> Result<(), Vec<ScheduleError>> {
    lower(s).map(|_| ())
}

#[cfg(test)]
mod tests {
    use super::super::generators::*;
    use super::super::ir::{LayerAssignment, Op, Schedule};
    use super::*;

    fn small_spec() -> ScheduleSpec {
        ScheduleSpec {
            d_l: 4,
            n_l: 2,
            n_mu: 2,
            tp: 1,
            partition: false,
            offload: false,
            data_parallel: false,
            zero: 0,
        }
    }

    #[test]
    fn all_generated_schedules_validate() {
        for (d_l, n_l, n_mu) in [(8, 4, 8), (16, 4, 6), (12, 3, 3), (8, 1, 4), (160, 5, 5)] {
            for partition in [false, true] {
                for offload in [false, true] {
                    for tp in [1, 2] {
                        let sp = ScheduleSpec {
                            d_l,
                            n_l,
                            n_mu,
                            tp,
                            partition,
                            offload,
                            data_parallel: true,
                            zero: 0,
                        };
                        if n_l == 1 {
                            validate(&layered_ga(&sp)).expect("layered");
                        } else {
                            validate(&modular_pipeline(&sp)).expect("modular");
                            validate(&one_f_one_b(&sp)).expect("1f1b");
                        }
                        validate(&standard_ga(&sp)).expect("standard");
                    }
                }
            }
        }
    }

    #[test]
    fn interleaved_schedules_validate() {
        for (d_l, n_l, n_mu, chunks) in [(8, 4, 8, 2), (16, 4, 8, 2), (16, 2, 4, 4), (8, 1, 2, 2)]
        {
            for partition in [false, true] {
                for offload in [false, true] {
                    for tp in [1, 2] {
                        let sp = ScheduleSpec {
                            d_l,
                            n_l,
                            n_mu,
                            tp,
                            partition,
                            offload,
                            data_parallel: true,
                            zero: 0,
                        };
                        validate(&interleaved_1f1b(&sp, chunks))
                            .unwrap_or_else(|e| panic!("{d_l}/{n_l}/{n_mu} v={chunks}: {e:?}"));
                    }
                }
            }
        }
    }

    #[test]
    fn detects_missing_bwd() {
        let sp = small_spec();
        let mut s = modular_pipeline(&sp);
        // Drop one backward op.
        let pos = s.ops[0].iter().position(|o| matches!(o, Op::Bwd { .. })).unwrap();
        s.ops[0].remove(pos);
        let errs = validate(&s).unwrap_err();
        assert!(errs.iter().any(|e| matches!(e, ScheduleError::BadComputeCount { .. })));
    }

    #[test]
    fn detects_unmatched_send() {
        let sp = small_spec();
        let mut s = modular_pipeline(&sp);
        let pos = s.ops[0].iter().position(|o| matches!(o, Op::SendAct { .. })).unwrap();
        s.ops[0].remove(pos);
        let errs = validate(&s).unwrap_err();
        assert!(errs.iter().any(|e| matches!(e, ScheduleError::UnmatchedTransfer { .. })));
    }

    #[test]
    fn detects_wrong_stage() {
        let sp = small_spec();
        let mut s = modular_pipeline(&sp);
        s.ops[0].push(Op::Fwd { layer: 1, mb: 0 }); // layer 1 belongs to stage 1
        let errs = validate(&s).unwrap_err();
        assert!(errs.iter().any(|e| matches!(e, ScheduleError::WrongStage { .. })));
    }

    #[test]
    fn detects_deadlock_cycle() {
        // A backward scheduled before its forward on the same compute
        // stream: data edge Fwd->Bwd, FIFO edge Bwd->Fwd — a cycle the
        // old closure-based validator could only approximate.
        let s = Schedule {
            name: "cyclic".into(),
            n_stages: 1,
            d_l: 1,
            n_mu: 1,
            assignment: LayerAssignment::Contiguous,
            ops: vec![vec![Op::Bwd { layer: 0, mb: 0 }, Op::Fwd { layer: 0, mb: 0 }]],
            tp: 1,
            partitioned: false,
            offloaded: false,
            zero: 0,
        };
        let errs = validate(&s).unwrap_err();
        assert!(errs.iter().any(|e| matches!(e, ScheduleError::Cycle { .. })), "{errs:?}");
    }

    #[test]
    fn detects_missing_local_producer() {
        // A SendGrad whose stage never runs the corresponding backward.
        let sp = small_spec();
        let mut s = modular_pipeline(&sp);
        s.ops[0].push(Op::SendGrad { layer: 0, mb: 5 }); // mb 5 never computed
        let errs = validate(&s).unwrap_err();
        assert!(
            errs.iter().any(|e| matches!(e, ScheduleError::MissingDependency { .. })),
            "{errs:?}"
        );
    }
}
