//! Lowering pass: compile a [`Schedule`] into a [`ScheduleProgram`].
//!
//! A `Schedule` is policy — per-stage ordered op lists. A
//! `ScheduleProgram` is the same batch of work with every data dependency
//! made explicit: a flat op arena, compressed-sparse pred/succ edge lists,
//! and per-stage per-stream run queues. Lowering derives the paper's
//! dependency rules exactly once:
//!
//! * **activation chains** — `Fwd(l, mb)` depends on the producer of
//!   layer `l−1`'s activation on the same stage (a local `Fwd` or a
//!   `RecvAct`); `Bwd(l, mb)` depends on its checkpoint (`Fwd(l, mb)`);
//! * **gradient chains** — `Bwd(l, mb)` depends on the producer of layer
//!   `l+1`'s input-gradient on the same stage (a local `Bwd` or a
//!   `RecvGrad`); the last layer has no gradient dependency;
//! * **send/recv pairing** — `SendX` depends on its local payload
//!   producer; `RecvX` depends on the matching `SendX` on the producing
//!   stage (wire time is charged on the sender);
//! * **restore-before-use** — `Fwd`/`Bwd` of layer `l` depend on the
//!   latest preceding `RestoreParams(l)` on their stage, when present;
//! * **gather-before-use** — likewise for the latest preceding
//!   `AllGatherParams(l)` (ZeRO stage 3); a post-step gather (stages
//!   1–2) instead depends on its layer's `OptimStep`;
//! * **reduce-after-last-bwd** — `ReduceGrad(l)` (and its ZeRO ≥2
//!   replacement `ReduceScatterGrad(l)`) depends on every local
//!   `Bwd(l, ·)`;
//! * **optim-after-reduce** — `OptimStep(l)` depends on the stage's
//!   `ReduceGrad(l)` when present, else on every local `Bwd(l, ·)`;
//! * **reduce-before-send** — a `TensorAllReduce(l, mb)` depends on the
//!   compute op of its phase (`Fwd`/`Bwd`), and *replaces* that op as
//!   the producer of the phase's tensor: the matching `SendAct`/
//!   `SendGrad` and the next local compute consume the reduced tensor,
//!   so they wait for the all-reduce, not just the raw compute;
//! * **store-after-optim** — `OffloadStore(l)` depends on the stage's
//!   `OptimStep(l)` (the streamed checkpoint must hold the *post-step*
//!   state), falling back to the reduction / backward ops for hand-built
//!   schedules without one.
//!
//! Every consumer of scheduling semantics — the validator
//! ([`super::validate`]), the discrete-event simulator
//! ([`crate::sim::engine`]) and the real trainer
//! ([`crate::trainer::worker`]) — works from this one graph, so they
//! cannot disagree about legality. Lowering also runs a Kahn topological
//! pass over the data edges *plus* the implicit same-stream FIFO edges;
//! a cycle there is exactly a schedule that would deadlock an in-order
//! executor.

use std::collections::HashMap;

use super::ir::{LayerAssignment, Op, Schedule};
use super::validate::ScheduleError;

/// Which per-device stream an op occupies. Compute ops serialise on the
/// compute cores; transfers overlap with compute on the network/PCIe
/// streams — the overlap (or lack of it) is what the schedules exploit.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Stream {
    /// The compute cores.
    Compute,
    /// Outbound inter-device traffic (pipeline sends, gradient reduction).
    NetOut,
    /// Inbound inter-device traffic (pipeline receives, parameter
    /// restoration).
    NetIn,
    /// The CPU-GPU (PCIe) link used for offload traffic.
    CpuLink,
}

pub const STREAMS: [Stream; 4] = [Stream::Compute, Stream::NetOut, Stream::NetIn, Stream::CpuLink];

/// Number of per-device streams.
pub const N_STREAMS: usize = 4;

impl Stream {
    /// The stream an op occupies.
    pub fn of(op: &Op) -> Stream {
        match op {
            Op::Fwd { .. } | Op::Bwd { .. } | Op::OptimStep { .. } => Stream::Compute,
            Op::SendAct { .. }
            | Op::SendGrad { .. }
            | Op::ReduceGrad { .. }
            | Op::ReduceScatterGrad { .. } => Stream::NetOut,
            Op::RecvAct { .. }
            | Op::RecvGrad { .. }
            | Op::RestoreParams { .. }
            | Op::AllGatherParams { .. } => Stream::NetIn,
            // Serialised with compute (C.4.3).
            Op::TensorAllReduce { .. } => Stream::Compute,
            Op::OffloadStore { .. } => Stream::CpuLink,
        }
    }

    /// Index into [`STREAMS`].
    pub fn index(self) -> usize {
        match self {
            Stream::Compute => 0,
            Stream::NetOut => 1,
            Stream::NetIn => 2,
            Stream::CpuLink => 3,
        }
    }
}

/// One op in the flat arena.
#[derive(Debug, Clone, Copy)]
pub struct ProgOp {
    /// Arena index (== position in [`ScheduleProgram::ops`]).
    pub id: u32,
    /// Pipeline stage the op runs on.
    pub stage: u32,
    /// Stream the op occupies on its stage.
    pub stream: Stream,
    pub op: Op,
}

/// A compiled schedule: flat op arena with precomputed dependency edges
/// and per-stage/per-stream run queues. Produced by [`lower`]; immutable
/// afterwards.
#[derive(Debug, Clone)]
pub struct ScheduleProgram {
    /// Policy name inherited from the source [`Schedule`].
    pub name: String,
    pub n_stages: usize,
    pub d_l: usize,
    pub n_mu: usize,
    pub assignment: LayerAssignment,
    /// Tensor-parallel degree the source schedule was generated for
    /// (1 = no tensor parallelism; > 1 implies `TensorAllReduce` ops).
    pub tp: usize,
    pub partitioned: bool,
    pub offloaded: bool,
    /// ZeRO stage (0–3) inherited from the source [`Schedule`].
    pub zero: u8,
    /// Flat arena, stage-major, each stage's ops in source order.
    pub ops: Vec<ProgOp>,
    /// Run queues: `queues[stage][stream_index]` lists op ids in issue
    /// order. Ops on one stream run FIFO; an op additionally waits for
    /// its dependency edges.
    pub queues: Vec<[Vec<u32>; N_STREAMS]>,
    /// CSR predecessor lists: preds of op `i` are
    /// `preds[pred_offsets[i]..pred_offsets[i+1]]`.
    preds: Vec<u32>,
    pred_offsets: Vec<u32>,
    /// CSR successor lists (transpose of `preds`).
    succs: Vec<u32>,
    succ_offsets: Vec<u32>,
    /// `stage_starts[s]..stage_starts[s+1]` is stage `s`'s arena slice.
    stage_starts: Vec<usize>,
}

impl ScheduleProgram {
    /// Total number of ops.
    pub fn len(&self) -> usize {
        self.ops.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ops.is_empty()
    }

    /// Total number of dependency edges.
    pub fn n_edges(&self) -> usize {
        self.preds.len()
    }

    /// Append every op's predecessor-edge count to `out`, in arena order.
    /// This is the simulator's initial pending-counter vector; a method
    /// (rather than per-op `preds_of(i).len()` calls) so the engine can
    /// fill a reusable buffer in one pass and stay allocation-free on its
    /// hot path.
    pub fn fill_pending(&self, out: &mut Vec<u32>) {
        out.extend(self.pred_offsets.windows(2).map(|w| w[1] - w[0]));
    }

    /// Dependency predecessors of an op (ids into the arena).
    pub fn preds_of(&self, id: u32) -> &[u32] {
        let (a, b) = (self.pred_offsets[id as usize], self.pred_offsets[id as usize + 1]);
        &self.preds[a as usize..b as usize]
    }

    /// Dependency successors of an op (ids into the arena).
    pub fn succs_of(&self, id: u32) -> &[u32] {
        let (a, b) = (self.succ_offsets[id as usize], self.succ_offsets[id as usize + 1]);
        &self.succs[a as usize..b as usize]
    }

    /// The arena slice of one stage, in source order.
    pub fn stage_ops(&self, stage: usize) -> &[ProgOp] {
        &self.ops[self.stage_starts[stage]..self.stage_starts[stage + 1]]
    }

    /// The stage owning a layer under the program's assignment.
    pub fn stage_of(&self, layer: usize) -> usize {
        self.assignment.stage_of(layer, self.d_l, self.n_stages)
    }

    /// Count ops matching a predicate.
    pub fn count(&self, pred: impl Fn(&Op) -> bool) -> usize {
        self.ops.iter().filter(|n| pred(&n.op)).count()
    }

    /// Check that one *synchronous* in-order worker per stage — the real
    /// trainer's execution model, where a blocking receive stalls every
    /// later op of its stage regardless of stream — can execute the
    /// program. Stricter than the per-stream model [`lower`] already
    /// checked: here the FIFO edge runs between *consecutive stage ops*,
    /// not consecutive same-stream ops, so e.g. a send list-ordered
    /// after a blocking receive cannot be used to satisfy that receive.
    pub fn check_inorder_executable(&self) -> Result<(), ScheduleError> {
        let mut next: Vec<Option<u32>> = vec![None; self.len()];
        for stage in 0..self.n_stages {
            let (start, end) = (self.stage_starts[stage], self.stage_starts[stage + 1]);
            for idx in start..end.saturating_sub(1).max(start) {
                next[idx] = Some((idx + 1) as u32);
            }
        }
        self.kahn_with_next(&next)
    }

    /// Kahn's algorithm over the dependency edges plus caller-supplied
    /// implicit ordering edges: `next[i]` is the op the executor forces
    /// to wait for op `i`. Shared by the lowering cycle check
    /// (per-stream FIFO edges) and [`Self::check_inorder_executable`]
    /// (per-stage total-order edges).
    fn kahn_with_next(&self, next: &[Option<u32>]) -> Result<(), ScheduleError> {
        let n = self.len();
        let mut indeg: Vec<u32> =
            (0..n).map(|id| self.preds_of(id as u32).len() as u32).collect();
        for nx in next.iter().flatten() {
            indeg[*nx as usize] += 1;
        }
        let mut work: Vec<u32> = (0..n as u32).filter(|&i| indeg[i as usize] == 0).collect();
        let mut processed = 0usize;
        while let Some(id) = work.pop() {
            processed += 1;
            for &sc in self.succs_of(id) {
                indeg[sc as usize] -= 1;
                if indeg[sc as usize] == 0 {
                    work.push(sc);
                }
            }
            if let Some(nx) = next[id as usize] {
                indeg[nx as usize] -= 1;
                if indeg[nx as usize] == 0 {
                    work.push(nx);
                }
            }
        }
        if processed < n {
            let stuck: Vec<String> = self
                .ops
                .iter()
                .filter(|o| indeg[o.id as usize] > 0)
                .take(8)
                .map(|o| format!("stage {} {}", o.stage, o.op))
                .collect();
            return Err(ScheduleError::Cycle { ops: stuck });
        }
        Ok(())
    }

    /// Find the id of the first op matching a predicate.
    pub fn find(&self, pred: impl Fn(&Op) -> bool) -> Option<u32> {
        self.ops.iter().find(|n| pred(&n.op)).map(|n| n.id)
    }
}

/// Compile a schedule into a [`ScheduleProgram`], or report every
/// structural error found along the way. A program that lowers cleanly is
/// deadlock-free on any in-order-*per-stream* executor (the simulator's
/// model) — the cycle check covers the implicit stream-FIFO edges. The
/// synchronous trainer is stricter (one total order per stage); it
/// additionally runs [`ScheduleProgram::check_inorder_executable`].
pub fn lower(s: &Schedule) -> Result<ScheduleProgram, Vec<ScheduleError>> {
    let mut errors: Vec<ScheduleError> = Vec::new();

    // ---- arena ---------------------------------------------------------
    let total: usize = s.ops.iter().map(Vec::len).sum();
    let mut ops: Vec<ProgOp> = Vec::with_capacity(total);
    let mut stage_starts: Vec<usize> = Vec::with_capacity(s.n_stages + 1);
    let mut queues: Vec<[Vec<u32>; N_STREAMS]> = Vec::with_capacity(s.n_stages);
    for (stage, stage_ops) in s.ops.iter().enumerate() {
        stage_starts.push(ops.len());
        let mut q: [Vec<u32>; N_STREAMS] = Default::default();
        for op in stage_ops {
            let id = ops.len() as u32;
            let stream = Stream::of(op);
            q[stream.index()].push(id);
            ops.push(ProgOp { id, stage: stage as u32, stream, op: *op });
        }
        queues.push(q);
    }
    stage_starts.push(ops.len());

    // ---- pass 1: producers, transfers, counts --------------------------
    // Activation of `layer` for `mb` available on `stage` (local Fwd, or a
    // RecvAct re-homing the upstream activation).
    let mut act_producer: HashMap<(usize, usize, usize), u32> = HashMap::new();
    // Input-gradient w.r.t. `layer`'s output available on `stage`.
    let mut grad_producer: HashMap<(usize, usize, usize), u32> = HashMap::new();
    // Wire producers, keyed by the payload identity: (producing layer, mb).
    let mut send_act: HashMap<(usize, usize), u32> = HashMap::new();
    let mut send_grad: HashMap<(usize, usize), u32> = HashMap::new();
    // Which wire payloads were consumed (for unmatched-send reporting).
    let mut recv_act: HashMap<(usize, usize), u32> = HashMap::new();
    let mut recv_grad: HashMap<(usize, usize), u32> = HashMap::new();
    // Local Bwd ops per (stage, layer), and the stage's ReduceGrad per
    // (stage, layer).
    let mut bwd_ids: HashMap<(usize, usize), Vec<u32>> = HashMap::new();
    let mut reduce_id: HashMap<(usize, usize), u32> = HashMap::new();
    let mut optim_id: HashMap<(usize, usize), u32> = HashMap::new();
    // Tensor-parallel all-reduces per (stage, layer, mb): the fwd one
    // supersedes the Fwd as the activation producer, the bwd one the Bwd
    // as the input-gradient producer (reduce-before-send).
    let mut tar_fwd: HashMap<(usize, usize, usize), u32> = HashMap::new();
    let mut tar_bwd: HashMap<(usize, usize, usize), u32> = HashMap::new();

    let mut fwd_count = vec![vec![0usize; s.n_mu]; s.d_l];
    let mut bwd_count = vec![vec![0usize; s.n_mu]; s.d_l];

    for node in &ops {
        let stage = node.stage as usize;
        let id = node.id;
        let layer = node.op.layer();
        match node.op {
            Op::Fwd { layer: l, mb } => {
                if l >= s.d_l || mb >= s.n_mu {
                    errors.push(ScheduleError::WrongStage { stage, op: node.op.to_string() });
                    continue;
                }
                fwd_count[l][mb] += 1;
                act_producer.entry((stage, l, mb)).or_insert(id);
            }
            Op::Bwd { layer: l, mb } => {
                if l >= s.d_l || mb >= s.n_mu {
                    errors.push(ScheduleError::WrongStage { stage, op: node.op.to_string() });
                    continue;
                }
                bwd_count[l][mb] += 1;
                grad_producer.entry((stage, l, mb)).or_insert(id);
                bwd_ids.entry((stage, l)).or_default().push(id);
            }
            Op::SendAct { layer: l, mb } => {
                send_act.entry((l, mb)).or_insert(id);
            }
            Op::RecvAct { layer: l, mb } => {
                if l == 0 {
                    errors.push(ScheduleError::UnmatchedTransfer {
                        op: format!("{} (layer 0 has no upstream activation)", node.op),
                    });
                    continue;
                }
                recv_act.entry((l - 1, mb)).or_insert(id);
                act_producer.entry((stage, l - 1, mb)).or_insert(id);
            }
            Op::SendGrad { layer: l, mb } => {
                send_grad.entry((l, mb)).or_insert(id);
            }
            Op::RecvGrad { layer: l, mb } => {
                recv_grad.entry((l + 1, mb)).or_insert(id);
                grad_producer.entry((stage, l + 1, mb)).or_insert(id);
            }
            Op::ReduceGrad { layer: l } | Op::ReduceScatterGrad { layer: l } => {
                reduce_id.entry((stage, l)).or_insert(id);
            }
            Op::OptimStep { layer: l } => {
                optim_id.entry((stage, l)).or_insert(id);
            }
            Op::TensorAllReduce { layer: l, mb, bwd } => {
                if l >= s.d_l || mb >= s.n_mu {
                    errors.push(ScheduleError::WrongStage { stage, op: node.op.to_string() });
                    continue;
                }
                let slot = if bwd { &mut tar_bwd } else { &mut tar_fwd };
                slot.entry((stage, l, mb)).or_insert(id);
            }
            _ => {}
        }
        // Ownership: compute ops only on the owning stage.
        if node.op.is_compute() && layer < s.d_l && s.stage_of(layer) != stage {
            errors.push(ScheduleError::WrongStage { stage, op: node.op.to_string() });
        }
    }

    // A schedule with no Bwd anywhere is a forward-only (inference)
    // program — serving prefill/decode schedules. Its compute contract
    // is exactly one Fwd and zero Bwd per (layer, mb); a training
    // schedule merely *missing* some backwards still fails (the counts
    // are not all zero).
    let inference = bwd_count.iter().all(|row| row.iter().all(|&c| c == 0));
    let want_bwd = usize::from(!inference);
    for l in 0..s.d_l {
        for mb in 0..s.n_mu {
            if fwd_count[l][mb] != 1 || bwd_count[l][mb] != want_bwd {
                errors.push(ScheduleError::BadComputeCount {
                    layer: l,
                    mb,
                    fwd: fwd_count[l][mb],
                    bwd: bwd_count[l][mb],
                });
            }
        }
    }

    // Send/recv pairing, both directions.
    for (key, &id) in &send_act {
        if !recv_act.contains_key(key) {
            errors.push(ScheduleError::UnmatchedTransfer {
                op: format!("{} has no matching RecvAct", ops[id as usize].op),
            });
        }
    }
    for (key, &id) in &recv_act {
        if !send_act.contains_key(key) {
            errors.push(ScheduleError::UnmatchedTransfer {
                op: format!("{} has no matching SendAct", ops[id as usize].op),
            });
        }
    }
    for (key, &id) in &send_grad {
        if !recv_grad.contains_key(key) {
            errors.push(ScheduleError::UnmatchedTransfer {
                op: format!("{} has no matching RecvGrad", ops[id as usize].op),
            });
        }
    }
    for (key, &id) in &recv_grad {
        if !send_grad.contains_key(key) {
            errors.push(ScheduleError::UnmatchedTransfer {
                op: format!("{} has no matching SendGrad", ops[id as usize].op),
            });
        }
    }

    // ---- pass 2: dependency edges --------------------------------------
    // (pred, succ) pairs; duplicates are harmless (pred counts and succ
    // lists stay consistent) but we avoid emitting them.
    //
    // Effective producers: when a tensor-parallel all-reduce follows the
    // compute op of a phase, *it* is what makes the tensor usable —
    // consumers (sends, the next local compute) wait for the reduced
    // tensor, not the raw partial one.
    let eff_act = |stage: usize, l: usize, mb: usize| -> Option<u32> {
        tar_fwd.get(&(stage, l, mb)).or_else(|| act_producer.get(&(stage, l, mb))).copied()
    };
    let eff_grad = |stage: usize, l: usize, mb: usize| -> Option<u32> {
        tar_bwd.get(&(stage, l, mb)).or_else(|| grad_producer.get(&(stage, l, mb))).copied()
    };
    let mut edges: Vec<(u32, u32)> = Vec::with_capacity(total * 2);
    for stage in 0..s.n_stages {
        // Latest preceding RestoreParams per layer, positional.
        let mut last_restore: HashMap<usize, u32> = HashMap::new();
        // Latest preceding AllGatherParams per layer (ZeRO stage 3
        // gather-before-use), positional — post-step gathers come after
        // every compute op of their stage and are never "latest
        // preceding" for one.
        let mut last_gather: HashMap<usize, u32> = HashMap::new();
        for node in &ops[stage_starts[stage]..stage_starts[stage + 1]] {
            let id = node.id;
            let mut missing = |needs: String| {
                errors.push(ScheduleError::MissingDependency {
                    stage,
                    op: node.op.to_string(),
                    needs,
                });
            };
            match node.op {
                Op::RestoreParams { layer } => {
                    last_restore.insert(layer, id);
                }
                Op::AllGatherParams { layer } => {
                    last_gather.insert(layer, id);
                    // A post-step gather (ZeRO 1–2) redistributes the
                    // freshly updated owned slices: it must wait for the
                    // layer's optimizer update when that precedes it.
                    if let Some(&u) = optim_id.get(&(stage, layer)) {
                        if u < id {
                            edges.push((u, id));
                        }
                    }
                }
                Op::Fwd { layer, mb } => {
                    if layer > 0 {
                        match eff_act(stage, layer - 1, mb) {
                            Some(p) => edges.push((p, id)),
                            None => missing(format!("activation of layer {} mb {}", layer - 1, mb)),
                        }
                    }
                    if let Some(&r) = last_restore.get(&layer) {
                        edges.push((r, id));
                    }
                    if let Some(&g) = last_gather.get(&layer) {
                        edges.push((g, id));
                    }
                }
                Op::Bwd { layer, mb } => {
                    // The checkpoint is the *input* the local Fwd stored —
                    // available at the Fwd itself, untouched by the fwd
                    // all-reduce (which concerns the layer's output).
                    match act_producer.get(&(stage, layer, mb)) {
                        Some(&p) => edges.push((p, id)),
                        None => missing(format!("checkpoint of layer {layer} mb {mb}")),
                    }
                    if layer + 1 < s.d_l {
                        match eff_grad(stage, layer + 1, mb) {
                            Some(p) => edges.push((p, id)),
                            None => missing(format!("gradient of layer {} mb {}", layer + 1, mb)),
                        }
                    }
                    if let Some(&r) = last_restore.get(&layer) {
                        edges.push((r, id));
                    }
                    if let Some(&g) = last_gather.get(&layer) {
                        edges.push((g, id));
                    }
                }
                Op::SendAct { layer, mb } => match eff_act(stage, layer, mb) {
                    Some(p) => edges.push((p, id)),
                    None => missing(format!("activation of layer {layer} mb {mb}")),
                },
                Op::SendGrad { layer, mb } => match eff_grad(stage, layer, mb) {
                    Some(p) => edges.push((p, id)),
                    None => missing(format!("gradient of layer {layer} mb {mb}")),
                },
                Op::RecvAct { layer, mb } => {
                    if layer > 0 {
                        if let Some(&p) = send_act.get(&(layer - 1, mb)) {
                            edges.push((p, id));
                        }
                        // Unmatched case already reported in pass 1.
                    }
                }
                Op::RecvGrad { layer, mb } => {
                    if let Some(&p) = send_grad.get(&(layer + 1, mb)) {
                        edges.push((p, id));
                    }
                }
                Op::ReduceGrad { layer } | Op::ReduceScatterGrad { layer } => {
                    match bwd_ids.get(&(stage, layer)) {
                        Some(ids) => edges.extend(ids.iter().map(|&b| (b, id))),
                        None => missing(format!("backward ops of layer {layer}")),
                    }
                }
                Op::OptimStep { layer } => {
                    if let Some(&r) = reduce_id.get(&(stage, layer)) {
                        edges.push((r, id));
                    } else if let Some(ids) = bwd_ids.get(&(stage, layer)) {
                        edges.extend(ids.iter().map(|&b| (b, id)));
                    } else {
                        missing(format!("reduction or backward ops of layer {layer}"));
                    }
                }
                Op::OffloadStore { layer } => {
                    // The streamed checkpoint must hold the post-step
                    // state: wait for the optimizer update (generators
                    // always emit one), else degrade to the reduction /
                    // backward ops for hand-built schedules.
                    if let Some(&u) = optim_id.get(&(stage, layer)) {
                        edges.push((u, id));
                    } else if let Some(&r) = reduce_id.get(&(stage, layer)) {
                        edges.push((r, id));
                    } else if let Some(ids) = bwd_ids.get(&(stage, layer)) {
                        edges.extend(ids.iter().map(|&b| (b, id)));
                    } else {
                        missing(format!("optimizer step of layer {layer}"));
                    }
                }
                Op::TensorAllReduce { layer, mb, bwd } => {
                    // The all-reduce consumes the tensor its phase just
                    // produced: the layer's output activation (fwd) or
                    // input-gradient (bwd). Consumers were rewired onto
                    // this op through eff_act/eff_grad above.
                    let src = if bwd {
                        grad_producer.get(&(stage, layer, mb))
                    } else {
                        act_producer.get(&(stage, layer, mb))
                    };
                    match src {
                        Some(&p) => edges.push((p, id)),
                        None => missing(format!(
                            "{} of layer {layer} mb {mb}",
                            if bwd { "gradient" } else { "activation" }
                        )),
                    }
                }
            }
        }
    }

    if !errors.is_empty() {
        // The edge set is incomplete for a structurally broken schedule;
        // a cycle report would be noise on top of the real errors.
        return Err(errors);
    }

    // ---- CSR -----------------------------------------------------------
    let n = ops.len();
    let mut pred_offsets = vec![0u32; n + 1];
    let mut succ_offsets = vec![0u32; n + 1];
    for &(p, c) in &edges {
        pred_offsets[c as usize + 1] += 1;
        succ_offsets[p as usize + 1] += 1;
    }
    for i in 0..n {
        pred_offsets[i + 1] += pred_offsets[i];
        succ_offsets[i + 1] += succ_offsets[i];
    }
    let mut preds = vec![0u32; edges.len()];
    let mut succs = vec![0u32; edges.len()];
    let mut pred_fill = pred_offsets.clone();
    let mut succ_fill = succ_offsets.clone();
    for &(p, c) in &edges {
        preds[pred_fill[c as usize] as usize] = p;
        pred_fill[c as usize] += 1;
        succs[succ_fill[p as usize] as usize] = c;
        succ_fill[p as usize] += 1;
    }

    let program = ScheduleProgram {
        name: s.name.clone(),
        n_stages: s.n_stages,
        d_l: s.d_l,
        n_mu: s.n_mu,
        assignment: s.assignment,
        tp: s.tp,
        partitioned: s.partitioned,
        offloaded: s.offloaded,
        zero: s.zero,
        ops,
        queues,
        preds,
        pred_offsets,
        succs,
        succ_offsets,
        stage_starts,
    };

    // ---- cycle check (data edges + stream-FIFO edges) ------------------
    if let Err(e) = check_acyclic(&program) {
        return Err(vec![e]);
    }

    Ok(program)
}

/// Cycle check for the per-stream executor model: the dependency edges
/// plus the implicit FIFO edge between consecutive ops of each
/// (stage, stream) queue. Exactly the deadlock condition of an
/// in-order-per-stream executor (the simulator).
fn check_acyclic(p: &ScheduleProgram) -> Result<(), ScheduleError> {
    let mut next: Vec<Option<u32>> = vec![None; p.len()];
    for q in p.queues.iter().flat_map(|stage_q| stage_q.iter()) {
        for w in q.windows(2) {
            next[w[0] as usize] = Some(w[1]);
        }
    }
    p.kahn_with_next(&next)
}

#[cfg(test)]
mod tests {
    use super::super::generators::{modular_pipeline, standard_ga, ScheduleSpec};
    use super::super::ir::{LayerAssignment, Op, Schedule};
    use super::*;

    fn spec(d_l: usize, n_l: usize, n_mu: usize, partition: bool) -> ScheduleSpec {
        ScheduleSpec {
            d_l,
            n_l,
            n_mu,
            tp: 1,
            partition,
            offload: false,
            data_parallel: true,
            zero: 0,
        }
    }

    #[test]
    fn lowering_preserves_every_op_in_stage_order() {
        let s = modular_pipeline(&spec(8, 4, 8, true));
        let p = lower(&s).expect("lowers");
        assert_eq!(p.len(), s.len());
        for stage in 0..s.n_stages {
            let arena: Vec<Op> = p.stage_ops(stage).iter().map(|n| n.op).collect();
            assert_eq!(arena, s.ops[stage], "stage {stage}");
        }
    }

    #[test]
    fn bwd_depends_on_its_checkpoint_and_upstream_gradient() {
        let s = modular_pipeline(&spec(8, 4, 8, false));
        let p = lower(&s).unwrap();
        let fwd = p.find(|o| *o == Op::Fwd { layer: 2, mb: 3 }).unwrap();
        let bwd = p.find(|o| *o == Op::Bwd { layer: 2, mb: 3 }).unwrap();
        assert!(p.preds_of(bwd).contains(&fwd), "checkpoint edge");
        // Layer 3 lives on another stage -> the gradient arrives via a
        // RecvGrad, which itself depends on the remote SendGrad.
        let recv = p.find(|o| *o == Op::RecvGrad { layer: 2, mb: 3 }).unwrap();
        let send = p.find(|o| *o == Op::SendGrad { layer: 3, mb: 3 }).unwrap();
        assert!(p.preds_of(bwd).contains(&recv));
        assert!(p.preds_of(recv).contains(&send));
    }

    #[test]
    fn reduce_waits_for_every_local_backward() {
        let s = standard_ga(&spec(4, 1, 6, false));
        let p = lower(&s).unwrap();
        let reduce = p.find(|o| *o == Op::ReduceGrad { layer: 2 }).unwrap();
        let preds = p.preds_of(reduce);
        assert_eq!(preds.len(), 6);
        for &b in preds {
            assert!(matches!(p.ops[b as usize].op, Op::Bwd { layer: 2, .. }));
        }
        // And the optimizer step waits for the reduction.
        let optim = p.find(|o| *o == Op::OptimStep { layer: 2 }).unwrap();
        assert_eq!(p.preds_of(optim), &[reduce][..]);
    }

    #[test]
    fn restore_before_use_tracks_the_latest_preceding_restore() {
        let s = standard_ga(&spec(2, 1, 2, true));
        let p = lower(&s).unwrap();
        // Standard GA with partition restores per (layer, mb): each Fwd
        // depends on exactly the restore issued just before it.
        for node in p.ops.iter() {
            if let Op::Fwd { layer, .. } = node.op {
                let restores: Vec<u32> = p
                    .preds_of(node.id)
                    .iter()
                    .copied()
                    .filter(|&x| matches!(p.ops[x as usize].op, Op::RestoreParams { .. }))
                    .collect();
                assert_eq!(restores.len(), 1, "{}", node.op);
                assert!(matches!(
                    p.ops[restores[0] as usize].op,
                    Op::RestoreParams { layer: l } if l == layer
                ));
            }
        }
    }

    #[test]
    fn edge_counts_are_symmetric() {
        let s = modular_pipeline(&spec(16, 4, 8, true));
        let p = lower(&s).unwrap();
        let pred_total: usize = (0..p.len()).map(|i| p.preds_of(i as u32).len()).sum();
        let succ_total: usize = (0..p.len()).map(|i| p.succs_of(i as u32).len()).sum();
        assert_eq!(pred_total, succ_total);
        assert_eq!(pred_total, p.n_edges());
    }

    #[test]
    fn fill_pending_matches_preds_of() {
        let s = modular_pipeline(&spec(16, 4, 8, true));
        let p = lower(&s).unwrap();
        let mut pending = Vec::new();
        p.fill_pending(&mut pending);
        assert_eq!(pending.len(), p.len());
        for (i, &count) in pending.iter().enumerate() {
            assert_eq!(count as usize, p.preds_of(i as u32).len(), "op {i}");
        }
    }

    #[test]
    fn cycle_is_reported() {
        // Bwd before its own Fwd on the single compute stream: the data
        // edge (Fwd -> Bwd) and the FIFO edge (Bwd -> Fwd) form a cycle.
        let s = Schedule {
            name: "cyclic".into(),
            n_stages: 1,
            d_l: 1,
            n_mu: 1,
            assignment: LayerAssignment::Contiguous,
            ops: vec![vec![Op::Bwd { layer: 0, mb: 0 }, Op::Fwd { layer: 0, mb: 0 }]],
            tp: 1,
            partitioned: false,
            offloaded: false,
            zero: 0,
        };
        let errs = lower(&s).unwrap_err();
        assert!(errs.iter().any(|e| matches!(e, ScheduleError::Cycle { .. })), "{errs:?}");
    }

    #[test]
    fn per_stream_legal_but_inorder_deadlock_is_caught() {
        // SendAct list-ordered after a blocking RecvGrad: legal for the
        // simulator (different streams), but a synchronous worker blocks
        // on the receive before ever sending, deadlocking the peer stage.
        let s = Schedule {
            name: "inorder-trap".into(),
            n_stages: 2,
            d_l: 2,
            n_mu: 1,
            assignment: LayerAssignment::Contiguous,
            ops: vec![
                vec![
                    Op::Fwd { layer: 0, mb: 0 },
                    Op::RecvGrad { layer: 0, mb: 0 },
                    Op::SendAct { layer: 0, mb: 0 },
                    Op::Bwd { layer: 0, mb: 0 },
                ],
                vec![
                    Op::RecvAct { layer: 1, mb: 0 },
                    Op::Fwd { layer: 1, mb: 0 },
                    Op::Bwd { layer: 1, mb: 0 },
                    Op::SendGrad { layer: 1, mb: 0 },
                ],
            ],
            tp: 1,
            partitioned: false,
            offloaded: false,
            zero: 0,
        };
        let p = lower(&s).expect("per-stream model accepts this schedule");
        assert!(
            matches!(p.check_inorder_executable(), Err(ScheduleError::Cycle { .. })),
            "the synchronous-worker check must reject it"
        );
        // Every generated schedule passes the stricter check.
        let sp = spec(8, 4, 8, true);
        lower(&modular_pipeline(&sp)).unwrap().check_inorder_executable().unwrap();
        lower(&standard_ga(&sp)).unwrap().check_inorder_executable().unwrap();
    }

    #[test]
    fn offload_store_waits_for_the_optimizer_step() {
        let mut sp = spec(8, 4, 8, true);
        sp.offload = true;
        let p = lower(&modular_pipeline(&sp)).unwrap();
        for l in 0..8 {
            let store = p.find(|o| *o == Op::OffloadStore { layer: l }).unwrap();
            let optim = p.find(|o| *o == Op::OptimStep { layer: l }).unwrap();
            assert_eq!(p.preds_of(store), &[optim][..], "layer {l}");
        }
        // And the offload schedule still survives the synchronous-worker
        // executability check.
        p.check_inorder_executable().unwrap();
        assert!(p.offloaded);
    }

    #[test]
    fn tensor_all_reduce_is_wired_reduce_before_send() {
        let mut sp = spec(8, 4, 8, false);
        sp.tp = 2;
        let p = lower(&modular_pipeline(&sp)).expect("tp schedules lower");
        assert_eq!(p.tp, 2);
        // tf(2, 3): after F2.3, before sa2.3 — and the downstream stage's
        // recv chain is unchanged.
        let fwd = p.find(|o| *o == Op::Fwd { layer: 2, mb: 3 }).unwrap();
        let tar = p.find(|o| *o == Op::TensorAllReduce { layer: 2, mb: 3, bwd: false }).unwrap();
        let send = p.find(|o| *o == Op::SendAct { layer: 2, mb: 3 }).unwrap();
        assert!(p.preds_of(tar).contains(&fwd), "tar depends on its Fwd");
        assert!(p.preds_of(send).contains(&tar), "send waits for the reduced tensor");
        assert!(!p.preds_of(send).contains(&fwd), "send is rewired off the raw Fwd");
        // Backward: tb(2, 3) between B2.3 and sg2.3.
        let bwd = p.find(|o| *o == Op::Bwd { layer: 2, mb: 3 }).unwrap();
        let tarb = p.find(|o| *o == Op::TensorAllReduce { layer: 2, mb: 3, bwd: true }).unwrap();
        let sendg = p.find(|o| *o == Op::SendGrad { layer: 2, mb: 3 }).unwrap();
        assert!(p.preds_of(tarb).contains(&bwd));
        assert!(p.preds_of(sendg).contains(&tarb));
        // The whole program still executes on synchronous workers.
        p.check_inorder_executable().unwrap();
    }

    #[test]
    fn local_consumers_wait_for_the_fwd_all_reduce() {
        // Single stage: layer 1's Fwd consumes layer 0's *reduced*
        // output, and the bwd chain consumes layer 1's reduced input-
        // gradient.
        let mut sp = spec(2, 1, 2, false);
        sp.tp = 2;
        let p = lower(&standard_ga(&sp)).unwrap();
        let tar0 = p.find(|o| *o == Op::TensorAllReduce { layer: 0, mb: 0, bwd: false }).unwrap();
        let fwd1 = p.find(|o| *o == Op::Fwd { layer: 1, mb: 0 }).unwrap();
        assert!(p.preds_of(fwd1).contains(&tar0));
        let tarb1 = p.find(|o| *o == Op::TensorAllReduce { layer: 1, mb: 0, bwd: true }).unwrap();
        let bwd0 = p.find(|o| *o == Op::Bwd { layer: 0, mb: 0 }).unwrap();
        assert!(p.preds_of(bwd0).contains(&tarb1));
        p.check_inorder_executable().unwrap();
    }

    #[test]
    fn zero2_reduce_scatter_feeds_optim_and_post_step_gather() {
        let mut sp = spec(8, 4, 8, false);
        sp.zero = 2;
        let p = lower(&modular_pipeline(&sp)).unwrap();
        assert_eq!(p.zero, 2);
        for l in 0..8 {
            let rs = p.find(|o| *o == Op::ReduceScatterGrad { layer: l }).unwrap();
            // The reduce-scatter waits for every local backward.
            assert_eq!(p.preds_of(rs).len(), 8, "layer {l}");
            // The optimizer step consumes the owned gradient slice.
            let optim = p.find(|o| *o == Op::OptimStep { layer: l }).unwrap();
            assert_eq!(p.preds_of(optim), &[rs][..], "layer {l}");
            // The post-step gather redistributes the updated slice.
            let gather = p.find(|o| *o == Op::AllGatherParams { layer: l }).unwrap();
            assert_eq!(p.preds_of(gather), &[optim][..], "layer {l}");
        }
        p.check_inorder_executable().unwrap();
    }

    #[test]
    fn zero3_gather_before_use_is_wired_like_restore() {
        let mut sp = spec(8, 4, 8, false);
        sp.zero = 3;
        let p = lower(&modular_pipeline(&sp)).unwrap();
        // Every Fwd/Bwd depends on the latest preceding gather of its
        // layer; the pre-use gathers precede the optimizer step, so no
        // OptimStep edge (and no cycle) exists.
        for node in p.ops.iter() {
            if let Op::Fwd { layer, .. } | Op::Bwd { layer, .. } = node.op {
                let gathers: Vec<u32> = p
                    .preds_of(node.id)
                    .iter()
                    .copied()
                    .filter(|&x| matches!(p.ops[x as usize].op, Op::AllGatherParams { .. }))
                    .collect();
                assert_eq!(gathers.len(), 1, "{}", node.op);
                assert!(matches!(
                    p.ops[gathers[0] as usize].op,
                    Op::AllGatherParams { layer: l } if l == layer
                ));
            }
            if let Op::AllGatherParams { .. } = node.op {
                // Stage-3 gathers precede the step: no OptimStep pred.
                assert!(p
                    .preds_of(node.id)
                    .iter()
                    .all(|&x| !matches!(p.ops[x as usize].op, Op::OptimStep { .. })));
            }
        }
        p.check_inorder_executable().unwrap();
    }

    #[test]
    fn queues_partition_the_arena() {
        let s = modular_pipeline(&spec(8, 2, 4, true));
        let p = lower(&s).unwrap();
        let queued: usize =
            p.queues.iter().map(|q| q.iter().map(Vec::len).sum::<usize>()).sum();
        assert_eq!(queued, p.len());
        for (stage, q) in p.queues.iter().enumerate() {
            for (si, ids) in q.iter().enumerate() {
                for &id in ids {
                    assert_eq!(p.ops[id as usize].stage as usize, stage);
                    assert_eq!(p.ops[id as usize].stream.index(), si);
                }
            }
        }
    }
}
