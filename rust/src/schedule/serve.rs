//! Forward-only serving schedules: prefill and decode.
//!
//! Inference reuses the training pipeline wholesale — micro-batch slots
//! become in-flight requests, pipeline stages stay stages, and the
//! tensor-parallel all-reduces stay per layer (the Megatron-LM
//! decomposition: one reduced activation per layer pass, forward
//! phase only). The generators here emit ordinary [`Schedule`]s with
//! zero `Bwd` ops; [`super::program::lower`] recognises the
//! forward-only compute contract (exactly one `Fwd`, zero `Bwd` per
//! (layer, mb)) and compiles them through the same CSR machinery, so
//! `repro verify` proves p2p matching, collective congruence, deadlock
//! freedom and the KV-aware memory bound for serving worlds exactly as
//! it does for training worlds.
//!
//! Two shapes:
//!
//! * **Prefill** ([`prefill_pipeline`]): each in-flight request's whole
//!   prompt runs through the pipeline as one micro-batch — GPipe-style
//!   forward-only pipelining, request-major per stage so requests
//!   overlap across stages.
//! * **Decode** ([`decode_wave`] / [`decode_waves`]): one wave advances
//!   every in-flight request by one token. A wave is layer-major per
//!   stage (all requests pass a layer before the next layer starts),
//!   the natural batched-GEMM order of a serving engine. Multi-wave
//!   programs encode token identity into the micro-batch index:
//!   `mb = token · n_req + request` (see [`decode_identity`]).
//!
//! The [`ScheduleSpec`] vocabulary is reused — `n_mu` is the in-flight
//! request count, `tp` the tensor-parallel degree — so the planner's
//! [`crate::planner::LoweringCache`] can memoise serving lowerings
//! beside training ones. `partition`/`offload`/`data_parallel` are
//! training-only concepts and must be off: serving keeps weights
//! resident and has no gradients to reduce.

use super::generators::ScheduleSpec;
use super::ir::{LayerAssignment, Op, Schedule};

/// Validate a spec for serving: the training-only axes must be off, and
/// the pipeline-starvation rule (`n_mu ≥ n_l`) is *not* applied — a
/// decode wave legitimately runs fewer in-flight requests than stages
/// (it bubbles, and the simulator prices that bubble).
fn validate_serve(spec: &ScheduleSpec) {
    assert!(
        spec.n_l > 0 && spec.d_l > 0 && spec.n_mu > 0 && spec.tp > 0,
        "zero dimension in serving spec"
    );
    assert!(
        spec.d_l % spec.n_l == 0,
        "d_l = {} not divisible by n_l = {}",
        spec.d_l,
        spec.n_l
    );
    assert!(
        !spec.partition && !spec.offload && !spec.data_parallel,
        "partition/offload/data_parallel are training-only axes"
    );
}

/// Emit one forward pass of layer `l` for micro-batch slot `mb` on
/// `stage`: boundary receive, compute, tensor-parallel reduce, boundary
/// send — the per-layer idiom every training generator uses, minus the
/// backward half.
fn push_fwd(ops: &mut Vec<Op>, spec: &ScheduleSpec, stage: usize, l: usize, mb: usize) {
    let a = LayerAssignment::Contiguous;
    if l > 0 && a.stage_of(l - 1, spec.d_l, spec.n_l) != stage {
        ops.push(Op::RecvAct { layer: l, mb });
    }
    ops.push(Op::Fwd { layer: l, mb });
    if spec.tp > 1 {
        ops.push(Op::TensorAllReduce { layer: l, mb, bwd: false });
    }
    if l + 1 < spec.d_l && a.stage_of(l + 1, spec.d_l, spec.n_l) != stage {
        ops.push(Op::SendAct { layer: l, mb });
    }
}

/// Prefill: `n_mu` in-flight requests, each one prompt as one
/// micro-batch, pipelined forward-only over `n_l` contiguous stages.
/// Request-major per stage, so request r+1 enters stage 0 while
/// request r runs on stage 1 — the training pipeline's fill phase,
/// which is *all* there is without a backward.
pub fn prefill_pipeline(spec: &ScheduleSpec) -> Schedule {
    validate_serve(spec);
    let assignment = LayerAssignment::Contiguous;
    let mut ops: Vec<Vec<Op>> = vec![Vec::new(); spec.n_l];
    for (stage, stage_ops) in ops.iter_mut().enumerate() {
        let layers = assignment.layers_of(stage, spec.d_l, spec.n_l);
        for mb in 0..spec.n_mu {
            for &l in &layers {
                push_fwd(stage_ops, spec, stage, l, mb);
            }
        }
    }
    Schedule {
        name: format!("serve-prefill(stages={}, tp={}, reqs={})", spec.n_l, spec.tp, spec.n_mu),
        n_stages: spec.n_l,
        d_l: spec.d_l,
        n_mu: spec.n_mu,
        assignment,
        ops,
        tp: spec.tp,
        partitioned: false,
        offloaded: false,
        zero: 0,
    }
}

/// One decode wave: every in-flight request (`n_mu` of them) advances
/// by one token. Layer-major per stage — the batched order a serving
/// engine runs, with one `TensorAllReduce` per (layer, request) when
/// `tp > 1`.
pub fn decode_wave(spec: &ScheduleSpec) -> Schedule {
    let mut s = decode_waves(spec, 1);
    s.name = format!("serve-decode(stages={}, tp={}, reqs={})", spec.n_l, spec.tp, spec.n_mu);
    s
}

/// `tokens` consecutive decode waves. Token identity rides in the
/// micro-batch index (`mb = token · n_req + request`, where
/// `n_req = spec.n_mu`), keeping every (layer, mb) pair unique so the
/// forward-only lowering contract holds; [`decode_identity`] inverts
/// the encoding for timeline labelling. Per-stage order is
/// wave-by-wave, but the lowering adds no cross-wave barrier: wave
/// t+1 may enter stage 0 while wave t drains later stages, which
/// models requests whose next token is already scheduled — the
/// continuous batcher accounts the sequential per-request dependency
/// by stepping one wave at a time.
pub fn decode_waves(spec: &ScheduleSpec, tokens: usize) -> Schedule {
    validate_serve(spec);
    assert!(tokens > 0, "a decode program needs at least one wave");
    let n_req = spec.n_mu;
    let assignment = LayerAssignment::Contiguous;
    let mut ops: Vec<Vec<Op>> = vec![Vec::new(); spec.n_l];
    for (stage, stage_ops) in ops.iter_mut().enumerate() {
        let layers = assignment.layers_of(stage, spec.d_l, spec.n_l);
        for t in 0..tokens {
            for &l in &layers {
                for r in 0..n_req {
                    push_fwd(stage_ops, spec, stage, l, t * n_req + r);
                }
            }
        }
    }
    Schedule {
        name: format!(
            "serve-decode(stages={}, tp={}, reqs={}, tokens={tokens})",
            spec.n_l, spec.tp, n_req
        ),
        n_stages: spec.n_l,
        d_l: spec.d_l,
        n_mu: n_req * tokens,
        assignment,
        ops,
        tp: spec.tp,
        partitioned: false,
        offloaded: false,
        zero: 0,
    }
}

/// Invert the decode micro-batch encoding: `mb -> (token, request)`
/// for a program built with `n_req` in-flight requests.
pub fn decode_identity(mb: usize, n_req: usize) -> (usize, usize) {
    let n = n_req.max(1);
    (mb / n, mb % n)
}

#[cfg(test)]
mod tests {
    use super::super::validate::validate;
    use super::*;

    fn spec(d_l: usize, n_l: usize, n_mu: usize, tp: usize) -> ScheduleSpec {
        ScheduleSpec {
            d_l,
            n_l,
            n_mu,
            tp,
            partition: false,
            offload: false,
            data_parallel: false,
            zero: 0,
        }
    }

    #[test]
    fn prefill_lowers_cleanly_across_the_grid() {
        for (d_l, n_l) in [(8, 1), (8, 2), (8, 4), (12, 3)] {
            for n_mu in [1, 2, 6] {
                for tp in [1, 2] {
                    let s = prefill_pipeline(&spec(d_l, n_l, n_mu, tp));
                    validate(&s).unwrap_or_else(|e| panic!("{}: {e:?}", s.name));
                }
            }
        }
    }

    #[test]
    fn decode_waves_lower_cleanly_across_the_grid() {
        for (d_l, n_l) in [(8, 1), (8, 2), (8, 4)] {
            for n_req in [1, 2, 4] {
                for tokens in [1, 3] {
                    for tp in [1, 2] {
                        let sp = spec(d_l, n_l, n_req, tp);
                        let s = decode_waves(&sp, tokens);
                        validate(&s).unwrap_or_else(|e| panic!("{}: {e:?}", s.name));
                        assert_eq!(s.n_mu, n_req * tokens);
                    }
                }
            }
        }
    }

    #[test]
    fn serving_schedules_are_forward_only() {
        let p = prefill_pipeline(&spec(8, 4, 3, 2));
        let d = decode_waves(&spec(8, 4, 3, 2), 2);
        for s in [&p, &d] {
            assert_eq!(s.count(|o| matches!(o, Op::Bwd { .. })), 0, "{}", s.name);
            assert_eq!(s.count(|o| matches!(o, Op::ReduceGrad { .. })), 0, "{}", s.name);
            assert_eq!(s.count(|o| matches!(o, Op::OptimStep { .. })), 0, "{}", s.name);
            assert_eq!(
                s.count(|o| matches!(o, Op::TensorAllReduce { bwd: true, .. })),
                0,
                "{}",
                s.name
            );
        }
        // Exactly one Fwd per (layer, slot), with the per-layer forward
        // all-reduce beside it.
        assert_eq!(p.count(|o| matches!(o, Op::Fwd { .. })), 8 * 3);
        assert_eq!(p.count(|o| matches!(o, Op::TensorAllReduce { .. })), 8 * 3);
        assert_eq!(d.count(|o| matches!(o, Op::Fwd { .. })), 8 * 3 * 2);
    }

    #[test]
    fn single_stage_has_no_transfers() {
        let s = prefill_pipeline(&spec(8, 1, 4, 1));
        assert_eq!(s.count(|o| matches!(o, Op::SendAct { .. } | Op::RecvAct { .. })), 0);
        assert_eq!(s.count(|o| matches!(o, Op::Fwd { .. })), 32);
    }

    #[test]
    fn decode_identity_roundtrips() {
        let n_req = 3;
        for t in 0..4 {
            for r in 0..n_req {
                assert_eq!(decode_identity(t * n_req + r, n_req), (t, r));
            }
        }
        assert_eq!(decode_identity(5, 0), (5, 0));
    }

    #[test]
    fn fewer_requests_than_stages_is_legal_for_serving() {
        // Training's n_mu >= n_l starvation rule does not apply: a
        // half-empty decode wave is a real serving state.
        let s = decode_wave(&spec(8, 4, 1, 1));
        validate(&s).expect("starved decode wave must still lower");
    }
}
