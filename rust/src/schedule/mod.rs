//! Schedule IR, generators, lowering and validation.
//!
//! The scheduling subsystem is a small compiler pipeline:
//!
//! ```text
//! generate  ──►  lower  ──►  validate | simulate | execute
//! (policy)      (graph)      (one shared dependency graph)
//! ```
//!
//! Generators ([`generators`]) express the paper's policies — standard /
//! layered gradient accumulation × contiguous / modular pipeline split,
//! plus the 1F1B and interleaved-1F1B Megatron-LM baselines — as
//! per-stage ordered op lists ([`ir::Schedule`]); the forward-only
//! serving generators ([`serve`]) emit inference prefill/decode
//! programs through the same IR. The lowering pass
//! ([`program::lower`]) compiles a schedule once into a
//! [`program::ScheduleProgram`]: a flat op arena with explicit dependency
//! edges and per-stream run queues. The validator ([`validate`]), the
//! discrete-event simulator ([`crate::sim`]) and the real PJRT trainer
//! ([`crate::trainer`]) all consume that one program, so the simulated
//! and executed semantics cannot drift apart.

pub mod generators;
pub mod ir;
pub mod program;
pub mod serve;
pub mod validate;

pub use generators::{
    interleaved_1f1b, interleaved_applicable, layered_ga, modular_pipeline, one_f_one_b,
    standard_ga, ScheduleSpec,
};
pub use ir::{LayerAssignment, Op, Schedule};
pub use program::{lower, ProgOp, ScheduleProgram, Stream, N_STREAMS, STREAMS};
pub use serve::{decode_identity, decode_wave, decode_waves, prefill_pipeline};
pub use validate::{validate, ScheduleError};
