//! Schedule IR, generators and validation.
//!
//! The four scheduling policies of Figures 1–3 (standard/layered gradient
//! accumulation × contiguous/modular pipeline split) plus 1F1B, expressed
//! as per-stage ordered op lists that both the discrete-event simulator
//! ([`crate::sim`]) and the real trainer ([`crate::trainer`]) execute.

pub mod generators;
pub mod ir;
pub mod validate;

pub use generators::{layered_ga, modular_pipeline, one_f_one_b, standard_ga, ScheduleSpec};
pub use ir::{LayerAssignment, Op, Schedule};
pub use validate::{validate, ScheduleError};
