//! Continuous-batching simulator: a thin serving driver over
//! `sim/engine.rs`.
//!
//! The batcher alternates two phases over one shared pipeline, the
//! standard chunked continuous-batching discipline:
//!
//! 1. **Admit + prefill.** Requests whose arrival time has passed are
//!    admitted up to the batch cap (the user's `--max-batch`, clamped
//!    by the KV admission limit) and their prompts run one pipelined
//!    forward pass together.
//! 2. **Decode wave.** Every in-flight request advances one token.
//!    Requests that reach their output length complete at the wave
//!    boundary and their cache slots are evicted before the next
//!    admission check.
//!
//! Wave and prefill latencies are not modelled analytically: each
//! distinct (batch, tokens-per-fwd) point lowers the actual serving
//! schedule (memoised in the planner's [`LoweringCache`]) and runs the
//! discrete-event simulator against the (optionally wire-calibrated)
//! [`CostTable`]. The batcher's event loop is then pure arithmetic
//! over those measured wave latencies, so thousand-request traces cost
//! only a handful of simulations.

use std::collections::HashMap;

use crate::costmodel::{KvCacheModel, Strategy, TrainConfig};
use crate::hardware::ClusterSpec;
use crate::model::TransformerShape;
use crate::planner::{LoweringCache, PolicyKind};
use crate::runtime::DType;
use crate::schedule::ScheduleSpec;
use crate::sim::{simulate_program_opts, CostTable, SimOptions};

use super::Trace;

/// Simulated serving latencies for one deployment `{stages, tp}` of a
/// model shape on a cluster: prefill time per (batch, prompt) and
/// decode-wave time per batch, each measured by simulating the
/// compiled forward-only schedule and memoised.
pub struct ServeCosts<'a> {
    shape: &'a TransformerShape,
    cluster: &'a ClusterSpec,
    pub stages: usize,
    pub tp: usize,
    prefill: HashMap<(usize, usize), f64>,
    decode: HashMap<usize, f64>,
}

impl<'a> ServeCosts<'a> {
    pub fn new(
        shape: &'a TransformerShape,
        cluster: &'a ClusterSpec,
        stages: usize,
        tp: usize,
    ) -> Self {
        assert!(stages > 0 && shape.d_l % stages == 0, "stages must divide d_l");
        ServeCosts { shape, cluster, stages, tp, prefill: HashMap::new(), decode: HashMap::new() }
    }

    fn spec(&self, batch: usize) -> ScheduleSpec {
        ScheduleSpec {
            d_l: self.shape.d_l,
            n_l: self.stages,
            n_mu: batch,
            tp: self.tp,
            partition: false,
            offload: false,
            data_parallel: false,
            zero: 0,
        }
    }

    /// Cost table for forward passes covering `tokens_per_fwd` tokens
    /// each. The training table prices one `Fwd` as `b_μ · d_s` tokens
    /// of compute, so a serving pass over T tokens is exactly
    /// `b_μ = T / d_s` — prompt-length for prefill, 1/d_s for decode.
    pub fn table(&self, tokens_per_fwd: usize) -> CostTable {
        let cfg = TrainConfig {
            strategy: Strategy::Improved,
            n_b: 1,
            n_l: self.stages,
            n_a: self.tp,
            n_mu: 1,
            b_mu: tokens_per_fwd as f64 / self.shape.d_s as f64,
            offload: false,
            partition: false,
            zero: 0,
        };
        CostTable::new(self.shape, &cfg, self.cluster)
    }

    /// Simulated makespan of one serving program.
    fn simulate(&self, kind: PolicyKind, batch: usize, tokens_per_fwd: usize) -> f64 {
        let program = LoweringCache::global().lower(kind, &self.spec(batch));
        let costs = self.table(tokens_per_fwd);
        simulate_program_opts(&program, &costs, SimOptions { record_timeline: false }).makespan
    }

    /// Wall-clock of prefilling `batch` prompts of `prompt` tokens
    /// through the pipeline together.
    pub fn prefill_latency(&mut self, batch: usize, prompt: usize) -> f64 {
        if let Some(&v) = self.prefill.get(&(batch, prompt)) {
            return v;
        }
        let v = self.simulate(PolicyKind::ServePrefill, batch, prompt);
        self.prefill.insert((batch, prompt), v);
        v
    }

    /// Wall-clock of one decode wave advancing `batch` requests by one
    /// token each.
    pub fn decode_latency(&mut self, batch: usize) -> f64 {
        if let Some(&v) = self.decode.get(&batch) {
            return v;
        }
        let v = self.simulate(PolicyKind::ServeDecode, batch, 1);
        self.decode.insert(batch, v);
        v
    }
}

/// Per-request outcome.
#[derive(Debug, Clone, Copy)]
pub struct RequestMetrics {
    pub id: usize,
    pub arrival: f64,
    /// When the request entered the batch (start of its prefill).
    pub admitted: f64,
    /// When its first output token completed (end of its first decode
    /// wave) — TTFT is `first_token - arrival`.
    pub first_token: f64,
    pub finish: f64,
    pub decode: usize,
}

impl RequestMetrics {
    pub fn ttft(&self) -> f64 {
        self.first_token - self.arrival
    }
}

/// Aggregate serving report for one trace on one deployment.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub stages: usize,
    pub tp: usize,
    /// Effective batch cap: `min(max_batch, KV admission limit)`.
    pub cap: usize,
    /// What bound the cap: `"max-batch"` or `"kv-admission"`.
    pub cap_bound: &'static str,
    pub completed: usize,
    pub waves: usize,
    /// Clock when the last request finished (time origin = first
    /// arrival at 0).
    pub makespan: f64,
    pub ttft_p50: f64,
    pub ttft_p99: f64,
    pub token_p50: f64,
    pub token_p99: f64,
    /// Decoded output tokens per second of makespan.
    pub tokens_per_sec: f64,
    pub peak_in_flight: usize,
    /// Highest per-rank residency (weights + live KV) the run reached.
    pub kv_peak_bytes: f64,
    pub per_request: Vec<RequestMetrics>,
}

/// Nearest-rank percentile of an unsorted sample; 0 for an empty one.
pub(crate) fn percentile(values: &[f64], p: f64) -> f64 {
    if values.is_empty() {
        return 0.0;
    }
    let mut v = values.to_vec();
    v.sort_by(|a, b| a.total_cmp(b));
    let rank = ((p / 100.0) * v.len() as f64).ceil() as usize;
    v[rank.clamp(1, v.len()) - 1]
}

struct Active {
    id: usize,
    remaining: usize,
    produced: usize,
    prompt: usize,
}

/// Run `trace` through the continuous batcher on a `{stages, tp}`
/// deployment capped at `max_batch` in-flight requests. Fails with a
/// diagnostic naming the binding constraint when the deployment cannot
/// admit even one request.
pub fn run_trace(
    shape: &TransformerShape,
    cluster: &ClusterSpec,
    stages: usize,
    tp: usize,
    max_batch: usize,
    trace: &Trace,
) -> Result<ServeReport, String> {
    if trace.requests.is_empty() {
        return Err("empty trace".into());
    }
    if max_batch == 0 {
        return Err("max_batch must be at least 1".into());
    }
    let kv = KvCacheModel::new(shape, stages, tp, DType::F32, cluster.gpu.memory_bytes);
    let context = trace.max_context();
    let admission = kv.admission_limit(context);
    if admission == 0 {
        return Err(if kv.budget < kv.weight_bytes {
            format!(
                "infeasible: resident weights ({:.3e} B/rank) exceed the device budget \
                 ({:.3e} B) at stages={stages}, tp={tp} — shard further",
                kv.weight_bytes, kv.budget
            )
        } else {
            format!(
                "infeasible: one request's KV cache at context {context} ({:.3e} B/rank) \
                 does not fit beside the weights ({:.3e} B of {:.3e} B budget) at \
                 stages={stages}, tp={tp}",
                kv.request_bytes(context),
                kv.weight_bytes,
                kv.budget
            )
        });
    }
    let (cap, cap_bound) = if max_batch <= admission {
        (max_batch, "max-batch")
    } else {
        (admission, "kv-admission")
    };

    let mut costs = ServeCosts::new(shape, cluster, stages, tp);
    let mut queue: Vec<&super::Request> = trace.requests.iter().collect();
    queue.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
    let mut next = 0usize; // first not-yet-admitted request
    let mut active: Vec<Active> = Vec::new();
    let mut done: Vec<RequestMetrics> = Vec::new();
    let mut token_lats: Vec<f64> = Vec::new();
    let mut t = 0.0f64;
    let mut waves = 0usize;
    let mut peak_in_flight = 0usize;
    let mut kv_peak = kv.weight_bytes;
    // Indexed by request id.
    let mut metrics: Vec<RequestMetrics> = trace
        .requests
        .iter()
        .map(|r| RequestMetrics {
            id: r.id,
            arrival: r.arrival,
            admitted: f64::NAN,
            first_token: f64::NAN,
            finish: f64::NAN,
            decode: r.decode,
        })
        .collect();

    while next < queue.len() || !active.is_empty() {
        // Admission: fill free slots with requests that have arrived.
        let mut newly: Vec<usize> = Vec::new(); // indices into `active`
        while next < queue.len() && active.len() < cap && queue[next].arrival <= t {
            let r = queue[next];
            metrics[r.id].admitted = t;
            active.push(Active { id: r.id, remaining: r.decode, produced: 0, prompt: r.prompt });
            newly.push(active.len() - 1);
            next += 1;
        }
        if active.is_empty() {
            // Idle: jump to the next arrival.
            t = t.max(queue[next].arrival);
            continue;
        }
        peak_in_flight = peak_in_flight.max(active.len());

        // Prefill the newly admitted prompts as one pipelined pass.
        if !newly.is_empty() {
            let prompt = newly.iter().map(|&i| active[i].prompt).max().unwrap();
            t += costs.prefill_latency(newly.len(), prompt);
        }

        // One decode wave over everything in flight.
        let dt = costs.decode_latency(active.len());
        t += dt;
        waves += 1;
        let mut resident = kv.weight_bytes;
        for a in active.iter_mut() {
            a.produced += 1;
            a.remaining -= 1;
            token_lats.push(dt);
            let m = &mut metrics[a.id];
            if m.first_token.is_nan() {
                m.first_token = t;
            }
            resident += kv.request_bytes(a.prompt + a.produced);
        }
        kv_peak = kv_peak.max(resident);
        // Evict completions at the wave boundary.
        active.retain(|a| {
            if a.remaining == 0 {
                metrics[a.id].finish = t;
                done.push(metrics[a.id]);
                false
            } else {
                true
            }
        });
    }

    let ttfts: Vec<f64> = done.iter().map(|m| m.ttft()).collect();
    let total_tokens = trace.total_decode_tokens();
    done.sort_by_key(|m| m.id);
    Ok(ServeReport {
        stages,
        tp,
        cap,
        cap_bound,
        completed: done.len(),
        waves,
        makespan: t,
        ttft_p50: percentile(&ttfts, 50.0),
        ttft_p99: percentile(&ttfts, 99.0),
        token_p50: percentile(&token_lats, 50.0),
        token_p99: percentile(&token_lats, 99.0),
        tokens_per_sec: if t > 0.0 { total_tokens as f64 / t } else { 0.0 },
        peak_in_flight,
        kv_peak_bytes: kv_peak,
        per_request: done,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::XModel;

    fn setup() -> (TransformerShape, ClusterSpec) {
        (XModel::new(8).shape(), ClusterSpec::reference())
    }

    #[test]
    fn percentile_is_nearest_rank() {
        let v = [4.0, 1.0, 3.0, 2.0];
        assert_eq!(percentile(&v, 50.0), 2.0);
        assert_eq!(percentile(&v, 99.0), 4.0);
        assert_eq!(percentile(&v, 100.0), 4.0);
        assert_eq!(percentile(&[], 50.0), 0.0);
    }

    #[test]
    fn single_request_latency_is_prefill_plus_decode_waves() {
        let (shape, cluster) = setup();
        let trace = Trace::uniform(1, 1.0, 16, 4);
        let r = run_trace(&shape, &cluster, 1, 1, 8, &trace).unwrap();
        let mut costs = ServeCosts::new(&shape, &cluster, 1, 1);
        let prefill = costs.prefill_latency(1, 16);
        let wave = costs.decode_latency(1);
        let m = r.per_request[0];
        assert!((m.ttft() - (prefill + wave)).abs() < 1e-12, "ttft {}", m.ttft());
        assert!((m.finish - (prefill + 4.0 * wave)).abs() < 1e-12);
        assert_eq!(r.completed, 1);
        assert_eq!(r.waves, 4);
    }

    #[test]
    fn identity_single_stage_latency_is_the_summed_op_cost() {
        // 1 stage, tp = 1, one request: the simulated prefill is d_l
        // serial Fwd ops and a wave is d_l one-token Fwd ops — the
        // batcher's latency must equal the summed per-op cost exactly.
        let (shape, cluster) = setup();
        let mut costs = ServeCosts::new(&shape, &cluster, 1, 1);
        let d_l = shape.d_l as f64;
        assert!((costs.prefill_latency(1, 16) - d_l * costs.table(16).fwd).abs() < 1e-15);
        assert!((costs.decode_latency(1) - d_l * costs.table(1).fwd).abs() < 1e-15);
    }

    #[test]
    fn batcher_is_deterministic_and_conserves_tokens() {
        let (shape, cluster) = setup();
        let trace = Trace::poisson(7, 50.0, 24, 16, 6);
        let a = run_trace(&shape, &cluster, 2, 1, 4, &trace).unwrap();
        let b = run_trace(&shape, &cluster, 2, 1, 4, &trace).unwrap();
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.waves, b.waves);
        assert_eq!(a.makespan, b.makespan);
        assert_eq!(a.completed, 24);
        // Every decoded token shows up once in the per-token sample.
        assert!(a.waves >= 6, "at least one request's worth of waves");
        let tokens: usize = a.per_request.iter().map(|m| m.decode).sum();
        assert_eq!(tokens, trace.total_decode_tokens());
        assert!((a.tokens_per_sec * a.makespan - tokens as f64).abs() < 1e-6);
        assert!(a.peak_in_flight <= a.cap);
    }

    #[test]
    fn overload_raises_tail_latency() {
        let (shape, cluster) = setup();
        let mut costs = ServeCosts::new(&shape, &cluster, 2, 1);
        let wave = costs.decode_latency(4);
        // Offered rate far above and far below one request per wave.
        let slow = Trace::uniform(16, wave * 0.01, 16, 8);
        let fast = Trace::uniform(16, wave * 100.0, 16, 8);
        let hot = run_trace(&shape, &cluster, 2, 1, 4, &slow).unwrap();
        let cold = run_trace(&shape, &cluster, 2, 1, 4, &fast).unwrap();
        assert!(
            hot.ttft_p99 > cold.ttft_p99,
            "queueing at overload must raise p99 TTFT ({} vs {})",
            hot.ttft_p99,
            cold.ttft_p99
        );
    }

    #[test]
    fn infeasible_deployments_name_the_binding_constraint() {
        let (shape, _) = setup();
        let mut small = ClusterSpec::reference();
        small.gpu.memory_bytes = 1.0;
        let trace = Trace::uniform(2, 1.0, 16, 4);
        let err = run_trace(&shape, &small, 1, 1, 4, &trace).unwrap_err();
        assert!(err.contains("infeasible"), "{err}");
        assert!(err.contains("weights"), "{err}");
    }
}
