//! Inference serving: continuous batching over the compiled-schedule
//! pipeline.
//!
//! This module turns the training machinery into a serving engine
//! without forking any of it. The request lifecycle maps onto the
//! existing concepts one-to-one:
//!
//! ```text
//! request arrives ──► admitted into a batch slot (micro-batch index)
//!   │                   gated by the KV admission limit
//!   ├─ prefill: the prompt runs the forward pipeline once
//!   │    (schedule::prefill_pipeline — GPipe fill phase, no drain)
//!   ├─ decode: one token per wave, all in-flight requests together
//!   │    (schedule::decode_wave — layer-major stage waves,
//!   │     TensorAllReduce per layer when tp > 1)
//!   └─ completion: the request leaves, its KV cache is evicted
//! ```
//!
//! * **Schedules** are forward-only [`crate::schedule::Schedule`]s,
//!   lowered through the same CSR machinery and verified by the same
//!   whole-world analyzer (`repro verify`) as training — with the
//!   KV-cache taking the activation checkpoints' place in the static
//!   memory walk ([`crate::analysis::MemoryModel::serving`]).
//! * **Memory** is priced by [`crate::costmodel::KvCacheModel`]: the
//!   admission limit (how many requests fit at full context beside the
//!   resident weights) gates the batcher.
//! * **Time** comes from the discrete-event simulator: per-wave
//!   latencies are measured by simulating the compiled prefill/decode
//!   programs against the calibrated [`crate::sim::CostTable`]
//!   (memoised per batch size in [`batcher::ServeCosts`]).
//! * **Load** is a seeded Poisson stream or an explicit trace
//!   ([`Trace`]), drawn from the shared audited PRNG
//!   ([`crate::sim::Xorshift`]) so every run is replayable.
//!
//! The continuous batcher ([`batcher::run_trace`]) alternates
//! admission+prefill with decode waves and reports p50/p99
//! time-to-first-token, per-token latency and tokens/sec; the SLO
//! planner ([`crate::planner::slo`]) searches {stages, tp, max batch}
//! over these reports.

pub mod batcher;

pub use batcher::{run_trace, RequestMetrics, ServeCosts, ServeReport};

use crate::sim::Xorshift;

/// One inference request: arrival time (seconds), prompt length and
/// the number of output tokens to decode.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Request {
    pub id: usize,
    pub arrival: f64,
    pub prompt: usize,
    pub decode: usize,
}

/// A request stream, sorted by arrival time.
#[derive(Debug, Clone, PartialEq)]
pub struct Trace {
    pub requests: Vec<Request>,
}

impl Trace {
    /// Seeded Poisson arrivals: `n` requests at `rate` per second
    /// (exponential inter-arrival gaps from the shared generator),
    /// each `prompt` tokens in and `decode` tokens out.
    pub fn poisson(seed: u64, rate: f64, n: usize, prompt: usize, decode: usize) -> Trace {
        assert!(rate > 0.0, "arrival rate must be positive");
        let mut rng = Xorshift::new(seed);
        let mut t = 0.0;
        let requests = (0..n)
            .map(|id| {
                t += rng.next_exp(rate);
                Request { id, arrival: t, prompt, decode }
            })
            .collect();
        Trace { requests }
    }

    /// Deterministic uniform arrivals: `n` requests `gap` seconds
    /// apart — the regression-test stream (no randomness at all).
    pub fn uniform(n: usize, gap: f64, prompt: usize, decode: usize) -> Trace {
        let requests = (0..n)
            .map(|id| Request { id, arrival: id as f64 * gap, prompt, decode })
            .collect();
        Trace { requests }
    }

    /// Parse a trace file: one request per line as
    /// `arrival_secs prompt_tokens decode_tokens`, `#` comments and
    /// blank lines ignored. Requests are re-sorted by arrival.
    pub fn parse(text: &str) -> Result<Trace, String> {
        let mut requests = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.split('#').next().unwrap_or("").trim();
            if line.is_empty() {
                continue;
            }
            let fields: Vec<&str> = line.split_whitespace().collect();
            if fields.len() != 3 {
                return Err(format!(
                    "trace line {}: want `arrival prompt decode`, got {line:?}",
                    lineno + 1
                ));
            }
            let arrival: f64 = fields[0]
                .parse()
                .map_err(|e| format!("trace line {}: bad arrival: {e}", lineno + 1))?;
            let prompt: usize = fields[1]
                .parse()
                .map_err(|e| format!("trace line {}: bad prompt length: {e}", lineno + 1))?;
            let decode: usize = fields[2]
                .parse()
                .map_err(|e| format!("trace line {}: bad decode length: {e}", lineno + 1))?;
            if prompt == 0 || decode == 0 {
                return Err(format!(
                    "trace line {}: prompt and decode must be nonzero",
                    lineno + 1
                ));
            }
            requests.push(Request { id: requests.len(), arrival, prompt, decode });
        }
        if requests.is_empty() {
            return Err("trace holds no requests".into());
        }
        let mut t = Trace { requests };
        t.requests.sort_by(|a, b| a.arrival.total_cmp(&b.arrival));
        for (id, r) in t.requests.iter_mut().enumerate() {
            r.id = id;
        }
        Ok(t)
    }

    /// Largest full context (`prompt + decode`) any request reaches —
    /// what the admission limit must budget for.
    pub fn max_context(&self) -> usize {
        self.requests.iter().map(|r| r.prompt + r.decode).max().unwrap_or(0)
    }

    /// Total output tokens the whole trace decodes.
    pub fn total_decode_tokens(&self) -> usize {
        self.requests.iter().map(|r| r.decode).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_is_seed_deterministic() {
        let a = Trace::poisson(11, 4.0, 64, 32, 8);
        let b = Trace::poisson(11, 4.0, 64, 32, 8);
        assert_eq!(a, b);
        let c = Trace::poisson(12, 4.0, 64, 32, 8);
        assert_ne!(a, c, "different seeds must produce different arrival streams");
    }

    #[test]
    fn poisson_arrivals_are_increasing_at_roughly_the_rate() {
        let t = Trace::poisson(5, 10.0, 2000, 16, 4);
        assert!(t.requests.windows(2).all(|w| w[0].arrival < w[1].arrival));
        let span = t.requests.last().unwrap().arrival;
        let rate = t.requests.len() as f64 / span;
        assert!((rate / 10.0 - 1.0).abs() < 0.1, "measured rate {rate}, want ~10");
    }

    #[test]
    fn uniform_trace_is_exact() {
        let t = Trace::uniform(4, 0.5, 32, 8);
        let arr: Vec<f64> = t.requests.iter().map(|r| r.arrival).collect();
        assert_eq!(arr, vec![0.0, 0.5, 1.0, 1.5]);
        assert_eq!(t.max_context(), 40);
        assert_eq!(t.total_decode_tokens(), 32);
    }

    #[test]
    fn parse_roundtrips_and_sorts() {
        let t = Trace::parse(
            "# a comment\n0.5 32 8\n0.0 16 4  # inline comment\n\n1.0 8 2\n",
        )
        .unwrap();
        assert_eq!(t.requests.len(), 3);
        assert_eq!(t.requests[0].prompt, 16);
        assert_eq!(t.requests[0].id, 0);
        assert_eq!(t.requests[2].arrival, 1.0);
        assert!(Trace::parse("").is_err());
        assert!(Trace::parse("0.0 32").is_err());
        assert!(Trace::parse("0.0 32 0").is_err());
        assert!(Trace::parse("x 32 8").is_err());
    }
}
