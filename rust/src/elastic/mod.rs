//! Elastic training (paper §8): dynamic critical batch size ("don't decay
//! the learning rate, increase the cluster size", §8.1) and the
//! cluster-resize replanning that real-time checkpoints make nearly free
//! (§8.2).
//!
//! Model, following McCandlish et al. (the paper's [15]): reaching a
//! given loss requires E "effective samples"; training at batch size b
//! when the critical batch size is b_c consumes E·(1 + b/b_c) actual
//! samples. The critical batch size grows during training; a fixed-size
//! cluster therefore trains far above b_c early on and wastes compute,
//! while an elastic cluster sized to b ≈ b_c(t) stays efficient.

use crate::model::XModel;

/// Critical-batch-size trajectory: b_c at progress fraction f ∈ [0, 1],
/// relative to the late-training value the paper's tables use.
/// McCandlish et al. observe b_c roughly proportional to L^(-~4), which
/// over a typical LM run maps to a steep ramp; we use b_c(f) ≈
/// b_c_final · max(f, f0) as a serviceable first-order model.
pub fn bc_fraction(f: f64, f0: f64) -> f64 {
    f.clamp(f0, 1.0)
}

/// One phase of the elastic-vs-fixed comparison.
#[derive(Debug, Clone, Copy)]
pub struct Phase {
    /// Progress fraction at the phase midpoint.
    pub f: f64,
    /// Effective samples required by the phase (arbitrary units).
    pub effective: f64,
}

/// Outcome of running the phases with a policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct ElasticOutcome {
    /// Total samples processed (∝ GPU-hours ∝ cost).
    pub samples: f64,
    /// Total wall-clock (arbitrary units; rate ∝ cluster size).
    pub wall: f64,
    /// Peak cluster size used (fraction of the maximum).
    pub peak_cluster: f64,
}

/// Phases for the comparison. Effective-sample demand grows with
/// progress (∝ f): late training, where gradients are noisy and b_c is
/// large, consumes most of the sample budget — the same observation that
/// drives the critical-batch-size growth itself.
pub fn default_phases(n: usize) -> Vec<Phase> {
    let norm: f64 = (0..n).map(|i| (i as f64 + 0.5) / n as f64).sum();
    (0..n)
        .map(|i| {
            let f = (i as f64 + 0.5) / n as f64;
            Phase { f, effective: f / norm }
        })
        .collect()
}

/// Fixed-size cluster: batch pinned to the late-training b_c.
pub fn run_fixed(phases: &[Phase], f0: f64) -> ElasticOutcome {
    let mut samples = 0.0;
    let mut wall = 0.0;
    for p in phases {
        let ratio = 1.0 / bc_fraction(p.f, f0); // b / b_c(f)
        let s = p.effective * (1.0 + ratio);
        samples += s;
        wall += s; // cluster size 1.0 (normalised), rate ∝ size
    }
    ElasticOutcome { samples, wall, peak_cluster: 1.0 }
}

/// Elastic cluster: batch (and cluster) scaled to b_c(f).
pub fn run_elastic(phases: &[Phase], f0: f64) -> ElasticOutcome {
    let mut samples = 0.0;
    let mut wall = 0.0;
    let mut peak: f64 = 0.0;
    for p in phases {
        let size = bc_fraction(p.f, f0); // cluster ∝ b = b_c(f)
        let s = p.effective * 2.0; // b = b_c -> (1 + b/b_c) = 2
        samples += s;
        wall += s / size;
        peak = peak.max(size);
    }
    ElasticOutcome { samples, wall, peak_cluster: peak }
}

/// §8.2: downtime for a cluster-resize event, seconds. Classic
/// checkpointing stalls the whole cluster for a save + load; with
/// real-time (streamed) checkpoints the new node loads its shard on the
/// fly and the rest keep training.
pub fn resize_downtime_secs(state_bytes: f64, tier_bandwidth: f64, realtime: bool) -> f64 {
    if realtime {
        0.0
    } else {
        2.0 * state_bytes / tier_bandwidth // save + load
    }
}

/// Smallest batch (sequences) worth scheduling a cluster for: below this
/// the gradient noise floor, not the hardware, limits progress, so the
/// elastic schedule never shrinks the batch past it.
pub const MIN_BATCH_SEQS: f64 = 32.0;

/// The §8.1 cluster-size schedule for a model: GPUs to use at progress f,
/// given the fastest-plan cluster size at the late-training b_c. The
/// early-training floor is the caller's `f0` combined with a
/// model-derived one: the batch never drops below [`MIN_BATCH_SEQS`]
/// sequences, i.e. the cluster fraction never drops below
/// `MIN_BATCH_SEQS / b_c(final)` — larger models, whose critical batch
/// is bigger, can therefore shrink *further* early in training.
pub fn cluster_schedule(model: &XModel, n_gpu_max: usize, steps: usize, f0: f64) -> Vec<(f64, usize)> {
    let f_floor = f0.max((MIN_BATCH_SEQS / model.critical_batch_size()).min(1.0));
    (0..steps)
        .map(|i| {
            let f = (i as f64 + 0.5) / steps as f64;
            (f, ((n_gpu_max as f64) * bc_fraction(f, f_floor)).round().max(1.0) as usize)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn elastic_is_cheaper_without_being_much_slower() {
        // §8.1: "reduces the cost of training without significantly
        // affecting the training time".
        let phases = default_phases(100);
        let fixed = run_fixed(&phases, 0.05);
        let elastic = run_elastic(&phases, 0.05);
        assert!(
            elastic.samples < 0.75 * fixed.samples,
            "cost: elastic {} vs fixed {}",
            elastic.samples,
            fixed.samples
        );
        assert!(
            elastic.wall < 1.5 * fixed.wall,
            "wall: elastic {} vs fixed {}",
            elastic.wall,
            fixed.wall
        );
    }

    #[test]
    fn elastic_peak_cluster_matches_fixed() {
        let phases = default_phases(50);
        let e = run_elastic(&phases, 0.1);
        assert!((e.peak_cluster - 1.0).abs() < 0.02);
    }

    #[test]
    fn realtime_checkpoints_eliminate_resize_downtime() {
        let classic = resize_downtime_secs(2e12, 3.2e9, false);
        assert!(classic > 600.0); // 20+ minutes for a 2 TB state on NVMe
        assert_eq!(resize_downtime_secs(2e12, 3.2e9, true), 0.0);
    }

    #[test]
    fn cluster_schedule_is_monotone() {
        let sched = cluster_schedule(&XModel::x160(), 38_640, 20, 0.05);
        assert!(sched.windows(2).all(|w| w[0].1 <= w[1].1));
        assert_eq!(sched.last().unwrap().1, 37_674); // ~n_max at the end
    }

    #[test]
    fn cluster_schedule_floor_scales_with_the_model() {
        // With f0 = 0 the model floor binds early on: the cluster still
        // processes MIN_BATCH_SEQS sequences per step. (The argument used
        // to be ignored entirely — `let _ = model;`.)
        let big = XModel::x160();
        let sched = cluster_schedule(&big, 38_640, 100, 0.0);
        let want = (38_640.0 * (MIN_BATCH_SEQS / big.critical_batch_size())).round() as usize;
        assert_eq!(sched[0].1, want);
        assert!(want > 1, "floor must actually bind in this setup");
        // A smaller model has a smaller critical batch, hence a *larger*
        // relative floor — its cluster cannot shrink as far.
        let small = XModel::new(32);
        let s2 = cluster_schedule(&small, 38_640, 100, 0.0);
        assert!(s2[0].1 > sched[0].1, "{} vs {}", s2[0].1, sched[0].1);
        // Late-training sizes are unaffected by the floor.
        assert_eq!(s2.last().unwrap().1, sched.last().unwrap().1);
    }
}
