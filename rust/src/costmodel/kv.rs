//! KV-cache memory model for the serving workload.
//!
//! Inference replaces the training memory ledger wholesale: there is no
//! optimizer state, no gradients, no activation checkpoints — the
//! footprint is resident weights plus the per-request key/value cache,
//! which grows by one (K, V) pair per layer per generated token and
//! lives until the request completes. The KV cache is the serving
//! analogue of the activation-checkpoint term the training model
//! stashes between forward and backward: it is acquired by `Fwd` and
//! — unlike training — never released by a `Bwd`, so a forward-only
//! program's static memory walk shows exactly the monotone cache
//! growth of a decode.
//!
//! All byte accounting routes through [`DType::bytes`], the same
//! plumbing every other byte path in the repo uses, so a future
//! half-precision cache automatically re-prices admission limits.

use crate::model::TransformerShape;
use crate::runtime::DType;

/// Per-stage KV-cache accounting for one serving deployment
/// `{stages, tp}` of a model shape.
#[derive(Debug, Clone, Copy)]
pub struct KvCacheModel {
    /// Bytes one token adds to one layer's cache on one rank: K + V,
    /// each `d_m` elements, sharded over the tensor-parallel group
    /// (each tp rank holds its heads' slice).
    pub bytes_per_token_layer: f64,
    /// Layers resident on each pipeline stage (`d_l / stages`).
    pub layers_per_stage: usize,
    /// Resident weight bytes per rank: this stage's layers, sharded
    /// over tp. Inference keeps no optimizer state or gradients.
    pub weight_bytes: f64,
    /// Device budget the residency is checked against.
    pub budget: f64,
}

impl KvCacheModel {
    /// Build the model for a deployment of `shape` over `stages`
    /// pipeline stages at tensor-parallel degree `tp`, with per-element
    /// width `dtype` and a per-device byte budget.
    pub fn new(
        shape: &TransformerShape,
        stages: usize,
        tp: usize,
        dtype: DType,
        budget: f64,
    ) -> Self {
        let stages = stages.max(1);
        let tp = tp.max(1) as f64;
        let elem = dtype.bytes() as f64;
        let layers_per_stage = shape.d_l.div_ceil(stages);
        KvCacheModel {
            bytes_per_token_layer: 2.0 * shape.d_m() as f64 * elem / tp,
            layers_per_stage,
            weight_bytes: shape.params_per_layer() * layers_per_stage as f64 * elem / tp,
            budget,
        }
    }

    /// Cache bytes one request with `context` tokens holds on one rank
    /// (all of this stage's layers).
    pub fn request_bytes(&self, context: usize) -> f64 {
        context as f64 * self.bytes_per_token_layer * self.layers_per_stage as f64
    }

    /// Total per-rank residency: weights plus the cache of `in_flight`
    /// requests at `context` tokens each.
    pub fn residency(&self, in_flight: usize, context: usize) -> f64 {
        self.weight_bytes + in_flight as f64 * self.request_bytes(context)
    }

    /// Headroom left for cache after the weights.
    pub fn cache_budget(&self) -> f64 {
        (self.budget - self.weight_bytes).max(0.0)
    }

    /// Admission limit: the largest in-flight request count whose
    /// full-context (`prompt + decode`) cache fits beside the weights.
    /// Zero means the weights alone overflow (or a single request
    /// cannot fit) — the deployment is infeasible at this context.
    pub fn admission_limit(&self, context: usize) -> usize {
        let per = self.request_bytes(context);
        if self.budget < self.weight_bytes || per <= 0.0 {
            return 0;
        }
        (self.cache_budget() / per).floor() as usize
    }

    /// Whether `in_flight` requests at full `context` fit.
    pub fn fits(&self, in_flight: usize, context: usize) -> bool {
        self.residency(in_flight, context) <= self.budget
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::XModel;

    fn model(budget: f64) -> KvCacheModel {
        KvCacheModel::new(&XModel::new(8).shape(), 2, 1, DType::F32, budget)
    }

    #[test]
    fn per_token_bytes_follow_the_shape_and_dtype() {
        let shape = XModel::new(8).shape();
        let m = model(f64::INFINITY);
        assert_eq!(m.bytes_per_token_layer, 2.0 * shape.d_m() as f64 * 4.0);
        assert_eq!(m.layers_per_stage, shape.d_l / 2);
        // tp shards both the weights and the cache.
        let m2 = KvCacheModel::new(&shape, 2, 2, DType::F32, f64::INFINITY);
        assert!((m.bytes_per_token_layer / m2.bytes_per_token_layer - 2.0).abs() < 1e-12);
        assert!((m.weight_bytes / m2.weight_bytes - 2.0).abs() < 1e-12);
    }

    #[test]
    fn residency_is_linear_in_requests_and_context() {
        let m = model(f64::INFINITY);
        let base = m.residency(0, 128);
        assert_eq!(base, m.weight_bytes);
        let one = m.residency(1, 128) - base;
        assert!((m.residency(4, 128) - base - 4.0 * one).abs() < 1e-6);
        assert!((m.residency(1, 256) - base - 2.0 * one).abs() < 1e-6);
    }

    #[test]
    fn admission_limit_matches_the_residency_check() {
        let m0 = model(f64::INFINITY);
        // Budget for the weights plus ~5.5 requests of 64-token cache.
        let budget = m0.weight_bytes + 5.5 * m0.request_bytes(64);
        let m = model(budget);
        let limit = m.admission_limit(64);
        assert_eq!(limit, 5);
        assert!(m.fits(limit, 64));
        assert!(!m.fits(limit + 1, 64));
    }

    #[test]
    fn overflowing_weights_admit_nothing() {
        let m0 = model(f64::INFINITY);
        let m = model(m0.weight_bytes * 0.5);
        assert_eq!(m.admission_limit(64), 0);
        assert!(!m.fits(1, 64));
    }
}
