//! Per-device memory model (paper §2.5 and Appendix C.3) — reproduces
//! Table 6.2 digit-for-digit.
//!
//! Four categories:
//! * **training state** — parameters + Adam moments, fp32, 12 bytes/param;
//!   split across model-parallel instances, or across every device when
//!   partitioned (ZeRO-3 style);
//! * **activation checkpoints** — one fp16 layer output per layer,
//!   2·b·d_s·d_m·d_l bytes total, split across all devices;
//! * **parameter/gradient buffers** — mixed buffering (Appendix C.2):
//!   two fp16 parameter buffers + one fp16 gradient buffer of one layer,
//!   split in the tensor-parallel direction;
//! * **layer activations** — intermediate activations + their gradients
//!   between two checkpoints, m₀ bytes/token (see
//!   [`TransformerShape::m0_bytes_per_token`]).
//!
//! State and checkpoints are offloadable to CPU memory; buffers and live
//! activations are not (§2.5, C.3).

use crate::hardware::Bytes;
use crate::model::TransformerShape;

use super::config::TrainConfig;

/// Per-device memory usage breakdown, bytes.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemoryBreakdown {
    pub state: Bytes,
    pub checkpoints: Bytes,
    pub buffers: Bytes,
    pub activations: Bytes,
}

impl MemoryBreakdown {
    /// Evaluate the Appendix C.3 formulas for a shape + configuration.
    pub fn evaluate(shape: &TransformerShape, cfg: &TrainConfig) -> Self {
        let p = shape.params();
        let p_l = shape.params_per_layer();
        let b = cfg.batch_size();
        let (n_b, n_l, n_a) = (cfg.n_b as f64, cfg.n_l as f64, cfg.n_a as f64);
        let n_gpu = cfg.n_gpu() as f64;
        let n_mu = cfg.n_mu as f64;

        // Training state: 12 bytes/param (fp32 params + Adam mean and
        // variance; gradients folded away by eager weight updates, C.3).
        // The modular partition divides all 12 bytes by every device;
        // ZeRO divides per stage: 1–2 shard only the 8 bytes of Adam
        // moments 1/n_b (params stay replicated across the dp group),
        // 3 shards all 12.
        let state = if cfg.partition {
            12.0 * p / n_gpu
        } else {
            match cfg.zero {
                1 | 2 => (4.0 + 8.0 / n_b) * p / (n_l * n_a),
                3 => 12.0 / n_b * p / (n_l * n_a),
                _ => 12.0 * p / (n_l * n_a),
            }
        };

        // Activation checkpoints: fp16 layer outputs for the whole batch,
        // split across data, pipeline and tensor dimensions (C.3).
        let checkpoints = shape.checkpoint_bytes(b) * shape.d_l as f64 / n_gpu;

        // Mixed buffering (C.2): 2 parameter + 1 gradient buffer, one
        // layer each, fp16, split in the tensor-parallel direction.
        let buffers = 6.0 * p_l / n_a;

        // Live layer activations + gradients for one micro-batch,
        // split across tensor-parallel instances (C.3).
        let activations = cfg.b_mu.max(b / (n_b * n_mu)) * shape.d_s as f64
            * shape.m0_bytes_per_token()
            / n_a;

        MemoryBreakdown { state, checkpoints, buffers, activations }
    }

    /// Memory that can be offloaded to CPU (state + checkpoints).
    pub fn offloadable(&self) -> Bytes {
        self.state + self.checkpoints
    }

    /// Memory that must stay on the GPU (buffers + live activations).
    pub fn non_offloadable(&self) -> Bytes {
        self.buffers + self.activations
    }

    /// Total footprint if nothing is offloaded.
    pub fn total(&self) -> Bytes {
        self.offloadable() + self.non_offloadable()
    }

    /// GPU-resident footprint for a configuration (respects the offload
    /// flag).
    pub fn gpu_resident(&self, offload: bool) -> Bytes {
        if offload {
            self.non_offloadable()
        } else {
            self.total()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::config::Strategy;
    use crate::hardware::GIB;
    use crate::model::XModel;

    fn cfg(
        strategy: Strategy,
        n_b: usize,
        n_l: usize,
        n_a: usize,
        n_mu: usize,
        b_mu: f64,
        offload: bool,
        partition: bool,
    ) -> TrainConfig {
        TrainConfig { strategy, n_b, n_l, n_a, n_mu, b_mu, offload, partition, zero: 0 }
    }

    /// Full check of Table 6.2 (all 9 rows, all 6 columns), tolerance 1%.
    #[test]
    fn table_6_2_memory_breakdown() {
        use Strategy::*;
        let shape = XModel::x160().shape();
        // (cfg, state, ckpt, buffers, acts, offloadable, non-offloadable)
        // — memory values in GiB, straight from Table 6.2.
        #[allow(clippy::type_complexity)]
        let rows: [(TrainConfig, [f64; 6]); 9] = [
            (cfg(Baseline, 1, 1, 1, 604, 4.0, true, false),
             [14.1e3, 47.2e3, 43.9, 24.9, 61.2e3, 68.8]),
            (cfg(Baseline, 483, 1, 1, 1, 5.0, true, false),
             [14.1e3, 97.7, 43.9, 31.1, 14.2e3, 75.1]),
            (cfg(Partitioned, 483, 1, 1, 1, 5.0, true, true),
             [29.1, 97.7, 43.9, 31.1, 127.0, 75.1]),
            (cfg(Baseline, 3, 160, 1, 201, 4.0, true, false),
             [87.9, 98.1, 43.9, 24.9, 186.0, 68.8]),
            (cfg(Improved, 483, 5, 1, 5, 1.0, false, true),
             [5.82, 19.5, 43.9, 6.23, 25.4, 50.2]),
            (cfg(Baseline, 483, 1, 16, 1, 5.0, true, false),
             [879.0, 6.10, 2.75, 1.95, 885.0, 4.69]),
            (cfg(Partitioned, 483, 1, 16, 1, 5.0, false, true),
             [1.82, 6.10, 2.75, 1.95, 7.92, 4.69]),
            (cfg(Baseline, 14, 160, 16, 172, 1.0, false, false),
             [5.49, 1.31, 2.75, 0.389, 6.81, 3.14]),
            (cfg(Improved, 483, 5, 16, 5, 1.0, false, true),
             [0.364, 1.22, 2.75, 0.389, 1.58, 3.14]),
        ];
        for (i, (c, want)) in rows.iter().enumerate() {
            let m = MemoryBreakdown::evaluate(&shape, c);
            let got = [
                m.state / GIB,
                m.checkpoints / GIB,
                m.buffers / GIB,
                m.activations / GIB,
                m.offloadable() / GIB,
                m.non_offloadable() / GIB,
            ];
            for (j, (g, w)) in got.iter().zip(want.iter()).enumerate() {
                assert!(
                    (g / w - 1.0).abs() < 0.011,
                    "row {i} col {j}: got {g:.4}, want {w}"
                );
            }
        }
    }

    #[test]
    fn partition_divides_state_by_data_parallel_degree() {
        let shape = XModel::x160().shape();
        let base = cfg(Strategy::Baseline, 483, 1, 1, 1, 5.0, true, false);
        let part = cfg(Strategy::Partitioned, 483, 1, 1, 1, 5.0, true, true);
        let mb = MemoryBreakdown::evaluate(&shape, &base);
        let mp = MemoryBreakdown::evaluate(&shape, &part);
        assert!((mb.state / mp.state - 483.0).abs() < 1e-6);
        // Non-state categories are unaffected by the partition.
        assert_eq!(mb.checkpoints, mp.checkpoints);
        assert_eq!(mb.buffers, mp.buffers);
        assert_eq!(mb.activations, mp.activations);
    }

    #[test]
    fn zero_stages_divide_state_per_rajbhandari() {
        let shape = XModel::x160().shape();
        let base = cfg(Strategy::Baseline, 483, 1, 1, 1, 5.0, true, false);
        let m0 = MemoryBreakdown::evaluate(&shape, &base);
        let mut z = base;
        // Stage 1 and 2 shard the 8/12 of state that is Adam moments;
        // stage 2 changes traffic, not residency, so they coincide here.
        z.zero = 1;
        let m1 = MemoryBreakdown::evaluate(&shape, &z);
        z.zero = 2;
        let m2 = MemoryBreakdown::evaluate(&shape, &z);
        assert_eq!(m1.state, m2.state);
        let want12 = m0.state * (4.0 + 8.0 / 483.0) / 12.0;
        assert!((m1.state / want12 - 1.0).abs() < 1e-12);
        // Stage 3 shards all 12 bytes: state / n_b, the partition's
        // division along the dp axis alone (n_l = n_a = 1 here, so the
        // two coincide).
        z.zero = 3;
        let m3 = MemoryBreakdown::evaluate(&shape, &z);
        assert!((m0.state / m3.state - 483.0).abs() < 1e-6);
        // Non-state categories are unaffected by ZeRO.
        assert_eq!(m0.checkpoints, m3.checkpoints);
        assert_eq!(m0.buffers, m3.buffers);
        assert_eq!(m0.activations, m3.activations);
    }

    #[test]
    fn gpu_resident_respects_offload_flag() {
        let shape = XModel::x160().shape();
        let c = cfg(Strategy::Baseline, 483, 1, 1, 1, 5.0, true, false);
        let m = MemoryBreakdown::evaluate(&shape, &c);
        assert!(m.gpu_resident(true) < m.gpu_resident(false));
        assert_eq!(m.gpu_resident(false), m.total());
    }
}
