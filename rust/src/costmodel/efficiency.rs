//! Efficiency and training-time estimation (paper §5 "Resource usage").
//!
//! The computational efficiency of a configuration is
//!
//! ```text
//!   efficiency = 1 / (1 + bubble + Σ_serial ν_net/ν + Σ_overlapped max(0, ν_net/ν − 1))
//! ```
//!
//! where `bubble` is the pipeline fill/drain overhead and each network
//! stream contributes by its arithmetic-intensity ratio (Appendix C.4).
//! The training time is then `total_flops / (n_gpu · peak · efficiency)`.

use crate::hardware::{ClusterSpec, LinkKind, SECS_PER_DAY};
use crate::model::{TransformerShape, XModel, TRAINING_STEPS};

use super::config::TrainConfig;
use super::intensity::{
    data_parallel_intensity, pipeline_parallel_intensity, state_offload_intensity,
    tensor_parallel_intensity,
};

/// The individual overhead terms making up an efficiency estimate.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct Overheads {
    /// Pipeline bubble: (n_l−1)/n_μ for the contiguous split, reduced by
    /// d_l/n_l for the modular split (§4).
    pub bubble: f64,
    /// Data-parallel gradient reduction (and partition restore) overhead.
    pub data_parallel: f64,
    /// Pipeline-parallel boundary-transfer overhead.
    pub pipeline_parallel: f64,
    /// Tensor-parallel all-reduce overhead (never overlapped).
    pub tensor_parallel: f64,
    /// CPU-GPU offload transfer overhead.
    pub offload: f64,
    /// Extra overhead when offload and NIC traffic contend for the shared
    /// PCIe link (Appendix A; the HGX design halves the effective CPU-GPU
    /// bandwidth and shares it with InfiniBand).
    pub pcie_contention: f64,
}

impl Overheads {
    pub fn total(&self) -> f64 {
        self.bubble
            + self.data_parallel
            + self.pipeline_parallel
            + self.tensor_parallel
            + self.offload
            + self.pcie_contention
    }

    pub fn efficiency(&self) -> f64 {
        1.0 / (1.0 + self.total())
    }
}

/// Pipeline bubble fraction (§2.4 and §4).
///
/// Contiguous split: a micro-batch crosses n_l−1 stage boundaries of
/// d_l/n_l layers each before the pipe is full → bubble = (n_l−1)/n_μ.
/// Modular split: the fill costs n_l−1 *single* layers → the bubble
/// shrinks by d_l/n_l: bubble = n_l(n_l−1)/(n_μ·d_l).
pub fn bubble_fraction(shape: &TransformerShape, cfg: &TrainConfig) -> f64 {
    if cfg.n_l <= 1 {
        return 0.0;
    }
    let n_l = cfg.n_l as f64;
    let n_mu = cfg.n_mu as f64;
    if cfg.is_improved() {
        n_l * (n_l - 1.0) / (n_mu * shape.d_l as f64)
    } else {
        (n_l - 1.0) / n_mu
    }
}

/// Evaluate every overhead term for a configuration on a cluster.
pub fn overheads(shape: &TransformerShape, cfg: &TrainConfig, cluster: &ClusterSpec) -> Overheads {
    let inter = cluster.inter_node_threshold();
    let gpu = &cluster.gpu;

    let dp = data_parallel_intensity(shape, cfg);
    let pp = pipeline_parallel_intensity(shape, cfg);
    let tp = tensor_parallel_intensity(shape, cfg);
    let off = state_offload_intensity(shape, cfg);

    let cpu_gpu = LinkKind::CpuGpu.intensity_threshold(gpu);
    let pcie = LinkKind::PciExpress.intensity_threshold(gpu);

    let mut o = Overheads {
        bubble: bubble_fraction(shape, cfg),
        data_parallel: dp.overhead(inter),
        pipeline_parallel: pp.overhead(inter),
        tensor_parallel: tp.overhead(cluster.tensor_parallel_threshold(cfg.n_a)),
        offload: off.overhead(cpu_gpu),
        pcie_contention: 0.0,
    };

    // PCIe contention (Appendix A / §5): when offload traffic and
    // overlapped InfiniBand traffic flow simultaneously, their combined
    // bytes-per-flop must stay under the PCIe threshold. The combined
    // effective intensity is the harmonic sum 1/(1/ν_s + 1/ν_b).
    if cluster.pcie_shared_with_nic
        && cfg.offload
        && !off.is_absent()
        && !dp.is_absent()
        && dp.overlapped
        && cluster.inter_node == crate::hardware::InterNode::InfiniBand
    {
        let combined = 1.0 / (1.0 / off.nu + 1.0 / dp.nu);
        // Only the *extra* cost of sharing beyond what was already charged
        // to the offload stream on its own link.
        o.pcie_contention = ((pcie / combined - 1.0).max(0.0) - o.offload).max(0.0);
    }
    o
}

/// A full speed estimate for one configuration.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpeedEstimate {
    pub overheads: Overheads,
    pub efficiency: f64,
    /// Wall-clock training time for the paper's standard 100k-step run,
    /// seconds.
    pub training_secs: f64,
}

impl SpeedEstimate {
    pub fn training_days(&self) -> f64 {
        self.training_secs / SECS_PER_DAY
    }

    pub fn training_years(&self) -> f64 {
        self.training_secs / (SECS_PER_DAY * 365.25)
    }
}

/// Estimate efficiency + training time for `model` under `cfg` on
/// `cluster`.
///
/// Total training compute is evaluated at the critical batch size: below
/// b_c the product b·steps is invariant (§2.1 — halving the batch doubles
/// the required steps), so a configuration with b < b_c trains for
/// proportionally more steps and the total flops stay 8·b_c·d_s·p·100k.
/// Training *above* b_c is wasteful and costs extra flops. This is the
/// convention that reproduces both Table 6.1 and the reduced-batch rows
/// of Table 6.3.
pub fn estimate(model: &XModel, cfg: &TrainConfig, cluster: &ClusterSpec) -> SpeedEstimate {
    let shape = model.shape();
    let o = overheads(&shape, cfg, cluster);
    let eff = o.efficiency();
    let b_eff = cfg.batch_size().max(model.critical_batch_size());
    let flops = model.training_flops(b_eff, TRAINING_STEPS);
    let rate = cfg.n_gpu() as f64 * cluster.gpu.peak_flops * eff;
    SpeedEstimate { overheads: o, efficiency: eff, training_secs: flops / rate }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::config::{Strategy, TrainConfig};

    fn cfg(
        strategy: Strategy,
        n_b: usize,
        n_l: usize,
        n_a: usize,
        n_mu: usize,
        b_mu: f64,
        offload: bool,
        partition: bool,
    ) -> TrainConfig {
        TrainConfig { strategy, n_b, n_l, n_a, n_mu, b_mu, offload, partition, zero: 0 }
    }

    /// Reproduce Table 6.1's efficiency and training-time columns.
    #[test]
    fn table_6_1_efficiency_and_time() {
        use Strategy::*;
        let model = XModel::x160();
        let cluster = ClusterSpec::reference();
        // (config, efficiency, time_days, eff_tol, time_tol) per row.
        let rows = [
            (cfg(Baseline, 1, 1, 1, 604, 4.0, true, false), 1.00, 630.0 * 365.25, 0.01, 0.02),
            (cfg(Baseline, 483, 1, 1, 1, 5.0, true, false), 1.00, 1.3 * 365.25, 0.01, 0.02),
            (cfg(Partitioned, 483, 1, 1, 1, 5.0, true, true), 1.00, 1.3 * 365.25, 0.01, 0.02),
            (cfg(Baseline, 3, 160, 1, 201, 4.0, true, false), 0.56, 2.4 * 365.25, 0.01, 0.03),
            (cfg(Improved, 483, 5, 1, 5, 1.0, false, true), 0.94, 100.0, 0.01, 0.03),
            (cfg(Baseline, 483, 1, 16, 1, 5.0, true, false), 0.93, 32.0, 0.01, 0.02),
            (cfg(Partitioned, 483, 1, 16, 1, 5.0, false, true), 0.93, 32.0, 0.01, 0.02),
            (cfg(Baseline, 14, 160, 16, 172, 1.0, false, false), 0.48, 13.0, 0.04, 0.06),
            (cfg(Improved, 483, 5, 16, 5, 1.0, false, true), 0.88, 6.8, 0.01, 0.03),
        ];
        for (i, (c, eff, days, eff_tol, t_tol)) in rows.iter().enumerate() {
            c.validate().unwrap();
            let e = estimate(&model, c, &cluster);
            assert!(
                (e.efficiency - eff).abs() < *eff_tol + 0.005,
                "row {i}: efficiency {:.3} vs paper {eff}",
                e.efficiency
            );
            assert!(
                (e.training_days() / days - 1.0).abs() < *t_tol + 0.015,
                "row {i}: {:.1} days vs paper {days:.1}",
                e.training_days()
            );
        }
    }

    #[test]
    fn modular_bubble_is_dl_over_nl_smaller() {
        let shape = XModel::x160().shape();
        let naive = cfg(Strategy::Baseline, 1, 8, 1, 16, 1.0, false, false);
        let modular = cfg(Strategy::Improved, 1, 8, 1, 16, 1.0, false, true);
        let bn = bubble_fraction(&shape, &naive);
        let bm = bubble_fraction(&shape, &modular);
        assert!((bn / bm - 160.0 / 8.0).abs() < 1e-9);
    }

    #[test]
    fn improved_is_at_least_twice_as_fast_as_baseline_3d() {
        // The paper's headline claim: the new methods cut the minimum
        // training time in half (13 d -> 6.8 d for X_160 3d).
        let model = XModel::x160();
        let cluster = ClusterSpec::reference();
        let base = estimate(&model, &cfg(Strategy::Baseline, 14, 160, 16, 172, 1.0, false, false), &cluster);
        let impr = estimate(&model, &cfg(Strategy::Improved, 483, 5, 16, 5, 1.0, false, true), &cluster);
        assert!(base.training_secs / impr.training_secs > 1.9);
    }

    #[test]
    fn efficiency_monotone_in_overheads() {
        let mut o = Overheads::default();
        let e0 = o.efficiency();
        o.bubble = 0.5;
        assert!(o.efficiency() < e0);
        assert!((o.efficiency() - 1.0 / 1.5).abs() < 1e-12);
    }
}
