//! Training-configuration description shared by the cost model, the
//! planner, the simulator and the real trainer.

use std::fmt;

/// The three training strategies compared throughout the paper (§5).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Strategy {
    /// Standard data + pipeline parallelism: contiguous layer split,
    /// per-micro-batch gradient accumulation, no state partition.
    Baseline,
    /// Baseline plus ZeRO-3-style training-state partition in the
    /// data-parallel direction (no pipeline parallelism — the paper finds
    /// the combination counter-productive for this strategy).
    Partitioned,
    /// This paper's contribution: layered gradient accumulation +
    /// modular pipeline parallelism, with the state partitioned unless
    /// `partition: false` is set explicitly (§5).
    Improved,
}

impl Strategy {
    pub const ALL: [Strategy; 3] = [Strategy::Baseline, Strategy::Partitioned, Strategy::Improved];

    pub fn name(self) -> &'static str {
        match self {
            Strategy::Baseline => "Baseline",
            Strategy::Partitioned => "Partitioned",
            Strategy::Improved => "Improved",
        }
    }
}

impl fmt::Display for Strategy {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Which parallelism dimensions a configuration may use (the "Parallelism"
/// column of Tables 6.1–6.3).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub struct ParallelismMenu {
    pub data: bool,
    pub pipeline: bool,
    pub tensor: bool,
}

impl ParallelismMenu {
    pub const NONE: Self = Self { data: false, pipeline: false, tensor: false };
    pub const DATA: Self = Self { data: true, pipeline: false, tensor: false };
    pub const DATA_PIPE: Self = Self { data: true, pipeline: true, tensor: false };
    pub const DATA_TENSOR: Self = Self { data: true, pipeline: false, tensor: true };
    pub const PIPE_TENSOR: Self = Self { data: false, pipeline: true, tensor: true };
    pub const THREE_D: Self = Self { data: true, pipeline: true, tensor: true };

    pub fn name(self) -> &'static str {
        match (self.data, self.pipeline, self.tensor) {
            (false, false, false) => "None",
            (true, false, false) => "Data",
            (true, true, false) => "Data + pipe",
            (true, false, true) => "Data + tensor",
            (false, true, true) => "Pipe + tensor",
            (true, true, true) => "3d",
            (false, true, false) => "Pipe",
            (false, false, true) => "Tensor",
        }
    }
}

impl fmt::Display for ParallelismMenu {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// A concrete distributed-training configuration (one row of Table 6.1).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct TrainConfig {
    pub strategy: Strategy,
    /// Data-parallel degree n_b.
    pub n_b: usize,
    /// Pipeline-parallel degree n_l.
    pub n_l: usize,
    /// Tensor-parallel degree n_a.
    pub n_a: usize,
    /// Micro-batch count n_μ (sequential micro-batches per data-parallel
    /// instance).
    pub n_mu: usize,
    /// Micro-batch size b_μ, sequences.
    pub b_mu: f64,
    /// Whether the training state + activation checkpoints are offloaded
    /// to CPU memory.
    pub offload: bool,
    /// Whether the training state is partitioned in the data-parallel
    /// direction (always true for `Partitioned`; default true for
    /// `Improved`; always false for `Baseline`).
    pub partition: bool,
    /// ZeRO stage (0–3, Rajbhandari et al.) over the data-parallel
    /// group: 1 shards the Adam moments 1/n_b, 2 additionally
    /// reduce-scatters the gradients, 3 additionally divides the
    /// parameters (gather-before-use). Mutually exclusive with
    /// `partition` — the two are competing ways to shard the state, and
    /// keeping them distinct is what lets the planner quantify
    /// ZeRO vs the paper's modular partition.
    pub zero: u8,
}

impl TrainConfig {
    /// Global batch size b = n_b · n_μ · b_μ.
    pub fn batch_size(&self) -> f64 {
        self.n_b as f64 * self.n_mu as f64 * self.b_mu
    }

    /// Total device count n_gpu = n_b · n_l · n_a.
    pub fn n_gpu(&self) -> usize {
        self.n_b * self.n_l * self.n_a
    }

    /// Whether this config uses layered gradient accumulation / modular
    /// pipeline scheduling.
    pub fn is_improved(&self) -> bool {
        self.strategy == Strategy::Improved
    }

    /// The parallelism menu implied by the degrees.
    pub fn menu(&self) -> ParallelismMenu {
        ParallelismMenu { data: self.n_b > 1, pipeline: self.n_l > 1, tensor: self.n_a > 1 }
    }

    /// Consistency checks: degrees positive, micro-batch positive, the
    /// partition flag consistent with the strategy.
    pub fn validate(&self) -> Result<(), String> {
        if self.n_b == 0 || self.n_l == 0 || self.n_a == 0 || self.n_mu == 0 {
            return Err(format!("zero parallelism degree in {self:?}"));
        }
        if self.b_mu <= 0.0 {
            return Err(format!("non-positive micro-batch size in {self:?}"));
        }
        if self.strategy == Strategy::Baseline && self.partition {
            return Err("Baseline strategy cannot partition the state".into());
        }
        if self.strategy == Strategy::Partitioned && !self.partition {
            return Err("Partitioned strategy must partition the state".into());
        }
        if self.zero > 3 {
            return Err(format!("ZeRO stage {} out of range (stages are 0-3)", self.zero));
        }
        if self.zero > 0 && self.partition {
            return Err("ZeRO sharding and the modular state partition are mutually exclusive"
                .into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> TrainConfig {
        TrainConfig {
            strategy: Strategy::Improved,
            n_b: 483,
            n_l: 5,
            n_a: 16,
            n_mu: 5,
            b_mu: 1.0,
            offload: false,
            partition: true,
            zero: 0,
        }
    }

    #[test]
    fn batch_and_gpu_arithmetic() {
        let c = cfg();
        assert_eq!(c.batch_size(), 2415.0);
        assert_eq!(c.n_gpu(), 38_640);
        assert_eq!(c.menu(), ParallelismMenu::THREE_D);
    }

    #[test]
    fn validation_rejects_inconsistent_partition() {
        let mut c = cfg();
        c.strategy = Strategy::Baseline;
        assert!(c.validate().is_err());
        c.partition = false;
        assert!(c.validate().is_ok());
        c.strategy = Strategy::Partitioned;
        assert!(c.validate().is_err());
    }

    #[test]
    fn validation_rejects_zero_partition_overlap() {
        let mut c = cfg();
        c.zero = 4;
        assert!(c.validate().is_err());
        c.zero = 2;
        assert!(c.validate().is_err(), "zero and partition are mutually exclusive");
        c.partition = false;
        assert!(c.validate().is_ok());
    }

    #[test]
    fn menu_names_match_paper() {
        assert_eq!(ParallelismMenu::THREE_D.name(), "3d");
        assert_eq!(ParallelismMenu::DATA_PIPE.name(), "Data + pipe");
    }
}
