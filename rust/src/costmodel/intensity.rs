//! Arithmetic-intensity formulas for every network stream (paper
//! Appendix C.4) and for CPU-GPU offload traffic (Appendix C.5).
//!
//! Each formula returns the operation intensity ν_op (flops per byte of
//! transfer). A stream is hideable behind compute when ν_op ≥ ν_net, the
//! link's intensity threshold (eq. 3); the relative overhead otherwise is
//! ν_net / ν_op (eq. 4 discussion).

use crate::model::TransformerShape;

use super::config::TrainConfig;

/// Data-parallel gradient-reduction intensity (C.4.1, eqs. 5–9), and
/// whether the paper treats the stream as overlapped with compute.
///
/// * Baseline, no pipeline: reduction overlaps the backward pass of the
///   last micro-batch — ν = 3 b d_s / (4 n_b n_μ) (eq. 5).
/// * Baseline with pipeline: overlap is impractical (the last micro-batch
///   is spread across stages), treat as non-overlapped — ν = b d_s / n_b
///   (eq. 6).
/// * Partitioned: restore/reduce repeat per micro-batch, overlapping all
///   of them — ν = b d_s / (2 n_b n_μ) (eq. 7).
/// * Improved (layered gradient accumulation): reduction spreads over the
///   whole backward pass — ν = 3 b d_s / (4 n_b) non-partitioned (eq. 8)
///   or b d_s / (2 n_b) partitioned (eq. 9).
pub fn data_parallel_intensity(shape: &TransformerShape, cfg: &TrainConfig) -> StreamIntensity {
    let b = cfg.batch_size();
    let d_s = shape.d_s as f64;
    let n_b = cfg.n_b as f64;
    let n_mu = cfg.n_mu as f64;
    if cfg.n_b == 1 {
        return StreamIntensity::absent();
    }
    let (nu, overlapped) = if cfg.is_improved() {
        if cfg.partition {
            (b * d_s / (2.0 * n_b), true)
        } else {
            (3.0 * b * d_s / (4.0 * n_b), true)
        }
    } else if cfg.partition {
        (b * d_s / (2.0 * n_b * n_mu), true)
    } else if cfg.n_l > 1 {
        (b * d_s / n_b, false)
    } else {
        (3.0 * b * d_s / (4.0 * n_b * n_mu), true)
    };
    StreamIntensity { nu, overlapped }
}

/// Pipeline-parallel activation-transfer intensity (C.4.2, eqs. 10–11).
///
/// * Baseline (contiguous split): one boundary transfer per d_l/n_l
///   layers — ν = (4 + 2 n_I) d_m d_l / (2 n_l); overlapped by running
///   slightly more micro-batches than stages.
/// * Improved (modular split): a transfer after every layer —
///   ν = (4 + 2 n_I) d_m / 2 (eq. 11, = (2+n_I) d_m for n_I = 4);
///   the paper prefers *not* to overlap it (n_μ is small; an extra
///   micro-batch would cost more than the exposed transfer).
pub fn pipeline_parallel_intensity(shape: &TransformerShape, cfg: &TrainConfig) -> StreamIntensity {
    if cfg.n_l == 1 {
        return StreamIntensity::absent();
    }
    let d_m = shape.d_m() as f64;
    let n_i = shape.n_i as f64;
    let per_layer = (4.0 + 2.0 * n_i) * d_m / 2.0;
    if cfg.is_improved() {
        // Modular: boundary after every layer; not overlapped unless the
        // planner allocated slack micro-batches (n_μ > n_l).
        StreamIntensity { nu: per_layer, overlapped: cfg.n_mu > cfg.n_l }
    } else {
        let chunk = shape.d_l as f64 / cfg.n_l as f64;
        StreamIntensity { nu: per_layer * chunk, overlapped: true }
    }
}

/// Tensor-parallel all-reduce intensity (C.4.3, eq. 12): six all-reduces
/// per layer per micro-batch (2 fwd + 2 bwd + 2 recompute), never
/// overlapped with compute in the Megatron-LM scheme.
pub fn tensor_parallel_intensity(shape: &TransformerShape, cfg: &TrainConfig) -> StreamIntensity {
    if cfg.n_a == 1 {
        return StreamIntensity::absent();
    }
    let d_m = shape.d_m() as f64;
    let n_i = shape.n_i as f64;
    let n_a = cfg.n_a as f64;
    StreamIntensity { nu: (4.0 + 2.0 * n_i) * d_m / (3.0 * (n_a - 1.0)), overlapped: false }
}

/// CPU-GPU training-state offload intensity (C.5, eq. 13). The transfer
/// overlaps the compute of the neighbouring layer; the bottleneck is the
/// forward pass. Layered gradient accumulation moves the state once per
/// batch instead of once per micro-batch, and the partition shrinks the
/// moved state by n_b.
pub fn state_offload_intensity(shape: &TransformerShape, cfg: &TrainConfig) -> StreamIntensity {
    if !cfg.offload {
        return StreamIntensity::absent();
    }
    let b = cfg.batch_size();
    let d_s = shape.d_s as f64;
    let n_b = cfg.n_b as f64;
    let n_mu = cfg.n_mu as f64;
    let nu = match (cfg.is_improved(), cfg.partition) {
        (false, false) => b * d_s / (n_mu * n_b),
        (false, true) => b * d_s / n_mu,
        (true, false) => b * d_s / n_b,
        (true, true) => b * d_s,
    };
    StreamIntensity { nu, overlapped: true }
}

/// Activation-checkpoint offload intensity (C.5, eq. 14): the checkpoint
/// write/read overlaps the layer compute, ν = (4 + 2 n_I) d_m.
pub fn checkpoint_offload_intensity(shape: &TransformerShape) -> f64 {
    (4.0 + 2.0 * shape.n_i as f64) * shape.d_m() as f64
}

/// An individual data stream: its operation intensity and whether it is
/// overlapped with compute.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct StreamIntensity {
    /// Operation arithmetic intensity ν_op, flops/byte. `f64::INFINITY`
    /// when the stream does not exist for this configuration.
    pub nu: f64,
    /// Whether the stream runs concurrently with compute.
    pub overlapped: bool,
}

impl StreamIntensity {
    pub fn absent() -> Self {
        StreamIntensity { nu: f64::INFINITY, overlapped: true }
    }

    pub fn is_absent(&self) -> bool {
        self.nu.is_infinite()
    }

    /// Relative time overhead of this stream given the link's intensity
    /// threshold ν_net:
    /// * absent → 0;
    /// * overlapped → max(0, ν_net/ν − 1) (the stream only costs time when
    ///   it is slower than the compute it hides behind);
    /// * non-overlapped → ν_net/ν (the transfer is serialized).
    pub fn overhead(&self, nu_net: f64) -> f64 {
        if self.is_absent() {
            0.0
        } else if self.overlapped {
            (nu_net / self.nu - 1.0).max(0.0)
        } else {
            nu_net / self.nu
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::costmodel::config::Strategy;
    use crate::model::XModel;

    fn x160_cfg(strategy: Strategy, n_b: usize, n_l: usize, n_a: usize, n_mu: usize, b_mu: f64, partition: bool) -> TrainConfig {
        TrainConfig { strategy, n_b, n_l, n_a, n_mu, b_mu, offload: false, partition, zero: 0 }
    }

    #[test]
    fn improved_dp_intensity_is_n_mu_times_baseline() {
        // LGA spreads the reduction over the whole backward pass: ν gains
        // a factor n_μ over per-micro-batch overlap (eq. 5 vs eq. 8).
        let shape = XModel::x160().shape();
        let base = x160_cfg(Strategy::Baseline, 10, 1, 1, 8, 4.0, false);
        let impr = x160_cfg(Strategy::Improved, 10, 1, 1, 8, 4.0, false);
        let nb = data_parallel_intensity(&shape, &base);
        let ni = data_parallel_intensity(&shape, &impr);
        assert!((ni.nu / nb.nu - 8.0).abs() < 1e-9);
    }

    #[test]
    fn baseline_dp_with_pipeline_is_not_overlapped() {
        let shape = XModel::x160().shape();
        let c = x160_cfg(Strategy::Baseline, 3, 160, 1, 201, 4.0, false);
        let s = data_parallel_intensity(&shape, &c);
        assert!(!s.overlapped);
        // eq. 6: ν = b d_s / n_b = 2412·2560/3.
        assert!((s.nu - 2412.0 * 2560.0 / 3.0).abs() < 1.0);
    }

    #[test]
    fn modular_pipeline_intensity_matches_eq_11() {
        // ν_l^impr = (2 + n_I) d_m = 6 · 25600 for X_160 (n_I = 4).
        let shape = XModel::x160().shape();
        let c = x160_cfg(Strategy::Improved, 483, 5, 16, 5, 1.0, true);
        let s = pipeline_parallel_intensity(&shape, &c);
        assert!((s.nu - 6.0 * 25_600.0).abs() < 1e-6);
        assert!(!s.overlapped, "n_mu == n_l leaves no slack to overlap");
    }

    #[test]
    fn naive_pipeline_intensity_gains_chunk_factor() {
        let shape = XModel::x160().shape();
        let naive = x160_cfg(Strategy::Baseline, 3, 8, 1, 10, 4.0, false);
        let modular = x160_cfg(Strategy::Improved, 3, 8, 1, 10, 4.0, true);
        let sn = pipeline_parallel_intensity(&shape, &naive);
        let sm = pipeline_parallel_intensity(&shape, &modular);
        // d_l / n_l = 160/8 = 20x more compute per boundary transfer.
        assert!((sn.nu / sm.nu - 20.0).abs() < 1e-9);
    }

    #[test]
    fn tensor_parallel_overhead_at_16_ways_is_about_7_percent() {
        // The Table 6.1 "Data + tensor" rows show efficiency 0.93 from the
        // TP all-reduce overhead alone: ν_net(NVLink)/ν_a ≈ 0.071.
        use crate::hardware::{ClusterSpec, LinkKind};
        let shape = XModel::x160().shape();
        let c = x160_cfg(Strategy::Baseline, 483, 1, 16, 1, 5.0, false);
        let s = tensor_parallel_intensity(&shape, &c);
        let nu_net = LinkKind::NvLink.intensity_threshold(&ClusterSpec::reference().gpu);
        let overhead = s.overhead(nu_net);
        assert!((overhead - 0.0709).abs() < 0.002, "overhead = {overhead:.4}");
    }

    #[test]
    fn lga_state_offload_needs_no_microbatch_scaling() {
        // eq. 13: improved+partitioned intensity is b·d_s — independent of
        // n_μ, which is the whole point of layered gradient accumulation.
        let shape = XModel::x160().shape();
        let mut c = x160_cfg(Strategy::Improved, 483, 5, 1, 5, 1.0, true);
        c.offload = true;
        let s1 = state_offload_intensity(&shape, &c);
        c.n_mu = 50;
        c.b_mu = 0.1;
        let s2 = state_offload_intensity(&shape, &c);
        assert!((s1.nu - s2.nu).abs() < 1e-6);
    }

    #[test]
    fn overhead_semantics() {
        let over = StreamIntensity { nu: 100.0, overlapped: true };
        assert_eq!(over.overhead(50.0), 0.0); // hidden
        assert!((over.overhead(200.0) - 1.0).abs() < 1e-12); // 2x data-bound
        let serial = StreamIntensity { nu: 100.0, overlapped: false };
        assert!((serial.overhead(50.0) - 0.5).abs() < 1e-12);
        assert_eq!(StreamIntensity::absent().overhead(1e9), 0.0);
    }
}
