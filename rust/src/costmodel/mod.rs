//! The paper's analytical cost model (Appendix C): memory usage,
//! arithmetic intensities of every network stream, pipeline bubble, and
//! training-time estimation.

pub mod config;
pub mod efficiency;
pub mod intensity;
pub mod kv;
pub mod memory;

pub use config::{ParallelismMenu, Strategy, TrainConfig};
pub use kv::KvCacheModel;
pub use efficiency::{bubble_fraction, estimate, overheads, Overheads, SpeedEstimate};
pub use intensity::{
    checkpoint_offload_intensity, data_parallel_intensity, pipeline_parallel_intensity,
    state_offload_intensity, tensor_parallel_intensity, StreamIntensity,
};
pub use memory::MemoryBreakdown;
