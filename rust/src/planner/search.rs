//! Grid search over the configuration space, used for the scaling figures
//! (4, 5, 8) where the closed-form §5 rules need to adapt to the cluster
//! (e.g. Ethernet forces different pipeline/micro-batch trade-offs).
//!
//! The search enumerates (n_l, n_μ, b_μ, n_a) structures, derives the
//! data-parallel degree from the critical-batch budget, evaluates the full
//! cost model for each candidate and keeps the fastest feasible plan.

use crate::costmodel::{ParallelismMenu, Strategy, TrainConfig};
use crate::hardware::ClusterSpec;
use crate::model::XModel;

use super::rules::{max_tensor_parallel, Plan};

/// Candidate micro-batch sizes tried by the search.
const B_MU_CANDIDATES: [f64; 7] = [1.0, 2.0, 4.0, 5.0, 8.0, 16.0, 32.0];

/// Exhaustive-ish search for the fastest feasible configuration of a
/// strategy on a cluster. Slower than [`super::rules::fastest_plan`] but
/// robust to unusual clusters; used by the figure sweeps.
pub fn search_fastest(
    model: &XModel,
    cluster: &ClusterSpec,
    strategy: Strategy,
    menu: ParallelismMenu,
) -> Option<Plan> {
    let shape = model.shape();
    let d_l = shape.d_l;
    let bc = model.critical_batch_size();

    let n_a_max = if menu.tensor { max_tensor_parallel(model, cluster) } else { 1 };
    let n_a_candidates: Vec<usize> = {
        let mut v = vec![1usize, 2, 4, 8, 16, 32, 64, 128];
        v.retain(|&a| a <= n_a_max);
        if !v.contains(&n_a_max) {
            v.push(n_a_max);
        }
        v
    };

    let n_l_candidates: Vec<usize> = if menu.pipeline {
        let mut v: Vec<usize> = [1usize, 2, 3, 4, 5, 6, 8, 10, 12, 16, 20, 24, 32, 40, 48, 64, 80, 96, 128, 160, 192, 256]
            .iter()
            .copied()
            .filter(|&l| l <= d_l)
            .collect();
        if !v.contains(&d_l) {
            v.push(d_l);
        }
        v
    } else {
        vec![1]
    };

    // Multipliers applied to max(n_l, 1) to get the micro-batch count.
    let n_mu_factors: [f64; 8] = [1.0, 1.05, 1.1, 1.25, 1.5, 2.0, 4.0, 8.0];

    let mut best: Option<Plan> = None;
    for &n_a in &n_a_candidates {
        for &n_l in &n_l_candidates {
            if strategy == Strategy::Partitioned && n_l > 1 {
                continue; // §5: partitioned approach forgoes pipelining
            }
            for &f in &n_mu_factors {
                let n_mu_base = ((n_l as f64 * f).round() as usize).max(1);
                // Also explore large plain gradient accumulation when
                // there is no pipeline.
                let extra: Vec<usize> = if n_l == 1 {
                    vec![n_mu_base, 2, 8, 32, 128, 512]
                } else {
                    vec![n_mu_base]
                };
                for n_mu in extra {
                    for &b_mu in &B_MU_CANDIDATES {
                        let n_b = if menu.data {
                            ((bc / (n_mu as f64 * b_mu)).floor() as usize).max(1)
                        } else {
                            1
                        };
                        if menu.data && n_b == 0 {
                            continue;
                        }
                        if (n_b as f64) * (n_mu as f64) * b_mu > bc * 1.001 && menu.data {
                            continue;
                        }
                        let partitions: &[bool] = match strategy {
                            Strategy::Baseline => &[false],
                            Strategy::Partitioned => &[true],
                            // §8.3: for small models the improved method
                            // may skip the partition for extra speed.
                            Strategy::Improved => &[true, false],
                        };
                        for (offload, &partition) in [false, true]
                            .into_iter()
                            .flat_map(|o| partitions.iter().map(move |p| (o, p)))
                        {
                            let cfg = TrainConfig {
                                strategy,
                                n_b,
                                n_l,
                                n_a,
                                n_mu,
                                b_mu,
                                offload,
                                partition,
                            };
                            if cfg.validate().is_err() {
                                continue;
                            }
                            let plan = Plan::build_pub(model, cfg, cluster);
                            if !plan.fits_gpu(cluster) {
                                continue;
                            }
                            // Skip pointless offload (fits without it and
                            // offload only adds overhead).
                            if offload && plan.speed.overheads.offload == 0.0 {
                                // keep — zero-cost offload may still be
                                // wanted; prefer the non-offloaded twin
                                // via the tie-break below.
                            }
                            let better = match &best {
                                None => true,
                                Some(b) => {
                                    plan.speed.training_secs < b.speed.training_secs * 0.9999
                                        || ((plan.speed.training_secs
                                            - b.speed.training_secs)
                                            .abs()
                                            < b.speed.training_secs * 1e-4
                                            && !plan.cfg.offload
                                            && b.cfg.offload)
                                }
                            };
                            if better {
                                best = Some(plan);
                            }
                        }
                    }
                }
            }
        }
    }
    best
}

impl Plan {
    /// Public constructor used by the search (same as the private
    /// `Plan::build`).
    pub fn build_pub(model: &XModel, cfg: TrainConfig, cluster: &ClusterSpec) -> Self {
        use crate::costmodel::MemoryBreakdown;
        let memory = MemoryBreakdown::evaluate(&model.shape(), &cfg);
        let speed = crate::costmodel::estimate(model, &cfg, cluster);
        let cpu_memory_exceeded =
            cfg.offload && memory.offloadable() > cluster.cpu_memory_per_gpu;
        Plan { cfg, speed, memory, cpu_memory_exceeded }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn search_matches_rules_at_x160_3d() {
        // The grid search should find a plan at least as fast as the
        // closed-form rules on the reference cluster.
        let model = XModel::x160();
        let cluster = ClusterSpec::reference();
        let ruled = super::super::rules::fastest_plan(
            &model,
            &cluster,
            Strategy::Improved,
            ParallelismMenu::THREE_D,
        )
        .unwrap();
        let searched =
            search_fastest(&model, &cluster, Strategy::Improved, ParallelismMenu::THREE_D)
                .unwrap();
        assert!(searched.speed.training_secs <= ruled.speed.training_secs * 1.02);
    }

    #[test]
    fn ethernet_penalty_shrinks_with_scale() {
        // Figure 8 / §8.3 shape check: the relative Ethernet slowdown of
        // the improved method decreases as the model grows.
        let ib = ClusterSpec::reference();
        let eth = ClusterSpec::ethernet();
        let penalty = |x: usize| {
            let m = XModel::new(x);
            let a = search_fastest(&m, &ib, Strategy::Improved, ParallelismMenu::THREE_D)
                .unwrap()
                .speed
                .training_secs;
            let b = search_fastest(&m, &eth, Strategy::Improved, ParallelismMenu::THREE_D)
                .unwrap()
                .speed
                .training_secs;
            b / a
        };
        let small = penalty(32);
        let large = penalty(160);
        assert!(
            large < small,
            "penalty should shrink with scale: X_32 {small:.3} vs X_160 {large:.3}"
        );
        assert!(large < 1.6, "X_160 Ethernet penalty too large: {large:.3}");
    }
}
