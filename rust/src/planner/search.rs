//! Grid search over the configuration space, used for the scaling figures
//! (4, 5, 8) where the closed-form §5 rules need to adapt to the cluster
//! (e.g. Ethernet forces different pipeline/micro-batch trade-offs).
//!
//! The search runs the planner's **enumerate → prune → evaluate**
//! pipeline:
//!
//! 1. **enumerate** — [`super::candidates::Candidates`] yields the
//!    (n_a, n_l, n_μ, b_μ, offload, partition) grid lazily, in a fixed
//!    order, after the cheap structural filters (§5 rules, critical-batch
//!    budget, config validity);
//! 2. **prune** — each candidate first passes a memory lower bound (the
//!    closed-form breakdown, no speed estimate) and a branch-and-bound
//!    cutoff: a candidate whose compute-only optimistic time
//!    ([`super::candidates::optimistic_secs`]) already exceeds the
//!    incumbent's total can neither beat nor tie it, so the full cost
//!    model is never evaluated;
//! 3. **evaluate** — surviving candidates get the full cost-model
//!    evaluation, fanned out over [`super::par::planner_threads`] scoped
//!    worker threads that self-schedule chunks of the grid and share the
//!    incumbent through an atomic.
//!
//! The selection fold runs serially over the results *in enumeration
//! order*, using the same tie-break rule as the retained serial reference
//! ([`search_fastest_exhaustive`]); a pruned candidate is lazily
//! re-evaluated at fold time in the rare case the bound cannot rule it
//! out against the fold's own best. That makes the parallel search
//! *provably* pick the identical plan — `tests/planner_parity.rs` checks
//! it across strategies and clusters.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::OnceLock;

use crate::costmodel::{MemoryBreakdown, ParallelismMenu, Strategy, TrainConfig};
use crate::hardware::ClusterSpec;
use crate::model::XModel;

use crate::analysis::{check_program_memory, MemoryModel};
use crate::sim::CostTable;

use super::cache::{LoweringCache, PolicyKind};
use super::candidates::{optimistic_secs, Candidates};
use super::par::{in_parallel_region, mark_worker, planner_threads};
use super::rules::Plan;
use super::simloop::plan_spec;

/// A candidate must be this factor faster to displace the incumbent.
const STRICT_IMPROVE: f64 = 0.9999;
/// Relative band within which two plans count as tied (and the
/// non-offloaded one is preferred).
const TIE_BAND: f64 = 1e-4;
/// Branch-and-bound margin: a candidate whose *optimistic* time exceeds
/// `incumbent × PRUNE_MARGIN` has an actual time strictly outside the tie
/// band of any plan at least as fast as the incumbent, so it can neither
/// displace nor tie the eventual winner. (The fold re-checks the bound
/// against its own best before trusting a prune — see `search_over`.)
const PRUNE_MARGIN: f64 = 1.0 + 2.0 * TIE_BAND;
/// Candidates per work-queue claim in the parallel fan-out.
const CHUNK: usize = 64;
/// Below this many candidates the fan-out is not worth the thread spawns.
const PAR_THRESHOLD: usize = 4 * CHUNK;

/// Exhaustive-grid search for the fastest feasible configuration of a
/// strategy on a cluster, pruned and parallelised. Slower than
/// [`super::rules::fastest_plan`] but robust to unusual clusters; used by
/// the figure sweeps. Selects the identical plan as
/// [`search_fastest_exhaustive`].
pub fn search_fastest(
    model: &XModel,
    cluster: &ClusterSpec,
    strategy: Strategy,
    menu: ParallelismMenu,
) -> Option<Plan> {
    search_fastest_tp(model, cluster, strategy, menu, None)
}

/// [`search_fastest`] restricted to one tensor-parallel degree: the
/// `repro plan --tp N` sweep axis. `None` searches the whole n_a grid
/// (identical to `search_fastest` — the filter preserves enumeration
/// order, so parity with the exhaustive reference is untouched).
pub fn search_fastest_tp(
    model: &XModel,
    cluster: &ClusterSpec,
    strategy: Strategy,
    menu: ParallelismMenu,
    tp: Option<usize>,
) -> Option<Plan> {
    let mut cands: Vec<TrainConfig> =
        Candidates::new(model, cluster, strategy, menu).collect();
    if let Some(tp) = tp {
        cands.retain(|c| c.n_a == tp);
    }
    search_over(model, cluster, &cands)
}

/// [`search_fastest`] with the candidate grid moved to one ZeRO stage:
/// the `repro plan --zero N` axis. `Some(z)` with z > 0 drops the
/// partitioned candidates (the two state shardings are mutually
/// exclusive) and re-prices the survivors at stage `z` — the memory
/// model then shards the optimizer state 1/n_b and the cost table
/// prices the reduce-scatter + all-gather volume. `Some(0)` / `None`
/// leave the grid untouched (identical to `search_fastest`).
pub fn search_fastest_zero(
    model: &XModel,
    cluster: &ClusterSpec,
    strategy: Strategy,
    menu: ParallelismMenu,
    zero: Option<u8>,
) -> Option<Plan> {
    let mut cands: Vec<TrainConfig> =
        Candidates::new(model, cluster, strategy, menu).collect();
    if let Some(z) = zero {
        if z > 0 {
            cands.retain(|c| !c.partition);
            for c in &mut cands {
                c.zero = z;
            }
        }
    }
    search_over(model, cluster, &cands)
}

/// The retained serial reference: full cost-model evaluation of every
/// enumerated candidate, no pruning, no threads. Kept so the parity
/// tests can prove the optimised search changes nothing, and as the
/// baseline in `benches/planner_search.rs`.
pub fn search_fastest_exhaustive(
    model: &XModel,
    cluster: &ClusterSpec,
    strategy: Strategy,
    menu: ParallelismMenu,
) -> Option<Plan> {
    let mut best: Option<Plan> = None;
    for cfg in Candidates::new(model, cluster, strategy, menu) {
        if let Some(plan) = evaluate_exhaustive(model, cluster, &cfg) {
            consider(&mut best, plan);
        }
    }
    best
}

/// Whole-world static verification of one candidate plan — the
/// planner-side hook of [`crate::analysis`]. Snaps the plan to the
/// executable spec the simulator would run ([`plan_spec`]), checks the
/// structural properties (p2p matching, collective congruence, global
/// deadlock freedom) through the memoised verdict in
/// [`LoweringCache::global`], then bounds the per-rank peak memory
/// against the device budget with the candidate's own cost table.
///
/// For generated schedules this accepts everything the planner's
/// analytic feasibility checks admit: the structural checks hold by
/// construction, and the static memory bound is provably ≤ the
/// analytic [`MemoryBreakdown`] footprint the search already requires
/// to fit (see [`crate::analysis`]'s memory docs). The filter therefore
/// only bites on hand-built or corrupted plans — which is the point:
/// statically-invalid plans never reach the simulator, let alone a
/// cluster.
pub fn statically_valid(model: &XModel, cluster: &ClusterSpec, plan: &Plan) -> Result<(), String> {
    let shape = model.shape();
    let (cfg, spec) = plan_spec(shape.d_l, &plan.cfg);
    let kind = PolicyKind::for_config(cfg.strategy, cfg.n_l);
    let cache = LoweringCache::global();
    cache.verify_structural(kind, &spec)?;
    let program = cache.lower(kind, &spec);
    let costs = CostTable::new(&shape, &cfg, cluster);
    let memory = MemoryBreakdown::evaluate(&shape, &cfg);
    let budget = MemoryModel::new(&costs, &memory, cluster.gpu.memory_bytes, cfg.offload);
    check_program_memory(&program, &budget).map_err(|e| e.to_string())
}

/// The shared selection fold step. `plan` displaces `best` when it is
/// strictly faster (beyond [`STRICT_IMPROVE`]) or ties within
/// [`TIE_BAND`] while avoiding offload the incumbent pays for.
fn consider(best: &mut Option<Plan>, plan: Plan) {
    let better = match best {
        None => true,
        Some(b) => {
            plan.speed.training_secs < b.speed.training_secs * STRICT_IMPROVE
                || ((plan.speed.training_secs - b.speed.training_secs).abs()
                    < b.speed.training_secs * TIE_BAND
                    && !plan.cfg.offload
                    && b.cfg.offload)
        }
    };
    if better {
        *best = Some(plan);
    }
}

/// The §5 tie-break the pre-refactor code described but left as a no-op:
/// an offloaded candidate whose offload traffic is fully overlapped
/// (zero overhead) buys nothing over its non-offloaded twin — when the
/// twin also fits the GPU, prefer the twin (which the enumeration always
/// visits first) and drop the offloaded copy.
///
/// No twin evaluation is needed: `cfg.offload` enters the speed estimate
/// only through the offload and PCIe-contention overhead terms (both
/// ≥ 0 and both absent for the twin), so the twin is never slower; and
/// `MemoryBreakdown::evaluate` never reads the flag, so the twin's
/// un-offloaded footprint is exactly `plan.memory.total()`. The
/// regression test below proves both claims against explicitly built
/// twins.
fn skip_pointless_offload(cluster: &ClusterSpec, plan: &Plan) -> bool {
    plan.cfg.offload
        && plan.speed.overheads.offload == 0.0
        && plan.memory.total() <= cluster.gpu.memory_bytes
}

/// Full evaluation in the legacy cost order (memory and speed both
/// computed before the fit check) — the serial reference's per-candidate
/// work, and the "before" cost the planner bench measures.
fn evaluate_exhaustive(model: &XModel, cluster: &ClusterSpec, cfg: &TrainConfig) -> Option<Plan> {
    let plan = Plan::build_pub(model, *cfg, cluster);
    if !plan.fits_gpu(cluster) {
        return None;
    }
    if skip_pointless_offload(cluster, &plan) {
        return None;
    }
    Some(plan)
}

/// Pre-filtered evaluation: the cheap memory lower bound runs first and
/// rejects unfittable candidates before the speed estimate is ever
/// computed. Accepts exactly the same candidates (with identical plan
/// values) as [`evaluate_exhaustive`].
fn evaluate_pruned(model: &XModel, cluster: &ClusterSpec, cfg: &TrainConfig) -> Option<Plan> {
    let memory = MemoryBreakdown::evaluate(&model.shape(), cfg);
    if memory.gpu_resident(cfg.offload) > cluster.gpu.memory_bytes {
        return None;
    }
    let plan = Plan::build_with_memory(model, *cfg, cluster, memory);
    if skip_pointless_offload(cluster, &plan) {
        return None;
    }
    Some(plan)
}

/// One evaluated slot of the parallel fan-out.
enum Slot {
    Plan(Plan),
    /// Evaluated and rejected (does not fit, or pointless offload).
    Rejected,
    /// Branch-and-bound skipped it; the fold re-checks the bound.
    Pruned,
}

/// Lower monotonically: `incumbent = min(incumbent, t)` over f64 bits
/// (all values are positive and finite, so bit-compare via `from_bits`
/// is exact).
fn relax_incumbent(incumbent: &AtomicU64, t: f64) {
    let mut cur = incumbent.load(Ordering::Relaxed);
    while t < f64::from_bits(cur) {
        match incumbent.compare_exchange_weak(
            cur,
            t.to_bits(),
            Ordering::Relaxed,
            Ordering::Relaxed,
        ) {
            Ok(_) => break,
            Err(seen) => cur = seen,
        }
    }
}

/// Prune + evaluate + fold an ordered candidate list.
fn search_over(model: &XModel, cluster: &ClusterSpec, cands: &[TrainConfig]) -> Option<Plan> {
    let n = cands.len();
    let threads = if n < PAR_THRESHOLD || in_parallel_region() {
        1
    } else {
        planner_threads().min(n.div_ceil(CHUNK))
    };

    if threads <= 1 {
        // Serial path: branch-and-bound directly against the fold best.
        // `PRUNE_MARGIN` > 1 + TIE_BAND, so a pruned candidate could
        // neither displace nor tie it — exactness is immediate.
        let mut best: Option<Plan> = None;
        for cfg in cands {
            if let Some(b) = &best {
                if optimistic_secs(model, cfg, cluster)
                    > b.speed.training_secs * PRUNE_MARGIN
                {
                    continue;
                }
            }
            if let Some(plan) = evaluate_pruned(model, cluster, cfg) {
                consider(&mut best, plan);
            }
        }
        return best;
    }

    // Parallel phase: workers claim chunks in enumeration order and share
    // the best time seen so far through `incumbent` (a heuristic — only
    // used to skip work, never to decide the winner).
    let slots: Vec<OnceLock<Slot>> = std::iter::repeat_with(OnceLock::new).take(n).collect();
    let incumbent = AtomicU64::new(f64::INFINITY.to_bits());
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| {
                mark_worker();
                loop {
                    let start = next.fetch_add(CHUNK, Ordering::Relaxed);
                    if start >= n {
                        break;
                    }
                    for i in start..(start + CHUNK).min(n) {
                        let cfg = &cands[i];
                        let inc = f64::from_bits(incumbent.load(Ordering::Relaxed));
                        let slot = if optimistic_secs(model, cfg, cluster) > inc * PRUNE_MARGIN
                        {
                            Slot::Pruned
                        } else {
                            match evaluate_pruned(model, cluster, cfg) {
                                Some(plan) => {
                                    relax_incumbent(&incumbent, plan.speed.training_secs);
                                    Slot::Plan(plan)
                                }
                                None => Slot::Rejected,
                            }
                        };
                        let _ = slots[i].set(slot);
                    }
                }
            });
        }
    });

    // Ordered fold — byte-for-byte the serial reference's selection. A
    // parallel-phase prune was taken against a racing incumbent; trust it
    // only when the bound also rules the candidate out against the fold's
    // own best (it cannot strictly beat nor tie inside TIE_BAND), else
    // evaluate it here.
    let mut best: Option<Plan> = None;
    for (i, slot) in slots.into_iter().enumerate() {
        match slot.into_inner().expect("worker filled every slot") {
            Slot::Plan(plan) => consider(&mut best, plan),
            Slot::Rejected => {}
            Slot::Pruned => {
                let needs_eval = match &best {
                    None => true,
                    Some(b) => {
                        optimistic_secs(model, &cands[i], cluster)
                            < b.speed.training_secs * (1.0 + TIE_BAND)
                    }
                };
                if needs_eval {
                    if let Some(plan) = evaluate_pruned(model, cluster, &cands[i]) {
                        consider(&mut best, plan);
                    }
                }
            }
        }
    }
    best
}

impl Plan {
    /// Public constructor used by the search (same as the private
    /// `Plan::build`).
    pub fn build_pub(model: &XModel, cfg: TrainConfig, cluster: &ClusterSpec) -> Self {
        let memory = MemoryBreakdown::evaluate(&model.shape(), &cfg);
        Self::build_with_memory(model, cfg, cluster, memory)
    }

    /// Constructor for callers that already evaluated the memory
    /// breakdown (the search's pre-filter): only the speed estimate is
    /// computed here.
    pub(crate) fn build_with_memory(
        model: &XModel,
        cfg: TrainConfig,
        cluster: &ClusterSpec,
        memory: MemoryBreakdown,
    ) -> Self {
        let speed = crate::costmodel::estimate(model, &cfg, cluster);
        let cpu_memory_exceeded =
            cfg.offload && memory.offloadable() > cluster.cpu_memory_per_gpu;
        Plan { cfg, speed, memory, cpu_memory_exceeded }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn search_matches_rules_at_x160_3d() {
        // The grid search should find a plan at least as fast as the
        // closed-form rules on the reference cluster.
        let model = XModel::x160();
        let cluster = ClusterSpec::reference();
        let ruled = super::super::rules::fastest_plan(
            &model,
            &cluster,
            Strategy::Improved,
            ParallelismMenu::THREE_D,
        )
        .unwrap();
        let searched =
            search_fastest(&model, &cluster, Strategy::Improved, ParallelismMenu::THREE_D)
                .unwrap();
        assert!(searched.speed.training_secs <= ruled.speed.training_secs * 1.02);
    }

    #[test]
    fn ethernet_penalty_shrinks_with_scale() {
        // Figure 8 / §8.3 shape check: the relative Ethernet slowdown of
        // the improved method decreases as the model grows.
        let ib = ClusterSpec::reference();
        let eth = ClusterSpec::ethernet();
        let penalty = |x: usize| {
            let m = XModel::new(x);
            let a = search_fastest(&m, &ib, Strategy::Improved, ParallelismMenu::THREE_D)
                .unwrap()
                .speed
                .training_secs;
            let b = search_fastest(&m, &eth, Strategy::Improved, ParallelismMenu::THREE_D)
                .unwrap()
                .speed
                .training_secs;
            b / a
        };
        let small = penalty(32);
        let large = penalty(160);
        assert!(
            large < small,
            "penalty should shrink with scale: X_32 {small:.3} vs X_160 {large:.3}"
        );
        assert!(large < 1.6, "X_160 Ethernet penalty too large: {large:.3}");
    }

    #[test]
    fn pruned_parallel_search_matches_the_exhaustive_reference() {
        // The full matrix lives in tests/planner_parity.rs; this is the
        // in-crate smoke version.
        let model = XModel::new(32);
        let cluster = ClusterSpec::reference();
        let fast =
            search_fastest(&model, &cluster, Strategy::Improved, ParallelismMenu::THREE_D)
                .expect("plan");
        let slow = search_fastest_exhaustive(
            &model,
            &cluster,
            Strategy::Improved,
            ParallelismMenu::THREE_D,
        )
        .expect("plan");
        assert_eq!(fast.cfg, slow.cfg);
        assert!(
            (fast.speed.training_secs - slow.speed.training_secs).abs()
                <= 1e-9 * slow.speed.training_secs,
            "{} vs {}",
            fast.speed.training_secs,
            slow.speed.training_secs
        );
    }

    /// Regression for the once-empty offload tie-break branch: a
    /// zero-overhead offloaded candidate whose twin fits must be dropped
    /// in favour of the twin.
    #[test]
    fn pointless_offload_candidates_are_skipped() {
        let cluster = ClusterSpec::reference();
        let mut found = 0usize;
        for x in [8usize, 32, 64] {
            let model = XModel::new(x);
            for strategy in Strategy::ALL {
                for cfg in
                    Candidates::new(&model, &cluster, strategy, ParallelismMenu::THREE_D)
                {
                    if !cfg.offload {
                        continue;
                    }
                    let plan = Plan::build_pub(&model, cfg, &cluster);
                    if !plan.fits_gpu(&cluster) {
                        continue;
                    }
                    // The shortcut must agree with the explicitly built
                    // twin in both directions — this is the proof of the
                    // "no twin evaluation needed" claims in its docs.
                    let twin = Plan::build_pub(
                        &model,
                        TrainConfig { offload: false, ..cfg },
                        &cluster,
                    );
                    let twin_wins = plan.speed.overheads.offload == 0.0
                        && twin.fits_gpu(&cluster)
                        && twin.speed.training_secs <= plan.speed.training_secs;
                    assert_eq!(
                        skip_pointless_offload(&cluster, &plan),
                        twin_wins,
                        "shortcut disagrees with the built twin: {cfg:?}"
                    );
                    if twin_wins {
                        found += 1;
                        assert!(evaluate_pruned(&model, &cluster, &cfg).is_none());
                        assert!(evaluate_exhaustive(&model, &cluster, &cfg).is_none());
                    }
                }
            }
        }
        assert!(found > 0, "test never exercised the tie-break");
    }

    /// The search must never return a plan that pays for offload it does
    /// not need (zero overhead and a feasible twin).
    #[test]
    fn search_result_never_carries_pointless_offload() {
        for cluster in [ClusterSpec::reference(), ClusterSpec::ethernet()] {
            for x in [16usize, 64] {
                let model = XModel::new(x);
                for strategy in Strategy::ALL {
                    let Some(plan) =
                        search_fastest(&model, &cluster, strategy, ParallelismMenu::THREE_D)
                    else {
                        continue;
                    };
                    assert!(
                        !skip_pointless_offload(&cluster, &plan),
                        "{strategy:?}/X_{x}: {:?}",
                        plan.cfg
                    );
                }
            }
        }
    }
}
