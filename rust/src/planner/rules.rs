//! The paper's §5 closed-form configuration selection rules.
//!
//! These rules reproduce the configuration columns of Table 6.1 exactly
//! (see the tests). The general procedure:
//!
//! * train at (or just below) the critical batch size b_c;
//! * tensor parallelism: the largest n_a within the node whose all-reduce
//!   overhead stays under 25%;
//! * baseline pipeline: n_l = d_l, with enough extra micro-batches to
//!   overlap the boundary transfers, and the rest of the batch budget
//!   spent on more micro-batches (smaller bubble);
//! * improved pipeline: b_μ = 1, the smallest n_μ = n_l that satisfies the
//!   gradient-reduction overlap bound, data parallelism maximised first;
//! * offload when (and only when) the un-offloaded footprint exceeds GPU
//!   memory; micro-batch sizes are bumped until the CPU-GPU (and shared
//!   PCIe) transfer is hidden.

use crate::costmodel::{MemoryBreakdown, ParallelismMenu, SpeedEstimate, Strategy, TrainConfig};
use crate::hardware::{ClusterSpec, InterNode, LinkKind};
use crate::model::XModel;

/// Maximum tolerated overhead for the tensor-parallel all-reduce and the
/// non-overlapped gradient reduction (§5: "we impose a maximum overhead of
/// 25%").
pub const MAX_OVERHEAD: f64 = 0.25;

/// A planned configuration with its predicted resources.
#[derive(Debug, Clone)]
pub struct Plan {
    pub cfg: TrainConfig,
    pub speed: SpeedEstimate,
    pub memory: MemoryBreakdown,
    /// True when the plan needs more CPU memory than the cluster provides
    /// per GPU (the paper flags but does not forbid this).
    pub cpu_memory_exceeded: bool,
}

impl Plan {
    fn build(model: &XModel, cfg: TrainConfig, cluster: &ClusterSpec) -> Self {
        // One constructor shared with the grid search (defined in
        // `search.rs` next to its memory-prefiltered sibling).
        Plan::build_pub(model, cfg, cluster)
    }

    /// Whether the GPU-resident footprint fits in device memory.
    pub fn fits_gpu(&self, cluster: &ClusterSpec) -> bool {
        self.memory.gpu_resident(self.cfg.offload) <= cluster.gpu.memory_bytes
    }
}

/// Largest tensor-parallel degree with all-reduce overhead ≤ 25%
/// (Appendix C.4.3): ν_a = (4+2n_I)·d_m/(3(n_a−1)) against the TP link
/// threshold, capped by the node size and the head count.
pub fn max_tensor_parallel(model: &XModel, cluster: &ClusterSpec) -> usize {
    let shape = model.shape();
    let d_m = shape.d_m() as f64;
    let n_i = shape.n_i as f64;
    let thr_nvlink = LinkKind::NvLink.intensity_threshold(&cluster.gpu);
    // overhead = thr·3(n_a−1)/((4+2n_I)d_m) ≤ MAX_OVERHEAD
    let by_overhead = |thr: f64| 1.0 + MAX_OVERHEAD * (4.0 + 2.0 * n_i) * d_m / (3.0 * thr);
    let cap = shape.d_a.max(1);
    let in_node = (by_overhead(thr_nvlink).floor() as usize)
        .min(cluster.max_node_size)
        .min(cap)
        .max(1);
    // §7: at extreme scales tensor parallelism can spill past the node
    // over the inter-node fabric.
    let thr_inter = cluster.inter_node_threshold();
    let beyond = (by_overhead(thr_inter).floor() as usize).min(cap).max(1);
    if beyond > cluster.max_node_size {
        beyond
    } else {
        in_node
    }
}

/// The fastest configuration for a (strategy, menu) pair per the paper's
/// §5 rules. Returns `None` when the pair is meaningless (e.g. a
/// Partitioned strategy with no data parallelism) or cannot fit.
pub fn fastest_plan(
    model: &XModel,
    cluster: &ClusterSpec,
    strategy: Strategy,
    menu: ParallelismMenu,
) -> Option<Plan> {
    match strategy {
        Strategy::Baseline => baseline_plan(model, cluster, menu),
        Strategy::Partitioned => partitioned_plan(model, cluster, menu),
        Strategy::Improved => improved_plan(model, cluster, menu, true),
    }
}

/// The improved plan with the partition disabled (§8.3 dotted line).
pub fn improved_unpartitioned_plan(
    model: &XModel,
    cluster: &ClusterSpec,
    menu: ParallelismMenu,
) -> Option<Plan> {
    improved_plan(model, cluster, menu, false)
}

fn inter_threshold(cluster: &ClusterSpec) -> f64 {
    cluster.inter_node_threshold()
}

/// Smallest integer micro-batch size ≥ `min_f` (at least 1).
fn ceil_bmu(min_f: f64) -> f64 {
    min_f.max(1.0).ceil()
}

fn baseline_plan(model: &XModel, cluster: &ClusterSpec, menu: ParallelismMenu) -> Option<Plan> {
    let shape = model.shape();
    let d_s = shape.d_s as f64;
    let bc = model.critical_batch_size();
    let n_a = if menu.tensor { max_tensor_parallel(model, cluster) } else { 1 };
    let n_l = if menu.pipeline { shape.d_l } else { 1 };
    let thr = inter_threshold(cluster);
    let thr_cpu = LinkKind::CpuGpu.intensity_threshold(&cluster.gpu);
    let thr_pcie = LinkKind::PciExpress.intensity_threshold(&cluster.gpu);

    // Iterate on the offload decision (it feeds back into b_μ).
    let mut offload = false;
    for _ in 0..3 {
        // --- micro-batch size ---
        let mut b_mu_min: f64 = 1.0;
        if offload {
            // ν_s^base = b_μ·d_s must beat the CPU-GPU threshold (eq. 13).
            b_mu_min = b_mu_min.max(thr_cpu / d_s);
        }
        if menu.data && n_l == 1 {
            // Overlapped reduction, n_μ = 1: ν_b = 3 b_μ d_s/4 (eq. 5).
            b_mu_min = b_mu_min.max(4.0 * thr / (3.0 * d_s));
            if offload
                && cluster.pcie_shared_with_nic
                && cluster.inter_node == InterNode::InfiniBand
            {
                // Shared-PCIe harmonic constraint: 3 b_μ d_s / 7 ≥ ν_pcie.
                b_mu_min = b_mu_min.max(7.0 * thr_pcie / (3.0 * d_s));
            }
        }
        let b_mu = ceil_bmu(b_mu_min);

        // --- micro-batch count & data parallel degree ---
        let (n_b, n_mu) = if n_l > 1 {
            // Enough extra micro-batches to overlap boundary transfers
            // (C.4.2), then spend the rest of the batch budget on more
            // micro-batches to shrink the bubble.
            let nu_l = (4.0 + 2.0 * shape.n_i as f64) * shape.d_m() as f64 / 2.0
                * (shape.d_l as f64 / n_l as f64);
            let n_mu_min = ((n_l as f64) * (1.0 + thr / nu_l)).ceil() as usize;
            let n_b = if menu.data {
                ((bc / (n_mu_min as f64 * b_mu)).floor() as usize).max(1)
            } else {
                1
            };
            let n_mu = ((bc / (n_b as f64 * b_mu)).floor() as usize).max(n_mu_min);
            (n_b, n_mu)
        } else if menu.data {
            let n_b = ((bc / b_mu).floor() as usize).max(1);
            (n_b, 1)
        } else {
            (1, ((bc / b_mu).floor() as usize).max(1))
        };

        let cfg = TrainConfig {
            strategy: Strategy::Baseline,
            n_b,
            n_l,
            n_a,
            n_mu,
            b_mu,
            offload,
            partition: false,
            zero: 0,
        };
        let plan = Plan::build(model, cfg, cluster);
        if plan.fits_gpu(cluster) {
            return Some(plan);
        }
        if offload {
            // Even offloaded it does not fit: infeasible.
            return Some(plan);
        }
        offload = true;
    }
    None
}

fn partitioned_plan(model: &XModel, cluster: &ClusterSpec, menu: ParallelismMenu) -> Option<Plan> {
    if !menu.data {
        return None; // the partition is a data-parallel-direction concept
    }
    if menu.pipeline {
        return None; // §5: "we do not consider pipeline parallelism as it
                     // leads to worse results" for the partitioned approach
    }
    let shape = model.shape();
    let d_s = shape.d_s as f64;
    let bc = model.critical_batch_size();
    let n_a = if menu.tensor { max_tensor_parallel(model, cluster) } else { 1 };
    let thr = inter_threshold(cluster);

    // ν_b^base-part = b_μ d_s / 2 ≥ thr (eq. 7 with n_μ = 1).
    let b_mu = ceil_bmu(2.0 * thr / d_s);
    let n_b = ((bc / b_mu).floor() as usize).max(1);

    let mut cfg = TrainConfig {
        strategy: Strategy::Partitioned,
        n_b,
        n_l: 1,
        n_a,
        n_mu: 1,
        b_mu,
        offload: false,
        partition: true,
        zero: 0,
    };
    let mut plan = Plan::build(model, cfg, cluster);
    if !plan.fits_gpu(cluster) {
        cfg.offload = true;
        plan = Plan::build(model, cfg, cluster);
    }
    Some(plan)
}

fn improved_plan(
    model: &XModel,
    cluster: &ClusterSpec,
    menu: ParallelismMenu,
    partition: bool,
) -> Option<Plan> {
    if !menu.pipeline && !menu.data {
        return None;
    }
    let shape = model.shape();
    let d_s = shape.d_s as f64;
    let bc = model.critical_batch_size();
    let n_a = if menu.tensor { max_tensor_parallel(model, cluster) } else { 1 };
    let thr = inter_threshold(cluster);
    let b_mu = 1.0;

    // Gradient-reduction overlap bound (eqs. 8–9 with b = n_b·n_μ):
    // partitioned: n_μ ≥ 2 thr / d_s ; plain: n_μ ≥ 4 thr / (3 d_s).
    let n_mu_req = if menu.data {
        let f = if partition { 2.0 * thr / d_s } else { 4.0 * thr / (3.0 * d_s) };
        (f.ceil() as usize).max(1)
    } else {
        1
    };

    // Candidate A: n_l = n_μ (minimal bubble-free-ish, transfers exposed).
    // Candidate B: extra micro-batches so the modular boundary transfers
    // overlap (useful on slow networks / small models).
    let mut best: Option<Plan> = None;
    let d_l = shape.d_l;
    let candidates: Vec<(usize, usize)> = if menu.pipeline {
        let n_l_a = n_mu_req.clamp(2, d_l);
        let n_mu_a = n_mu_req.max(n_l_a);
        let n_mu_b = n_mu_a + (n_l_a as f64 * 0.25).ceil() as usize;
        vec![(n_l_a, n_mu_a), (n_l_a, n_mu_b)]
    } else {
        vec![(1, n_mu_req)]
    };
    for (n_l, n_mu) in candidates {
        let n_b = if menu.data {
            ((bc / (n_mu as f64 * b_mu)).floor() as usize).max(1)
        } else {
            1
        };
        if partition && n_b == 1 && menu.data {
            // partition over one instance is a no-op but harmless
        }
        let mut cfg = TrainConfig {
            strategy: Strategy::Improved,
            n_b,
            n_l,
            n_a,
            n_mu,
            b_mu,
            offload: false,
            partition,
            zero: 0,
        };
        let mut plan = Plan::build(model, cfg, cluster);
        if !plan.fits_gpu(cluster) {
            cfg.offload = true;
            plan = Plan::build(model, cfg, cluster);
            if !plan.fits_gpu(cluster) {
                continue;
            }
        }
        let better = match &best {
            None => true,
            Some(b) => plan.speed.training_secs < b.speed.training_secs,
        };
        if better {
            best = Some(plan);
        }
    }
    best
}

#[cfg(test)]
mod tests {
    use super::*;

    /// The §5 rules reproduce the configuration columns of Table 6.1.
    #[test]
    fn table_6_1_configurations() {
        let model = XModel::x160();
        let cluster = ClusterSpec::reference();
        // (strategy, menu, b, b_mu, n_mu, n_gpu, n_b, n_l, n_a, offload)
        let rows: Vec<(Strategy, ParallelismMenu, f64, f64, usize, usize, usize, usize, usize, bool)> = vec![
            (Strategy::Baseline, ParallelismMenu::NONE, 2416.0, 4.0, 604, 1, 1, 1, 1, true),
            (Strategy::Baseline, ParallelismMenu::DATA, 2415.0, 5.0, 1, 483, 483, 1, 1, true),
            (Strategy::Partitioned, ParallelismMenu::DATA, 2415.0, 5.0, 1, 483, 483, 1, 1, true),
            (Strategy::Baseline, ParallelismMenu::DATA_PIPE, 2412.0, 4.0, 201, 480, 3, 160, 1, true),
            (Strategy::Improved, ParallelismMenu::DATA_PIPE, 2415.0, 1.0, 5, 2415, 483, 5, 1, false),
            (Strategy::Baseline, ParallelismMenu::DATA_TENSOR, 2415.0, 5.0, 1, 7728, 483, 1, 16, true),
            (Strategy::Partitioned, ParallelismMenu::DATA_TENSOR, 2415.0, 5.0, 1, 7728, 483, 1, 16, false),
            (Strategy::Baseline, ParallelismMenu::THREE_D, 2408.0, 1.0, 172, 35840, 14, 160, 16, false),
            (Strategy::Improved, ParallelismMenu::THREE_D, 2415.0, 1.0, 5, 38640, 483, 5, 16, false),
        ];
        for (i, (s, m, b, b_mu, n_mu, n_gpu, n_b, n_l, n_a, offload)) in
            rows.into_iter().enumerate()
        {
            let plan = fastest_plan(&model, &cluster, s, m)
                .unwrap_or_else(|| panic!("row {i}: no plan"));
            let c = plan.cfg;
            assert_eq!(c.n_b, n_b, "row {i} n_b");
            assert_eq!(c.n_l, n_l, "row {i} n_l");
            assert_eq!(c.n_a, n_a, "row {i} n_a");
            assert_eq!(c.n_mu, n_mu, "row {i} n_mu");
            assert_eq!(c.b_mu, b_mu, "row {i} b_mu");
            assert_eq!(c.n_gpu(), n_gpu, "row {i} n_gpu");
            assert_eq!(c.offload, offload, "row {i} offload");
            assert!((c.batch_size() - b).abs() < 0.5, "row {i} batch");
        }
    }

    #[test]
    fn max_tp_is_16_for_large_models_in_a_node() {
        // §5: for models above ~50B parameters the 25% bound allows the
        // practical node limit n_a = 16.
        let cluster = ClusterSpec::reference();
        assert_eq!(max_tensor_parallel(&XModel::x160(), &cluster), 16);
        assert_eq!(max_tensor_parallel(&XModel::new(108), &cluster), 16);
        // Tiny models cannot use 16-way TP efficiently.
        assert!(max_tensor_parallel(&XModel::new(4), &cluster) < 16);
    }

    #[test]
    fn unlimited_node_allows_larger_tp() {
        let na = max_tensor_parallel(&XModel::x160(), &ClusterSpec::unlimited_node());
        assert!(na > 16, "got {na}");
    }

    #[test]
    fn improved_beats_baseline_at_x160_for_every_shared_menu() {
        let model = XModel::x160();
        let cluster = ClusterSpec::reference();
        for menu in [ParallelismMenu::DATA_PIPE, ParallelismMenu::THREE_D] {
            let b = fastest_plan(&model, &cluster, Strategy::Baseline, menu).unwrap();
            let i = fastest_plan(&model, &cluster, Strategy::Improved, menu).unwrap();
            assert!(
                i.speed.training_secs < b.speed.training_secs,
                "{menu}: improved {:.1}d vs baseline {:.1}d",
                i.speed.training_days(),
                b.speed.training_days()
            );
        }
    }

    #[test]
    fn improved_3d_memory_is_a_tiny_fraction_of_the_gpu() {
        // §6: "lowest memory footprint of 4.72 GB, 17 times less than an
        // 80 GB A100" (1.58 offloadable + 3.14 non-offloadable GiB).
        let model = XModel::x160();
        let cluster = ClusterSpec::reference();
        let p = fastest_plan(&model, &cluster, Strategy::Improved, ParallelismMenu::THREE_D)
            .unwrap();
        let total = p.memory.total();
        assert!(total < cluster.gpu.memory_bytes / 15.0);
    }
}
