//! MTBF-aware planning: "cheapest plan with ≤ X% expected lost work".
//!
//! At cluster scale the planner's question is not just "which
//! configuration is fastest" but "which is fastest *subject to* a
//! reliability budget": every failure rolls the job back to its last
//! durable checkpoint and charges a parameter restore. Offloaded plans
//! stream the training state through host memory every step, so their
//! effective checkpoint interval is one step; classic in-GPU training
//! checkpoints orders of magnitude less often. That asymmetry is the
//! paper's Figure 2 restore-ratio argument, surfaced here as a planner
//! constraint (`repro plan --mtbf HOURS --max-lost-work PCT`).
//!
//! The bound is deliberately conservative: it charges every failure the
//! *worst-case* rollback (a full checkpoint interval plus the restore),
//! so a plan that passes the filter also passes the discrete-event
//! replay in [`crate::sim::simulate_with_failures`] for any failure
//! draw (`tests/chaos.rs` checks both directions).

use crate::costmodel::{ParallelismMenu, Strategy, TrainConfig};
use crate::hardware::ClusterSpec;
use crate::model::XModel;
use crate::sim::{recovery_costs, CostTable};

use super::rules::{fastest_plan, Plan};
use super::search::search_fastest_tp;
use super::simloop::{lower_plan, rank_by_simulation, SimulatedPlan};

/// Reliability constraint for [`plan_with_reliability`].
#[derive(Debug, Clone, Copy)]
pub struct ReliabilityParams {
    /// Mean time between failures of a single device, hours. The job's
    /// failure rate scales with its device count: λ_job = n_gpu / MTBF.
    /// Must be positive.
    pub mtbf_hours: f64,
    /// Acceptable expected lost work, as a fraction of wall clock.
    pub max_lost_work: f64,
}

/// Durable-checkpoint interval (steps) assumed for plans that keep the
/// training state resident in GPU memory. Classic jobs checkpoint
/// rarely because a full-state dump stalls training; 64 steps is the
/// order of magnitude the Figure 2 comparison assumes. Offloaded plans
/// pay nothing extra for durability — the state already streams through
/// the host every step — so their interval is 1.
pub const CLASSIC_CKPT_INTERVAL_STEPS: usize = 64;

/// The checkpoint interval a configuration's storage tier implies.
pub fn ckpt_interval_steps(cfg: &TrainConfig) -> usize {
    if cfg.offload {
        1
    } else {
        CLASSIC_CKPT_INTERVAL_STEPS
    }
}

/// A plan's reliability accounting, from the lowered schedule's real
/// costs (not closed-form estimates).
#[derive(Debug, Clone, Copy)]
pub struct LostWorkBound {
    /// Simulated seconds per training step (one batch on one
    /// data-parallel instance).
    pub step_secs: f64,
    /// Restore cost charged per failure: the slowest stage's
    /// `RestoreParams` volume at the schedule's real wire costs.
    pub restore_secs: f64,
    /// Durable-checkpoint interval the bound assumes, steps.
    pub ckpt_interval: usize,
    /// Upper bound on the expected lost-work fraction:
    /// λ_job · (restore + interval · step) — failure rate times the
    /// worst-case wall clock one failure can cost.
    pub fraction: f64,
}

/// Bound the expected lost-work fraction of `plan` under `rel`.
pub fn lost_work_bound(
    model: &XModel,
    cluster: &ClusterSpec,
    plan: &Plan,
    rel: &ReliabilityParams,
) -> LostWorkBound {
    let (cfg, prog) = lower_plan(model, plan);
    let costs = CostTable::new(&model.shape(), &cfg, cluster);
    let (step_secs, restore_secs) = recovery_costs(&prog, &costs);
    let ckpt_interval = ckpt_interval_steps(&cfg);
    let lambda_job = cfg.n_gpu() as f64 / (rel.mtbf_hours * 3600.0);
    let fraction = lambda_job * (restore_secs + ckpt_interval as f64 * step_secs);
    LostWorkBound { step_secs, restore_secs, ckpt_interval, fraction }
}

/// A plan annotated with its simulated speed and reliability bound.
#[derive(Debug, Clone)]
pub struct ReliablePlan {
    pub sim: SimulatedPlan,
    pub bound: LostWorkBound,
}

/// The fastest (by simulated seconds-per-sequence) configuration whose
/// expected lost work stays within `rel.max_lost_work`.
///
/// Candidates: the grid-search winner, the §5 closed-form plan, and —
/// because the offload decision is the reliability lever (checkpoint
/// interval 1 vs [`CLASSIC_CKPT_INTERVAL_STEPS`]) — each one's
/// offload-flipped twin, even when it is slower. Returns `None` when no
/// candidate fits the device memory and the budget at once.
pub fn plan_with_reliability(
    model: &XModel,
    cluster: &ClusterSpec,
    strategy: Strategy,
    menu: ParallelismMenu,
    rel: &ReliabilityParams,
) -> Option<ReliablePlan> {
    let mut seeds: Vec<Plan> = Vec::new();
    if let Some(p) = search_fastest_tp(model, cluster, strategy, menu, None) {
        seeds.push(p);
    }
    if let Some(p) = fastest_plan(model, cluster, strategy, menu) {
        seeds.push(p);
    }
    let mut candidates: Vec<Plan> = Vec::with_capacity(2 * seeds.len());
    for p in &seeds {
        let cfg = TrainConfig { offload: !p.cfg.offload, ..p.cfg };
        candidates.push(Plan::build_pub(model, cfg, cluster));
    }
    candidates.extend(seeds);
    candidates.retain(|p| {
        p.fits_gpu(cluster)
            && lost_work_bound(model, cluster, p, rel).fraction <= rel.max_lost_work
    });
    let sim = rank_by_simulation(model, cluster, &candidates)?;
    let bound = lost_work_bound(model, cluster, &sim.plan, rel);
    Some(ReliablePlan { sim, bound })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rel(mtbf_hours: f64, max_lost_work: f64) -> ReliabilityParams {
        ReliabilityParams { mtbf_hours, max_lost_work }
    }

    fn seed_plan(model: &XModel, cluster: &ClusterSpec) -> Plan {
        search_fastest_tp(model, cluster, Strategy::Improved, ParallelismMenu::THREE_D, None)
            .expect("the reference cluster plans the improved strategy")
    }

    #[test]
    fn offload_shrinks_the_checkpoint_interval_and_the_bound() {
        let model = XModel::x160();
        let cluster = ClusterSpec::reference();
        let seed = seed_plan(&model, &cluster);
        let r = rel(200.0, 1.0);
        let on = Plan::build_pub(&model, TrainConfig { offload: true, ..seed.cfg }, &cluster);
        let off = Plan::build_pub(&model, TrainConfig { offload: false, ..seed.cfg }, &cluster);
        let b_on = lost_work_bound(&model, &cluster, &on, &r);
        let b_off = lost_work_bound(&model, &cluster, &off, &r);
        assert_eq!(b_on.ckpt_interval, 1);
        assert_eq!(b_off.ckpt_interval, CLASSIC_CKPT_INTERVAL_STEPS);
        assert!(b_on.step_secs > 0.0 && b_off.step_secs > 0.0);
        assert!(b_on.restore_secs > 0.0, "offloaded schedules restore params every step");
        assert!(
            b_on.fraction < b_off.fraction,
            "streamed checkpoints must cut expected lost work: {} vs {}",
            b_on.fraction,
            b_off.fraction
        );
    }

    #[test]
    fn a_binding_budget_forces_the_streamed_checkpoint_plan() {
        let model = XModel::x160();
        let cluster = ClusterSpec::reference();
        let seed = seed_plan(&model, &cluster);
        let r0 = rel(200.0, 1.0);
        let on = Plan::build_pub(&model, TrainConfig { offload: true, ..seed.cfg }, &cluster);
        let off = Plan::build_pub(&model, TrainConfig { offload: false, ..seed.cfg }, &cluster);
        let b_on = lost_work_bound(&model, &cluster, &on, &r0).fraction;
        let b_off = lost_work_bound(&model, &cluster, &off, &r0).fraction;
        // A budget strictly between the two bounds admits only the
        // streamed-checkpoint (offloaded) candidates.
        let mid = (b_on.max(1e-15) * b_off).sqrt();
        assert!(b_on < mid && mid < b_off);
        let picked = plan_with_reliability(
            &model,
            &cluster,
            Strategy::Improved,
            ParallelismMenu::THREE_D,
            &rel(200.0, mid),
        )
        .expect("the offloaded twin fits the budget");
        assert!(picked.sim.plan.cfg.offload, "a binding budget must select offload");
        assert!(picked.bound.fraction <= mid);
    }

    #[test]
    fn a_loose_budget_does_not_distort_the_ranking() {
        let model = XModel::x160();
        let cluster = ClusterSpec::reference();
        let picked = plan_with_reliability(
            &model,
            &cluster,
            Strategy::Improved,
            ParallelismMenu::THREE_D,
            &rel(1.0e9, 1.0),
        )
        .expect("an effectively infinite MTBF rejects nothing");
        assert!(picked.sim.plan.fits_gpu(&cluster));
        assert!(picked.bound.fraction <= 1.0);
        assert!(picked.bound.fraction < 1e-3, "a 1e9-hour MTBF implies negligible lost work");
    }
}
