//! Std-only fork-join helpers for the planner's fan-outs.
//!
//! The planner parallelises at two grains: over sweep x-values (the
//! figure/table generators) and over candidate configurations inside one
//! [`super::search::search_fastest`] call. Both use scoped threads with a
//! self-scheduling atomic work queue — the cheap, dependency-free cousin
//! of work stealing: idle workers keep claiming the next unclaimed index,
//! so an uneven item (a big model's search next to a tiny one's) never
//! leaves the other cores parked.
//!
//! Nested fan-outs collapse to serial execution automatically (a worker
//! thread marks itself with a thread-local flag), so a parallel sweep of
//! parallel searches does not oversubscribe the machine: whichever level
//! fans out first wins the threads.

use std::cell::Cell;
use std::sync::atomic::{AtomicUsize, Ordering};
use std::sync::OnceLock;

thread_local! {
    static IN_FAN_OUT: Cell<bool> = const { Cell::new(false) };
}

/// True on a worker thread spawned by [`par_map`] (or the search's
/// candidate fan-out): nested parallel regions should run serial.
pub fn in_parallel_region() -> bool {
    IN_FAN_OUT.with(|c| c.get())
}

/// Mark the current thread as a fan-out worker. Crate-internal: the
/// search and ranking loops spawn their own scoped workers and need the
/// same nesting guard `par_map` applies.
pub(crate) fn mark_worker() {
    IN_FAN_OUT.with(|c| c.set(true));
}

/// Number of worker threads planner fan-outs use: the `PLANNER_THREADS`
/// environment variable when set (and positive), else
/// `std::thread::available_parallelism()`. Computed once per process.
pub fn planner_threads() -> usize {
    static N: OnceLock<usize> = OnceLock::new();
    *N.get_or_init(|| {
        std::env::var("PLANNER_THREADS")
            .ok()
            .and_then(|v| v.parse::<usize>().ok())
            .filter(|&n| n > 0)
            .unwrap_or_else(|| {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            })
    })
}

/// Map `f` over `items` on up to [`planner_threads`] scoped threads,
/// preserving order. Workers self-schedule one index at a time, so the
/// call balances uneven per-item cost; it falls back to a plain serial
/// map when only one thread is available, the input is tiny, or the
/// caller is itself a fan-out worker.
///
/// `R: Sync` is required because results land in shared
/// `OnceLock` slots that every worker holds a reference to.
pub fn par_map<T, R, F>(items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send + Sync,
    F: Fn(usize, &T) -> R + Sync,
{
    par_map_with(items, || (), |_state, i, t| f(i, t))
}

/// [`par_map`] with per-worker mutable state: each worker (or the serial
/// fallback) calls `init` once and threads the value through its items.
/// The planner's simulate-in-the-loop ranking uses this to give every
/// worker its own reusable `SimScratch`.
pub fn par_map_with<T, S, R, I, F>(items: &[T], init: I, f: F) -> Vec<R>
where
    T: Sync,
    R: Send + Sync,
    I: Fn() -> S + Sync,
    F: Fn(&mut S, usize, &T) -> R + Sync,
{
    let n = items.len();
    let threads = planner_threads().min(n);
    if threads <= 1 || in_parallel_region() {
        let mut state = init();
        return items.iter().enumerate().map(|(i, t)| f(&mut state, i, t)).collect();
    }
    let slots: Vec<OnceLock<R>> = std::iter::repeat_with(OnceLock::new).take(n).collect();
    let next = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| {
                mark_worker();
                let mut state = init();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= n {
                        break;
                    }
                    let _ = slots[i].set(f(&mut state, i, &items[i]));
                }
            });
        }
    });
    slots
        .into_iter()
        .map(|slot| slot.into_inner().expect("every index was claimed by a worker"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_preserves_order_and_values() {
        let items: Vec<usize> = (0..1000).collect();
        let out = par_map(&items, |i, &x| {
            assert_eq!(i, x);
            x * x
        });
        assert_eq!(out.len(), 1000);
        for (i, v) in out.iter().enumerate() {
            assert_eq!(*v, i * i);
        }
    }

    #[test]
    fn par_map_handles_empty_and_single() {
        let empty: Vec<u32> = vec![];
        assert!(par_map(&empty, |_, &x| x).is_empty());
        assert_eq!(par_map(&[7u32], |_, &x| x + 1), vec![8]);
    }

    #[test]
    fn nested_par_map_runs_serial_without_deadlock() {
        let items: Vec<usize> = (0..16).collect();
        let out = par_map(&items, |_, &x| {
            let inner: Vec<usize> = (0..8).collect();
            par_map(&inner, |_, &y| x * y).iter().sum::<usize>()
        });
        for (x, v) in out.iter().enumerate() {
            assert_eq!(*v, x * 28);
        }
    }

    #[test]
    fn par_map_with_gives_each_worker_its_own_state() {
        // Every worker counts the items it processed into its own state;
        // the per-item results must still be position-correct.
        let items: Vec<usize> = (0..200).collect();
        let out = par_map_with(
            &items,
            || 0usize,
            |seen, i, &x| {
                *seen += 1;
                (i, x, *seen)
            },
        );
        for (i, &(oi, ox, seen)) in out.iter().enumerate() {
            assert_eq!((oi, ox), (i, i));
            assert!(seen >= 1 && seen <= items.len());
        }
    }

    #[test]
    fn planner_threads_is_positive() {
        assert!(planner_threads() >= 1);
    }
}
